"""Layer-1 kernel correctness: Pallas vs pure-jnp oracle (ref.py).

This is the CORE correctness signal for the compute layer — hypothesis
sweeps shapes and dtypes-edge values and asserts allclose against the
reference on every draw.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.dgc_pallas import dgc_step
from compile.kernels.matmul_pallas import matmul, matmul_pallas_raw, _pick_block
from compile.kernels.ref import dgc_step_ref, matmul_ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------

dims = st.sampled_from([1, 2, 3, 8, 16, 27, 50, 64, 100, 128, 200, 256])


@given(m=dims, k=dims, n=dims, seed=st.integers(0, 2**31 - 1))
def test_matmul_matches_ref_across_shapes(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(m, k)).astype(np.float32)
    b = rng.normal(size=(k, n)).astype(np.float32)
    got = np.asarray(matmul_pallas_raw(a, b))
    want = np.asarray(matmul_ref(a, b))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@given(scale=st.sampled_from([1e-20, 1e-3, 1.0, 1e3, 1e10]))
def test_matmul_extreme_scales(scale):
    rng = np.random.default_rng(7)
    a = (rng.normal(size=(16, 32)) * scale).astype(np.float32)
    b = rng.normal(size=(32, 8)).astype(np.float32)
    got = np.asarray(matmul_pallas_raw(a, b))
    want = np.asarray(matmul_ref(a, b))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4 * max(scale, 1.0))


def test_matmul_identity():
    eye = np.eye(64, dtype=np.float32)
    x = np.random.default_rng(0).normal(size=(64, 64)).astype(np.float32)
    np.testing.assert_allclose(np.asarray(matmul_pallas_raw(x, eye)), x, rtol=1e-6)


def test_matmul_zeros():
    a = np.zeros((32, 16), np.float32)
    b = np.ones((16, 8), np.float32)
    assert np.all(np.asarray(matmul_pallas_raw(a, b)) == 0.0)


def test_matmul_vjp_matches_ref():
    rng = np.random.default_rng(3)
    a = rng.normal(size=(64, 384)).astype(np.float32)
    b = rng.normal(size=(384, 256)).astype(np.float32)

    def f(a, b):
        return jnp.mean(matmul(a, b) ** 2)

    def fr(a, b):
        return jnp.mean(matmul_ref(a, b) ** 2)

    ga, gb = jax.grad(f, argnums=(0, 1))(a, b)
    gar, gbr = jax.grad(fr, argnums=(0, 1))(a, b)
    np.testing.assert_allclose(np.asarray(ga), np.asarray(gar), rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gb), np.asarray(gbr), rtol=1e-3, atol=1e-4)


def test_matmul_under_jit():
    rng = np.random.default_rng(5)
    a = rng.normal(size=(8, 24)).astype(np.float32)
    b = rng.normal(size=(24, 8)).astype(np.float32)
    got = jax.jit(matmul)(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(matmul_ref(a, b)), rtol=1e-4, atol=1e-5)


@given(dim=st.integers(1, 300), target=st.sampled_from([8, 64, 128, 4096]))
def test_pick_block_divides_and_bounded(dim, target):
    b = _pick_block(dim, target)
    assert 1 <= b <= max(target, 1)
    assert dim % b == 0


# ---------------------------------------------------------------------------
# DGC kernel
# ---------------------------------------------------------------------------

@given(
    n=st.sampled_from([1, 2, 7, 64, 1000, 4096, 5000]),
    sigma=st.sampled_from([0.0, 0.5, 0.9]),
    thresh=st.sampled_from([0.0, 0.5, 1.5, 100.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_dgc_matches_ref(n, sigma, thresh, seed):
    rng = np.random.default_rng(seed)
    g = rng.normal(size=(n,)).astype(np.float32)
    u = rng.normal(size=(n,)).astype(np.float32)
    v = rng.normal(size=(n,)).astype(np.float32)
    got = dgc_step(g, u, v, sigma, thresh)
    want = dgc_step_ref(g, u, v, sigma, thresh)
    for name, o, r in zip(("ghat", "u", "v"), got, want):
        np.testing.assert_allclose(
            np.asarray(o), np.asarray(r), rtol=1e-6, atol=1e-6, err_msg=name
        )


def test_dgc_invariants():
    """ghat + v_next == v + sigma*u + g (nothing lost), disjoint supports."""
    rng = np.random.default_rng(11)
    n = 512
    g = rng.normal(size=(n,)).astype(np.float32)
    u = rng.normal(size=(n,)).astype(np.float32)
    v = rng.normal(size=(n,)).astype(np.float32)
    ghat, u2, v2 = (np.asarray(x) for x in dgc_step(g, u, v, 0.9, 1.0))
    total = v + 0.9 * u + g
    np.testing.assert_allclose(ghat + v2, total, rtol=1e-5, atol=1e-6)
    # A coordinate is either transmitted or retained, never both.
    assert np.all((ghat == 0.0) | (v2 == 0.0))
    assert np.all((ghat == 0.0) | (u2 == 0.0))


def test_dgc_threshold_zero_sends_all():
    g = np.ones(64, np.float32)
    z = np.zeros(64, np.float32)
    ghat, u2, v2 = (np.asarray(x) for x in dgc_step(g, z, z, 0.0, 0.0))
    np.testing.assert_allclose(ghat, g)
    assert np.all(u2 == 0.0) and np.all(v2 == 0.0)


def test_dgc_huge_threshold_sends_nothing():
    rng = np.random.default_rng(13)
    g = rng.normal(size=(128,)).astype(np.float32)
    z = np.zeros(128, np.float32)
    ghat, u2, v2 = (np.asarray(x) for x in dgc_step(g, z, z, 0.0, 1e9))
    assert np.all(ghat == 0.0)
    np.testing.assert_allclose(v2, g, rtol=1e-6)
