"""Layer-2 model tests: flat packing, Pallas-vs-reference forward/backward
equivalence, and a short end-to-end training sanity run per variant."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

MODELS = ("mlp", "cnn")


def synth_batch(n, seed=0):
    """Linearly-separable-ish synthetic batch for sanity training."""
    rng = np.random.default_rng(seed)
    y = rng.integers(0, M.N_CLASSES, size=n).astype(np.int32)
    templates = rng.normal(size=(M.N_CLASSES, M.INPUT_DIM)).astype(np.float32)
    x = templates[y] + 0.5 * rng.normal(size=(n, M.INPUT_DIM)).astype(np.float32)
    return x.astype(np.float32), y


@pytest.mark.parametrize("model", MODELS)
def test_param_count_matches_layout(model):
    q = M.n_params(model)
    flat = M.init_params(model, seed=0)
    assert flat.shape == (q,)
    parts = M.unpack(model, flat)
    total = sum(int(np.prod(v.shape)) for v in parts.values())
    assert total == q


@pytest.mark.parametrize("model", MODELS)
def test_init_deterministic_and_scaled(model):
    a = np.asarray(M.init_params(model, seed=0))
    b = np.asarray(M.init_params(model, seed=0))
    np.testing.assert_array_equal(a, b)
    c = np.asarray(M.init_params(model, seed=1))
    assert not np.array_equal(a, c)
    # He-init: weight std near sqrt(2/fan_in); biases zero.
    parts = M.unpack(model, jnp.asarray(a))
    for name, w in parts.items():
        if w.ndim == 1:
            assert np.all(np.asarray(w) == 0.0), name
        else:
            std = float(np.std(np.asarray(w)))
            want = (2.0 / w.shape[0]) ** 0.5
            assert abs(std - want) / want < 0.15, (name, std, want)


@pytest.mark.parametrize("model", MODELS)
def test_forward_shapes(model):
    flat = M.init_params(model, 0)
    x, _ = synth_batch(16, 1)
    logits = M.forward(model, flat, x, use_pallas=False)
    assert logits.shape == (16, M.N_CLASSES)


@pytest.mark.parametrize("model", MODELS)
def test_pallas_forward_matches_reference(model):
    flat = M.init_params(model, 0)
    x, _ = synth_batch(8, 2)
    ref = np.asarray(M.forward(model, flat, x, use_pallas=False))
    pal = np.asarray(M.forward(model, flat, x, use_pallas=True))
    np.testing.assert_allclose(pal, ref, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("model", MODELS)
def test_pallas_gradient_matches_reference(model):
    flat = M.init_params(model, 0)
    x, y = synth_batch(8, 3)
    loss_r, grad_r = M.train_step(model, flat, x, y, use_pallas=False)
    loss_p, grad_p = M.train_step(model, flat, x, y, use_pallas=True)
    assert abs(float(loss_r) - float(loss_p)) < 1e-4
    np.testing.assert_allclose(
        np.asarray(grad_p), np.asarray(grad_r), rtol=1e-3, atol=1e-4
    )


@pytest.mark.parametrize("model", MODELS)
def test_gradient_is_finite_and_nonzero(model):
    flat = M.init_params(model, 0)
    x, y = synth_batch(8, 4)
    _, grad = M.train_step(model, flat, x, y, use_pallas=False)
    g = np.asarray(grad)
    assert np.all(np.isfinite(g))
    assert np.abs(g).max() > 0.0


@pytest.mark.parametrize("model", MODELS)
def test_eval_step_counts(model):
    flat = M.init_params(model, 0)
    x, y = synth_batch(32, 5)
    loss_sum, correct = M.eval_step(model, flat, x, y, use_pallas=False)
    assert 0.0 <= float(correct) <= 32.0
    # Untrained loss ≈ 32·ln10.
    assert abs(float(loss_sum) / 32.0 - np.log(10)) < 1.5


@pytest.mark.parametrize("model", MODELS)
def test_short_training_reduces_loss(model):
    """A few SGD steps on a separable toy set must reduce the loss — proves
    fwd+bwd compose correctly end-to-end (reference path; the Pallas path is
    equivalence-tested above)."""
    flat = M.init_params(model, 0)
    x, y = synth_batch(64, 6)
    step = jax.jit(lambda w: M.train_step(model, w, x, y, use_pallas=False))
    loss0, _ = step(flat)
    for _ in range(30):
        _, g = step(flat)
        flat = flat - 0.05 * g
    loss1, _ = step(flat)
    assert float(loss1) < 0.7 * float(loss0), (float(loss0), float(loss1))


def test_unpack_is_pure_view_roundtrip():
    model = "mlp"
    q = M.n_params(model)
    flat = jnp.arange(q, dtype=jnp.float32)
    parts = M.unpack(model, flat)
    recon = jnp.concatenate([parts[n].reshape(-1) for n, _ in M.layer_shapes(model)])
    np.testing.assert_array_equal(np.asarray(recon), np.asarray(flat))
