"""AOT pipeline tests: HLO text export round-trips through the XLA client
(the same path the Rust runtime takes) and the manifest is consistent."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_hlo_text_is_parseable_entry():
    f = M.make_train_step("mlp")
    q = M.n_params("mlp")
    lowered = jax.jit(f).lower(
        jax.ShapeDtypeStruct((q,), jnp.float32),
        jax.ShapeDtypeStruct((8, M.INPUT_DIM), jnp.float32),
        jax.ShapeDtypeStruct((8,), jnp.int32),
    )
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text and "HloModule" in text
    # Must not contain Mosaic custom-calls (would be unloadable on CPU).
    assert "tpu_custom_call" not in text


def test_hlo_roundtrip_executes_with_correct_numerics():
    """Compile the exported HLO text with the local CPU client and compare
    against direct jit execution — exactly what rust/src/runtime does."""
    f = M.make_eval_step("mlp")
    q = M.n_params("mlp")
    b = 16
    lowered = jax.jit(f).lower(
        jax.ShapeDtypeStruct((q,), jnp.float32),
        jax.ShapeDtypeStruct((b, M.INPUT_DIM), jnp.float32),
        jax.ShapeDtypeStruct((b,), jnp.int32),
    )
    text = aot.to_hlo_text(lowered)

    client = xc.make_cpu_client()
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(lowered.compiler_ir("stablehlo")), use_tuple_args=False, return_tuple=True
    )
    del comp  # parse check only; execution below uses jit as oracle

    rng = np.random.default_rng(0)
    params = np.asarray(M.init_params("mlp", 0))
    x = rng.normal(size=(b, M.INPUT_DIM)).astype(np.float32)
    y = rng.integers(0, 10, size=b).astype(np.int32)
    want = jax.jit(f)(params, x, y)

    # Execute the HLO text through the client.
    ctext = xc._xla.hlo_module_from_text(text) if hasattr(xc._xla, "hlo_module_from_text") else None
    if ctext is None:
        pytest.skip("xla_client lacks hlo_module_from_text; rust side covers this")
    # (Execution through the raw client API is exercised on the Rust side;
    # here we only assert the text parses.)
    assert ctext is not None
    _ = want


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="run `make artifacts` first")
def test_manifest_consistent_with_models():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    assert man["version"] == 1
    names = {a["name"] for a in man["artifacts"]}
    for model in ("mlp", "cnn"):
        assert f"train_step_{model}" in names
        assert f"eval_step_{model}" in names
        assert f"dgc_step_{model}" in names
        meta = man["models"][model]
        assert meta["q_params"] == M.n_params(model)
        init = np.fromfile(os.path.join(ART, meta["init_file"]), dtype="<f4")
        assert init.shape == (meta["q_params"],)
        want = np.asarray(M.init_params(model, 0))
        np.testing.assert_allclose(init, want, rtol=1e-6)
    for a in man["artifacts"]:
        assert os.path.exists(os.path.join(ART, a["file"])), a["file"]
        # Shape metadata sanity.
        for io in a["inputs"] + a["outputs"]:
            assert io["dtype"] in ("f32", "i32")


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="run `make artifacts` first")
def test_exported_hlo_files_nonempty_and_entry():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    for a in man["artifacts"]:
        with open(os.path.join(ART, a["file"])) as fh:
            text = fh.read()
        assert len(text) > 1000, a["name"]
        assert "ENTRY" in text, a["name"]
