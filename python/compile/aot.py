"""AOT exporter: lower the Layer-2 training graph to HLO *text* artifacts.

Run once at build time (``make artifacts``); the Rust coordinator loads the
results through the PJRT CPU client and Python never touches the training
path again.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 rejects;
the text parser reassigns ids (see /opt/xla-example/README.md).

Artifacts written to ``--out`` (default ../artifacts):

    train_step_{mlp,cnn}.hlo.txt   (params[Q], x[64,3072], y[i32 64]) -> (loss, grad[Q])
    eval_step_{mlp,cnn}.hlo.txt    (params[Q], x[256,3072], y[i32 256]) -> (loss_sum, correct)
    dgc_step_{mlp,cnn}.hlo.txt     (g[Q], u[Q], v[Q], sigma, thresh) -> (ghat, u', v')
    init_{mlp,cnn}.f32             raw little-endian f32[Q] initial parameters
    manifest.json                  shapes/metadata consumed by rust/src/runtime
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels.dgc_pallas import dgc_step

TRAIN_BATCH = 64
EVAL_BATCH = 256
MODELS = ("mlp", "cnn")


def to_hlo_text(lowered):
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def export_model(model, out_dir, manifest):
    q = M.n_params(model)
    p_spec = spec((q,))

    # --- train step ---
    train = M.make_train_step(model)
    lowered = jax.jit(train).lower(
        p_spec, spec((TRAIN_BATCH, M.INPUT_DIM)), spec((TRAIN_BATCH,), jnp.int32)
    )
    path = f"train_step_{model}.hlo.txt"
    with open(os.path.join(out_dir, path), "w") as f:
        f.write(to_hlo_text(lowered))
    manifest["artifacts"].append(
        {
            "name": f"train_step_{model}",
            "file": path,
            "inputs": [
                {"shape": [q], "dtype": "f32"},
                {"shape": [TRAIN_BATCH, M.INPUT_DIM], "dtype": "f32"},
                {"shape": [TRAIN_BATCH], "dtype": "i32"},
            ],
            "outputs": [
                {"shape": [], "dtype": "f32"},
                {"shape": [q], "dtype": "f32"},
            ],
        }
    )

    # --- eval step ---
    ev = M.make_eval_step(model)
    lowered = jax.jit(ev).lower(
        p_spec, spec((EVAL_BATCH, M.INPUT_DIM)), spec((EVAL_BATCH,), jnp.int32)
    )
    path = f"eval_step_{model}.hlo.txt"
    with open(os.path.join(out_dir, path), "w") as f:
        f.write(to_hlo_text(lowered))
    manifest["artifacts"].append(
        {
            "name": f"eval_step_{model}",
            "file": path,
            "inputs": [
                {"shape": [q], "dtype": "f32"},
                {"shape": [EVAL_BATCH, M.INPUT_DIM], "dtype": "f32"},
                {"shape": [EVAL_BATCH], "dtype": "i32"},
            ],
            "outputs": [
                {"shape": [], "dtype": "f32"},
                {"shape": [], "dtype": "f32"},
            ],
        }
    )

    # --- fused DGC step (ablation: XLA sparsifier vs native Rust) ---
    lowered = jax.jit(dgc_step).lower(
        spec((q,)), spec((q,)), spec((q,)), spec(()), spec(())
    )
    path = f"dgc_step_{model}.hlo.txt"
    with open(os.path.join(out_dir, path), "w") as f:
        f.write(to_hlo_text(lowered))
    manifest["artifacts"].append(
        {
            "name": f"dgc_step_{model}",
            "file": path,
            "inputs": [
                {"shape": [q], "dtype": "f32"},
                {"shape": [q], "dtype": "f32"},
                {"shape": [q], "dtype": "f32"},
                {"shape": [], "dtype": "f32"},
                {"shape": [], "dtype": "f32"},
            ],
            "outputs": [
                {"shape": [q], "dtype": "f32"},
                {"shape": [q], "dtype": "f32"},
                {"shape": [q], "dtype": "f32"},
            ],
        }
    )

    # --- deterministic initial parameters (raw f32 little-endian) ---
    import numpy as np

    init = np.asarray(M.init_params(model, seed=0), dtype="<f4")
    init_path = f"init_{model}.f32"
    init.tofile(os.path.join(out_dir, init_path))
    manifest["models"][model] = {
        "q_params": q,
        "train_batch": TRAIN_BATCH,
        "eval_batch": EVAL_BATCH,
        "input_dim": M.INPUT_DIM,
        "n_classes": M.N_CLASSES,
        "init_file": init_path,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default=",".join(MODELS))
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    manifest = {"version": 1, "artifacts": [], "models": {}}
    for model in args.models.split(","):
        print(f"exporting {model} ...", flush=True)
        export_model(model.strip(), args.out, manifest)
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {len(manifest['artifacts'])} artifacts to {args.out}")


if __name__ == "__main__":
    main()
