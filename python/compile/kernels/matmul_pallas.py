"""MXU-tiled Pallas GEMM (Layer 1).

TPU-shaped even though we execute in interpret mode on CPU: (bm, bk) and
(bk, bn) operand tiles are staged HBM->VMEM by BlockSpec and accumulated
directly into the resident (bm, bn) output block across the K grid axis
(the innermost grid dimension revisits the same output block, the classic
Pallas accumulation pattern). VMEM footprint per grid step is
bm*bk + bk*bn + bm*bn floats -- 3 x 64 KiB at the default 128^3 tile, far
under the ~16 MiB budget; arithmetic intensity 128/3 ~= 42.7 FLOP/byte
keeps the MXU busy (DESIGN.md section 2).

``matmul`` wraps the kernel in ``jax.custom_vjp`` so reverse-mode autodiff
(the Layer-2 backward pass) also runs through the Pallas kernel:
dA = dC @ B^T and dB = A^T @ dC.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(a_ref, b_ref, o_ref, *, n_k):
    """Grid point (i, j, k): accumulate A[i,k] @ B[k,j] into the o block."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


def _pick_block(dim, target):
    """Largest divisor of ``dim`` that is <= target, MXU-aligned preferred."""
    for cand in (target, 256, 128, 64, 32, 16, 8):
        if cand <= target and dim % cand == 0:
            return cand
    # Odd dimension (e.g. the CNN's 27-wide im2col K): largest divisor.
    for cand in range(min(dim, target), 0, -1):
        if dim % cand == 0:
            return cand
    return 1


def matmul_pallas_raw(a, b, bm=128, bk=512, bn=128):
    """The raw forward kernel call (no autodiff wrapper)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {a.shape} @ {b.shape}"
    bm = _pick_block(m, bm)
    bk = _pick_block(k, bk)
    bn = _pick_block(n, bn)
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)


@jax.custom_vjp
def matmul(a, b):
    """Pallas GEMM with a Pallas backward pass (f32 in/out)."""
    return matmul_pallas_raw(a, b)


def _matmul_fwd(a, b):
    return matmul_pallas_raw(a, b), (a, b)


def _matmul_bwd(res, dc):
    a, b = res
    da = matmul_pallas_raw(dc, b.T)
    db = matmul_pallas_raw(a.T, dc)
    return da, db


matmul.defvjp(_matmul_fwd, _matmul_bwd)
