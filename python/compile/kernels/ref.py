"""Pure-jnp oracles for the Pallas kernels — the correctness ground truth.

Every kernel test asserts `kernel(x) ≈ ref(x)`; the AOT artifacts are only
built from kernels that pass those tests.
"""

import jax.numpy as jnp


def matmul_ref(a, b):
    """Plain dense GEMM, f32 accumulation."""
    return jnp.matmul(a, b, preferred_element_type=jnp.float32)


def dgc_step_ref(g, u, v, sigma, thresh):
    """One DGC sparsification step (Algorithm 4 lines 6-12).

    Returns ``(ghat, u_next, v_next)``:

        u' = sigma * u + g
        v' = v + u'
        mask = |v'| >= thresh
        ghat = v' * mask
        u_next = u' * (1 - mask)
        v_next = v' * (1 - mask)
    """
    u_new = sigma * u + g
    v_new = v + u_new
    mask = (jnp.abs(v_new) >= thresh).astype(v_new.dtype)
    ghat = v_new * mask
    keep = 1.0 - mask
    return ghat, u_new * keep, v_new * keep
