"""Fused DGC sparsification kernel (Layer 1).

One pass over the flat gradient fuses the five elementwise stages of
Algorithm 4 (momentum-correct, error-accumulate, threshold, mask-apply,
buffer-mask) so each of g/u/v is read and written exactly once per step —
on TPU this is one HBM round-trip per buffer instead of five.

The vector is processed in 1-D blocks staged through VMEM; the threshold
is a scalar operand broadcast to every block (the top-k quantile itself is
computed by the caller — quickselect in the Rust coordinator, or
``jnp.quantile`` in the reference path).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dgc_kernel(sigma_ref, thresh_ref, g_ref, u_ref, v_ref, ghat_ref, u_out_ref, v_out_ref):
    sigma = sigma_ref[0]
    thresh = thresh_ref[0]
    u_new = sigma * u_ref[...] + g_ref[...]
    v_new = v_ref[...] + u_new
    mask = (jnp.abs(v_new) >= thresh).astype(v_new.dtype)
    keep = 1.0 - mask
    ghat_ref[...] = v_new * mask
    u_out_ref[...] = u_new * keep
    v_out_ref[...] = v_new * keep


def _pick_block(n, target=4096):
    for cand in (target, 2048, 1024, 512, 256, 128, 64, 32, 16, 8, 4, 2, 1):
        if cand <= target and n % cand == 0:
            return cand
    return 1


def dgc_step(g, u, v, sigma, thresh):
    """Fused DGC step on flat f32 vectors.

    Args:
        g, u, v: f32[Q] gradient / momentum buffer / error buffer.
        sigma: scalar momentum factor.
        thresh: scalar magnitude threshold (phi-quantile of ``|v + sigma*u + g|``).

    Returns:
        (ghat, u_next, v_next) — each f32[Q].

    Q is padded up to a 4096 multiple before the kernel and sliced back
    after: block pickers that merely *divide* Q degenerate catastrophically
    on odd lengths (e.g. Q=820,874 factors as 2 x 410,437 -> a 410k-step
    interpret grid; see EXPERIMENTS.md section Perf). Zero padding is exact:
    padded u', v' stay 0 and padded ghat is 0.
    """
    (n,) = g.shape
    pad = (-n) % 4096
    if pad:
        z = jnp.zeros((pad,), g.dtype)
        g = jnp.concatenate([g, z])
        u = jnp.concatenate([u, z])
        v = jnp.concatenate([v, z])
    n_padded = n + pad
    bn = _pick_block(n_padded)
    grid = (n_padded // bn,)
    sigma = jnp.asarray(sigma, jnp.float32).reshape((1,))
    thresh = jnp.asarray(thresh, jnp.float32).reshape((1,))
    shapes = [jax.ShapeDtypeStruct((n_padded,), jnp.float32)] * 3
    vec = pl.BlockSpec((bn,), lambda i: (i,))
    scalar = pl.BlockSpec((1,), lambda i: (0,))
    ghat, u_next, v_next = pl.pallas_call(
        _dgc_kernel,
        grid=grid,
        in_specs=[scalar, scalar, vec, vec, vec],
        out_specs=[vec, vec, vec],
        out_shape=shapes,
        interpret=True,
    )(sigma, thresh, g, u, v)
    if pad:
        ghat, u_next, v_next = ghat[:n], u_next[:n], v_next[:n]
    return ghat, u_next, v_next
