"""Layer-1 Pallas kernels (build-time only).

Two kernels back the training graph:

* :mod:`matmul_pallas` — MXU-tiled GEMM used by every dense/conv-as-GEMM
  layer of the Layer-2 model, wrapped in ``jax.custom_vjp`` so the backward
  pass also runs through the kernel.
* :mod:`dgc_pallas` — fused DGC sparsification step (momentum-correct,
  error-accumulate, threshold-mask) used by the ``dgc_step`` AOT artifact.

Both are verified against the pure-jnp oracles in :mod:`ref` and lowered
with ``interpret=True`` (the CPU PJRT plugin cannot execute Mosaic
custom-calls; see DESIGN.md §Hardware-Adaptation).
"""
