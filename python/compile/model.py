"""Layer-2 JAX model: forward/backward on a FLAT parameter vector.

The Rust coordinator owns the model as one ``f32[Q]`` vector (the ``w`` of
Eq. 1) — sparsification, momentum and averaging all operate coordinate-wise
on it. This module defines:

* two model variants ("mlp", "cnn") for 32x32x3 10-class images,
* deterministic pack/unpack between the flat vector and layer shapes,
* ``train_step(params, x, y) -> (loss, grad)`` — the AOT hot path,
* ``eval_step(params, x, y) -> (loss_sum, correct)`` — held-out metrics,
* ``init_params(seed) -> flat`` — He-initialised weights.

Every dense contraction (the model's FLOP hot-spot) routes through the
Layer-1 Pallas GEMM (`kernels.matmul_pallas.matmul`); set
``use_pallas=False`` to get the pure-jnp reference for oracle tests.
The CNN implements convolution as im2col + GEMM, the standard TPU/MXU
mapping (DESIGN.md section Hardware-Adaptation).
"""

import functools

import jax
import jax.numpy as jnp

from .kernels.matmul_pallas import matmul as matmul_pallas
from .kernels.ref import matmul_ref

IMAGE_SHAPE = (32, 32, 3)
N_CLASSES = 10
INPUT_DIM = 32 * 32 * 3


def _mm(use_pallas):
    return matmul_pallas if use_pallas else matmul_ref


# ---------------------------------------------------------------------------
# Parameter shapes
# ---------------------------------------------------------------------------

def layer_shapes(model):
    """Ordered (name, shape) pairs defining the flat layout."""
    if model == "mlp":
        return [
            ("w1", (INPUT_DIM, 256)),
            ("b1", (256,)),
            ("w2", (256, 128)),
            ("b2", (128,)),
            ("w3", (128, N_CLASSES)),
            ("b3", (N_CLASSES,)),
        ]
    if model == "cnn":
        return [
            ("conv1", (3 * 3 * 3, 16)),   # 3x3 kernel over 3 channels -> 16
            ("bc1", (16,)),
            ("conv2", (3 * 3 * 16, 32)),  # 3x3 over 16 -> 32
            ("bc2", (32,)),
            ("w1", (8 * 8 * 32, 64)),
            ("b1", (64,)),
            ("w2", (64, N_CLASSES)),
            ("b2", (N_CLASSES,)),
        ]
    raise ValueError(f"unknown model {model!r}")


def n_params(model):
    """Total flat dimension Q."""
    total = 0
    for _, shape in layer_shapes(model):
        size = 1
        for s in shape:
            size *= s
        total += size
    return total


def unpack(model, flat):
    """Flat f32[Q] -> dict of shaped arrays (pure reshape/slice)."""
    params = {}
    off = 0
    for name, shape in layer_shapes(model):
        size = 1
        for s in shape:
            size *= s
        params[name] = flat[off : off + size].reshape(shape)
        off += size
    return params


def init_params(model, seed=0):
    """He-normal weights, zero biases, packed flat. Deterministic."""
    key = jax.random.PRNGKey(seed)
    chunks = []
    for name, shape in layer_shapes(model):
        key, sub = jax.random.split(key)
        if len(shape) == 1:
            chunks.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = shape[0]
            std = (2.0 / fan_in) ** 0.5
            chunks.append(std * jax.random.normal(sub, shape, jnp.float32))
    return jnp.concatenate([c.reshape(-1) for c in chunks])


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def _im2col(x, kh, kw):
    """N,H,W,C -> N*H*W, kh*kw*C patches with SAME padding (stride 1)."""
    n, h, w, c = x.shape
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    cols = []
    for di in range(kh):
        for dj in range(kw):
            cols.append(xp[:, di : di + h, dj : dj + w, :])
    # (N, H, W, kh*kw*C)
    patches = jnp.concatenate(cols, axis=-1)
    return patches.reshape(n * h * w, kh * kw * c)


def _avg_pool2(x):
    """2x2 average pooling, N,H,W,C."""
    n, h, w, c = x.shape
    x = x.reshape(n, h // 2, 2, w // 2, 2, c)
    return x.mean(axis=(2, 4))


def forward(model, flat, x, use_pallas=True):
    """Logits f32[N, 10]. ``x`` is f32[N, 3072] (flattened, normalized)."""
    mm = _mm(use_pallas)
    p = unpack(model, flat)
    if model == "mlp":
        h = jax.nn.relu(mm(x, p["w1"]) + p["b1"])
        h = jax.nn.relu(mm(h, p["w2"]) + p["b2"])
        return mm(h, p["w3"]) + p["b3"]
    # CNN: conv-as-GEMM via im2col.
    n = x.shape[0]
    img = x.reshape(n, *IMAGE_SHAPE)
    h = _im2col(img, 3, 3)                      # (N*32*32, 27)
    h = jax.nn.relu(mm(h, p["conv1"]) + p["bc1"])
    h = _avg_pool2(h.reshape(n, 32, 32, 16))    # (N,16,16,16)
    h = _im2col(h, 3, 3)                        # (N*16*16, 144)
    h = jax.nn.relu(mm(h, p["conv2"]) + p["bc2"])
    h = _avg_pool2(h.reshape(n, 16, 16, 32))    # (N,8,8,32)
    h = h.reshape(n, 8 * 8 * 32)
    h = jax.nn.relu(mm(h, p["w1"]) + p["b1"])
    return mm(h, p["w2"]) + p["b2"]


def _softmax_xent(logits, y):
    """Mean cross-entropy over the batch; y is int32[N]."""
    logp = jax.nn.log_softmax(logits)
    picked = jnp.take_along_axis(logp, y[:, None], axis=1)[:, 0]
    return -picked.mean()


# ---------------------------------------------------------------------------
# AOT entry points
# ---------------------------------------------------------------------------

def train_step(model, flat, x, y, use_pallas=True):
    """(mean loss, flat gradient) at ``flat`` on minibatch (x, y)."""

    def loss_fn(w):
        return _softmax_xent(forward(model, w, x, use_pallas), y)

    loss, grad = jax.value_and_grad(loss_fn)(flat)
    return loss, grad


def eval_step(model, flat, x, y, use_pallas=True):
    """(summed loss, correct count) on an eval batch — chunk-accumulable."""
    logits = forward(model, flat, x, use_pallas)
    logp = jax.nn.log_softmax(logits)
    picked = jnp.take_along_axis(logp, y[:, None], axis=1)[:, 0]
    loss_sum = -picked.sum()
    correct = (jnp.argmax(logits, axis=1) == y).sum().astype(jnp.float32)
    return loss_sum, correct


def make_train_step(model, use_pallas=True):
    return functools.partial(train_step, model, use_pallas=use_pallas)


def make_eval_step(model, use_pallas=True):
    return functools.partial(eval_step, model, use_pallas=use_pallas)
