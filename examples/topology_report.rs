//! Topology report: the §V-A network layout — hexagonal clusters, reuse
//! coloring, MU placement — plus the Algorithm-2 sub-carrier allocation for
//! one cluster and for the flat-FL macro cell.
//!
//! ```bash
//! cargo run --release --example topology_report -- [--mus 8] [--clusters 7]
//! ```

use hfl::cli::Args;
use hfl::config::Config;
use hfl::topology::NetworkTopology;
use hfl::wireless::{allocate_subcarriers, LinkParams};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let mut cfg = Config::paper_table2();
    if let Some(m) = args.get_parsed::<usize>("mus")? {
        cfg.topology.mus_per_cluster = m;
    }
    if let Some(n) = args.get_parsed::<usize>("clusters")? {
        cfg.topology.n_clusters = n;
    }
    args.finish()?;

    let topo = NetworkTopology::generate(&cfg.topology);
    println!("{}", topo.ascii_map(72, 36));
    println!(
        "\n{} clusters, {} reuse colors, {} sub-carriers per cluster",
        topo.n_clusters(),
        topo.layout.n_colors,
        topo.layout.subcarriers_per_cluster(cfg.radio.subcarriers)
    );

    let link = |d: f64, p: f64| LinkParams {
        p_max_w: p,
        dist_m: d,
        alpha: cfg.radio.pathloss_exp,
        noise_w: cfg.radio.noise_power_w(),
        b0_hz: cfg.radio.subcarrier_spacing_hz,
        ber: cfg.radio.ber,
    };

    // Algorithm 2 inside cluster 1.
    let dists = topo.sbs_distances(1);
    let links: Vec<_> = dists.iter().map(|&d| link(d, cfg.radio.mu_power_w)).collect();
    let m_cluster = topo.layout.subcarriers_per_cluster(cfg.radio.subcarriers);
    let alloc = allocate_subcarriers(&links, m_cluster);
    println!("\nAlgorithm 2 within cluster 1 ({} sub-carriers):", m_cluster);
    for (i, (&d, (&c, &r))) in dists
        .iter()
        .zip(alloc.counts.iter().zip(&alloc.rates))
        .enumerate()
    {
        println!("  MU {i}: d={d:>5.0} m  {c:>3} sub-carriers  {:>8.2} Mbit/s", r / 1e6);
    }
    println!("  min rate: {:.2} Mbit/s", alloc.min_rate() / 1e6);

    // Flat FL over the macro cell.
    let links: Vec<_> = topo
        .mbs_distances()
        .iter()
        .map(|&d| link(d, cfg.radio.mu_power_w))
        .collect();
    let alloc = allocate_subcarriers(&links, cfg.radio.subcarriers);
    println!(
        "\nflat FL over the macro cell ({} MUs, {} sub-carriers): min rate {:.2} Mbit/s, max {:.2}",
        links.len(),
        cfg.radio.subcarriers,
        alloc.min_rate() / 1e6,
        alloc.max_rate() / 1e6
    );
    println!("\ntopology_report OK");
    Ok(())
}
