//! Scenario-matrix sweep: expand a custom declarative grid (clusters ×
//! MUs × non-IID skew × sparsity × H × channel profiles), run every cell in
//! parallel on the work-stealing pool, and write the shared-schema CSV plus
//! the golden-trace fixture. Results are bit-identical for any `--threads`
//! value — the example proves it by running the grid twice.
//!
//! ```bash
//! cargo run --release --example matrix_sweep -- [--threads 8] [--iters 40]
//! ```

use hfl::cli::Args;
use hfl::config::Config;
use hfl::des::{MobilityProfile, StragglerPolicy};
use hfl::sim::matrix::{ChannelProfile, MatrixOptions, ScenarioSpec};
use hfl::sim::{result, run_matrix};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let threads = args.get_parsed_or("threads", 8usize)?;
    let iters = args.get_parsed_or("iters", 40usize)?;
    let out = args.get_or("out", "results");
    args.finish()?;

    let cfg = Config::paper_table2();
    // A custom grid: the paper's 7-cluster flower plus smaller layouts,
    // crossed with data heterogeneity, DGC sparsity, H, and two channel
    // profiles (nominal vs deep fade with stragglers). The mobility and
    // straggler-policy axes stay at their defaults here (static,
    // wait-for-all) — add `MobilityProfile::Waypoint`/`StragglerPolicy::
    // Deadline` values to route cells through the discrete-event engine.
    let spec = ScenarioSpec {
        cells: vec![1, 4, 7],
        mus_per_cell: vec![4],
        skews: vec![0.0, 1.0],
        phis: vec![None, Some(0.9)],
        h_periods: vec![2, 6],
        profiles: vec![ChannelProfile::nominal(), ChannelProfile::straggler()],
        mobilities: vec![MobilityProfile::Static],
        stragglers: vec![StragglerPolicy::WaitForAll],
    };
    println!(
        "matrix sweep: {} scenarios across {threads} threads ({iters} iters/cell)\n",
        spec.n_scenarios()
    );

    let opts = MatrixOptions {
        threads,
        iters,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let results = run_matrix(&cfg, &spec, &opts)?;
    let wall = t0.elapsed().as_secs_f64();
    for r in &results {
        println!("{}", r.table_row());
    }
    println!("\n{} scenarios in {wall:.2}s wall", results.len());

    // Determinism proof: a single-threaded rerun yields identical traces.
    let serial = run_matrix(&cfg, &spec, &MatrixOptions { threads: 1, ..opts })?;
    let fixture = result::golden_from_json(&hfl::util::json::parse(
        &result::golden_to_json(&serial).to_string_compact(),
    )
    .expect("self-serialized fixture"))?;
    let diff = result::golden_diff(&results, &fixture);
    assert!(diff.is_empty(), "thread-count changed results: {diff:?}");
    println!("determinism check: {threads}-thread run is bit-identical to 1-thread run");

    let csv = format!("{out}/matrix_sweep.csv");
    result::results_to_csv(&results).save(&csv)?;
    std::fs::write(
        format!("{out}/matrix_sweep_golden.json"),
        format!("{}\n", result::golden_to_json(&results).to_string_compact()),
    )?;
    println!("wrote {csv} and {out}/matrix_sweep_golden.json");
    Ok(())
}
