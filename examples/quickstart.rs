//! Quickstart: load the AOT artifacts, run one federated round by hand, and
//! print what happened. Mirrors the README's five-minute tour.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use hfl::config::Config;
use hfl::data::SyntheticSpec;
use hfl::fl::{fl, TrainOptions};
use hfl::runtime::{ModelOracle, Runtime};
use hfl::wireless::{fl_latency, hfl_latency, LatencyInputs};

fn main() -> anyhow::Result<()> {
    // 1. Load the AOT-compiled training graph (built once by `make
    //    artifacts`; Python is NOT used from here on).
    let rt = Runtime::load_default()?;
    println!("PJRT platform: {}", rt.platform());
    let meta = rt.model_meta("mlp")?.clone();
    println!(
        "model: mlp  Q = {} parameters, train batch {}",
        meta.q_params, meta.train_batch
    );

    // 2. Build the gradient oracle: 8 MUs sharing a synthetic CIFAR-like
    //    corpus in unshuffled contiguous shards (paper §V-B).
    let spec = SyntheticSpec {
        n_train: 1024,
        n_test: 512,
        noise: 0.6,
        seed: 7,
        ..SyntheticSpec::default()
    };
    let mut oracle = ModelOracle::new(&rt, "mlp", 8, &spec)?;

    // 3. Train 30 iterations of plain federated SGD (Algorithm 1).
    let opts = TrainOptions {
        iters: 30,
        peak_lr: 0.1,
        warmup_iters: 3,
        momentum: 0.9,
        eval_every: 10,
        ..Default::default()
    };
    let log = fl(&mut oracle, &opts);
    for (it, m) in &log.evals {
        println!("iter {it:>3}: top-1 {:.1}%  loss {:.3}", m.accuracy * 100.0, m.loss);
    }

    // 4. Ask the wireless model what one iteration costs over the paper's
    //    HCN — flat FL vs hierarchical FL.
    let cfg = Config::paper_table2();
    let inputs = LatencyInputs::new(&cfg);
    let t_fl = fl_latency(&inputs).total();
    let t_hfl = hfl_latency(&inputs).per_iteration();
    println!(
        "\nper-iteration communication latency (Q = ResNet18-scale, sparse):\n  \
         flat FL  : {t_fl:.3} s\n  HFL (H=2): {t_hfl:.3} s  → speed-up ×{:.2}",
        t_fl / t_hfl
    );
    let acc = log.final_eval().unwrap().accuracy * 100.0;
    assert!(acc > 30.0, "quickstart training should beat chance");
    println!("\nquickstart OK");
    Ok(())
}
