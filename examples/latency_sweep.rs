//! Regenerate the data behind every latency figure (Fig. 3, 4, 5a, 5b) and
//! save CSV series under `results/`.
//!
//! ```bash
//! cargo run --release --example latency_sweep -- --fig all
//! ```

use hfl::cli::Args;
use hfl::config::Config;
use hfl::sim::{fig3, fig4, fig5a, fig5b};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let which = args.get_or("fig", "all");
    let out = args.get_or("out", "results");
    args.finish()?;
    let cfg = Config::paper_table2();
    let mus = [2usize, 4, 6, 8, 10, 14, 20];
    let alphas: Vec<f64> = (0..=10).map(|i| 2.0 + 0.2 * i as f64).collect();

    let figs: Vec<(&str, hfl::sim::FigureSeries)> = match which.as_str() {
        "3" => vec![("fig3", fig3(&cfg, &mus))],
        "4" => vec![("fig4", fig4(&cfg, &alphas))],
        "5a" => vec![("fig5a", fig5a(&cfg, &mus))],
        "5b" => vec![("fig5b", fig5b(&cfg, &mus))],
        _ => vec![
            ("fig3", fig3(&cfg, &mus)),
            ("fig4", fig4(&cfg, &alphas)),
            ("fig5a", fig5a(&cfg, &mus)),
            ("fig5b", fig5b(&cfg, &mus)),
        ],
    };
    for (name, f) in figs {
        println!("{}", f.render());
        let path = format!("{out}/{name}.csv");
        f.to_csv().save(&path)?;
        println!("wrote {path}\n");
    }
    Ok(())
}
