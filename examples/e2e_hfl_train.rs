//! End-to-end driver (the EXPERIMENTS.md validation run): train the AOT
//! model through the FULL stack — MU/SBS/MBS thread actors, DGC sparse
//! uplinks, discounted-error downlinks, H-period global averaging, PJRT
//! compute service — on the synthetic CIFAR-like corpus, comparing FL vs
//! HFL (H = 2, 4, 6), and report accuracy, loss curves, per-link traffic,
//! and simulated network time from the wireless model.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_hfl_train            # standard
//! cargo run --release --example e2e_hfl_train -- --quick                   # CI-sized
//! cargo run --release --example e2e_hfl_train -- --iters 300 --mus 4      # custom
//! ```

use hfl::cli::Args;
use hfl::config::Config;
use hfl::coordinator::{run_coordinated, CoordinatorOptions, LinkKind};
use hfl::data::SyntheticSpec;
use hfl::runtime::{ModelOracle, Runtime};
use hfl::sim::experiments::{scenario_latency, Scenario};
use hfl::util::csv::CsvTable;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let quick = args.flag("quick");
    let iters = args.get_parsed_or("iters", if quick { 48 } else { 160 })?;
    let mus = args.get_parsed_or("mus", 4usize)?;
    let model = args.get_or("model", "mlp");
    let out = args.get_or("out", "results");
    args.finish()?;

    let mut cfg = Config::paper_table2();
    cfg.topology.mus_per_cluster = mus;
    let workers = cfg.topology.total_mus();
    let n_clusters = cfg.topology.n_clusters;
    let train_samples = (workers * 64 * if quick { 1 } else { 2 }).max(workers * 64);
    let test_samples = if quick { 512 } else { 1024 };

    println!(
        "== end-to-end HFL training ==\nmodel={model} workers={workers} ({n_clusters} clusters × {mus}), iters={iters}\n"
    );

    let mut rows = CsvTable::new([
        "algo", "h", "final_acc", "final_loss", "mu_ul_bits", "sbs_dl_bits", "sbs_ul_bits",
        "mbs_dl_bits", "sim_time_s",
    ]);
    let mut loss_curves: Vec<(String, Vec<(usize, f64)>)> = Vec::new();

    let variants: Vec<(String, usize, usize)> = vec![
        ("FL".into(), 1, 1),
        ("HFL".into(), n_clusters, 2),
        ("HFL".into(), n_clusters, 4),
        ("HFL".into(), n_clusters, 6),
    ];
    for (name, clusters, h) in variants {
        let label = if clusters == 1 {
            name.clone()
        } else {
            format!("{name} H={h}")
        };
        let opts = CoordinatorOptions {
            iters,
            peak_lr: cfg.training.scaled_lr(workers),
            warmup_iters: iters / 10,
            milestones: cfg.training.decay_milestones,
            momentum: cfg.training.momentum as f32,
            weight_decay: cfg.training.weight_decay as f32,
            h_period: h,
            n_clusters: clusters,
            sparsity: cfg.sparsity.clone(),
            eval_every_syncs: 4,
            agg: cfg.agg,
        };
        let spec = SyntheticSpec {
            n_train: train_samples,
            n_test: test_samples,
            noise: 0.6,
            seed: cfg.training.seed,
            ..SyntheticSpec::default()
        };
        let model2 = model.clone();
        let run = run_coordinated(
            move || {
                let rt = Runtime::load_default().expect("run `make artifacts` first");
                ModelOracle::new(&rt, &model2, workers, &spec).expect("oracle")
            },
            &opts,
        )?;

        // Simulated per-iteration network time from the wireless model.
        let sc = Scenario {
            name: label.clone(),
            n_clusters: clusters,
            h_period: h,
            workers,
            sparse: true,
        };
        let per_iter_s = scenario_latency(&cfg, &sc);
        let sim_time = per_iter_s * iters as f64;

        println!("-- {label}: final top-1 {:.2}%  loss {:.4}  sim-time {:.1}s ({:.3}s/iter)",
            run.final_eval.accuracy * 100.0,
            run.final_eval.loss,
            sim_time,
            per_iter_s,
        );
        for (it, m) in &run.sync_evals {
            println!("   iter {it:>4}  acc {:>6.2}%", m.accuracy * 100.0);
        }
        rows.push_row([
            label.clone(),
            h.to_string(),
            format!("{:.4}", run.final_eval.accuracy * 100.0),
            format!("{:.5}", run.final_eval.loss),
            format!("{:.3e}", run.metrics.total_bits(LinkKind::MuUl)),
            format!("{:.3e}", run.metrics.total_bits(LinkKind::SbsDl)),
            format!("{:.3e}", run.metrics.total_bits(LinkKind::SbsUl)),
            format!("{:.3e}", run.metrics.total_bits(LinkKind::MbsDl)),
            format!("{sim_time:.2}"),
        ]);
        loss_curves.push((label, run.train_loss));
    }

    rows.save(format!("{out}/e2e_summary.csv"))?;
    // Loss curves CSV (iteration, one column per variant).
    let mut curve_table = CsvTable::new(
        std::iter::once("iter".to_string())
            .chain(loss_curves.iter().map(|(n, _)| n.clone()))
            .collect::<Vec<_>>(),
    );
    let n_rows = loss_curves[0].1.len();
    for i in 0..n_rows {
        let mut row = vec![loss_curves[0].1[i].0 as f64];
        for (_, c) in &loss_curves {
            row.push(c.get(i).map(|x| x.1).unwrap_or(f64::NAN));
        }
        curve_table.push_nums(&row);
    }
    curve_table.save(format!("{out}/e2e_loss_curves.csv"))?;
    println!("\nwrote {out}/e2e_summary.csv and {out}/e2e_loss_curves.csv");
    println!("e2e_hfl_train OK");
    Ok(())
}
