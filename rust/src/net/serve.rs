//! The MBS side of the service: accept workers, run the barrier-round
//! sync protocol, fold the outcome into a [`CoordinatorRun`].
//!
//! The protocol is lockstep by construction: every cluster runs the same
//! iteration count and H-period, so each sends the same number of `Sync`
//! messages followed by one `Done`. The MBS therefore receives exactly
//! one message per cluster per barrier round, reads them in cluster
//! order, and aggregates in that order — the same cluster-ordered fold
//! as the in-process engine, hence bit-identical results.
//!
//! `run_coordinated_service` wires every cluster over a loopback
//! transport pair, which is how `coordinator::run_coordinated` (and so
//! every existing golden trace) exercises the full frame/wire codec on
//! each run.

use super::metrics_http::LiveMetrics;
use super::session::{Direction, SessionLog, BROADCAST};
use super::transport::{LoopbackTransport, TcpTransport, Transport};
use super::wire::WireMsg;
use super::worker::run_cell;
use crate::coordinator::{
    ComputeService, CoordinatorOptions, CoordinatorRun, LinkKind, MetricEvent, MetricsLog,
};
use crate::fl::oracle::{EvalMetrics, GradOracle};
use crate::sparse::merge::{self, DenseShadow, MergeScratch};
use crate::sparse::{DiscountedError, SparseVec};
use anyhow::{anyhow, bail, Context, Result};
use std::net::TcpListener;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Waiting longer than this on one cluster's message counts as a
/// straggler wait on the live metrics endpoint (observability only —
/// nothing here feeds back into the run).
const STRAGGLER_THRESHOLD: Duration = Duration::from_secs(1);

/// One connected worker cell, keyed by its assigned cluster.
pub struct ClusterLink {
    pub cluster: usize,
    pub transport: Box<dyn Transport>,
}

/// MBS side of the session handshake. Checks the worker's scenario
/// fingerprint against ours (the same refusal discipline as snapshot
/// restore: refuse loudly rather than diverge silently) and assigns a
/// cluster — the requested one if free, else the lowest free id.
pub fn handshake_mbs(
    transport: &mut dyn Transport,
    fingerprint: u64,
    taken: &mut [bool],
) -> Result<usize> {
    let n = taken.len();
    let refuse = |t: &mut dyn Transport, reason: String| -> anyhow::Error {
        let _ = t.send(&WireMsg::Refuse {
            reason: reason.clone(),
        });
        anyhow!("{reason}")
    };
    let (fp, want) = match transport.recv().context("waiting for Hello")? {
        WireMsg::Hello {
            fingerprint,
            cluster,
        } => (fingerprint, cluster),
        other => {
            return Err(refuse(
                transport,
                format!("expected Hello, got {}", other.kind()),
            ))
        }
    };
    if fp != fingerprint {
        return Err(refuse(
            transport,
            format!("scenario fingerprint mismatch: serving {fingerprint:016x}, worker has {fp:016x} (same flags/config on both sides?)"),
        ));
    }
    let cluster = match want {
        Some(c) if c >= n => {
            return Err(refuse(
                transport,
                format!("cluster {c} out of range 0..{n}"),
            ))
        }
        Some(c) if taken[c] => {
            return Err(refuse(transport, format!("cluster {c} already connected")))
        }
        Some(c) => c,
        None => match taken.iter().position(|t| !t) {
            Some(c) => c,
            None => {
                return Err(refuse(
                    transport,
                    format!("all {n} clusters already connected"),
                ))
            }
        },
    };
    taken[cluster] = true;
    transport
        .send(&WireMsg::Welcome {
            cluster,
            n_clusters: n,
        })
        .context("sending Welcome")?;
    Ok(cluster)
}

/// Accept TCP workers until every cluster slot is filled. A connection
/// that fails its handshake is reported and dropped; the listener keeps
/// going — a mis-configured worker must not wedge the session.
pub fn accept_workers(
    listener: &TcpListener,
    fingerprint: u64,
    n_clusters: usize,
) -> Result<Vec<ClusterLink>> {
    let mut taken = vec![false; n_clusters];
    let mut links: Vec<ClusterLink> = Vec::with_capacity(n_clusters);
    while links.len() < n_clusters {
        let (stream, peer) = listener.accept().context("accepting worker connection")?;
        let mut transport = match TcpTransport::new(stream) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("rejecting {peer}: {e:#}");
                continue;
            }
        };
        match handshake_mbs(&mut transport, fingerprint, &mut taken) {
            Ok(cluster) => {
                eprintln!("worker {peer} joined as cluster {cluster}");
                links.push(ClusterLink {
                    cluster,
                    transport: Box::new(transport),
                });
            }
            Err(e) => eprintln!("refused {peer}: {e:#}"),
        }
    }
    links.sort_by_key(|l| l.cluster);
    Ok(links)
}

/// Fold one cluster's final model into the consensus average.
pub(crate) fn fold_final_model(final_params: &mut [f32], model: &[f32], n: usize) -> Result<()> {
    if model.len() != final_params.len() {
        bail!(
            "final model has {} parameters, expected {}",
            model.len(),
            final_params.len()
        );
    }
    for (i, v) in model.iter().enumerate() {
        final_params[i] += v / n as f32;
    }
    Ok(())
}

/// Merge one cluster's per-iteration losses into the cross-cluster
/// accumulator (iter, sum, count).
pub(crate) fn merge_losses(acc: &mut Vec<(usize, f64, usize)>, iter_losses: &[(usize, f64)]) {
    for &(it, loss) in iter_losses {
        match acc.iter_mut().find(|(i, _, _)| *i == it) {
            Some((_, sum, cnt)) => {
                *sum += loss;
                *cnt += 1;
            }
            None => acc.push((it, loss, 1)),
        }
    }
}

/// Finish the loss accumulator into the run's (iter, mean loss) curve.
pub(crate) fn finish_losses(mut acc: Vec<(usize, f64, usize)>) -> Vec<(usize, f64)> {
    acc.sort_by_key(|(i, _, _)| *i);
    acc.into_iter().map(|(i, s, c)| (i, s / c as f64)).collect()
}

/// Run the MBS over a set of connected cluster links.
///
/// `eval` maps parameters to held-out metrics — `run_coordinated` passes
/// the shared compute service, the TCP server its own oracle. `log`
/// records every data-plane message for `hfl replay`; `live` feeds the
/// `/metrics` endpoint. Both are observability-only and do not perturb
/// the arithmetic.
pub fn run_mbs(
    mut links: Vec<ClusterLink>,
    opts: &CoordinatorOptions,
    dim: usize,
    init: &[f32],
    eval: &mut dyn FnMut(&[f32]) -> EvalMetrics,
    mut log: Option<&mut SessionLog>,
    live: Option<&LiveMetrics>,
) -> Result<CoordinatorRun> {
    let n = opts.n_clusters;
    links.sort_by_key(|l| l.cluster);
    if links.len() != n || links.iter().enumerate().any(|(i, l)| l.cluster != i) {
        bail!(
            "expected one link per cluster 0..{n}, got [{}]",
            links
                .iter()
                .map(|l| l.cluster.to_string())
                .collect::<Vec<_>>()
                .join(", ")
        );
    }

    let mut w_global: Vec<f32> = init.to_vec();
    let (_phi_ul, _phi_sdl, _phi_sul, phi_mdl) = effective_phis(opts);
    let mut mbs_enc = DiscountedError::new(dim, phi_mdl, opts.sparsity.beta_m as f32);
    let mut agg = vec![0.0f32; dim];
    // Density-adaptive sync aggregation (reference baseline +0.0: the
    // accumulator is zeroed, never scaled).
    let mut mbs_shadow = DenseShadow::new();
    let mut mbs_merged = SparseVec::empty(dim);
    let mut mbs_scratch = MergeScratch::default();
    let mut metrics = MetricsLog::default();
    let mut sync_evals = Vec::new();
    let mut sync_index = 0usize;

    // Barrier rounds: one message per cluster, read in cluster order.
    // Lockstep makes this exhaustive — a cluster cannot pass sync k
    // without the broadcast, which requires every cluster's sync k, so a
    // round is either all-Sync or all-Done.
    loop {
        let mut round: Vec<WireMsg> = Vec::with_capacity(n);
        for link in links.iter_mut() {
            let t0 = Instant::now();
            let msg = link.transport.recv().with_context(|| {
                format!(
                    "receiving from cluster {} ({}) at sync round {sync_index}",
                    link.cluster,
                    link.transport.peer()
                )
            })?;
            if let Some(l) = live {
                if t0.elapsed() > STRAGGLER_THRESHOLD {
                    l.note_straggler();
                }
            }
            let from = match &msg {
                WireMsg::Sync { cluster, .. } | WireMsg::Done { cluster, .. } => *cluster,
                other => bail!(
                    "cluster {} sent {} during a sync round",
                    link.cluster,
                    other.kind()
                ),
            };
            if from != link.cluster {
                bail!(
                    "link for cluster {} delivered a message from cluster {from}",
                    link.cluster
                );
            }
            if let Some(l) = log.as_deref_mut() {
                l.append(Direction::Rx, link.cluster as u32, &msg)?;
            }
            round.push(msg);
        }

        if round.iter().all(|m| matches!(m, WireMsg::Done { .. })) {
            // --- Shutdown: fold final cluster models (cluster order) ----
            let mut final_params = vec![0.0f32; dim];
            let mut loss_acc: Vec<(usize, f64, usize)> = Vec::new();
            for msg in round {
                let WireMsg::Done {
                    cluster,
                    final_model,
                    iter_losses,
                    events,
                } = msg
                else {
                    unreachable!()
                };
                if let Some(l) = live {
                    l.note_events(&events);
                    l.note_done();
                }
                for ev in events {
                    metrics.push(ev);
                }
                fold_final_model(&mut final_params, &final_model, n)
                    .with_context(|| format!("folding Done from cluster {cluster}"))?;
                merge_losses(&mut loss_acc, &iter_losses);
            }
            let final_eval = eval(&final_params);
            if let Some(l) = live {
                l.finish();
            }
            return Ok(CoordinatorRun {
                final_params,
                final_eval,
                sync_evals,
                metrics,
                train_loss: finish_losses(loss_acc),
            });
        }
        if !round.iter().all(|m| matches!(m, WireMsg::Sync { .. })) {
            bail!("protocol violation at sync round {sync_index}: clusters disagree on Sync vs Done");
        }

        // --- All-Sync round: aggregate in cluster order -----------------
        let mut deltas: Vec<SparseVec> = Vec::with_capacity(n);
        let mut loss_total = 0.0f64;
        for msg in round {
            let WireMsg::Sync {
                cluster,
                mean_loss,
                delta,
                events,
            } = msg
            else {
                unreachable!()
            };
            if delta.dim != dim {
                bail!(
                    "cluster {cluster} sync delta has dimension {}, expected {dim}",
                    delta.dim
                );
            }
            if let Some(l) = live {
                l.note_events(&events);
            }
            for ev in events {
                metrics.push(ev);
            }
            loss_total += mean_loss;
            deltas.push(delta);
        }
        let scale = 1.0 / n as f32;
        let parts: Vec<(&SparseVec, f32)> = deltas.iter().map(|m| (m, scale)).collect();
        merge::aggregate_adaptive(
            &opts.agg,
            &parts,
            dim,
            None,
            &mut agg,
            &mut mbs_merged,
            &mut mbs_scratch,
            &mut mbs_shadow,
        );
        let msg = mbs_enc.compress(&agg);
        let ev = MetricEvent {
            iter: (sync_index + 1) * opts.h_period - 1,
            cluster: usize::MAX,
            link: LinkKind::MbsDl,
            bits: msg.wire_bits(32),
            loss: f64::NAN,
        };
        metrics.push(ev);
        if let Some(l) = live {
            l.note_events(&[ev]);
            l.note_sync_round(loss_total / n as f64);
        }
        let broadcast = WireMsg::GlobalDelta {
            sync_index,
            delta: msg.clone(),
        };
        // One log record per broadcast — it is the same bytes to every
        // cluster, and replay re-fans it out.
        if let Some(l) = log.as_deref_mut() {
            l.append(Direction::Tx, BROADCAST, &broadcast)?;
        }
        msg.add_into(&mut w_global, 1.0);
        for link in links.iter_mut() {
            link.transport.send(&broadcast).with_context(|| {
                format!(
                    "broadcasting sync {sync_index} to cluster {} ({})",
                    link.cluster,
                    link.transport.peer()
                )
            })?;
        }
        sync_index += 1;
        if opts.eval_every_syncs > 0 && sync_index % opts.eval_every_syncs == 0 {
            sync_evals.push((sync_index * opts.h_period, eval(&w_global)));
        }
    }
}

/// The per-link sparsification levels in effect (zeros when sparsity is
/// disabled) — shared between MBS, cells and replay so the selection
/// logic cannot drift.
pub(crate) fn effective_phis(opts: &CoordinatorOptions) -> (f64, f64, f64, f64) {
    crate::coordinator::run::effective_phis(opts)
}

/// Run the full coordinated topology in-process, every SBS↔MBS hop over
/// a loopback transport: MBS on the caller's thread, one cell thread per
/// cluster, one shared compute service. `coordinator::run_coordinated`
/// delegates here — the framed codec is on the hot path of every
/// existing test and golden trace.
pub fn run_coordinated_service<F, O>(
    factory: F,
    opts: &CoordinatorOptions,
    log: Option<&mut SessionLog>,
    live: Option<&LiveMetrics>,
) -> Result<CoordinatorRun>
where
    F: FnOnce() -> O + Send + 'static,
    O: GradOracle + 'static,
{
    let svc = ComputeService::spawn(factory);
    let compute = svc.handle();
    let (dim, k_total, init, _ipe) = compute.meta();
    let n = opts.n_clusters;
    if n == 0 || k_total % n != 0 {
        svc.shutdown();
        bail!("workers ({k_total}) must divide evenly into clusters ({n})");
    }

    let mut links: Vec<ClusterLink> = Vec::with_capacity(n);
    let mut cells = Vec::with_capacity(n);
    for c in 0..n {
        let (mbs_end, mut cell_end) = LoopbackTransport::pair();
        links.push(ClusterLink {
            cluster: c,
            transport: Box::new(mbs_end),
        });
        let cell_opts = opts.clone();
        let cell_compute = compute.clone();
        cells.push(
            std::thread::Builder::new()
                .name(format!("hfl-cell-{c}"))
                .spawn(move || run_cell(cell_compute, &cell_opts, c, &mut cell_end))
                .with_context(|| format!("spawning cell thread for cluster {c}"))?,
        );
    }

    let mut eval = |p: &[f32]| compute.eval(Arc::new(p.to_vec()));
    let run = run_mbs(links, opts, dim, &init, &mut eval, log, live);
    // `run_mbs` consumed (and dropped) the links, so a cell blocked on a
    // dead MBS sees a transport error rather than a hang. Prefer a cell's
    // error — it is usually the root cause of an MBS-side failure.
    let mut cell_err: Option<anyhow::Error> = None;
    for (c, j) in cells.into_iter().enumerate() {
        match j.join() {
            Err(_) => {
                if cell_err.is_none() {
                    cell_err = Some(anyhow!("cell thread for cluster {c} panicked"));
                }
            }
            Ok(Err(e)) => {
                if cell_err.is_none() {
                    cell_err = Some(e.context(format!("cell for cluster {c} failed")));
                }
            }
            Ok(Ok(())) => {}
        }
    }
    svc.shutdown();
    match cell_err {
        Some(e) => Err(e),
        None => run,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::worker::handshake_worker;

    #[test]
    fn handshake_assigns_lowest_free_cluster() {
        let (mut w, mut m) = LoopbackTransport::pair();
        let j = std::thread::spawn(move || handshake_worker(&mut w, 42, None));
        let mut taken = vec![true, false, false];
        let c = handshake_mbs(&mut m, 42, &mut taken).unwrap();
        assert_eq!(c, 1);
        assert!(taken[1]);
        assert_eq!(j.join().unwrap().unwrap(), (1, 3));
    }

    #[test]
    fn handshake_refuses_fingerprint_mismatch() {
        let (mut w, mut m) = LoopbackTransport::pair();
        let j = std::thread::spawn(move || handshake_worker(&mut w, 1, None));
        let mut taken = vec![false];
        let err = handshake_mbs(&mut m, 2, &mut taken).unwrap_err();
        assert!(format!("{err:#}").contains("fingerprint mismatch"), "{err:#}");
        assert!(!taken[0]);
        let worker_err = j.join().unwrap().unwrap_err();
        assert!(format!("{worker_err:#}").contains("refused"), "{worker_err:#}");
    }

    #[test]
    fn handshake_refuses_taken_or_out_of_range_cluster() {
        let (mut w, mut m) = LoopbackTransport::pair();
        let j = std::thread::spawn(move || handshake_worker(&mut w, 7, Some(0)));
        let mut taken = vec![true];
        assert!(handshake_mbs(&mut m, 7, &mut taken).is_err());
        assert!(j.join().unwrap().is_err());

        let (mut w, mut m) = LoopbackTransport::pair();
        let j = std::thread::spawn(move || handshake_worker(&mut w, 7, Some(5)));
        let mut taken = vec![false];
        let err = handshake_mbs(&mut m, 7, &mut taken).unwrap_err();
        assert!(format!("{err:#}").contains("out of range"), "{err:#}");
        assert!(j.join().unwrap().is_err());
    }

    #[test]
    fn loss_fold_helpers_mirror_in_process_merge() {
        let mut acc = Vec::new();
        merge_losses(&mut acc, &[(0, 1.0), (1, 3.0)]);
        merge_losses(&mut acc, &[(1, 5.0), (0, 3.0)]);
        assert_eq!(finish_losses(acc), vec![(0, 2.0), (1, 4.0)]);

        let mut fp = vec![0.0f32; 2];
        fold_final_model(&mut fp, &[2.0, 4.0], 2).unwrap();
        fold_final_model(&mut fp, &[4.0, 0.0], 2).unwrap();
        assert_eq!(fp, vec![3.0, 2.0]);
        assert!(fold_final_model(&mut fp, &[1.0], 2).is_err());
    }
}
