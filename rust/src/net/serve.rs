//! The MBS side of the service: accept workers, run the barrier-round
//! sync protocol, fold the outcome into a [`CoordinatorRun`].
//!
//! The protocol is lockstep by construction: every cluster runs the same
//! iteration count and H-period, so each sends the same number of `Sync`
//! messages followed by one `Done`. The MBS therefore receives exactly
//! one message per cluster per barrier round, reads them in cluster
//! order, and aggregates in that order — the same cluster-ordered fold
//! as the in-process engine, hence bit-identical results.
//!
//! `run_coordinated_service` wires every cluster over a loopback
//! transport pair, which is how `coordinator::run_coordinated` (and so
//! every existing golden trace) exercises the full frame/wire codec on
//! each run.
//!
//! ## Fault tolerance
//!
//! [`run_mbs_faulty`] is the fault-aware barrier loop; [`run_mbs`] is its
//! zero-fault specialization (policy `wait_all`, no rejoin lane), so the
//! clean path is arithmetically untouched. When a cluster's link errors
//! mid-run the MBS first offers the **rejoin lane** (if a listener and
//! deadline are configured): a relaunched worker replays the `Welcome`
//! handshake, announces `Rejoin{cluster, round}`, and is caught up from
//! the [`RecoveryPoint`] — the per-round, `snapshot`-codec-serializable
//! broadcast history — by replaying every stored `GlobalDelta` against
//! the worker's recomputed `Sync`s, which converges bit-exactly because
//! workers are deterministic. Only if no worker rejoins in time does the
//! [`FaultPolicy`] apply: `deadline_skip`/`quorum(k)` declare the cluster
//! dead, reweight the consensus over survivors (the k-way merge's
//! weighted parts, scale `1/alive`), and record the skip in the session
//! log, `LiveMetrics`, and the run's `skips` (hence the `GoldenTrace`).

use super::chaos::{ChaosConfig, ChaosTransport, FaultCounters, FaultPolicy};
use super::metrics_http::LiveMetrics;
use super::session::{Direction, SessionLog, BROADCAST};
use super::transport::{LoopbackTransport, TcpTransport, Transport};
use super::wire::WireMsg;
use super::worker::run_cell;
use crate::coordinator::{
    ComputeService, CoordinatorOptions, CoordinatorRun, LinkKind, MetricEvent, MetricsLog,
};
use crate::fl::oracle::{EvalMetrics, GradOracle};
use crate::snapshot::codec::{ByteReader, ByteWriter};
use crate::sparse::merge::{self, DenseShadow, MergeScratch};
use crate::sparse::{DiscountedError, SparseVec};
use anyhow::{anyhow, bail, Context, Result};
use std::net::TcpListener;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Waiting longer than this on one cluster's message counts as a
/// straggler wait on the live metrics endpoint (observability only —
/// nothing here feeds back into the run).
const STRAGGLER_THRESHOLD: Duration = Duration::from_secs(1);

/// One connected worker cell, keyed by its assigned cluster.
pub struct ClusterLink {
    pub cluster: usize,
    pub transport: Box<dyn Transport>,
}

/// MBS side of the session handshake. Checks the worker's scenario
/// fingerprint against ours (the same refusal discipline as snapshot
/// restore: refuse loudly rather than diverge silently) and assigns a
/// cluster — the requested one if free, else the lowest free id.
pub fn handshake_mbs(
    transport: &mut dyn Transport,
    fingerprint: u64,
    taken: &mut [bool],
) -> Result<usize> {
    let n = taken.len();
    let refuse = |t: &mut dyn Transport, reason: String| -> anyhow::Error {
        let _ = t.send(&WireMsg::Refuse {
            reason: reason.clone(),
        });
        anyhow!("{reason}")
    };
    let (fp, want) = match transport.recv().context("waiting for Hello")? {
        WireMsg::Hello {
            fingerprint,
            cluster,
        } => (fingerprint, cluster),
        other => {
            return Err(refuse(
                transport,
                format!("expected Hello, got {}", other.kind()),
            ))
        }
    };
    if fp != fingerprint {
        return Err(refuse(
            transport,
            format!("scenario fingerprint mismatch: serving {fingerprint:016x}, worker has {fp:016x} (same flags/config on both sides?)"),
        ));
    }
    let cluster = match want {
        Some(c) if c >= n => {
            return Err(refuse(
                transport,
                format!("cluster {c} out of range 0..{n}"),
            ))
        }
        Some(c) if taken[c] => {
            return Err(refuse(transport, format!("cluster {c} already connected")))
        }
        Some(c) => c,
        None => match taken.iter().position(|t| !t) {
            Some(c) => c,
            None => {
                return Err(refuse(
                    transport,
                    format!("all {n} clusters already connected"),
                ))
            }
        },
    };
    taken[cluster] = true;
    transport
        .send(&WireMsg::Welcome {
            cluster,
            n_clusters: n,
        })
        .context("sending Welcome")?;
    Ok(cluster)
}

/// Accept TCP workers until every cluster slot is filled. A connection
/// that fails its handshake is reported and dropped; the listener keeps
/// going — a mis-configured worker must not wedge the session.
pub fn accept_workers(
    listener: &TcpListener,
    fingerprint: u64,
    n_clusters: usize,
) -> Result<Vec<ClusterLink>> {
    accept_workers_timeout(listener, fingerprint, n_clusters, None)
}

/// [`accept_workers`] with an io timeout applied to every accepted
/// transport, so a worker that hangs mid-run yields a named error (which
/// the fault policy can then act on) instead of wedging the MBS.
pub fn accept_workers_timeout(
    listener: &TcpListener,
    fingerprint: u64,
    n_clusters: usize,
    io_timeout: Option<Duration>,
) -> Result<Vec<ClusterLink>> {
    let mut taken = vec![false; n_clusters];
    let mut links: Vec<ClusterLink> = Vec::with_capacity(n_clusters);
    while links.len() < n_clusters {
        let (stream, peer) = listener.accept().context("accepting worker connection")?;
        let mut transport = match TcpTransport::new(stream) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("rejecting {peer}: {e:#}");
                continue;
            }
        };
        if let Err(e) = transport.set_io_timeout(io_timeout) {
            eprintln!("rejecting {peer}: {e:#}");
            continue;
        }
        match handshake_mbs(&mut transport, fingerprint, &mut taken) {
            Ok(cluster) => {
                eprintln!("worker {peer} joined as cluster {cluster}");
                links.push(ClusterLink {
                    cluster,
                    transport: Box::new(transport),
                });
            }
            Err(e) => eprintln!("refused {peer}: {e:#}"),
        }
    }
    links.sort_by_key(|l| l.cluster);
    Ok(links)
}

/// Stand-in for a declared-dead cluster's transport. Installing it drops
/// the real transport, so a loopback cell blocked on the MBS sees a
/// closed channel (an error) rather than hanging forever, and any stray
/// use of the dead link is a named error.
struct DeadTransport {
    cluster: usize,
}

impl Transport for DeadTransport {
    fn send(&mut self, msg: &WireMsg) -> Result<()> {
        bail!(
            "cluster {} was declared dead (dropping {})",
            self.cluster,
            msg.kind()
        )
    }

    fn recv(&mut self) -> Result<WireMsg> {
        bail!("cluster {} was declared dead", self.cluster)
    }

    fn peer(&self) -> String {
        format!("dead(cluster-{})", self.cluster)
    }
}

/// The MBS's per-round recovery state for the rejoin lane: the broadcast
/// history plus the current global model, serializable through the
/// `snapshot` byte codec (all fields round-trip bit-exactly). Catch-up
/// replays from the *serialized* form, so rejoin provably needs nothing
/// beyond what this struct persists.
pub struct RecoveryPoint {
    /// Sync rounds completed (== `broadcasts.len()`).
    pub sync_index: usize,
    /// Global model after the last broadcast.
    pub w_global: Vec<f32>,
    /// Every `GlobalDelta` broadcast so far, in sync order.
    pub broadcasts: Vec<SparseVec>,
}

impl RecoveryPoint {
    fn new(init: &[f32]) -> Self {
        Self {
            sync_index: 0,
            w_global: init.to_vec(),
            broadcasts: Vec::new(),
        }
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.put_usize(self.sync_index);
        w.put_f32_slice(&self.w_global);
        w.put_usize(self.broadcasts.len());
        for b in &self.broadcasts {
            w.put_usize(b.dim);
            w.put_u32_slice(&b.indices);
            w.put_f32_slice(&b.values);
        }
        w.into_bytes()
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        let mut r = ByteReader::new(bytes);
        let sync_index = r.get_usize()?;
        let w_global = r.get_f32_vec()?;
        let n = r.get_usize()?;
        let mut broadcasts = Vec::with_capacity(n.min(1 << 20));
        for _ in 0..n {
            broadcasts.push(SparseVec {
                dim: r.get_usize()?,
                indices: r.get_u32_vec()?,
                values: r.get_f32_vec()?,
            });
        }
        r.finish()?;
        Ok(Self {
            sync_index,
            w_global,
            broadcasts,
        })
    }
}

/// Fault-handling context for [`run_mbs_faulty`]. The default is the
/// pre-fault-tolerance behaviour: `wait_all`, no rejoin lane.
pub struct FaultContext<'a> {
    /// What to do when a cluster stays dead past the rejoin deadline.
    pub policy: FaultPolicy,
    /// How long the rejoin lane waits for a replacement worker after a
    /// link dies. Zero disables the lane.
    pub rejoin_deadline: Duration,
    /// Listener the rejoin lane accepts on (TCP serve only; loopback
    /// sessions have no reconnect surface).
    pub listener: Option<&'a TcpListener>,
    /// Scenario fingerprint a rejoining worker must re-present.
    pub fingerprint: u64,
    /// io timeout applied to rejoined transports.
    pub io_timeout: Option<Duration>,
}

impl Default for FaultContext<'_> {
    fn default() -> Self {
        Self {
            policy: FaultPolicy::WaitAll,
            rejoin_deadline: Duration::ZERO,
            listener: None,
            fingerprint: 0,
            io_timeout: None,
        }
    }
}

/// Rejoin lane: wait up to `deadline` for a replacement worker for
/// `cluster`, replay the `Welcome` handshake (every other slot presented
/// as taken, so the newcomer lands on exactly the dead cluster), demand
/// its `Rejoin`, and catch it up by replaying the stored broadcast
/// history against its recomputed `Sync`s. Returns the caught-up
/// transport plus the round the worker rejoined from.
fn accept_rejoin(
    listener: &TcpListener,
    fingerprint: u64,
    cluster: usize,
    n_clusters: usize,
    deadline: Duration,
    io_timeout: Option<Duration>,
    recovery: &RecoveryPoint,
) -> Result<(Box<dyn Transport>, usize)> {
    listener
        .set_nonblocking(true)
        .context("rejoin lane: listener mode")?;
    let t0 = Instant::now();
    let accepted = loop {
        match listener.accept() {
            Ok((stream, _peer)) => {
                listener.set_nonblocking(false).ok();
                break stream;
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if t0.elapsed() >= deadline {
                    listener.set_nonblocking(false).ok();
                    bail!("no worker rejoined cluster {cluster} within {deadline:?}");
                }
                std::thread::sleep(Duration::from_millis(20));
            }
            Err(e) => {
                listener.set_nonblocking(false).ok();
                return Err(e).context("rejoin lane: accept");
            }
        }
    };
    accepted
        .set_nonblocking(false)
        .context("rejoin lane: stream mode")?;
    let mut transport = TcpTransport::new(accepted)?;
    transport.set_io_timeout(io_timeout)?;
    let mut taken = vec![true; n_clusters];
    taken[cluster] = false;
    let assigned =
        handshake_mbs(&mut transport, fingerprint, &mut taken).context("rejoin handshake")?;
    debug_assert_eq!(assigned, cluster);
    let round = match transport.recv().context("waiting for Rejoin")? {
        WireMsg::Rejoin { cluster: rc, round } if rc == cluster => round,
        WireMsg::Rejoin { cluster: rc, .. } => {
            bail!("rejoining worker claims cluster {rc}, expected {cluster}")
        }
        other => bail!(
            "expected Rejoin from reconnected worker for cluster {cluster}, got {}",
            other.kind()
        ),
    };
    if round > recovery.broadcasts.len() {
        bail!(
            "rejoining cluster {cluster} claims round {round}, but only {} broadcasts happened",
            recovery.broadcasts.len()
        );
    }
    // Round-trip the recovery point through the snapshot codec and catch
    // up from the decoded copy: rejoin provably depends only on the
    // persistable state, and the f32/u32 round-trip is bit-exact. The
    // deterministic worker recomputes from `round`; its `Sync`s are
    // consumed (not logged — the live run already logged round `i` once)
    // and answered with the stored broadcasts until it converges onto the
    // current round.
    let rp = RecoveryPoint::from_bytes(&recovery.to_bytes()).context("recovery point codec")?;
    for i in round..rp.broadcasts.len() {
        match transport
            .recv()
            .with_context(|| format!("catch-up sync {i} from cluster {cluster}"))?
        {
            WireMsg::Sync { cluster: sc, .. } if sc == cluster => {}
            other => bail!(
                "catch-up expected Sync {i} from cluster {cluster}, got {}",
                other.kind()
            ),
        }
        transport
            .send(&WireMsg::GlobalDelta {
                sync_index: i,
                delta: rp.broadcasts[i].clone(),
            })
            .with_context(|| format!("catch-up broadcast {i} to cluster {cluster}"))?;
    }
    Ok((Box::new(transport), round))
}

/// Fold one cluster's final model into the consensus average.
pub(crate) fn fold_final_model(final_params: &mut [f32], model: &[f32], n: usize) -> Result<()> {
    if model.len() != final_params.len() {
        bail!(
            "final model has {} parameters, expected {}",
            model.len(),
            final_params.len()
        );
    }
    for (i, v) in model.iter().enumerate() {
        final_params[i] += v / n as f32;
    }
    Ok(())
}

/// Merge one cluster's per-iteration losses into the cross-cluster
/// accumulator (iter, sum, count).
pub(crate) fn merge_losses(acc: &mut Vec<(usize, f64, usize)>, iter_losses: &[(usize, f64)]) {
    for &(it, loss) in iter_losses {
        match acc.iter_mut().find(|(i, _, _)| *i == it) {
            Some((_, sum, cnt)) => {
                *sum += loss;
                *cnt += 1;
            }
            None => acc.push((it, loss, 1)),
        }
    }
}

/// Finish the loss accumulator into the run's (iter, mean loss) curve.
pub(crate) fn finish_losses(mut acc: Vec<(usize, f64, usize)>) -> Vec<(usize, f64)> {
    acc.sort_by_key(|(i, _, _)| *i);
    acc.into_iter().map(|(i, s, c)| (i, s / c as f64)).collect()
}

/// Run the MBS over a set of connected cluster links.
///
/// `eval` maps parameters to held-out metrics — `run_coordinated` passes
/// the shared compute service, the TCP server its own oracle. `log`
/// records every data-plane message for `hfl replay`; `live` feeds the
/// `/metrics` endpoint. Both are observability-only and do not perturb
/// the arithmetic.
pub fn run_mbs(
    links: Vec<ClusterLink>,
    opts: &CoordinatorOptions,
    dim: usize,
    init: &[f32],
    eval: &mut dyn FnMut(&[f32]) -> EvalMetrics,
    log: Option<&mut SessionLog>,
    live: Option<&LiveMetrics>,
) -> Result<CoordinatorRun> {
    run_mbs_faulty(links, opts, dim, init, eval, log, live, &FaultContext::default())
}

/// [`run_mbs`] with fault handling — see the module docs. Under the
/// default [`FaultContext`] this IS the clean lockstep loop: every link
/// alive, scale `1/n`, any link error fatal.
#[allow(clippy::too_many_arguments)]
pub fn run_mbs_faulty(
    mut links: Vec<ClusterLink>,
    opts: &CoordinatorOptions,
    dim: usize,
    init: &[f32],
    eval: &mut dyn FnMut(&[f32]) -> EvalMetrics,
    mut log: Option<&mut SessionLog>,
    live: Option<&LiveMetrics>,
    faults: &FaultContext<'_>,
) -> Result<CoordinatorRun> {
    let n = opts.n_clusters;
    links.sort_by_key(|l| l.cluster);
    if links.len() != n || links.iter().enumerate().any(|(i, l)| l.cluster != i) {
        bail!(
            "expected one link per cluster 0..{n}, got [{}]",
            links
                .iter()
                .map(|l| l.cluster.to_string())
                .collect::<Vec<_>>()
                .join(", ")
        );
    }
    // Refuse impossible robustness configs before any cluster trains: an
    // unreachable quorum, a trim depth the sync fold can't satisfy, or an
    // out-of-range adversary plan each get a named startup error.
    faults.policy.validate(n).context("fault policy")?;
    opts.agg.validate().context("aggregation policy")?;
    if n > 1 {
        opts.agg
            .validate_participants(n)
            .context("MBS sync aggregation (clusters)")?;
    }
    opts.spec.adversary.validate().context("adversary plan")?;

    let mut w_global: Vec<f32> = init.to_vec();
    let (_phi_ul, _phi_sdl, _phi_sul, phi_mdl) = effective_phis(opts);
    let mut mbs_enc = DiscountedError::new(dim, phi_mdl, opts.sparsity.beta_m as f32);
    let mut agg = vec![0.0f32; dim];
    // Density-adaptive sync aggregation (reference baseline +0.0: the
    // accumulator is zeroed, never scaled).
    let mut mbs_shadow = DenseShadow::new();
    let mut mbs_merged = SparseVec::empty(dim);
    let mut mbs_scratch = MergeScratch::default();
    let mut metrics = MetricsLog::default();
    let mut sync_evals = Vec::new();
    let mut sync_index = 0usize;
    let mut alive = vec![true; n];
    let mut skips: Vec<(usize, usize)> = Vec::new();
    // The rejoin lane only exists over TCP; loopback sessions keep no
    // broadcast history.
    let mut recovery = faults.listener.map(|_| RecoveryPoint::new(init));
    let rejoin_enabled = faults.listener.is_some() && faults.rejoin_deadline > Duration::ZERO;
    // Under wait_all WITHOUT a rejoin lane any link error is immediately
    // fatal (the clean path). With a lane, send errors defer to the next
    // recv — the deterministic protocol point where recovery runs.
    let defer_send_errors = faults.policy != FaultPolicy::WaitAll || rejoin_enabled;

    // Barrier rounds: one message per alive cluster, read in cluster
    // order. Lockstep makes this exhaustive — a cluster cannot pass sync
    // k without the broadcast, which requires every alive cluster's sync
    // k, so a round is either all-Sync or all-Done.
    loop {
        let mut round: Vec<WireMsg> = Vec::with_capacity(n);
        for c in 0..n {
            if !alive[c] {
                continue;
            }
            let t0 = Instant::now();
            let mut msg = links[c].transport.recv();
            if msg.is_err() && rejoin_enabled {
                if let (Some(listener), Some(rp)) = (faults.listener, recovery.as_ref()) {
                    match accept_rejoin(
                        listener,
                        faults.fingerprint,
                        c,
                        n,
                        faults.rejoin_deadline,
                        faults.io_timeout,
                        rp,
                    ) {
                        Ok((transport, from_round)) => {
                            eprintln!(
                                "cluster {c} rejoined at sync round {sync_index} \
                                 (caught up from broadcast {from_round})"
                            );
                            links[c].transport = transport;
                            if let Some(l) = live {
                                l.note_reconnect();
                            }
                            if let Some(l) = log.as_deref_mut() {
                                l.append(
                                    Direction::Rx,
                                    c as u32,
                                    &WireMsg::Rejoin {
                                        cluster: c,
                                        round: from_round,
                                    },
                                )?;
                            }
                            msg = links[c].transport.recv();
                        }
                        Err(e) => eprintln!("rejoin lane for cluster {c} came up empty: {e:#}"),
                    }
                }
            }
            let msg = match msg {
                Ok(m) => m,
                Err(e) => {
                    if faults.policy == FaultPolicy::WaitAll {
                        return Err(e).with_context(|| {
                            format!(
                                "receiving from cluster {c} ({}) at sync round {sync_index}",
                                links[c].transport.peer()
                            )
                        });
                    }
                    // Degrade: declare the cluster dead and reweight the
                    // consensus over survivors — unless that would drop
                    // us below the policy's quorum.
                    alive[c] = false;
                    let n_alive = alive.iter().filter(|a| **a).count();
                    let reason = format!("{e:#}");
                    eprintln!("cluster {c} declared dead at sync round {sync_index}: {reason}");
                    if n_alive < faults.policy.min_alive() {
                        bail!(
                            "quorum lost at sync round {sync_index}: {n_alive} clusters alive \
                             after cluster {c} died, policy requires {}",
                            faults.policy.min_alive()
                        );
                    }
                    links[c].transport = Box::new(DeadTransport { cluster: c });
                    skips.push((c, sync_index));
                    if let Some(l) = log.as_deref_mut() {
                        l.append(
                            Direction::Tx,
                            c as u32,
                            &WireMsg::Skip {
                                cluster: c,
                                round: sync_index,
                                reason,
                            },
                        )?;
                    }
                    if let Some(l) = live {
                        l.note_cluster_skipped();
                    }
                    continue;
                }
            };
            if let Some(l) = live {
                if t0.elapsed() > STRAGGLER_THRESHOLD {
                    l.note_straggler();
                }
            }
            let from = match &msg {
                WireMsg::Sync { cluster, .. } | WireMsg::Done { cluster, .. } => *cluster,
                other => bail!("cluster {c} sent {} during a sync round", other.kind()),
            };
            if from != c {
                bail!("link for cluster {c} delivered a message from cluster {from}");
            }
            if let Some(l) = log.as_deref_mut() {
                l.append(Direction::Rx, c as u32, &msg)?;
            }
            round.push(msg);
        }

        if round.iter().all(|m| matches!(m, WireMsg::Done { .. })) {
            // --- Shutdown: fold final cluster models (cluster order).
            // The divisor is the count of Done messages — the survivors —
            // which equals n on the clean path.
            let n_done = round.len();
            let mut final_params = vec![0.0f32; dim];
            let mut loss_acc: Vec<(usize, f64, usize)> = Vec::new();
            for msg in round {
                let WireMsg::Done {
                    cluster,
                    final_model,
                    iter_losses,
                    events,
                } = msg
                else {
                    unreachable!()
                };
                if let Some(l) = live {
                    l.note_events(&events);
                    l.note_done();
                }
                for ev in events {
                    metrics.push(ev);
                }
                fold_final_model(&mut final_params, &final_model, n_done)
                    .with_context(|| format!("folding Done from cluster {cluster}"))?;
                merge_losses(&mut loss_acc, &iter_losses);
            }
            let final_eval = eval(&final_params);
            if let Some(l) = live {
                l.finish();
            }
            return Ok(CoordinatorRun {
                final_params,
                final_eval,
                sync_evals,
                metrics,
                train_loss: finish_losses(loss_acc),
                skips,
            });
        }
        if !round.iter().all(|m| matches!(m, WireMsg::Sync { .. })) {
            bail!("protocol violation at sync round {sync_index}: clusters disagree on Sync vs Done");
        }

        // --- All-Sync round: aggregate in cluster order (survivors
        // only; the consensus reweights over them) ----------------------
        let mut deltas: Vec<SparseVec> = Vec::with_capacity(n);
        let mut loss_total = 0.0f64;
        for msg in round {
            let WireMsg::Sync {
                cluster,
                mean_loss,
                delta,
                events,
            } = msg
            else {
                unreachable!()
            };
            if delta.dim != dim {
                bail!(
                    "cluster {cluster} sync delta has dimension {}, expected {dim}",
                    delta.dim
                );
            }
            if let Some(l) = live {
                l.note_events(&events);
            }
            for ev in events {
                metrics.push(ev);
            }
            loss_total += mean_loss;
            deltas.push(delta);
        }
        let scale = 1.0 / deltas.len() as f32;
        let parts: Vec<(&SparseVec, f32)> = deltas.iter().map(|m| (m, scale)).collect();
        merge::aggregate_adaptive(
            &opts.agg,
            &parts,
            dim,
            None,
            &mut agg,
            &mut mbs_merged,
            &mut mbs_scratch,
            &mut mbs_shadow,
        );
        let msg = mbs_enc.compress(&agg);
        let ev = MetricEvent {
            iter: (sync_index + 1) * opts.h_period - 1,
            cluster: usize::MAX,
            link: LinkKind::MbsDl,
            bits: msg.wire_bits(32),
            loss: f64::NAN,
        };
        metrics.push(ev);
        if let Some(l) = live {
            l.note_events(&[ev]);
            l.note_sync_round(loss_total / deltas.len() as f64);
        }
        let broadcast = WireMsg::GlobalDelta {
            sync_index,
            delta: msg.clone(),
        };
        // One log record per broadcast — it is the same bytes to every
        // cluster, and replay re-fans it out.
        if let Some(l) = log.as_deref_mut() {
            l.append(Direction::Tx, BROADCAST, &broadcast)?;
        }
        msg.add_into(&mut w_global, 1.0);
        if let Some(rp) = recovery.as_mut() {
            rp.broadcasts.push(msg.clone());
            rp.sync_index = sync_index + 1;
            rp.w_global.clone_from(&w_global);
        }
        for c in 0..n {
            if !alive[c] {
                continue;
            }
            if let Err(e) = links[c].transport.send(&broadcast) {
                if !defer_send_errors {
                    return Err(e).with_context(|| {
                        format!(
                            "broadcasting sync {sync_index} to cluster {c} ({})",
                            links[c].transport.peer()
                        )
                    });
                }
                // Death is only *declared* on recv: the next recv from
                // this link fails at a deterministic protocol point, where
                // the rejoin lane / fault policy take over. This keeps the
                // skip round independent of send-vs-recv timing.
                eprintln!(
                    "broadcast {sync_index} to cluster {c} failed (deferring to next recv): {e:#}"
                );
            }
        }
        sync_index += 1;
        if opts.eval_every_syncs > 0 && sync_index % opts.eval_every_syncs == 0 {
            sync_evals.push((sync_index * opts.h_period, eval(&w_global)));
        }
    }
}

/// The per-link sparsification levels in effect (zeros when sparsity is
/// disabled) — shared between MBS, cells and replay so the selection
/// logic cannot drift.
pub(crate) fn effective_phis(opts: &CoordinatorOptions) -> (f64, f64, f64, f64) {
    crate::coordinator::run::effective_phis(opts)
}

/// Run the full coordinated topology in-process, every SBS↔MBS hop over
/// a loopback transport: MBS on the caller's thread, one cell thread per
/// cluster, one shared compute service. `coordinator::run_coordinated`
/// delegates here — the framed codec is on the hot path of every
/// existing test and golden trace.
pub fn run_coordinated_service<F, O>(
    factory: F,
    opts: &CoordinatorOptions,
    log: Option<&mut SessionLog>,
    live: Option<&LiveMetrics>,
) -> Result<CoordinatorRun>
where
    F: FnOnce() -> O + Send + 'static,
    O: GradOracle + 'static,
{
    let svc = ComputeService::spawn(factory);
    let compute = svc.handle();
    let (dim, k_total, init, _ipe) = compute.meta();
    let n = opts.n_clusters;
    if n == 0 || k_total % n != 0 {
        svc.shutdown();
        bail!("workers ({k_total}) must divide evenly into clusters ({n})");
    }

    let mut links: Vec<ClusterLink> = Vec::with_capacity(n);
    let mut cells = Vec::with_capacity(n);
    for c in 0..n {
        let (mbs_end, mut cell_end) = LoopbackTransport::pair();
        links.push(ClusterLink {
            cluster: c,
            transport: Box::new(mbs_end),
        });
        let cell_opts = opts.clone();
        let cell_compute = compute.clone();
        cells.push(
            std::thread::Builder::new()
                .name(format!("hfl-cell-{c}"))
                .spawn(move || run_cell(cell_compute, &cell_opts, c, &mut cell_end))
                .with_context(|| format!("spawning cell thread for cluster {c}"))?,
        );
    }

    let mut eval = |p: &[f32]| compute.eval(Arc::new(p.to_vec()));
    let run = run_mbs(links, opts, dim, &init, &mut eval, log, live);
    // `run_mbs` consumed (and dropped) the links, so a cell blocked on a
    // dead MBS sees a transport error rather than a hang. Prefer a cell's
    // error — it is usually the root cause of an MBS-side failure.
    let mut cell_err: Option<anyhow::Error> = None;
    for (c, j) in cells.into_iter().enumerate() {
        match j.join() {
            Err(_) => {
                if cell_err.is_none() {
                    cell_err = Some(anyhow!("cell thread for cluster {c} panicked"));
                }
            }
            Ok(Err(e)) => {
                if cell_err.is_none() {
                    cell_err = Some(e.context(format!("cell for cluster {c} failed")));
                }
            }
            Ok(Ok(())) => {}
        }
    }
    svc.shutdown();
    match cell_err {
        Some(e) => Err(e),
        None => run,
    }
}

/// [`run_coordinated_service`] under a seeded fault plan: every MBS-side
/// loopback endpoint is wrapped in a [`ChaosTransport`] (stream tag =
/// cluster id) and the barrier loop runs under `policy`. Cell threads of
/// clusters the policy skipped die on their closed channel — those
/// errors are expected and tolerated; any other cluster's error still
/// propagates. With `chaos.enabled == false` this is byte-identical to
/// [`run_coordinated_service`].
pub fn run_chaos_service<F, O>(
    factory: F,
    opts: &CoordinatorOptions,
    chaos: &ChaosConfig,
    policy: FaultPolicy,
    counters: Arc<FaultCounters>,
    log: Option<&mut SessionLog>,
    live: Option<&LiveMetrics>,
) -> Result<CoordinatorRun>
where
    F: FnOnce() -> O + Send + 'static,
    O: GradOracle + 'static,
{
    let svc = ComputeService::spawn(factory);
    let compute = svc.handle();
    let (dim, k_total, init, _ipe) = compute.meta();
    let n = opts.n_clusters;
    if n == 0 || k_total % n != 0 {
        svc.shutdown();
        bail!("workers ({k_total}) must divide evenly into clusters ({n})");
    }

    let mut links: Vec<ClusterLink> = Vec::with_capacity(n);
    let mut cells = Vec::with_capacity(n);
    for c in 0..n {
        let (mbs_end, mut cell_end) = LoopbackTransport::pair();
        links.push(ClusterLink {
            cluster: c,
            transport: ChaosTransport::wrap(
                Box::new(mbs_end),
                chaos,
                c,
                c as u64,
                Arc::clone(&counters),
            ),
        });
        let cell_opts = opts.clone();
        let cell_compute = compute.clone();
        cells.push(
            std::thread::Builder::new()
                .name(format!("hfl-cell-{c}"))
                .spawn(move || run_cell(cell_compute, &cell_opts, c, &mut cell_end))
                .with_context(|| format!("spawning cell thread for cluster {c}"))?,
        );
    }

    let mut eval = |p: &[f32]| compute.eval(Arc::new(p.to_vec()));
    let faults = FaultContext {
        policy,
        ..FaultContext::default()
    };
    let run = run_mbs_faulty(links, opts, dim, &init, &mut eval, log, live, &faults);
    let skipped: Vec<usize> = run
        .as_ref()
        .map(|r| r.skips.iter().map(|(c, _)| *c).collect())
        .unwrap_or_default();
    let mut cell_err: Option<anyhow::Error> = None;
    for (c, j) in cells.into_iter().enumerate() {
        let tolerated = skipped.contains(&c);
        match j.join() {
            Err(_) => {
                if !tolerated && cell_err.is_none() {
                    cell_err = Some(anyhow!("cell thread for cluster {c} panicked"));
                }
            }
            Ok(Err(e)) => {
                if !tolerated && cell_err.is_none() {
                    cell_err = Some(e.context(format!("cell for cluster {c} failed")));
                }
            }
            Ok(Ok(())) => {}
        }
    }
    svc.shutdown();
    match cell_err {
        Some(e) => Err(e),
        None => run,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::worker::handshake_worker;

    #[test]
    fn handshake_assigns_lowest_free_cluster() {
        let (mut w, mut m) = LoopbackTransport::pair();
        let j = std::thread::spawn(move || handshake_worker(&mut w, 42, None));
        let mut taken = vec![true, false, false];
        let c = handshake_mbs(&mut m, 42, &mut taken).unwrap();
        assert_eq!(c, 1);
        assert!(taken[1]);
        assert_eq!(j.join().unwrap().unwrap(), (1, 3));
    }

    #[test]
    fn handshake_refuses_fingerprint_mismatch() {
        let (mut w, mut m) = LoopbackTransport::pair();
        let j = std::thread::spawn(move || handshake_worker(&mut w, 1, None));
        let mut taken = vec![false];
        let err = handshake_mbs(&mut m, 2, &mut taken).unwrap_err();
        assert!(format!("{err:#}").contains("fingerprint mismatch"), "{err:#}");
        assert!(!taken[0]);
        let worker_err = j.join().unwrap().unwrap_err();
        assert!(format!("{worker_err:#}").contains("refused"), "{worker_err:#}");
    }

    #[test]
    fn handshake_refuses_taken_or_out_of_range_cluster() {
        let (mut w, mut m) = LoopbackTransport::pair();
        let j = std::thread::spawn(move || handshake_worker(&mut w, 7, Some(0)));
        let mut taken = vec![true];
        assert!(handshake_mbs(&mut m, 7, &mut taken).is_err());
        assert!(j.join().unwrap().is_err());

        let (mut w, mut m) = LoopbackTransport::pair();
        let j = std::thread::spawn(move || handshake_worker(&mut w, 7, Some(5)));
        let mut taken = vec![false];
        let err = handshake_mbs(&mut m, 7, &mut taken).unwrap_err();
        assert!(format!("{err:#}").contains("out of range"), "{err:#}");
        assert!(j.join().unwrap().is_err());
    }

    #[test]
    fn recovery_point_roundtrips_bit_exactly() {
        let rp = RecoveryPoint {
            sync_index: 3,
            w_global: vec![1.5, -0.0, f32::MIN_POSITIVE, 42.0],
            broadcasts: vec![
                SparseVec {
                    dim: 4,
                    indices: vec![0, 2],
                    values: vec![0.25, -8.0],
                },
                SparseVec::empty(4),
                SparseVec {
                    dim: 4,
                    indices: vec![3],
                    values: vec![f32::EPSILON],
                },
            ],
        };
        let back = RecoveryPoint::from_bytes(&rp.to_bytes()).unwrap();
        assert_eq!(back.sync_index, rp.sync_index);
        assert_eq!(
            back.w_global.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            rp.w_global.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(back.broadcasts, rp.broadcasts);
        // Truncated bytes are a named error, not garbage.
        let bytes = rp.to_bytes();
        assert!(RecoveryPoint::from_bytes(&bytes[..bytes.len() - 2]).is_err());
    }

    #[test]
    fn dead_transport_names_the_cluster() {
        let mut t = DeadTransport { cluster: 3 };
        let err = t.recv().unwrap_err().to_string();
        assert!(err.contains("cluster 3"), "{err}");
        assert!(t
            .send(&WireMsg::Rejoin {
                cluster: 3,
                round: 0
            })
            .is_err());
        assert_eq!(t.peer(), "dead(cluster-3)");
    }

    #[test]
    fn loss_fold_helpers_mirror_in_process_merge() {
        let mut acc = Vec::new();
        merge_losses(&mut acc, &[(0, 1.0), (1, 3.0)]);
        merge_losses(&mut acc, &[(1, 5.0), (0, 3.0)]);
        assert_eq!(finish_losses(acc), vec![(0, 2.0), (1, 4.0)]);

        let mut fp = vec![0.0f32; 2];
        fold_final_model(&mut fp, &[2.0, 4.0], 2).unwrap();
        fold_final_model(&mut fp, &[4.0, 0.0], 2).unwrap();
        assert_eq!(fp, vec![3.0, 2.0]);
        assert!(fold_final_model(&mut fp, &[1.0], 2).is_err());
    }
}
