//! Coordinator-as-a-service: the SBS↔MBS tier of the hierarchy over a
//! real message transport.
//!
//! ```text
//!            hfl worker ──┐  framed SparseWire       ┌── hfl serve
//!   MU ⇄ SBS (in-process) ├── Sync / GlobalDelta ────┤  MBS + session log
//!            hfl worker ──┘  over TCP or loopback    └── /metrics endpoint
//! ```
//!
//! Layering (each module one concern):
//!
//! - [`frame`] — length-prefixed, checksummed byte framing (`HFLN` magic).
//! - [`wire`] — [`wire::WireMsg`]: the session's message vocabulary.
//!   Control messages (`Hello`/`Welcome`/`Refuse`) travel as exact JSON;
//!   data-plane deltas as the `SparseWire` delta-packed codec, asserted
//!   at the boundary to never exceed the fixed-width `payload_bits`
//!   pricing the latency model charges.
//! - [`transport`] — [`transport::Transport`] over loopback channels or
//!   TCP. `coordinator::run_coordinated` runs every cluster over
//!   loopback, so the whole codec path is proven bit-exact against the
//!   in-process golden traces on every run.
//! - [`serve`] / [`worker`] — the MBS barrier-round loop and the SBS+MUs
//!   cell behind `hfl serve` / `hfl worker`; a config-fingerprint
//!   handshake refuses mismatched peers before any training happens.
//! - [`session`] / [`replay`] — fsynced append-only message log, folded
//!   back into a bit-identical `CoordinatorRun` by `hfl replay` without
//!   re-running any training.
//! - [`metrics_http`] — live `GET /metrics` JSON endpoint
//!   (`--metrics-addr`), observability-only.
//! - [`chaos`] — deterministic fault injection ([`chaos::ChaosTransport`],
//!   seeded fault plans) plus the MBS [`chaos::FaultPolicy`] vocabulary:
//!   wait-all, deadline-skip, quorum. Same chaos seed ⇒ bit-identical run.
//! - [`scenario`] — the shared scenario both processes construct; its
//!   fingerprint is what the handshake compares.

pub mod chaos;
pub mod frame;
pub mod metrics_http;
pub mod replay;
pub mod scenario;
pub mod serve;
pub mod session;
pub mod transport;
pub mod wire;
pub mod worker;

pub use chaos::{ChaosConfig, ChaosTransport, FaultCounters, FaultPolicy};
pub use metrics_http::{LiveMetrics, MetricsServer};
pub use replay::replay_session;
pub use scenario::NetScenario;
pub use serve::{
    accept_workers, accept_workers_timeout, run_chaos_service, run_coordinated_service, run_mbs,
    run_mbs_faulty, ClusterLink, FaultContext, RecoveryPoint,
};
pub use session::{read_session, Direction, SessionHeader, SessionLog, SessionRecord};
pub use transport::{LoopbackTransport, TcpTransport, Transport};
pub use wire::WireMsg;
pub use worker::{handshake_worker, run_cell};
