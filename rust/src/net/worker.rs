//! The worker side of the service: one SBS + its MU actors, speaking to
//! the MBS over a [`Transport`].
//!
//! [`run_cell`] is the former in-process SBS actor with its MBS channel
//! hops replaced by framed wire messages — same compressors, same
//! slot-ordered aggregation, same arithmetic expressions, so a cell run
//! over loopback (or TCP) reproduces the in-process engine bit-exactly.
//! MU↔SBS traffic stays on in-process channels: the cell *is* the
//! process boundary.

use super::transport::Transport;
use super::wire::WireMsg;
use crate::coordinator::run::{effective_phis, mu_actor, MuContext};
use crate::coordinator::{
    ComputeHandle, CoordinatorOptions, LinkKind, MetricEvent, MetricsSink, MuToSbs, SbsToMu,
};
use crate::fl::lr_schedule::LrSchedule;
use crate::sparse::merge::{self, DenseShadow, MergeScratch};
use crate::sparse::{DiscountedError, SparseVec};
use anyhow::{anyhow, bail, Context, Result};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

/// Identify as a worker and obtain a cluster assignment. `want` pins a
/// specific cluster id (`--cluster`); `None` lets the MBS assign the
/// lowest free one.
pub fn handshake_worker(
    transport: &mut dyn Transport,
    fingerprint: u64,
    want: Option<usize>,
) -> Result<(usize, usize)> {
    transport
        .send(&WireMsg::Hello {
            fingerprint,
            cluster: want,
        })
        .context("sending Hello")?;
    match transport.recv().context("waiting for cluster assignment")? {
        WireMsg::Welcome {
            cluster,
            n_clusters,
        } => Ok((cluster, n_clusters)),
        WireMsg::Refuse { reason } => bail!("MBS refused session: {reason}"),
        other => bail!("expected Welcome or Refuse, got {}", other.kind()),
    }
}

/// Pull everything currently queued on the cell's local metric channel.
/// At a sync point this is exactly the cluster's events since the last
/// drain: every MU emits before uploading, and the SBS has received all
/// uploads for the rounds it completed.
fn drain_events(rx: &Receiver<MetricEvent>) -> Vec<MetricEvent> {
    let mut out = Vec::new();
    while let Ok(ev) = rx.try_recv() {
        out.push(ev);
    }
    out
}

/// Run one cluster's SBS+MUs cell against the MBS behind `transport`.
///
/// The compute service is cell-local (each worker process builds its own
/// oracle; `run_coordinated` shares one handle across loopback cells —
/// equivalent for the deterministic oracles the service contract
/// requires).
pub fn run_cell(
    compute: ComputeHandle,
    opts: &CoordinatorOptions,
    cluster: usize,
    transport: &mut dyn Transport,
) -> Result<()> {
    let (dim, k_total, init, _ipe) = compute.meta();
    let n = opts.n_clusters;
    if n == 0 || k_total % n != 0 {
        bail!("workers ({k_total}) must divide evenly into clusters ({n})");
    }
    if cluster >= n {
        bail!("cluster id {cluster} out of range 0..{n}");
    }
    let per_cluster = k_total / n;
    // Same refuse-at-startup discipline as the MBS: a trim depth the SBS
    // round fold can't satisfy, or a bad adversary plan, is a named error
    // before any MU thread spawns.
    opts.agg.validate().context("aggregation policy")?;
    opts.agg
        .validate_participants(per_cluster)
        .context("SBS round aggregation (MUs per cluster)")?;
    opts.spec.adversary.validate().context("adversary plan")?;
    let (phi_ul, _phi_sdl, phi_sul, _phi_mdl) = effective_phis(opts);
    let init = Arc::new(init);

    // --- Spawn MU actors on in-process channels --------------------------
    let (from_mu_tx, inbox) = channel::<MuToSbs>();
    let (metric_tx, metric_rx) = channel::<MetricEvent>();
    let metrics = MetricsSink::new(metric_tx);
    let mut mu_txs: Vec<Sender<SbsToMu>> = Vec::with_capacity(per_cluster);
    let mut mu_joins = Vec::with_capacity(per_cluster);
    for slot in 0..per_cluster {
        let (tx, rx) = channel::<SbsToMu>();
        mu_txs.push(tx);
        let mctx = MuContext {
            cluster,
            slot,
            worker: cluster * per_cluster + slot,
            dim,
            iters: opts.iters,
            h_period: opts.h_period,
            hierarchical: n > 1,
            momentum: opts.momentum,
            weight_decay: opts.weight_decay,
            phi_ul,
            init: init.clone(),
            compute: compute.clone(),
            adversary: opts.spec.adversary,
            metrics: metrics.clone(),
        };
        let to_sbs = from_mu_tx.clone();
        mu_joins.push(
            std::thread::Builder::new()
                .name(format!("hfl-mu-{}", mctx.worker))
                .spawn(move || mu_actor(mctx, rx, to_sbs))
                .with_context(|| format!("spawning MU thread (cluster {cluster}, slot {slot})"))?,
        );
    }
    drop(from_mu_tx);

    let rounds = cell_rounds(
        opts, cluster, dim, per_cluster, &init, transport, &inbox, &mu_txs, &metrics, &metric_rx,
    );

    // Always release the MUs, error path included — a dead peer must not
    // leave threads parked on their inboxes.
    for tx in &mu_txs {
        let _ = tx.send(SbsToMu::Stop);
    }
    for (slot, j) in mu_joins.into_iter().enumerate() {
        j.join()
            .map_err(|_| anyhow!("MU thread panicked (cluster {cluster}, slot {slot})"))?;
    }
    let (final_model, iter_losses) = rounds?;

    // All producers are gone; what's queued is the complete tail.
    drop(metrics);
    let events = drain_events(&metric_rx);
    transport
        .send(&WireMsg::Done {
            cluster,
            final_model,
            iter_losses,
            events,
        })
        .with_context(|| format!("cluster {cluster} reporting Done"))?;
    Ok(())
}

/// The SBS round loop — bit-identical arithmetic to the in-process actor.
#[allow(clippy::too_many_arguments)]
fn cell_rounds(
    opts: &CoordinatorOptions,
    cluster: usize,
    dim: usize,
    per_cluster: usize,
    init: &Arc<Vec<f32>>,
    transport: &mut dyn Transport,
    inbox: &Receiver<MuToSbs>,
    mu_txs: &[Sender<SbsToMu>],
    metrics: &MetricsSink,
    metric_rx: &Receiver<MetricEvent>,
) -> Result<(Vec<f32>, Vec<(usize, f64)>)> {
    let n = opts.n_clusters;
    let (_phi_ul, phi_sdl, phi_sul, phi_mdl) = effective_phis(opts);
    let (dl_phi, dl_beta) = if n == 1 {
        (phi_mdl, opts.sparsity.beta_m as f32)
    } else {
        (phi_sdl, opts.sparsity.beta_s as f32)
    };
    let schedule = LrSchedule::new(opts.peak_lr, opts.warmup_iters, opts.iters, opts.milestones);

    let mut w_tilde: Vec<f32> = (**init).clone();
    let mut w_global: Vec<f32> = (**init).clone();
    let mut dl_enc = DiscountedError::new(dim, dl_phi, dl_beta);
    let mut ul_enc = DiscountedError::new(dim, phi_sul, opts.sparsity.beta_s as f32);
    let mut agg = vec![0.0f32; dim];
    // Density-adaptive round aggregation (reference baseline −0.0: the
    // accumulator is zeroed, scattered into, then scaled by −lr).
    let mut agg_shadow = DenseShadow::new();
    let mut agg_merged = SparseVec::default();
    let mut agg_scratch = MergeScratch::default();
    let mut iter_losses = Vec::with_capacity(opts.iters);
    let mut period_loss = 0.0f64;
    let mut period_count = 0usize;

    for t in 0..opts.iters {
        let lr = schedule.at(t) as f32;
        // Collect one gradient per slot.
        let mut slots: Vec<Option<MuToSbs>> = (0..per_cluster).map(|_| None).collect();
        let mut got = 0;
        while got < per_cluster {
            let m = inbox
                .recv()
                .map_err(|_| anyhow!("MU actors of cluster {cluster} died at iter {t}"))?;
            let slot = m.slot;
            if slots[slot].is_some() {
                bail!("duplicate gradient from slot {slot} (cluster {cluster}, iter {t})");
            }
            slots[slot] = Some(m);
            got += 1;
        }
        // Aggregate in slot order → bit-identical to the engine; the
        // sparse merge folds each coordinate in the same slot order as
        // the dense scatter, so either path is exact.
        let mut loss_sum = 0.0;
        for m in slots.iter().flatten() {
            loss_sum += m.loss;
        }
        let scale = 1.0 / per_cluster as f32;
        let parts: Vec<(&SparseVec, f32)> =
            slots.iter().flatten().map(|m| (&m.grad, scale)).collect();
        merge::aggregate_adaptive(
            &opts.agg,
            &parts,
            dim,
            Some(-lr),
            &mut agg,
            &mut agg_merged,
            &mut agg_scratch,
            &mut agg_shadow,
        );
        let mean_loss = loss_sum / per_cluster as f64;
        iter_losses.push((t, mean_loss));
        period_loss += mean_loss;
        period_count += 1;

        let dl_msg = dl_enc.compress(&agg);
        metrics.emit(MetricEvent {
            iter: t,
            cluster,
            link: LinkKind::SbsDl,
            bits: dl_msg.wire_bits(32),
            loss: f64::NAN,
        });
        dl_msg.add_into(&mut w_tilde, 1.0);
        for (slot, tx) in mu_txs.iter().enumerate() {
            tx.send(SbsToMu::Update {
                iter: t,
                delta: dl_msg.clone(),
            })
            .map_err(|_| anyhow!("MU inbox closed (cluster {cluster}, slot {slot}, iter {t})"))?;
        }

        // Global sync through the transport.
        if n > 1 && (t + 1) % opts.h_period == 0 {
            let delta: Vec<f32> = (0..dim)
                .map(|i| w_tilde[i] + dl_enc.error()[i] - w_global[i])
                .collect();
            let ul_msg = ul_enc.compress(&delta);
            metrics.emit(MetricEvent {
                iter: t,
                cluster,
                link: LinkKind::SbsUl,
                bits: ul_msg.wire_bits(32),
                loss: f64::NAN,
            });
            transport
                .send(&WireMsg::Sync {
                    cluster,
                    mean_loss: period_loss / period_count.max(1) as f64,
                    delta: ul_msg,
                    events: drain_events(metric_rx),
                })
                .with_context(|| format!("cluster {cluster} syncing at iter {t}"))?;
            period_loss = 0.0;
            period_count = 0;
            // Wait for the MBS's aggregated broadcast.
            let global = match transport
                .recv()
                .with_context(|| format!("cluster {cluster} waiting for broadcast at iter {t}"))?
            {
                WireMsg::GlobalDelta { delta, .. } => delta,
                WireMsg::Refuse { reason } => {
                    bail!("MBS refused mid-run (cluster {cluster}, iter {t}): {reason}")
                }
                other => bail!(
                    "expected GlobalDelta, got {} (cluster {cluster}, iter {t})",
                    other.kind()
                ),
            };
            if global.dim != dim {
                bail!(
                    "broadcast dimension {} != model dimension {dim} (cluster {cluster})",
                    global.dim
                );
            }
            // (MbsDl bits are accounted once at the MBS — it is a broadcast.)
            global.add_into(&mut w_global, 1.0);
            // Pull the cluster reference toward the new global model.
            let delta: Vec<f32> = (0..dim).map(|i| w_global[i] - w_tilde[i]).collect();
            let dl_msg = dl_enc.compress(&delta);
            metrics.emit(MetricEvent {
                iter: t,
                cluster,
                link: LinkKind::SbsDl,
                bits: dl_msg.wire_bits(32),
                loss: f64::NAN,
            });
            dl_msg.add_into(&mut w_tilde, 1.0);
            for (slot, tx) in mu_txs.iter().enumerate() {
                tx.send(SbsToMu::Update {
                    iter: t,
                    delta: dl_msg.clone(),
                })
                .map_err(|_| {
                    anyhow!("MU inbox closed (cluster {cluster}, slot {slot}, iter {t})")
                })?;
            }
        }
    }
    Ok((w_tilde, iter_losses))
}
