//! The coordinator's wire messages and their two serialization lanes.
//!
//! Control messages (session handshake) travel as **exact JSON** — the
//! crate's own strict codec, no serde — so a refused handshake is
//! human-readable on the wire. Data-plane messages (sync deltas, the
//! global broadcast, final models) travel **binary**: `ByteWriter`
//! scalars plus the delta-packed [`SparseWire`] codec for every model
//! delta, so the framed payload *is* the realized stream the latency
//! model prices.
//!
//! Bit-accounting invariant, asserted at this boundary for every encoded
//! delta: `SparseWire::encoded_bits() ≤ SparseVec::wire_bits(32)` — the
//! framed form never exceeds the fixed-width pricing
//! ([`crate::wireless::latency::payload_bits`]) the engines bill.

use crate::coordinator::{LinkKind, MetricEvent};
use crate::snapshot::codec::{ByteReader, ByteWriter};
use crate::sparse::{SparseVec, SparseWire};
use crate::util::json::{self, Json, ObjBuilder};
use anyhow::{anyhow, bail, Context, Result};

/// Handshake: worker → MBS (JSON lane).
pub const TAG_HELLO: u8 = 1;
/// Handshake: MBS → worker, cluster assignment (JSON lane).
pub const TAG_WELCOME: u8 = 2;
/// Handshake: MBS → worker, session refused (JSON lane).
pub const TAG_REFUSE: u8 = 3;
/// Data plane: SBS → MBS period sync (binary lane).
pub const TAG_SYNC: u8 = 4;
/// Data plane: MBS → SBS global broadcast (binary lane).
pub const TAG_GLOBAL_DELTA: u8 = 5;
/// Data plane: SBS → MBS final model + losses (binary lane).
pub const TAG_DONE: u8 = 6;
/// Session log only: run header (JSON lane).
pub const TAG_SESSION_HEADER: u8 = 7;
/// Session log only: one logged message envelope (binary lane).
pub const TAG_SESSION_RECORD: u8 = 8;
/// Recovery: worker → MBS after reconnecting mid-run (JSON lane).
pub const TAG_REJOIN: u8 = 9;
/// Recovery: MBS declares a cluster dead and reweights without it
/// (session log / observability, JSON lane).
pub const TAG_SKIP: u8 = 10;

/// One message between a worker cell (SBS + its MUs) and the MBS.
#[derive(Clone, Debug, PartialEq)]
pub enum WireMsg {
    /// Worker joins: scenario fingerprint + optionally requested cluster.
    Hello {
        fingerprint: u64,
        cluster: Option<usize>,
    },
    /// MBS accepts: deterministic cluster assignment.
    Welcome { cluster: usize, n_clusters: usize },
    /// MBS refuses (fingerprint mismatch, cluster taken, …).
    Refuse { reason: String },
    /// One H-period sync: the cluster's discounted-error delta plus the
    /// metric events accumulated since the last send.
    Sync {
        cluster: usize,
        mean_loss: f64,
        delta: SparseVec,
        events: Vec<MetricEvent>,
    },
    /// The MBS's aggregated broadcast after sync round `sync_index`.
    GlobalDelta { sync_index: usize, delta: SparseVec },
    /// End of run: the cluster's final reference model, its per-iteration
    /// losses, and any metric events not yet shipped.
    Done {
        cluster: usize,
        final_model: Vec<f32>,
        iter_losses: Vec<(usize, f64)>,
        events: Vec<MetricEvent>,
    },
    /// A reconnected worker re-enters the run: it has replayed the
    /// `Welcome` handshake for `cluster` and asks to be caught up from
    /// broadcast `round` onward (0 = replay everything).
    Rejoin { cluster: usize, round: usize },
    /// The MBS declared `cluster` dead during sync round `round` and
    /// reweighted the consensus over survivors. Logged (Tx/broadcast
    /// lane) so replay reconstructs the degraded trace; never sent to a
    /// live worker.
    Skip {
        cluster: usize,
        round: usize,
        reason: String,
    },
}

impl WireMsg {
    /// Short name for error contexts.
    pub fn kind(&self) -> &'static str {
        match self {
            WireMsg::Hello { .. } => "Hello",
            WireMsg::Welcome { .. } => "Welcome",
            WireMsg::Refuse { .. } => "Refuse",
            WireMsg::Sync { .. } => "Sync",
            WireMsg::GlobalDelta { .. } => "GlobalDelta",
            WireMsg::Done { .. } => "Done",
            WireMsg::Rejoin { .. } => "Rejoin",
            WireMsg::Skip { .. } => "Skip",
        }
    }
}

fn link_to_u8(l: LinkKind) -> u8 {
    match l {
        LinkKind::MuUl => 0,
        LinkKind::SbsDl => 1,
        LinkKind::SbsUl => 2,
        LinkKind::MbsDl => 3,
    }
}

fn link_from_u8(b: u8) -> Result<LinkKind> {
    Ok(match b {
        0 => LinkKind::MuUl,
        1 => LinkKind::SbsDl,
        2 => LinkKind::SbsUl,
        3 => LinkKind::MbsDl,
        other => bail!("unknown link kind tag {other}"),
    })
}

/// Serialize a model delta through [`SparseWire`], asserting the
/// bit-accounting invariant at the transport boundary: the realized
/// stream must never exceed the fixed-width `wire_bits(32)` form the
/// wireless model prices.
fn put_delta(w: &mut ByteWriter, v: &SparseVec) {
    let wire = SparseWire::encode(v);
    assert!(
        wire.encoded_bits() as f64 <= v.wire_bits(32) + 1e-9,
        "framed delta ({} bits) exceeds priced payload_bits form ({} bits)",
        wire.encoded_bits(),
        v.wire_bits(32)
    );
    w.put_usize(wire.dim);
    w.put_usize(wire.nnz);
    w.put_u32(wire.gap_bits());
    w.put_u64_slice(wire.words());
}

fn get_delta(r: &mut ByteReader) -> Result<SparseVec> {
    let dim = r.get_usize()?;
    let nnz = r.get_usize()?;
    let gap_bits = r.get_u32()?;
    let words = r.get_u64_vec()?;
    let wire = SparseWire::from_parts(dim, nnz, gap_bits, words)?;
    wire.decode_checked()
}

fn put_events(w: &mut ByteWriter, events: &[MetricEvent]) {
    w.put_usize(events.len());
    for e in events {
        w.put_usize(e.iter);
        w.put_usize(e.cluster);
        w.put_u8(link_to_u8(e.link));
        w.put_f64(e.bits);
        w.put_f64(e.loss);
    }
}

fn get_events(r: &mut ByteReader) -> Result<Vec<MetricEvent>> {
    let n = r.get_usize()?;
    let mut out = Vec::with_capacity(n.min(1 << 20));
    for _ in 0..n {
        out.push(MetricEvent {
            iter: r.get_usize()?,
            cluster: r.get_usize()?,
            link: link_from_u8(r.get_u8()?)?,
            bits: r.get_f64()?,
            loss: r.get_f64()?,
        });
    }
    Ok(out)
}

fn fingerprint_to_json(fp: u64) -> String {
    format!("{fp:016x}")
}

fn fingerprint_from_json(j: &Json, key: &str) -> Result<u64> {
    let s = j
        .get(key)
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("missing `{key}`"))?;
    u64::from_str_radix(s, 16).with_context(|| format!("parsing `{key}` hex `{s}`"))
}

/// Encode one message to its `(tag, payload)` pair.
pub fn encode_payload(msg: &WireMsg) -> (u8, Vec<u8>) {
    match msg {
        WireMsg::Hello {
            fingerprint,
            cluster,
        } => {
            let b = ObjBuilder::new().str("fingerprint", fingerprint_to_json(*fingerprint));
            let b = match cluster {
                Some(c) => b.num("cluster", *c as f64),
                None => b.val("cluster", Json::Null),
            };
            (TAG_HELLO, b.build().to_string_compact().into_bytes())
        }
        WireMsg::Welcome {
            cluster,
            n_clusters,
        } => (
            TAG_WELCOME,
            ObjBuilder::new()
                .num("cluster", *cluster as f64)
                .num("n_clusters", *n_clusters as f64)
                .build()
                .to_string_compact()
                .into_bytes(),
        ),
        WireMsg::Refuse { reason } => (
            TAG_REFUSE,
            ObjBuilder::new()
                .str("reason", reason.clone())
                .build()
                .to_string_compact()
                .into_bytes(),
        ),
        WireMsg::Sync {
            cluster,
            mean_loss,
            delta,
            events,
        } => {
            let mut w = ByteWriter::new();
            w.put_usize(*cluster);
            w.put_f64(*mean_loss);
            put_delta(&mut w, delta);
            put_events(&mut w, events);
            (TAG_SYNC, w.into_bytes())
        }
        WireMsg::GlobalDelta { sync_index, delta } => {
            let mut w = ByteWriter::new();
            w.put_usize(*sync_index);
            put_delta(&mut w, delta);
            (TAG_GLOBAL_DELTA, w.into_bytes())
        }
        WireMsg::Done {
            cluster,
            final_model,
            iter_losses,
            events,
        } => {
            let mut w = ByteWriter::new();
            w.put_usize(*cluster);
            w.put_f32_slice(final_model);
            w.put_usize(iter_losses.len());
            for (it, loss) in iter_losses {
                w.put_usize(*it);
                w.put_f64(*loss);
            }
            put_events(&mut w, events);
            (TAG_DONE, w.into_bytes())
        }
        WireMsg::Rejoin { cluster, round } => (
            TAG_REJOIN,
            ObjBuilder::new()
                .num("cluster", *cluster as f64)
                .num("round", *round as f64)
                .build()
                .to_string_compact()
                .into_bytes(),
        ),
        WireMsg::Skip {
            cluster,
            round,
            reason,
        } => (
            TAG_SKIP,
            ObjBuilder::new()
                .num("cluster", *cluster as f64)
                .num("round", *round as f64)
                .str("reason", reason.clone())
                .build()
                .to_string_compact()
                .into_bytes(),
        ),
    }
}

/// Decode one message from its `(tag, payload)` pair.
pub fn decode_payload(tag: u8, payload: &[u8]) -> Result<WireMsg> {
    match tag {
        TAG_HELLO | TAG_WELCOME | TAG_REFUSE | TAG_REJOIN | TAG_SKIP => {
            let text = std::str::from_utf8(payload).context("control payload is not UTF-8")?;
            let j = json::parse(text).map_err(|e| anyhow!("control payload JSON: {e}"))?;
            let field = |key: &str| {
                j.get(key)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("control payload missing `{key}`"))
            };
            match tag {
                TAG_HELLO => Ok(WireMsg::Hello {
                    fingerprint: fingerprint_from_json(&j, "fingerprint")
                        .context("decoding Hello")?,
                    cluster: match j.get("cluster") {
                        Some(Json::Null) | None => None,
                        Some(v) => Some(
                            v.as_usize()
                                .ok_or_else(|| anyhow!("Hello cluster not a usize"))?,
                        ),
                    },
                }),
                TAG_WELCOME => Ok(WireMsg::Welcome {
                    cluster: field("cluster").context("decoding Welcome")?,
                    n_clusters: field("n_clusters").context("decoding Welcome")?,
                }),
                TAG_REJOIN => Ok(WireMsg::Rejoin {
                    cluster: field("cluster").context("decoding Rejoin")?,
                    round: field("round").context("decoding Rejoin")?,
                }),
                TAG_SKIP => Ok(WireMsg::Skip {
                    cluster: field("cluster").context("decoding Skip")?,
                    round: field("round").context("decoding Skip")?,
                    reason: j
                        .get("reason")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("Skip missing reason"))?
                        .to_string(),
                }),
                _ => Ok(WireMsg::Refuse {
                    reason: j
                        .get("reason")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("Refuse missing reason"))?
                        .to_string(),
                }),
            }
        }
        TAG_SYNC => {
            let mut r = ByteReader::new(payload);
            let msg = WireMsg::Sync {
                cluster: r.get_usize()?,
                mean_loss: r.get_f64()?,
                delta: get_delta(&mut r).context("decoding Sync delta")?,
                events: get_events(&mut r).context("decoding Sync events")?,
            };
            r.finish()?;
            Ok(msg)
        }
        TAG_GLOBAL_DELTA => {
            let mut r = ByteReader::new(payload);
            let msg = WireMsg::GlobalDelta {
                sync_index: r.get_usize()?,
                delta: get_delta(&mut r).context("decoding GlobalDelta delta")?,
            };
            r.finish()?;
            Ok(msg)
        }
        TAG_DONE => {
            let mut r = ByteReader::new(payload);
            let cluster = r.get_usize()?;
            let final_model = r.get_f32_vec()?;
            let n = r.get_usize()?;
            let mut iter_losses = Vec::with_capacity(n.min(1 << 20));
            for _ in 0..n {
                iter_losses.push((r.get_usize()?, r.get_f64()?));
            }
            let events = get_events(&mut r).context("decoding Done events")?;
            r.finish()?;
            Ok(WireMsg::Done {
                cluster,
                final_model,
                iter_losses,
                events,
            })
        }
        other => bail!("unknown message tag {other}"),
    }
}

/// Encode one message as a complete frame (header + payload + checksum).
pub fn encode_frame_msg(msg: &WireMsg) -> Vec<u8> {
    let (tag, payload) = encode_payload(msg);
    super::frame::encode_frame(tag, &payload)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn sparse(dim: usize, keep: f64, seed: u64) -> SparseVec {
        let mut rng = Pcg64::seeded(seed);
        let mut v = SparseVec::empty(dim);
        for i in 0..dim {
            if rng.uniform() < keep {
                v.indices.push(i as u32);
                v.values.push(rng.normal() as f32);
            }
        }
        v
    }

    fn events() -> Vec<MetricEvent> {
        vec![
            MetricEvent {
                iter: 3,
                cluster: 1,
                link: LinkKind::MuUl,
                bits: 1536.0,
                loss: 0.25,
            },
            MetricEvent {
                iter: 7,
                cluster: usize::MAX,
                link: LinkKind::MbsDl,
                bits: 4096.0,
                loss: f64::NAN,
            },
        ]
    }

    fn roundtrip(msg: &WireMsg) -> WireMsg {
        let (tag, payload) = encode_payload(msg);
        decode_payload(tag, &payload).unwrap()
    }

    #[test]
    fn control_messages_roundtrip_as_json() {
        for msg in [
            WireMsg::Hello {
                fingerprint: 0xdead_beef_0123_4567,
                cluster: Some(2),
            },
            WireMsg::Hello {
                fingerprint: 7,
                cluster: None,
            },
            WireMsg::Welcome {
                cluster: 1,
                n_clusters: 4,
            },
            WireMsg::Refuse {
                reason: "fingerprint mismatch".into(),
            },
            WireMsg::Rejoin {
                cluster: 1,
                round: 3,
            },
            WireMsg::Skip {
                cluster: 2,
                round: 4,
                reason: "recv deadline".into(),
            },
        ] {
            assert_eq!(roundtrip(&msg), msg, "{}", msg.kind());
            // The control lane really is JSON.
            let (_, payload) = encode_payload(&msg);
            json::parse(std::str::from_utf8(&payload).unwrap()).unwrap();
        }
    }

    #[test]
    fn sync_roundtrips_bit_exactly() {
        let msg = WireMsg::Sync {
            cluster: 3,
            mean_loss: 0.015625,
            delta: sparse(200, 0.1, 11),
            events: events(),
        };
        let back = roundtrip(&msg);
        // NaN loss breaks PartialEq; compare fields by bits.
        let (WireMsg::Sync { delta: a, events: ea, .. }, WireMsg::Sync { delta: b, events: eb, .. }) =
            (&msg, &back)
        else {
            panic!("kind changed");
        };
        assert_eq!(a, b);
        assert_eq!(ea.len(), eb.len());
        for (x, y) in ea.iter().zip(eb) {
            assert_eq!(x.iter, y.iter);
            assert_eq!(x.cluster, y.cluster);
            assert_eq!(x.link, y.link);
            assert_eq!(x.bits.to_bits(), y.bits.to_bits());
            assert_eq!(x.loss.to_bits(), y.loss.to_bits());
        }
    }

    #[test]
    fn global_delta_and_done_roundtrip() {
        let g = WireMsg::GlobalDelta {
            sync_index: 5,
            delta: sparse(64, 0.5, 12),
        };
        assert_eq!(roundtrip(&g), g);
        let d = WireMsg::Done {
            cluster: 0,
            final_model: vec![1.0, -0.0, f32::MIN_POSITIVE, 3.5],
            iter_losses: vec![(0, 0.5), (1, 0.25)],
            events: Vec::new(),
        };
        assert_eq!(roundtrip(&d), d);
    }

    #[test]
    fn sync_delta_bits_never_exceed_priced_form() {
        // Satellite invariant, per delta-bearing message kind: the framed
        // SparseWire stream stays within the fixed-width pricing.
        for keep in [0.0, 0.05, 0.5, 1.0] {
            let v = sparse(1 << 12, keep, 21);
            let bound = v.wire_bits(32);
            let wire = SparseWire::encode(&v);
            assert!(wire.encoded_bits() as f64 <= bound + 1e-9, "keep {keep}");
            // Encoding through each message kind exercises the boundary
            // assert in put_delta.
            let _ = encode_payload(&WireMsg::Sync {
                cluster: 0,
                mean_loss: 0.0,
                delta: v.clone(),
                events: Vec::new(),
            });
        }
    }

    #[test]
    fn global_delta_bits_never_exceed_priced_form() {
        for keep in [0.01, 0.3, 1.0] {
            let v = sparse(1 << 10, keep, 22);
            let bound = v.wire_bits(32);
            assert!(SparseWire::encode(&v).encoded_bits() as f64 <= bound + 1e-9);
            let _ = encode_payload(&WireMsg::GlobalDelta {
                sync_index: 0,
                delta: v,
            });
        }
    }

    #[test]
    fn corrupt_delta_payload_is_named_error() {
        // Re-frame a Sync whose delta claims a smaller dim than its
        // indices reach: the checked decode must refuse it.
        let v = sparse(100, 0.3, 31);
        let msg = WireMsg::Sync {
            cluster: 0,
            mean_loss: 0.0,
            delta: v,
            events: Vec::new(),
        };
        let (tag, payload) = encode_payload(&msg);
        let mut w = ByteWriter::new();
        w.put_usize(0); // cluster
        w.put_f64(0.0); // mean_loss
        w.put_usize(4); // lie about dim
        let mut r = ByteReader::new(&payload);
        let _ = r.get_usize().unwrap();
        let _ = r.get_f64().unwrap();
        let _ = r.get_usize().unwrap(); // original dim
        let nnz = r.get_usize().unwrap();
        w.put_usize(nnz);
        w.put_u32(r.get_u32().unwrap());
        w.put_u64_slice(&r.get_u64_vec().unwrap());
        put_events(&mut w, &[]);
        let err = decode_payload(tag, &w.into_bytes()).unwrap_err();
        assert!(format!("{err:#}").contains("outside dim"), "{err:#}");
    }

    #[test]
    fn frame_msg_roundtrips_through_frame_codec() {
        let msg = WireMsg::GlobalDelta {
            sync_index: 2,
            delta: sparse(50, 0.2, 41),
        };
        let bytes = encode_frame_msg(&msg);
        let (tag, payload, consumed) = super::super::frame::decode_frame(&bytes).unwrap().unwrap();
        assert_eq!(consumed, bytes.len());
        assert_eq!(decode_payload(tag, &payload).unwrap(), msg);
    }
}
