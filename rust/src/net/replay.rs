//! Deterministic replay: rebuild a full [`CoordinatorRun`] — and hence
//! its `GoldenTrace` — from a session log alone, bit-exactly.
//!
//! Replay is a *fold over logged messages*, not a re-execution: every
//! golden-traced quantity is already in the log. Final parameters fold
//! from the `Done` records in cluster order with the same
//! `+= v / n` expression as the live MBS; the training-loss curve merges
//! the logged per-iteration losses through the same helpers; per-link
//! bits come from the events piggybacked on `Sync`/`Done`, with the
//! `MbsDl` broadcast events re-derived from the logged `GlobalDelta`
//! payloads exactly as the live MBS prices them. Held-out evaluation is
//! the one thing a log cannot contain (it needs the oracle), so
//! `final_eval`/`sync_evals` are empty defaults — neither enters the
//! golden trace.
//!
//! Degraded sessions replay too: a logged `Skip` marks its cluster dead
//! (excused from `Done`, collected into the run's `skips`), a logged
//! `Rejoin` revives it, and the final-model divisor is the count of
//! `Done` records — the survivors — matching the live MBS's
//! degrade-and-continue fold exactly.

use super::serve::{finish_losses, fold_final_model, merge_losses};
use super::session::{read_session, Direction, SessionHeader, BROADCAST};
use super::wire::WireMsg;
use crate::coordinator::{CoordinatorRun, LinkKind, MetricEvent, MetricsLog};
use crate::fl::oracle::EvalMetrics;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Fold a session log back into the run it recorded.
pub fn replay_session(path: &Path) -> Result<(SessionHeader, CoordinatorRun)> {
    let (header, records) = read_session(path)?;
    let n = header.n_clusters;
    if n == 0 {
        bail!("session header claims 0 clusters");
    }
    let mut metrics = MetricsLog::default();
    let mut final_params = vec![0.0f32; header.dim];
    let mut loss_acc: Vec<(usize, f64, usize)> = Vec::new();
    let mut done = vec![false; n];
    let mut skipped = vec![false; n];
    let mut skips: Vec<(usize, usize)> = Vec::new();
    let mut next_sync = 0usize;

    // Pre-pass: the live MBS folds Done models over the survivor count,
    // so replay must divide by the number of Done records — not n.
    let n_done = records
        .iter()
        .filter(|r| matches!((&r.dir, &r.msg), (Direction::Rx, WireMsg::Done { .. })))
        .count();

    for (i, rec) in records.iter().enumerate() {
        let at = || format!("session record {i}");
        match (&rec.dir, &rec.msg) {
            (Direction::Rx, WireMsg::Sync { cluster, events, .. }) => {
                if *cluster as u32 != rec.cluster {
                    bail!("{}: Sync from cluster {cluster} logged under {}", at(), rec.cluster);
                }
                for ev in events {
                    metrics.push(*ev);
                }
            }
            (Direction::Tx, WireMsg::GlobalDelta { sync_index, delta }) => {
                if rec.cluster != BROADCAST {
                    bail!("{}: GlobalDelta not logged as a broadcast", at());
                }
                if *sync_index != next_sync {
                    bail!(
                        "{}: broadcast for sync {sync_index}, expected {next_sync} (log out of order?)",
                        at()
                    );
                }
                next_sync += 1;
                // Re-derive the MbsDl accounting event exactly as the
                // live MBS emitted it for this broadcast.
                metrics.push(MetricEvent {
                    iter: (sync_index + 1) * header.h_period - 1,
                    cluster: usize::MAX,
                    link: LinkKind::MbsDl,
                    bits: delta.wire_bits(32),
                    loss: f64::NAN,
                });
            }
            (Direction::Rx, WireMsg::Done { cluster, final_model, iter_losses, events }) => {
                if *cluster >= n {
                    bail!("{}: Done from out-of-range cluster {cluster}", at());
                }
                if done[*cluster] {
                    bail!("{}: duplicate Done from cluster {cluster}", at());
                }
                done[*cluster] = true;
                for ev in events {
                    metrics.push(*ev);
                }
                fold_final_model(&mut final_params, final_model, n_done)
                    .with_context(|| format!("{}: folding cluster {cluster}", at()))?;
                merge_losses(&mut loss_acc, iter_losses);
            }
            (Direction::Tx, WireMsg::Skip { cluster, round, .. }) => {
                if *cluster >= n {
                    bail!("{}: Skip of out-of-range cluster {cluster}", at());
                }
                skipped[*cluster] = true;
                skips.push((*cluster, *round));
            }
            (Direction::Rx, WireMsg::Rejoin { cluster, .. }) => {
                if *cluster >= n {
                    bail!("{}: Rejoin of out-of-range cluster {cluster}", at());
                }
                // A rejoined cluster is live again (informational — a
                // Rejoin record normally precedes any Skip of it).
                skipped[*cluster] = false;
            }
            (dir, msg) => bail!("{}: unexpected {:?} {} in session log", at(), dir, msg.kind()),
        }
    }

    // A skipped cluster is excused from Done; anyone else missing means
    // the log is torn.
    if let Some(missing) = done
        .iter()
        .zip(&skipped)
        .position(|(d, s)| !d && !s)
    {
        bail!(
            "cluster {missing} never reported Done — incomplete session log \
             (the run may have crashed; {next_sync} sync rounds were recorded)"
        );
    }
    Ok((
        header,
        CoordinatorRun {
            final_params,
            final_eval: EvalMetrics::default(),
            sync_evals: Vec::new(),
            metrics,
            train_loss: finish_losses(loss_acc),
            skips,
        },
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::session::SessionLog;
    use crate::sparse::SparseVec;

    fn header(n_clusters: usize) -> SessionHeader {
        SessionHeader {
            name: "replay-test".into(),
            fingerprint: 7,
            dim: 4,
            n_clusters,
            workers: 2 * n_clusters,
            h_period: 2,
            iters: 2,
            sparse: false,
        }
    }

    fn done(cluster: usize) -> WireMsg {
        WireMsg::Done {
            cluster,
            final_model: vec![2.0, 4.0, 6.0, 8.0],
            iter_losses: vec![(0, 1.0), (1, 0.5)],
            events: vec![MetricEvent {
                iter: 0,
                cluster,
                link: LinkKind::MuUl,
                bits: 64.0,
                loss: 1.0,
            }],
        }
    }

    #[test]
    fn replays_fold_of_done_records() {
        let dir = std::env::temp_dir().join(format!("hfl-replay-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("fold.hlog");
        {
            let mut log = SessionLog::create(&path, &header(2)).unwrap();
            log.append(
                Direction::Tx,
                BROADCAST,
                &WireMsg::GlobalDelta {
                    sync_index: 0,
                    delta: SparseVec {
                        dim: 4,
                        indices: vec![1],
                        values: vec![0.5],
                    },
                },
            )
            .unwrap();
            log.append(Direction::Rx, 0, &done(0)).unwrap();
            log.append(Direction::Rx, 1, &done(1)).unwrap();
        }
        let (h, run) = replay_session(&path).unwrap();
        assert_eq!(h.n_clusters, 2);
        // Two identical final models averaged over n=2 → the model itself.
        assert_eq!(run.final_params, vec![2.0, 4.0, 6.0, 8.0]);
        assert_eq!(run.train_loss, vec![(0, 1.0), (1, 0.5)]);
        // Two MuUl events plus one re-derived MbsDl broadcast event.
        let bits = run.metrics.comm_bits();
        assert_eq!(bits.n_mu_msgs, 2);
        assert_eq!(bits.mu_ul, 128.0);
        assert!(bits.mbs_dl > 0.0);
        // MbsDl event sits at the sync boundary iteration (h_period 2).
        assert!(run
            .metrics
            .events
            .iter()
            .any(|e| e.link == LinkKind::MbsDl && e.iter == 1));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_done_is_a_named_error() {
        let dir = std::env::temp_dir().join(format!("hfl-replay-miss-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("missing.hlog");
        {
            let mut log = SessionLog::create(&path, &header(2)).unwrap();
            log.append(Direction::Rx, 0, &done(0)).unwrap();
        }
        let err = replay_session(&path).unwrap_err();
        assert!(
            format!("{err:#}").contains("cluster 1 never reported Done"),
            "{err:#}"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn skip_record_excuses_missing_done_and_reweights_fold() {
        let dir = std::env::temp_dir().join(format!("hfl-replay-skip-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("skip.hlog");
        {
            let mut log = SessionLog::create(&path, &header(2)).unwrap();
            // Cluster 1 dies during round 0; only cluster 0 finishes.
            log.append(
                Direction::Tx,
                1,
                &WireMsg::Skip {
                    cluster: 1,
                    round: 0,
                    reason: "recv failed".into(),
                },
            )
            .unwrap();
            log.append(Direction::Rx, 0, &done(0)).unwrap();
        }
        let (_, run) = replay_session(&path).unwrap();
        // Divisor is the survivor count (1), not n (2).
        assert_eq!(run.final_params, vec![2.0, 4.0, 6.0, 8.0]);
        assert_eq!(run.skips, vec![(1, 0)]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejoin_record_revives_a_cluster() {
        let dir = std::env::temp_dir().join(format!("hfl-replay-rejoin-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rejoin.hlog");
        {
            let mut log = SessionLog::create(&path, &header(2)).unwrap();
            log.append(
                Direction::Rx,
                1,
                &WireMsg::Rejoin {
                    cluster: 1,
                    round: 0,
                },
            )
            .unwrap();
            log.append(Direction::Rx, 0, &done(0)).unwrap();
            log.append(Direction::Rx, 1, &done(1)).unwrap();
        }
        let (_, run) = replay_session(&path).unwrap();
        // Both clusters finished: the rejoin kept cluster 1 accountable
        // and the fold divides by 2 as on a clean run.
        assert_eq!(run.final_params, vec![2.0, 4.0, 6.0, 8.0]);
        assert!(run.skips.is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn out_of_order_broadcast_is_a_named_error() {
        let dir = std::env::temp_dir().join(format!("hfl-replay-ooo-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ooo.hlog");
        {
            let mut log = SessionLog::create(&path, &header(1)).unwrap();
            log.append(
                Direction::Tx,
                BROADCAST,
                &WireMsg::GlobalDelta {
                    sync_index: 3,
                    delta: SparseVec::empty(4),
                },
            )
            .unwrap();
        }
        let err = replay_session(&path).unwrap_err();
        assert!(format!("{err:#}").contains("expected 0"), "{err:#}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
