//! The scenario a `hfl serve`/`hfl worker` session trains: a quadratic
//! oracle plus coordinator options, built identically on both sides of
//! the wire from config + flags.
//!
//! Because server and workers construct their own oracles (the model
//! never crosses the wire, only deltas), both sides MUST agree on every
//! bit-relevant scalar. [`NetScenario::fingerprint`] hashes exactly those
//! scalars; the handshake refuses a worker whose fingerprint differs —
//! the same refuse-loudly discipline as snapshot restore. The
//! aggregation `path`/`crossover` are deliberately excluded (every
//! `--agg-path` is bit-identical, so mixed dispatch across processes is
//! legal), but the consensus `rule` and the adversary plan change the
//! arithmetic and ride [`RunSpec::put_fingerprint`] — both sides must
//! pass the same `--agg-rule`/`--adversary-*` flags.

use super::session::SessionHeader;
use crate::cli::Args;
use crate::config::{Config, SparsityConfig};
use crate::coordinator::CoordinatorOptions;
use crate::fl::oracle::QuadraticOracle;
use crate::sim::result::{fnv1a64, ScenarioMeta};
use crate::snapshot::codec::ByteWriter;
use crate::spec::RunSpec;
use anyhow::{bail, Result};

/// One fully specified network-training scenario.
#[derive(Clone, Debug)]
pub struct NetScenario {
    pub name: String,
    pub dim: usize,
    pub n_clusters: usize,
    pub mus_per_cluster: usize,
    pub iters: usize,
    /// MU-uplink sparsity pin (`--phi`); `None` = dense.
    pub phi: Option<f64>,
    pub seed: u64,
    pub copts: CoordinatorOptions,
}

impl NetScenario {
    /// Build from the shared scenario flags (`--dim`, `--iters`, `--phi`)
    /// on top of a loaded config (which already carries `--clusters`,
    /// `--mus`, `--h` and `--seed`). Must parse identically for `serve`
    /// and `worker` — the fingerprint only *detects* divergence.
    pub fn from_cli(args: &Args, cfg: &Config) -> Result<Self> {
        let dim = args.get_parsed_or("dim", 64usize)?;
        let iters = crate::cli::count_from_args(args, "iters")?.unwrap_or(24);
        let phi = crate::cli::phi_from_args(args)?;
        if dim == 0 || iters == 0 {
            bail!("--dim and --iters must be > 0");
        }
        let n_clusters = cfg.topology.n_clusters;
        let mus_per_cluster = cfg.topology.mus_per_cluster;
        let seed = cfg.training.seed;
        let sparsity = match phi {
            Some(p) => SparsityConfig {
                enabled: true,
                phi_mu_ul: p,
                ..cfg.sparsity.clone()
            },
            None => SparsityConfig::dense(),
        };
        let copts = CoordinatorOptions {
            spec: RunSpec::new()
                .iters(iters)
                .peak_lr(0.05)
                .warmup(iters / 10)
                .milestones(0.6, 0.85)
                .h_period(cfg.training.h_period)
                .sparsity(sparsity)
                .agg(cfg.agg),
            n_clusters,
            eval_every_syncs: 0,
        };
        let sparse_tag = match phi {
            Some(p) => format!("phi{p:.2}"),
            None => "dense".into(),
        };
        Ok(Self {
            name: format!(
                "net-c{n_clusters}x{mus_per_cluster}-h{}-i{iters}-{sparse_tag}-d{dim}-s{seed}",
                copts.h_period
            ),
            dim,
            n_clusters,
            mus_per_cluster,
            iters,
            phi,
            seed,
            copts,
        })
    }

    pub fn workers(&self) -> usize {
        self.n_clusters * self.mus_per_cluster
    }

    /// Hash of every bit-relevant scalar — what the handshake compares.
    /// The training scalars come from [`RunSpec::put_fingerprint`] (which
    /// covers `iters`), so the list cannot drift from the snapshot
    /// fingerprints; only the topology/seed scalars are added here.
    pub fn fingerprint(&self) -> u64 {
        let mut w = ByteWriter::new();
        w.put_usize(self.dim);
        w.put_usize(self.n_clusters);
        w.put_usize(self.mus_per_cluster);
        w.put_u64(self.seed);
        self.copts.spec.put_fingerprint(&mut w);
        fnv1a64(w.into_bytes())
    }

    /// The deterministic oracle both sides construct (noiseless — required
    /// for cross-process bit-equality).
    pub fn oracle(&self) -> QuadraticOracle {
        QuadraticOracle::new(self.dim, self.workers(), 0.0, self.seed)
    }

    /// Session-log header for this scenario.
    pub fn header(&self) -> SessionHeader {
        SessionHeader {
            name: self.name.clone(),
            fingerprint: self.fingerprint(),
            dim: self.dim,
            n_clusters: self.n_clusters,
            workers: self.workers(),
            h_period: self.copts.h_period,
            iters: self.iters,
            sparse: self.copts.sparsity.enabled,
        }
    }

    /// Scenario identity for result/golden-trace construction.
    pub fn meta(&self) -> ScenarioMeta {
        self.header().meta()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scenario(extra: &[&str]) -> Result<NetScenario> {
        let mut argv = vec!["serve"];
        argv.extend_from_slice(extra);
        let args = Args::parse(argv)?;
        let mut cfg = Config::default();
        cfg.topology.n_clusters = 2;
        cfg.topology.mus_per_cluster = 3;
        NetScenario::from_cli(&args, &cfg)
    }

    #[test]
    fn defaults_and_name_are_stable() {
        let s = scenario(&[]).unwrap();
        assert_eq!(s.dim, 64);
        assert_eq!(s.iters, 24);
        assert_eq!(s.workers(), 6);
        assert!(!s.copts.sparsity.enabled);
        assert_eq!(s.name, "net-c2x3-h2-i24-dense-d64-s1");
        assert_eq!(s.meta().workers, 6);
        assert_eq!(s.header().fingerprint, s.fingerprint());
    }

    #[test]
    fn fingerprint_is_sensitive_to_bit_relevant_scalars() {
        let base = scenario(&[]).unwrap().fingerprint();
        for flags in [
            vec!["--dim", "65"],
            vec!["--iters", "25"],
            vec!["--phi", "0.9"],
        ] {
            let other = scenario(&flags).unwrap().fingerprint();
            assert_ne!(base, other, "{flags:?} should change the fingerprint");
        }
        // Same flags → same fingerprint (both sides of the handshake).
        assert_eq!(base, scenario(&[]).unwrap().fingerprint());
    }

    #[test]
    fn rule_and_adversary_plan_move_the_fingerprint() {
        // `cmd_serve`/`cmd_worker` set these after `from_cli`; both change
        // the arithmetic, so the handshake must detect a one-sided flag.
        let mut s = scenario(&[]).unwrap();
        let base = s.fingerprint();
        s.copts.agg.rule = crate::sparse::AggRule::CoordMedian;
        let ruled = s.fingerprint();
        assert_ne!(base, ruled);
        s.copts.spec.adversary.enabled = true;
        assert_ne!(ruled, s.fingerprint());
    }

    #[test]
    fn phi_pin_enables_sparsity_and_is_validated() {
        let s = scenario(&["--phi", "0.9"]).unwrap();
        assert!(s.copts.sparsity.enabled);
        assert_eq!(s.copts.sparsity.phi_mu_ul, 0.9);
        assert!(s.name.contains("phi0.90"));
        assert!(scenario(&["--phi", "1.0"]).is_err());
        assert!(scenario(&["--phi", "-0.1"]).is_err());
    }
}
