//! Append-only session message log with deterministic replay support.
//!
//! The MBS logs every data-plane message it processes — each worker
//! `Sync`/`Done` as it is received (in cluster order within a barrier
//! round) and each `GlobalDelta` broadcast once (cluster `u32::MAX`) —
//! so `hfl replay` can reconstruct the full [`CoordinatorRun`] and its
//! `GoldenTrace` from the log alone, bit-exactly
//! (see [`super::replay`]).
//!
//! File layout: a sequence of [`super::frame`] frames. The first frame
//! (tag `TAG_SESSION_HEADER`) is an exact-JSON run header; every later
//! frame (tag `TAG_SESSION_RECORD`) wraps one direction byte, one
//! cluster id, and one serialized [`WireMsg`]. Each append is fsynced,
//! and a torn final frame (the process died mid-write) is tolerated on
//! read exactly like the matrix run log's torn last line — complete
//! prefix returned, mid-file corruption still a named error.
//!
//! [`CoordinatorRun`]: crate::coordinator::CoordinatorRun

use super::frame::{decode_frame, encode_frame};
use super::wire::{self, WireMsg, TAG_SESSION_HEADER, TAG_SESSION_RECORD};
use crate::sim::result::ScenarioMeta;
use crate::util::json::{self, Json, ObjBuilder};
use anyhow::{anyhow, bail, Context, Result};
use std::io::{Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

/// Which way a logged message travelled.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Direction {
    /// Worker → MBS (`Sync`, `Done`).
    Rx,
    /// MBS → workers (`GlobalDelta`; logged once per broadcast).
    Tx,
}

/// Cluster id marking a broadcast record (sent to every cluster).
pub const BROADCAST: u32 = u32::MAX;

/// The session's identity and the scalars replay needs.
#[derive(Clone, Debug, PartialEq)]
pub struct SessionHeader {
    /// Scenario name (also the golden-trace key).
    pub name: String,
    /// Scenario fingerprint (the handshake's refusal key).
    pub fingerprint: u64,
    pub dim: usize,
    pub n_clusters: usize,
    pub workers: usize,
    pub h_period: usize,
    pub iters: usize,
    pub sparse: bool,
}

impl SessionHeader {
    pub fn to_json(&self) -> Json {
        ObjBuilder::new()
            .str("name", self.name.clone())
            .str("fingerprint", format!("{:016x}", self.fingerprint))
            .num("dim", self.dim as f64)
            .num("n_clusters", self.n_clusters as f64)
            .num("workers", self.workers as f64)
            .num("h_period", self.h_period as f64)
            .num("iters", self.iters as f64)
            .bool("sparse", self.sparse)
            .build()
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let field = |k: &str| {
            j.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("session header missing `{k}`"))
        };
        let fp = j
            .get("fingerprint")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("session header missing `fingerprint`"))?;
        Ok(Self {
            name: j
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("session header missing `name`"))?
                .to_string(),
            fingerprint: u64::from_str_radix(fp, 16)
                .with_context(|| format!("parsing fingerprint `{fp}`"))?,
            dim: field("dim")?,
            n_clusters: field("n_clusters")?,
            workers: field("workers")?,
            h_period: field("h_period")?,
            iters: field("iters")?,
            sparse: matches!(j.get("sparse"), Some(Json::Bool(true))),
        })
    }

    /// The scenario identity for result/golden-trace construction.
    pub fn meta(&self) -> ScenarioMeta {
        ScenarioMeta {
            id: 0,
            name: self.name.clone(),
            n_clusters: self.n_clusters,
            workers: self.workers,
            h_period: self.h_period,
            sparse: self.sparse,
        }
    }
}

/// One logged data-plane message.
#[derive(Clone, Debug)]
pub struct SessionRecord {
    pub dir: Direction,
    /// Source cluster for `Rx`, [`BROADCAST`] for `Tx`.
    pub cluster: u32,
    pub msg: WireMsg,
}

/// Appending side of a session log (MBS only).
pub struct SessionLog {
    file: std::fs::File,
    path: PathBuf,
    /// Byte offset of the end of the last fully fsynced frame. A failed
    /// append rolls the file back here, so the write side never leaves a
    /// torn frame behind (readers tolerate one only at the tail of a
    /// crashed session).
    committed: u64,
}

impl SessionLog {
    /// Create (truncate) the log and write its fsynced header frame.
    pub fn create(path: &Path, header: &SessionHeader) -> Result<Self> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating {}", dir.display()))?;
            }
        }
        let file = std::fs::File::create(path)
            .with_context(|| format!("creating session log {}", path.display()))?;
        let mut log = Self {
            file,
            path: path.to_path_buf(),
            committed: 0,
        };
        let text = header
            .to_json()
            .to_string_strict()
            .map_err(|e| anyhow!("session header serialization: {e}"))?;
        log.write_frame(&encode_frame(TAG_SESSION_HEADER, text.as_bytes()))?;
        Ok(log)
    }

    /// Append one message record; fsynced so a crash tears at most the
    /// final frame.
    pub fn append(&mut self, dir: Direction, cluster: u32, msg: &WireMsg) -> Result<()> {
        let (tag, payload) = wire::encode_payload(msg);
        let mut body = Vec::with_capacity(payload.len() + 6);
        body.push(match dir {
            Direction::Rx => 0u8,
            Direction::Tx => 1u8,
        });
        body.extend_from_slice(&cluster.to_le_bytes());
        body.push(tag);
        body.extend_from_slice(&payload);
        self.write_frame(&encode_frame(TAG_SESSION_RECORD, &body))
    }

    fn write_frame(&mut self, bytes: &[u8]) -> Result<()> {
        if let Err(e) = self.file.write_all(bytes).and_then(|_| self.file.sync_data()) {
            // The failed append may have landed a prefix of the frame on
            // disk; truncate back to the last whole record before
            // surfacing the error.
            let rolled = self.rollback();
            return Err(anyhow::Error::new(e)).with_context(|| match rolled {
                Ok(()) => format!(
                    "appending to session log {} (rolled back to last whole frame at byte {})",
                    self.path.display(),
                    self.committed
                ),
                Err(r) => format!(
                    "appending to session log {} (rollback to byte {} also failed: {r})",
                    self.path.display(),
                    self.committed
                ),
            });
        }
        self.committed += bytes.len() as u64;
        Ok(())
    }

    /// Truncate the file back to the last fully committed frame boundary,
    /// discarding partial bytes a failed append may have left behind.
    fn rollback(&mut self) -> std::io::Result<()> {
        self.file.set_len(self.committed)?;
        self.file.seek(SeekFrom::Start(self.committed))?;
        self.file.sync_data()
    }
}

/// Read a session log: header plus the complete prefix of records. A torn
/// final frame is tolerated; corruption earlier in the file is an error.
pub fn read_session(path: &Path) -> Result<(SessionHeader, Vec<SessionRecord>)> {
    let bytes =
        std::fs::read(path).with_context(|| format!("reading session log {}", path.display()))?;
    let mut pos = 0usize;
    let mut frames: Vec<(u8, Vec<u8>)> = Vec::new();
    while pos < bytes.len() {
        match decode_frame(&bytes[pos..])
            .with_context(|| format!("session log {} at byte {pos}", path.display()))?
        {
            Some((tag, payload, consumed)) => {
                frames.push((tag, payload));
                pos += consumed;
            }
            // Incomplete trailing frame: the writer died mid-append.
            None => break,
        }
    }
    let Some((first_tag, header_bytes)) = frames.first() else {
        bail!("session log {} is empty", path.display());
    };
    if *first_tag != TAG_SESSION_HEADER {
        bail!(
            "session log {} does not start with a header frame (tag {first_tag})",
            path.display()
        );
    }
    let text = std::str::from_utf8(header_bytes).context("session header is not UTF-8")?;
    let header = SessionHeader::from_json(
        &json::parse(text).map_err(|e| anyhow!("session header JSON: {e}"))?,
    )?;
    let mut records = Vec::with_capacity(frames.len() - 1);
    for (i, (tag, payload)) in frames.iter().enumerate().skip(1) {
        if *tag != TAG_SESSION_RECORD {
            bail!("session log frame {i} has unexpected tag {tag}");
        }
        if payload.len() < 6 {
            bail!("session log record {i} truncated ({} bytes)", payload.len());
        }
        let dir = match payload[0] {
            0 => Direction::Rx,
            1 => Direction::Tx,
            other => bail!("session log record {i} has unknown direction {other}"),
        };
        let cluster = u32::from_le_bytes(payload[1..5].try_into().unwrap());
        let msg = wire::decode_payload(payload[5], &payload[6..])
            .with_context(|| format!("session log record {i}"))?;
        records.push(SessionRecord { dir, cluster, msg });
    }
    Ok((header, records))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::SparseVec;

    fn header() -> SessionHeader {
        SessionHeader {
            name: "net-test".into(),
            fingerprint: 0x0123_4567_89ab_cdef,
            dim: 16,
            n_clusters: 2,
            workers: 6,
            h_period: 4,
            iters: 12,
            sparse: true,
        }
    }

    fn sync(cluster: usize) -> WireMsg {
        WireMsg::Sync {
            cluster,
            mean_loss: 0.5,
            delta: SparseVec {
                dim: 16,
                indices: vec![0, 7, 15],
                values: vec![1.0, 2.0, 3.0],
            },
            events: Vec::new(),
        }
    }

    #[test]
    fn header_json_roundtrip() {
        let h = header();
        assert_eq!(SessionHeader::from_json(&h.to_json()).unwrap(), h);
        assert_eq!(h.meta().name, "net-test");
        assert_eq!(h.meta().workers, 6);
    }

    #[test]
    fn log_roundtrip_and_torn_tail() {
        let dir = std::env::temp_dir().join(format!("hfl-session-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("roundtrip.hlog");
        {
            let mut log = SessionLog::create(&path, &header()).unwrap();
            log.append(Direction::Rx, 0, &sync(0)).unwrap();
            log.append(Direction::Rx, 1, &sync(1)).unwrap();
            log.append(
                Direction::Tx,
                BROADCAST,
                &WireMsg::GlobalDelta {
                    sync_index: 0,
                    delta: SparseVec::empty(16),
                },
            )
            .unwrap();
        }
        let (h, recs) = read_session(&path).unwrap();
        assert_eq!(h, header());
        assert_eq!(recs.len(), 3);
        assert_eq!(recs[0].dir, Direction::Rx);
        assert_eq!(recs[0].msg, sync(0));
        assert_eq!(recs[2].cluster, BROADCAST);

        // Tear the final frame: the complete prefix still reads.
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 5]).unwrap();
        let (_, torn) = read_session(&path).unwrap();
        assert_eq!(torn.len(), 2);

        // Corrupt a mid-file byte: named error, not silence.
        let mut corrupt = bytes.clone();
        corrupt[70] ^= 0x01;
        std::fs::write(&path, &corrupt).unwrap();
        assert!(read_session(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn failed_append_rolls_back_to_the_last_whole_frame() {
        let dir = std::env::temp_dir().join(format!("hfl-session-roll-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rollback.hlog");
        let mut log = SessionLog::create(&path, &header()).unwrap();
        log.append(Direction::Rx, 0, &sync(0)).unwrap();
        let committed = log.committed;
        assert_eq!(std::fs::metadata(&path).unwrap().len(), committed);

        // Simulate a torn append: partial frame bytes reach the disk but
        // the write fails — exercise the same rollback write_frame takes.
        log.file.write_all(b"partial frame wreckage").unwrap();
        log.file.sync_data().unwrap();
        assert!(std::fs::metadata(&path).unwrap().len() > committed);
        log.rollback().unwrap();
        assert_eq!(std::fs::metadata(&path).unwrap().len(), committed);

        // The log keeps appending cleanly from the restored boundary.
        log.append(Direction::Rx, 1, &sync(1)).unwrap();
        drop(log);
        let (_, recs) = read_session(&path).unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[1].msg, sync(1));
        std::fs::remove_dir_all(&dir).ok();
    }
}
