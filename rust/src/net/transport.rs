//! The SBS↔MBS transport abstraction: one framed [`WireMsg`] per call.
//!
//! Two implementations share the byte-level codec, so the loopback pair
//! exercises the exact frame/wire encoding the TCP path ships:
//!
//! - [`LoopbackTransport`] — an in-memory channel of framed byte vectors.
//!   `coordinator::run_coordinated` wires every cluster over these, which
//!   is how the in-process engine proves the codec bit-exact on every run.
//! - [`TcpTransport`] — a `TcpStream` with an incremental receive buffer
//!   (`TCP_NODELAY`; frames re-assembled across arbitrary segmentation).

use super::frame;
use super::wire::{self, WireMsg};
use crate::util::rng::Pcg64;
use anyhow::{bail, Context, Result};
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::{Duration, Instant};

/// A bidirectional, blocking, message-oriented link between one worker
/// cell and the MBS.
pub trait Transport: Send {
    /// Frame and send one message.
    fn send(&mut self, msg: &WireMsg) -> Result<()>;
    /// Block until the next complete frame arrives and decode it.
    fn recv(&mut self) -> Result<WireMsg>;
    /// Human-readable peer name for error contexts.
    fn peer(&self) -> String;
}

// ---------------------------------------------------------------------------
// Loopback
// ---------------------------------------------------------------------------

/// In-memory transport endpoint: framed bytes over an `mpsc` channel.
pub struct LoopbackTransport {
    tx: Sender<Vec<u8>>,
    rx: Receiver<Vec<u8>>,
    rxbuf: Vec<u8>,
}

impl LoopbackTransport {
    /// Create a connected pair of endpoints.
    pub fn pair() -> (LoopbackTransport, LoopbackTransport) {
        let (atx, arx) = channel();
        let (btx, brx) = channel();
        (
            LoopbackTransport {
                tx: atx,
                rx: brx,
                rxbuf: Vec::new(),
            },
            LoopbackTransport {
                tx: btx,
                rx: arx,
                rxbuf: Vec::new(),
            },
        )
    }
}

impl Transport for LoopbackTransport {
    fn send(&mut self, msg: &WireMsg) -> Result<()> {
        self.tx
            .send(wire::encode_frame_msg(msg))
            .map_err(|_| anyhow::anyhow!("loopback peer closed while sending {}", msg.kind()))
    }

    fn recv(&mut self) -> Result<WireMsg> {
        loop {
            if let Some((tag, payload, consumed)) =
                frame::decode_frame(&self.rxbuf).context("loopback frame")?
            {
                self.rxbuf.drain(..consumed);
                return wire::decode_payload(tag, &payload);
            }
            let chunk = self
                .rx
                .recv()
                .map_err(|_| anyhow::anyhow!("loopback peer closed while receiving"))?;
            self.rxbuf.extend_from_slice(&chunk);
        }
    }

    fn peer(&self) -> String {
        "loopback".into()
    }
}

// ---------------------------------------------------------------------------
// TCP
// ---------------------------------------------------------------------------

/// TCP transport endpoint with an incremental frame re-assembly buffer.
pub struct TcpTransport {
    stream: TcpStream,
    rxbuf: Vec<u8>,
    peer: String,
    io_timeout: Option<Duration>,
}

/// FNV-1a over an address string — a deterministic per-peer seed for the
/// backoff jitter stream (no wall-clock entropy in the retry schedule).
fn addr_seed(addr: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in addr.as_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Exponential backoff for connect attempt `attempt` (0-based):
/// 50ms·2^attempt capped at 2s, plus deterministic jitter in [0, 25%)
/// drawn from a stream keyed by `(addr, attempt)` so concurrent workers
/// retrying the same MBS don't stampede in lockstep, yet every rerun
/// sleeps the same schedule.
fn backoff_delay(addr: &str, attempt: u32) -> Duration {
    let base_ms = 50u64.saturating_mul(1u64 << attempt.min(5)).min(2_000);
    let jitter_ms = Pcg64::new(addr_seed(addr), attempt as u64).uniform_u64(base_ms / 4 + 1);
    Duration::from_millis(base_ms + jitter_ms)
}

impl TcpTransport {
    /// Wrap an accepted or connected stream (sets `TCP_NODELAY` — sync
    /// messages are latency-bound, not throughput-bound).
    pub fn new(stream: TcpStream) -> Result<Self> {
        let peer = stream
            .peer_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| "<unknown>".into());
        stream
            .set_nodelay(true)
            .with_context(|| format!("setting TCP_NODELAY toward {peer}"))?;
        Ok(Self {
            stream,
            rxbuf: Vec::new(),
            peer,
            io_timeout: None,
        })
    }

    /// Bound every blocking read/write on this stream: a hung peer then
    /// yields a named "io timeout" error instead of wedging the MBS
    /// lockstep loop forever. `None` restores unbounded blocking.
    pub fn set_io_timeout(&mut self, timeout: Option<Duration>) -> Result<()> {
        self.stream
            .set_read_timeout(timeout)
            .with_context(|| format!("setting read timeout toward {}", self.peer))?;
        self.stream
            .set_write_timeout(timeout)
            .with_context(|| format!("setting write timeout toward {}", self.peer))?;
        self.io_timeout = timeout;
        Ok(())
    }

    /// Connect to `addr`, retrying until `total` elapses — workers may
    /// launch before the MBS listener binds (the CI multiprocess job
    /// starts all three processes concurrently). Retries back off
    /// exponentially (50ms·2^k, capped at 2s) with deterministic
    /// per-`(addr, attempt)` jitter — see [`backoff_delay`].
    pub fn connect_retry(addr: &str, total: Duration) -> Result<Self> {
        let deadline = Instant::now() + total;
        let mut attempt = 0u32;
        loop {
            match addr
                .to_socket_addrs()
                .with_context(|| format!("resolving {addr}"))?
                .next()
            {
                None => bail!("{addr} resolved to no address"),
                Some(sock) => match TcpStream::connect(sock) {
                    Ok(s) => return Self::new(s),
                    Err(e) => {
                        if Instant::now() >= deadline {
                            return Err(e).with_context(|| {
                                format!(
                                    "connecting to MBS at {addr} ({} attempts over {total:?})",
                                    attempt + 1
                                )
                            });
                        }
                        std::thread::sleep(backoff_delay(addr, attempt));
                        attempt = attempt.saturating_add(1);
                    }
                },
            }
        }
    }
}

impl Transport for TcpTransport {
    fn send(&mut self, msg: &WireMsg) -> Result<()> {
        let bytes = wire::encode_frame_msg(msg);
        self.stream
            .write_all(&bytes)
            .with_context(|| format!("sending {} to {}", msg.kind(), self.peer))?;
        self.stream
            .flush()
            .with_context(|| format!("flushing toward {}", self.peer))
    }

    fn recv(&mut self) -> Result<WireMsg> {
        let mut chunk = [0u8; 64 * 1024];
        loop {
            if let Some((tag, payload, consumed)) = frame::decode_frame(&self.rxbuf)
                .with_context(|| format!("frame from {}", self.peer))?
            {
                self.rxbuf.drain(..consumed);
                return wire::decode_payload(tag, &payload)
                    .with_context(|| format!("message from {}", self.peer));
            }
            let n = match self.stream.read(&mut chunk) {
                Ok(n) => n,
                // Both kinds occur across platforms for a fired
                // SO_RCVTIMEO; name the hang instead of wedging.
                Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                    bail!(
                        "io timeout: no bytes from {} within {:?} ({} buffered bytes)",
                        self.peer,
                        self.io_timeout.unwrap_or_default(),
                        self.rxbuf.len()
                    );
                }
                Err(e) => {
                    return Err(e).with_context(|| format!("reading from {}", self.peer));
                }
            };
            if n == 0 {
                bail!(
                    "connection closed by {} mid-stream ({} buffered bytes)",
                    self.peer,
                    self.rxbuf.len()
                );
            }
            self.rxbuf.extend_from_slice(&chunk[..n]);
        }
    }

    fn peer(&self) -> String {
        self.peer.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::SparseVec;

    fn msg(sync_index: usize) -> WireMsg {
        WireMsg::GlobalDelta {
            sync_index,
            delta: SparseVec {
                dim: 10,
                indices: vec![1, 4, 9],
                values: vec![0.5, -1.5, 2.0],
            },
        }
    }

    #[test]
    fn loopback_roundtrips_messages_in_order() {
        let (mut a, mut b) = LoopbackTransport::pair();
        a.send(&msg(0)).unwrap();
        a.send(&msg(1)).unwrap();
        assert_eq!(b.recv().unwrap(), msg(0));
        assert_eq!(b.recv().unwrap(), msg(1));
        b.send(&msg(2)).unwrap();
        assert_eq!(a.recv().unwrap(), msg(2));
    }

    #[test]
    fn loopback_closed_peer_is_error() {
        let (mut a, b) = LoopbackTransport::pair();
        drop(b);
        assert!(a.send(&msg(0)).is_err());
        assert!(a.recv().is_err());
    }

    #[test]
    fn tcp_pair_roundtrips_across_segmentation() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut t = TcpTransport::new(stream).unwrap();
            for i in 0..20 {
                assert_eq!(t.recv().unwrap(), msg(i));
            }
            t.send(&msg(99)).unwrap();
        });
        let mut t =
            TcpTransport::connect_retry(&addr.to_string(), Duration::from_secs(10)).unwrap();
        for i in 0..20 {
            t.send(&msg(i)).unwrap();
        }
        assert_eq!(t.recv().unwrap(), msg(99));
        server.join().unwrap();
    }

    #[test]
    fn tcp_hung_peer_yields_named_io_timeout() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        // The "peer" accepts but never writes a byte.
        let silent = std::thread::spawn(move || listener.accept().unwrap());
        let mut t =
            TcpTransport::connect_retry(&addr.to_string(), Duration::from_secs(10)).unwrap();
        t.set_io_timeout(Some(Duration::from_millis(50))).unwrap();
        let err = t.recv().unwrap_err().to_string();
        assert!(err.contains("io timeout"), "unexpected error: {err}");
        drop(silent.join().unwrap());
    }

    #[test]
    fn backoff_schedule_is_deterministic_and_bounded() {
        for attempt in 0..12 {
            let a = backoff_delay("127.0.0.1:7070", attempt);
            let b = backoff_delay("127.0.0.1:7070", attempt);
            assert_eq!(a, b, "jitter must be deterministic per (addr, attempt)");
            assert!(a >= Duration::from_millis(50));
            assert!(a <= Duration::from_millis(2_500), "attempt {attempt}: {a:?}");
        }
        // Exponential: later attempts never shrink below the first.
        assert!(backoff_delay("x:1", 4) > backoff_delay("x:1", 0));
        // Distinct addresses draw distinct jitter streams (compare the
        // whole schedule; any single attempt could collide).
        let schedule = |addr: &str| (0..8).map(|k| backoff_delay(addr, k)).collect::<Vec<_>>();
        assert_ne!(schedule("x:1"), schedule("y:2"));
    }
}
