//! Deterministic chaos/fault injection for the coordinator transport,
//! plus the MBS-side fault policy vocabulary.
//!
//! [`ChaosTransport`] wraps any [`Transport`] and perturbs it from a
//! *seeded fault plan*: every fault decision is drawn from a [`Pcg64`]
//! stream keyed by `(chaos seed, endpoint stream tag, message index)` —
//! never from wall-clock time — so two runs with the same chaos seed
//! inject the exact same faults at the exact same protocol points, at
//! any thread count. Fault handling thereby *joins* the determinism
//! contract instead of escaping it: a chaos run is reproducible and
//! golden-diffable like any other.
//!
//! ## Fault model
//!
//! Two fault classes, deliberately different in mechanism:
//!
//! - **Healed byte faults** (`drop`, `duplicate`, `truncate`, `corrupt`,
//!   `delay`): the `WireMsg` protocol is lockstep with no retransmit
//!   lane, so a damaged frame is detected by the checksummed frame codec
//!   and recovered by retransmission *below* the message boundary. The
//!   wrapper models that reliability sublayer: it draws the fault,
//!   counts it (and sleeps for planned delays — wall-clock only, never
//!   arithmetic), then delivers the intact frame exactly once, i.e. the
//!   detect-and-retransmit exchange collapsed to its deterministic
//!   outcome. What the run observes — fault counters, delays, retry
//!   totals — is real; the delivered message stream is byte-identical,
//!   which is precisely the invariant a checksummed transport must hold.
//! - **Kills** (`kill_cluster`/`kill_after`): the one fault the message
//!   layer *can* see. Once the plan's operation index is reached the
//!   endpoint is dead — every later `send`/`recv` fails with a named
//!   error — exercising the real recovery machinery: the MBS rejoin
//!   lane, [`FaultPolicy`] degradation, and worker rejoin
//!   (`WireMsg::Rejoin`).
//!
//! With chaos disabled (the default) [`ChaosTransport::wrap`] returns
//! the inner transport untouched, so the zero-fault path is the
//! byte-identical status quo every existing golden fixture pins.

use super::transport::Transport;
use super::wire::WireMsg;
use crate::util::rng::Pcg64;
use anyhow::{bail, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// The `[chaos]` config section / `--chaos-*` CLI flags: a seeded fault
/// plan. All probabilities are per-message; everything defaults to off.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ChaosConfig {
    /// Master switch; `false` makes [`ChaosTransport::wrap`] a no-op.
    pub enabled: bool,
    /// Seed of every fault stream (`--chaos-seed`).
    pub seed: u64,
    /// P(frame dropped, then retransmitted) per message.
    pub drop_p: f64,
    /// P(frame delayed by [`ChaosConfig::delay_ms`]) per message.
    pub delay_p: f64,
    /// Injected delay per delayed frame (wall-clock only).
    pub delay_ms: u64,
    /// P(frame duplicated, duplicate discarded) per message.
    pub dup_p: f64,
    /// P(frame truncated, then retransmitted) per message.
    pub truncate_p: f64,
    /// P(frame corrupted, then retransmitted) per message.
    pub corrupt_p: f64,
    /// Kill the connection of this cluster's endpoint…
    pub kill_cluster: Option<usize>,
    /// …once its send+recv operation count reaches this index.
    pub kill_after: u64,
}

impl ChaosConfig {
    pub fn validate(&self) -> Result<()> {
        for (name, p) in [
            ("drop_p", self.drop_p),
            ("delay_p", self.delay_p),
            ("dup_p", self.dup_p),
            ("truncate_p", self.truncate_p),
            ("corrupt_p", self.corrupt_p),
        ] {
            if !(0.0..=1.0).contains(&p) {
                bail!("chaos {name} {p} outside [0, 1]");
            }
        }
        if self.delay_ms > 60_000 {
            bail!("chaos delay_ms {} outside [0, 60000]", self.delay_ms);
        }
        Ok(())
    }

    /// True when enabled with at least one fault that can fire.
    pub fn any_faults(&self) -> bool {
        self.enabled
            && (self.drop_p > 0.0
                || self.delay_p > 0.0
                || self.dup_p > 0.0
                || self.truncate_p > 0.0
                || self.corrupt_p > 0.0
                || self.kill_cluster.is_some())
    }
}

/// How the MBS reacts when a cluster stops answering (its link errors or
/// its recv deadline fires and no rejoin arrives in time).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultPolicy {
    /// Any cluster fault is fatal (the pre-chaos behaviour; default).
    WaitAll,
    /// Declare the cluster dead, reweight the consensus over survivors,
    /// keep going while at least one cluster remains.
    DeadlineSkip,
    /// Like `DeadlineSkip`, but abort once fewer than `k` clusters
    /// survive.
    Quorum(usize),
}

impl FaultPolicy {
    /// Parse `--fault-policy wait-all|deadline-skip|quorum` (+ `k`).
    pub fn parse(s: &str, quorum: usize) -> Result<Self> {
        match s {
            "wait-all" => Ok(FaultPolicy::WaitAll),
            "deadline-skip" => Ok(FaultPolicy::DeadlineSkip),
            "quorum" => {
                if quorum == 0 {
                    bail!("--fault-policy quorum needs --fault-quorum K >= 1");
                }
                Ok(FaultPolicy::Quorum(quorum))
            }
            other => bail!("unknown fault policy `{other}` (wait-all|deadline-skip|quorum)"),
        }
    }

    /// Minimum surviving clusters this policy tolerates.
    pub fn min_alive(&self) -> usize {
        match self {
            FaultPolicy::WaitAll => usize::MAX,
            FaultPolicy::DeadlineSkip => 1,
            FaultPolicy::Quorum(k) => *k,
        }
    }

    /// Refuse a policy the topology can never satisfy: `quorum(k)` with
    /// `k > n_clusters` would abort at round 0 even with every cluster
    /// healthy (and `k == 0` is `deadline-skip` spelled confusingly).
    /// Named error at startup instead of a baffling mid-run abort.
    pub fn validate(&self, n_clusters: usize) -> Result<()> {
        if let FaultPolicy::Quorum(k) = *self {
            if k == 0 {
                bail!("fault policy quorum(0) is vacuous — use deadline-skip");
            }
            if k > n_clusters {
                bail!(
                    "fault policy quorum({k}) can never be met: only {n_clusters} \
                     cluster(s) configured"
                );
            }
        }
        Ok(())
    }
}

/// Shared fault counters: incremented by every [`ChaosTransport`] built
/// from the same `Arc`, read by the `/metrics` endpoint and the
/// end-of-run summary. Counters are observability only — they never feed
/// back into the run.
#[derive(Debug, Default)]
pub struct FaultCounters {
    pub frames_dropped: AtomicU64,
    pub frames_delayed: AtomicU64,
    pub frames_duplicated: AtomicU64,
    pub frames_truncated: AtomicU64,
    pub frames_corrupted: AtomicU64,
    /// Retransmissions performed by the healed-fault sublayer.
    pub frames_retried: AtomicU64,
    pub kills: AtomicU64,
}

impl FaultCounters {
    pub fn total_faults(&self) -> u64 {
        self.frames_dropped.load(Ordering::Relaxed)
            + self.frames_delayed.load(Ordering::Relaxed)
            + self.frames_duplicated.load(Ordering::Relaxed)
            + self.frames_truncated.load(Ordering::Relaxed)
            + self.frames_corrupted.load(Ordering::Relaxed)
            + self.kills.load(Ordering::Relaxed)
    }
}

/// Fault-injecting wrapper around any [`Transport`]. Build with
/// [`ChaosTransport::wrap`]; every endpoint gets independent send/recv
/// fault streams derived from `(seed, stream_tag)`.
pub struct ChaosTransport {
    inner: Box<dyn Transport>,
    cfg: ChaosConfig,
    /// This endpoint serves this cluster's link (kill targeting).
    cluster: usize,
    tx_rng: Pcg64,
    rx_rng: Pcg64,
    counters: Arc<FaultCounters>,
    /// send+recv operations completed (the kill clock).
    ops: u64,
    /// Once set, the connection is dead and every call fails.
    killed: Option<String>,
}

impl ChaosTransport {
    /// Wrap `inner` under the fault plan `cfg`. `cluster` identifies the
    /// link (kill targeting); `stream_tag` decorrelates endpoints that
    /// share a seed (use distinct tags for the two sides of one link).
    /// Disabled chaos returns `inner` unchanged — a byte-identical no-op.
    pub fn wrap(
        inner: Box<dyn Transport>,
        cfg: &ChaosConfig,
        cluster: usize,
        stream_tag: u64,
        counters: Arc<FaultCounters>,
    ) -> Box<dyn Transport> {
        if !cfg.enabled {
            return inner;
        }
        Box::new(ChaosTransport {
            inner,
            cfg: cfg.clone(),
            cluster,
            tx_rng: Pcg64::new(cfg.seed, stream_tag.wrapping_mul(2)),
            rx_rng: Pcg64::new(cfg.seed, stream_tag.wrapping_mul(2).wrapping_add(1)),
            counters,
            ops: 0,
            killed: None,
        })
    }

    /// Draw this message's faults from `rng` in a fixed order so the
    /// stream position depends only on the message index, never on which
    /// faults fired. Returns the planned delay.
    fn draw_faults(cfg: &ChaosConfig, rng: &mut Pcg64, counters: &FaultCounters) -> Duration {
        let (drop, delay, dup, trunc, corrupt) = (
            rng.uniform(),
            rng.uniform(),
            rng.uniform(),
            rng.uniform(),
            rng.uniform(),
        );
        let mut retries = 0u64;
        if drop < cfg.drop_p {
            counters.frames_dropped.fetch_add(1, Ordering::Relaxed);
            retries += 1;
        }
        if dup < cfg.dup_p {
            counters.frames_duplicated.fetch_add(1, Ordering::Relaxed);
        }
        if trunc < cfg.truncate_p {
            counters.frames_truncated.fetch_add(1, Ordering::Relaxed);
            retries += 1;
        }
        if corrupt < cfg.corrupt_p {
            counters.frames_corrupted.fetch_add(1, Ordering::Relaxed);
            retries += 1;
        }
        if retries > 0 {
            counters.frames_retried.fetch_add(retries, Ordering::Relaxed);
        }
        if delay < cfg.delay_p {
            counters.frames_delayed.fetch_add(1, Ordering::Relaxed);
            Duration::from_millis(cfg.delay_ms)
        } else {
            Duration::ZERO
        }
    }

    /// Advance the kill clock; returns the death notice when the plan
    /// kills this endpoint at this operation.
    fn tick_kill(&mut self) -> Option<String> {
        if let Some(reason) = &self.killed {
            return Some(reason.clone());
        }
        if self.cfg.kill_cluster == Some(self.cluster) && self.ops >= self.cfg.kill_after {
            let reason = format!(
                "chaos fault plan (seed {}) killed the cluster-{} connection to {} at operation {}",
                self.cfg.seed,
                self.cluster,
                self.inner.peer(),
                self.ops
            );
            self.counters.kills.fetch_add(1, Ordering::Relaxed);
            self.killed = Some(reason.clone());
            return Some(reason);
        }
        self.ops += 1;
        None
    }
}

impl Transport for ChaosTransport {
    fn send(&mut self, msg: &WireMsg) -> Result<()> {
        if let Some(reason) = self.tick_kill() {
            bail!("{reason}");
        }
        let delay = Self::draw_faults(&self.cfg, &mut self.tx_rng, &self.counters);
        if !delay.is_zero() {
            std::thread::sleep(delay);
        }
        self.inner.send(msg)
    }

    fn recv(&mut self) -> Result<WireMsg> {
        if let Some(reason) = self.tick_kill() {
            bail!("{reason}");
        }
        let delay = Self::draw_faults(&self.cfg, &mut self.rx_rng, &self.counters);
        let msg = self.inner.recv()?;
        if !delay.is_zero() {
            std::thread::sleep(delay);
        }
        Ok(msg)
    }

    fn peer(&self) -> String {
        format!("chaos({})", self.inner.peer())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::transport::LoopbackTransport;

    fn msg(i: usize) -> WireMsg {
        WireMsg::GlobalDelta {
            sync_index: i,
            delta: crate::sparse::SparseVec {
                dim: 8,
                indices: vec![0, 3],
                values: vec![1.0, -2.0],
            },
        }
    }

    fn plan(seed: u64) -> ChaosConfig {
        ChaosConfig {
            enabled: true,
            seed,
            drop_p: 0.5,
            dup_p: 0.25,
            truncate_p: 0.25,
            corrupt_p: 0.25,
            ..ChaosConfig::default()
        }
    }

    #[test]
    fn fault_policy_validate_refuses_unreachable_quorum() {
        assert!(FaultPolicy::WaitAll.validate(1).is_ok());
        assert!(FaultPolicy::DeadlineSkip.validate(1).is_ok());
        assert!(FaultPolicy::Quorum(2).validate(2).is_ok());
        assert!(FaultPolicy::Quorum(3).validate(2).is_err());
        assert!(FaultPolicy::Quorum(0).validate(2).is_err());
        let err = FaultPolicy::Quorum(5).validate(2).unwrap_err().to_string();
        assert!(err.contains("quorum(5)") && err.contains("2"), "{err}");
    }

    #[test]
    fn disabled_wrap_is_identity() {
        let (a, _b) = LoopbackTransport::pair();
        let counters = Arc::new(FaultCounters::default());
        let t = ChaosTransport::wrap(
            Box::new(a),
            &ChaosConfig::default(),
            0,
            0,
            Arc::clone(&counters),
        );
        // The inner transport passes through untouched (loopback peer
        // name, no chaos prefix).
        assert_eq!(t.peer(), "loopback");
        assert_eq!(counters.total_faults(), 0);
    }

    #[test]
    fn healed_faults_never_change_the_message_stream() {
        let (a, mut b) = LoopbackTransport::pair();
        let counters = Arc::new(FaultCounters::default());
        let mut t = ChaosTransport::wrap(Box::new(a), &plan(11), 0, 7, Arc::clone(&counters));
        for i in 0..50 {
            t.send(&msg(i)).unwrap();
        }
        for i in 0..50 {
            assert_eq!(b.recv().unwrap(), msg(i), "stream perturbed at {i}");
        }
        assert!(counters.total_faults() > 0, "plan with p=0.5 never fired");
        assert!(counters.frames_retried.load(Ordering::Relaxed) > 0);
    }

    #[test]
    fn same_seed_draws_identical_fault_schedules() {
        let run = |seed: u64| {
            let (a, _b) = LoopbackTransport::pair();
            let counters = Arc::new(FaultCounters::default());
            let mut t = ChaosTransport::wrap(Box::new(a), &plan(seed), 0, 3, Arc::clone(&counters));
            for i in 0..64 {
                t.send(&msg(i)).unwrap();
            }
            (
                counters.frames_dropped.load(Ordering::Relaxed),
                counters.frames_duplicated.load(Ordering::Relaxed),
                counters.frames_truncated.load(Ordering::Relaxed),
                counters.frames_corrupted.load(Ordering::Relaxed),
            )
        };
        assert_eq!(run(42), run(42), "same seed must replay the same plan");
        assert_ne!(run(42), run(43), "distinct seeds should diverge (p=0.5 over 64 draws)");
    }

    #[test]
    fn kill_fires_at_the_planned_operation_and_sticks() {
        let (a, _b) = LoopbackTransport::pair();
        let cfg = ChaosConfig {
            enabled: true,
            seed: 1,
            kill_cluster: Some(2),
            kill_after: 3,
            ..ChaosConfig::default()
        };
        let counters = Arc::new(FaultCounters::default());
        let mut t = ChaosTransport::wrap(Box::new(a), &cfg, 2, 0, Arc::clone(&counters));
        for i in 0..3 {
            t.send(&msg(i)).unwrap();
        }
        let err = t.send(&msg(3)).unwrap_err().to_string();
        assert!(err.contains("chaos fault plan"), "{err}");
        assert!(err.contains("operation 3"), "{err}");
        // Dead is dead: recv fails too, and the kill counts once.
        assert!(t.recv().is_err());
        assert_eq!(counters.kills.load(Ordering::Relaxed), 1);

        // A different cluster under the same plan is never killed.
        let (a2, _b2) = LoopbackTransport::pair();
        let mut t2 = ChaosTransport::wrap(Box::new(a2), &cfg, 0, 0, counters);
        for i in 0..10 {
            t2.send(&msg(i)).unwrap();
        }
    }

    #[test]
    fn config_validation_rejects_bad_probabilities() {
        let mut c = ChaosConfig::default();
        c.validate().unwrap();
        c.drop_p = 1.5;
        assert!(c.validate().is_err());
        c.drop_p = 0.0;
        c.delay_ms = 120_000;
        assert!(c.validate().is_err());
        assert!(!ChaosConfig::default().any_faults());
        assert!(plan(0).any_faults());
    }

    #[test]
    fn fault_policy_parse_and_min_alive() {
        assert_eq!(FaultPolicy::parse("wait-all", 0).unwrap(), FaultPolicy::WaitAll);
        assert_eq!(
            FaultPolicy::parse("deadline-skip", 0).unwrap(),
            FaultPolicy::DeadlineSkip
        );
        assert_eq!(FaultPolicy::parse("quorum", 2).unwrap(), FaultPolicy::Quorum(2));
        assert!(FaultPolicy::parse("quorum", 0).is_err());
        assert!(FaultPolicy::parse("sometimes", 0).is_err());
        assert_eq!(FaultPolicy::DeadlineSkip.min_alive(), 1);
        assert_eq!(FaultPolicy::Quorum(3).min_alive(), 3);
    }
}
