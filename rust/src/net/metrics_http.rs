//! Live metrics for a serving MBS: a shared counter block updated by
//! `run_mbs` and a hand-rolled HTTP/1.1 endpoint (`GET /metrics`) that
//! serves it as JSON. No framework, no new dependencies — one listener
//! thread, one short-lived connection per scrape.
//!
//! The endpoint is observability only: it reads the same
//! [`MetricEvent`] stream that builds the golden-traced `MetricsLog`,
//! but nothing here feeds back into the run (wall-clock straggler
//! timing included), so serving metrics cannot perturb bit-exactness.

use super::chaos::FaultCounters;
use crate::coordinator::{LinkKind, MetricEvent};
use crate::util::json::{Json, ObjBuilder};
use anyhow::{Context, Result};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

#[derive(Debug, Default)]
struct LiveStats {
    n_clusters: usize,
    sync_rounds: u64,
    clusters_done: usize,
    n_events: u64,
    mu_ul_bits: f64,
    sbs_dl_bits: f64,
    sbs_ul_bits: f64,
    mbs_dl_bits: f64,
    mu_msgs: u64,
    /// Mean training loss reported at the latest sync round (NaN before
    /// the first).
    last_loss: f64,
    straggler_waits: u64,
    reconnects: u64,
    clusters_skipped: u64,
    finished: bool,
}

/// Shared live view of a running session (MBS side).
pub struct LiveMetrics {
    inner: Mutex<LiveStats>,
    /// Chaos-layer counters, when a fault plan is active. Scrapes read
    /// them live; absent counters scrape as zeros so the `/metrics`
    /// schema is identical with chaos on or off.
    faults: Mutex<Option<Arc<FaultCounters>>>,
}

impl LiveMetrics {
    pub fn new(n_clusters: usize) -> Self {
        Self {
            inner: Mutex::new(LiveStats {
                n_clusters,
                last_loss: f64::NAN,
                ..LiveStats::default()
            }),
            faults: Mutex::new(None),
        }
    }

    /// Expose a chaos layer's [`FaultCounters`] through `/metrics`.
    pub fn attach_fault_counters(&self, counters: Arc<FaultCounters>) {
        *self.faults.lock().unwrap() = Some(counters);
    }

    /// Fold a batch of per-link events (piggybacked on `Sync`/`Done`, or
    /// the MBS's own broadcast event).
    pub fn note_events(&self, events: &[MetricEvent]) {
        let mut s = self.inner.lock().unwrap();
        for e in events {
            s.n_events += 1;
            match e.link {
                LinkKind::MuUl => {
                    s.mu_ul_bits += e.bits;
                    s.mu_msgs += 1;
                }
                LinkKind::SbsDl => s.sbs_dl_bits += e.bits,
                LinkKind::SbsUl => s.sbs_ul_bits += e.bits,
                LinkKind::MbsDl => s.mbs_dl_bits += e.bits,
            }
        }
    }

    /// A sync round completed with this cross-cluster mean training loss.
    pub fn note_sync_round(&self, mean_loss: f64) {
        let mut s = self.inner.lock().unwrap();
        s.sync_rounds += 1;
        s.last_loss = mean_loss;
    }

    /// The MBS waited noticeably long on one cluster's message.
    pub fn note_straggler(&self) {
        self.inner.lock().unwrap().straggler_waits += 1;
    }

    /// One cluster reported `Done`.
    pub fn note_done(&self) {
        self.inner.lock().unwrap().clusters_done += 1;
    }

    /// A dead worker connection was replaced by a rejoin.
    pub fn note_reconnect(&self) {
        self.inner.lock().unwrap().reconnects += 1;
    }

    /// The fault policy declared one cluster dead and continued without it.
    pub fn note_cluster_skipped(&self) {
        self.inner.lock().unwrap().clusters_skipped += 1;
    }

    /// The run completed.
    pub fn finish(&self) {
        self.inner.lock().unwrap().finished = true;
    }

    /// Current snapshot as the `/metrics` JSON document.
    pub fn to_json(&self) -> Json {
        // Snapshot the chaos counters first (separate lock, never held
        // together with `inner`); zeros when no fault plan is attached.
        let f = self.faults.lock().unwrap().clone();
        let load = |pick: fn(&FaultCounters) -> u64| {
            f.as_ref().map_or(0, |c| pick(c)) as f64
        };
        let s = self.inner.lock().unwrap();
        let b = ObjBuilder::new()
            .num("n_clusters", s.n_clusters as f64)
            .num("sync_rounds", s.sync_rounds as f64)
            .num("clusters_done", s.clusters_done as f64)
            .num("n_events", s.n_events as f64)
            .num("mu_ul_bits", s.mu_ul_bits)
            .num("sbs_dl_bits", s.sbs_dl_bits)
            .num("sbs_ul_bits", s.sbs_ul_bits)
            .num("mbs_dl_bits", s.mbs_dl_bits)
            .num("mu_msgs", s.mu_msgs as f64)
            .num("straggler_waits", s.straggler_waits as f64)
            .num("frames_dropped", load(|c| c.frames_dropped.load(Ordering::Relaxed)))
            .num("frames_delayed", load(|c| c.frames_delayed.load(Ordering::Relaxed)))
            .num("frames_duplicated", load(|c| c.frames_duplicated.load(Ordering::Relaxed)))
            .num("frames_truncated", load(|c| c.frames_truncated.load(Ordering::Relaxed)))
            .num("frames_corrupted", load(|c| c.frames_corrupted.load(Ordering::Relaxed)))
            .num("frames_retried", load(|c| c.frames_retried.load(Ordering::Relaxed)))
            .num("kills", load(|c| c.kills.load(Ordering::Relaxed)))
            .num("reconnects", s.reconnects as f64)
            .num("clusters_skipped", s.clusters_skipped as f64)
            .bool("finished", s.finished);
        let b = if s.last_loss.is_finite() {
            b.num("last_loss", s.last_loss)
        } else {
            b.val("last_loss", Json::Null)
        };
        b.build()
    }
}

/// The `/metrics` HTTP listener. Dropping it stops the thread.
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` (port 0 picks a free port) and serve `live` until drop.
    pub fn spawn(addr: &str, live: Arc<LiveMetrics>) -> Result<Self> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding metrics endpoint {addr}"))?;
        let local = listener.local_addr().context("metrics local_addr")?;
        let stop = Arc::new(AtomicBool::new(false));
        let thread_stop = stop.clone();
        let join = std::thread::Builder::new()
            .name("hfl-metrics-http".into())
            .spawn(move || serve_loop(listener, live, thread_stop))
            .context("spawning metrics thread")?;
        Ok(Self {
            addr: local,
            stop,
            join: Some(join),
        })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Unblock the accept loop with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn serve_loop(listener: TcpListener, live: Arc<LiveMetrics>, stop: Arc<AtomicBool>) {
    for conn in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        // A failed scrape never disturbs the run — drop and keep serving.
        if let Ok(mut stream) = conn {
            let _ = handle(&mut stream, &live);
        }
    }
}

fn handle(stream: &mut TcpStream, live: &LiveMetrics) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(2)))?;
    let mut req = Vec::new();
    let mut chunk = [0u8; 1024];
    // Read until the end of the request head (we ignore any body). A
    // client that stalls or resets mid-head still gets an answer: fall
    // through with whatever arrived and reject it as malformed, rather
    // than dropping the socket on the read error.
    while !req.windows(4).any(|w| w == b"\r\n\r\n") && req.len() < 16 * 1024 {
        match stream.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => req.extend_from_slice(&chunk[..n]),
            Err(_) => break,
        }
    }
    let complete = req.windows(4).any(|w| w == b"\r\n\r\n");
    let head = String::from_utf8_lossy(&req);
    let mut parts = head.lines().next().unwrap_or("").split_whitespace();
    let (method, path, version) = (
        parts.next().unwrap_or(""),
        parts.next().unwrap_or(""),
        parts.next().unwrap_or(""),
    );
    let malformed = !complete || method.is_empty() || path.is_empty() || !version.starts_with("HTTP/");
    let (status, body) = if malformed {
        ("400 Bad Request", "{\"error\":\"malformed request\"}".to_string())
    } else if method != "GET" {
        ("405 Method Not Allowed", "{\"error\":\"GET only\"}".to_string())
    } else if path == "/metrics" {
        ("200 OK", live.to_json().to_string_compact())
    } else {
        ("404 Not Found", "{\"error\":\"try /metrics\"}".to_string())
    };
    let response = format!(
        "HTTP/1.1 {status}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(response.as_bytes())?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scrape(addr: SocketAddr, request: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(request.as_bytes()).unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn serves_metrics_json_and_404() {
        let live = Arc::new(LiveMetrics::new(2));
        live.note_events(&[MetricEvent {
            iter: 0,
            cluster: 0,
            link: LinkKind::MuUl,
            bits: 128.0,
            loss: 0.5,
        }]);
        live.note_sync_round(0.25);
        live.note_done();
        let server = MetricsServer::spawn("127.0.0.1:0", live.clone()).unwrap();
        let addr = server.local_addr();

        let ok = scrape(addr, "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(ok.starts_with("HTTP/1.1 200"), "{ok}");
        let body = ok.split("\r\n\r\n").nth(1).unwrap();
        let j = crate::util::json::parse(body).unwrap();
        assert_eq!(j.get("n_clusters").and_then(Json::as_usize), Some(2));
        assert_eq!(j.get("mu_msgs").and_then(Json::as_usize), Some(1));
        assert_eq!(j.get("clusters_done").and_then(Json::as_usize), Some(1));
        assert_eq!(j.get("last_loss").and_then(Json::as_f64), Some(0.25));
        assert_eq!(j.get("mu_ul_bits").and_then(Json::as_f64), Some(128.0));

        let missing = scrape(addr, "GET /nope HTTP/1.1\r\n\r\n");
        assert!(missing.starts_with("HTTP/1.1 404"), "{missing}");
        let wrong = scrape(addr, "POST /metrics HTTP/1.1\r\n\r\n");
        assert!(wrong.starts_with("HTTP/1.1 405"), "{wrong}");
        drop(server); // joins the listener thread
    }

    #[test]
    fn malformed_and_partial_requests_get_400_not_a_dropped_socket() {
        let live = Arc::new(LiveMetrics::new(1));
        let server = MetricsServer::spawn("127.0.0.1:0", live).unwrap();
        let addr = server.local_addr();

        // Garbage bytes with a terminator: unparsable request line.
        let garbage = scrape(addr, "\u{1}\u{2}\r\n\r\n");
        assert!(garbage.starts_with("HTTP/1.1 400"), "{garbage}");

        // Missing HTTP version token.
        let no_version = scrape(addr, "GET /metrics\r\n\r\n");
        assert!(no_version.starts_with("HTTP/1.1 400"), "{no_version}");

        // Partial head: the client hangs up before "\r\n\r\n".
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(b"GET /metrics HTTP/1.1\r\n").unwrap();
        s.shutdown(std::net::Shutdown::Write).unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        assert!(out.starts_with("HTTP/1.1 400"), "{out}");
        drop(server);
    }

    #[test]
    fn fault_counters_scrape_as_zeros_then_live_values() {
        let live = Arc::new(LiveMetrics::new(2));
        // Without an attached chaos layer every fault key is present at 0.
        let j = live.to_json();
        for key in [
            "frames_dropped",
            "frames_corrupted",
            "frames_retried",
            "kills",
            "reconnects",
            "clusters_skipped",
        ] {
            assert_eq!(j.get(key).and_then(Json::as_usize), Some(0), "{key}");
        }

        let counters = Arc::new(FaultCounters::default());
        counters.frames_dropped.store(3, Ordering::Relaxed);
        counters.frames_corrupted.store(1, Ordering::Relaxed);
        live.attach_fault_counters(counters.clone());
        live.note_reconnect();
        live.note_cluster_skipped();
        let j = live.to_json();
        assert_eq!(j.get("frames_dropped").and_then(Json::as_usize), Some(3));
        assert_eq!(j.get("frames_corrupted").and_then(Json::as_usize), Some(1));
        assert_eq!(j.get("reconnects").and_then(Json::as_usize), Some(1));
        assert_eq!(j.get("clusters_skipped").and_then(Json::as_usize), Some(1));
        // The scrape reads the shared counters live, not a copy.
        counters.frames_dropped.store(7, Ordering::Relaxed);
        assert_eq!(
            live.to_json().get("frames_dropped").and_then(Json::as_usize),
            Some(7)
        );
    }

    #[test]
    fn last_loss_is_null_before_first_sync() {
        let live = LiveMetrics::new(1);
        let j = live.to_json();
        assert!(matches!(j.get("last_loss"), Some(Json::Null)));
        live.finish();
        assert!(matches!(live.to_json().get("finished"), Some(Json::Bool(true))));
    }
}
