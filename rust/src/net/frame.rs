//! Length-prefixed message framing for the coordinator transport.
//!
//! Every message on the wire (and every record in a session log) is one
//! frame:
//!
//! ```text
//! ┌──────────┬─────────┬───────┬────────────┬───────────┬──────────────┐
//! │ magic    │ version │ tag   │ len        │ payload   │ checksum     │
//! │ "HFLN"   │ u8 = 1  │ u8    │ u32 LE     │ len bytes │ u64 LE       │
//! └──────────┴─────────┴───────┴────────────┴───────────┴──────────────┘
//! ```
//!
//! The checksum is FNV-1a over `version ‖ tag ‖ len ‖ payload` (the same
//! hash the golden traces and snapshots use), so a flipped bit anywhere
//! after the magic is a named error. [`decode_frame`] is incremental:
//! `Ok(None)` means "not enough bytes yet" — a TCP reader keeps appending,
//! and a torn tail in a session log is tolerated exactly like the matrix
//! run log's final line.

use crate::sim::result::Fnv1a;
use anyhow::{bail, Result};

/// Leading magic of every frame.
pub const MAGIC: [u8; 4] = *b"HFLN";
/// Wire-format version; bump on any layout change.
pub const VERSION: u8 = 1;
/// Fixed bytes before the payload: magic + version + tag + len.
pub const HEADER_LEN: usize = 4 + 1 + 1 + 4;
/// Trailing checksum bytes.
pub const TRAILER_LEN: usize = 8;
/// Refuse frames claiming more than this (256 MiB) — a corrupt length
/// field must not drive an allocation.
pub const MAX_PAYLOAD: usize = 256 << 20;

fn checksum(tag: u8, len: u32, payload: &[u8]) -> u64 {
    let mut h = Fnv1a::new();
    h.absorb([VERSION, tag]);
    h.absorb(len.to_le_bytes());
    h.absorb(payload.iter().copied());
    h.finish()
}

/// Encode one frame.
pub fn encode_frame(tag: u8, payload: &[u8]) -> Vec<u8> {
    assert!(payload.len() <= MAX_PAYLOAD, "payload exceeds MAX_PAYLOAD");
    let len = payload.len() as u32;
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len() + TRAILER_LEN);
    out.extend_from_slice(&MAGIC);
    out.push(VERSION);
    out.push(tag);
    out.extend_from_slice(&len.to_le_bytes());
    out.extend_from_slice(payload);
    out.extend_from_slice(&checksum(tag, len, payload).to_le_bytes());
    out
}

/// Try to decode one frame from the front of `buf`.
///
/// Returns `Ok(Some((tag, payload, consumed)))` on a complete, verified
/// frame; `Ok(None)` when `buf` holds only a prefix (read more / torn
/// tail); `Err` on bad magic, unknown version, an oversized length field,
/// or a checksum mismatch.
pub fn decode_frame(buf: &[u8]) -> Result<Option<(u8, Vec<u8>, usize)>> {
    if buf.len() < HEADER_LEN {
        return Ok(None);
    }
    if buf[..4] != MAGIC {
        bail!(
            "bad frame magic {:02x}{:02x}{:02x}{:02x} (want \"HFLN\")",
            buf[0],
            buf[1],
            buf[2],
            buf[3]
        );
    }
    if buf[4] != VERSION {
        bail!("unsupported frame version {} (want {VERSION})", buf[4]);
    }
    let tag = buf[5];
    let len = u32::from_le_bytes([buf[6], buf[7], buf[8], buf[9]]) as usize;
    if len > MAX_PAYLOAD {
        bail!("frame length {len} exceeds {MAX_PAYLOAD}-byte cap");
    }
    let total = HEADER_LEN + len + TRAILER_LEN;
    if buf.len() < total {
        return Ok(None);
    }
    let payload = &buf[HEADER_LEN..HEADER_LEN + len];
    let got = u64::from_le_bytes(buf[HEADER_LEN + len..total].try_into().unwrap());
    let want = checksum(tag, len as u32, payload);
    if got != want {
        bail!("frame checksum mismatch: stored {got:016x}, computed {want:016x} (tag {tag}, len {len})");
    }
    Ok(Some((tag, payload.to_vec(), total)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let frame = encode_frame(4, b"hello delta");
        let (tag, payload, consumed) = decode_frame(&frame).unwrap().unwrap();
        assert_eq!(tag, 4);
        assert_eq!(payload, b"hello delta");
        assert_eq!(consumed, frame.len());
        // Empty payloads frame fine too.
        let empty = encode_frame(1, b"");
        let (tag, payload, _) = decode_frame(&empty).unwrap().unwrap();
        assert_eq!((tag, payload.len()), (1, 0));
    }

    #[test]
    fn incremental_prefixes_are_incomplete_not_errors() {
        let frame = encode_frame(2, b"partial");
        for cut in 0..frame.len() {
            assert!(
                decode_frame(&frame[..cut]).unwrap().is_none(),
                "prefix of {cut} bytes should be incomplete"
            );
        }
        // Trailing garbage after a complete frame is the next frame's
        // problem: consumed points past this one only.
        let mut two = frame.clone();
        two.extend_from_slice(&frame);
        let (_, _, consumed) = decode_frame(&two).unwrap().unwrap();
        assert_eq!(consumed, frame.len());
        assert!(decode_frame(&two[consumed..]).unwrap().is_some());
    }

    #[test]
    fn bad_magic_is_named_error() {
        let mut frame = encode_frame(3, b"x");
        frame[0] = b'X';
        let err = decode_frame(&frame).unwrap_err().to_string();
        assert!(err.contains("magic"), "{err}");
    }

    #[test]
    fn bad_version_is_named_error() {
        let mut frame = encode_frame(3, b"x");
        frame[4] = 99;
        let err = decode_frame(&frame).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
    }

    #[test]
    fn checksum_flip_is_named_error() {
        let mut frame = encode_frame(3, b"checksummed");
        let mid = HEADER_LEN + 3;
        frame[mid] ^= 0x40;
        let err = decode_frame(&frame).unwrap_err().to_string();
        assert!(err.contains("checksum"), "{err}");
    }

    #[test]
    fn oversized_length_is_named_error() {
        let mut frame = encode_frame(3, b"x");
        frame[6..10].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = decode_frame(&frame).unwrap_err().to_string();
        assert!(err.contains("cap"), "{err}");
    }
}
