//! The deterministic discrete-event engine: simulates the full HCN
//! timeline — per-MU gradient compute, uplink transmission priced by the
//! `wireless` link model, SBS intra-cluster aggregation with straggler
//! policies, and the H-periodic MBS global sync — while executing exactly
//! the arithmetic of the sequential reference engine
//! ([`crate::fl::run_hierarchical`]).
//!
//! ## Determinism contract
//!
//! The run is a pure function of `(config, TrainOptions, DesParams)`:
//!
//! * the event queue orders by `(time, seq)` with a deterministic insertion
//!   counter, so simultaneous events never race;
//! * every MU owns private `Pcg64` streams (compute jitter, mobility) keyed
//!   by `(seed, entity id)` — nothing is shared or order-dependent;
//! * all floating-point reductions happen at fixed program points in fixed
//!   (cluster-id, MU-id) order, never in event-arrival order;
//! * the per-MU compute+uplink work inside one cluster aggregation may fan
//!   out across threads (`TrainOptions::inner_threads`) — MUs own disjoint
//!   state and the reduction still folds in MU-id order, so results are
//!   bit-identical for every fan-out width.
//!
//! ## Equivalence to the sequential engine
//!
//! In the static, wait-for-all configuration with a deterministic oracle
//! (`grad_noise = 0`, the matrix default) the DES executes the *identical*
//! f32/f64 operation sequence as `run_hierarchical`: final parameters, the
//! per-iteration loss curve, and the per-link bit totals are bit-exact, and
//! the simulated wall-clock per iteration equals the analytic
//! [`crate::wireless::hfl_latency`] / [`crate::wireless::fl_latency`] value
//! (within f64 accumulation noise ≪ 1e-6 relative) — asserted by
//! `rust/tests/des_golden.rs`. Evaluation points additionally coincide when
//! `eval_every` is a multiple of `H` (clusters are only time-aligned at
//! sync barriers).
//!
//! With mobility, deadlines, or nonzero compute profiles the timeline
//! departs from the closed form — that is the point of the subsystem — but
//! stays bit-reproducible across reruns and thread counts.
//!
//! ## Scale: millions of MUs
//!
//! Per-MU engine state is O(nnz), not O(dim), so idle MUs are nearly
//! free and a 10⁶-MU run fits in laptop memory:
//!
//! * each MU's DGC accumulators live in joint-support sparse form
//!   (`MuDgc`): one sorted index array plus the momentum/residual
//!   values at those coordinates. A touched MU is materialized into an
//!   all-`+0.0` dense scratch (`LaneScratch`), stepped through the
//!   stateless [`DgcKernel`] — the *identical* arithmetic of the dense
//!   [`crate::sparse::DgcCompressor`] — and re-extracted by bit pattern
//!   (`to_bits() != 0`, preserving `−0.0`), so the reconstruction is
//!   provably bit-exact at every step. (A dense config — φ = 0 — keeps a
//!   dense momentum buffer by necessity: that *is* the algorithm's
//!   state.)
//! * the per-(round, MU) loss slots occupy a rolling window of `H` rounds
//!   (the maximum inter-cluster round spread between sync barriers), not
//!   `iters × K`;
//! * fan-out scratch is per *lane* (leased width), message slots are per
//!   *participant of the largest cluster seen*, and cluster/sync
//!   aggregation streams through the k-way sparse merge
//!   ([`merge::aggregate_adaptive_pooled`]) — coordinate ranges fan out
//!   across the idle leased lanes — so no O(MUs × dim) buffer ever
//!   materializes;
//! * the event queue is a hierarchical calendar queue
//!   ([`crate::des::events::EventQueue`]) with O(1) expected push/pop at
//!   10⁷-event populations.

use crate::adversary::ChurnConfig;
use crate::config::Config;
use crate::des::events::{EventKind, EventQueue, TimelineRecorder};
use crate::des::mobility::{MobilityProfile, Waypoint};
use crate::des::straggler::{ComputeProfile, StragglerPolicy};
use crate::fl::{consensus_from_rows, GradOracle, LrSchedule, TrainLog, TrainOptions};
use crate::pool::Lease;
use crate::sim::result::TimelineDigest;
use crate::snapshot::codec::{get_rng, put_rng, ByteReader, ByteWriter};
use crate::snapshot::{self, CheckpointSpec};
use crate::sparse::merge::{self, AggPath, AggRule, DenseShadow, MergeScratch, ParMergeScratch};
use crate::sparse::{DgcKernel, DiscountedError, SparseVec};
use crate::tensor::{kernels, RowMatrix};
use crate::topology::{HexLayout, NetworkTopology, Point};
use crate::util::rng::Pcg64;
use crate::wireless::broadcast::{broadcast_latency, BroadcastParams};
use crate::wireless::latency::payload_bits;
use crate::wireless::{allocate_subcarriers, LinkParams};
use anyhow::{bail, Context, Result};
use std::collections::BTreeSet;
use std::path::Path;
use std::sync::Mutex;

/// Execution parameters of one DES run, beyond the shared [`TrainOptions`].
#[derive(Clone, Debug)]
pub struct DesParams {
    pub topts: TrainOptions,
    pub mobility: MobilityProfile,
    pub straggler: StragglerPolicy,
    pub compute: ComputeProfile,
    /// Multiplies every MU's mean compute time (the legacy channel-profile
    /// straggler factor of [`crate::sim::matrix::ChannelProfile`]).
    pub compute_scale: f64,
    /// Seed of the per-entity compute/mobility streams.
    pub seed: u64,
    /// Client churn + energy-budget participation gating (`--churn-*`,
    /// `[churn]`). Disabled by default; a disabled config is byte-identical
    /// to the pre-churn engine.
    pub churn: ChurnConfig,
}

/// Everything a DES run produces.
#[derive(Clone, Debug)]
pub struct DesOutcome {
    /// Training log in the sequential engine's schema.
    pub log: TrainLog,
    /// Simulated wall-clock of the whole run (s).
    pub total_time_s: f64,
    /// `total_time_s / iters` — comparable to the analytic per-iteration
    /// latency in the static wait-for-all configuration.
    pub per_iter_s: f64,
    /// Fingerprint of the processed event stream.
    pub timeline: TimelineDigest,
    pub n_handovers: u64,
    /// Messages that arrived after their round's deadline.
    pub n_late: u64,
    /// MU-rounds skipped because the MU was still transmitting.
    pub n_skipped_rounds: u64,
    /// `(mu, round)` pairs skipped by the churn/energy gate — departed or
    /// exhausted MUs that sat out the round. Feeds the golden trace's skip
    /// digest; empty when churn is disabled (traces unchanged).
    pub skips: Vec<(usize, usize)>,
}

/// Link-latency pricing of the current topology snapshot, mirroring the
/// analytic model line by line (`wireless::fl_latency` / `hfl_latency`) so
/// the static timeline reproduces it exactly.
struct Pricing {
    /// Per-MU uplink transmission time of one sparse gradient (s).
    ul_time: Vec<f64>,
    /// Per-cluster SBS→MU broadcast latency of one round update (s).
    gamma_dl: Vec<f64>,
    /// SBS→MBS fronthaul per sync (s).
    theta_ul: f64,
    /// MBS→SBS fronthaul per sync (s).
    theta_dl: f64,
    /// Worst-cluster final model broadcast per sync (s).
    max_final_dl: f64,
}

fn mu_link(cfg: &Config, dist: f64) -> LinkParams {
    let r = &cfg.radio;
    LinkParams {
        p_max_w: r.mu_power_w,
        dist_m: dist,
        alpha: r.pathloss_exp,
        noise_w: r.noise_power_w(),
        b0_hz: r.subcarrier_spacing_hz,
        ber: r.ber,
    }
}

fn price(
    cfg: &Config,
    members: &[Vec<usize>],
    dist_sbs: &[f64],
    dist_mbs: &[f64],
    m_cluster: usize,
    flat: bool,
) -> Result<Pricing> {
    let k_total = dist_sbs.len();
    let n_clusters = members.len();
    let mut p = Pricing {
        ul_time: vec![0.0; k_total],
        gamma_dl: vec![0.0; n_clusters],
        theta_ul: 0.0,
        theta_dl: 0.0,
        max_final_dl: 0.0,
    };
    if k_total <= 1 {
        // A single MU transmits nothing (same convention as the matrix
        // engine's analytic pricing).
        return Ok(p);
    }
    let q = cfg.latency.q_params;
    let qb = cfg.latency.bits_per_param;
    let s = &cfg.sparsity;
    let (phi_ul, phi_sdl, phi_mdl, phi_sul) = if s.enabled {
        (s.phi_mu_ul, s.phi_sbs_dl, s.phi_mbs_dl, s.phi_sbs_ul)
    } else {
        (0.0, 0.0, 0.0, 0.0)
    };
    let ul_bits = payload_bits(q, qb, phi_ul);

    if flat {
        if cfg.radio.subcarriers < k_total {
            bail!(
                "flat uplink needs ≥1 sub-carrier per MU ({k_total} MUs, {} sub-carriers)",
                cfg.radio.subcarriers
            );
        }
        let links: Vec<LinkParams> = dist_mbs.iter().map(|&d| mu_link(cfg, d)).collect();
        let alloc = allocate_subcarriers(&links, cfg.radio.subcarriers);
        for (k, rate) in alloc.rates.iter().enumerate() {
            p.ul_time[k] = ul_bits / rate;
        }
        let bp = BroadcastParams {
            p_total_w: cfg.radio.mbs_power_w,
            m_subcarriers: cfg.radio.subcarriers,
            noise_w: cfg.radio.noise_power_w(),
            b0_hz: cfg.radio.subcarrier_spacing_hz,
            alpha: cfg.radio.pathloss_exp,
            dists_m: dist_mbs.to_vec(),
            slot_s: cfg.radio.broadcast_slot_s,
        };
        p.gamma_dl[0] = broadcast_latency(&bp, payload_bits(q, qb, phi_mdl));
        p.max_final_dl = p.gamma_dl[0];
        return Ok(p);
    }

    let dl_bits = payload_bits(q, qb, phi_sdl);
    let mut rate_sum = 0.0;
    let mut rate_count = 0usize;
    for (c, mems) in members.iter().enumerate() {
        if mems.is_empty() {
            continue; // mobility emptied this cluster: nothing to price
        }
        let dists: Vec<f64> = mems.iter().map(|&k| dist_sbs[k]).collect();
        let links: Vec<LinkParams> = dists.iter().map(|&d| mu_link(cfg, d)).collect();
        let alloc = allocate_subcarriers(&links, m_cluster.max(links.len()));
        for (j, &k) in mems.iter().enumerate() {
            p.ul_time[k] = ul_bits / alloc.rates[j];
        }
        rate_sum += alloc.rates.iter().sum::<f64>();
        rate_count += alloc.rates.len();
        let bp = BroadcastParams {
            p_total_w: cfg.radio.sbs_power_w,
            m_subcarriers: m_cluster,
            noise_w: cfg.radio.noise_power_w(),
            b0_hz: cfg.radio.subcarrier_spacing_hz,
            alpha: cfg.radio.pathloss_exp,
            dists_m: dists,
            slot_s: cfg.radio.broadcast_slot_s,
        };
        p.gamma_dl[c] = broadcast_latency(&bp, dl_bits);
    }
    if rate_count > 0 {
        let fronthaul_rate = cfg.radio.fronthaul_multiplier * (rate_sum / rate_count as f64);
        p.theta_ul = payload_bits(q, qb, phi_sul) / fronthaul_rate;
        p.theta_dl = payload_bits(q, qb, phi_mdl) / fronthaul_rate;
    }
    p.max_final_dl = p.gamma_dl.iter().cloned().fold(0.0, f64::max);
    Ok(p)
}

/// Per-cluster round bookkeeping.
struct RoundCtx {
    round: usize,
    aggregated: bool,
    /// MUs computing this round (sorted by id).
    participants: Vec<usize>,
    /// Participants whose uplink landed before aggregation.
    fresh: BTreeSet<usize>,
    /// Participants whose uplink has not landed yet.
    awaiting: usize,
    done: bool,
}

struct Sim<'a, O: GradOracle + ?Sized> {
    oracle: &'a mut O,
    topts: &'a TrainOptions,
    cfg: &'a Config,
    params: &'a DesParams,
    n: usize,
    k_total: usize,
    dim: usize,
    h: usize,
    flat: bool,
    // Geometry / membership.
    layout: HexLayout,
    m_cluster: usize,
    dist_sbs: Vec<f64>,
    dist_mbs: Vec<f64>,
    mu_cluster: Vec<usize>,
    members: Vec<Vec<usize>>,
    walkers: Vec<Option<Waypoint>>,
    // Timing.
    pricing: Pricing,
    mu_mean_comp: Vec<f64>,
    comp_rng: Vec<Pcg64>,
    busy_until: Vec<f64>,
    // Training state (mirrors `run_hierarchical`). Per-MU DGC state sits
    // behind per-MU mutexes so the intra-round fan-out can drive disjoint
    // MUs from worker threads; the sequential path locks uncontended.
    schedule: LrSchedule,
    /// The shared stateless DGC step (σ, φ) every MU runs through.
    kernel: DgcKernel,
    /// Joint-support sparse momentum/residual state, one entry per MU —
    /// O(nnz) per idle MU, the million-MU scale-out's key invariant.
    dgc: Vec<Mutex<MuDgc>>,
    /// Dense materialization scratch, one slot per fan-out lane (one slot
    /// total when aggregations run sequentially). The `u`/`v` buffers hold
    /// `+0.0` everywhere between uses.
    scratch_pool: Vec<Mutex<LaneScratch>>,
    /// Per-cluster reference models in one flat cache-aligned allocation.
    w_tilde: RowMatrix,
    dl_enc: Vec<DiscountedError>,
    ul_enc: Vec<DiscountedError>,
    w_tilde_global: Vec<f32>,
    mbs_enc: DiscountedError,
    /// Per-cluster stale messages `(msg, weight, arrives_at)` awaiting a
    /// later aggregation. An entry is only applied once the simulated clock
    /// has passed `arrives_at` — a late update cannot land before its
    /// transmission physically completes.
    stale: Vec<Vec<(SparseVec, f32, f64)>>,
    // Bookkeeping.
    ctx: Vec<RoundCtx>,
    /// Raw per-(round, MU) losses in a rolling window of `loss_window`
    /// rounds (slot `(round % loss_window) * k_total + mu`); folded in
    /// global MU order when the iteration completes — so the loss curve
    /// matches the sequential engine bit-for-bit in the static
    /// wait-for-all configuration — and the row reset to NaN for reuse.
    /// Clusters never drift more than one H-period apart (the sync is a
    /// barrier), so a window of `H` rounds always suffices.
    round_loss: Vec<f64>,
    loss_window: usize,
    clusters_done_at: Vec<usize>,
    queue: EventQueue,
    rec: TimelineRecorder,
    log: TrainLog,
    agg: Vec<f32>,
    msg: SparseVec,
    /// Reusable SBS→MU downlink message (per-round DL encode).
    dl_out: SparseVec,
    /// Reusable sync scratch: Δ vectors of the H-period global sync.
    sync_delta: Vec<f32>,
    /// Reusable sync message (UL/MBS/final-DL encodes).
    sync_msg: SparseVec,
    /// Lease on the persistent worker pool for the per-MU compute+uplink
    /// fan-out inside one cluster aggregation (width resolved from
    /// `TrainOptions::inner_threads`; `None` = sequential aggregations).
    lease: Option<Lease>,
    /// Fan-out message slots, keyed by position in the current round's
    /// participant list and grown lazily to the largest participant count
    /// seen — bounded by the largest cluster, never by K.
    par_msgs: Vec<Mutex<SparseVec>>,
    /// True when cluster aggregations keep per-participant messages live
    /// for the density-adaptive sparse merge (φ_ul > 0 and the agg path
    /// is not forced dense); false keeps the historical streaming
    /// single-buffer scatter byte for byte.
    collect_agg: bool,
    /// Same gate for the H-sync aggregation (keyed on φ^ul_SBS).
    collect_sync: bool,
    /// Per-participant message slots of the sequential collect path,
    /// grown lazily to the largest participant count seen.
    seq_msgs: Vec<SparseVec>,
    /// Per-cluster sync messages of the collect path (length N).
    sync_msgs: Vec<SparseVec>,
    /// Reusable merged consensus of the sparse path.
    agg_sparse: SparseVec,
    /// k-way merge scratch (heap + cursors) of the sequential dispatch.
    merge_scratch: MergeScratch,
    /// Per-lane scratch of the pooled merge dispatch (used whenever a
    /// lane lease is held — the lanes are idle during the aggregation
    /// tail, so the coordinate-range fan-out rides for free).
    par_merge_scratch: ParMergeScratch,
    /// Keeps `agg` bit-identical to the reference `zero → scatter →
    /// scale(−lr)` round sequence on the sparse path (−0.0 baseline).
    agg_shadow: DenseShadow,
    /// The H-sync aggregation accumulator. Separate from `agg` so the
    /// round path's −0.0 baseline and the sync path's +0.0 baseline each
    /// stay stable — sharing one buffer would flip the baseline at every
    /// round/sync boundary and force a full O(dim) refill each time,
    /// defeating the shadow's O(nnz) steady state.
    sync_agg: Vec<f32>,
    /// Shadow of `sync_agg` (+0.0 baseline; zeroed, never scaled).
    sync_shadow: DenseShadow,
    n_handovers: u64,
    n_late: u64,
    n_skipped: u64,
    finish_time: f64,
    // Churn / adversary per-MU state (checkpointed; all empty/identity
    // when the corresponding feature is disabled).
    /// Churn liveness per MU: a dropped MU sits out rounds until its
    /// rejoin draw fires. All-true when churn is disabled.
    alive: Vec<bool>,
    /// Energy units spent per MU (1.0 per participated round); once
    /// `churn.energy` is exhausted the MU departs permanently.
    energy_spent: Vec<f64>,
    /// Per-MU stale-replay slots: the previous honest post-DGC message,
    /// recorded by [`crate::adversary::AdversaryPlan::corrupt`]. Only
    /// touched in the sequential MU-id reduction, never from fan-out
    /// lanes.
    mu_stale: Vec<Option<(Vec<u32>, Vec<f32>)>>,
    /// `(mu, round)` pairs skipped by the churn/energy gate, in decision
    /// order (cluster-round start, MU-id order within a cluster).
    skips: Vec<(usize, usize)>,
}

/// One MU's DGC accumulators in joint-support sparse form: `indices` is
/// the sorted union of the coordinates where the momentum (`u`) or
/// residual (`v`) accumulator is non-zero **by bit pattern** (so `−0.0`
/// survives round trips), and `u`/`v` hold the values at those
/// coordinates. Every coordinate outside the support is exactly `+0.0` in
/// the equivalent dense state — the invariant that makes materialization
/// bit-exact.
#[derive(Default)]
struct MuDgc {
    indices: Vec<u32>,
    u: Vec<f32>,
    v: Vec<f32>,
}

impl MuDgc {
    /// Materialize into `s`'s all-`+0.0` dense buffers, run one DGC step
    /// over `s.grad` (identical arithmetic to the dense
    /// [`crate::sparse::DgcCompressor`]), then re-extract the joint
    /// support by bit pattern — leaving `s.u`/`s.v` all-`+0.0` again. The
    /// extraction scan doubles as the re-zeroing pass: a coordinate it
    /// skips already holds `+0.0`.
    fn step_from_scratch(&mut self, k: &DgcKernel, s: &mut LaneScratch, out: &mut SparseVec) {
        let LaneScratch { grad, u, v, quant } = s;
        for (j, &i) in self.indices.iter().enumerate() {
            u[i as usize] = self.u[j];
            v[i as usize] = self.v[j];
        }
        k.step_into(grad, u, v, quant, out);
        self.indices.clear();
        self.u.clear();
        self.v.clear();
        for i in 0..u.len() {
            if u[i].to_bits() != 0 || v[i].to_bits() != 0 {
                self.indices.push(i as u32);
                self.u.push(u[i]);
                self.v.push(v[i]);
                u[i] = 0.0;
                v[i] = 0.0;
            }
        }
    }

    /// Overwrite from checkpointed state (validated by the caller).
    fn restore(&mut self, indices: Vec<u32>, u: Vec<f32>, v: Vec<f32>) {
        self.indices = indices;
        self.u = u;
        self.v = v;
    }
}

/// One lane's private dense scratch: the gradient buffer plus the
/// momentum/residual/quantile buffers the stateless DGC step runs over.
/// `u` and `v` hold `+0.0` everywhere between uses (established on grow,
/// restored by [`MuDgc::step_from_scratch`]'s extraction pass), so which
/// lane an MU lands on cannot influence a single bit.
#[derive(Default)]
struct LaneScratch {
    grad: Vec<f32>,
    u: Vec<f32>,
    v: Vec<f32>,
    quant: Vec<f32>,
}

impl LaneScratch {
    fn ensure_dim(&mut self, dim: usize) {
        if self.u.len() != dim {
            self.grad.clear();
            self.grad.resize(dim, 0.0);
            self.u.clear();
            self.u.resize(dim, 0.0);
            self.v.clear();
            self.v.resize(dim, 0.0);
            self.quant.clear();
            self.quant.resize(dim, 0.0);
        }
    }
}

/// Claim any free lane scratch. At most `slots.len()` executors run
/// concurrently (the lease width), so a free slot always exists; the spin
/// only rides out the instant between a peer's `try_lock` and its
/// release. Which slot a task gets is scheduling-dependent — harmless,
/// because the all-`+0.0` invariant makes every slot interchangeable.
fn acquire_scratch(slots: &[Mutex<LaneScratch>]) -> std::sync::MutexGuard<'_, LaneScratch> {
    loop {
        for s in slots {
            if let Ok(g) = s.try_lock() {
                return g;
            }
        }
        std::thread::yield_now();
    }
}

/// Apply one MU's compressed update to the cluster aggregate — the single
/// definition of the fresh/late policy, shared by the fan-out reduction
/// and the sequential path so the two can never drift apart. A fresh
/// message folds into `agg`; a late one (deadline missed) counts toward
/// `n_late` and, when discounted, is queued as stale mass that lands once
/// its uplink physically completes at `arrives_at`.
#[allow(clippy::too_many_arguments)]
fn apply_mu_message(
    msg: &SparseVec,
    fresh: bool,
    denom: f32,
    stale_discount: f32,
    arrives_at: f64,
    agg: &mut [f32],
    stale_c: &mut Vec<(SparseVec, f32, f64)>,
    n_late: &mut u64,
) {
    if fresh {
        msg.add_into(agg, 1.0 / denom);
    } else {
        *n_late += 1;
        if stale_discount > 0.0 {
            stale_c.push((msg.clone(), stale_discount / denom, arrives_at));
        }
    }
}

/// Trajectory-defining scalars of a DES run. A snapshot taken under one
/// fingerprint refuses to resume under another — the shared training
/// scalars are folded in by [`crate::spec::RunSpec::put_fingerprint`], so
/// thread counts, pool wiring, and `agg` dispatch are excluded
/// (bit-irrelevant by the determinism contract, so resuming at a different
/// thread count is legal and still bit-exact).
fn put_des_fingerprint(
    w: &mut ByteWriter,
    dim: usize,
    k_total: usize,
    cfg: &Config,
    params: &DesParams,
) {
    let topts = &params.topts;
    w.put_usize(dim);
    w.put_usize(k_total);
    w.put_usize(topts.n_clusters);
    w.put_usize(topts.eval_every);
    topts.spec.put_fingerprint(w);
    w.put_u64(params.seed);
    w.put_f64(params.compute_scale);
    match &params.mobility {
        MobilityProfile::Static => w.put_u8(0),
        MobilityProfile::Waypoint { speed_mps, pause_s } => {
            w.put_u8(1);
            w.put_f64(*speed_mps);
            w.put_f64(*pause_s);
        }
    }
    match &params.straggler {
        StragglerPolicy::WaitForAll => w.put_u8(0),
        StragglerPolicy::Deadline { rel, stale_discount } => {
            w.put_u8(1);
            w.put_f64(*rel);
            w.put_f32(*stale_discount);
        }
    }
    w.put_f64(params.compute.mean_s);
    w.put_f64(params.compute.het);
    w.put_usize(cfg.topology.n_clusters);
    w.put_usize(cfg.topology.mus_per_cluster);
    w.put_f64(cfg.topology.radius_m);
    w.put_usize(cfg.radio.subcarriers);
    // Churn gates participation per (seed, mu, round) — trajectory-defining.
    // (The adversary plan and aggregation rule ride in the RunSpec
    // fingerprint above.)
    let ch = &params.churn;
    w.put_bool(ch.enabled);
    w.put_u64(ch.seed);
    w.put_f64(ch.drop_p);
    w.put_f64(ch.rejoin_p);
    w.put_f64(ch.energy);
}

fn check_des_fingerprint(
    r: &mut ByteReader,
    dim: usize,
    k_total: usize,
    cfg: &Config,
    params: &DesParams,
) -> Result<()> {
    let mut expect = ByteWriter::new();
    put_des_fingerprint(&mut expect, dim, k_total, cfg, params);
    let expect = expect.into_bytes();
    let got = r.take(expect.len()).context("snapshot fingerprint")?;
    if got != expect.as_slice() {
        bail!(
            "snapshot was taken under a different DES configuration \
             (dim/workers/clusters/iters/seed/mobility/straggler/compute/\
             radio must match the resuming run exactly)"
        );
    }
    Ok(())
}

fn put_sparse(w: &mut ByteWriter, m: &SparseVec) {
    w.put_usize(m.dim);
    w.put_u32_slice(&m.indices);
    w.put_f32_slice(&m.values);
}

fn get_sparse(r: &mut ByteReader) -> Result<SparseVec> {
    let dim = r.get_usize()?;
    let indices = r.get_u32_vec()?;
    let values = r.get_f32_vec()?;
    if indices.len() != values.len() {
        bail!("corrupt sparse vector in snapshot (nnz mismatch)");
    }
    Ok(SparseVec { dim, indices, values })
}

impl<O: GradOracle + ?Sized> Sim<'_, O> {
    fn eval_due(&self, round: usize) -> bool {
        self.topts.eval_every > 0 && (round + 1) % self.topts.eval_every == 0
    }

    fn push_eval(&mut self, round: usize) {
        let consensus = consensus_from_rows(self.w_tilde.iter_rows(), self.dim, self.n);
        let m = self.oracle.eval(&consensus);
        self.log.evals.push((round + 1, m));
    }

    fn start_round(&mut self, c: usize, round: usize, t: f64) -> Result<()> {
        let churn = self.params.churn;
        let mut participants = Vec::new();
        for &mu in &self.members[c] {
            if churn.enabled {
                // Churn/energy gate, evaluated before the busy gate so the
                // skip record is independent of radio timing. Decisions are
                // keyed `(seed, mu, round)` — bit-identical at any thread
                // count, and replayed identically on resume.
                if self.alive[mu] {
                    if churn.drops(mu as u64, round as u64) {
                        self.alive[mu] = false;
                    }
                } else if churn.rejoins(mu as u64, round as u64) {
                    self.alive[mu] = true;
                }
                if !self.alive[mu] || churn.exhausted(self.energy_spent[mu]) {
                    self.n_skipped += 1;
                    self.skips.push((mu, round));
                    continue;
                }
            }
            if self.busy_until[mu] <= t {
                participants.push(mu);
                if churn.enabled {
                    // Participation costs one energy unit; an exhausted MU
                    // sits out every later round (permanent departure).
                    self.energy_spent[mu] += 1.0;
                }
            } else {
                self.n_skipped += 1;
            }
        }
        let awaiting = participants.len();
        self.ctx[c] = RoundCtx {
            round,
            aggregated: false,
            participants,
            fresh: BTreeSet::new(),
            awaiting,
            done: false,
        };
        if awaiting == 0 {
            // Nothing computes this round (empty or fully-busy cluster):
            // aggregate whatever stale mass has arrived and move on.
            self.aggregate(c, t)?;
            self.queue
                .push(t + self.pricing.gamma_dl[c], EventKind::RoundEnd { cluster: c, round });
            return Ok(());
        }
        let parts = self.ctx[c].participants.clone();
        let mut expected_worst = 0.0f64;
        for &mu in &parts {
            let comp = self
                .params
                .compute
                .sample_round(self.mu_mean_comp[mu], &mut self.comp_rng[mu]);
            self.busy_until[mu] = t + comp + self.pricing.ul_time[mu];
            self.queue
                .push(t + comp, EventKind::ComputeDone { mu, cluster: c, round });
            expected_worst =
                expected_worst.max(self.mu_mean_comp[mu] + self.pricing.ul_time[mu]);
        }
        if let StragglerPolicy::Deadline { rel, .. } = &self.params.straggler {
            let d = rel * expected_worst;
            if d > 0.0 {
                self.queue.push(t + d, EventKind::Deadline { cluster: c, round });
            }
        }
        Ok(())
    }

    /// Execute the cluster's round arithmetic (identical to one iteration of
    /// the sequential engine's inner loop) at the aggregation instant `t`.
    ///
    /// The per-MU compute+uplink work fans out across lanes leased from
    /// the persistent worker pool ([`crate::pool`]) when
    /// `inner_threads > 1` and the oracle has a
    /// [`crate::fl::ParGradOracle`] view; the reduction (loss slots, bit
    /// accounting, aggregation into `agg`) always folds sequentially in
    /// MU-id order afterwards, so results are bit-identical to the
    /// sequential path for any thread count.
    fn aggregate(&mut self, c: usize, t: f64) -> Result<()> {
        let (round, parts) = {
            let ctx = &mut self.ctx[c];
            ctx.aggregated = true;
            (ctx.round, ctx.participants.clone())
        };
        let denom = parts.len() as f32;
        let stale_discount = match &self.params.straggler {
            StragglerPolicy::Deadline { stale_discount, .. } => *stale_discount,
            StragglerPolicy::WaitForAll => 0.0,
        };
        // Stale updates whose transmission has landed by now fold first
        // (in stored order, pre-discounted); ones still in flight go back
        // in the queue (their original order preserved) for a later
        // aggregation.
        let pending = std::mem::take(&mut self.stale[c]);
        let mut landed: Vec<(SparseVec, f32)> = Vec::new();
        for (m, w, arrives_at) in pending {
            if arrives_at <= t {
                landed.push((m, w));
            } else {
                self.stale[c].push((m, w, arrives_at));
            }
        }
        if self.collect_agg {
            return self.aggregate_collect(c, round, &parts, landed, denom, stale_discount);
        }
        kernels::zero(&mut self.agg);
        self.agg_shadow.mark_dirty();
        for (m, w) in &landed {
            m.add_into(&mut self.agg, *w);
        }
        let wd = self.topts.weight_decay;
        let mut ran_parallel = false;
        if parts.len() > 1 && self.lease.is_some() {
            // Message slots are keyed by *position in this round's
            // participant list*, not MU id: only one cluster is in flight
            // at a time, so the slot count is bounded by the largest
            // cluster, not K.
            while self.par_msgs.len() < parts.len() {
                self.par_msgs.push(Mutex::new(SparseVec::empty(self.dim)));
            }
            if let (Some(lease), Some(par)) = (self.lease.as_ref(), self.oracle.par_view()) {
                // Fan out: gradient + DGC compression per participant —
                // lane-private dense scratch, per-participant message
                // slots (disjoint MUs → disjoint state), on lanes leased
                // from the persistent pool — no per-round thread spawns.
                let w_row = self.w_tilde.row(c);
                let kernel = self.kernel;
                let dgc = &self.dgc;
                let msgs = &self.par_msgs;
                let scratch = &self.scratch_pool;
                let dim = self.dim;
                let losses = lease
                    .run_ordered(parts.len(), |idx| {
                        let mu = parts[idx];
                        let mut s = acquire_scratch(scratch);
                        s.ensure_dim(dim);
                        let loss = par.loss_grad_par(mu, w_row, &mut s.grad);
                        if wd != 0.0 {
                            kernels::axpy(&mut s.grad, w_row, wd);
                        }
                        dgc[mu].lock().unwrap().step_from_scratch(
                            &kernel,
                            &mut s,
                            &mut msgs[idx].lock().unwrap(),
                        );
                        loss
                    })
                    .with_context(|| {
                        format!("DES intra-round fan-out (cluster {c}, round {round})")
                    })?;
                // Ordered reduction in MU-id order — never arrival order.
                let adversary = self.topts.spec.adversary;
                for (idx, &mu) in parts.iter().enumerate() {
                    let slot = (round % self.loss_window) * self.k_total + mu;
                    self.round_loss[slot] = losses[idx];
                    let mut m = self.par_msgs[idx].lock().unwrap();
                    if adversary.enabled {
                        // Corruption happens here, in the sequential MU-id
                        // reduction, so fan-out scheduling cannot touch it.
                        adversary.corrupt(
                            mu as u64,
                            round as u64,
                            &mut m.indices,
                            &mut m.values,
                            &mut self.mu_stale[mu],
                        );
                    }
                    self.log.bits.mu_ul += m.wire_bits(32);
                    self.log.bits.n_mu_msgs += 1;
                    apply_mu_message(
                        &m,
                        self.ctx[c].fresh.contains(&mu),
                        denom,
                        stale_discount,
                        self.busy_until[mu],
                        &mut self.agg,
                        &mut self.stale[c],
                        &mut self.n_late,
                    );
                }
                ran_parallel = true;
            }
        }
        if !ran_parallel {
            // Fresh computation + uplink, in MU-id order — never arrival
            // order.
            let adversary = self.topts.spec.adversary;
            for &mu in &parts {
                let mut s = self.scratch_pool[0].lock().unwrap();
                s.ensure_dim(self.dim);
                let loss = self.oracle.loss_grad(mu, self.w_tilde.row(c), &mut s.grad);
                let slot = (round % self.loss_window) * self.k_total + mu;
                self.round_loss[slot] = loss;
                if wd != 0.0 {
                    kernels::axpy(&mut s.grad, self.w_tilde.row(c), wd);
                }
                self.dgc[mu]
                    .lock()
                    .unwrap()
                    .step_from_scratch(&self.kernel, &mut s, &mut self.msg);
                drop(s);
                if adversary.enabled {
                    // Attack the post-DGC uplink message; the honest DGC
                    // residual above already evolved as if the honest
                    // update had been sent.
                    adversary.corrupt(
                        mu as u64,
                        round as u64,
                        &mut self.msg.indices,
                        &mut self.msg.values,
                        &mut self.mu_stale[mu],
                    );
                }
                self.log.bits.mu_ul += self.msg.wire_bits(32);
                self.log.bits.n_mu_msgs += 1;
                // Bits are spent either way; a late update lands stale
                // once its uplink completes (or is discarded at discount 0).
                apply_mu_message(
                    &self.msg,
                    self.ctx[c].fresh.contains(&mu),
                    denom,
                    stale_discount,
                    self.busy_until[mu],
                    &mut self.agg,
                    &mut self.stale[c],
                    &mut self.n_late,
                );
            }
        }
        let lr = self.schedule.at(round) as f32;
        kernels::scale(&mut self.agg, -lr);
        self.dl_enc[c].compress_into(&self.agg, &mut self.dl_out);
        self.log.bits.sbs_dl += self.dl_out.wire_bits(32);
        self.dl_out.add_into(self.w_tilde.row_mut(c), 1.0);
        Ok(())
    }

    /// The collect variant of [`Sim::aggregate`]'s arithmetic tail: every
    /// participant's message is materialized in a per-slot buffer (the
    /// fan-out already had them; the sequential path gets `seq_msgs`),
    /// then the round aggregate is built either by the k-way sparse merge
    /// or by the dense scatter, chosen from the measured total nnz. All
    /// side effects — loss slots, bit accounting, the fresh/late policy,
    /// stale-queue pushes — execute in the exact MU-id order of the
    /// streaming path, and the dense `agg` buffer handed to the DL
    /// encoder is bit-identical either way (−0.0 baseline via the
    /// shadow).
    fn aggregate_collect(
        &mut self,
        c: usize,
        round: usize,
        parts: &[usize],
        landed: Vec<(SparseVec, f32)>,
        denom: f32,
        stale_discount: f32,
    ) -> Result<()> {
        let wd = self.topts.weight_decay;
        let mut ran_parallel = false;
        if parts.len() > 1 && self.lease.is_some() {
            while self.par_msgs.len() < parts.len() {
                self.par_msgs.push(Mutex::new(SparseVec::empty(self.dim)));
            }
            if let (Some(lease), Some(par)) = (self.lease.as_ref(), self.oracle.par_view()) {
                let w_row = self.w_tilde.row(c);
                let kernel = self.kernel;
                let dgc = &self.dgc;
                let msgs = &self.par_msgs;
                let scratch = &self.scratch_pool;
                let dim = self.dim;
                let losses = lease
                    .run_ordered(parts.len(), |idx| {
                        let mu = parts[idx];
                        let mut s = acquire_scratch(scratch);
                        s.ensure_dim(dim);
                        let loss = par.loss_grad_par(mu, w_row, &mut s.grad);
                        if wd != 0.0 {
                            kernels::axpy(&mut s.grad, w_row, wd);
                        }
                        dgc[mu].lock().unwrap().step_from_scratch(
                            &kernel,
                            &mut s,
                            &mut msgs[idx].lock().unwrap(),
                        );
                        loss
                    })
                    .with_context(|| {
                        format!("DES intra-round fan-out (cluster {c}, round {round})")
                    })?;
                for (idx, &mu) in parts.iter().enumerate() {
                    let slot = (round % self.loss_window) * self.k_total + mu;
                    self.round_loss[slot] = losses[idx];
                }
                ran_parallel = true;
            }
        }
        if !ran_parallel {
            while self.seq_msgs.len() < parts.len() {
                self.seq_msgs.push(SparseVec::empty(self.dim));
            }
            for (idx, &mu) in parts.iter().enumerate() {
                let mut s = self.scratch_pool[0].lock().unwrap();
                s.ensure_dim(self.dim);
                let loss = self.oracle.loss_grad(mu, self.w_tilde.row(c), &mut s.grad);
                let slot = (round % self.loss_window) * self.k_total + mu;
                self.round_loss[slot] = loss;
                if wd != 0.0 {
                    kernels::axpy(&mut s.grad, self.w_tilde.row(c), wd);
                }
                self.dgc[mu]
                    .lock()
                    .unwrap()
                    .step_from_scratch(&self.kernel, &mut s, &mut self.seq_msgs[idx]);
            }
        }
        // Ordered reduction in MU-id order — never arrival order. The
        // fan-out guards stay alive so the merge can borrow the messages.
        let mut guards: Vec<std::sync::MutexGuard<'_, SparseVec>> = if ran_parallel {
            parts
                .iter()
                .enumerate()
                .map(|(idx, _)| self.par_msgs[idx].lock().unwrap())
                .collect()
        } else {
            Vec::new()
        };
        let adversary = self.topts.spec.adversary;
        if adversary.enabled {
            // Corrupt the post-DGC messages in MU-id order before any bit
            // accounting or aggregation — identical placement to the
            // streaming path, so the attack stream is path-independent.
            for (idx, &mu) in parts.iter().enumerate() {
                let m: &mut SparseVec =
                    if ran_parallel { &mut guards[idx] } else { &mut self.seq_msgs[idx] };
                adversary.corrupt(
                    mu as u64,
                    round as u64,
                    &mut m.indices,
                    &mut m.values,
                    &mut self.mu_stale[mu],
                );
            }
        }
        let mut agg_parts: Vec<(&SparseVec, f32)> =
            Vec::with_capacity(landed.len() + parts.len());
        for (m, w) in &landed {
            agg_parts.push((m, *w));
        }
        let mut late: Vec<(SparseVec, f32, f64)> = Vec::new();
        for (idx, &mu) in parts.iter().enumerate() {
            let m: &SparseVec = if ran_parallel { &guards[idx] } else { &self.seq_msgs[idx] };
            self.log.bits.mu_ul += m.wire_bits(32);
            self.log.bits.n_mu_msgs += 1;
            // Bits are spent either way; a late update lands stale once
            // its uplink completes (or is discarded at discount 0).
            if self.ctx[c].fresh.contains(&mu) {
                agg_parts.push((m, 1.0 / denom));
            } else {
                self.n_late += 1;
                if stale_discount > 0.0 {
                    late.push((m.clone(), stale_discount / denom, self.busy_until[mu]));
                }
            }
        }
        let lr = self.schedule.at(round) as f32;
        match self.lease.as_ref() {
            Some(lease) => merge::aggregate_adaptive_pooled(
                &self.topts.agg,
                &agg_parts,
                self.dim,
                Some(-lr),
                lease.width(),
                self.topts.pool.as_ref(),
                &mut self.agg,
                &mut self.agg_sparse,
                &mut self.par_merge_scratch,
                &mut self.agg_shadow,
            )?,
            None => merge::aggregate_adaptive(
                &self.topts.agg,
                &agg_parts,
                self.dim,
                Some(-lr),
                &mut self.agg,
                &mut self.agg_sparse,
                &mut self.merge_scratch,
                &mut self.agg_shadow,
            ),
        }
        drop(agg_parts);
        drop(guards);
        for e in late {
            self.stale[c].push(e);
        }
        self.dl_enc[c].compress_into(&self.agg, &mut self.dl_out);
        self.log.bits.sbs_dl += self.dl_out.wire_bits(32);
        self.dl_out.add_into(self.w_tilde.row_mut(c), 1.0);
        Ok(())
    }

    /// Fold the completed iteration's per-MU losses in global MU order —
    /// the sequential engine's exact summation order.
    fn fold_iteration_loss(&mut self, round: usize) {
        let base = (round % self.loss_window) * self.k_total;
        let mut iter_loss = 0.0f64;
        for mu in 0..self.k_total {
            let v = self.round_loss[base + mu];
            if !v.is_nan() {
                iter_loss += v / self.k_total as f64;
            }
        }
        self.log.train_loss.push((round, iter_loss));
        // Recycle the window row for the round that will reuse this slot.
        self.round_loss[base..base + self.k_total].fill(f64::NAN);
    }

    /// The H-periodic global sync: identical arithmetic to the sequential
    /// engine's sync block, then fronthaul + final broadcast pricing.
    /// Allocation-free: the Δ vectors land in a reusable scratch slice and
    /// each encoder's error buffer is borrowed in place.
    fn do_sync(&mut self, round: usize, t: f64) -> Result<()> {
        if !self.collect_sync {
            kernels::zero(&mut self.sync_agg);
            self.sync_shadow.mark_dirty();
            for c in 0..self.n {
                // Δ_n = W̃_n + e_n − W̃ (fused; e_n borrowed, never cloned).
                kernels::add_sub(
                    &mut self.sync_delta,
                    self.w_tilde.row(c),
                    self.dl_enc[c].error(),
                    &self.w_tilde_global,
                );
                self.ul_enc[c].compress_into(&self.sync_delta, &mut self.sync_msg);
                self.log.bits.sbs_ul += self.sync_msg.wire_bits(32);
                self.sync_msg.add_into(&mut self.sync_agg, 1.0 / self.n as f32);
            }
        } else {
            // Collect every cluster's encoded Δ (same cluster-ordered
            // encoder updates and bit accounting), then aggregate through
            // the density-adaptive dispatch. The sync accumulator's
            // reference baseline is +0.0 (zeroed, never scaled).
            for c in 0..self.n {
                kernels::add_sub(
                    &mut self.sync_delta,
                    self.w_tilde.row(c),
                    self.dl_enc[c].error(),
                    &self.w_tilde_global,
                );
                let out = &mut self.sync_msgs[c];
                self.ul_enc[c].compress_into(&self.sync_delta, out);
                self.log.bits.sbs_ul += out.wire_bits(32);
            }
            let scale = 1.0 / self.n as f32;
            let parts: Vec<(&SparseVec, f32)> =
                self.sync_msgs.iter().map(|m| (m, scale)).collect();
            match self.lease.as_ref() {
                Some(lease) => merge::aggregate_adaptive_pooled(
                    &self.topts.agg,
                    &parts,
                    self.dim,
                    None,
                    lease.width(),
                    self.topts.pool.as_ref(),
                    &mut self.sync_agg,
                    &mut self.agg_sparse,
                    &mut self.par_merge_scratch,
                    &mut self.sync_shadow,
                )?,
                None => merge::aggregate_adaptive(
                    &self.topts.agg,
                    &parts,
                    self.dim,
                    None,
                    &mut self.sync_agg,
                    &mut self.agg_sparse,
                    &mut self.merge_scratch,
                    &mut self.sync_shadow,
                ),
            }
        }
        self.mbs_enc.compress_into(&self.sync_agg, &mut self.sync_msg);
        self.log.bits.mbs_dl += self.sync_msg.wire_bits(32);
        self.sync_msg.add_into(&mut self.w_tilde_global, 1.0);
        for c in 0..self.n {
            kernels::sub(&mut self.sync_delta, &self.w_tilde_global, self.w_tilde.row(c));
            self.dl_enc[c].compress_into(&self.sync_delta, &mut self.sync_msg);
            self.log.bits.sbs_dl += self.sync_msg.wire_bits(32);
            self.sync_msg.add_into(self.w_tilde.row_mut(c), 1.0);
        }
        // Clusters resume together once the slowest final broadcast lands.
        let t_resume =
            t + self.pricing.theta_ul + self.pricing.theta_dl + self.pricing.max_final_dl;
        self.queue
            .push(t_resume, EventKind::GlobalSync { period: (round + 1) / self.h });
        Ok(())
    }

    /// Move the MUs to their positions at time `t`, re-associate to the
    /// nearest SBS, and reprice every link. Called when all clusters are
    /// time-aligned: at sync boundaries, or at every round end for flat
    /// (single-cluster) topologies that never sync.
    fn update_mobility(&mut self, t: f64) -> Result<()> {
        if self.params.mobility.is_static() {
            return Ok(());
        }
        for k in 0..self.k_total {
            let pos = match self.walkers[k].as_mut() {
                Some(w) => w.position_at(t),
                None => continue,
            };
            self.dist_mbs[k] = pos.norm().max(1.0);
            let nearest = self.layout.nearest_center(&pos);
            if nearest != self.mu_cluster[k] {
                self.n_handovers += 1;
                self.rec.record_kind(
                    t,
                    &EventKind::Handover { mu: k, from: self.mu_cluster[k], to: nearest },
                );
                self.mu_cluster[k] = nearest;
            }
            self.dist_sbs[k] = pos.dist(&self.layout.centers[self.mu_cluster[k]]).max(1.0);
        }
        for m in self.members.iter_mut() {
            m.clear();
        }
        for k in 0..self.k_total {
            self.members[self.mu_cluster[k]].push(k);
        }
        self.pricing = price(
            self.cfg,
            &self.members,
            &self.dist_sbs,
            &self.dist_mbs,
            self.m_cluster,
            self.flat,
        )?;
        Ok(())
    }

    /// Serialize every piece of mutable simulation state — mobility and
    /// association, per-entity RNG streams, compressor error/momentum
    /// buffers, cluster models, the stale queue, round bookkeeping, the
    /// event queue with its insertion counter, the timeline recorder, the
    /// training log, and the oracle's exported state. Everything derived
    /// (pricing, membership lists, scratch buffers) is recomputed on
    /// restore from what is stored here.
    fn snapshot_payload(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        put_des_fingerprint(&mut w, self.dim, self.k_total, self.cfg, self.params);
        // Mobility / association.
        w.put_f64_slice(&self.dist_sbs);
        w.put_f64_slice(&self.dist_mbs);
        for &c in &self.mu_cluster {
            w.put_usize(c);
        }
        for wk in &self.walkers {
            match wk {
                None => w.put_bool(false),
                Some(wp) => {
                    w.put_bool(true);
                    let (anchor, target, leg_start, arrive, speed, pause, disc_r, rng) =
                        wp.raw_state();
                    w.put_f64(anchor.x);
                    w.put_f64(anchor.y);
                    w.put_f64(target.x);
                    w.put_f64(target.y);
                    w.put_f64(leg_start);
                    w.put_f64(arrive);
                    w.put_f64(speed);
                    w.put_f64(pause);
                    w.put_f64(disc_r);
                    put_rng(&mut w, rng);
                }
            }
        }
        // Timing state.
        for rng in &self.comp_rng {
            put_rng(&mut w, rng);
        }
        w.put_f64_slice(&self.busy_until);
        // Training state. The per-MU DGC state is stored sparse — exactly
        // the joint-support triples held in memory — so snapshot size scales
        // with live residual mass, not `k_total * dim`.
        for d in &self.dgc {
            let d = d.lock().unwrap();
            w.put_u32_slice(&d.indices);
            w.put_f32_slice(&d.u);
            w.put_f32_slice(&d.v);
        }
        for c in 0..self.n {
            w.put_f32_slice(self.w_tilde.row(c));
        }
        for e in &self.dl_enc {
            w.put_f32_slice(e.error());
        }
        for e in &self.ul_enc {
            w.put_f32_slice(e.error());
        }
        w.put_f32_slice(&self.w_tilde_global);
        w.put_f32_slice(self.mbs_enc.error());
        for sc in &self.stale {
            w.put_usize(sc.len());
            for (m, wt, at) in sc {
                put_sparse(&mut w, m);
                w.put_f32(*wt);
                w.put_f64(*at);
            }
        }
        // Round bookkeeping.
        for ctx in &self.ctx {
            w.put_usize(ctx.round);
            w.put_bool(ctx.aggregated);
            w.put_usize(ctx.participants.len());
            for &p in &ctx.participants {
                w.put_usize(p);
            }
            w.put_usize(ctx.fresh.len());
            for &p in &ctx.fresh {
                w.put_usize(p);
            }
            w.put_usize(ctx.awaiting);
            w.put_bool(ctx.done);
        }
        w.put_f64_slice(&self.round_loss);
        for &x in &self.clusters_done_at {
            w.put_usize(x);
        }
        // Event queue (original seq values preserved) + timeline digest.
        w.put_u64(self.queue.next_seq());
        let evs = self.queue.snapshot_events();
        w.put_usize(evs.len());
        for ev in &evs {
            w.put_f64(ev.time);
            w.put_u64(ev.seq);
            let (tag, fields) = ev.kind.digest_fields();
            w.put_u8(tag);
            for f in fields {
                w.put_u64(f);
            }
        }
        let (rec_n, rec_d) = self.rec.raw_state();
        w.put_u64(rec_n);
        w.put_u64(rec_d);
        crate::fl::algorithms::put_train_log(&mut w, &self.log);
        w.put_u64(self.n_handovers);
        w.put_u64(self.n_late);
        w.put_u64(self.n_skipped);
        w.put_f64(self.finish_time);
        // Churn / adversary state. All-default when both features are off,
        // costing a few bytes per MU; the stale-replay slots are sparse.
        for &a in &self.alive {
            w.put_bool(a);
        }
        w.put_f64_slice(&self.energy_spent);
        for s in &self.mu_stale {
            match s {
                Some((si, sv)) => {
                    w.put_bool(true);
                    w.put_u32_slice(si);
                    w.put_f32_slice(sv);
                }
                None => w.put_bool(false),
            }
        }
        w.put_usize(self.skips.len());
        for &(mu, rd) in &self.skips {
            w.put_usize(mu);
            w.put_usize(rd);
        }
        let blob = self
            .oracle
            .export_state()
            .expect("export_state checked before the run");
        w.put_bytes(&blob);
        w.into_bytes()
    }

    /// Inverse of [`Sim::snapshot_payload`]: overwrite the freshly
    /// constructed simulation with the checkpointed state, then recompute
    /// the derived pieces (membership lists, link pricing, shadow
    /// bookkeeping).
    fn restore(&mut self, payload: &[u8]) -> Result<()> {
        let mut r = ByteReader::new(payload);
        check_des_fingerprint(&mut r, self.dim, self.k_total, self.cfg, self.params)?;
        let dist_sbs = r.get_f64_vec()?;
        let dist_mbs = r.get_f64_vec()?;
        if dist_sbs.len() != self.k_total || dist_mbs.len() != self.k_total {
            bail!("snapshot distance vectors have the wrong length");
        }
        self.dist_sbs = dist_sbs;
        self.dist_mbs = dist_mbs;
        for k in 0..self.k_total {
            let c = r.get_usize()?;
            if c >= self.n {
                bail!("snapshot MU {k} associated to nonexistent cluster {c}");
            }
            self.mu_cluster[k] = c;
        }
        for k in 0..self.k_total {
            let has = r.get_bool()?;
            if has != self.walkers[k].is_some() {
                bail!("snapshot mobility state disagrees with the mobility profile");
            }
            if has {
                let ax = r.get_f64()?;
                let ay = r.get_f64()?;
                let tx = r.get_f64()?;
                let ty = r.get_f64()?;
                let leg_start = r.get_f64()?;
                let arrive = r.get_f64()?;
                let speed = r.get_f64()?;
                let pause = r.get_f64()?;
                let disc_r = r.get_f64()?;
                let rng = get_rng(&mut r)?;
                self.walkers[k] = Some(Waypoint::from_raw_state(
                    Point::new(ax, ay),
                    Point::new(tx, ty),
                    leg_start,
                    arrive,
                    speed,
                    pause,
                    disc_r,
                    rng,
                ));
            }
        }
        for k in 0..self.k_total {
            self.comp_rng[k] = get_rng(&mut r)?;
        }
        let busy = r.get_f64_vec()?;
        if busy.len() != self.k_total {
            bail!("snapshot busy_until has the wrong length");
        }
        self.busy_until = busy;
        for d in &self.dgc {
            let indices = r.get_u32_vec()?;
            let u = r.get_f32_vec()?;
            let v = r.get_f32_vec()?;
            if u.len() != indices.len() || v.len() != indices.len() {
                bail!("snapshot DGC state has mismatched triple lengths");
            }
            let mut prev: Option<u32> = None;
            for &i in &indices {
                if (i as usize) >= self.dim || prev.is_some_and(|p| p >= i) {
                    bail!("snapshot DGC indices not strictly increasing within dim");
                }
                prev = Some(i);
            }
            d.lock().unwrap().restore(indices, u, v);
        }
        for c in 0..self.n {
            r.get_f32_into(self.w_tilde.row_mut(c))?;
        }
        for e in self.dl_enc.iter_mut() {
            let buf = r.get_f32_vec()?;
            if buf.len() != self.dim {
                bail!("snapshot DL encoder error has the wrong dimension");
            }
            e.restore_error(&buf);
        }
        for e in self.ul_enc.iter_mut() {
            let buf = r.get_f32_vec()?;
            if buf.len() != self.dim {
                bail!("snapshot UL encoder error has the wrong dimension");
            }
            e.restore_error(&buf);
        }
        r.get_f32_into(&mut self.w_tilde_global)?;
        let buf = r.get_f32_vec()?;
        if buf.len() != self.dim {
            bail!("snapshot MBS encoder error has the wrong dimension");
        }
        self.mbs_enc.restore_error(&buf);
        for sc in self.stale.iter_mut() {
            let len = r.get_usize()?;
            sc.clear();
            for _ in 0..len {
                let m = get_sparse(&mut r)?;
                let wt = r.get_f32()?;
                let at = r.get_f64()?;
                sc.push((m, wt, at));
            }
        }
        for ctx in self.ctx.iter_mut() {
            ctx.round = r.get_usize()?;
            ctx.aggregated = r.get_bool()?;
            let np = r.get_usize()?;
            ctx.participants.clear();
            for _ in 0..np {
                ctx.participants.push(r.get_usize()?);
            }
            let nf = r.get_usize()?;
            ctx.fresh.clear();
            for _ in 0..nf {
                ctx.fresh.insert(r.get_usize()?);
            }
            ctx.awaiting = r.get_usize()?;
            ctx.done = r.get_bool()?;
        }
        let round_loss = r.get_f64_vec()?;
        if round_loss.len() != self.round_loss.len() {
            bail!("snapshot round_loss has the wrong length");
        }
        self.round_loss = round_loss;
        for x in self.clusters_done_at.iter_mut() {
            *x = r.get_usize()?;
        }
        let next_seq = r.get_u64()?;
        let n_evs = r.get_usize()?;
        let mut evs = Vec::with_capacity(n_evs.min(1 << 20));
        for _ in 0..n_evs {
            let time = r.get_f64()?;
            let seq = r.get_u64()?;
            if seq >= next_seq {
                bail!("snapshot event seq beyond the insertion counter");
            }
            let tag = r.get_u8()?;
            let fields = [r.get_u64()?, r.get_u64()?, r.get_u64()?];
            let kind = EventKind::from_wire(tag, fields)
                .ok_or_else(|| anyhow::anyhow!("unknown event tag {tag} in snapshot"))?;
            evs.push(crate::des::events::Event { time, seq, kind });
        }
        self.queue = EventQueue::restore(evs, next_seq);
        let rec_n = r.get_u64()?;
        let rec_d = r.get_u64()?;
        self.rec = TimelineRecorder::from_raw_state(rec_n, rec_d);
        self.log = crate::fl::algorithms::get_train_log(&mut r)?;
        self.n_handovers = r.get_u64()?;
        self.n_late = r.get_u64()?;
        self.n_skipped = r.get_u64()?;
        self.finish_time = r.get_f64()?;
        for a in self.alive.iter_mut() {
            *a = r.get_bool()?;
        }
        let energy_spent = r.get_f64_vec()?;
        if energy_spent.len() != self.k_total {
            bail!("snapshot energy vector has the wrong length");
        }
        self.energy_spent = energy_spent;
        for s in self.mu_stale.iter_mut() {
            *s = if r.get_bool()? {
                let si = r.get_u32_vec()?;
                let sv = r.get_f32_vec()?;
                if si.len() != sv.len() {
                    bail!("corrupt stale-replay slot in snapshot (nnz mismatch)");
                }
                Some((si, sv))
            } else {
                None
            };
        }
        let n_skips = r.get_usize()?;
        self.skips.clear();
        for _ in 0..n_skips {
            let mu = r.get_usize()?;
            let rd = r.get_usize()?;
            self.skips.push((mu, rd));
        }
        let blob = r.get_bytes()?;
        self.oracle
            .import_state(&blob)
            .context("restoring oracle RNG state")?;
        r.finish()?;
        // Derived state: membership lists from the restored association,
        // link pricing from the restored geometry (price() is pure), and
        // shadow bookkeeping invalidated — the aggregate buffers no longer
        // match the shadows' baseline records.
        for m in self.members.iter_mut() {
            m.clear();
        }
        for k in 0..self.k_total {
            self.members[self.mu_cluster[k]].push(k);
        }
        self.pricing = price(
            self.cfg,
            &self.members,
            &self.dist_sbs,
            &self.dist_mbs,
            self.m_cluster,
            self.flat,
        )?;
        self.agg_shadow.mark_dirty();
        self.sync_shadow.mark_dirty();
        Ok(())
    }

    fn run(&mut self, resumed: bool, ckpt: Option<&CheckpointSpec>) -> Result<()> {
        let iters = self.topts.iters;
        if !resumed {
            for c in 0..self.n {
                self.start_round(c, 0, 0.0)?;
            }
        }
        // Generous upper bound on legitimate events; a breach means a
        // scheduling bug, reported as an error rather than a hang.
        let cap = 64
            + (iters as u64 + 2) * (4 * self.k_total as u64 + 4 * self.n as u64 + 8);
        let mut processed = 0u64;
        while let Some(ev) = self.queue.pop() {
            self.rec.record(&ev);
            processed += 1;
            if processed > cap {
                bail!("DES event cap exceeded ({cap}): the scheduler is looping");
            }
            // Set when this event completes a round; the snapshot is taken
            // after the full match arm so the serialized queue already
            // holds everything the arm scheduled.
            let mut snap_round: Option<usize> = None;
            match ev.kind {
                EventKind::ComputeDone { mu, cluster, round } => {
                    self.queue.push(
                        self.busy_until[mu],
                        EventKind::UplinkDone { mu, cluster, round },
                    );
                }
                EventKind::UplinkDone { mu, cluster, round } => {
                    let ready = {
                        let ctx = &mut self.ctx[cluster];
                        if ctx.round == round && !ctx.aggregated {
                            ctx.fresh.insert(mu);
                            ctx.awaiting -= 1;
                            ctx.awaiting == 0
                        } else {
                            false // late arrival — charged at aggregation
                        }
                    };
                    if ready {
                        self.aggregate(cluster, ev.time)?;
                        self.queue.push(
                            ev.time + self.pricing.gamma_dl[cluster],
                            EventKind::RoundEnd { cluster, round },
                        );
                    }
                }
                EventKind::Deadline { cluster, round } => {
                    let fire = {
                        let ctx = &self.ctx[cluster];
                        ctx.round == round && !ctx.aggregated
                    };
                    if fire {
                        self.aggregate(cluster, ev.time)?;
                        self.queue.push(
                            ev.time + self.pricing.gamma_dl[cluster],
                            EventKind::RoundEnd { cluster, round },
                        );
                    }
                }
                EventKind::RoundEnd { cluster, round } => {
                    self.clusters_done_at[round] += 1;
                    let complete = self.clusters_done_at[round] == self.n;
                    if complete {
                        self.fold_iteration_loss(round);
                        snap_round = Some(round);
                    }
                    let sync_due = self.n > 1 && (round + 1) % self.h == 0;
                    if sync_due {
                        // Barrier: the last cluster to finish triggers the
                        // sync at the barrier instant.
                        if complete {
                            self.do_sync(round, ev.time)?;
                        }
                    } else {
                        if complete && self.eval_due(round) {
                            self.push_eval(round);
                        }
                        if round + 1 < self.topts.iters {
                            if self.flat {
                                // Flat topologies have no sync barriers, but
                                // their single cluster is time-aligned at
                                // every round end — move/reprice here.
                                self.update_mobility(ev.time)?;
                            }
                            self.start_round(cluster, round + 1, ev.time)?;
                        } else {
                            self.ctx[cluster].done = true;
                            self.finish_time = self.finish_time.max(ev.time);
                        }
                    }
                }
                EventKind::GlobalSync { period } => {
                    let round = period * self.h - 1;
                    self.update_mobility(ev.time)?;
                    if self.eval_due(round) {
                        self.push_eval(round);
                    }
                    for c in 0..self.n {
                        if round + 1 < self.topts.iters {
                            self.start_round(c, round + 1, ev.time)?;
                        } else {
                            self.ctx[c].done = true;
                            self.finish_time = self.finish_time.max(ev.time);
                        }
                    }
                }
                EventKind::Handover { .. } => {
                    // Handovers are digest records, never queued.
                    bail!("handover events must not enter the queue");
                }
            }
            if let (Some(spec), Some(done)) = (ckpt, snap_round) {
                if spec.due_after_round(done, iters) {
                    snapshot::write_snapshot(
                        &spec.path,
                        snapshot::ENGINE_DES,
                        &self.snapshot_payload(),
                    )?;
                }
            }
        }
        if self.ctx.iter().any(|c| !c.done) {
            bail!("DES queue drained with unfinished clusters — scheduling bug");
        }
        Ok(())
    }
}

/// Run the discrete-event simulation. See the module docs for the
/// determinism and sequential-equivalence contracts.
pub fn run_des<O: GradOracle + ?Sized>(
    oracle: &mut O,
    cfg: &Config,
    params: &DesParams,
) -> Result<DesOutcome> {
    run_des_checkpointed(oracle, cfg, params, None, None)
}

/// [`run_des`] with optional periodic checkpointing and resume-from-snapshot.
///
/// With `ckpt` set, a full engine snapshot is written after each round whose
/// completion satisfies [`CheckpointSpec::due_after_round`]. With `resume`
/// set, the engine is reconstructed exactly as for a fresh run and then
/// overwritten with the snapshot's state, so the continued run reproduces
/// the uninterrupted run's timeline digest, loss digest, and final
/// parameters bit for bit. Both require the oracle to support
/// [`GradOracle::export_state`].
pub fn run_des_checkpointed<O: GradOracle + ?Sized>(
    oracle: &mut O,
    cfg: &Config,
    params: &DesParams,
    ckpt: Option<&CheckpointSpec>,
    resume: Option<&Path>,
) -> Result<DesOutcome> {
    if (ckpt.is_some() || resume.is_some()) && oracle.export_state().is_none() {
        bail!("this oracle does not support state export; checkpoint/resume is unavailable");
    }
    let topts = &params.topts;
    let n = topts.n_clusters;
    let k_total = oracle.n_workers();
    let dim = oracle.dim();
    if topts.iters == 0 {
        bail!("DES needs at least one iteration");
    }
    if n < 1 || k_total < n {
        bail!("need ≥1 worker per cluster ({k_total} workers, {n} clusters)");
    }
    if k_total % n != 0 {
        bail!("workers ({k_total}) must divide evenly into clusters ({n}) — Assumption 1");
    }
    if topts.h_period == 0 {
        bail!("h_period must be ≥ 1");
    }
    if cfg.topology.n_clusters != n || cfg.topology.total_mus() != k_total {
        bail!(
            "topology config ({} clusters × {} MUs) disagrees with the oracle/TrainOptions \
             ({n} clusters, {k_total} workers)",
            cfg.topology.n_clusters,
            cfg.topology.mus_per_cluster
        );
    }
    topts.agg.validate().context("aggregation policy")?;
    topts
        .agg
        .validate_participants(k_total / n)
        .context("round aggregation (MUs per cluster)")?;
    if n > 1 {
        topts
            .agg
            .validate_participants(n)
            .context("H-sync aggregation (clusters)")?;
    }
    topts.spec.adversary.validate().context("adversary plan")?;
    params.churn.validate().context("churn config")?;

    let topo = NetworkTopology::generate(&cfg.topology);
    let flat = n == 1;
    let m_cluster = topo.layout.subcarriers_per_cluster(cfg.radio.subcarriers);
    let dist_sbs: Vec<f64> = topo.users.iter().map(|u| u.dist_sbs).collect();
    let dist_mbs: Vec<f64> = topo.users.iter().map(|u| u.dist_mbs).collect();
    let mu_cluster: Vec<usize> = topo.users.iter().map(|u| u.cluster).collect();
    let mut members: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (k, &c) in mu_cluster.iter().enumerate() {
        members[c].push(k);
    }

    // Per-entity streams: compute heterogeneity, per-round jitter, mobility.
    let mut mu_mean_comp = Vec::with_capacity(k_total);
    let mut comp_rng = Vec::with_capacity(k_total);
    let mut walkers: Vec<Option<Waypoint>> = Vec::with_capacity(k_total);
    for k in 0..k_total {
        let mut het_stream = Pcg64::new(params.seed, 0x1000_0000 + k as u64);
        mu_mean_comp.push(params.compute.mu_mean(&mut het_stream) * params.compute_scale);
        comp_rng.push(Pcg64::new(params.seed, 0x2000_0000 + k as u64));
        walkers.push(match &params.mobility {
            MobilityProfile::Static => None,
            MobilityProfile::Waypoint { speed_mps, pause_s } => Some(Waypoint::new(
                topo.users[k].pos,
                *speed_mps,
                *pause_s,
                cfg.topology.radius_m,
                Pcg64::new(params.seed, 0x3000_0000 + k as u64),
            )),
        });
    }

    // Training state — constructed in the sequential engine's exact order.
    let (phi_ul, phi_sdl, phi_sul, phi_mdl) = if topts.sparsity.enabled {
        (
            topts.sparsity.phi_mu_ul,
            topts.sparsity.phi_sbs_dl,
            topts.sparsity.phi_sbs_ul,
            topts.sparsity.phi_mbs_dl,
        )
    } else {
        (0.0, 0.0, 0.0, 0.0)
    };
    let (cluster_dl_phi, cluster_dl_beta) = if n == 1 {
        (phi_mdl, topts.sparsity.beta_m)
    } else {
        (phi_sdl, topts.sparsity.beta_s)
    };
    let schedule = LrSchedule::new(
        topts.peak_lr,
        topts.warmup_iters,
        topts.iters,
        topts.milestones,
    );
    // Per-MU DGC state is held sparse (joint-support index/u/v triples) and
    // materialized into dense lane scratch only while an MU actually steps —
    // resident cost is O(live residual mass), not O(K · dim).
    let kernel = DgcKernel::new(topts.momentum, phi_ul);
    let dgc: Vec<Mutex<MuDgc>> = (0..k_total).map(|_| Mutex::new(MuDgc::default())).collect();
    let init = oracle.init_params();
    let w_tilde = RowMatrix::broadcast(&init, n);
    let dl_enc: Vec<DiscountedError> = (0..n)
        .map(|_| DiscountedError::new(dim, cluster_dl_phi, cluster_dl_beta as f32))
        .collect();
    let ul_enc: Vec<DiscountedError> = (0..n)
        .map(|_| DiscountedError::new(dim, phi_sul, topts.sparsity.beta_s as f32))
        .collect();
    let mbs_enc = DiscountedError::new(dim, phi_mdl, topts.sparsity.beta_m as f32);

    // Intra-round fan-out width (same resolution policy as the sequential
    // engine), leased once from the persistent pool for the whole run.
    // Fan-out scratch slots exist only when the fan-out can actually run
    // (the oracle has a thread-safe view); they start empty and grow to
    // `dim` lazily, so resident memory is bounded by the largest cluster
    // actually fanned out, not by K.
    let inner_threads = crate::fl::algorithms::resolve_inner_threads(topts.inner_threads);
    let lease: Option<Lease> = if inner_threads > 1 && oracle.par_view().is_some() {
        let handle = topts.pool.clone().unwrap_or_else(crate::pool::global_handle);
        Some(handle.lease(inner_threads))
    } else {
        if inner_threads > 1 {
            crate::log_info!(
                "inner_threads={} requested but this oracle has no parallel view \
                 (shared mutable state); DES aggregations run sequentially",
                topts.inner_threads
            );
        }
        None
    };
    // One dense scratch lane per concurrent executor (the leased width
    // includes the submitting thread; sequential runs get exactly one).
    // Lanes are interchangeable — each is returned all-+0.0 — so which lane
    // an MU lands on never affects the arithmetic.
    let lane_width = lease.as_ref().map(|l| l.width()).unwrap_or(1).max(1);
    let scratch_pool: Vec<Mutex<LaneScratch>> =
        (0..lane_width).map(|_| Mutex::new(LaneScratch::default())).collect();

    // Losses live in a rolling window of `h` rounds: the sync barrier
    // guarantees no round older than one H-period is still in flight, and
    // flat (n = 1) topologies complete rounds strictly in order.
    let loss_window = if n == 1 { 1 } else { topts.h_period.min(topts.iters).max(1) };

    // Density-adaptive aggregation: keep per-participant messages live
    // only when a sparse merge could ever win (φ > 0 on the link and the
    // path is not forced dense) — otherwise the historical streaming
    // scatter runs byte for byte with no extra buffers.
    // Robust rules always collect: trimming/medianing needs every
    // participant's value per coordinate, which the streaming scatter
    // cannot provide.
    let collect_agg = (phi_ul > 0.0 && topts.agg.path != AggPath::Dense)
        || topts.agg.rule != AggRule::Mean;
    let collect_sync = (phi_sul > 0.0 && topts.agg.path != AggPath::Dense)
        || topts.agg.rule != AggRule::Mean;
    let sync_msgs: Vec<SparseVec> = if collect_sync {
        (0..n).map(|_| SparseVec::empty(dim)).collect()
    } else {
        Vec::new()
    };

    let pricing = price(cfg, &members, &dist_sbs, &dist_mbs, m_cluster, flat)?;
    let ctx: Vec<RoundCtx> = (0..n)
        .map(|_| RoundCtx {
            round: 0,
            aggregated: true,
            participants: Vec::new(),
            fresh: BTreeSet::new(),
            awaiting: 0,
            done: false,
        })
        .collect();

    let mut sim = Sim {
        oracle,
        topts,
        cfg,
        params,
        n,
        k_total,
        dim,
        h: topts.h_period,
        flat,
        layout: topo.layout.clone(),
        m_cluster,
        dist_sbs,
        dist_mbs,
        mu_cluster,
        members,
        walkers,
        pricing,
        mu_mean_comp,
        comp_rng,
        busy_until: vec![0.0; k_total],
        schedule,
        kernel,
        dgc,
        w_tilde,
        dl_enc,
        ul_enc,
        w_tilde_global: init,
        mbs_enc,
        stale: vec![Vec::new(); n],
        ctx,
        loss_window,
        round_loss: vec![f64::NAN; loss_window * k_total],
        clusters_done_at: vec![0; topts.iters],
        queue: EventQueue::new(),
        rec: TimelineRecorder::new(),
        log: TrainLog::default(),
        agg: vec![0.0; dim],
        msg: SparseVec::empty(dim),
        dl_out: SparseVec::empty(dim),
        sync_delta: vec![0.0; dim],
        sync_msg: SparseVec::empty(dim),
        lease,
        scratch_pool,
        par_msgs: Vec::new(),
        collect_agg,
        collect_sync,
        seq_msgs: Vec::new(),
        sync_msgs,
        agg_sparse: SparseVec::empty(dim),
        merge_scratch: MergeScratch::default(),
        par_merge_scratch: ParMergeScratch::default(),
        agg_shadow: DenseShadow::new(),
        sync_agg: vec![0.0; dim],
        sync_shadow: DenseShadow::new(),
        n_handovers: 0,
        n_late: 0,
        n_skipped: 0,
        finish_time: 0.0,
        alive: vec![true; k_total],
        energy_spent: vec![0.0; k_total],
        mu_stale: (0..k_total).map(|_| None).collect(),
        skips: Vec::new(),
    };
    let resumed = if let Some(path) = resume {
        let payload = snapshot::read_snapshot(path, snapshot::ENGINE_DES)
            .with_context(|| format!("reading DES snapshot {}", path.display()))?;
        sim.restore(&payload)
            .with_context(|| format!("restoring DES snapshot {}", path.display()))?;
        crate::log_info!("resumed DES run from {}", path.display());
        true
    } else {
        false
    };
    sim.run(resumed, ckpt)?;

    // Final consensus + eval, exactly like the sequential engine.
    let consensus = consensus_from_rows(sim.w_tilde.iter_rows(), dim, n);
    let m = sim.oracle.eval(&consensus);
    sim.log.evals.push((topts.iters, m));
    sim.log.final_params = consensus;

    let total = sim.finish_time;
    Ok(DesOutcome {
        per_iter_s: total / topts.iters as f64,
        total_time_s: total,
        timeline: sim.rec.digest(),
        n_handovers: sim.n_handovers,
        n_late: sim.n_late,
        n_skipped_rounds: sim.n_skipped,
        skips: sim.skips,
        log: sim.log,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::{run_hierarchical, QuadraticOracle};

    fn cfg_for(n: usize, mus: usize) -> Config {
        let mut c = Config::smoke();
        c.topology.n_clusters = n;
        c.topology.mus_per_cluster = mus;
        c.topology.reuse_colors = c.topology.reuse_colors.min(n);
        c.training.h_period = 2;
        c.sparsity.enabled = true;
        c.sparsity.phi_mu_ul = 0.9;
        c
    }

    fn topts_for(cfg: &Config, iters: usize) -> TrainOptions {
        TrainOptions {
            spec: crate::spec::RunSpec::new()
                .iters(iters)
                .peak_lr(0.05)
                .warmup(3)
                .milestones(0.6, 0.85)
                .h_period(cfg.training.h_period)
                .sparsity(cfg.sparsity.clone()),
            n_clusters: cfg.topology.n_clusters,
            eval_every: 10,
        }
    }

    fn static_params(topts: TrainOptions) -> DesParams {
        DesParams {
            topts,
            mobility: MobilityProfile::Static,
            straggler: StragglerPolicy::WaitForAll,
            compute: ComputeProfile::none(),
            compute_scale: 1.0,
            seed: 99,
            churn: ChurnConfig::default(),
        }
    }

    fn bits_f32(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn static_waitall_matches_sequential_engine_bit_exactly() {
        let cfg = cfg_for(2, 4);
        let topts = topts_for(&cfg, 20);
        let mut des_oracle = QuadraticOracle::new_skewed(16, 8, 0.0, 1.0, 4242);
        let out = run_des(&mut des_oracle, &cfg, &static_params(topts.clone())).unwrap();
        let mut seq_oracle = QuadraticOracle::new_skewed(16, 8, 0.0, 1.0, 4242);
        let seq = run_hierarchical(&mut seq_oracle, &topts);
        assert_eq!(
            bits_f32(&out.log.final_params),
            bits_f32(&seq.final_params),
            "final params must be bit-identical"
        );
        assert_eq!(out.log.bits, seq.bits, "per-link bits must agree");
        // The loss curve folds in the sequential engine's exact order.
        let curve_bits = |c: &[(usize, f64)]| -> Vec<(usize, u64)> {
            c.iter().map(|(i, x)| (*i, x.to_bits())).collect()
        };
        assert_eq!(curve_bits(&out.log.train_loss), curve_bits(&seq.train_loss));
        // Evals land on sync boundaries (eval_every % H == 0) — identical.
        assert_eq!(out.log.evals.len(), seq.evals.len());
        for ((ia, ma), (ib, mb)) in out.log.evals.iter().zip(&seq.evals) {
            assert_eq!(ia, ib);
            assert_eq!(ma.loss.to_bits(), mb.loss.to_bits());
        }
        assert_eq!(out.n_late, 0);
        assert_eq!(out.n_handovers, 0);
        assert_eq!(out.n_skipped_rounds, 0);
    }

    #[test]
    fn static_waitall_matches_analytic_hfl_latency() {
        let cfg = cfg_for(4, 4);
        let topts = topts_for(&cfg, 8); // multiple of H = 2
        let mut oracle = QuadraticOracle::new_skewed(8, 16, 0.0, 1.0, 7);
        let out = run_des(&mut oracle, &cfg, &static_params(topts)).unwrap();
        let analytic = crate::sim::price_latency(&cfg, false);
        let rel = (out.per_iter_s - analytic).abs() / analytic;
        assert!(
            rel < 1e-6,
            "DES per-iter {} vs analytic {analytic} (rel {rel})",
            out.per_iter_s
        );
    }

    #[test]
    fn flat_static_matches_analytic_fl_latency() {
        let cfg = cfg_for(1, 4);
        let topts = topts_for(&cfg, 6);
        let mut oracle = QuadraticOracle::new_skewed(8, 4, 0.0, 1.0, 8);
        let out = run_des(&mut oracle, &cfg, &static_params(topts)).unwrap();
        let analytic = crate::sim::price_latency(&cfg, true);
        let rel = (out.per_iter_s - analytic).abs() / analytic;
        assert!(
            rel < 1e-6,
            "flat DES per-iter {} vs analytic {analytic} (rel {rel})",
            out.per_iter_s
        );
    }

    #[test]
    fn rerun_with_same_seed_is_bit_identical() {
        let cfg = cfg_for(2, 4);
        let run = || {
            let topts = topts_for(&cfg, 12);
            let params = DesParams {
                topts,
                mobility: MobilityProfile::Waypoint { speed_mps: 30.0, pause_s: 1.0 },
                straggler: StragglerPolicy::Deadline { rel: 0.9, stale_discount: 0.5 },
                compute: ComputeProfile { mean_s: 0.5, het: 0.5 },
                compute_scale: 1.0,
                seed: 1234,
                churn: ChurnConfig::default(),
            };
            let mut oracle = QuadraticOracle::new_skewed(12, 8, 0.0, 1.0, 55);
            run_des(&mut oracle, &cfg, &params).unwrap()
        };
        let a = run();
        let b = run();
        assert_eq!(a.timeline, b.timeline, "timeline digest must be reproducible");
        assert_eq!(bits_f32(&a.log.final_params), bits_f32(&b.log.final_params));
        assert_eq!(a.total_time_s.to_bits(), b.total_time_s.to_bits());
        assert_eq!(a.n_late, b.n_late);
        assert_eq!(a.n_handovers, b.n_handovers);
        // A different seed produces a different timeline.
        let topts = topts_for(&cfg, 12);
        let params = DesParams {
            seed: 1235,
            ..DesParams {
                topts,
                mobility: MobilityProfile::Waypoint { speed_mps: 30.0, pause_s: 1.0 },
                straggler: StragglerPolicy::Deadline { rel: 0.9, stale_discount: 0.5 },
                compute: ComputeProfile { mean_s: 0.5, het: 0.5 },
                compute_scale: 1.0,
                seed: 0,
                churn: ChurnConfig::default(),
            }
        };
        let mut oracle = QuadraticOracle::new_skewed(12, 8, 0.0, 1.0, 55);
        let c = run_des(&mut oracle, &cfg, &params).unwrap();
        assert_ne!(a.timeline.digest, c.timeline.digest);
    }

    #[test]
    fn fast_waypoint_mobility_triggers_handovers() {
        let cfg = cfg_for(4, 2);
        let topts = topts_for(&cfg, 8);
        let params = DesParams {
            topts,
            mobility: MobilityProfile::Waypoint { speed_mps: 400.0, pause_s: 0.5 },
            straggler: StragglerPolicy::WaitForAll,
            compute: ComputeProfile::none(),
            compute_scale: 1.0,
            seed: 31,
            churn: ChurnConfig::default(),
        };
        let mut oracle = QuadraticOracle::new_skewed(8, 8, 0.0, 1.0, 31);
        let out = run_des(&mut oracle, &cfg, &params).unwrap();
        assert!(
            out.n_handovers > 0,
            "400 m/s walkers across 4 cells must hand over at least once"
        );
        // Mobility must not corrupt the training loop: every iteration logged.
        assert_eq!(out.log.train_loss.len(), 8);
        assert_eq!(out.log.final_params.len(), 8);
    }

    #[test]
    fn tight_deadline_produces_late_updates_and_different_params() {
        let cfg = cfg_for(2, 4);
        let run = |straggler: StragglerPolicy| {
            let topts = topts_for(&cfg, 10);
            let params = DesParams {
                topts,
                mobility: MobilityProfile::Static,
                straggler,
                compute: ComputeProfile::none(),
                compute_scale: 1.0,
                seed: 77,
                churn: ChurnConfig::default(),
            };
            let mut oracle = QuadraticOracle::new_skewed(12, 8, 0.0, 1.0, 77);
            run_des(&mut oracle, &cfg, &params).unwrap()
        };
        let waitall = run(StragglerPolicy::WaitForAll);
        let tight = run(StragglerPolicy::Deadline { rel: 0.5, stale_discount: 0.5 });
        assert!(tight.n_late > 0, "a 0.5× deadline must cut off stragglers");
        assert_ne!(
            bits_f32(&waitall.log.final_params),
            bits_f32(&tight.log.final_params),
            "stale discounting must change the training trajectory"
        );
        // The deadline round ends no later than the wait-for-all round.
        assert!(tight.total_time_s <= waitall.total_time_s + 1e-9);
    }

    #[test]
    fn loose_deadline_reproduces_waitall_arithmetic() {
        // With instantaneous compute the arrival times are deterministic,
        // so a 2× deadline never fires before the last uplink: identical
        // parameters, different timeline (the deadline events exist).
        let cfg = cfg_for(2, 4);
        let run = |straggler: StragglerPolicy| {
            let topts = topts_for(&cfg, 8);
            let params = DesParams {
                topts,
                mobility: MobilityProfile::Static,
                straggler,
                compute: ComputeProfile::none(),
                compute_scale: 1.0,
                seed: 5,
                churn: ChurnConfig::default(),
            };
            let mut oracle = QuadraticOracle::new_skewed(10, 8, 0.0, 1.0, 5);
            run_des(&mut oracle, &cfg, &params).unwrap()
        };
        let waitall = run(StragglerPolicy::WaitForAll);
        let loose = run(StragglerPolicy::Deadline { rel: 2.0, stale_discount: 0.5 });
        assert_eq!(loose.n_late, 0);
        assert_eq!(
            bits_f32(&waitall.log.final_params),
            bits_f32(&loose.log.final_params)
        );
        assert_ne!(waitall.timeline, loose.timeline, "deadline events enter the digest");
    }

    #[test]
    fn inner_fanout_is_bit_exact_with_sequential_des() {
        // The per-MU fan-out inside cluster aggregation must not change a
        // single bit — including under deadlines, stale discounting, and
        // heterogeneous compute (the RNG streams are per-entity, and every
        // reduction folds in MU-id order).
        let cfg = cfg_for(2, 4);
        let run = |inner: usize| {
            let mut topts = topts_for(&cfg, 12);
            topts.inner_threads = inner;
            let params = DesParams {
                topts,
                mobility: MobilityProfile::Waypoint { speed_mps: 30.0, pause_s: 1.0 },
                straggler: StragglerPolicy::Deadline { rel: 0.8, stale_discount: 0.5 },
                compute: ComputeProfile { mean_s: 0.4, het: 0.5 },
                compute_scale: 1.0,
                seed: 2222,
                churn: ChurnConfig::default(),
            };
            let mut oracle = QuadraticOracle::new_skewed(14, 8, 0.0, 1.0, 66);
            run_des(&mut oracle, &cfg, &params).unwrap()
        };
        let seq = run(1);
        for inner in [2usize, 8] {
            let par = run(inner);
            assert_eq!(par.timeline, seq.timeline, "inner={inner}");
            assert_eq!(
                bits_f32(&par.log.final_params),
                bits_f32(&seq.log.final_params),
                "inner={inner}"
            );
            assert_eq!(par.log.bits, seq.log.bits, "inner={inner}");
            assert_eq!(par.n_late, seq.n_late);
            assert_eq!(par.n_skipped_rounds, seq.n_skipped_rounds);
            let curve = |l: &TrainLog| -> Vec<(usize, u64)> {
                l.train_loss.iter().map(|(i, x)| (*i, x.to_bits())).collect()
            };
            assert_eq!(curve(&par.log), curve(&seq.log), "inner={inner}");
        }
    }

    #[test]
    fn agg_path_dispatch_is_bit_exact_in_des() {
        // The sparse-merge aggregation must not change a single bit of a
        // DES run — including under deadlines (stale weighted parts),
        // mobility, heterogeneous compute, and the per-MU fan-out.
        let cfg = cfg_for(2, 4);
        let run = |path: crate::sparse::AggPath, inner: usize| {
            let mut topts = topts_for(&cfg, 12);
            topts.inner_threads = inner;
            topts.agg = crate::sparse::AggPolicy { path, ..Default::default() };
            let params = DesParams {
                topts,
                mobility: MobilityProfile::Waypoint { speed_mps: 30.0, pause_s: 1.0 },
                straggler: StragglerPolicy::Deadline { rel: 0.8, stale_discount: 0.5 },
                compute: ComputeProfile { mean_s: 0.4, het: 0.5 },
                compute_scale: 1.0,
                seed: 4711,
                churn: ChurnConfig::default(),
            };
            let mut oracle = QuadraticOracle::new_skewed(14, 8, 0.0, 1.0, 66);
            run_des(&mut oracle, &cfg, &params).unwrap()
        };
        let dense = run(crate::sparse::AggPath::Dense, 1);
        for (path, inner) in [
            (crate::sparse::AggPath::Sparse, 1),
            (crate::sparse::AggPath::Auto, 1),
            (crate::sparse::AggPath::Sparse, 4),
        ] {
            let other = run(path, inner);
            assert_eq!(other.timeline, dense.timeline, "{path:?} inner={inner}");
            assert_eq!(
                bits_f32(&other.log.final_params),
                bits_f32(&dense.log.final_params),
                "{path:?} inner={inner}"
            );
            assert_eq!(other.log.bits, dense.log.bits, "{path:?} inner={inner}");
            assert_eq!(other.n_late, dense.n_late, "{path:?} inner={inner}");
            let curve = |l: &TrainLog| -> Vec<(usize, u64)> {
                l.train_loss.iter().map(|(i, x)| (*i, x.to_bits())).collect()
            };
            assert_eq!(curve(&other.log), curve(&dense.log), "{path:?} inner={inner}");
        }
    }

    #[test]
    fn invalid_setups_are_errors_not_panics() {
        let cfg = cfg_for(2, 4);
        // Worker count not divisible by clusters.
        let mut oracle = QuadraticOracle::new_skewed(8, 7, 0.0, 1.0, 3);
        let topts = TrainOptions { n_clusters: 2, ..topts_for(&cfg, 4) };
        assert!(run_des(&mut oracle, &cfg, &static_params(topts)).is_err());
        // Topology config disagreeing with the oracle.
        let mut oracle = QuadraticOracle::new_skewed(8, 8, 0.0, 1.0, 3);
        let bad_cfg = cfg_for(4, 4);
        let topts = topts_for(&cfg, 4);
        assert!(run_des(&mut oracle, &bad_cfg, &static_params(topts)).is_err());
    }

    #[test]
    fn checkpoint_resume_is_bit_exact_mid_run() {
        // Full state coverage: waypoint mobility (walker RNGs, handovers,
        // repricing), a deadline policy (stale queue, late counters), and a
        // heterogeneous compute profile (per-MU jitter RNGs), plus oracle
        // gradient noise so the oracle RNG matters too.
        let cfg = cfg_for(2, 4);
        let make_params = || {
            let topts = topts_for(&cfg, 14);
            DesParams {
                topts,
                mobility: MobilityProfile::Waypoint { speed_mps: 60.0, pause_s: 0.5 },
                straggler: StragglerPolicy::Deadline { rel: 0.8, stale_discount: 0.5 },
                compute: ComputeProfile { mean_s: 0.4, het: 0.6 },
                compute_scale: 1.0,
                seed: 2024,
                churn: ChurnConfig::default(),
            }
        };
        let make_oracle = || QuadraticOracle::new_skewed(12, 8, 0.01, 1.0, 909);

        // Uninterrupted reference run.
        let mut oracle = make_oracle();
        let full = run_des(&mut oracle, &cfg, &make_params()).unwrap();

        // Checkpointed run: identical output, plus a snapshot on disk
        // (every=5 over 14 iters → last snapshot after round 9).
        let snap = std::env::temp_dir()
            .join(format!("hfl_des_ckpt_{}.snap", std::process::id()));
        let spec = CheckpointSpec::new(5, snap.clone());
        let mut oracle = make_oracle();
        let ckpt =
            run_des_checkpointed(&mut oracle, &cfg, &make_params(), Some(&spec), None)
                .unwrap();
        assert_eq!(ckpt.timeline, full.timeline, "checkpointing must not perturb the run");
        assert_eq!(bits_f32(&ckpt.log.final_params), bits_f32(&full.log.final_params));

        // Resume from the round-9 snapshot: bit-identical everything.
        let mut oracle = make_oracle(); // fresh oracle; state comes from the snapshot
        let res =
            run_des_checkpointed(&mut oracle, &cfg, &make_params(), None, Some(&snap))
                .unwrap();
        assert_eq!(res.timeline, full.timeline, "resumed timeline digest must match");
        assert_eq!(
            bits_f32(&res.log.final_params),
            bits_f32(&full.log.final_params),
            "resumed final params must be bit-identical"
        );
        assert_eq!(res.log.bits, full.log.bits, "resumed bit counters must match");
        let curve = |l: &TrainLog| -> Vec<(usize, u64)> {
            l.train_loss.iter().map(|(i, x)| (*i, x.to_bits())).collect()
        };
        assert_eq!(curve(&res.log), curve(&full.log));
        assert_eq!(res.log.evals.len(), full.log.evals.len());
        for ((ia, ma), (ib, mb)) in res.log.evals.iter().zip(&full.log.evals) {
            assert_eq!(ia, ib);
            assert_eq!(ma.loss.to_bits(), mb.loss.to_bits());
        }
        assert_eq!(res.n_late, full.n_late);
        assert_eq!(res.n_handovers, full.n_handovers);
        assert_eq!(res.n_skipped_rounds, full.n_skipped_rounds);
        assert_eq!(res.total_time_s.to_bits(), full.total_time_s.to_bits());

        // A mismatched configuration must be rejected, not silently resumed.
        let mut wrong = make_params();
        wrong.seed += 1;
        let mut oracle = make_oracle();
        assert!(
            run_des_checkpointed(&mut oracle, &cfg, &wrong, None, Some(&snap)).is_err(),
            "resuming under a different seed must error"
        );
        let _ = std::fs::remove_file(&snap);
    }

    #[test]
    fn sparse_residual_state_matches_dense_compressor_bit_exactly() {
        // The million-MU invariant: materialize-on-touch through the
        // stateless kernel reproduces the dense compressor bit for bit —
        // messages AND internal accumulators — across sparse and dense
        // configs, with and without momentum, including exact-zero and
        // sign-flipping gradient coordinates.
        let dim = 64usize;
        for (phi, momentum) in [(0.0, 0.0f32), (0.0, 0.9), (0.9, 0.0), (0.9, 0.9)] {
            let kernel = DgcKernel::new(momentum, phi);
            let mut dense = crate::sparse::DgcCompressor::new(dim, momentum, phi);
            let mut sparse = MuDgc::default();
            let mut scratch = LaneScratch::default();
            scratch.ensure_dim(dim);
            let mut msg_dense = SparseVec::empty(dim);
            let mut msg_sparse = SparseVec::empty(dim);
            let mut rng = Pcg64::new(97, (phi * 10.0) as u64 + momentum as u64);
            for step in 0..30 {
                let grad: Vec<f32> = (0..dim)
                    .map(|i| {
                        if (i + step) % 7 == 0 {
                            0.0 // exact zeros must stay off the support
                        } else {
                            rng.normal() as f32
                        }
                    })
                    .collect();
                dense.step_into(&grad, &mut msg_dense);
                scratch.grad.copy_from_slice(&grad);
                sparse.step_from_scratch(&kernel, &mut scratch, &mut msg_sparse);
                assert_eq!(
                    bits_f32(&msg_dense.values),
                    bits_f32(&msg_sparse.values),
                    "message values (phi={phi} m={momentum} step={step})"
                );
                assert_eq!(
                    msg_dense.indices, msg_sparse.indices,
                    "message support (phi={phi} m={momentum} step={step})"
                );
                // Scatter the sparse triples into dense buffers: must equal
                // the compressor's internal state exactly, and the scratch
                // lanes must be back to all-+0.0 bit patterns.
                let mut u = vec![0.0f32; dim];
                let mut v = vec![0.0f32; dim];
                for (j, &i) in sparse.indices.iter().enumerate() {
                    u[i as usize] = sparse.u[j];
                    v[i as usize] = sparse.v[j];
                }
                assert_eq!(bits_f32(&u), bits_f32(dense.momentum_buf()), "u state");
                assert_eq!(bits_f32(&v), bits_f32(dense.residual()), "v state");
                assert!(scratch.u.iter().all(|x| x.to_bits() == 0), "lane u not re-zeroed");
                assert!(scratch.v.iter().all(|x| x.to_bits() == 0), "lane v not re-zeroed");
                assert!(
                    sparse.indices.windows(2).all(|w| w[0] < w[1]),
                    "support must stay strictly sorted"
                );
            }
        }
    }

    #[test]
    fn churn_skips_are_deterministic_and_thread_independent() {
        // Churn draws are keyed (seed, mu, round) on the event-loop thread,
        // so the skip record — and everything downstream of survivor
        // reweighting — must be bit-identical at any fan-out width.
        let cfg = cfg_for(2, 4);
        let run = |inner: usize| {
            let mut topts = topts_for(&cfg, 12);
            topts.inner_threads = inner;
            let params = DesParams {
                topts,
                mobility: MobilityProfile::Static,
                straggler: StragglerPolicy::WaitForAll,
                compute: ComputeProfile::none(),
                compute_scale: 1.0,
                seed: 606,
                churn: ChurnConfig {
                    enabled: true,
                    seed: 606,
                    drop_p: 0.3,
                    rejoin_p: 0.5,
                    energy: 0.0,
                },
            };
            let mut oracle = QuadraticOracle::new_skewed(12, 8, 0.0, 1.0, 606);
            run_des(&mut oracle, &cfg, &params).unwrap()
        };
        let seq = run(1);
        assert!(!seq.skips.is_empty(), "drop_p=0.3 over 12 rounds must skip someone");
        for inner in [2usize, 8] {
            let par = run(inner);
            assert_eq!(par.skips, seq.skips, "inner={inner}");
            assert_eq!(par.timeline, seq.timeline, "inner={inner}");
            assert_eq!(
                bits_f32(&par.log.final_params),
                bits_f32(&seq.log.final_params),
                "inner={inner}"
            );
            assert_eq!(par.log.bits, seq.log.bits, "inner={inner}");
        }
    }

    #[test]
    fn energy_budget_forces_permanent_departure() {
        // With a 3-round energy budget and no random churn, every MU
        // participates exactly 3 times and then departs for good.
        let cfg = cfg_for(2, 4);
        let iters = 10usize;
        let topts = topts_for(&cfg, iters);
        let params = DesParams {
            topts,
            mobility: MobilityProfile::Static,
            straggler: StragglerPolicy::WaitForAll,
            compute: ComputeProfile::none(),
            compute_scale: 1.0,
            seed: 17,
            churn: ChurnConfig {
                enabled: true,
                seed: 17,
                drop_p: 0.0,
                rejoin_p: 0.0,
                energy: 3.0,
            },
        };
        let mut oracle = QuadraticOracle::new_skewed(10, 8, 0.0, 1.0, 17);
        let out = run_des(&mut oracle, &cfg, &params).unwrap();
        // 8 MUs × (10 − 3) post-budget rounds all land in the skip record.
        assert_eq!(out.skips.len(), 8 * (iters - 3));
        assert!(out.skips.iter().all(|&(_, r)| r >= 3), "budget covers rounds 0..3");
    }

    #[test]
    fn disabled_churn_is_byte_identical_to_pre_churn_engine() {
        // A disabled churn config — whatever its other knobs say — must not
        // move a single bit or record a single skip.
        let cfg = cfg_for(2, 4);
        let run = |churn: ChurnConfig| {
            let topts = topts_for(&cfg, 10);
            let params = DesParams {
                topts,
                mobility: MobilityProfile::Static,
                straggler: StragglerPolicy::WaitForAll,
                compute: ComputeProfile::none(),
                compute_scale: 1.0,
                seed: 23,
                churn,
            };
            let mut oracle = QuadraticOracle::new_skewed(10, 8, 0.0, 1.0, 23);
            run_des(&mut oracle, &cfg, &params).unwrap()
        };
        let base = run(ChurnConfig::default());
        let off = run(ChurnConfig {
            enabled: false,
            seed: 1,
            drop_p: 0.9,
            rejoin_p: 0.1,
            energy: 1.0,
        });
        assert!(base.skips.is_empty());
        assert_eq!(off.skips, base.skips);
        assert_eq!(off.timeline, base.timeline);
        assert_eq!(bits_f32(&off.log.final_params), bits_f32(&base.log.final_params));
        assert_eq!(off.log.bits, base.log.bits);
    }

    #[test]
    fn adversary_changes_trajectory_deterministically_in_des() {
        // A 25% attacker population must move the trajectory, reproduce
        // bit-exactly across reruns and fan-out widths, and leave the
        // honest run untouched when disabled.
        let cfg = cfg_for(2, 4);
        let run = |enabled: bool, inner: usize| {
            let mut topts = topts_for(&cfg, 12);
            topts.inner_threads = inner;
            topts.spec.adversary = crate::adversary::AdversaryPlan {
                enabled,
                seed: 404,
                fraction: 0.25,
                scale: 10.0,
                garbage_std: 1.0,
            };
            let params = static_params(topts);
            let mut oracle = QuadraticOracle::new_skewed(12, 8, 0.0, 1.0, 404);
            run_des(&mut oracle, &cfg, &params).unwrap()
        };
        let honest = run(false, 1);
        let attacked = run(true, 1);
        assert_ne!(
            bits_f32(&honest.log.final_params),
            bits_f32(&attacked.log.final_params),
            "25% attackers must perturb the model"
        );
        // Radio timing is untouched: the attack corrupts message values,
        // not the event schedule.
        assert_eq!(honest.timeline, attacked.timeline);
        for inner in [2usize, 8] {
            let again = run(true, inner);
            assert_eq!(
                bits_f32(&again.log.final_params),
                bits_f32(&attacked.log.final_params),
                "inner={inner}"
            );
            assert_eq!(again.log.bits, attacked.log.bits, "inner={inner}");
        }
    }

    #[test]
    fn robust_rules_run_under_attack_in_des() {
        // TrimmedMean/CoordMedian must run end-to-end in the DES under an
        // active attack, stay bit-reproducible, and differ from plain Mean.
        let cfg = cfg_for(2, 4);
        let run = |rule: crate::sparse::AggRule| {
            let mut topts = topts_for(&cfg, 12);
            topts.agg = crate::sparse::AggPolicy { rule, ..Default::default() };
            topts.spec.adversary = crate::adversary::AdversaryPlan {
                enabled: true,
                seed: 505,
                fraction: 0.25,
                scale: 10.0,
                garbage_std: 1.0,
            };
            let params = static_params(topts);
            let mut oracle = QuadraticOracle::new_skewed(12, 8, 0.0, 1.0, 505);
            run_des(&mut oracle, &cfg, &params).unwrap()
        };
        let mean = run(crate::sparse::AggRule::Mean);
        for rule in
            [crate::sparse::AggRule::TrimmedMean(1), crate::sparse::AggRule::CoordMedian]
        {
            let robust = run(rule);
            let robust2 = run(rule);
            assert_eq!(
                bits_f32(&robust.log.final_params),
                bits_f32(&robust2.log.final_params),
                "{rule:?} must be reproducible"
            );
            assert_ne!(
                bits_f32(&robust.log.final_params),
                bits_f32(&mean.log.final_params),
                "{rule:?} must actually change the aggregate under attack"
            );
        }
    }
}
