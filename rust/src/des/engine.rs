//! The deterministic discrete-event engine: simulates the full HCN
//! timeline — per-MU gradient compute, uplink transmission priced by the
//! `wireless` link model, SBS intra-cluster aggregation with straggler
//! policies, and the H-periodic MBS global sync — while executing exactly
//! the arithmetic of the sequential reference engine
//! ([`crate::fl::run_hierarchical`]).
//!
//! ## Determinism contract
//!
//! The run is a pure function of `(config, TrainOptions, DesParams)`:
//!
//! * the event queue orders by `(time, seq)` with a deterministic insertion
//!   counter, so simultaneous events never race;
//! * every MU owns private `Pcg64` streams (compute jitter, mobility) keyed
//!   by `(seed, entity id)` — nothing is shared or order-dependent;
//! * all floating-point reductions happen at fixed program points in fixed
//!   (cluster-id, MU-id) order, never in event-arrival order.
//!
//! ## Equivalence to the sequential engine
//!
//! In the static, wait-for-all configuration with a deterministic oracle
//! (`grad_noise = 0`, the matrix default) the DES executes the *identical*
//! f32/f64 operation sequence as `run_hierarchical`: final parameters, the
//! per-iteration loss curve, and the per-link bit totals are bit-exact, and
//! the simulated wall-clock per iteration equals the analytic
//! [`crate::wireless::hfl_latency`] / [`crate::wireless::fl_latency`] value
//! (within f64 accumulation noise ≪ 1e-6 relative) — asserted by
//! `rust/tests/des_golden.rs`. Evaluation points additionally coincide when
//! `eval_every` is a multiple of `H` (clusters are only time-aligned at
//! sync barriers).
//!
//! With mobility, deadlines, or nonzero compute profiles the timeline
//! departs from the closed form — that is the point of the subsystem — but
//! stays bit-reproducible across reruns and thread counts.

use crate::config::Config;
use crate::des::events::{EventKind, EventQueue, TimelineRecorder};
use crate::des::mobility::{MobilityProfile, Waypoint};
use crate::des::straggler::{ComputeProfile, StragglerPolicy};
use crate::fl::{consensus_params, GradOracle, LrSchedule, TrainLog, TrainOptions};
use crate::sim::result::TimelineDigest;
use crate::sparse::{DgcCompressor, DiscountedError, SparseVec};
use crate::topology::{HexLayout, NetworkTopology};
use crate::util::rng::Pcg64;
use crate::wireless::broadcast::{broadcast_latency, BroadcastParams};
use crate::wireless::latency::payload_bits;
use crate::wireless::{allocate_subcarriers, LinkParams};
use anyhow::{bail, Result};
use std::collections::BTreeSet;

/// Execution parameters of one DES run, beyond the shared [`TrainOptions`].
#[derive(Clone, Debug)]
pub struct DesParams {
    pub topts: TrainOptions,
    pub mobility: MobilityProfile,
    pub straggler: StragglerPolicy,
    pub compute: ComputeProfile,
    /// Multiplies every MU's mean compute time (the legacy channel-profile
    /// straggler factor of [`crate::sim::matrix::ChannelProfile`]).
    pub compute_scale: f64,
    /// Seed of the per-entity compute/mobility streams.
    pub seed: u64,
}

/// Everything a DES run produces.
#[derive(Clone, Debug)]
pub struct DesOutcome {
    /// Training log in the sequential engine's schema.
    pub log: TrainLog,
    /// Simulated wall-clock of the whole run (s).
    pub total_time_s: f64,
    /// `total_time_s / iters` — comparable to the analytic per-iteration
    /// latency in the static wait-for-all configuration.
    pub per_iter_s: f64,
    /// Fingerprint of the processed event stream.
    pub timeline: TimelineDigest,
    pub n_handovers: u64,
    /// Messages that arrived after their round's deadline.
    pub n_late: u64,
    /// MU-rounds skipped because the MU was still transmitting.
    pub n_skipped_rounds: u64,
}

/// Link-latency pricing of the current topology snapshot, mirroring the
/// analytic model line by line (`wireless::fl_latency` / `hfl_latency`) so
/// the static timeline reproduces it exactly.
struct Pricing {
    /// Per-MU uplink transmission time of one sparse gradient (s).
    ul_time: Vec<f64>,
    /// Per-cluster SBS→MU broadcast latency of one round update (s).
    gamma_dl: Vec<f64>,
    /// SBS→MBS fronthaul per sync (s).
    theta_ul: f64,
    /// MBS→SBS fronthaul per sync (s).
    theta_dl: f64,
    /// Worst-cluster final model broadcast per sync (s).
    max_final_dl: f64,
}

fn mu_link(cfg: &Config, dist: f64) -> LinkParams {
    let r = &cfg.radio;
    LinkParams {
        p_max_w: r.mu_power_w,
        dist_m: dist,
        alpha: r.pathloss_exp,
        noise_w: r.noise_power_w(),
        b0_hz: r.subcarrier_spacing_hz,
        ber: r.ber,
    }
}

fn price(
    cfg: &Config,
    members: &[Vec<usize>],
    dist_sbs: &[f64],
    dist_mbs: &[f64],
    m_cluster: usize,
    flat: bool,
) -> Result<Pricing> {
    let k_total = dist_sbs.len();
    let n_clusters = members.len();
    let mut p = Pricing {
        ul_time: vec![0.0; k_total],
        gamma_dl: vec![0.0; n_clusters],
        theta_ul: 0.0,
        theta_dl: 0.0,
        max_final_dl: 0.0,
    };
    if k_total <= 1 {
        // A single MU transmits nothing (same convention as the matrix
        // engine's analytic pricing).
        return Ok(p);
    }
    let q = cfg.latency.q_params;
    let qb = cfg.latency.bits_per_param;
    let s = &cfg.sparsity;
    let (phi_ul, phi_sdl, phi_mdl, phi_sul) = if s.enabled {
        (s.phi_mu_ul, s.phi_sbs_dl, s.phi_mbs_dl, s.phi_sbs_ul)
    } else {
        (0.0, 0.0, 0.0, 0.0)
    };
    let ul_bits = payload_bits(q, qb, phi_ul);

    if flat {
        if cfg.radio.subcarriers < k_total {
            bail!(
                "flat uplink needs ≥1 sub-carrier per MU ({k_total} MUs, {} sub-carriers)",
                cfg.radio.subcarriers
            );
        }
        let links: Vec<LinkParams> = dist_mbs.iter().map(|&d| mu_link(cfg, d)).collect();
        let alloc = allocate_subcarriers(&links, cfg.radio.subcarriers);
        for (k, rate) in alloc.rates.iter().enumerate() {
            p.ul_time[k] = ul_bits / rate;
        }
        let bp = BroadcastParams {
            p_total_w: cfg.radio.mbs_power_w,
            m_subcarriers: cfg.radio.subcarriers,
            noise_w: cfg.radio.noise_power_w(),
            b0_hz: cfg.radio.subcarrier_spacing_hz,
            alpha: cfg.radio.pathloss_exp,
            dists_m: dist_mbs.to_vec(),
            slot_s: cfg.radio.broadcast_slot_s,
        };
        p.gamma_dl[0] = broadcast_latency(&bp, payload_bits(q, qb, phi_mdl));
        p.max_final_dl = p.gamma_dl[0];
        return Ok(p);
    }

    let dl_bits = payload_bits(q, qb, phi_sdl);
    let mut rate_sum = 0.0;
    let mut rate_count = 0usize;
    for (c, mems) in members.iter().enumerate() {
        if mems.is_empty() {
            continue; // mobility emptied this cluster: nothing to price
        }
        let dists: Vec<f64> = mems.iter().map(|&k| dist_sbs[k]).collect();
        let links: Vec<LinkParams> = dists.iter().map(|&d| mu_link(cfg, d)).collect();
        let alloc = allocate_subcarriers(&links, m_cluster.max(links.len()));
        for (j, &k) in mems.iter().enumerate() {
            p.ul_time[k] = ul_bits / alloc.rates[j];
        }
        rate_sum += alloc.rates.iter().sum::<f64>();
        rate_count += alloc.rates.len();
        let bp = BroadcastParams {
            p_total_w: cfg.radio.sbs_power_w,
            m_subcarriers: m_cluster,
            noise_w: cfg.radio.noise_power_w(),
            b0_hz: cfg.radio.subcarrier_spacing_hz,
            alpha: cfg.radio.pathloss_exp,
            dists_m: dists,
            slot_s: cfg.radio.broadcast_slot_s,
        };
        p.gamma_dl[c] = broadcast_latency(&bp, dl_bits);
    }
    if rate_count > 0 {
        let fronthaul_rate = cfg.radio.fronthaul_multiplier * (rate_sum / rate_count as f64);
        p.theta_ul = payload_bits(q, qb, phi_sul) / fronthaul_rate;
        p.theta_dl = payload_bits(q, qb, phi_mdl) / fronthaul_rate;
    }
    p.max_final_dl = p.gamma_dl.iter().cloned().fold(0.0, f64::max);
    Ok(p)
}

/// Per-cluster round bookkeeping.
struct RoundCtx {
    round: usize,
    aggregated: bool,
    /// MUs computing this round (sorted by id).
    participants: Vec<usize>,
    /// Participants whose uplink landed before aggregation.
    fresh: BTreeSet<usize>,
    /// Participants whose uplink has not landed yet.
    awaiting: usize,
    done: bool,
}

struct Sim<'a, O: GradOracle + ?Sized> {
    oracle: &'a mut O,
    topts: &'a TrainOptions,
    cfg: &'a Config,
    params: &'a DesParams,
    n: usize,
    k_total: usize,
    dim: usize,
    h: usize,
    flat: bool,
    // Geometry / membership.
    layout: HexLayout,
    m_cluster: usize,
    dist_sbs: Vec<f64>,
    dist_mbs: Vec<f64>,
    mu_cluster: Vec<usize>,
    members: Vec<Vec<usize>>,
    walkers: Vec<Option<Waypoint>>,
    // Timing.
    pricing: Pricing,
    mu_mean_comp: Vec<f64>,
    comp_rng: Vec<Pcg64>,
    busy_until: Vec<f64>,
    // Training state (mirrors `run_hierarchical`).
    schedule: LrSchedule,
    dgc: Vec<DgcCompressor>,
    w_tilde: Vec<Vec<f32>>,
    dl_enc: Vec<DiscountedError>,
    ul_enc: Vec<DiscountedError>,
    w_tilde_global: Vec<f32>,
    mbs_enc: DiscountedError,
    /// Per-cluster stale messages `(msg, weight, arrives_at)` awaiting a
    /// later aggregation. An entry is only applied once the simulated clock
    /// has passed `arrives_at` — a late update cannot land before its
    /// transmission physically completes.
    stale: Vec<Vec<(SparseVec, f32, f64)>>,
    // Bookkeeping.
    ctx: Vec<RoundCtx>,
    /// Raw per-(round, MU) losses; folded in global MU order when the
    /// iteration completes so the loss curve matches the sequential engine
    /// bit-for-bit in the static wait-for-all configuration.
    round_loss: Vec<f64>,
    clusters_done_at: Vec<usize>,
    queue: EventQueue,
    rec: TimelineRecorder,
    log: TrainLog,
    grad: Vec<f32>,
    agg: Vec<f32>,
    msg: SparseVec,
    n_handovers: u64,
    n_late: u64,
    n_skipped: u64,
    finish_time: f64,
}

impl<O: GradOracle + ?Sized> Sim<'_, O> {
    fn eval_due(&self, round: usize) -> bool {
        self.topts.eval_every > 0 && (round + 1) % self.topts.eval_every == 0
    }

    fn push_eval(&mut self, round: usize) {
        let consensus = consensus_params(&self.w_tilde);
        let m = self.oracle.eval(&consensus);
        self.log.evals.push((round + 1, m));
    }

    fn start_round(&mut self, c: usize, round: usize, t: f64) {
        let mut participants = Vec::new();
        for &mu in &self.members[c] {
            if self.busy_until[mu] <= t {
                participants.push(mu);
            } else {
                self.n_skipped += 1;
            }
        }
        let awaiting = participants.len();
        self.ctx[c] = RoundCtx {
            round,
            aggregated: false,
            participants,
            fresh: BTreeSet::new(),
            awaiting,
            done: false,
        };
        if awaiting == 0 {
            // Nothing computes this round (empty or fully-busy cluster):
            // aggregate whatever stale mass has arrived and move on.
            self.aggregate(c, t);
            self.queue
                .push(t + self.pricing.gamma_dl[c], EventKind::RoundEnd { cluster: c, round });
            return;
        }
        let parts = self.ctx[c].participants.clone();
        let mut expected_worst = 0.0f64;
        for &mu in &parts {
            let comp = self
                .params
                .compute
                .sample_round(self.mu_mean_comp[mu], &mut self.comp_rng[mu]);
            self.busy_until[mu] = t + comp + self.pricing.ul_time[mu];
            self.queue
                .push(t + comp, EventKind::ComputeDone { mu, cluster: c, round });
            expected_worst =
                expected_worst.max(self.mu_mean_comp[mu] + self.pricing.ul_time[mu]);
        }
        if let StragglerPolicy::Deadline { rel, .. } = &self.params.straggler {
            let d = rel * expected_worst;
            if d > 0.0 {
                self.queue.push(t + d, EventKind::Deadline { cluster: c, round });
            }
        }
    }

    /// Execute the cluster's round arithmetic (identical to one iteration of
    /// the sequential engine's inner loop) at the aggregation instant `t`.
    fn aggregate(&mut self, c: usize, t: f64) {
        let (round, parts) = {
            let ctx = &mut self.ctx[c];
            ctx.aggregated = true;
            (ctx.round, ctx.participants.clone())
        };
        let denom = parts.len() as f32;
        let stale_discount = match &self.params.straggler {
            StragglerPolicy::Deadline { stale_discount, .. } => *stale_discount,
            StragglerPolicy::WaitForAll => 0.0,
        };
        self.agg.iter_mut().for_each(|x| *x = 0.0);
        // Stale updates whose transmission has landed by now apply first,
        // pre-discounted; ones still in flight go back in the queue (their
        // original order preserved) for a later aggregation.
        let pending = std::mem::take(&mut self.stale[c]);
        for (m, w, arrives_at) in pending {
            if arrives_at <= t {
                m.add_into(&mut self.agg, w);
            } else {
                self.stale[c].push((m, w, arrives_at));
            }
        }
        // Fresh computation + uplink, in MU-id order — never arrival order.
        for &mu in &parts {
            let loss = self
                .oracle
                .loss_grad(mu, &self.w_tilde[c], &mut self.grad);
            self.round_loss[round * self.k_total + mu] = loss;
            if self.topts.weight_decay != 0.0 {
                for i in 0..self.dim {
                    self.grad[i] += self.topts.weight_decay * self.w_tilde[c][i];
                }
            }
            self.dgc[mu].step_into(&self.grad, &mut self.msg);
            self.log.bits.mu_ul += self.msg.wire_bits(32);
            self.log.bits.n_mu_msgs += 1;
            if self.ctx[c].fresh.contains(&mu) {
                self.msg.add_into(&mut self.agg, 1.0 / denom);
            } else {
                // Missed the deadline: the bits were still spent; the
                // update arrives stale once its uplink completes (or is
                // discarded when the discount is zero).
                self.n_late += 1;
                if stale_discount > 0.0 {
                    self.stale[c].push((
                        self.msg.clone(),
                        stale_discount / denom,
                        self.busy_until[mu],
                    ));
                }
            }
        }
        let lr = self.schedule.at(round) as f32;
        for x in self.agg.iter_mut() {
            *x *= -lr;
        }
        let dl_msg = self.dl_enc[c].compress(&self.agg);
        self.log.bits.sbs_dl += dl_msg.wire_bits(32);
        dl_msg.add_into(&mut self.w_tilde[c], 1.0);
    }

    /// Fold the completed iteration's per-MU losses in global MU order —
    /// the sequential engine's exact summation order.
    fn fold_iteration_loss(&mut self, round: usize) {
        let mut iter_loss = 0.0f64;
        for mu in 0..self.k_total {
            let v = self.round_loss[round * self.k_total + mu];
            if !v.is_nan() {
                iter_loss += v / self.k_total as f64;
            }
        }
        self.log.train_loss.push((round, iter_loss));
    }

    /// The H-periodic global sync: identical arithmetic to the sequential
    /// engine's sync block, then fronthaul + final broadcast pricing.
    fn do_sync(&mut self, round: usize, t: f64) {
        self.agg.iter_mut().for_each(|x| *x = 0.0);
        for c in 0..self.n {
            let e_dl = self.dl_enc[c].error().to_vec();
            let delta: Vec<f32> = (0..self.dim)
                .map(|i| self.w_tilde[c][i] + e_dl[i] - self.w_tilde_global[i])
                .collect();
            let ul_msg = self.ul_enc[c].compress(&delta);
            self.log.bits.sbs_ul += ul_msg.wire_bits(32);
            ul_msg.add_into(&mut self.agg, 1.0 / self.n as f32);
        }
        let mbs_msg = self.mbs_enc.compress(&self.agg);
        self.log.bits.mbs_dl += mbs_msg.wire_bits(32);
        mbs_msg.add_into(&mut self.w_tilde_global, 1.0);
        for c in 0..self.n {
            let delta: Vec<f32> = (0..self.dim)
                .map(|i| self.w_tilde_global[i] - self.w_tilde[c][i])
                .collect();
            let dl_msg = self.dl_enc[c].compress(&delta);
            self.log.bits.sbs_dl += dl_msg.wire_bits(32);
            dl_msg.add_into(&mut self.w_tilde[c], 1.0);
        }
        // Clusters resume together once the slowest final broadcast lands.
        let t_resume =
            t + self.pricing.theta_ul + self.pricing.theta_dl + self.pricing.max_final_dl;
        self.queue
            .push(t_resume, EventKind::GlobalSync { period: (round + 1) / self.h });
    }

    /// Move the MUs to their positions at time `t`, re-associate to the
    /// nearest SBS, and reprice every link. Called when all clusters are
    /// time-aligned: at sync boundaries, or at every round end for flat
    /// (single-cluster) topologies that never sync.
    fn update_mobility(&mut self, t: f64) -> Result<()> {
        if self.params.mobility.is_static() {
            return Ok(());
        }
        for k in 0..self.k_total {
            let pos = match self.walkers[k].as_mut() {
                Some(w) => w.position_at(t),
                None => continue,
            };
            self.dist_mbs[k] = pos.norm().max(1.0);
            let nearest = self.layout.nearest_center(&pos);
            if nearest != self.mu_cluster[k] {
                self.n_handovers += 1;
                self.rec.record_kind(
                    t,
                    &EventKind::Handover { mu: k, from: self.mu_cluster[k], to: nearest },
                );
                self.mu_cluster[k] = nearest;
            }
            self.dist_sbs[k] = pos.dist(&self.layout.centers[self.mu_cluster[k]]).max(1.0);
        }
        for m in self.members.iter_mut() {
            m.clear();
        }
        for k in 0..self.k_total {
            self.members[self.mu_cluster[k]].push(k);
        }
        self.pricing = price(
            self.cfg,
            &self.members,
            &self.dist_sbs,
            &self.dist_mbs,
            self.m_cluster,
            self.flat,
        )?;
        Ok(())
    }

    fn run(&mut self) -> Result<()> {
        let iters = self.topts.iters;
        for c in 0..self.n {
            self.start_round(c, 0, 0.0);
        }
        // Generous upper bound on legitimate events; a breach means a
        // scheduling bug, reported as an error rather than a hang.
        let cap = 64
            + (iters as u64 + 2) * (4 * self.k_total as u64 + 4 * self.n as u64 + 8);
        let mut processed = 0u64;
        while let Some(ev) = self.queue.pop() {
            self.rec.record(&ev);
            processed += 1;
            if processed > cap {
                bail!("DES event cap exceeded ({cap}): the scheduler is looping");
            }
            match ev.kind {
                EventKind::ComputeDone { mu, cluster, round } => {
                    self.queue.push(
                        self.busy_until[mu],
                        EventKind::UplinkDone { mu, cluster, round },
                    );
                }
                EventKind::UplinkDone { mu, cluster, round } => {
                    let ready = {
                        let ctx = &mut self.ctx[cluster];
                        if ctx.round == round && !ctx.aggregated {
                            ctx.fresh.insert(mu);
                            ctx.awaiting -= 1;
                            ctx.awaiting == 0
                        } else {
                            false // late arrival — charged at aggregation
                        }
                    };
                    if ready {
                        self.aggregate(cluster, ev.time);
                        self.queue.push(
                            ev.time + self.pricing.gamma_dl[cluster],
                            EventKind::RoundEnd { cluster, round },
                        );
                    }
                }
                EventKind::Deadline { cluster, round } => {
                    let fire = {
                        let ctx = &self.ctx[cluster];
                        ctx.round == round && !ctx.aggregated
                    };
                    if fire {
                        self.aggregate(cluster, ev.time);
                        self.queue.push(
                            ev.time + self.pricing.gamma_dl[cluster],
                            EventKind::RoundEnd { cluster, round },
                        );
                    }
                }
                EventKind::RoundEnd { cluster, round } => {
                    self.clusters_done_at[round] += 1;
                    let complete = self.clusters_done_at[round] == self.n;
                    if complete {
                        self.fold_iteration_loss(round);
                    }
                    let sync_due = self.n > 1 && (round + 1) % self.h == 0;
                    if sync_due {
                        // Barrier: the last cluster to finish triggers the
                        // sync at the barrier instant.
                        if complete {
                            self.do_sync(round, ev.time);
                        }
                    } else {
                        if complete && self.eval_due(round) {
                            self.push_eval(round);
                        }
                        if round + 1 < self.topts.iters {
                            if self.flat {
                                // Flat topologies have no sync barriers, but
                                // their single cluster is time-aligned at
                                // every round end — move/reprice here.
                                self.update_mobility(ev.time)?;
                            }
                            self.start_round(cluster, round + 1, ev.time);
                        } else {
                            self.ctx[cluster].done = true;
                            self.finish_time = self.finish_time.max(ev.time);
                        }
                    }
                }
                EventKind::GlobalSync { period } => {
                    let round = period * self.h - 1;
                    self.update_mobility(ev.time)?;
                    if self.eval_due(round) {
                        self.push_eval(round);
                    }
                    for c in 0..self.n {
                        if round + 1 < self.topts.iters {
                            self.start_round(c, round + 1, ev.time);
                        } else {
                            self.ctx[c].done = true;
                            self.finish_time = self.finish_time.max(ev.time);
                        }
                    }
                }
                EventKind::Handover { .. } => {
                    // Handovers are digest records, never queued.
                    bail!("handover events must not enter the queue");
                }
            }
        }
        if self.ctx.iter().any(|c| !c.done) {
            bail!("DES queue drained with unfinished clusters — scheduling bug");
        }
        Ok(())
    }
}

/// Run the discrete-event simulation. See the module docs for the
/// determinism and sequential-equivalence contracts.
pub fn run_des<O: GradOracle + ?Sized>(
    oracle: &mut O,
    cfg: &Config,
    params: &DesParams,
) -> Result<DesOutcome> {
    let topts = &params.topts;
    let n = topts.n_clusters;
    let k_total = oracle.n_workers();
    let dim = oracle.dim();
    if topts.iters == 0 {
        bail!("DES needs at least one iteration");
    }
    if n < 1 || k_total < n {
        bail!("need ≥1 worker per cluster ({k_total} workers, {n} clusters)");
    }
    if k_total % n != 0 {
        bail!("workers ({k_total}) must divide evenly into clusters ({n}) — Assumption 1");
    }
    if topts.h_period == 0 {
        bail!("h_period must be ≥ 1");
    }
    if cfg.topology.n_clusters != n || cfg.topology.total_mus() != k_total {
        bail!(
            "topology config ({} clusters × {} MUs) disagrees with the oracle/TrainOptions \
             ({n} clusters, {k_total} workers)",
            cfg.topology.n_clusters,
            cfg.topology.mus_per_cluster
        );
    }

    let topo = NetworkTopology::generate(&cfg.topology);
    let flat = n == 1;
    let m_cluster = topo.layout.subcarriers_per_cluster(cfg.radio.subcarriers);
    let dist_sbs: Vec<f64> = topo.users.iter().map(|u| u.dist_sbs).collect();
    let dist_mbs: Vec<f64> = topo.users.iter().map(|u| u.dist_mbs).collect();
    let mu_cluster: Vec<usize> = topo.users.iter().map(|u| u.cluster).collect();
    let mut members: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (k, &c) in mu_cluster.iter().enumerate() {
        members[c].push(k);
    }

    // Per-entity streams: compute heterogeneity, per-round jitter, mobility.
    let mut mu_mean_comp = Vec::with_capacity(k_total);
    let mut comp_rng = Vec::with_capacity(k_total);
    let mut walkers: Vec<Option<Waypoint>> = Vec::with_capacity(k_total);
    for k in 0..k_total {
        let mut het_stream = Pcg64::new(params.seed, 0x1000_0000 + k as u64);
        mu_mean_comp.push(params.compute.mu_mean(&mut het_stream) * params.compute_scale);
        comp_rng.push(Pcg64::new(params.seed, 0x2000_0000 + k as u64));
        walkers.push(match &params.mobility {
            MobilityProfile::Static => None,
            MobilityProfile::Waypoint { speed_mps, pause_s } => Some(Waypoint::new(
                topo.users[k].pos,
                *speed_mps,
                *pause_s,
                cfg.topology.radius_m,
                Pcg64::new(params.seed, 0x3000_0000 + k as u64),
            )),
        });
    }

    // Training state — constructed in the sequential engine's exact order.
    let (phi_ul, phi_sdl, phi_sul, phi_mdl) = if topts.sparsity.enabled {
        (
            topts.sparsity.phi_mu_ul,
            topts.sparsity.phi_sbs_dl,
            topts.sparsity.phi_sbs_ul,
            topts.sparsity.phi_mbs_dl,
        )
    } else {
        (0.0, 0.0, 0.0, 0.0)
    };
    let (cluster_dl_phi, cluster_dl_beta) = if n == 1 {
        (phi_mdl, topts.sparsity.beta_m)
    } else {
        (phi_sdl, topts.sparsity.beta_s)
    };
    let schedule = LrSchedule::new(
        topts.peak_lr,
        topts.warmup_iters,
        topts.iters,
        topts.milestones,
    );
    let dgc: Vec<DgcCompressor> = (0..k_total)
        .map(|_| DgcCompressor::new(dim, topts.momentum, phi_ul))
        .collect();
    let init = oracle.init_params();
    let w_tilde: Vec<Vec<f32>> = vec![init.clone(); n];
    let dl_enc: Vec<DiscountedError> = (0..n)
        .map(|_| DiscountedError::new(dim, cluster_dl_phi, cluster_dl_beta as f32))
        .collect();
    let ul_enc: Vec<DiscountedError> = (0..n)
        .map(|_| DiscountedError::new(dim, phi_sul, topts.sparsity.beta_s as f32))
        .collect();
    let mbs_enc = DiscountedError::new(dim, phi_mdl, topts.sparsity.beta_m as f32);

    let pricing = price(cfg, &members, &dist_sbs, &dist_mbs, m_cluster, flat)?;
    let ctx: Vec<RoundCtx> = (0..n)
        .map(|_| RoundCtx {
            round: 0,
            aggregated: true,
            participants: Vec::new(),
            fresh: BTreeSet::new(),
            awaiting: 0,
            done: false,
        })
        .collect();

    let mut sim = Sim {
        oracle,
        topts,
        cfg,
        params,
        n,
        k_total,
        dim,
        h: topts.h_period,
        flat,
        layout: topo.layout.clone(),
        m_cluster,
        dist_sbs,
        dist_mbs,
        mu_cluster,
        members,
        walkers,
        pricing,
        mu_mean_comp,
        comp_rng,
        busy_until: vec![0.0; k_total],
        schedule,
        dgc,
        w_tilde,
        dl_enc,
        ul_enc,
        w_tilde_global: init,
        mbs_enc,
        stale: vec![Vec::new(); n],
        ctx,
        round_loss: vec![f64::NAN; topts.iters * k_total],
        clusters_done_at: vec![0; topts.iters],
        queue: EventQueue::new(),
        rec: TimelineRecorder::new(),
        log: TrainLog::default(),
        grad: vec![0.0; dim],
        agg: vec![0.0; dim],
        msg: SparseVec::empty(dim),
        n_handovers: 0,
        n_late: 0,
        n_skipped: 0,
        finish_time: 0.0,
    };
    sim.run()?;

    // Final consensus + eval, exactly like the sequential engine.
    let consensus = consensus_params(&sim.w_tilde);
    let m = sim.oracle.eval(&consensus);
    sim.log.evals.push((topts.iters, m));
    sim.log.final_params = consensus;

    let total = sim.finish_time;
    Ok(DesOutcome {
        per_iter_s: total / topts.iters as f64,
        total_time_s: total,
        timeline: sim.rec.digest(),
        n_handovers: sim.n_handovers,
        n_late: sim.n_late,
        n_skipped_rounds: sim.n_skipped,
        log: sim.log,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SparsityConfig;
    use crate::fl::{run_hierarchical, QuadraticOracle};

    fn cfg_for(n: usize, mus: usize) -> Config {
        let mut c = Config::smoke();
        c.topology.n_clusters = n;
        c.topology.mus_per_cluster = mus;
        c.topology.reuse_colors = c.topology.reuse_colors.min(n);
        c.training.h_period = 2;
        c.sparsity.enabled = true;
        c.sparsity.phi_mu_ul = 0.9;
        c
    }

    fn topts_for(cfg: &Config, iters: usize) -> TrainOptions {
        TrainOptions {
            iters,
            peak_lr: 0.05,
            warmup_iters: 3,
            milestones: (0.6, 0.85),
            momentum: 0.9,
            weight_decay: 0.0,
            h_period: cfg.training.h_period,
            n_clusters: cfg.topology.n_clusters,
            sparsity: cfg.sparsity.clone(),
            eval_every: 10,
        }
    }

    fn static_params(topts: TrainOptions) -> DesParams {
        DesParams {
            topts,
            mobility: MobilityProfile::Static,
            straggler: StragglerPolicy::WaitForAll,
            compute: ComputeProfile::none(),
            compute_scale: 1.0,
            seed: 99,
        }
    }

    fn bits_f32(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn static_waitall_matches_sequential_engine_bit_exactly() {
        let cfg = cfg_for(2, 4);
        let topts = topts_for(&cfg, 20);
        let mut des_oracle = QuadraticOracle::new_skewed(16, 8, 0.0, 1.0, 4242);
        let out = run_des(&mut des_oracle, &cfg, &static_params(topts.clone())).unwrap();
        let mut seq_oracle = QuadraticOracle::new_skewed(16, 8, 0.0, 1.0, 4242);
        let seq = run_hierarchical(&mut seq_oracle, &topts);
        assert_eq!(
            bits_f32(&out.log.final_params),
            bits_f32(&seq.final_params),
            "final params must be bit-identical"
        );
        assert_eq!(out.log.bits, seq.bits, "per-link bits must agree");
        // The loss curve folds in the sequential engine's exact order.
        let curve_bits = |c: &[(usize, f64)]| -> Vec<(usize, u64)> {
            c.iter().map(|(i, x)| (*i, x.to_bits())).collect()
        };
        assert_eq!(curve_bits(&out.log.train_loss), curve_bits(&seq.train_loss));
        // Evals land on sync boundaries (eval_every % H == 0) — identical.
        assert_eq!(out.log.evals.len(), seq.evals.len());
        for ((ia, ma), (ib, mb)) in out.log.evals.iter().zip(&seq.evals) {
            assert_eq!(ia, ib);
            assert_eq!(ma.loss.to_bits(), mb.loss.to_bits());
        }
        assert_eq!(out.n_late, 0);
        assert_eq!(out.n_handovers, 0);
        assert_eq!(out.n_skipped_rounds, 0);
    }

    #[test]
    fn static_waitall_matches_analytic_hfl_latency() {
        let cfg = cfg_for(4, 4);
        let topts = topts_for(&cfg, 8); // multiple of H = 2
        let mut oracle = QuadraticOracle::new_skewed(8, 16, 0.0, 1.0, 7);
        let out = run_des(&mut oracle, &cfg, &static_params(topts)).unwrap();
        let analytic = crate::sim::price_latency(&cfg, false);
        let rel = (out.per_iter_s - analytic).abs() / analytic;
        assert!(
            rel < 1e-6,
            "DES per-iter {} vs analytic {analytic} (rel {rel})",
            out.per_iter_s
        );
    }

    #[test]
    fn flat_static_matches_analytic_fl_latency() {
        let cfg = cfg_for(1, 4);
        let topts = topts_for(&cfg, 6);
        let mut oracle = QuadraticOracle::new_skewed(8, 4, 0.0, 1.0, 8);
        let out = run_des(&mut oracle, &cfg, &static_params(topts)).unwrap();
        let analytic = crate::sim::price_latency(&cfg, true);
        let rel = (out.per_iter_s - analytic).abs() / analytic;
        assert!(
            rel < 1e-6,
            "flat DES per-iter {} vs analytic {analytic} (rel {rel})",
            out.per_iter_s
        );
    }

    #[test]
    fn rerun_with_same_seed_is_bit_identical() {
        let cfg = cfg_for(2, 4);
        let run = || {
            let topts = topts_for(&cfg, 12);
            let params = DesParams {
                topts,
                mobility: MobilityProfile::Waypoint { speed_mps: 30.0, pause_s: 1.0 },
                straggler: StragglerPolicy::Deadline { rel: 0.9, stale_discount: 0.5 },
                compute: ComputeProfile { mean_s: 0.5, het: 0.5 },
                compute_scale: 1.0,
                seed: 1234,
            };
            let mut oracle = QuadraticOracle::new_skewed(12, 8, 0.0, 1.0, 55);
            run_des(&mut oracle, &cfg, &params).unwrap()
        };
        let a = run();
        let b = run();
        assert_eq!(a.timeline, b.timeline, "timeline digest must be reproducible");
        assert_eq!(bits_f32(&a.log.final_params), bits_f32(&b.log.final_params));
        assert_eq!(a.total_time_s.to_bits(), b.total_time_s.to_bits());
        assert_eq!(a.n_late, b.n_late);
        assert_eq!(a.n_handovers, b.n_handovers);
        // A different seed produces a different timeline.
        let topts = topts_for(&cfg, 12);
        let params = DesParams {
            seed: 1235,
            ..DesParams {
                topts,
                mobility: MobilityProfile::Waypoint { speed_mps: 30.0, pause_s: 1.0 },
                straggler: StragglerPolicy::Deadline { rel: 0.9, stale_discount: 0.5 },
                compute: ComputeProfile { mean_s: 0.5, het: 0.5 },
                compute_scale: 1.0,
                seed: 0,
            }
        };
        let mut oracle = QuadraticOracle::new_skewed(12, 8, 0.0, 1.0, 55);
        let c = run_des(&mut oracle, &cfg, &params).unwrap();
        assert_ne!(a.timeline.digest, c.timeline.digest);
    }

    #[test]
    fn fast_waypoint_mobility_triggers_handovers() {
        let cfg = cfg_for(4, 2);
        let topts = topts_for(&cfg, 8);
        let params = DesParams {
            topts,
            mobility: MobilityProfile::Waypoint { speed_mps: 400.0, pause_s: 0.5 },
            straggler: StragglerPolicy::WaitForAll,
            compute: ComputeProfile::none(),
            compute_scale: 1.0,
            seed: 31,
        };
        let mut oracle = QuadraticOracle::new_skewed(8, 8, 0.0, 1.0, 31);
        let out = run_des(&mut oracle, &cfg, &params).unwrap();
        assert!(
            out.n_handovers > 0,
            "400 m/s walkers across 4 cells must hand over at least once"
        );
        // Mobility must not corrupt the training loop: every iteration logged.
        assert_eq!(out.log.train_loss.len(), 8);
        assert_eq!(out.log.final_params.len(), 8);
    }

    #[test]
    fn tight_deadline_produces_late_updates_and_different_params() {
        let cfg = cfg_for(2, 4);
        let run = |straggler: StragglerPolicy| {
            let topts = topts_for(&cfg, 10);
            let params = DesParams {
                topts,
                mobility: MobilityProfile::Static,
                straggler,
                compute: ComputeProfile::none(),
                compute_scale: 1.0,
                seed: 77,
            };
            let mut oracle = QuadraticOracle::new_skewed(12, 8, 0.0, 1.0, 77);
            run_des(&mut oracle, &cfg, &params).unwrap()
        };
        let waitall = run(StragglerPolicy::WaitForAll);
        let tight = run(StragglerPolicy::Deadline { rel: 0.5, stale_discount: 0.5 });
        assert!(tight.n_late > 0, "a 0.5× deadline must cut off stragglers");
        assert_ne!(
            bits_f32(&waitall.log.final_params),
            bits_f32(&tight.log.final_params),
            "stale discounting must change the training trajectory"
        );
        // The deadline round ends no later than the wait-for-all round.
        assert!(tight.total_time_s <= waitall.total_time_s + 1e-9);
    }

    #[test]
    fn loose_deadline_reproduces_waitall_arithmetic() {
        // With instantaneous compute the arrival times are deterministic,
        // so a 2× deadline never fires before the last uplink: identical
        // parameters, different timeline (the deadline events exist).
        let cfg = cfg_for(2, 4);
        let run = |straggler: StragglerPolicy| {
            let topts = topts_for(&cfg, 8);
            let params = DesParams {
                topts,
                mobility: MobilityProfile::Static,
                straggler,
                compute: ComputeProfile::none(),
                compute_scale: 1.0,
                seed: 5,
            };
            let mut oracle = QuadraticOracle::new_skewed(10, 8, 0.0, 1.0, 5);
            run_des(&mut oracle, &cfg, &params).unwrap()
        };
        let waitall = run(StragglerPolicy::WaitForAll);
        let loose = run(StragglerPolicy::Deadline { rel: 2.0, stale_discount: 0.5 });
        assert_eq!(loose.n_late, 0);
        assert_eq!(
            bits_f32(&waitall.log.final_params),
            bits_f32(&loose.log.final_params)
        );
        assert_ne!(waitall.timeline, loose.timeline, "deadline events enter the digest");
    }

    #[test]
    fn invalid_setups_are_errors_not_panics() {
        let cfg = cfg_for(2, 4);
        // Worker count not divisible by clusters.
        let mut oracle = QuadraticOracle::new_skewed(8, 7, 0.0, 1.0, 3);
        let topts = TrainOptions { n_clusters: 2, ..topts_for(&cfg, 4) };
        assert!(run_des(&mut oracle, &cfg, &static_params(topts)).is_err());
        // Topology config disagreeing with the oracle.
        let mut oracle = QuadraticOracle::new_skewed(8, 8, 0.0, 1.0, 3);
        let bad_cfg = cfg_for(4, 4);
        let topts = topts_for(&cfg, 4);
        assert!(run_des(&mut oracle, &bad_cfg, &static_params(topts)).is_err());
    }
}
