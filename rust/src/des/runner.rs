//! Glue between the scenario matrix and the discrete-event engine: builds
//! the scenario's oracle + radio config exactly like the sequential cell
//! runner (same seed derivation, same `TrainOptions` — including the
//! [`crate::pool::PoolHandle`] lease source for the per-MU fan-out — same
//! config overrides), executes [`crate::des::engine::run_des`], and emits
//! the shared [`ScenarioResult`]/[`GoldenTrace`] schema with the per-event
//! timeline digest attached.

use crate::config::Config;
use crate::des::engine::{run_des, DesOutcome, DesParams};
use crate::des::straggler::ComputeProfile;
use crate::fl::QuadraticOracle;
use crate::sim::matrix::{cell_train_options, scenario_config, MatrixOptions, MatrixScenario};
use crate::sim::result::{Engine, ScenarioMeta, ScenarioResult};
use crate::util::rng::Pcg64;
use anyhow::Result;

/// Execute one grid cell on the discrete-event engine.
///
/// The first `base_seed`-derived draw seeds the oracle — identical to the
/// sequential cell runner, so a static wait-for-all DES cell trains the
/// exact same problem as its sequential twin (the cross-validation suite
/// relies on this). The second draw seeds the DES per-entity streams.
pub fn run_des_cell(
    cfg: &Config,
    sc: &MatrixScenario,
    opts: &MatrixOptions,
) -> Result<ScenarioResult> {
    let mut stream = Pcg64::new(opts.base_seed, sc.id as u64);
    let oracle_seed = stream.next_u64();
    let des_seed = stream.next_u64();
    let workers = sc.workers();
    let mut oracle =
        QuadraticOracle::new_skewed(opts.dim, workers, opts.grad_noise, sc.skew, oracle_seed);
    let topts = cell_train_options(cfg, sc, opts);
    let scfg = scenario_config(cfg, sc);
    // The cell's churn axis (when non-default) overrides the base config's
    // drop rate and switches the gate on — mirroring how the adversary and
    // rule axes compose with the base spec in `cell_train_options`.
    let mut churn = opts.churn;
    if sc.churn_drop > 0.0 {
        churn.enabled = true;
        churn.drop_p = sc.churn_drop;
    }
    let params = DesParams {
        topts,
        mobility: sc.mobility.clone(),
        straggler: sc.straggler.clone(),
        compute: ComputeProfile {
            mean_s: opts.compute_mean_s,
            het: opts.compute_het,
        },
        compute_scale: sc.profile.straggler_factor,
        seed: des_seed,
        churn,
    };
    let outcome = run_des(&mut oracle, &scfg, &params)?;
    Ok(result_from_outcome(sc, &outcome))
}

/// Fold a [`DesOutcome`] into the shared scenario-result schema: the
/// standard `TrainLog` mapping plus the DES-only timeline digest.
pub fn result_from_outcome(sc: &MatrixScenario, out: &DesOutcome) -> ScenarioResult {
    let meta = ScenarioMeta {
        id: sc.id,
        name: sc.name.clone(),
        n_clusters: sc.n_clusters,
        workers: sc.workers(),
        h_period: sc.h_period,
        sparse: sc.phi.is_some(),
    };
    let mut result =
        ScenarioResult::from_train_log(meta, Engine::Des, out.per_iter_s, &out.log);
    result.trace.timeline = Some(out.timeline);
    result.trace.skips = crate::sim::result::SkipDigest::from_skips(&out.skips);
    result
}
