//! Deterministic **discrete-event HCN simulator**.
//!
//! Where the analytic `wireless::latency` model prices a *time-invariant*
//! round in closed form, this subsystem simulates the timeline event by
//! event — MU gradient compute (heterogeneous per-MU profiles), uplink
//! transmission timed by the `wireless::mqam`/`subcarrier` link model, SBS
//! intra-cluster aggregation, and the H-periodic MBS global sync — which
//! unlocks the scenarios where *time actually matters*:
//!
//! * **Mobility / handover** ([`mobility`]): MUs follow random-waypoint
//!   traces over the hex flower and re-associate to the nearest SBS at
//!   sync boundaries, repricing every link as they move.
//! * **Straggler policies** ([`straggler`]): wait-for-all rounds vs. a
//!   deadline cutoff with stale-update discounting.
//!
//! The arithmetic is *reused*, not reimplemented: rounds execute the exact
//! compressor/optimizer operations of [`crate::fl::run_hierarchical`]
//! (DGC uplinks, discounted-error encoders, period-H averaging), so in the
//! static wait-for-all configuration the final parameters are bit-identical
//! to the sequential engine and the simulated per-round wall clock agrees
//! with the analytic model within 1e-6 relative error (cross-validated by
//! `rust/tests/des_golden.rs`). See [`engine`] for the full determinism
//! contract and [`events`] for the `(time, seq)`-ordered queue and the
//! timeline digest that golden fixtures pin.
//!
//! Entry points: [`run_des`] (one simulation), [`run_des_cell`] (one
//! scenario-matrix grid cell → shared [`crate::sim::result`] schema), and
//! the `hfl des` CLI subcommand (quick/full DES scenario grids).

pub mod engine;
pub mod events;
pub mod mobility;
pub mod runner;
pub mod straggler;

pub use engine::{run_des, run_des_checkpointed, DesOutcome, DesParams};
pub use events::{Event, EventKind, EventQueue, TimelineRecorder};
pub use mobility::{MobilityProfile, Waypoint};
pub use runner::run_des_cell;
pub use straggler::{ComputeProfile, StragglerPolicy};
