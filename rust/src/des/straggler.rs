//! Straggler policies and heterogeneous compute-time profiles for the
//! discrete-event engine.
//!
//! A cluster round ends when its SBS aggregates. Under
//! [`StragglerPolicy::WaitForAll`] that is when the last member's uplink
//! lands (the paper's synchronous model — the slowest MU holds the round).
//! Under [`StragglerPolicy::Deadline`] the SBS aggregates at
//! `rel ×` the round's *expected* slowest member time (mean compute +
//! uplink, known at round start); updates that land later are **stale**:
//! they are folded into the first aggregation *after their transmission
//! completes*, scaled by `stale_discount` (0 ⇒ discarded), and the late MU
//! skips rounds until its transmission finishes. Every transmitted message — fresh or late — is charged to the
//! MU-uplink bit budget: the airtime was spent either way.

use crate::util::rng::Pcg64;

/// Straggler-policy axis of a DES scenario.
#[derive(Clone, Debug, PartialEq)]
pub enum StragglerPolicy {
    /// Synchronous: every round waits for all participating members.
    WaitForAll,
    /// Deadline cutoff with stale-update discounting.
    Deadline {
        /// Deadline as a multiple of the expected slowest member round
        /// time; < 1 cuts off the tail.
        rel: f64,
        /// Weight applied to post-deadline updates at the next aggregation.
        stale_discount: f32,
    },
}

impl StragglerPolicy {
    pub fn is_wait_for_all(&self) -> bool {
        matches!(self, StragglerPolicy::WaitForAll)
    }

    /// Short tag used in scenario names (stable across runs).
    pub fn label(&self) -> String {
        match self {
            StragglerPolicy::WaitForAll => "waitall".to_string(),
            StragglerPolicy::Deadline { rel, stale_discount } => {
                format!("dl{rel}s{stale_discount}")
            }
        }
    }
}

/// Heterogeneous per-MU gradient-compute times.
///
/// Each MU draws a *mean* compute time once (lognormal around `mean_s` with
/// σ = `het`), then every round it participates in draws a jittered
/// duration around that mean. `mean_s = 0` disables computation time
/// entirely — the regime in which the DES timeline must agree with the
/// analytic `wireless::latency` model.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ComputeProfile {
    pub mean_s: f64,
    pub het: f64,
}

impl ComputeProfile {
    /// Instantaneous compute (communication-only timelines).
    pub fn none() -> Self {
        Self { mean_s: 0.0, het: 0.0 }
    }

    /// Per-MU mean compute time (one draw per MU at simulation start).
    pub fn mu_mean(&self, rng: &mut Pcg64) -> f64 {
        if self.mean_s <= 0.0 {
            return 0.0;
        }
        self.mean_s * (self.het * rng.normal()).exp()
    }

    /// One round's compute duration for an MU with per-MU mean `m`: mean-1
    /// multiplicative jitter with an exponential tail (the occasional slow
    /// minibatch that deadline policies exist to cut off).
    pub fn sample_round(&self, m: f64, rng: &mut Pcg64) -> f64 {
        if m <= 0.0 {
            return 0.0;
        }
        m * (0.7 + 0.3 * rng.exponential())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_are_stable_and_distinct() {
        assert_eq!(StragglerPolicy::WaitForAll.label(), "waitall");
        let d = StragglerPolicy::Deadline { rel: 0.9, stale_discount: 0.5 };
        assert_eq!(d.label(), "dl0.9s0.5");
        assert_ne!(d.label(), StragglerPolicy::WaitForAll.label());
    }

    #[test]
    fn zero_mean_draws_nothing_and_costs_nothing() {
        let p = ComputeProfile::none();
        let mut rng = Pcg64::seeded(1);
        let before = rng.clone().next_u64();
        assert_eq!(p.mu_mean(&mut rng), 0.0);
        assert_eq!(p.sample_round(0.0, &mut rng), 0.0);
        // The RNG stream was not advanced (determinism: disabled compute
        // consumes no draws).
        assert_eq!(rng.next_u64(), before);
    }

    #[test]
    fn heterogeneity_spreads_mu_means() {
        let p = ComputeProfile { mean_s: 0.1, het: 0.8 };
        let mut rng = Pcg64::seeded(5);
        let means: Vec<f64> = (0..64).map(|_| p.mu_mean(&mut rng)).collect();
        let min = means.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = means.iter().cloned().fold(0.0, f64::max);
        assert!(min > 0.0);
        assert!(max / min > 2.0, "lognormal spread too narrow: {min}..{max}");
    }

    #[test]
    fn round_samples_jitter_around_mean() {
        let p = ComputeProfile { mean_s: 0.05, het: 0.0 };
        let mut rng = Pcg64::seeded(6);
        let m = p.mu_mean(&mut rng);
        assert!((m - 0.05).abs() < 1e-12);
        let n = 20_000;
        let mut sum = 0.0;
        let mut above = 0usize;
        for _ in 0..n {
            let s = p.sample_round(m, &mut rng);
            assert!(s >= 0.7 * m);
            sum += s;
            if s > m {
                above += 1;
            }
        }
        let mean = sum / n as f64;
        assert!((mean - m).abs() / m < 0.02, "jitter mean drifted: {mean} vs {m}");
        // The exponential tail exceeds the mean reasonably often.
        assert!(above > n / 10);
    }
}
