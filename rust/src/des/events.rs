//! Event queue of the discrete-event HCN simulator.
//!
//! Events are totally ordered by `(time, seq)`: `time` via IEEE-754 total
//! order (`f64::total_cmp`) and `seq` — a monotonically increasing insertion
//! counter — as the tiebreak, so simultaneous events process in the exact
//! order they were scheduled. The queue is a binary min-heap; together with
//! the per-entity RNG streams this makes the whole timeline a pure function
//! of `(config, seed)` — the determinism contract the golden-trace suite
//! pins down.
//!
//! [`TimelineRecorder`] folds every processed event into an incremental
//! FNV-1a digest (`kind tag ‖ time bits ‖ entity ids`, in processing
//! order). Two runs with equal [`TimelineDigest`]s executed the same events
//! at the same simulated times in the same order.

use crate::sim::result::{Fnv1a, TimelineDigest};
use std::cmp::{Ordering, Reverse};
use std::collections::BinaryHeap;

/// What happened (or is scheduled to happen) at one point in simulated time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// MU finished computing its local gradient for `round`.
    ComputeDone { mu: usize, cluster: usize, round: usize },
    /// MU's sparse uplink message fully arrived at its SBS.
    UplinkDone { mu: usize, cluster: usize, round: usize },
    /// The cluster's straggler deadline for `round` expired.
    Deadline { cluster: usize, round: usize },
    /// The SBS finished broadcasting the aggregated round update.
    RoundEnd { cluster: usize, round: usize },
    /// The H-periodic MBS global sync (fronthaul + final broadcast) ended.
    GlobalSync { period: usize },
    /// An MU re-associated from cluster `from` to cluster `to` (recorded
    /// into the timeline digest; never queued).
    Handover { mu: usize, from: usize, to: usize },
}

impl EventKind {
    /// Decode the `(tag, fields)` encoding of [`EventKind::digest_fields`]
    /// back into a kind — the inverse used when restoring a checkpointed
    /// event queue.
    pub fn from_wire(tag: u8, f: [u64; 3]) -> Option<Self> {
        let (a, b, c) = (f[0] as usize, f[1] as usize, f[2] as usize);
        Some(match tag {
            1 => EventKind::ComputeDone { mu: a, cluster: b, round: c },
            2 => EventKind::UplinkDone { mu: a, cluster: b, round: c },
            3 => EventKind::Deadline { cluster: a, round: b },
            4 => EventKind::RoundEnd { cluster: a, round: b },
            5 => EventKind::GlobalSync { period: a },
            6 => EventKind::Handover { mu: a, from: b, to: c },
            _ => return None,
        })
    }

    /// Stable tag + entity fields fed to the timeline digest; doubles as
    /// the checkpoint wire encoding (see [`EventKind::from_wire`]).
    pub fn digest_fields(&self) -> (u8, [u64; 3]) {
        match *self {
            EventKind::ComputeDone { mu, cluster, round } => {
                (1, [mu as u64, cluster as u64, round as u64])
            }
            EventKind::UplinkDone { mu, cluster, round } => {
                (2, [mu as u64, cluster as u64, round as u64])
            }
            EventKind::Deadline { cluster, round } => (3, [cluster as u64, round as u64, 0]),
            EventKind::RoundEnd { cluster, round } => (4, [cluster as u64, round as u64, 0]),
            EventKind::GlobalSync { period } => (5, [period as u64, 0, 0]),
            EventKind::Handover { mu, from, to } => (6, [mu as u64, from as u64, to as u64]),
        }
    }
}

/// One scheduled event.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub time: f64,
    /// Insertion counter — the deterministic tiebreak for equal times.
    pub seq: u64,
    pub kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.time.to_bits() == other.time.to_bits() && self.seq == other.seq
    }
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        self.time
            .total_cmp(&other.time)
            .then(self.seq.cmp(&other.seq))
    }
}

/// Binary min-heap of events keyed by `(time, seq)`.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Reverse<Event>>,
    next_seq: u64,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    /// Schedule `kind` at absolute simulated time `time`.
    pub fn push(&mut self, time: f64, kind: EventKind) {
        debug_assert!(time.is_finite(), "non-finite event time {time}");
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Reverse(Event { time, seq, kind }));
    }

    /// Pop the earliest event (ties broken by insertion order).
    pub fn pop(&mut self) -> Option<Event> {
        self.heap.pop().map(|r| r.0)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// The next insertion counter (for checkpointing).
    pub fn next_seq(&self) -> u64 {
        self.next_seq
    }

    /// Pending events in `(time, seq)` order with their original `seq`
    /// values — the checkpoint image of the queue.
    pub fn snapshot_events(&self) -> Vec<Event> {
        let mut evs: Vec<Event> = self.heap.iter().map(|r| r.0).collect();
        evs.sort_unstable();
        evs
    }

    /// Rebuild a queue from [`EventQueue::snapshot_events`] output and the
    /// saved [`EventQueue::next_seq`]. Original `seq` values are kept, so
    /// tie-breaking — and therefore the whole remaining timeline — is
    /// bit-identical to the uninterrupted run.
    pub fn restore(events: Vec<Event>, next_seq: u64) -> Self {
        let mut heap = BinaryHeap::with_capacity(events.len());
        for ev in events {
            assert!(ev.seq < next_seq, "restored event seq beyond next_seq");
            heap.push(Reverse(ev));
        }
        Self { heap, next_seq }
    }
}

/// Incremental FNV-1a digest over the processed-event stream (shares the
/// [`Fnv1a`] kernel with the parameter/loss hashes in `sim::result`).
#[derive(Clone, Debug, Default)]
pub struct TimelineRecorder {
    n: u64,
    h: Fnv1a,
}

impl TimelineRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fold one record `(time, kind)` into the digest. The queue's internal
    /// `seq` is deliberately excluded: record order already captures it.
    pub fn record_kind(&mut self, time: f64, kind: &EventKind) {
        let (tag, fields) = kind.digest_fields();
        self.n += 1;
        self.h.absorb([tag]);
        self.h.absorb(time.to_bits().to_le_bytes());
        for f in fields {
            self.h.absorb(f.to_le_bytes());
        }
    }

    /// Fold one queue-processed event.
    pub fn record(&mut self, ev: &Event) {
        self.record_kind(ev.time, &ev.kind);
    }

    pub fn digest(&self) -> TimelineDigest {
        TimelineDigest {
            n_events: self.n,
            digest: self.h.finish(),
        }
    }

    /// Checkpoint image `(n_events, running_digest)` — the mid-stream
    /// digest IS the FNV state, so this is exactly [`Self::digest`]'s
    /// fields.
    pub fn raw_state(&self) -> (u64, u64) {
        (self.n, self.h.finish())
    }

    /// Rebuild a recorder mid-stream from [`Self::raw_state`] output.
    pub fn from_raw_state(n: u64, digest: u64) -> Self {
        Self {
            n,
            h: Fnv1a::from_raw(digest),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order_with_seq_tiebreak() {
        let mut q = EventQueue::new();
        q.push(2.0, EventKind::RoundEnd { cluster: 0, round: 0 });
        q.push(1.0, EventKind::ComputeDone { mu: 3, cluster: 0, round: 0 });
        q.push(1.0, EventKind::ComputeDone { mu: 1, cluster: 0, round: 0 });
        q.push(0.5, EventKind::Deadline { cluster: 1, round: 0 });
        assert_eq!(q.len(), 4);
        let order: Vec<Event> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(order.len(), 4);
        assert_eq!(order[0].time, 0.5);
        // Equal times: insertion order (mu 3 was pushed before mu 1).
        assert_eq!(order[1].kind, EventKind::ComputeDone { mu: 3, cluster: 0, round: 0 });
        assert_eq!(order[2].kind, EventKind::ComputeDone { mu: 1, cluster: 0, round: 0 });
        assert_eq!(order[3].time, 2.0);
        assert!(q.is_empty());
    }

    #[test]
    fn recorder_is_order_and_content_sensitive() {
        let a_events = [
            (0.5, EventKind::ComputeDone { mu: 0, cluster: 0, round: 0 }),
            (1.0, EventKind::UplinkDone { mu: 0, cluster: 0, round: 0 }),
        ];
        let mut a = TimelineRecorder::new();
        for (t, k) in &a_events {
            a.record_kind(*t, k);
        }
        // Same events, same order: identical digest.
        let mut b = TimelineRecorder::new();
        for (t, k) in &a_events {
            b.record_kind(*t, k);
        }
        assert_eq!(a.digest(), b.digest());
        assert_eq!(a.digest().n_events, 2);
        // Swapped order: different digest.
        let mut c = TimelineRecorder::new();
        for (t, k) in a_events.iter().rev() {
            c.record_kind(*t, k);
        }
        assert_ne!(a.digest().digest, c.digest().digest);
        // A one-ulp time change is visible.
        let mut d = TimelineRecorder::new();
        d.record_kind(0.5, &a_events[0].1);
        d.record_kind(f64::from_bits(1.0f64.to_bits() + 1), &a_events[1].1);
        assert_ne!(a.digest().digest, d.digest().digest);
    }

    #[test]
    fn queue_snapshot_restore_preserves_order_and_seq() {
        let mut q = EventQueue::new();
        q.push(2.0, EventKind::RoundEnd { cluster: 0, round: 0 });
        q.push(1.0, EventKind::ComputeDone { mu: 3, cluster: 0, round: 0 });
        q.push(1.0, EventKind::ComputeDone { mu: 1, cluster: 0, round: 0 });
        let _ = q.pop(); // consume one so the image is mid-run
        let evs = q.snapshot_events();
        assert_eq!(evs.len(), 2);
        let mut r = EventQueue::restore(evs, q.next_seq());
        assert_eq!(r.next_seq(), q.next_seq());
        // Restored queue pops identically, including the seq tiebreak.
        loop {
            match (q.pop(), r.pop()) {
                (None, None) => break,
                (a, b) => assert_eq!(a, b),
            }
        }
        // New pushes continue the same seq sequence.
        let mut r2 = EventQueue::restore(Vec::new(), 7);
        r2.push(0.0, EventKind::GlobalSync { period: 0 });
        assert_eq!(r2.pop().unwrap().seq, 7);
    }

    #[test]
    fn event_kind_wire_roundtrips_every_variant() {
        let kinds = [
            EventKind::ComputeDone { mu: 1, cluster: 2, round: 3 },
            EventKind::UplinkDone { mu: 4, cluster: 5, round: 6 },
            EventKind::Deadline { cluster: 7, round: 8 },
            EventKind::RoundEnd { cluster: 9, round: 10 },
            EventKind::GlobalSync { period: 11 },
            EventKind::Handover { mu: 12, from: 13, to: 14 },
        ];
        for k in kinds {
            let (tag, fields) = k.digest_fields();
            assert_eq!(EventKind::from_wire(tag, fields), Some(k));
        }
        assert_eq!(EventKind::from_wire(0, [0; 3]), None);
        assert_eq!(EventKind::from_wire(7, [0; 3]), None);
    }

    #[test]
    fn recorder_raw_state_roundtrip_continues_the_digest() {
        let mut a = TimelineRecorder::new();
        a.record_kind(0.5, &EventKind::GlobalSync { period: 0 });
        let (n, d) = a.raw_state();
        let mut b = TimelineRecorder::from_raw_state(n, d);
        for i in 0..10 {
            let k = EventKind::Deadline { cluster: i, round: i };
            a.record_kind(i as f64, &k);
            b.record_kind(i as f64, &k);
        }
        assert_eq!(a.digest(), b.digest());
    }

    #[test]
    fn distinct_kinds_have_distinct_digests() {
        let kinds = [
            EventKind::ComputeDone { mu: 1, cluster: 2, round: 3 },
            EventKind::UplinkDone { mu: 1, cluster: 2, round: 3 },
            EventKind::Deadline { cluster: 1, round: 2 },
            EventKind::RoundEnd { cluster: 1, round: 2 },
            EventKind::GlobalSync { period: 1 },
            EventKind::Handover { mu: 1, from: 2, to: 0 },
        ];
        let mut digests = Vec::new();
        for k in &kinds {
            let mut r = TimelineRecorder::new();
            r.record_kind(1.0, k);
            digests.push(r.digest().digest);
        }
        digests.sort_unstable();
        digests.dedup();
        assert_eq!(digests.len(), kinds.len(), "kind tags collide");
    }
}
