//! Event queue of the discrete-event HCN simulator.
//!
//! Events are totally ordered by `(time, seq)`: `time` via IEEE-754 total
//! order (`f64::total_cmp`) and `seq` — a monotonically increasing insertion
//! counter — as the tiebreak, so simultaneous events process in the exact
//! order they were scheduled. The queue is a two-level **calendar queue**
//! (near-term day buckets + a far-future overflow level, see
//! [`EventQueue`]); together with the per-entity RNG streams this makes the
//! whole timeline a pure function of `(config, seed)` — the determinism
//! contract the golden-trace suite pins down. Every calendar decision
//! (bucket width, resize, year rotation) is derived from queue content
//! alone, never from wall clock or randomness, so the pop order is exactly
//! the binary-heap `(time, seq)` order at any scale — asserted against a
//! reference heap by the adversarial property test below.
//!
//! [`TimelineRecorder`] folds every processed event into an incremental
//! FNV-1a digest (`kind tag ‖ time bits ‖ entity ids`, in processing
//! order). Two runs with equal [`TimelineDigest`]s executed the same events
//! at the same simulated times in the same order.

use crate::sim::result::{Fnv1a, TimelineDigest};
use std::cmp::Ordering;

/// What happened (or is scheduled to happen) at one point in simulated time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// MU finished computing its local gradient for `round`.
    ComputeDone { mu: usize, cluster: usize, round: usize },
    /// MU's sparse uplink message fully arrived at its SBS.
    UplinkDone { mu: usize, cluster: usize, round: usize },
    /// The cluster's straggler deadline for `round` expired.
    Deadline { cluster: usize, round: usize },
    /// The SBS finished broadcasting the aggregated round update.
    RoundEnd { cluster: usize, round: usize },
    /// The H-periodic MBS global sync (fronthaul + final broadcast) ended.
    GlobalSync { period: usize },
    /// An MU re-associated from cluster `from` to cluster `to` (recorded
    /// into the timeline digest; never queued).
    Handover { mu: usize, from: usize, to: usize },
}

impl EventKind {
    /// Decode the `(tag, fields)` encoding of [`EventKind::digest_fields`]
    /// back into a kind — the inverse used when restoring a checkpointed
    /// event queue.
    pub fn from_wire(tag: u8, f: [u64; 3]) -> Option<Self> {
        let (a, b, c) = (f[0] as usize, f[1] as usize, f[2] as usize);
        Some(match tag {
            1 => EventKind::ComputeDone { mu: a, cluster: b, round: c },
            2 => EventKind::UplinkDone { mu: a, cluster: b, round: c },
            3 => EventKind::Deadline { cluster: a, round: b },
            4 => EventKind::RoundEnd { cluster: a, round: b },
            5 => EventKind::GlobalSync { period: a },
            6 => EventKind::Handover { mu: a, from: b, to: c },
            _ => return None,
        })
    }

    /// Stable tag + entity fields fed to the timeline digest; doubles as
    /// the checkpoint wire encoding (see [`EventKind::from_wire`]).
    pub fn digest_fields(&self) -> (u8, [u64; 3]) {
        match *self {
            EventKind::ComputeDone { mu, cluster, round } => {
                (1, [mu as u64, cluster as u64, round as u64])
            }
            EventKind::UplinkDone { mu, cluster, round } => {
                (2, [mu as u64, cluster as u64, round as u64])
            }
            EventKind::Deadline { cluster, round } => (3, [cluster as u64, round as u64, 0]),
            EventKind::RoundEnd { cluster, round } => (4, [cluster as u64, round as u64, 0]),
            EventKind::GlobalSync { period } => (5, [period as u64, 0, 0]),
            EventKind::Handover { mu, from, to } => (6, [mu as u64, from as u64, to as u64]),
        }
    }
}

/// One scheduled event.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub time: f64,
    /// Insertion counter — the deterministic tiebreak for equal times.
    pub seq: u64,
    pub kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.time.to_bits() == other.time.to_bits() && self.seq == other.seq
    }
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        self.time
            .total_cmp(&other.time)
            .then(self.seq.cmp(&other.seq))
    }
}

/// Two-level calendar queue of events keyed by `(time, seq)`.
///
/// Level 0 is a ring of `nb` unsorted *day buckets* of width `width`
/// seconds: an event at time `t` lives on day `⌊t/width⌋` in bucket
/// `day mod nb`. Level 1 is a single overflow list holding everything
/// beyond the current *year* (`year_end_day`); when the scan crosses a
/// year boundary the overflow is re-partitioned into the new year's
/// buckets. Pop scans forward from `current_day`, taking the `(time, seq)`
/// minimum among the events of that exact day (same day ⇒ same bucket, so
/// the linear scan sees them all); a bucket may also hold later-year
/// events, which the integer day check skips exactly. Pushing an event
/// earlier than the scan position rewinds `current_day`, so the order is
/// the global `(time, seq)` minimum even on adversarial schedules.
///
/// The queue resizes itself from content (`len` vs `nb`, bucket width
/// from the current time span), so `10^7`-event timelines stay O(1) per
/// operation amortized while 4-event unit tests behave identically to the
/// old binary heap — bit-identical pop order, by construction, at every
/// size.
#[derive(Debug)]
pub struct EventQueue {
    /// Level 0: `nb` day buckets, each an unsorted vec of near-term events.
    buckets: Vec<Vec<Event>>,
    /// Level 1: events at or beyond `year_end_day`, unsorted.
    overflow: Vec<Event>,
    /// Bucket width in simulated seconds (always finite and > 0).
    width: f64,
    /// Day the pop scan resumes from (`⌊t/width⌋` of the scan floor).
    current_day: u64,
    /// Exclusive day bound of level 0; events at later days overflow.
    year_end_day: u64,
    /// Total events across both levels.
    len: usize,
    /// Events in level 0 (buckets) only.
    level0_len: usize,
    next_seq: u64,
}

/// Initial/minimum bucket count (kept tiny so unit-test-sized queues cost
/// nothing; the first resize recalibrates from content).
const MIN_BUCKETS: usize = 16;
/// Hard cap on the bucket ring (2^22 buckets ≈ 10^7 events at the grow
/// threshold — beyond that buckets just get denser).
const MAX_BUCKETS: usize = 1 << 22;

/// Day index of time `t` for bucket width `width`: `⌊t/width⌋`, clamped
/// to `[0, u64::MAX − 1]`. Monotone in `t` (equal times ⇒ equal days), so
/// day order never contradicts time order; the clamp leaves room for an
/// exclusive `year_end_day` above every representable day. Far-future
/// times that saturate share one day — that only makes a bucket denser,
/// never reorders a pop (the in-bucket scan orders by exact `(time, seq)`).
fn day_of(t: f64, width: f64) -> u64 {
    if t <= 0.0 {
        0
    } else {
        ((t / width) as u64).min(u64::MAX - 1) // `as` saturates
    }
}

impl Default for EventQueue {
    fn default() -> Self {
        Self {
            buckets: vec![Vec::new(); MIN_BUCKETS],
            overflow: Vec::new(),
            width: 1.0,
            current_day: 0,
            year_end_day: MIN_BUCKETS as u64,
            len: 0,
            level0_len: 0,
            next_seq: 0,
        }
    }
}

impl EventQueue {
    /// An empty queue with the minimal bucket ring.
    pub fn new() -> Self {
        Self::default()
    }

    /// The day index of time `t` under the current width (see [`day_of`]).
    fn day(&self, t: f64) -> u64 {
        day_of(t, self.width)
    }

    /// Insert a restored or fresh event into the right level, rewinding the
    /// scan position if it lands before it.
    fn insert(&mut self, ev: Event) {
        let day = self.day(ev.time);
        if day < self.year_end_day {
            if day < self.current_day {
                self.current_day = day;
            }
            let nb = self.buckets.len() as u64;
            self.buckets[(day % nb) as usize].push(ev);
            self.level0_len += 1;
        } else {
            self.overflow.push(ev);
        }
        self.len += 1;
    }

    /// Schedule `kind` at absolute simulated time `time`.
    pub fn push(&mut self, time: f64, kind: EventKind) {
        debug_assert!(time.is_finite(), "non-finite event time {time}");
        let seq = self.next_seq;
        self.next_seq += 1;
        self.insert(Event { time, seq, kind });
        if self.len > self.buckets.len() * 4 && self.buckets.len() < MAX_BUCKETS {
            self.rebuild(self.buckets.len() * 2);
        }
    }

    /// Pop the earliest event (ties broken by insertion order).
    pub fn pop(&mut self) -> Option<Event> {
        if self.len == 0 {
            return None;
        }
        if self.level0_len == 0 {
            self.advance_year();
        }
        let nb = self.buckets.len() as u64;
        let width = self.width;
        let mut empty_scans = 0u64;
        loop {
            let day = self.current_day;
            let bucket = &mut self.buckets[(day % nb) as usize];
            // The `(time, seq)` minimum among this day's events; the same
            // bucket may hold later-year events, skipped by the day check.
            let mut best: Option<usize> = None;
            for (i, ev) in bucket.iter().enumerate() {
                if day_of(ev.time, width) != day {
                    continue;
                }
                match best {
                    Some(b) if bucket[b].cmp(ev) != Ordering::Greater => {}
                    _ => best = Some(i),
                }
            }
            if let Some(i) = best {
                let ev = bucket.swap_remove(i);
                self.len -= 1;
                self.level0_len -= 1;
                if self.len < self.buckets.len() / 4 && self.buckets.len() > MIN_BUCKETS {
                    let target = (self.buckets.len() / 2).max(MIN_BUCKETS);
                    self.rebuild(target);
                }
                return Some(ev);
            }
            self.current_day += 1;
            empty_scans += 1;
            if self.current_day >= self.year_end_day {
                if self.level0_len == 0 {
                    self.advance_year();
                } else {
                    self.jump_to_min_level0_day();
                }
                empty_scans = 0;
            } else if empty_scans >= nb {
                // A full empty lap: level-0 events exist but live on a far
                // day (possible after a rewind). Jump straight to them.
                self.jump_to_min_level0_day();
                empty_scans = 0;
            }
        }
    }

    /// Set the scan position to the earliest day present in level 0.
    fn jump_to_min_level0_day(&mut self) {
        debug_assert!(self.level0_len > 0);
        let mut min_day = u64::MAX;
        for b in &self.buckets {
            for ev in b {
                let d = self.day(ev.time);
                if d < min_day {
                    min_day = d;
                }
            }
        }
        self.current_day = min_day;
    }

    /// Rotate the calendar to the year containing the earliest overflow
    /// event and pull that year's events down into the buckets.
    fn advance_year(&mut self) {
        debug_assert!(self.level0_len == 0 && !self.overflow.is_empty());
        let mut min_day = u64::MAX;
        for ev in &self.overflow {
            let d = self.day(ev.time);
            if d < min_day {
                min_day = d;
            }
        }
        self.current_day = min_day;
        // `day_of` clamps below u64::MAX, so this is always > min_day.
        self.year_end_day = min_day.saturating_add(self.buckets.len() as u64);
        let nb = self.buckets.len() as u64;
        let mut keep = Vec::new();
        for ev in std::mem::take(&mut self.overflow) {
            let d = self.day(ev.time);
            if d < self.year_end_day {
                self.buckets[(d % nb) as usize].push(ev);
                self.level0_len += 1;
            } else {
                keep.push(ev);
            }
        }
        self.overflow = keep;
    }

    /// Re-bucket everything into a ring of `nb` buckets with a width
    /// recalibrated from the current time span (≈ one event per bucket for
    /// uniformly spread timelines). Content-determined, so rebuilds happen
    /// at the same points in every replay of the same schedule.
    fn rebuild(&mut self, nb: usize) {
        let mut events: Vec<Event> = Vec::with_capacity(self.len);
        for b in &mut self.buckets {
            events.append(b);
        }
        events.append(&mut self.overflow);
        let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
        for ev in &events {
            lo = lo.min(ev.time);
            hi = hi.max(ev.time);
        }
        let span = hi - lo;
        if span.is_finite() && span > 0.0 && !events.is_empty() {
            self.width = (span / events.len() as f64).max(1e-9);
        }
        self.buckets = vec![Vec::new(); nb];
        self.level0_len = 0;
        self.len = 0;
        let floor_day = if events.is_empty() { 0 } else { self.day(lo) };
        self.current_day = floor_day;
        self.year_end_day = floor_day.saturating_add(nb as u64);
        for ev in events {
            self.insert(ev);
        }
    }

    /// Number of pending events.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether no events are pending.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The next insertion counter (for checkpointing).
    pub fn next_seq(&self) -> u64 {
        self.next_seq
    }

    /// Pending events in `(time, seq)` order with their original `seq`
    /// values — the checkpoint image of the queue.
    pub fn snapshot_events(&self) -> Vec<Event> {
        let mut evs: Vec<Event> = Vec::with_capacity(self.len);
        for b in &self.buckets {
            evs.extend_from_slice(b);
        }
        evs.extend_from_slice(&self.overflow);
        evs.sort_unstable();
        evs
    }

    /// Rebuild a queue from [`EventQueue::snapshot_events`] output and the
    /// saved [`EventQueue::next_seq`]. Original `seq` values are kept, so
    /// tie-breaking — and therefore the whole remaining timeline — is
    /// bit-identical to the uninterrupted run.
    pub fn restore(events: Vec<Event>, next_seq: u64) -> Self {
        let mut q = Self::new();
        q.next_seq = next_seq;
        for ev in events {
            assert!(ev.seq < next_seq, "restored event seq beyond next_seq");
            q.insert(ev);
        }
        // One calibration pass so a huge restored image starts with a
        // content-sized ring instead of growing push by push.
        if q.len > q.buckets.len() * 4 {
            let target = q.len.next_power_of_two().min(MAX_BUCKETS);
            q.rebuild(target);
        }
        q
    }
}

/// Incremental FNV-1a digest over the processed-event stream (shares the
/// [`Fnv1a`] kernel with the parameter/loss hashes in `sim::result`).
#[derive(Clone, Debug, Default)]
pub struct TimelineRecorder {
    n: u64,
    h: Fnv1a,
}

impl TimelineRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fold one record `(time, kind)` into the digest. The queue's internal
    /// `seq` is deliberately excluded: record order already captures it.
    pub fn record_kind(&mut self, time: f64, kind: &EventKind) {
        let (tag, fields) = kind.digest_fields();
        self.n += 1;
        self.h.absorb([tag]);
        self.h.absorb(time.to_bits().to_le_bytes());
        for f in fields {
            self.h.absorb(f.to_le_bytes());
        }
    }

    /// Fold one queue-processed event.
    pub fn record(&mut self, ev: &Event) {
        self.record_kind(ev.time, &ev.kind);
    }

    pub fn digest(&self) -> TimelineDigest {
        TimelineDigest {
            n_events: self.n,
            digest: self.h.finish(),
        }
    }

    /// Checkpoint image `(n_events, running_digest)` — the mid-stream
    /// digest IS the FNV state, so this is exactly [`Self::digest`]'s
    /// fields.
    pub fn raw_state(&self) -> (u64, u64) {
        (self.n, self.h.finish())
    }

    /// Rebuild a recorder mid-stream from [`Self::raw_state`] output.
    pub fn from_raw_state(n: u64, digest: u64) -> Self {
        Self {
            n,
            h: Fnv1a::from_raw(digest),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order_with_seq_tiebreak() {
        let mut q = EventQueue::new();
        q.push(2.0, EventKind::RoundEnd { cluster: 0, round: 0 });
        q.push(1.0, EventKind::ComputeDone { mu: 3, cluster: 0, round: 0 });
        q.push(1.0, EventKind::ComputeDone { mu: 1, cluster: 0, round: 0 });
        q.push(0.5, EventKind::Deadline { cluster: 1, round: 0 });
        assert_eq!(q.len(), 4);
        let order: Vec<Event> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(order.len(), 4);
        assert_eq!(order[0].time, 0.5);
        // Equal times: insertion order (mu 3 was pushed before mu 1).
        assert_eq!(order[1].kind, EventKind::ComputeDone { mu: 3, cluster: 0, round: 0 });
        assert_eq!(order[2].kind, EventKind::ComputeDone { mu: 1, cluster: 0, round: 0 });
        assert_eq!(order[3].time, 2.0);
        assert!(q.is_empty());
    }

    #[test]
    fn recorder_is_order_and_content_sensitive() {
        let a_events = [
            (0.5, EventKind::ComputeDone { mu: 0, cluster: 0, round: 0 }),
            (1.0, EventKind::UplinkDone { mu: 0, cluster: 0, round: 0 }),
        ];
        let mut a = TimelineRecorder::new();
        for (t, k) in &a_events {
            a.record_kind(*t, k);
        }
        // Same events, same order: identical digest.
        let mut b = TimelineRecorder::new();
        for (t, k) in &a_events {
            b.record_kind(*t, k);
        }
        assert_eq!(a.digest(), b.digest());
        assert_eq!(a.digest().n_events, 2);
        // Swapped order: different digest.
        let mut c = TimelineRecorder::new();
        for (t, k) in a_events.iter().rev() {
            c.record_kind(*t, k);
        }
        assert_ne!(a.digest().digest, c.digest().digest);
        // A one-ulp time change is visible.
        let mut d = TimelineRecorder::new();
        d.record_kind(0.5, &a_events[0].1);
        d.record_kind(f64::from_bits(1.0f64.to_bits() + 1), &a_events[1].1);
        assert_ne!(a.digest().digest, d.digest().digest);
    }

    #[test]
    fn queue_snapshot_restore_preserves_order_and_seq() {
        let mut q = EventQueue::new();
        q.push(2.0, EventKind::RoundEnd { cluster: 0, round: 0 });
        q.push(1.0, EventKind::ComputeDone { mu: 3, cluster: 0, round: 0 });
        q.push(1.0, EventKind::ComputeDone { mu: 1, cluster: 0, round: 0 });
        let _ = q.pop(); // consume one so the image is mid-run
        let evs = q.snapshot_events();
        assert_eq!(evs.len(), 2);
        let mut r = EventQueue::restore(evs, q.next_seq());
        assert_eq!(r.next_seq(), q.next_seq());
        // Restored queue pops identically, including the seq tiebreak.
        loop {
            match (q.pop(), r.pop()) {
                (None, None) => break,
                (a, b) => assert_eq!(a, b),
            }
        }
        // New pushes continue the same seq sequence.
        let mut r2 = EventQueue::restore(Vec::new(), 7);
        r2.push(0.0, EventKind::GlobalSync { period: 0 });
        assert_eq!(r2.pop().unwrap().seq, 7);
    }

    /// Reference implementation: the pre-calendar binary min-heap, the
    /// ordering oracle the calendar queue must reproduce pop-for-pop.
    #[derive(Default)]
    struct HeapQueue {
        heap: std::collections::BinaryHeap<std::cmp::Reverse<Event>>,
        next_seq: u64,
    }

    impl HeapQueue {
        fn push(&mut self, time: f64, kind: EventKind) {
            let seq = self.next_seq;
            self.next_seq += 1;
            self.heap.push(std::cmp::Reverse(Event { time, seq, kind }));
        }
        fn pop(&mut self) -> Option<Event> {
            self.heap.pop().map(|r| r.0)
        }
    }

    #[test]
    fn calendar_matches_heap_on_adversarial_schedules() {
        use crate::util::rng::Pcg64;
        // Each case interleaves pushes and pops with duplicate timestamps,
        // far-future outliers, bursts (to cross resize thresholds both
        // ways) and mid-stream snapshot/restore of the calendar side.
        for seed in 0..8u64 {
            let mut rng = Pcg64::new(0xCA1E_17DA, seed);
            let mut cal = EventQueue::new();
            let mut heap = HeapQueue::default();
            let mut clock = 0.0f64;
            for step in 0..2_000usize {
                let r = rng.uniform();
                if r < 0.55 || cal.is_empty() {
                    // Push a burst; times cluster near the clock, repeat
                    // exactly (seq tiebreak), or jump far ahead.
                    let burst = 1 + rng.uniform_usize(8);
                    for _ in 0..burst {
                        let t = match rng.uniform_usize(10) {
                            0..=5 => clock + rng.uniform_range(0.0, 2.0),
                            6 | 7 => clock, // exact duplicate timestamp
                            8 => clock + rng.uniform_range(0.0, 1e6),
                            _ => clock + rng.uniform_range(0.0, 1e12), // far future
                        };
                        let kind = EventKind::Deadline { cluster: step, round: 0 };
                        cal.push(t, kind);
                        heap.push(t, kind);
                    }
                } else if r < 0.95 {
                    let (a, b) = (cal.pop(), heap.pop());
                    assert_eq!(a, b, "seed {seed} step {step}: pop order diverged");
                    if let Some(ev) = a {
                        clock = clock.max(ev.time);
                    }
                } else {
                    // Interleaved snapshot/restore must preserve the exact
                    // remaining order and seq stream.
                    let evs = cal.snapshot_events();
                    assert!(evs.windows(2).all(|w| w[0] < w[1]));
                    cal = EventQueue::restore(evs, cal.next_seq());
                    assert_eq!(cal.next_seq(), heap.next_seq);
                }
                assert_eq!(cal.len(), heap.heap.len());
            }
            // Drain: every remaining event in identical order.
            loop {
                match (cal.pop(), heap.pop()) {
                    (None, None) => break,
                    (a, b) => assert_eq!(a, b, "seed {seed}: drain diverged"),
                }
            }
        }
    }

    #[test]
    fn calendar_scales_past_resize_thresholds_in_order() {
        // A deterministic 60k-event storm (way past several grow/shrink
        // rebuilds) must drain in strict (time, seq) order.
        use crate::util::rng::Pcg64;
        let mut rng = Pcg64::new(0x5CA1E, 1);
        let mut q = EventQueue::new();
        for i in 0..60_000usize {
            let t = rng.uniform_range(0.0, 1e4);
            q.push(t, EventKind::GlobalSync { period: i });
        }
        let mut last: Option<Event> = None;
        let mut n = 0usize;
        while let Some(ev) = q.pop() {
            if let Some(prev) = last {
                assert!(prev < ev, "out of order at event {n}");
            }
            last = Some(ev);
            n += 1;
        }
        assert_eq!(n, 60_000);
    }

    #[test]
    fn event_kind_wire_roundtrips_every_variant() {
        let kinds = [
            EventKind::ComputeDone { mu: 1, cluster: 2, round: 3 },
            EventKind::UplinkDone { mu: 4, cluster: 5, round: 6 },
            EventKind::Deadline { cluster: 7, round: 8 },
            EventKind::RoundEnd { cluster: 9, round: 10 },
            EventKind::GlobalSync { period: 11 },
            EventKind::Handover { mu: 12, from: 13, to: 14 },
        ];
        for k in kinds {
            let (tag, fields) = k.digest_fields();
            assert_eq!(EventKind::from_wire(tag, fields), Some(k));
        }
        assert_eq!(EventKind::from_wire(0, [0; 3]), None);
        assert_eq!(EventKind::from_wire(7, [0; 3]), None);
    }

    #[test]
    fn recorder_raw_state_roundtrip_continues_the_digest() {
        let mut a = TimelineRecorder::new();
        a.record_kind(0.5, &EventKind::GlobalSync { period: 0 });
        let (n, d) = a.raw_state();
        let mut b = TimelineRecorder::from_raw_state(n, d);
        for i in 0..10 {
            let k = EventKind::Deadline { cluster: i, round: i };
            a.record_kind(i as f64, &k);
            b.record_kind(i as f64, &k);
        }
        assert_eq!(a.digest(), b.digest());
    }

    #[test]
    fn distinct_kinds_have_distinct_digests() {
        let kinds = [
            EventKind::ComputeDone { mu: 1, cluster: 2, round: 3 },
            EventKind::UplinkDone { mu: 1, cluster: 2, round: 3 },
            EventKind::Deadline { cluster: 1, round: 2 },
            EventKind::RoundEnd { cluster: 1, round: 2 },
            EventKind::GlobalSync { period: 1 },
            EventKind::Handover { mu: 1, from: 2, to: 0 },
        ];
        let mut digests = Vec::new();
        for k in &kinds {
            let mut r = TimelineRecorder::new();
            r.record_kind(1.0, k);
            digests.push(r.digest().digest);
        }
        digests.sort_unstable();
        digests.dedup();
        assert_eq!(digests.len(), kinds.len(), "kind tags collide");
    }
}
