//! MU mobility for the discrete-event simulator: random-waypoint traces
//! over the macro disc that hosts the hexagonal cluster flower
//! (`crate::topology::hex`).
//!
//! Each mobile MU owns a [`Waypoint`] walker with its own `Pcg64` stream:
//! it repeatedly draws a destination uniform over the macro disc, walks
//! there in a straight line at constant speed, pauses, and draws the next
//! leg. Positions are queried at monotonically increasing simulated times
//! (the engine samples them at global-sync boundaries), so the sequence of
//! RNG draws — and hence the whole trace — is a pure function of the seed.
//!
//! Handover is the engine's job: after moving the MUs it re-associates each
//! one to the nearest SBS centre ([`crate::topology::HexLayout::nearest_center`]).

use crate::topology::Point;
use crate::util::rng::Pcg64;

/// Mobility axis of a DES scenario.
#[derive(Clone, Debug, PartialEq)]
pub enum MobilityProfile {
    /// MUs stay at their placement positions (the analytic-model regime).
    Static,
    /// Random-waypoint over the macro disc.
    Waypoint { speed_mps: f64, pause_s: f64 },
}

impl MobilityProfile {
    pub fn is_static(&self) -> bool {
        matches!(self, MobilityProfile::Static)
    }

    /// Short tag used in scenario names (stable across runs).
    pub fn label(&self) -> String {
        match self {
            MobilityProfile::Static => "static".to_string(),
            MobilityProfile::Waypoint { speed_mps, .. } => format!("wp{speed_mps}"),
        }
    }
}

/// One MU's random-waypoint walker.
#[derive(Clone, Debug)]
pub struct Waypoint {
    /// Position at the start of the current leg (the last waypoint).
    anchor: Point,
    target: Point,
    /// Time the walker leaves `anchor` (after the pause).
    leg_start: f64,
    /// Time the walker reaches `target`.
    arrive: f64,
    speed: f64,
    pause: f64,
    disc_r: f64,
    rng: Pcg64,
}

impl Waypoint {
    pub fn new(start: Point, speed_mps: f64, pause_s: f64, disc_r: f64, rng: Pcg64) -> Self {
        let mut w = Self {
            anchor: start,
            target: start,
            leg_start: 0.0,
            arrive: 0.0,
            speed: speed_mps,
            pause: pause_s,
            disc_r,
            rng,
        };
        w.next_leg(0.0);
        w
    }

    fn next_leg(&mut self, now: f64) {
        // Destination uniform over the disc: r = R√u, θ ~ U[0, 2π).
        let r = self.disc_r * self.rng.uniform().sqrt();
        let ang = self.rng.uniform_range(0.0, std::f64::consts::TAU);
        self.target = Point::new(r * ang.cos(), r * ang.sin());
        self.leg_start = now + self.pause;
        let dist = self.anchor.dist(&self.target);
        self.arrive = if self.speed > 0.0 {
            self.leg_start + dist / self.speed
        } else {
            f64::INFINITY
        };
    }

    /// Full walker state for checkpointing:
    /// `(anchor, target, leg_start, arrive, speed, pause, disc_r, rng)`.
    #[allow(clippy::type_complexity)]
    pub fn raw_state(&self) -> (Point, Point, f64, f64, f64, f64, f64, &Pcg64) {
        (
            self.anchor,
            self.target,
            self.leg_start,
            self.arrive,
            self.speed,
            self.pause,
            self.disc_r,
            &self.rng,
        )
    }

    /// Rebuild a walker from [`Waypoint::raw_state`] output. Unlike
    /// [`Waypoint::new`] this draws no leg — the restored walker is
    /// mid-trace, continuing the snapshotted one exactly.
    #[allow(clippy::too_many_arguments)]
    pub fn from_raw_state(
        anchor: Point,
        target: Point,
        leg_start: f64,
        arrive: f64,
        speed: f64,
        pause: f64,
        disc_r: f64,
        rng: Pcg64,
    ) -> Self {
        Self {
            anchor,
            target,
            leg_start,
            arrive,
            speed,
            pause,
            disc_r,
            rng,
        }
    }

    /// Position at absolute simulated time `t`. Calls must use
    /// non-decreasing `t` (the walker advances through its legs and never
    /// rewinds).
    pub fn position_at(&mut self, t: f64) -> Point {
        while t >= self.arrive {
            self.anchor = self.target;
            let arrived = self.arrive;
            self.next_leg(arrived);
        }
        if t <= self.leg_start {
            self.anchor
        } else {
            let frac = (t - self.leg_start) / (self.arrive - self.leg_start);
            Point::new(
                self.anchor.x + (self.target.x - self.anchor.x) * frac,
                self.anchor.y + (self.target.y - self.anchor.y) * frac,
            )
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn walker(seed: u64) -> Waypoint {
        Waypoint::new(
            Point::new(100.0, -50.0),
            10.0,
            2.0,
            750.0,
            Pcg64::new(seed, 77),
        )
    }

    #[test]
    fn stays_inside_disc_and_moves() {
        let mut w = walker(1);
        let mut moved = false;
        let mut prev = w.position_at(0.0);
        for i in 1..400 {
            let p = w.position_at(i as f64 * 5.0);
            assert!(p.norm() <= 750.0 + 1e-6, "escaped the disc: {p:?}");
            if p.dist(&prev) > 1.0 {
                moved = true;
            }
            prev = p;
        }
        assert!(moved, "walker never moved");
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = walker(42);
        let mut b = walker(42);
        let mut c = walker(43);
        let mut diverged = false;
        for i in 0..200 {
            let t = i as f64 * 7.5;
            let pa = a.position_at(t);
            let pb = b.position_at(t);
            assert_eq!(pa, pb, "same seed must give the same trace");
            if pa.dist(&c.position_at(t)) > 1.0 {
                diverged = true;
            }
        }
        assert!(diverged, "different seeds should give different traces");
    }

    #[test]
    fn pauses_at_waypoints() {
        // Immediately after construction the walker pauses at its start.
        let mut w = walker(7);
        let p0 = w.position_at(0.0);
        let p1 = w.position_at(1.0); // pause is 2 s
        assert_eq!(p0, p1, "walker must pause before departing");
        let p3 = w.position_at(3.0);
        assert!(p3.dist(&p0) > 0.0, "walker must depart after the pause");
    }

    #[test]
    fn speed_bounds_displacement() {
        let mut w = walker(9);
        let mut prev = w.position_at(0.0);
        for i in 1..300 {
            let t = i as f64;
            let p = w.position_at(t);
            // 10 m/s ⇒ at most 10 m per second step (pauses make it less).
            assert!(p.dist(&prev) <= 10.0 + 1e-9, "too fast at t={t}");
            prev = p;
        }
    }

    #[test]
    fn raw_state_roundtrip_continues_the_trace() {
        let mut a = walker(11);
        // Advance mid-trace so the round trip carries a live leg.
        let _ = a.position_at(123.0);
        let (anchor, target, leg_start, arrive, speed, pause, disc_r, rng) = a.raw_state();
        let mut b = Waypoint::from_raw_state(
            anchor,
            target,
            leg_start,
            arrive,
            speed,
            pause,
            disc_r,
            rng.clone(),
        );
        for i in 0..300 {
            let t = 123.0 + i as f64 * 3.7;
            assert_eq!(a.position_at(t), b.position_at(t), "diverged at t={t}");
        }
    }

    #[test]
    fn zero_speed_never_moves() {
        let mut w = Waypoint::new(
            Point::new(5.0, 5.0),
            0.0,
            1.0,
            750.0,
            Pcg64::new(3, 3),
        );
        for i in 0..50 {
            assert_eq!(w.position_at(i as f64 * 100.0), Point::new(5.0, 5.0));
        }
    }
}
