//! Sparse vector wire format: parallel `(index, value)` arrays, the exact
//! message DGC transmits. Provides dense↔sparse conversion, in-place
//! accumulation (the aggregation primitive of MBS/SBS), the bit accounting
//! used by the latency model (`Q̂ + ⌈log2 Q⌉` bits per surviving
//! coordinate), and the delta-packed realized byte stream ([`SparseWire`]).

/// A sparse view of a length-`dim` f32 vector.
///
/// **Invariant: `indices` is strictly ascending (sorted, unique), every
/// index is `< dim`, and `values` is equally long.** Every producer in
/// the crate maintains it — DGC and
/// the discounted-error encoders extract coordinates in one ascending
/// scan, [`SparseVec::from_mask`] walks the dense vector front to back,
/// the k-way merge ([`crate::sparse::merge`]) emits a sorted union, and
/// [`SparseWire::decode_into`] reconstructs ascending indices from
/// non-negative gaps. The merge kernel and the wire codec *rely* on it
/// (`debug_assert`ed there; [`SparseWire::encode`] asserts it
/// unconditionally, since a violated invariant would silently corrupt the
/// delta encoding). Check with [`SparseVec::is_sorted_unique`].
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SparseVec {
    /// Logical dense length Q.
    pub dim: usize,
    /// Sorted, distinct coordinate indices (see the struct invariant).
    pub indices: Vec<u32>,
    /// Values aligned with `indices`.
    pub values: Vec<f32>,
}

impl SparseVec {
    pub fn empty(dim: usize) -> Self {
        Self {
            dim,
            indices: Vec::new(),
            values: Vec::new(),
        }
    }

    /// Collect every coordinate of `dense` where `keep` is true.
    pub fn from_mask(dense: &[f32], keep: impl Fn(usize, f32) -> bool) -> Self {
        let mut out = Self::empty(dense.len());
        for (i, &x) in dense.iter().enumerate() {
            if keep(i, x) {
                out.indices.push(i as u32);
                out.values.push(x);
            }
        }
        out
    }

    /// Collect coordinates with |x| ≥ threshold.
    pub fn from_threshold(dense: &[f32], threshold: f32) -> Self {
        Self::from_mask(dense, |_, x| x.abs() >= threshold)
    }

    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Check the struct invariant: indices strictly ascending and `< dim`,
    /// and the parallel arrays equally long.
    pub fn is_sorted_unique(&self) -> bool {
        self.indices.len() == self.values.len()
            && self.indices.windows(2).all(|w| w[0] < w[1])
            && match self.indices.last() {
                Some(&i) => (i as usize) < self.dim,
                None => true,
            }
    }

    /// Reserve room for at least `additional` more entries in both parallel
    /// arrays — the reuse paths (`step_into`/`compress_into`) call this
    /// with the expected survivor count so a warm buffer never reallocates
    /// mid-extraction.
    pub fn reserve(&mut self, additional: usize) {
        self.indices.reserve(additional);
        self.values.reserve(additional);
    }

    /// `values[j] *= a` — the sparse counterpart of
    /// [`crate::tensor::kernels::scale`] over the carried coordinates
    /// (same per-element expression, bit-identical on them).
    pub fn scale_values(&mut self, a: f32) {
        for v in self.values.iter_mut() {
            *v *= a;
        }
    }

    /// Achieved sparsity φ = 1 − nnz/dim.
    pub fn sparsity(&self) -> f64 {
        1.0 - self.nnz() as f64 / self.dim.max(1) as f64
    }

    /// Wire size in bits: each entry carries a ⌈log2 dim⌉-bit index and a
    /// `bits_per_value`-bit value. (A dense message would be dim × Q̂.)
    pub fn wire_bits(&self, bits_per_value: u32) -> f64 {
        let index_bits = (self.dim.max(2) as f64).log2().ceil();
        self.nnz() as f64 * (bits_per_value as f64 + index_bits)
    }

    /// Scatter-add into a dense buffer: `out[i] += scale·v_i` (fused kernel,
    /// bit-identical to the naive loop).
    pub fn add_into(&self, out: &mut [f32], scale: f32) {
        assert_eq!(out.len(), self.dim, "dimension mismatch");
        crate::tensor::kernels::scatter_add(out, &self.indices, &self.values, scale);
    }

    /// Materialize as dense.
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0; self.dim];
        self.add_into(&mut out, 1.0);
        out
    }

    /// Sum of several sparse vectors into one dense accumulator (the MBS/SBS
    /// aggregation step). Scale is applied uniformly (e.g. 1/K).
    pub fn aggregate(parts: &[SparseVec], scale: f32) -> Vec<f32> {
        assert!(!parts.is_empty());
        let dim = parts[0].dim;
        let mut out = vec![0.0; dim];
        for p in parts {
            assert_eq!(p.dim, dim, "dimension mismatch in aggregate");
            p.add_into(&mut out, scale);
        }
        out
    }

    /// L2 mass of the carried values.
    pub fn l2(&self) -> f64 {
        self.values.iter().map(|&v| (v as f64).powi(2)).sum::<f64>().sqrt()
    }
}

// ---------------------------------------------------------------------------
// SparseWire: the realized byte stream
// ---------------------------------------------------------------------------

/// Append-only little-endian bit packer over `u64` words.
#[derive(Debug, Default)]
struct BitWriter {
    words: Vec<u64>,
    bit_len: usize,
}

impl BitWriter {
    /// Append the low `bits` bits of `value`.
    fn push(&mut self, value: u64, bits: u32) {
        debug_assert!(bits <= 64);
        debug_assert!(bits == 64 || value < (1u64 << bits), "value {value} overflows {bits} bits");
        if bits == 0 {
            return;
        }
        let word = self.bit_len / 64;
        let off = (self.bit_len % 64) as u32;
        if word == self.words.len() {
            self.words.push(0);
        }
        self.words[word] |= value << off;
        if off + bits > 64 {
            self.words.push(value >> (64 - off));
        }
        self.bit_len += bits as usize;
    }
}

/// Sequential reader over a [`BitWriter`]'s words.
struct BitReader<'a> {
    words: &'a [u64],
    pos: usize,
}

impl BitReader<'_> {
    fn read(&mut self, bits: u32) -> u64 {
        debug_assert!(bits <= 64);
        if bits == 0 {
            return 0;
        }
        let word = self.pos / 64;
        let off = (self.pos % 64) as u32;
        let mut v = self.words[word] >> off;
        if off + bits > 64 {
            v |= self.words[word + 1] << (64 - off);
        }
        self.pos += bits as usize;
        if bits == 64 {
            v
        } else {
            v & ((1u64 << bits) - 1)
        }
    }
}

/// Delta-encoded, bit-packed wire form of a [`SparseVec`] — the byte
/// stream a DGC message actually occupies on the uplink.
///
/// Layout (one contiguous bit stream, little-endian within `u64` words):
///
/// ```text
/// [ gap₀ | gap₁ | … | gap_{n−1} ][ v₀ | v₁ | … | v_{n−1} ]
///   └──────── gap_bits each ───┘  └───── 32 bits each ───┘
/// gap₀ = idx₀,   gap_j = idx_j − idx_{j−1} − 1   (strictly-ascending ⇒ ≥ 0)
/// ```
///
/// `gap_bits` is the per-message width of the largest gap, so
/// [`SparseWire::encoded_bits`] `= nnz · (gap_bits + 32)` is **never more
/// than** the fixed-width accounting `nnz · (⌈log2 dim⌉ + 32)` that
/// [`SparseVec::wire_bits`] / [`crate::wireless::latency::payload_bits`]
/// price (a gap cannot exceed `dim − 1`, which needs exactly
/// `⌈log2 dim⌉` bits) — asserted by the round-trip property suite. The
/// engines keep *billing* the conservative fixed-width form, so golden
/// traces and the latency model are unchanged; `SparseWire` is the
/// realized stream those prices are an upper bound for.
///
/// Round-trips exactly: indices and f32 **bit patterns** (NaN payloads,
/// ±0.0 signs) survive encode→decode untouched.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseWire {
    /// Logical dense length Q of the encoded vector.
    pub dim: usize,
    /// Number of encoded coordinates.
    pub nnz: usize,
    /// Bit width of each packed index gap (0 when every gap is 0).
    gap_bits: u32,
    /// The packed payload.
    words: Vec<u64>,
}

impl SparseWire {
    /// Bits needed to represent `x` (0 for 0).
    #[inline]
    fn bits_for(x: u32) -> u32 {
        32 - x.leading_zeros()
    }

    /// Encode `v` (asserts the [`SparseVec`] sorted-unique invariant — a
    /// violation would corrupt the delta stream silently).
    pub fn encode(v: &SparseVec) -> Self {
        assert!(
            v.is_sorted_unique(),
            "SparseWire::encode requires sorted unique indices < dim"
        );
        let mut max_gap = 0u32;
        let mut prev: i64 = -1;
        for &i in &v.indices {
            let gap = (i as i64 - prev - 1) as u32;
            max_gap = max_gap.max(gap);
            prev = i as i64;
        }
        let gap_bits = Self::bits_for(max_gap);
        let mut w = BitWriter::default();
        let mut prev: i64 = -1;
        for &i in &v.indices {
            w.push((i as i64 - prev - 1) as u64, gap_bits);
            prev = i as i64;
        }
        for &x in &v.values {
            w.push(x.to_bits() as u64, 32);
        }
        Self {
            dim: v.dim,
            nnz: v.indices.len(),
            gap_bits,
            words: w.words,
        }
    }

    /// Decode into a reusable [`SparseVec`] (exact: same indices, same
    /// value bit patterns).
    pub fn decode_into(&self, out: &mut SparseVec) {
        out.dim = self.dim;
        out.indices.clear();
        out.values.clear();
        out.reserve(self.nnz);
        let mut r = BitReader {
            words: &self.words,
            pos: 0,
        };
        let mut prev: i64 = -1;
        for _ in 0..self.nnz {
            let gap = r.read(self.gap_bits) as i64;
            let idx = prev + 1 + gap;
            out.indices.push(idx as u32);
            prev = idx;
        }
        for _ in 0..self.nnz {
            out.values.push(f32::from_bits(r.read(32) as u32));
        }
    }

    /// Decode into a fresh [`SparseVec`].
    pub fn decode(&self) -> SparseVec {
        let mut out = SparseVec::empty(self.dim);
        self.decode_into(&mut out);
        out
    }

    /// Realized payload size in bits: `nnz · (gap_bits + 32)` — never more
    /// than the fixed-width [`SparseVec::wire_bits`]`(32)` pricing.
    pub fn encoded_bits(&self) -> u64 {
        self.nnz as u64 * (self.gap_bits as u64 + 32)
    }

    /// Backing storage in `u64` words (for transport-size accounting).
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Per-message bit width of the packed index gaps (for serialization).
    pub fn gap_bits(&self) -> u32 {
        self.gap_bits
    }

    /// Reassemble a wire message from its serialized parts (the network
    /// deserialization entry point). Validates the *shape* — `gap_bits`
    /// width and exact word count — but not the index stream itself; use
    /// [`SparseWire::decode_checked`] on untrusted input.
    pub fn from_parts(
        dim: usize,
        nnz: usize,
        gap_bits: u32,
        words: Vec<u64>,
    ) -> anyhow::Result<Self> {
        if gap_bits > 32 {
            anyhow::bail!("SparseWire gap_bits {gap_bits} > 32");
        }
        let total_bits = nnz * (gap_bits as usize + 32);
        let expect_words = total_bits.div_ceil(64);
        if words.len() != expect_words {
            anyhow::bail!(
                "SparseWire word count {} != {expect_words} (nnz {nnz}, gap_bits {gap_bits})",
                words.len()
            );
        }
        Ok(Self {
            dim,
            nnz,
            gap_bits,
            words,
        })
    }

    /// Decode with full index validation — gaps are accumulated in i64 so
    /// a corrupt stream whose indices run past `dim` (or past `u32`) is a
    /// named error instead of a wrapped index that would silently corrupt
    /// (or panic inside) the downstream scatter-add. Use at trust
    /// boundaries; [`SparseWire::decode`] stays the cheap in-process path.
    pub fn decode_checked(&self) -> anyhow::Result<SparseVec> {
        let mut out = SparseVec::empty(self.dim);
        out.reserve(self.nnz);
        let mut r = BitReader {
            words: &self.words,
            pos: 0,
        };
        let mut prev: i64 = -1;
        for j in 0..self.nnz {
            let gap = r.read(self.gap_bits) as i64;
            let idx = prev + 1 + gap;
            if idx >= self.dim as i64 || idx > u32::MAX as i64 {
                anyhow::bail!(
                    "SparseWire corrupt: decoded index {idx} (entry {j}) outside dim {}",
                    self.dim
                );
            }
            out.indices.push(idx as u32);
            prev = idx;
        }
        for _ in 0..self.nnz {
            out.values.push(f32::from_bits(r.read(32) as u32));
        }
        debug_assert!(out.is_sorted_unique());
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, PropConfig, VecF32};
    use crate::util::rng::Pcg64;

    #[test]
    fn threshold_roundtrip() {
        let dense = vec![0.0, 1.5, -0.2, 3.0, -4.0, 0.1];
        let s = SparseVec::from_threshold(&dense, 1.0);
        assert_eq!(s.indices, vec![1, 3, 4]);
        assert_eq!(s.values, vec![1.5, 3.0, -4.0]);
        let back = s.to_dense();
        assert_eq!(back, vec![0.0, 1.5, 0.0, 3.0, -4.0, 0.0]);
    }

    #[test]
    fn wire_bits_accounting() {
        let mut s = SparseVec::empty(1 << 20);
        s.indices = vec![1, 2, 3];
        s.values = vec![1.0, 2.0, 3.0];
        // 20 index bits + 32 value bits
        assert_eq!(s.wire_bits(32), 3.0 * 52.0);
    }

    #[test]
    fn aggregate_averages() {
        let a = SparseVec::from_threshold(&[1.0, 0.0, 2.0], 0.5);
        let b = SparseVec::from_threshold(&[0.0, 4.0, 2.0], 0.5);
        let sum = SparseVec::aggregate(&[a, b], 0.5);
        assert_eq!(sum, vec![0.5, 2.0, 2.0]);
    }

    #[test]
    fn prop_sparse_dense_roundtrip_preserves_kept_coords() {
        let gen = VecF32 { min_len: 1, max_len: 300, scale: 2.0 };
        check(&PropConfig::default(), &gen, |v| {
            let th = 0.7f32;
            let s = SparseVec::from_threshold(v, th);
            let dense = s.to_dense();
            for (i, (&orig, &rec)) in v.iter().zip(&dense).enumerate() {
                let want = if orig.abs() >= th { orig } else { 0.0 };
                if rec != want {
                    return Err(format!("coord {i}: {rec} != {want}"));
                }
            }
            // Indices sorted and distinct.
            if !s.indices.windows(2).all(|w| w[0] < w[1]) {
                return Err("indices not sorted/distinct".into());
            }
            Ok(())
        });
    }

    #[test]
    fn prop_mass_conservation_under_split() {
        // sparse(v) + residual(v) == v exactly, coordinate-wise.
        let gen = VecF32 { min_len: 1, max_len: 200, scale: 1.0 };
        check(&PropConfig::default(), &gen, |v| {
            let th = 0.5f32;
            let kept = SparseVec::from_threshold(v, th);
            let resid = SparseVec::from_mask(v, |_, x| x.abs() < th);
            if kept.nnz() + resid.nnz() != v.len() {
                return Err("split is not a partition".into());
            }
            let mut sum = kept.to_dense();
            resid.add_into(&mut sum, 1.0);
            if sum != *v {
                return Err("kept + residual != original".into());
            }
            Ok(())
        });
    }

    #[test]
    fn empty_and_full_extremes() {
        let v = vec![1.0f32, -2.0, 3.0];
        let none = SparseVec::from_threshold(&v, f32::INFINITY);
        assert_eq!(none.nnz(), 0);
        assert_eq!(none.sparsity(), 1.0);
        let all = SparseVec::from_threshold(&v, 0.0);
        assert_eq!(all.nnz(), 3);
        assert_eq!(all.sparsity(), 0.0);
        assert_eq!(all.to_dense(), v);
    }

    #[test]
    fn add_into_scale() {
        let s = SparseVec::from_threshold(&[2.0, 0.0], 1.0);
        let mut acc = vec![1.0f32, 1.0];
        s.add_into(&mut acc, -0.5);
        assert_eq!(acc, vec![0.0, 1.0]);
    }

    #[test]
    fn sorted_unique_invariant_check() {
        let ok = SparseVec { dim: 10, indices: vec![0, 3, 9], values: vec![1.0; 3] };
        assert!(ok.is_sorted_unique());
        assert!(SparseVec::empty(0).is_sorted_unique());
        let dup = SparseVec { dim: 10, indices: vec![0, 3, 3], values: vec![1.0; 3] };
        assert!(!dup.is_sorted_unique());
        let ragged = SparseVec { dim: 10, indices: vec![0, 3], values: vec![1.0; 3] };
        assert!(!ragged.is_sorted_unique());
        let unsorted = SparseVec { dim: 10, indices: vec![3, 0], values: vec![1.0; 2] };
        assert!(!unsorted.is_sorted_unique());
        let oob = SparseVec { dim: 10, indices: vec![0, 10], values: vec![1.0; 2] };
        assert!(!oob.is_sorted_unique());
    }

    #[test]
    fn wire_roundtrip_exact_and_within_priced_bits() {
        let mut rng = Pcg64::seeded(77);
        for dim in [1usize, 2, 7, 64, 1000, 1 << 14] {
            for keep in [0.0f64, 0.01, 0.3, 1.0] {
                let mut v = SparseVec::empty(dim);
                for i in 0..dim {
                    if rng.uniform() < keep {
                        v.indices.push(i as u32);
                        v.values.push(rng.normal() as f32);
                    }
                }
                let wire = SparseWire::encode(&v);
                let back = wire.decode();
                assert_eq!(back.dim, v.dim);
                assert_eq!(back.indices, v.indices, "dim={dim} keep={keep}");
                let bits = |s: &SparseVec| s.values.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                assert_eq!(bits(&back), bits(&v), "dim={dim} keep={keep}");
                // The realized stream never exceeds what payload_bits prices.
                assert!(
                    wire.encoded_bits() as f64 <= v.wire_bits(32) + 1e-9,
                    "dim={dim} keep={keep}: {} > {}",
                    wire.encoded_bits(),
                    v.wire_bits(32)
                );
            }
        }
    }

    #[test]
    fn wire_preserves_value_bit_patterns() {
        // ±0.0 and NaN payloads must survive the 32-bit value packing.
        let v = SparseVec {
            dim: 8,
            indices: vec![0, 2, 5, 7],
            values: vec![-0.0, f32::from_bits(0x7fc0_1234), f32::MIN_POSITIVE / 2.0, -1.5e-39],
        };
        let back = SparseWire::encode(&v).decode();
        for (a, b) in v.values.iter().zip(&back.values) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn wire_dense_run_uses_zero_gap_bits() {
        // Consecutive indices ⇒ every gap is 0 ⇒ 32 bits per value only.
        let v = SparseVec { dim: 100, indices: (0..100).collect(), values: vec![1.0; 100] };
        let wire = SparseWire::encode(&v);
        assert_eq!(wire.encoded_bits(), 100 * 32);
        assert_eq!(wire.decode(), v);
        assert!(!wire.words().is_empty());
    }

    #[test]
    #[should_panic(expected = "sorted unique")]
    fn wire_rejects_invariant_violation() {
        let bad = SparseVec { dim: 4, indices: vec![2, 1], values: vec![1.0, 2.0] };
        let _ = SparseWire::encode(&bad);
    }

    #[test]
    fn wire_from_parts_roundtrip_and_validation() {
        let v = SparseVec { dim: 50, indices: vec![3, 17, 49], values: vec![1.0, -2.5, 0.125] };
        let wire = SparseWire::encode(&v);
        let rebuilt = SparseWire::from_parts(
            wire.dim,
            wire.nnz,
            wire.gap_bits(),
            wire.words().to_vec(),
        )
        .unwrap();
        assert_eq!(rebuilt, wire);
        assert_eq!(rebuilt.decode_checked().unwrap(), v);
        // Shape violations are named errors, not panics.
        assert!(SparseWire::from_parts(50, 3, 40, wire.words().to_vec()).is_err());
        assert!(SparseWire::from_parts(50, 3, wire.gap_bits(), Vec::new()).is_err());
    }

    #[test]
    fn wire_decode_checked_rejects_out_of_range_indices() {
        // Craft a stream whose gaps walk past dim: one entry, gap 7 ⇒
        // index 7 ≥ dim 4.
        let v = SparseVec { dim: 8, indices: vec![7], values: vec![1.0] };
        let wire = SparseWire::encode(&v);
        let bad = SparseWire::from_parts(4, wire.nnz, wire.gap_bits(), wire.words().to_vec())
            .unwrap();
        let err = bad.decode_checked().unwrap_err().to_string();
        assert!(err.contains("outside dim"), "{err}");
        // The honest stream decodes clean.
        assert_eq!(wire.decode_checked().unwrap(), v);
    }

    #[test]
    fn random_large_vector_sparsity_matches_threshold_fraction() {
        let mut rng = Pcg64::seeded(31);
        let v: Vec<f32> = (0..50_000).map(|_| rng.normal() as f32).collect();
        // |N(0,1)| ≥ 1.96 with prob ≈ 0.05
        let s = SparseVec::from_threshold(&v, 1.96);
        let frac = s.nnz() as f64 / v.len() as f64;
        assert!((frac - 0.05).abs() < 0.01, "kept fraction {frac}");
    }
}
