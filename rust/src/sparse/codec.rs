//! Sparse vector wire format: parallel `(index, value)` arrays, the exact
//! message DGC transmits. Provides dense↔sparse conversion, in-place
//! accumulation (the aggregation primitive of MBS/SBS), and the bit
//! accounting used by the latency model (`Q̂ + ⌈log2 Q⌉` bits per surviving
//! coordinate).

/// A sparse view of a length-`dim` f32 vector.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SparseVec {
    /// Logical dense length Q.
    pub dim: usize,
    /// Sorted, distinct coordinate indices.
    pub indices: Vec<u32>,
    /// Values aligned with `indices`.
    pub values: Vec<f32>,
}

impl SparseVec {
    pub fn empty(dim: usize) -> Self {
        Self {
            dim,
            indices: Vec::new(),
            values: Vec::new(),
        }
    }

    /// Collect every coordinate of `dense` where `keep` is true.
    pub fn from_mask(dense: &[f32], keep: impl Fn(usize, f32) -> bool) -> Self {
        let mut out = Self::empty(dense.len());
        for (i, &x) in dense.iter().enumerate() {
            if keep(i, x) {
                out.indices.push(i as u32);
                out.values.push(x);
            }
        }
        out
    }

    /// Collect coordinates with |x| ≥ threshold.
    pub fn from_threshold(dense: &[f32], threshold: f32) -> Self {
        Self::from_mask(dense, |_, x| x.abs() >= threshold)
    }

    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Achieved sparsity φ = 1 − nnz/dim.
    pub fn sparsity(&self) -> f64 {
        1.0 - self.nnz() as f64 / self.dim.max(1) as f64
    }

    /// Wire size in bits: each entry carries a ⌈log2 dim⌉-bit index and a
    /// `bits_per_value`-bit value. (A dense message would be dim × Q̂.)
    pub fn wire_bits(&self, bits_per_value: u32) -> f64 {
        let index_bits = (self.dim.max(2) as f64).log2().ceil();
        self.nnz() as f64 * (bits_per_value as f64 + index_bits)
    }

    /// Scatter-add into a dense buffer: `out[i] += scale·v_i` (fused kernel,
    /// bit-identical to the naive loop).
    pub fn add_into(&self, out: &mut [f32], scale: f32) {
        assert_eq!(out.len(), self.dim, "dimension mismatch");
        crate::tensor::kernels::scatter_add(out, &self.indices, &self.values, scale);
    }

    /// Materialize as dense.
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0; self.dim];
        self.add_into(&mut out, 1.0);
        out
    }

    /// Sum of several sparse vectors into one dense accumulator (the MBS/SBS
    /// aggregation step). Scale is applied uniformly (e.g. 1/K).
    pub fn aggregate(parts: &[SparseVec], scale: f32) -> Vec<f32> {
        assert!(!parts.is_empty());
        let dim = parts[0].dim;
        let mut out = vec![0.0; dim];
        for p in parts {
            assert_eq!(p.dim, dim, "dimension mismatch in aggregate");
            p.add_into(&mut out, scale);
        }
        out
    }

    /// L2 mass of the carried values.
    pub fn l2(&self) -> f64 {
        self.values.iter().map(|&v| (v as f64).powi(2)).sum::<f64>().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, PropConfig, VecF32};
    use crate::util::rng::Pcg64;

    #[test]
    fn threshold_roundtrip() {
        let dense = vec![0.0, 1.5, -0.2, 3.0, -4.0, 0.1];
        let s = SparseVec::from_threshold(&dense, 1.0);
        assert_eq!(s.indices, vec![1, 3, 4]);
        assert_eq!(s.values, vec![1.5, 3.0, -4.0]);
        let back = s.to_dense();
        assert_eq!(back, vec![0.0, 1.5, 0.0, 3.0, -4.0, 0.0]);
    }

    #[test]
    fn wire_bits_accounting() {
        let mut s = SparseVec::empty(1 << 20);
        s.indices = vec![1, 2, 3];
        s.values = vec![1.0, 2.0, 3.0];
        // 20 index bits + 32 value bits
        assert_eq!(s.wire_bits(32), 3.0 * 52.0);
    }

    #[test]
    fn aggregate_averages() {
        let a = SparseVec::from_threshold(&[1.0, 0.0, 2.0], 0.5);
        let b = SparseVec::from_threshold(&[0.0, 4.0, 2.0], 0.5);
        let sum = SparseVec::aggregate(&[a, b], 0.5);
        assert_eq!(sum, vec![0.5, 2.0, 2.0]);
    }

    #[test]
    fn prop_sparse_dense_roundtrip_preserves_kept_coords() {
        let gen = VecF32 { min_len: 1, max_len: 300, scale: 2.0 };
        check(&PropConfig::default(), &gen, |v| {
            let th = 0.7f32;
            let s = SparseVec::from_threshold(v, th);
            let dense = s.to_dense();
            for (i, (&orig, &rec)) in v.iter().zip(&dense).enumerate() {
                let want = if orig.abs() >= th { orig } else { 0.0 };
                if rec != want {
                    return Err(format!("coord {i}: {rec} != {want}"));
                }
            }
            // Indices sorted and distinct.
            if !s.indices.windows(2).all(|w| w[0] < w[1]) {
                return Err("indices not sorted/distinct".into());
            }
            Ok(())
        });
    }

    #[test]
    fn prop_mass_conservation_under_split() {
        // sparse(v) + residual(v) == v exactly, coordinate-wise.
        let gen = VecF32 { min_len: 1, max_len: 200, scale: 1.0 };
        check(&PropConfig::default(), &gen, |v| {
            let th = 0.5f32;
            let kept = SparseVec::from_threshold(v, th);
            let resid = SparseVec::from_mask(v, |_, x| x.abs() < th);
            if kept.nnz() + resid.nnz() != v.len() {
                return Err("split is not a partition".into());
            }
            let mut sum = kept.to_dense();
            resid.add_into(&mut sum, 1.0);
            if sum != *v {
                return Err("kept + residual != original".into());
            }
            Ok(())
        });
    }

    #[test]
    fn empty_and_full_extremes() {
        let v = vec![1.0f32, -2.0, 3.0];
        let none = SparseVec::from_threshold(&v, f32::INFINITY);
        assert_eq!(none.nnz(), 0);
        assert_eq!(none.sparsity(), 1.0);
        let all = SparseVec::from_threshold(&v, 0.0);
        assert_eq!(all.nnz(), 3);
        assert_eq!(all.sparsity(), 0.0);
        assert_eq!(all.to_dense(), v);
    }

    #[test]
    fn add_into_scale() {
        let s = SparseVec::from_threshold(&[2.0, 0.0], 1.0);
        let mut acc = vec![1.0f32, 1.0];
        s.add_into(&mut acc, -0.5);
        assert_eq!(acc, vec![0.0, 1.0]);
    }

    #[test]
    fn random_large_vector_sparsity_matches_threshold_fraction() {
        let mut rng = Pcg64::seeded(31);
        let v: Vec<f32> = (0..50_000).map(|_| rng.normal() as f32).collect();
        // |N(0,1)| ≥ 1.96 with prob ≈ 0.05
        let s = SparseVec::from_threshold(&v, 1.96);
        let frac = s.nnz() as f64 / v.len() as f64;
        assert!((frac - 0.05).abs() < 0.01, "kept fraction {frac}");
    }
}
