//! Q̂-bit value quantization (§II-A: "Each MU uses Q̂ bits to quantize each
//! element of its gradient vector").
//!
//! The latency model charges `Q̂ + ⌈log2 Q⌉` bits per transmitted value; this
//! module supplies the actual quantizer so the end-to-end system can trade
//! `Q̂` against accuracy (an ablation the paper's model enables but does not
//! plot — see `EXPERIMENTS.md §Extensions`).
//!
//! Scheme: per-message symmetric uniform quantization. The encoder finds
//! `m = max|v|`, sends it once at full precision (32 bits, amortized), and
//! maps every value to a signed integer of `bits` bits:
//! `q = round(v / m · (2^{bits−1} − 1))`. Deterministic, zero-preserving,
//! and unbiased up to rounding.

use super::codec::SparseVec;

/// A quantized sparse message plus its dequantization scale.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantizedVec {
    pub dim: usize,
    pub indices: Vec<u32>,
    /// Quantized levels in `[-(2^{bits-1}-1), 2^{bits-1}-1]`.
    pub levels: Vec<i32>,
    /// Dequantization scale `m / (2^{bits-1}-1)`.
    pub scale: f32,
    pub bits: u32,
}

impl QuantizedVec {
    /// Quantize a sparse message to `bits`-bit values (2 ≤ bits ≤ 32).
    pub fn encode(v: &SparseVec, bits: u32) -> Self {
        assert!((2..=32).contains(&bits), "bits={bits} out of range");
        let qmax = ((1u64 << (bits - 1)) - 1) as f32;
        let m = v.values.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
        let scale = if m == 0.0 { 0.0 } else { m / qmax };
        let levels = v
            .values
            .iter()
            .map(|&x| {
                if scale == 0.0 {
                    0
                } else {
                    (x / scale).round().clamp(-qmax, qmax) as i32
                }
            })
            .collect();
        Self {
            dim: v.dim,
            indices: v.indices.clone(),
            levels,
            scale,
            bits,
        }
    }

    /// Reconstruct the sparse message (lossy).
    pub fn decode(&self) -> SparseVec {
        SparseVec {
            dim: self.dim,
            indices: self.indices.clone(),
            values: self.levels.iter().map(|&q| q as f32 * self.scale).collect(),
        }
    }

    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Wire size: per-entry index + `bits`-bit value, plus one 32-bit scale.
    pub fn wire_bits(&self) -> f64 {
        let index_bits = (self.dim.max(2) as f64).log2().ceil();
        self.nnz() as f64 * (self.bits as f64 + index_bits) + 32.0
    }

    /// Worst-case absolute quantization error of this message (scale/2).
    pub fn max_abs_error(&self) -> f32 {
        self.scale * 0.5
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, PropConfig, VecF32};

    fn sparse_of(values: &[f32]) -> SparseVec {
        SparseVec {
            dim: values.len(),
            indices: (0..values.len() as u32).collect(),
            values: values.to_vec(),
        }
    }

    #[test]
    fn roundtrip_error_bounded_by_half_step() {
        let v = sparse_of(&[0.5, -1.0, 0.125, 0.99, -0.33]);
        for bits in [4u32, 8, 12, 16] {
            let q = QuantizedVec::encode(&v, bits);
            let back = q.decode();
            for (a, b) in v.values.iter().zip(&back.values) {
                assert!(
                    (a - b).abs() <= q.max_abs_error() + 1e-7,
                    "bits={bits}: {a} vs {b} (step {})",
                    q.scale
                );
            }
        }
    }

    #[test]
    fn error_shrinks_with_bits() {
        let v = sparse_of(&[0.7, -0.3, 0.9, -0.05]);
        let mut prev = f32::INFINITY;
        for bits in [4u32, 8, 16, 24] {
            let q = QuantizedVec::encode(&v, bits);
            let back = q.decode();
            let err: f32 = v
                .values
                .iter()
                .zip(&back.values)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0, f32::max);
            assert!(err <= prev, "bits={bits}: {err} > {prev}");
            prev = err;
        }
        assert!(prev < 1e-6, "24-bit error should be tiny: {prev}");
    }

    #[test]
    fn zero_vector_and_empty_message() {
        let q = QuantizedVec::encode(&sparse_of(&[0.0, 0.0]), 8);
        assert_eq!(q.scale, 0.0);
        assert_eq!(q.decode().values, vec![0.0, 0.0]);
        let empty = SparseVec::empty(10);
        let q = QuantizedVec::encode(&empty, 8);
        assert_eq!(q.nnz(), 0);
        assert_eq!(q.wire_bits(), 32.0); // just the scale
    }

    #[test]
    fn max_magnitude_is_exactly_representable() {
        let v = sparse_of(&[2.0, -2.0, 1.0]);
        let q = QuantizedVec::encode(&v, 8);
        let back = q.decode();
        assert!((back.values[0] - 2.0).abs() < 1e-6);
        assert!((back.values[1] + 2.0).abs() < 1e-6);
    }

    #[test]
    fn wire_bits_accounting() {
        let mut s = SparseVec::empty(1 << 20);
        s.indices = vec![1, 2];
        s.values = vec![1.0, -1.0];
        let q = QuantizedVec::encode(&s, 8);
        // 2 × (8 + 20) + 32 scale
        assert_eq!(q.wire_bits(), 2.0 * 28.0 + 32.0);
    }

    #[test]
    fn prop_quantize_dequantize_monotone_and_bounded() {
        let gen = VecF32 {
            min_len: 1,
            max_len: 200,
            scale: 3.0,
        };
        check(&PropConfig::default(), &gen, |values| {
            let v = sparse_of(values);
            let q = QuantizedVec::encode(&v, 8);
            let back = q.decode();
            for (i, (&a, &b)) in v.values.iter().zip(&back.values).enumerate() {
                if (a - b).abs() > q.max_abs_error() + 1e-6 {
                    return Err(format!("coord {i}: |{a} - {b}| > {}", q.max_abs_error()));
                }
                // Sign preservation for values above one step.
                if a.abs() > q.scale && a.signum() != b.signum() {
                    return Err(format!("coord {i}: sign flipped {a} → {b}"));
                }
            }
            Ok(())
        });
    }
}
