//! Sparse-first aggregation: an allocation-free **k-way merge** that folds
//! N sparse updates into one sparse consensus in `O(Σnnz · log k)` — the
//! server-side replacement for scatter-adding every message into a dense
//! `dim`-length accumulator — plus the density-adaptive dispatch policy
//! ([`AggPolicy`]) the engines use to choose between the two paths, and the
//! [`DenseShadow`] bookkeeping that keeps the dense encoder-input buffer
//! bit-identical to the historical `zero → scatter → scale` sequence while
//! only touching `O(nnz)` coordinates per round.
//!
//! ## Bit-exactness contract
//!
//! The merge reproduces the MU-ordered dense fold **exactly**: for every
//! output coordinate `i` it computes
//!
//! ```text
//! acc = 0.0f32;  for each part j containing i (ascending j): acc += w_j · v_j[i]
//! ```
//!
//! which is the same f32 expression, in the same order, as the reference
//! `out[i] += w_j · v_j[i]` scatter fold over a zeroed accumulator
//! ([`crate::tensor::kernels::scatter_add`]). Ties (a coordinate present
//! in several parts) pop from the merge heap in ascending part order
//! because the heap key is `(index, part)` — so the result is
//! bit-identical to the dense path, and golden fixtures recorded against
//! the scatter engines pass unchanged. The pool-parallel variant
//! ([`merge_weighted_par`]) partitions the *coordinate space* into
//! contiguous per-lane ranges and merges each range independently; the
//! per-coordinate fold order is unchanged, so the concatenated result is
//! bit-identical for every width.
//!
//! The merge requires (and `debug_assert`s) the [`SparseVec`]
//! sorted-unique-index invariant — see the [`SparseVec`] docs.
//!
//! ## Robust consensus rules (`AggRule`)
//!
//! [`AggRule`] adds Byzantine-robust alternatives to the weighted-mean
//! fold on the *same* sorted-coordinate frontier the merge heap already
//! produces. At every coordinate in the support union the robust walk
//! collects one contribution per part — `x_j = (n · w_j) · v_j[i]` for a
//! part that carries the coordinate (so uniform `w = 1/n` weighting makes
//! `x_j ≈ v_j[i]`, and stale-discounted weights keep their discount), and
//! an exact `+0.0` for each absent part — then applies the statistic:
//!
//! * [`AggRule::TrimmedMean`]`(k)` — sort ascending, drop the `k` smallest
//!   and `k` largest, average the rest (summed in ascending order). If a
//!   site has fewer than `2k + 1` live parts (client churn), `k` is
//!   clamped to `⌊(n − 1)/2⌋` so the statistic stays defined; impossible
//!   *configured* shapes are refused at startup by
//!   [`AggPolicy::validate_participants`].
//! * [`AggRule::CoordMedian`] — sort ascending; odd `n` takes the middle
//!   value, even `n` takes `0.5 · (lower + upper)`.
//!
//! **Tie/order contract:** values are ordered by `f32::total_cmp`, which
//! is equality exactly on identical bit patterns — so the sort (unstable
//! or not) and the subsequent ascending-order sum are deterministic for
//! any thread count and any input permutation of equal values. `−0.0`
//! sorts below `+0.0`; NaNs (never produced by honest parts) order by
//! sign and payload instead of poisoning the comparison.
//!
//! `AggRule::Mean` never routes through the robust walk: it dispatches to
//! the exact weighted fold above, byte-identical to every trace recorded
//! before the rule existed.
//!
//! ## The −0.0 emulation (`DenseShadow`)
//!
//! The reference round aggregation ends with `scale(agg, -lr)`, which turns
//! every *untouched* coordinate into `+0.0 · (−lr) = −0.0`. A sparse path
//! that leaves untouched coordinates at `+0.0` would hand the downstream
//! encoder a buffer differing in the sign bit of zero — harmless in value
//! but visible to the `to_bits` golden contract in pathological
//! cancellation cases. [`DenseShadow::write`] therefore restores the
//! previous round's touched coordinates to the exact baseline bit pattern
//! (`−0.0` for post-scale round aggregates, `+0.0` for sync aggregates)
//! before writing the merged consensus, falling back to one full
//! `fill(baseline)` only when the baseline changes or the buffer was last
//! written by the dense path.

use super::codec::SparseVec;
use crate::pool::PoolHandle;
use anyhow::{bail, Result};
use std::sync::Mutex;

/// Which aggregation path the engines take at their SBS/MBS call sites.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum AggPath {
    /// Measure the round's total message nnz and pick the faster path
    /// against [`AggPolicy::crossover`].
    #[default]
    Auto,
    /// Always k-way merge (bit-identical to `Dense`, different wall-clock).
    Sparse,
    /// Always dense scatter-add — the historical path, byte for byte.
    Dense,
}

impl AggPath {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "auto" => Ok(AggPath::Auto),
            "sparse" => Ok(AggPath::Sparse),
            "dense" => Ok(AggPath::Dense),
            other => bail!("unknown aggregation path `{other}` (expected auto|sparse|dense)"),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            AggPath::Auto => "auto",
            AggPath::Sparse => "sparse",
            AggPath::Dense => "dense",
        }
    }
}

/// Which consensus statistic the aggregation computes per coordinate.
///
/// `Mean` is the historical weighted fold (bit-identical to the dense
/// scatter reference); the robust rules defend against Byzantine parts —
/// see the module docs for the exact per-coordinate contract.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum AggRule {
    /// Weighted mean — the reference fold, byte-identical to the
    /// pre-robustness engines at every call site.
    #[default]
    Mean,
    /// Drop the `k` smallest and `k` largest per-coordinate contributions,
    /// average the rest. Tolerates up to `k` Byzantine parts per site.
    TrimmedMean(usize),
    /// Coordinate-wise median. Tolerates up to `⌊(n−1)/2⌋` Byzantine parts.
    CoordMedian,
}

impl AggRule {
    /// Parse a `--agg-rule` / `[agg] rule` value. `trim_k` supplies the
    /// trim depth (`--agg-trim`) when the rule is `trimmed-mean`.
    pub fn parse(s: &str, trim_k: usize) -> Result<Self> {
        match s {
            "mean" => Ok(AggRule::Mean),
            "trimmed-mean" => Ok(AggRule::TrimmedMean(trim_k)),
            "coord-median" => Ok(AggRule::CoordMedian),
            other => {
                bail!("unknown aggregation rule `{other}` (expected mean|trimmed-mean|coord-median)")
            }
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            AggRule::Mean => "mean",
            AggRule::TrimmedMean(_) => "trimmed-mean",
            AggRule::CoordMedian => "coord-median",
        }
    }

    /// Short stable tag for scenario names (`-trim1`, `-median`); `Mean`
    /// is the default and carries no tag.
    pub fn label(&self) -> String {
        match self {
            AggRule::Mean => "mean".to_string(),
            AggRule::TrimmedMean(k) => format!("trim{k}"),
            AggRule::CoordMedian => "median".to_string(),
        }
    }

    /// The per-coordinate statistic over the collected contributions
    /// (present parts only — absent parts are padded to `n` exact `+0.0`s
    /// here). Consumes `vals` as scratch. `Mean` never routes here (the
    /// dispatcher keeps it on the reference fold); it is defined as
    /// `TrimmedMean(0)` for completeness.
    fn fold(&self, vals: &mut Vec<f32>, n: usize) -> f32 {
        debug_assert!(vals.len() <= n);
        vals.resize(n, 0.0);
        vals.sort_unstable_by(|a, b| a.total_cmp(b));
        match *self {
            AggRule::Mean | AggRule::TrimmedMean(_) => {
                let k = match *self {
                    AggRule::TrimmedMean(k) => k.min((n - 1) / 2),
                    _ => 0,
                };
                let kept = &vals[k..n - k];
                let mut acc = 0.0f32;
                for &x in kept {
                    acc += x;
                }
                acc / kept.len() as f32
            }
            AggRule::CoordMedian => {
                if n % 2 == 1 {
                    vals[n / 2]
                } else {
                    0.5 * (vals[n / 2 - 1] + vals[n / 2])
                }
            }
        }
    }
}

/// Default density crossover of [`AggPolicy`]: the sparse merge wins while
/// the round's total message nnz stays below this fraction of `dim`.
///
/// Tuned on the `micro_hotpath` `sparse_merge/{kway,scatter}` pair: the
/// dense path streams ≈ 2·dim floats (zero + scale) regardless of
/// sparsity, the merge touches ≈ Σnnz heap entries at a few ops each, so
/// the break-even sits well above the paper's headline regime (φ = 0.99 ×
/// 16 MUs ⇒ Σnnz/dim ≈ 0.16). The log k factor is deliberately folded
/// into the constant — k is small and bounded in every deployment shape.
/// Override per run with `[agg] crossover` in the config file.
pub const AGG_DENSITY_CROSSOVER: f64 = 0.25;

/// Density-adaptive aggregation dispatch, threaded from `[agg]` config /
/// `--agg-path` down to every SBS/MBS aggregation call site.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AggPolicy {
    pub path: AggPath,
    /// Auto-path crossover: use the sparse merge while
    /// `total_nnz ≤ crossover · dim`.
    pub crossover: f64,
    /// Per-coordinate consensus statistic. Robust rules always take the
    /// merge-frontier walk regardless of `path` (the statistic needs every
    /// part's contribution at a coordinate, which the dense scatter fold
    /// cannot provide).
    pub rule: AggRule,
}

impl Default for AggPolicy {
    fn default() -> Self {
        Self {
            path: AggPath::Auto,
            crossover: AGG_DENSITY_CROSSOVER,
            rule: AggRule::Mean,
        }
    }
}

impl AggPolicy {
    /// Should this round's aggregation take the sparse-merge path, given
    /// the measured total message nnz?
    pub fn use_sparse(&self, total_nnz: usize, dim: usize) -> bool {
        match self.path {
            AggPath::Dense => false,
            AggPath::Sparse => true,
            AggPath::Auto => (total_nnz as f64) <= self.crossover * dim as f64,
        }
    }

    pub fn validate(&self) -> Result<()> {
        if !self.crossover.is_finite() || self.crossover <= 0.0 || self.crossover > 1.0 {
            bail!("agg crossover must be in (0, 1], got {}", self.crossover);
        }
        if let AggRule::TrimmedMean(k) = self.rule {
            if k == 0 {
                bail!("trimmed-mean needs k >= 1 (k = 0 is plain mean — use `mean`)");
            }
        }
        Ok(())
    }

    /// Named startup refusal for rule/population shapes that can never
    /// work: `TrimmedMean(k)` needs at least `2k + 1` participating parts
    /// at every site it aggregates.
    pub fn validate_participants(&self, parts: usize) -> Result<()> {
        if let AggRule::TrimmedMean(k) = self.rule {
            if 2 * k >= parts {
                bail!(
                    "trimmed-mean k={k} needs at least 2k+1={} participating parts per site, got {parts}",
                    2 * k + 1
                );
            }
        }
        Ok(())
    }
}

/// Reusable scratch of the k-way merge: the `(index, part)` min-heap and
/// the per-part cursors. Grows to the part count once, then the merge is
/// allocation-free (apart from `out`'s own growth).
#[derive(Clone, Debug, Default)]
pub struct MergeScratch {
    heap: Vec<u64>,
    cursors: Vec<usize>,
    /// Per-coordinate contribution buffer of the robust walk (unused by
    /// the mean fold).
    vals: Vec<f32>,
}

#[inline]
fn heap_key(idx: u32, part: usize) -> u64 {
    ((idx as u64) << 32) | part as u64
}

#[inline]
fn heap_push(h: &mut Vec<u64>, key: u64) {
    h.push(key);
    let mut i = h.len() - 1;
    while i > 0 {
        let p = (i - 1) / 2;
        if h[p] <= h[i] {
            break;
        }
        h.swap(p, i);
        i = p;
    }
}

#[inline]
fn heap_pop(h: &mut Vec<u64>) -> Option<u64> {
    if h.is_empty() {
        return None;
    }
    let top = h.swap_remove(0);
    let n = h.len();
    let mut i = 0;
    loop {
        let l = 2 * i + 1;
        if l >= n {
            break;
        }
        let r = l + 1;
        let m = if r < n && h[r] < h[l] { r } else { l };
        if h[i] <= h[m] {
            break;
        }
        h.swap(i, m);
        i = m;
    }
    Some(top)
}

/// Merge the coordinate range `[lo, hi)` of every part into `out`
/// (appending), folding each coordinate's contributions in part order.
fn merge_range(
    parts: &[(&SparseVec, f32)],
    lo: u64,
    hi: u64,
    out: &mut SparseVec,
    scratch: &mut MergeScratch,
) {
    scratch.heap.clear();
    scratch.cursors.clear();
    scratch.cursors.resize(parts.len(), 0);
    for (j, (p, _)) in parts.iter().enumerate() {
        let start = p.indices.partition_point(|&i| (i as u64) < lo);
        scratch.cursors[j] = start;
        if start < p.indices.len() && (p.indices[start] as u64) < hi {
            heap_push(&mut scratch.heap, heap_key(p.indices[start], j));
        }
    }
    let mut cur: Option<u32> = None;
    let mut acc = 0.0f32;
    while let Some(key) = heap_pop(&mut scratch.heap) {
        let idx = (key >> 32) as u32;
        let j = (key & 0xffff_ffff) as usize;
        let (p, w) = parts[j];
        let pos = scratch.cursors[j];
        let v = p.values[pos];
        scratch.cursors[j] = pos + 1;
        if pos + 1 < p.indices.len() && (p.indices[pos + 1] as u64) < hi {
            heap_push(&mut scratch.heap, heap_key(p.indices[pos + 1], j));
        }
        match cur {
            Some(ci) if ci == idx => {}
            _ => {
                if let Some(ci) = cur {
                    out.indices.push(ci);
                    out.values.push(acc);
                }
                cur = Some(idx);
                acc = 0.0;
            }
        }
        // The reference scatter expression, contribution by contribution:
        // `out[i] += w · v` over a +0.0 start, in ascending part order.
        acc += w * v;
    }
    if let Some(ci) = cur {
        out.indices.push(ci);
        out.values.push(acc);
    }
}

/// Robust variant of [`merge_range`]: the same heap frontier, but each
/// coordinate collects its per-part contributions `(n · w_j) · v_j[i]`
/// (ascending part order — the heap's tie order) and emits
/// `rule.fold(...)` over them plus one `+0.0` per absent part.
fn robust_range(
    parts: &[(&SparseVec, f32)],
    rule: AggRule,
    lo: u64,
    hi: u64,
    out: &mut SparseVec,
    scratch: &mut MergeScratch,
) {
    let n = parts.len();
    if n == 0 {
        return;
    }
    let nf = n as f32;
    scratch.heap.clear();
    scratch.cursors.clear();
    scratch.cursors.resize(n, 0);
    for (j, (p, _)) in parts.iter().enumerate() {
        let start = p.indices.partition_point(|&i| (i as u64) < lo);
        scratch.cursors[j] = start;
        if start < p.indices.len() && (p.indices[start] as u64) < hi {
            heap_push(&mut scratch.heap, heap_key(p.indices[start], j));
        }
    }
    let mut cur: Option<u32> = None;
    let mut vals = std::mem::take(&mut scratch.vals);
    vals.clear();
    while let Some(key) = heap_pop(&mut scratch.heap) {
        let idx = (key >> 32) as u32;
        let j = (key & 0xffff_ffff) as usize;
        let (p, w) = parts[j];
        let pos = scratch.cursors[j];
        let v = p.values[pos];
        scratch.cursors[j] = pos + 1;
        if pos + 1 < p.indices.len() && (p.indices[pos + 1] as u64) < hi {
            heap_push(&mut scratch.heap, heap_key(p.indices[pos + 1], j));
        }
        match cur {
            Some(ci) if ci == idx => {}
            _ => {
                if let Some(ci) = cur {
                    out.indices.push(ci);
                    out.values.push(rule.fold(&mut vals, n));
                }
                cur = Some(idx);
                vals.clear();
            }
        }
        vals.push((w * nf) * v);
    }
    if let Some(ci) = cur {
        out.indices.push(ci);
        out.values.push(rule.fold(&mut vals, n));
    }
    scratch.vals = vals;
}

/// Robust k-way consensus of `parts` into `out`: the sorted union of the
/// part indices, each value the [`AggRule`] statistic over all `n`
/// per-part contributions at that coordinate (absent parts contribute an
/// exact `+0.0`). See the module docs for the tie/order contract.
pub fn merge_robust_into(
    parts: &[(&SparseVec, f32)],
    rule: AggRule,
    dim: usize,
    out: &mut SparseVec,
    scratch: &mut MergeScratch,
) {
    for (p, _) in parts {
        debug_assert_eq!(p.dim, dim, "merge part dimension mismatch");
        debug_assert!(p.is_sorted_unique(), "merge parts need sorted unique indices");
    }
    out.dim = dim;
    out.indices.clear();
    out.values.clear();
    robust_range(parts, rule, 0, dim as u64, out, scratch);
}

/// K-way merge of `parts` (each `(message, weight)`) into the sparse
/// consensus `out`: `out` carries the sorted union of the part indices,
/// each value the part-ordered fold `Σ_j w_j · v_j[i]` — bit-identical to
/// scatter-adding every part into a zeroed dense accumulator in the same
/// order. `O(Σnnz · log k)`; allocation-free given warm `scratch`/`out`.
pub fn merge_weighted_into(
    parts: &[(&SparseVec, f32)],
    dim: usize,
    out: &mut SparseVec,
    scratch: &mut MergeScratch,
) {
    for (p, _) in parts {
        debug_assert_eq!(p.dim, dim, "merge part dimension mismatch");
        debug_assert!(p.is_sorted_unique(), "merge parts need sorted unique indices");
    }
    out.dim = dim;
    out.indices.clear();
    out.values.clear();
    merge_range(parts, 0, dim as u64, out, scratch);
}

/// Per-lane scratch of [`merge_weighted_par`]: one output buffer + merge
/// scratch per coordinate range, reused across calls.
#[derive(Debug, Default)]
pub struct ParMergeScratch {
    lanes: Vec<Mutex<(SparseVec, MergeScratch)>>,
}

/// Pool-parallel k-way merge: partitions the coordinate space `[0, dim)`
/// into `width` contiguous ranges, merges each range independently on a
/// lane of `pool` (the process-wide shared pool when `None`), and
/// concatenates the per-range results in range order. Each coordinate's
/// fold is executed by exactly one lane with the identical part-ordered
/// arithmetic of [`merge_weighted_into`], so the result is **bit-identical
/// to the sequential merge (and to the dense scatter fold) at any width**.
///
/// The flat training engines do *not* route [`aggregate_adaptive`]
/// through this variant: their parallelism budget is already spent on the
/// cluster/MU lane fan-outs, and a nested range fan-out per aggregation
/// would contend for the same pool. The DES engine *does* use it (via
/// [`aggregate_adaptive_pooled`]): its cluster aggregation and H-sync
/// tails run on the submitting thread after the per-MU fan-out has
/// drained, so the leased lanes are idle exactly when the merge runs.
pub fn merge_weighted_par(
    parts: &[(&SparseVec, f32)],
    dim: usize,
    width: usize,
    pool: Option<&PoolHandle>,
    out: &mut SparseVec,
    scratch: &mut ParMergeScratch,
) -> Result<()> {
    if width == 0 {
        bail!("parallel merge needs at least one lane");
    }
    while scratch.lanes.len() < width {
        scratch.lanes.push(Mutex::new((SparseVec::default(), MergeScratch::default())));
    }
    for (p, _) in parts {
        debug_assert_eq!(p.dim, dim, "merge part dimension mismatch");
        debug_assert!(p.is_sorted_unique(), "merge parts need sorted unique indices");
    }
    let handle = match pool {
        Some(h) => h.clone(),
        None => crate::pool::global_handle(),
    };
    let lanes = &scratch.lanes;
    handle.run_ordered(width, width, |r| {
        let lo = dim as u64 * r as u64 / width as u64;
        let hi = dim as u64 * (r as u64 + 1) / width as u64;
        let mut lane = lanes[r].lock().unwrap();
        let (buf, ms) = &mut *lane;
        buf.dim = dim;
        buf.indices.clear();
        buf.values.clear();
        merge_range(parts, lo, hi, buf, ms);
    })?;
    out.dim = dim;
    out.indices.clear();
    out.values.clear();
    for lane in &scratch.lanes[..width] {
        let lane = lane.lock().unwrap();
        out.indices.extend_from_slice(&lane.0.indices);
        out.values.extend_from_slice(&lane.0.values);
    }
    Ok(())
}

/// Pool-parallel robust consensus: the [`merge_weighted_par`] range
/// decomposition with the robust per-coordinate walk. Every coordinate's
/// statistic is computed by exactly one lane over the identical collected
/// contributions, so the concatenated result is bit-identical to
/// [`merge_robust_into`] at any width.
pub fn merge_robust_par(
    parts: &[(&SparseVec, f32)],
    rule: AggRule,
    dim: usize,
    width: usize,
    pool: Option<&PoolHandle>,
    out: &mut SparseVec,
    scratch: &mut ParMergeScratch,
) -> Result<()> {
    if width == 0 {
        bail!("parallel merge needs at least one lane");
    }
    while scratch.lanes.len() < width {
        scratch.lanes.push(Mutex::new((SparseVec::default(), MergeScratch::default())));
    }
    for (p, _) in parts {
        debug_assert_eq!(p.dim, dim, "merge part dimension mismatch");
        debug_assert!(p.is_sorted_unique(), "merge parts need sorted unique indices");
    }
    let handle = match pool {
        Some(h) => h.clone(),
        None => crate::pool::global_handle(),
    };
    let lanes = &scratch.lanes;
    handle.run_ordered(width, width, |r| {
        let lo = dim as u64 * r as u64 / width as u64;
        let hi = dim as u64 * (r as u64 + 1) / width as u64;
        let mut lane = lanes[r].lock().unwrap();
        let (buf, ms) = &mut *lane;
        buf.dim = dim;
        buf.indices.clear();
        buf.values.clear();
        robust_range(parts, rule, lo, hi, buf, ms);
    })?;
    out.dim = dim;
    out.indices.clear();
    out.values.clear();
    for lane in &scratch.lanes[..width] {
        let lane = lane.lock().unwrap();
        out.indices.extend_from_slice(&lane.0.indices);
        out.values.extend_from_slice(&lane.0.values);
    }
    Ok(())
}

/// One density-adaptive aggregation — the single definition of the
/// dispatch every SBS/MBS call site (fl rounds + H-sync, DES cluster
/// aggregation + sync, coordinator SBS/MBS) goes through, so the
/// bit-exactness contract cannot drift apart across sites.
///
/// Folds `parts` into the dense accumulator `buf` exactly as the
/// reference `zero → scatter(part order) → [scale]` sequence would:
///
/// * **dense path** (policy says scatter): literally that sequence, via
///   the reference kernels;
/// * **sparse path**: k-way merge into `merged` (same per-coordinate
///   fold), values scaled by `post_scale`, written through `shadow` with
///   the baseline every untouched coordinate holds after the reference
///   sequence — computed as the reference's own `0.0 * post_scale`
///   expression (−0.0 for the round path's `−lr`), or `+0.0` when no
///   scale runs (sync accumulators).
///
/// `post_scale = Some(a)` multiplies the aggregate after the fold (the
/// round path's `−lr`); `None` leaves it unscaled. The merge itself is
/// allocation-free over warm scratch; the k-element `parts` list is the
/// caller's (engines rebuild it per aggregation — k pointers, negligible
/// against the O(nnz) fold).
#[allow(clippy::too_many_arguments)]
pub fn aggregate_adaptive(
    policy: &AggPolicy,
    parts: &[(&SparseVec, f32)],
    dim: usize,
    post_scale: Option<f32>,
    buf: &mut [f32],
    merged: &mut SparseVec,
    scratch: &mut MergeScratch,
    shadow: &mut DenseShadow,
) {
    if policy.rule != AggRule::Mean {
        // Robust rules always walk the merge frontier: the statistic needs
        // every part's contribution per coordinate, which the dense
        // scatter fold cannot provide. `path`/`crossover` stay a pure
        // wall-clock choice for the mean fold only.
        merge_robust_into(parts, policy.rule, dim, merged, scratch);
        let baseline = match post_scale {
            Some(a) => {
                merged.scale_values(a);
                0.0f32 * a
            }
            None => 0.0,
        };
        shadow.write(buf, baseline, merged);
        return;
    }
    let total_nnz: usize = parts.iter().map(|(m, _)| m.nnz()).sum();
    if policy.use_sparse(total_nnz, dim) {
        merge_weighted_into(parts, dim, merged, scratch);
        let baseline = match post_scale {
            Some(a) => {
                merged.scale_values(a);
                0.0f32 * a
            }
            None => 0.0,
        };
        shadow.write(buf, baseline, merged);
    } else {
        crate::tensor::kernels::zero(buf);
        for (m, w) in parts {
            m.add_into(buf, *w);
        }
        if let Some(a) = post_scale {
            crate::tensor::kernels::scale(buf, a);
        }
        shadow.mark_dirty();
    }
}

/// [`aggregate_adaptive`] with the sparse-path merge fanned out across
/// coordinate ranges on `width` pool lanes ([`merge_weighted_par`]).
/// Bit-identical to the sequential dispatch at every width — the
/// per-coordinate fold order never changes — so callers may switch
/// between the two freely (the DES engine uses this variant whenever it
/// holds a lane lease, and the sequential one otherwise). The dense path
/// is the same scatter fold as [`aggregate_adaptive`], untouched by
/// `width`.
#[allow(clippy::too_many_arguments)]
pub fn aggregate_adaptive_pooled(
    policy: &AggPolicy,
    parts: &[(&SparseVec, f32)],
    dim: usize,
    post_scale: Option<f32>,
    width: usize,
    pool: Option<&PoolHandle>,
    buf: &mut [f32],
    merged: &mut SparseVec,
    scratch: &mut ParMergeScratch,
    shadow: &mut DenseShadow,
) -> Result<()> {
    if policy.rule != AggRule::Mean {
        merge_robust_par(parts, policy.rule, dim, width.max(1), pool, merged, scratch)?;
        let baseline = match post_scale {
            Some(a) => {
                merged.scale_values(a);
                0.0f32 * a
            }
            None => 0.0,
        };
        shadow.write(buf, baseline, merged);
        return Ok(());
    }
    let total_nnz: usize = parts.iter().map(|(m, _)| m.nnz()).sum();
    if policy.use_sparse(total_nnz, dim) {
        merge_weighted_par(parts, dim, width.max(1), pool, merged, scratch)?;
        let baseline = match post_scale {
            Some(a) => {
                merged.scale_values(a);
                0.0f32 * a
            }
            None => 0.0,
        };
        shadow.write(buf, baseline, merged);
    } else {
        crate::tensor::kernels::zero(buf);
        for (m, w) in parts {
            m.add_into(buf, *w);
        }
        if let Some(a) = post_scale {
            crate::tensor::kernels::scale(buf, a);
        }
        shadow.mark_dirty();
    }
    Ok(())
}

/// Bookkeeping that lets the sparse aggregation path hand downstream
/// encoders a dense buffer **bit-identical** to the reference
/// `zero → scatter → [scale]` sequence while writing only `O(nnz)`
/// coordinates per use (steady state).
///
/// Contract: after [`DenseShadow::write`]`(buf, baseline, merged)`, `buf`
/// holds `merged`'s values at its indices and the exact `baseline` bit
/// pattern everywhere else — `−0.0` reproduces the post-`scale(-lr)` state
/// of the round path, `+0.0` the freshly zeroed state of the sync path.
/// Any dense-path use of the same buffer must call
/// [`DenseShadow::mark_dirty`]; the next sparse use then pays one full
/// `fill` to re-establish the baseline.
#[derive(Clone, Debug, Default)]
pub struct DenseShadow {
    /// Bit pattern every un-tracked coordinate currently holds (`None`
    /// after a dense-path write left the buffer in an unknown state).
    baseline: Option<u32>,
    /// Coordinates of the last sparse write, to be restored next time.
    touched: Vec<u32>,
}

impl DenseShadow {
    pub fn new() -> Self {
        Self::default()
    }

    /// The buffer was written outside this shadow's control (dense path).
    pub fn mark_dirty(&mut self) {
        self.baseline = None;
        self.touched.clear();
    }

    /// Establish `baseline` everywhere except `merged`'s coordinates,
    /// which receive `merged`'s values. `O(prev_nnz + nnz)` when the
    /// baseline is unchanged; one `fill` otherwise.
    pub fn write(&mut self, buf: &mut [f32], baseline: f32, merged: &SparseVec) {
        assert_eq!(buf.len(), merged.dim, "shadow buffer dimension mismatch");
        let b_bits = baseline.to_bits();
        if self.baseline == Some(b_bits) {
            for &i in &self.touched {
                buf[i as usize] = baseline;
            }
        } else {
            buf.fill(baseline);
            self.baseline = Some(b_bits);
        }
        for (&i, &v) in merged.indices.iter().zip(&merged.values) {
            buf[i as usize] = v;
        }
        self.touched.clear();
        self.touched.extend_from_slice(&merged.indices);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::kernels;
    use crate::util::rng::Pcg64;

    /// Random sparse parts with the given keep probability, plus weights.
    fn random_parts(
        rng: &mut Pcg64,
        k: usize,
        dim: usize,
        keep: f64,
    ) -> Vec<(SparseVec, f32)> {
        (0..k)
            .map(|_| {
                let dense: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
                let mask: Vec<bool> = (0..dim).map(|_| rng.uniform() < keep).collect();
                let sv = SparseVec::from_mask(&dense, |i, _| mask[i]);
                (sv, rng.uniform_range(0.1, 2.0) as f32)
            })
            .collect()
    }

    /// The reference: scatter every part into a zeroed dense accumulator.
    fn dense_reference(parts: &[(SparseVec, f32)], dim: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; dim];
        for (p, w) in parts {
            kernels::scatter_add(&mut out, &p.indices, &p.values, *w);
        }
        out
    }

    fn as_refs(parts: &[(SparseVec, f32)]) -> Vec<(&SparseVec, f32)> {
        parts.iter().map(|(p, w)| (p, *w)).collect()
    }

    #[test]
    fn merge_matches_scatter_bit_for_bit() {
        let mut rng = Pcg64::seeded(71);
        let mut out = SparseVec::default();
        let mut scratch = MergeScratch::default();
        for &(k, dim, keep) in
            &[(1usize, 50usize, 0.5f64), (3, 100, 0.1), (8, 64, 0.9), (16, 257, 0.01)]
        {
            let parts = random_parts(&mut rng, k, dim, keep);
            let reference = dense_reference(&parts, dim);
            merge_weighted_into(&as_refs(&parts), dim, &mut out, &mut scratch);
            assert!(out.is_sorted_unique(), "k={k}");
            let mut dense = vec![0.0f32; dim];
            for (&i, &v) in out.indices.iter().zip(&out.values) {
                dense[i as usize] = v;
            }
            for i in 0..dim {
                assert_eq!(
                    dense[i].to_bits(),
                    reference[i].to_bits(),
                    "k={k} dim={dim} keep={keep} coord {i}"
                );
            }
            // Union completeness: every coordinate present in any part
            // appears in the merge output.
            let union: std::collections::BTreeSet<u32> = parts
                .iter()
                .flat_map(|(p, _)| p.indices.iter().copied())
                .collect();
            assert_eq!(out.indices, union.into_iter().collect::<Vec<_>>());
        }
    }

    #[test]
    fn tie_fold_order_is_part_order() {
        // Two parts sharing a coordinate: the fold must be
        // (0 + w0·a) + w1·b, not any reassociation. Pick values where
        // f32 rounding distinguishes the orders.
        let a = SparseVec { dim: 4, indices: vec![2], values: vec![1.0e-7] };
        let b = SparseVec { dim: 4, indices: vec![2], values: vec![1.0] };
        let parts = vec![(a, 1.0f32), (b, 1.0f32)];
        let reference = dense_reference(&parts, 4);
        let mut out = SparseVec::default();
        merge_weighted_into(&as_refs(&parts), 4, &mut out, &mut MergeScratch::default());
        assert_eq!(out.indices, vec![2]);
        assert_eq!(out.values[0].to_bits(), reference[2].to_bits());
    }

    #[test]
    fn empty_parts_and_no_parts() {
        let mut out = SparseVec::default();
        let mut scratch = MergeScratch::default();
        merge_weighted_into(&[], 10, &mut out, &mut scratch);
        assert_eq!(out.dim, 10);
        assert_eq!(out.nnz(), 0);
        let empty = SparseVec::empty(10);
        merge_weighted_into(&[(&empty, 1.0), (&empty, 0.5)], 10, &mut out, &mut scratch);
        assert_eq!(out.nnz(), 0);
    }

    #[test]
    fn parallel_merge_is_bit_identical_for_every_width() {
        let mut rng = Pcg64::seeded(72);
        let parts = random_parts(&mut rng, 6, 300, 0.2);
        let refs = as_refs(&parts);
        let mut seq = SparseVec::default();
        merge_weighted_into(&refs, 300, &mut seq, &mut MergeScratch::default());
        let mut scratch = ParMergeScratch::default();
        for width in [1usize, 2, 3, 8] {
            let mut par = SparseVec::default();
            merge_weighted_par(&refs, 300, width, None, &mut par, &mut scratch).unwrap();
            assert_eq!(par.indices, seq.indices, "width={width}");
            let bits = |v: &SparseVec| v.values.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&par), bits(&seq), "width={width}");
            assert_eq!(par.dim, 300);
        }
        assert!(merge_weighted_par(&refs, 300, 0, None, &mut seq, &mut scratch).is_err());
    }

    #[test]
    fn shadow_reproduces_zero_scatter_scale_sequence() {
        let dim = 40;
        let mut rng = Pcg64::seeded(73);
        let mut shadow = DenseShadow::new();
        let mut buf = vec![0.0f32; dim];
        let mut scratch = MergeScratch::default();
        let mut merged = SparseVec::default();
        for round in 0..6 {
            let parts = random_parts(&mut rng, 4, dim, 0.15);
            let lr = 0.05f32 * (round + 1) as f32;
            // Reference: zero → scatter → scale(-lr), fresh buffer.
            let mut reference = dense_reference(&parts, dim);
            kernels::scale(&mut reference, -lr);
            // Sparse path: merge → scale values → shadow write at −0.0.
            merge_weighted_into(&as_refs(&parts), dim, &mut merged, &mut scratch);
            merged.scale_values(-lr);
            shadow.write(&mut buf, -0.0, &merged);
            for i in 0..dim {
                assert_eq!(
                    buf[i].to_bits(),
                    reference[i].to_bits(),
                    "round {round} coord {i}"
                );
            }
        }
        // A baseline flip (sync-style +0.0 use of the same buffer) refills.
        let parts = random_parts(&mut rng, 2, dim, 0.1);
        merge_weighted_into(&as_refs(&parts), dim, &mut merged, &mut scratch);
        shadow.write(&mut buf, 0.0, &merged);
        let reference = dense_reference(&parts, dim);
        for i in 0..dim {
            assert_eq!(buf[i].to_bits(), reference[i].to_bits(), "sync coord {i}");
        }
        // Dense-path interference → mark_dirty → next write still exact.
        buf.iter_mut().for_each(|x| *x = 9.0);
        shadow.mark_dirty();
        shadow.write(&mut buf, 0.0, &merged);
        for i in 0..dim {
            assert_eq!(buf[i].to_bits(), reference[i].to_bits(), "post-dirty coord {i}");
        }
    }

    #[test]
    fn aggregate_adaptive_matches_reference_on_both_paths() {
        // Forced Sparse and forced Dense must leave the accumulator
        // bit-identical to the reference zero → scatter → [scale]
        // sequence, across both the scaled (round) and unscaled (sync)
        // shapes, with interleaved path flips on one buffer.
        let dim = 60;
        let mut rng = Pcg64::seeded(74);
        let mut merged = SparseVec::default();
        let mut scratch = MergeScratch::default();
        for post_scale in [Some(-0.07f32), None] {
            let mut bufs = [vec![0.0f32; dim], vec![0.0f32; dim]];
            let mut shadows = [DenseShadow::new(), DenseShadow::new()];
            for round in 0..5 {
                let parts = random_parts(&mut rng, 3, dim, 0.2);
                let refs = as_refs(&parts);
                let mut reference = dense_reference(&parts, dim);
                if let Some(a) = post_scale {
                    kernels::scale(&mut reference, a);
                }
                for (which, path) in [(0usize, AggPath::Sparse), (1, AggPath::Dense)] {
                    // Alternate Auto in to flip paths on the same buffer.
                    let path = if round % 2 == 1 { AggPath::Auto } else { path };
                    let policy = AggPolicy { path, ..AggPolicy::default() };
                    aggregate_adaptive(
                        &policy,
                        &refs,
                        dim,
                        post_scale,
                        &mut bufs[which],
                        &mut merged,
                        &mut scratch,
                        &mut shadows[which],
                    );
                    for i in 0..dim {
                        assert_eq!(
                            bufs[which][i].to_bits(),
                            reference[i].to_bits(),
                            "round {round} path {path:?} scale {post_scale:?} coord {i}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn aggregate_adaptive_pooled_matches_sequential_dispatch() {
        // The pooled dispatch must agree bit for bit with the sequential
        // one at every width, on both paths and both post-scale shapes.
        let dim = 96;
        let mut rng = Pcg64::seeded(75);
        for post_scale in [Some(-0.03f32), None] {
            for path in [AggPath::Sparse, AggPath::Dense, AggPath::Auto] {
                let policy = AggPolicy { path, ..AggPolicy::default() };
                let parts = random_parts(&mut rng, 5, dim, 0.15);
                let refs = as_refs(&parts);
                let mut seq_buf = vec![0.0f32; dim];
                let mut seq_shadow = DenseShadow::new();
                aggregate_adaptive(
                    &policy,
                    &refs,
                    dim,
                    post_scale,
                    &mut seq_buf,
                    &mut SparseVec::default(),
                    &mut MergeScratch::default(),
                    &mut seq_shadow,
                );
                let mut scratch = ParMergeScratch::default();
                for width in [1usize, 2, 7] {
                    let mut buf = vec![0.0f32; dim];
                    let mut shadow = DenseShadow::new();
                    aggregate_adaptive_pooled(
                        &policy,
                        &refs,
                        dim,
                        post_scale,
                        width,
                        None,
                        &mut buf,
                        &mut SparseVec::default(),
                        &mut scratch,
                        &mut shadow,
                    )
                    .unwrap();
                    for i in 0..dim {
                        assert_eq!(
                            buf[i].to_bits(),
                            seq_buf[i].to_bits(),
                            "path {path:?} width {width} scale {post_scale:?} coord {i}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn agg_path_parse_and_policy() {
        assert_eq!(AggPath::parse("auto").unwrap(), AggPath::Auto);
        assert_eq!(AggPath::parse("sparse").unwrap(), AggPath::Sparse);
        assert_eq!(AggPath::parse("dense").unwrap(), AggPath::Dense);
        assert!(AggPath::parse("fast").is_err());
        let p = AggPolicy::default();
        p.validate().unwrap();
        assert_eq!(p.path.as_str(), "auto");
        // φ=0.99 × 16 MUs (the paper's headline regime) must take the
        // sparse path under the default crossover.
        let dim = 1 << 20;
        assert!(p.use_sparse(16 * dim / 100, dim));
        // Dense-ish traffic must not.
        assert!(!p.use_sparse(dim / 2, dim));
        assert!(AggPolicy { crossover: 0.0, ..Default::default() }.validate().is_err());
        assert!(AggPolicy { crossover: 1.5, ..Default::default() }.validate().is_err());
        let forced = AggPolicy { path: AggPath::Sparse, ..Default::default() };
        assert!(forced.use_sparse(usize::MAX, 1));
        let dense = AggPolicy { path: AggPath::Dense, ..Default::default() };
        assert!(!dense.use_sparse(0, 1 << 20));
    }

    #[test]
    fn agg_rule_parse_labels_and_validation() {
        assert_eq!(AggRule::parse("mean", 1).unwrap(), AggRule::Mean);
        assert_eq!(AggRule::parse("trimmed-mean", 2).unwrap(), AggRule::TrimmedMean(2));
        assert_eq!(AggRule::parse("coord-median", 1).unwrap(), AggRule::CoordMedian);
        assert!(AggRule::parse("krum", 1).is_err());
        assert_eq!(AggRule::TrimmedMean(3).label(), "trim3");
        assert_eq!(AggRule::CoordMedian.label(), "median");
        assert_eq!(AggRule::default(), AggRule::Mean);

        // k = 0 trimmed-mean is refused (that's just `mean`).
        let p = AggPolicy { rule: AggRule::TrimmedMean(0), ..Default::default() };
        assert!(p.validate().is_err());
        // 2k >= parts is an impossible configured shape — named refusal.
        let p = AggPolicy { rule: AggRule::TrimmedMean(2), ..Default::default() };
        p.validate().unwrap();
        assert!(p.validate_participants(4).is_err());
        let err = p.validate_participants(3).unwrap_err().to_string();
        assert!(err.contains("trimmed-mean"), "{err}");
        p.validate_participants(5).unwrap();
        // Mean and median never constrain the population.
        AggPolicy::default().validate_participants(1).unwrap();
        let med = AggPolicy { rule: AggRule::CoordMedian, ..Default::default() };
        med.validate_participants(1).unwrap();
    }

    #[test]
    fn robust_rules_match_hand_computed_statistics() {
        // 3 parts over dim 4; coordinate 1 only in parts 0 and 2 — the
        // absent part contributes an exact +0.0.
        let p0 = SparseVec { dim: 4, indices: vec![0, 1], values: vec![1.0, 4.0] };
        let p1 = SparseVec { dim: 4, indices: vec![0], values: vec![2.0] };
        let p2 = SparseVec { dim: 4, indices: vec![0, 1], values: vec![9.0, -2.0] };
        // Uniform 1/n weights make x_j = v_j exactly (n·w = 3·(1/3) rounds
        // to 1.0? — not guaranteed in f32, so use w = 1 and divide by hand).
        let w = 1.0f32 / 3.0;
        let parts: Vec<(&SparseVec, f32)> = vec![(&p0, w), (&p1, w), (&p2, w)];
        let nw = w * 3.0f32; // the exact factor the walk applies

        let mut out = SparseVec::default();
        let mut scratch = MergeScratch::default();
        merge_robust_into(&parts, AggRule::CoordMedian, 4, &mut out, &mut scratch);
        assert_eq!(out.indices, vec![0, 1]);
        // coord 0: values {1, 2, 9}·nw → median 2·nw; coord 1: {4·nw, 0, −2·nw} → 0.
        assert_eq!(out.values[0].to_bits(), (2.0f32 * nw).to_bits());
        assert_eq!(out.values[1].to_bits(), 0.0f32.to_bits());

        merge_robust_into(&parts, AggRule::TrimmedMean(1), 4, &mut out, &mut scratch);
        // Trim 1 high + 1 low leaves the median value at n = 3.
        assert_eq!(out.indices, vec![0, 1]);
        assert_eq!(out.values[0].to_bits(), ((2.0f32 * nw) / 1.0).to_bits());
        assert_eq!(out.values[1].to_bits(), 0.0f32.to_bits());

        // Even part count: median averages the two middle values.
        let q = SparseVec { dim: 4, indices: vec![0], values: vec![3.0] };
        let four: Vec<(&SparseVec, f32)> = vec![(&p0, 0.25), (&p1, 0.25), (&p2, 0.25), (&q, 0.25)];
        merge_robust_into(&four, AggRule::CoordMedian, 4, &mut out, &mut scratch);
        let s = 0.25f32 * 4.0; // per-part factor
        // coord 0: {1, 2, 9, 3}·s → 0.5·(2 + 3)·s.
        assert_eq!(out.values[0].to_bits(), (0.5 * (2.0 * s + 3.0 * s)).to_bits());
        // coord 1: {4·s, 0, −2·s, 0} sorted → middle pair (0, 0) → 0.
        assert_eq!(out.values[1].to_bits(), 0.0f32.to_bits());
    }

    #[test]
    fn trimmed_mean_discards_byzantine_outliers() {
        // 5 honest-ish parts + the k-clamp under churn: with only 2 live
        // parts and k = 1, the clamp takes k_eff = 0 (plain mean) instead
        // of panicking on an empty kept range.
        let honest: Vec<SparseVec> = (0..4)
            .map(|i| SparseVec { dim: 2, indices: vec![0], values: vec![1.0 + 0.1 * i as f32] })
            .collect();
        let attacker = SparseVec { dim: 2, indices: vec![0], values: vec![-1.0e6] };
        let mut parts: Vec<(&SparseVec, f32)> = honest.iter().map(|p| (p, 0.2f32)).collect();
        parts.push((&attacker, 0.2));
        let mut out = SparseVec::default();
        let mut scratch = MergeScratch::default();
        merge_robust_into(&parts, AggRule::TrimmedMean(1), 2, &mut out, &mut scratch);
        // The −1e6 outlier is trimmed: the statistic stays in the honest range.
        assert!(out.values[0] > 0.9 && out.values[0] < 1.5, "{}", out.values[0]);
        merge_robust_into(&parts, AggRule::Mean, 2, &mut out, &mut scratch);
        // Whereas the (robust-walk) mean is dragged far negative.
        assert!(out.values[0] < -1.0e4, "{}", out.values[0]);

        let two: Vec<(&SparseVec, f32)> = vec![(&honest[0], 0.5), (&attacker, 0.5)];
        merge_robust_into(&two, AggRule::TrimmedMean(1), 2, &mut out, &mut scratch);
        assert!(out.values[0].is_finite()); // clamped, defined, no panic
    }

    #[test]
    fn robust_parallel_merge_is_bit_identical_for_every_width() {
        let mut rng = Pcg64::seeded(76);
        let parts = random_parts(&mut rng, 7, 257, 0.3);
        let refs = as_refs(&parts);
        for rule in [AggRule::TrimmedMean(2), AggRule::CoordMedian] {
            let mut seq = SparseVec::default();
            merge_robust_into(&refs, rule, 257, &mut seq, &mut MergeScratch::default());
            assert!(seq.is_sorted_unique());
            let mut scratch = ParMergeScratch::default();
            for width in [1usize, 2, 3, 8] {
                let mut par = SparseVec::default();
                merge_robust_par(&refs, rule, 257, width, None, &mut par, &mut scratch).unwrap();
                assert_eq!(par.indices, seq.indices, "rule {rule:?} width {width}");
                let bits =
                    |v: &SparseVec| v.values.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                assert_eq!(bits(&par), bits(&seq), "rule {rule:?} width {width}");
            }
        }
    }

    #[test]
    fn robust_rules_route_through_aggregate_adaptive() {
        // A robust rule must take the frontier walk no matter what the
        // path says, and the pooled dispatch must agree bit for bit.
        let dim = 64;
        let mut rng = Pcg64::seeded(77);
        let parts = random_parts(&mut rng, 5, dim, 0.4);
        let refs = as_refs(&parts);
        for rule in [AggRule::TrimmedMean(1), AggRule::CoordMedian] {
            let mut reference = SparseVec::default();
            merge_robust_into(&refs, rule, dim, &mut reference, &mut MergeScratch::default());
            reference.scale_values(-0.05);
            for path in [AggPath::Auto, AggPath::Sparse, AggPath::Dense] {
                let policy = AggPolicy { path, rule, ..Default::default() };
                let mut buf = vec![0.0f32; dim];
                aggregate_adaptive(
                    &policy,
                    &refs,
                    dim,
                    Some(-0.05),
                    &mut buf,
                    &mut SparseVec::default(),
                    &mut MergeScratch::default(),
                    &mut DenseShadow::new(),
                );
                let mut pooled = vec![0.0f32; dim];
                aggregate_adaptive_pooled(
                    &policy,
                    &refs,
                    dim,
                    Some(-0.05),
                    3,
                    None,
                    &mut pooled,
                    &mut SparseVec::default(),
                    &mut ParMergeScratch::default(),
                    &mut DenseShadow::new(),
                )
                .unwrap();
                let mut expect = vec![-0.0f32; dim];
                for (&i, &v) in reference.indices.iter().zip(&reference.values) {
                    expect[i as usize] = v;
                }
                for i in 0..dim {
                    assert_eq!(buf[i].to_bits(), expect[i].to_bits(), "path {path:?} coord {i}");
                    assert_eq!(pooled[i].to_bits(), expect[i].to_bits(), "pooled {path:?} {i}");
                }
            }
        }
    }
}
