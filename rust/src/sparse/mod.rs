//! Sparse communication (§IV): DGC-style top-k gradient sparsification with
//! momentum correction ([`dgc`]), the sparse index+value wire format, its
//! bit accounting and the delta-packed realized stream ([`codec`]),
//! discounted error accumulation for the four sparsified links of the
//! hierarchy ([`error_accum`]), and the sparse-first aggregation kernels —
//! allocation-free k-way merge consensus plus the density-adaptive
//! dispatch policy — behind the SBS/MBS aggregation call sites ([`merge`]).
//!
//! Each compressor comes in two forms: an owning struct
//! ([`DgcCompressor`], [`DiscountedError`]) and a stateless slice-based
//! kernel ([`DgcKernel`], [`DiscountKernel`]) over caller-provided buffers,
//! which lets the flat training engine keep all compressor state in one
//! contiguous [`crate::tensor::TensorArena`]. Both forms execute identical
//! arithmetic (bit-exact); so does the k-way merge relative to the dense
//! scatter fold it replaces (see the [`merge`] module docs).

pub mod codec;
pub mod dgc;
pub mod error_accum;
pub mod merge;
pub mod quantize;

pub use codec::{SparseVec, SparseWire};
pub use dgc::{DgcCompressor, DgcKernel};
pub use error_accum::{DiscountKernel, DiscountedError};
pub use merge::{AggPath, AggPolicy, AggRule, DenseShadow, MergeScratch};
pub use quantize::QuantizedVec;
