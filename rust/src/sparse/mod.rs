//! Sparse communication (§IV): DGC-style top-k gradient sparsification with
//! momentum correction ([`dgc`]), the sparse index+value wire format and
//! its bit accounting ([`codec`]), and discounted error accumulation for
//! the four sparsified links of the hierarchy ([`error_accum`]).

pub mod codec;
pub mod dgc;
pub mod error_accum;
pub mod quantize;

pub use codec::SparseVec;
pub use dgc::DgcCompressor;
pub use error_accum::DiscountedError;
pub use quantize::QuantizedVec;
