//! Sparse communication (§IV): DGC-style top-k gradient sparsification with
//! momentum correction ([`dgc`]), the sparse index+value wire format and
//! its bit accounting ([`codec`]), and discounted error accumulation for
//! the four sparsified links of the hierarchy ([`error_accum`]).
//!
//! Each compressor comes in two forms: an owning struct
//! ([`DgcCompressor`], [`DiscountedError`]) and a stateless slice-based
//! kernel ([`DgcKernel`], [`DiscountKernel`]) over caller-provided buffers,
//! which lets the flat training engine keep all compressor state in one
//! contiguous [`crate::tensor::TensorArena`]. Both forms execute identical
//! arithmetic (bit-exact).

pub mod codec;
pub mod dgc;
pub mod error_accum;
pub mod quantize;

pub use codec::SparseVec;
pub use dgc::{DgcCompressor, DgcKernel};
pub use error_accum::{DiscountKernel, DiscountedError};
pub use quantize::QuantizedVec;
