//! Discounted error accumulation for sparsified *model-difference*
//! messages (Algorithm 5, lines 21/28/34; cf. Sattler et al., Tang et al.).
//!
//! Each of the hierarchy's sparsified links keeps a local error buffer: the
//! coordinates suppressed by `Ω(·, φ)` are remembered and folded — scaled by
//! a discount β — into the next message, so no signal is permanently lost
//! but stale error cannot compound unboundedly:
//!
//! ```text
//! x̃ = x + β·e                    (fold in discounted old error)
//! send Ω(x̃, φ)
//! e ← x̃ − Ω(x̃, φ)                (remember what was suppressed)
//! ```

use super::codec::SparseVec;
use crate::tensor::kernels;
use crate::util::math::quantile_abs_into;

/// The stateless discounted-error step: the persistent error buffer `e`,
/// the fold scratch, and the quantile scratch are all borrowed from the
/// caller, so the same kernel drives both the owning [`DiscountedError`]
/// and arena-resident encoder state in the flat training engine
/// ([`crate::fl::run_hierarchical`]).
///
/// Arithmetic is bit-identical to the historical in-struct implementation
/// (same fold, same threshold, same extraction order).
#[derive(Clone, Copy, Debug)]
pub struct DiscountKernel {
    /// Sparsity φ of this link (0 → dense passthrough, error stays empty).
    pub phi: f64,
    /// Error discount β.
    pub beta: f32,
}

impl DiscountKernel {
    pub fn new(phi: f64, beta: f32) -> Self {
        assert!((0.0..1.0).contains(&phi));
        assert!((0.0..=1.0).contains(&(beta as f64)));
        Self { phi, beta }
    }

    /// Encode `x` into `out` over borrowed state: transmit `Ω(x + β·e, φ)`
    /// and update `e`. `scratch` needs at least
    /// [`crate::util::math::quantile_sample_len`]`(dim)` elements (`dim`
    /// always suffices). Allocation-free apart from `out`'s own growth.
    pub fn compress_into(
        &self,
        x: &[f32],
        e: &mut [f32],
        folded: &mut [f32],
        scratch: &mut [f32],
        out: &mut SparseVec,
    ) {
        assert_eq!(x.len(), e.len(), "dim mismatch");
        assert_eq!(x.len(), folded.len(), "dim mismatch");
        // x̃ = x + β·e
        kernels::discount_fold(folded, x, e, self.beta);
        out.dim = x.len();
        out.indices.clear();
        out.values.clear();
        if self.phi == 0.0 {
            // Dense: transmit everything, error is identically zero. Bulk
            // `extend`s mirror the DGC dense fast path (one reserve +
            // memcpy each instead of per-element push pairs).
            out.indices.extend(0..folded.len() as u32);
            out.values.extend_from_slice(folded);
            kernels::zero(e);
            return;
        }
        out.reserve(((1.0 - self.phi) * folded.len() as f64).ceil() as usize);
        let th = quantile_abs_into(folded, self.phi, scratch);
        for (i, &v) in folded.iter().enumerate() {
            if v.abs() >= th {
                out.indices.push(i as u32);
                out.values.push(v);
                e[i] = 0.0;
            } else {
                e[i] = v;
            }
        }
    }
}

/// One link's sparsifying encoder with discounted error memory (owning
/// wrapper around [`DiscountKernel`]).
#[derive(Clone, Debug)]
pub struct DiscountedError {
    /// Sparsity φ of this link (0 → dense passthrough, error stays empty).
    pub phi: f64,
    /// Error discount β.
    pub beta: f32,
    e: Vec<f32>,
    folded: Vec<f32>,
    scratch: Vec<f32>,
}

impl DiscountedError {
    pub fn new(dim: usize, phi: f64, beta: f32) -> Self {
        let _ = DiscountKernel::new(phi, beta); // validate the parameters
        Self {
            phi,
            beta,
            e: vec![0.0; dim],
            folded: vec![0.0; dim],
            scratch: vec![0.0; dim],
        }
    }

    pub fn dim(&self) -> usize {
        self.e.len()
    }

    /// Current error buffer (suppressed mass).
    pub fn error(&self) -> &[f32] {
        &self.e
    }

    /// The stateless kernel configured like this encoder.
    pub fn kernel(&self) -> DiscountKernel {
        DiscountKernel {
            phi: self.phi,
            beta: self.beta,
        }
    }

    /// Encode `x` for transmission: returns `Ω(x + β·e, φ)` and updates the
    /// error buffer.
    pub fn compress(&mut self, x: &[f32]) -> SparseVec {
        let mut out = SparseVec::empty(x.len());
        self.compress_into(x, &mut out);
        out
    }

    /// Allocation-free variant of [`DiscountedError::compress`] reusing
    /// `out`'s storage — the hot-path entry point of the DES engine's
    /// per-round DL encode and H-period sync.
    pub fn compress_into(&mut self, x: &[f32], out: &mut SparseVec) {
        assert_eq!(x.len(), self.dim(), "dim mismatch");
        self.kernel()
            .compress_into(x, &mut self.e, &mut self.folded, &mut self.scratch, out);
    }

    /// Drop accumulated error (used at hard model resets).
    pub fn reset(&mut self) {
        self.e.iter_mut().for_each(|z| *z = 0.0);
    }

    /// Overwrite the error buffer from checkpointed state (exact bit copy;
    /// dim must match). Inverse of reading [`DiscountedError::error`].
    pub fn restore_error(&mut self, e: &[f32]) {
        assert_eq!(e.len(), self.dim(), "error dim mismatch");
        self.e.copy_from_slice(e);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, Gen, PropConfig};
    use crate::util::rng::Pcg64;

    #[test]
    fn dense_link_is_lossless() {
        let mut enc = DiscountedError::new(6, 0.0, 0.5);
        let x = vec![1.0, -2.0, 3.0, 0.0, 0.5, -0.1];
        let s = enc.compress(&x);
        assert_eq!(s.to_dense(), x);
        assert!(enc.error().iter().all(|&z| z == 0.0));
    }

    #[test]
    fn sent_plus_error_equals_folded_input() {
        // Invariant of one step: Ω(x̃) + e_new == x̃ == x + β·e_old.
        let mut enc = DiscountedError::new(50, 0.8, 0.5);
        let mut rng = Pcg64::seeded(51);
        let mut e_old = vec![0.0f32; 50];
        for _ in 0..10 {
            let x: Vec<f32> = (0..50).map(|_| rng.normal() as f32).collect();
            let s = enc.compress(&x);
            let mut recon = s.to_dense();
            for (r, &e) in recon.iter_mut().zip(enc.error()) {
                *r += e;
            }
            for i in 0..50 {
                let folded = x[i] + 0.5 * e_old[i];
                assert!(
                    (recon[i] - folded).abs() < 1e-5,
                    "coord {i}: {} vs {}",
                    recon[i],
                    folded
                );
            }
            e_old = enc.error().to_vec();
        }
    }

    #[test]
    fn beta_zero_discards_history() {
        let mut enc = DiscountedError::new(10, 0.9, 0.0);
        let x = vec![0.01f32; 10]; // everything suppressed except the top tie
        let _ = enc.compress(&x);
        let x2 = vec![0.0f32; 10];
        let s2 = enc.compress(&x2);
        // With β=0, the suppressed mass from step 1 must not reappear.
        assert!(s2.values.iter().all(|&v| v == 0.0), "{:?}", s2.values);
    }

    #[test]
    fn suppressed_signal_eventually_transmits_with_beta_one() {
        // A constant small input below the threshold accumulates with β=1
        // until it crosses and is sent.
        let dim = 100;
        let mut enc = DiscountedError::new(dim, 0.95, 1.0);
        let mut rng = Pcg64::seeded(52);
        let mut sent_0 = false;
        for _ in 0..100 {
            let mut x: Vec<f32> = (0..dim).map(|_| (rng.normal() * 0.02) as f32).collect();
            x[0] = 0.03; // persistent small signal
            let s = enc.compress(&x);
            if s.indices.contains(&0) {
                sent_0 = true;
                break;
            }
        }
        assert!(sent_0);
    }

    #[test]
    fn prop_error_norm_bounded_by_input_scale() {
        // The error buffer cannot blow up: after each step its entries are
        // below the sparsity threshold, which is bounded by max|x̃|.
        struct Inputs;
        impl Gen for Inputs {
            type Value = (u64, usize);
            fn generate(&self, rng: &mut Pcg64) -> Self::Value {
                (rng.next_u64(), 10 + rng.uniform_usize(100))
            }
        }
        check(&PropConfig { cases: 40, ..Default::default() }, &Inputs, |&(seed, dim)| {
            let mut rng = Pcg64::seeded(seed);
            let mut enc = DiscountedError::new(dim, 0.9, 0.5);
            for _ in 0..20 {
                let x: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
                let max_folded = x
                    .iter()
                    .zip(enc.error())
                    .map(|(&a, &e)| (a + 0.5 * e).abs())
                    .fold(0.0f32, f32::max);
                let _ = enc.compress(&x);
                let max_err = enc.error().iter().map(|z| z.abs()).fold(0.0f32, f32::max);
                if max_err > max_folded + 1e-6 {
                    return Err(format!("error {max_err} exceeds folded input {max_folded}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn kernel_over_borrowed_buffers_matches_encoder() {
        // The arena path (stateless kernel + external buffers) must be
        // bit-identical to the owning encoder, dense and sparse.
        for phi in [0.0, 0.8] {
            let dim = 200;
            let mut enc = DiscountedError::new(dim, phi, 0.5);
            let k = enc.kernel();
            let mut e = vec![0.0f32; dim];
            let mut folded = vec![0.0f32; dim];
            let mut scratch = vec![0.0f32; dim];
            let mut out = SparseVec::empty(dim);
            let mut rng = Pcg64::seeded(53);
            for step in 0..10 {
                let x: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
                let a = enc.compress(&x);
                k.compress_into(&x, &mut e, &mut folded, &mut scratch, &mut out);
                assert_eq!(a, out, "phi={phi} step {step}");
                assert_eq!(enc.error(), &e[..], "phi={phi} step {step}");
            }
        }
    }

    #[test]
    fn reset_clears() {
        let mut enc = DiscountedError::new(10, 0.9, 1.0);
        let x: Vec<f32> = (0..10).map(|i| (i + 1) as f32 * 0.1).collect();
        let _ = enc.compress(&x);
        assert!(enc.error().iter().any(|&z| z != 0.0));
        enc.reset();
        assert!(enc.error().iter().all(|&z| z == 0.0));
    }
}
