//! Deep-Gradient-Compression sparsifier (Algorithm 4, lines 6–12; Lin et
//! al. 2018 as adopted by the paper).
//!
//! Per worker it keeps two buffers: the momentum-corrected accumulator
//! `u` and the error (residual) accumulator `v`:
//!
//! ```text
//! u ← σ·u + g                  (momentum correction, Eq. 24)
//! v ← v + u                    (error accumulation, Eq. 25)
//! g_th ← φ-quantile of |v|     (top-(1−φ) selection)
//! mask ← |v| ≥ g_th
//! ĝ = v ⊙ mask                 (transmitted)
//! u ← u ⊙ ¬mask,  v ← v ⊙ ¬mask  (momentum-factor masking, Eq. 27–29)
//! ```
//!
//! All buffers and scratch space are pre-allocated; `step` performs no heap
//! allocation beyond the returned [`SparseVec`]'s own storage (which can be
//! reused via [`DgcCompressor::step_into`]).

use super::codec::SparseVec;
use crate::tensor::kernels;
use crate::util::math::quantile_abs_into;

/// The stateless DGC step: all buffers (`u`, `v`, quantile scratch) are
/// borrowed from the caller, so the same kernel drives both the owning
/// [`DgcCompressor`] and arena-resident state in the flat training engine
/// ([`crate::fl::run_hierarchical`]), where every worker's `u`/`v` pair
/// lives in one contiguous [`crate::tensor::TensorArena`].
///
/// Arithmetic is bit-identical to the historical in-struct implementation
/// (same fused accumulate, same threshold, same extraction order).
#[derive(Clone, Copy, Debug)]
pub struct DgcKernel {
    /// Momentum correction factor σ.
    pub momentum: f32,
    /// Sparsity φ ∈ [0,1): fraction of coordinates suppressed.
    pub phi: f64,
}

impl DgcKernel {
    pub fn new(momentum: f32, phi: f64) -> Self {
        assert!((0.0..1.0).contains(&phi), "phi={phi} outside [0,1)");
        assert!((0.0..1.0).contains(&(momentum as f64)), "momentum={momentum}");
        Self { momentum, phi }
    }

    /// One compression step over borrowed state. `scratch` needs at least
    /// [`crate::util::math::quantile_sample_len`]`(dim)` elements (`dim`
    /// always suffices). Allocation-free apart from `out`'s own growth.
    pub fn step_into(
        &self,
        grad: &[f32],
        u: &mut [f32],
        v: &mut [f32],
        scratch: &mut [f32],
        out: &mut SparseVec,
    ) {
        assert_eq!(grad.len(), u.len(), "gradient dim mismatch");
        assert_eq!(grad.len(), v.len(), "gradient dim mismatch");
        // u ← σu + g; v ← v + u
        kernels::dgc_accumulate(u, v, grad, self.momentum);
        out.dim = grad.len();
        out.indices.clear();
        out.values.clear();
        if self.phi == 0.0 {
            // Dense fast path: transmit v wholesale and keep the momentum
            // buffer — this is exactly classical momentum SGD (Eq. 23),
            // the paper's dense FL/HFL baseline. (DGC's momentum-factor
            // masking exists to stop *stale* momentum from sparsified,
            // delayed coordinates; with φ=0 nothing is delayed.) Bulk
            // `extend`s: one reserve + memcpy each instead of per-element
            // push pairs with interleaved capacity checks.
            out.indices.extend(0..v.len() as u32);
            out.values.extend_from_slice(v);
            kernels::zero(v);
            return;
        }
        // Threshold at the φ-quantile of |v|, then extract ĝ = v⊙mask and
        // zero masked u, v (momentum-factor masking, Eq. 27–29). A warm
        // reused `out` already has the capacity; a cold one reserves the
        // expected survivor count once instead of doubling through it.
        out.reserve(((1.0 - self.phi) * v.len() as f64).ceil() as usize);
        let th = quantile_abs_into(v, self.phi, scratch);
        for i in 0..v.len() {
            if v[i].abs() >= th {
                out.indices.push(i as u32);
                out.values.push(v[i]);
                u[i] = 0.0;
                v[i] = 0.0;
            }
        }
    }
}

/// Per-worker DGC state (owning wrapper around [`DgcKernel`]).
#[derive(Clone, Debug)]
pub struct DgcCompressor {
    /// Momentum correction factor σ.
    pub momentum: f32,
    /// Sparsity φ ∈ [0,1): fraction of coordinates suppressed.
    pub phi: f64,
    u: Vec<f32>,
    v: Vec<f32>,
    scratch: Vec<f32>,
}

impl DgcCompressor {
    pub fn new(dim: usize, momentum: f32, phi: f64) -> Self {
        let _ = DgcKernel::new(momentum, phi); // validate the parameters
        Self {
            momentum,
            phi,
            u: vec![0.0; dim],
            v: vec![0.0; dim],
            scratch: vec![0.0; dim],
        }
    }

    /// The stateless kernel configured like this compressor.
    pub fn kernel(&self) -> DgcKernel {
        DgcKernel {
            momentum: self.momentum,
            phi: self.phi,
        }
    }

    pub fn dim(&self) -> usize {
        self.u.len()
    }

    /// Residual (untransmitted) accumulator — exposed for tests/diagnostics.
    pub fn residual(&self) -> &[f32] {
        &self.v
    }

    /// Momentum accumulator — exposed for tests/diagnostics.
    pub fn momentum_buf(&self) -> &[f32] {
        &self.u
    }

    /// Overwrite both accumulators from checkpointed state (exact bit
    /// copies; dims must match). Inverse of reading
    /// [`DgcCompressor::momentum_buf`] / [`DgcCompressor::residual`].
    pub fn restore_state(&mut self, u: &[f32], v: &[f32]) {
        assert_eq!(u.len(), self.dim(), "momentum dim mismatch");
        assert_eq!(v.len(), self.dim(), "residual dim mismatch");
        self.u.copy_from_slice(u);
        self.v.copy_from_slice(v);
    }

    /// One compression step; returns the sparse message to transmit.
    pub fn step(&mut self, grad: &[f32]) -> SparseVec {
        let mut out = SparseVec::empty(grad.len());
        self.step_into(grad, &mut out);
        out
    }

    /// Allocation-free variant reusing `out`'s storage.
    pub fn step_into(&mut self, grad: &[f32], out: &mut SparseVec) {
        assert_eq!(grad.len(), self.dim(), "gradient dim mismatch");
        self.kernel()
            .step_into(grad, &mut self.u, &mut self.v, &mut self.scratch, out);
    }

    /// Reset both accumulators (used when the global model is replaced at a
    /// period boundary and stale local residuals must not leak across).
    pub fn reset(&mut self) {
        self.u.iter_mut().for_each(|x| *x = 0.0);
        self.v.iter_mut().for_each(|x| *x = 0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, Gen, PropConfig};
    use crate::util::math::quantile_abs;
    use crate::util::rng::Pcg64;

    #[test]
    fn keeps_top_fraction() {
        let dim = 1000;
        let mut c = DgcCompressor::new(dim, 0.0, 0.99);
        let mut rng = Pcg64::seeded(41);
        let g: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
        let s = c.step(&g);
        // ~1% of coordinates survive (quantile ties may admit a few extra).
        assert!(s.nnz() >= 10 && s.nnz() <= 20, "nnz={}", s.nnz());
        // Surviving values are the largest |g| (no momentum, first step → v = g).
        let min_kept = s.values.iter().map(|v| v.abs()).fold(f32::INFINITY, f32::min);
        let max_dropped = g
            .iter()
            .enumerate()
            .filter(|(i, _)| !s.indices.contains(&(*i as u32)))
            .map(|(_, v)| v.abs())
            .fold(0.0f32, f32::max);
        assert!(min_kept >= max_dropped, "{min_kept} < {max_dropped}");
    }

    #[test]
    fn untransmitted_mass_accumulates_and_eventually_sends() {
        // A small persistent gradient coordinate must eventually be sent.
        let dim = 100;
        let mut c = DgcCompressor::new(dim, 0.0, 0.9);
        let mut g = vec![0.0f32; dim];
        // Coordinate 7 gets a small constant gradient, others get noise that
        // changes sign (cancels in v).
        let mut rng = Pcg64::seeded(42);
        let mut sent_7 = false;
        for _ in 0..50 {
            for (i, x) in g.iter_mut().enumerate() {
                *x = if i == 7 { 0.05 } else { (rng.normal() * 0.5) as f32 };
            }
            let s = c.step(&g);
            if s.indices.contains(&7) {
                sent_7 = true;
                break;
            }
        }
        assert!(sent_7, "coordinate 7 was never transmitted");
    }

    #[test]
    fn dense_mode_transmits_everything_immediately() {
        let mut c = DgcCompressor::new(5, 0.0, 0.0);
        let g = vec![1.0, -2.0, 0.0, 0.5, 3.0];
        let s = c.step(&g);
        assert_eq!(s.nnz(), 5);
        assert_eq!(s.to_dense(), g);
        assert!(c.residual().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn dense_mode_with_momentum_is_momentum_sgd() {
        // φ=0, σ=0.9: transmitted message equals the classical momentum
        // accumulator u_t = Σ σ^i g_{t−i}.
        let mut c = DgcCompressor::new(1, 0.9, 0.0);
        let mut u_ref = 0.0f32;
        for step in 0..10 {
            let g = (step as f32 * 0.3 - 1.0).sin();
            u_ref = 0.9 * u_ref + g;
            let s = c.step(&[g]);
            assert_eq!(s.nnz(), 1);
            assert!((s.values[0] - u_ref).abs() < 1e-6, "step {step}");
        }
    }

    #[test]
    fn momentum_correction_matches_reference_recurrence() {
        // Against a straightforward reference implementation.
        let dim = 64;
        let sigma = 0.9f32;
        let phi = 0.8;
        let mut c = DgcCompressor::new(dim, sigma, phi);
        let mut ref_u = vec![0.0f32; dim];
        let mut ref_v = vec![0.0f32; dim];
        let mut rng = Pcg64::seeded(43);
        let mut scratch = Vec::new();
        for step in 0..20 {
            let g: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
            let s = c.step(&g);
            // reference
            for i in 0..dim {
                ref_u[i] = sigma * ref_u[i] + g[i];
                ref_v[i] += ref_u[i];
            }
            let th = quantile_abs(&ref_v, phi, &mut scratch);
            let mut ref_sent = Vec::new();
            for i in 0..dim {
                if ref_v[i].abs() >= th {
                    ref_sent.push((i as u32, ref_v[i]));
                    ref_u[i] = 0.0;
                    ref_v[i] = 0.0;
                }
            }
            let got: Vec<(u32, f32)> =
                s.indices.iter().copied().zip(s.values.iter().copied()).collect();
            assert_eq!(got, ref_sent, "step {step}");
            assert_eq!(c.residual(), &ref_v[..], "residual step {step}");
            assert_eq!(c.momentum_buf(), &ref_u[..], "momentum step {step}");
        }
    }

    #[test]
    fn prop_transmitted_plus_residual_conserve_signal() {
        // With σ=0: Σ_t sent_t + v_T == Σ_t g_t coordinate-wise.
        struct Steps;
        impl Gen for Steps {
            type Value = (usize, usize, u64);
            fn generate(&self, rng: &mut Pcg64) -> Self::Value {
                (1 + rng.uniform_usize(8), 4 + rng.uniform_usize(60), rng.next_u64())
            }
        }
        check(&PropConfig { cases: 50, ..Default::default() }, &Steps, |&(steps, dim, seed)| {
            let mut rng = Pcg64::seeded(seed);
            let mut c = DgcCompressor::new(dim, 0.0, 0.7);
            let mut total_g = vec![0.0f32; dim];
            let mut total_sent = vec![0.0f32; dim];
            for _ in 0..steps {
                let g: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
                for (t, &x) in total_g.iter_mut().zip(&g) {
                    *t += x;
                }
                let s = c.step(&g);
                s.add_into(&mut total_sent, 1.0);
            }
            for i in 0..dim {
                let recon = total_sent[i] + c.residual()[i];
                if (recon - total_g[i]).abs() > 1e-4 * (1.0 + total_g[i].abs()) {
                    return Err(format!(
                        "coord {i}: sent+resid {recon} != Σg {}",
                        total_g[i]
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn reset_clears_state() {
        let mut c = DgcCompressor::new(10, 0.9, 0.9);
        // Distinct magnitudes so the φ-quantile genuinely suppresses some.
        let g: Vec<f32> = (0..10).map(|i| (i + 1) as f32).collect();
        let _ = c.step(&g);
        assert!(c.residual().iter().any(|&x| x != 0.0));
        c.reset();
        assert!(c.residual().iter().all(|&x| x == 0.0));
        assert!(c.momentum_buf().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn kernel_over_borrowed_buffers_matches_compressor() {
        // The arena path (stateless kernel + external buffers) must be
        // bit-identical to the owning compressor, dense and sparse.
        for phi in [0.0, 0.8] {
            let dim = 300;
            let mut c = DgcCompressor::new(dim, 0.9, phi);
            let k = c.kernel();
            let (mut u, mut v) = (vec![0.0f32; dim], vec![0.0f32; dim]);
            let mut scratch = vec![0.0f32; dim];
            let mut rng = Pcg64::seeded(45);
            let (mut a, mut b) = (SparseVec::empty(dim), SparseVec::empty(dim));
            for step in 0..10 {
                let g: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
                c.step_into(&g, &mut a);
                k.step_into(&g, &mut u, &mut v, &mut scratch, &mut b);
                assert_eq!(a, b, "phi={phi} step {step}");
                assert_eq!(c.residual(), &v[..], "phi={phi} step {step}");
                assert_eq!(c.momentum_buf(), &u[..], "phi={phi} step {step}");
            }
        }
    }

    #[test]
    fn step_into_reuses_allocation() {
        let mut c = DgcCompressor::new(100, 0.5, 0.9);
        let mut out = SparseVec::empty(100);
        let mut rng = Pcg64::seeded(44);
        for _ in 0..5 {
            let g: Vec<f32> = (0..100).map(|_| rng.normal() as f32).collect();
            c.step_into(&g, &mut out);
            assert!(out.nnz() >= 1);
        }
    }
}
