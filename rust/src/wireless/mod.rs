//! The wireless PHY substrate of the paper (§II, §III-A): Rayleigh-fading
//! channels, truncated channel-inversion power control, threshold-optimized
//! M-QAM expected rates, the optimal max-min sub-carrier allocation
//! (Algorithm 2), the rateless broadcast downlink, and the end-to-end
//! latency of flat FL ([`latency::fl_latency`]) and hierarchical FL
//! ([`latency::hfl_latency`], Eq. 21).
//!
//! All quantities are *expected* values over the fading distribution, as in
//! the paper's analysis; the broadcast expectation has both an exact
//! closed form (derived in [`broadcast`]) and a Monte-Carlo estimator used
//! to cross-validate it in tests.

pub mod broadcast;
pub mod channel;
pub mod power;
pub mod latency;
pub mod mqam;
pub mod subcarrier;

pub use latency::{fl_latency, hfl_latency, FlLatency, HflLatency, LatencyInputs};
pub use mqam::LinkParams;
pub use subcarrier::{allocate_subcarriers, Allocation};
