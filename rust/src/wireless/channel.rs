//! Rayleigh block-fading channel model (§II).
//!
//! The complex coefficient `h` has unit-mean Rayleigh magnitude, so the
//! power gain `γ = |h|²` is Exp(1), i.i.d. across sub-carriers and slots.
//! Large-scale attenuation is the paper's `d^{-α}` path loss; Eq. (6)
//! normalizes the gain by the AWGN power and path loss:
//! `γ̃ = γ / (N0·B0·d^α)`.

use crate::util::rng::Pcg64;

/// Static link budget between one transmitter/receiver pair.
#[derive(Clone, Copy, Debug)]
pub struct LinkBudget {
    /// Distance d (m).
    pub dist_m: f64,
    /// Path-loss exponent α.
    pub alpha: f64,
    /// Noise power on one sub-carrier, N0·B0 (W).
    pub noise_w: f64,
}

impl LinkBudget {
    /// The deterministic denominator of Eq. (6): `N0·B0·d^α`.
    pub fn attenuation(&self) -> f64 {
        self.noise_w * self.dist_m.powf(self.alpha)
    }

    /// Sample an instantaneous *normalized* channel gain γ̃ (Eq. 6).
    pub fn sample_normalized_gain(&self, rng: &mut Pcg64) -> f64 {
        rng.exponential() / self.attenuation()
    }

    /// Instantaneous SNR for transmit power `p` split over `m` sub-carriers
    /// with a fresh fade (Eq. 17 shape).
    pub fn sample_snr(&self, p_per_subcarrier: f64, rng: &mut Pcg64) -> f64 {
        p_per_subcarrier * rng.exponential() / self.attenuation()
    }

    /// Mean SNR with power `p` on this link.
    pub fn mean_snr(&self, p_per_subcarrier: f64) -> f64 {
        p_per_subcarrier / self.attenuation()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn attenuation_matches_hand_calc() {
        let lb = LinkBudget {
            dist_m: 100.0,
            alpha: 2.0,
            noise_w: 1e-14,
        };
        assert!((lb.attenuation() - 1e-10).abs() < 1e-22);
    }

    #[test]
    fn gain_sampling_mean() {
        let lb = LinkBudget {
            dist_m: 10.0,
            alpha: 2.0,
            noise_w: 1e-12,
        };
        let mut rng = Pcg64::seeded(12);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| lb.sample_normalized_gain(&mut rng)).sum::<f64>() / n as f64;
        let expect = 1.0 / lb.attenuation();
        assert!((mean / expect - 1.0).abs() < 0.02, "mean {mean} vs {expect}");
    }

    #[test]
    fn pathloss_monotone_in_distance_and_alpha() {
        let mk = |d: f64, a: f64| LinkBudget {
            dist_m: d,
            alpha: a,
            noise_w: 3e-14,
        };
        assert!(mk(200.0, 2.8).attenuation() < mk(700.0, 2.8).attenuation());
        assert!(mk(200.0, 2.0).attenuation() < mk(200.0, 3.5).attenuation());
        // Mean SNR decreases with distance.
        assert!(mk(200.0, 2.8).mean_snr(0.01) > mk(700.0, 2.8).mean_snr(0.01));
    }
}
