//! End-to-end communication latency of flat FL (§II-A/B) and hierarchical
//! FL (§III-A, Eq. 21), with the sparse-payload bit accounting of §IV.
//!
//! Payloads: a dense model/gradient of `Q` parameters quantized to `Q̂` bits
//! costs `Q·Q̂` bits; a φ-sparsified one transmits the `(1−φ)·Q` surviving
//! values plus their indices (⌈log2 Q⌉ bits each), exactly what DGC sends.

use super::broadcast::{broadcast_latency, BroadcastParams};
use super::mqam::LinkParams;
use super::subcarrier::allocate_subcarriers;
use crate::config::{Config, SparsityConfig};
use crate::topology::NetworkTopology;

/// Payload size in bits for `q` parameters at `bits_per_param`, sparsified
/// by φ (φ = 0 → dense, no index overhead; φ = 1 clamps to the DGC floor of
/// a single surviving element — DGC always sends the top coordinate).
pub fn payload_bits(q: usize, bits_per_param: u32, phi: f64) -> f64 {
    assert!((0.0..=1.0).contains(&phi), "phi={phi} outside [0,1]");
    if phi == 0.0 {
        return q as f64 * bits_per_param as f64;
    }
    // Number of surviving values: round to counter fp noise in (1−φ)·Q,
    // at least one value survives (DGC always sends the top element).
    let kept = ((1.0 - phi) * q as f64).round().clamp(1.0, q as f64);
    let index_bits = (q as f64).log2().ceil();
    kept * (bits_per_param as f64 + index_bits)
}

/// Everything the latency model needs, bundled from the experiment config.
#[derive(Clone, Debug)]
pub struct LatencyInputs {
    pub cfg: Config,
    pub topo: NetworkTopology,
}

impl LatencyInputs {
    pub fn new(cfg: &Config) -> Self {
        Self {
            cfg: cfg.clone(),
            topo: NetworkTopology::generate(&cfg.topology),
        }
    }

    fn mu_link(&self, dist: f64) -> LinkParams {
        let r = &self.cfg.radio;
        LinkParams {
            p_max_w: r.mu_power_w,
            dist_m: dist,
            alpha: r.pathloss_exp,
            noise_w: r.noise_power_w(),
            b0_hz: r.subcarrier_spacing_hz,
            ber: r.ber,
        }
    }

    fn sparsity(&self) -> SparsityEffective {
        SparsityEffective::from(&self.cfg.sparsity)
    }
}

/// φ values with `enabled` folded in (disabled → all dense).
struct SparsityEffective {
    mu_ul: f64,
    sbs_dl: f64,
    sbs_ul: f64,
    mbs_dl: f64,
}

impl From<&SparsityConfig> for SparsityEffective {
    fn from(s: &SparsityConfig) -> Self {
        if s.enabled {
            Self {
                mu_ul: s.phi_mu_ul,
                sbs_dl: s.phi_sbs_dl,
                sbs_ul: s.phi_sbs_ul,
                mbs_dl: s.phi_mbs_dl,
            }
        } else {
            Self {
                mu_ul: 0.0,
                sbs_dl: 0.0,
                sbs_ul: 0.0,
                mbs_dl: 0.0,
            }
        }
    }
}

/// Per-iteration latency decomposition of flat FL.
#[derive(Clone, Copy, Debug)]
pub struct FlLatency {
    /// Gradient aggregation uplink, Eq. (15).
    pub t_ul_s: f64,
    /// Broadcast downlink, Eq. (18).
    pub t_dl_s: f64,
}

impl FlLatency {
    /// `T_FL = T_UL + T_DL` (per iteration).
    pub fn total(&self) -> f64 {
        self.t_ul_s + self.t_dl_s
    }
}

/// Per-iteration (period-amortized) latency decomposition of HFL, Eq. (21).
#[derive(Clone, Debug)]
pub struct HflLatency {
    /// Worst-cluster uplink latency per intra-cluster iteration, `max_n Γ_n^U`.
    pub gamma_ul_s: f64,
    /// Worst-cluster downlink latency per intra-cluster iteration, `max_n Γ_n^D`.
    pub gamma_dl_s: f64,
    /// SBS→MBS fronthaul uplink per period, `Θ^U`.
    pub theta_ul_s: f64,
    /// MBS→SBS fronthaul downlink per period, `Θ^D`.
    pub theta_dl_s: f64,
    /// Final SBS→MU model broadcast per period, `max_n Γ_n^D` term of Eq. 21.
    pub final_dl_s: f64,
    /// Averaging period H.
    pub h: usize,
    /// Per-cluster uplink latencies (diagnostics).
    pub per_cluster_ul_s: Vec<f64>,
    /// Per-cluster downlink latencies (diagnostics).
    pub per_cluster_dl_s: Vec<f64>,
}

impl HflLatency {
    /// Full period latency `Γ^period` (Eq. 21). The per-cluster sum uses the
    /// worst cluster's (UL+DL) since expected per-iteration latencies are
    /// time-invariant.
    pub fn period(&self) -> f64 {
        let worst_cluster: f64 = self
            .per_cluster_ul_s
            .iter()
            .zip(&self.per_cluster_dl_s)
            .map(|(u, d)| (u + d) * self.h as f64)
            .fold(0.0, f64::max);
        worst_cluster + self.theta_ul_s + self.theta_dl_s + self.final_dl_s
    }

    /// Amortized per-iteration latency `Γ^HFL = Γ^period / H`.
    pub fn per_iteration(&self) -> f64 {
        self.period() / self.h as f64
    }
}

/// Flat FL per-iteration latency: all K MUs transmit to the MBS over the
/// full band, MBS broadcasts the aggregate back.
pub fn fl_latency(inputs: &LatencyInputs) -> FlLatency {
    let cfg = &inputs.cfg;
    let phi = inputs.sparsity();
    let q = cfg.latency.q_params;
    let qb = cfg.latency.bits_per_param;

    // Uplink: Algorithm 2 over every MU's link to the MBS.
    let links: Vec<LinkParams> = inputs
        .topo
        .users
        .iter()
        .map(|u| inputs.mu_link(u.dist_mbs))
        .collect();
    let alloc = allocate_subcarriers(&links, cfg.radio.subcarriers);
    let ul_bits = payload_bits(q, qb, phi.mu_ul);
    let t_ul = alloc
        .rates
        .iter()
        .map(|r| ul_bits / r)
        .fold(0.0, f64::max);

    // Downlink: MBS broadcast to every MU. In flat FL the MBS applies the
    // model-difference sparsification φ^dl_MBS (§V-C discusses FL with
    // downlink sparsification).
    let dl_bits = payload_bits(q, qb, phi.mbs_dl);
    let bp = BroadcastParams {
        p_total_w: cfg.radio.mbs_power_w,
        m_subcarriers: cfg.radio.subcarriers,
        noise_w: cfg.radio.noise_power_w(),
        b0_hz: cfg.radio.subcarrier_spacing_hz,
        alpha: cfg.radio.pathloss_exp,
        dists_m: inputs.topo.mbs_distances(),
        slot_s: cfg.radio.broadcast_slot_s,
    };
    let t_dl = broadcast_latency(&bp, dl_bits);

    FlLatency {
        t_ul_s: t_ul,
        t_dl_s: t_dl,
    }
}

/// Hierarchical FL latency (Eq. 21) with frequency reuse: each cluster gets
/// `M / N_c` sub-carriers, MU↔SBS links replace MU↔MBS, and every H
/// iterations the SBSs exchange sparsified model differences with the MBS
/// over the ×`fronthaul_multiplier` fronthaul.
pub fn hfl_latency(inputs: &LatencyInputs) -> HflLatency {
    let cfg = &inputs.cfg;
    let phi = inputs.sparsity();
    let q = cfg.latency.q_params;
    let qb = cfg.latency.bits_per_param;
    let topo = &inputs.topo;

    let m_cluster = topo.layout.subcarriers_per_cluster(cfg.radio.subcarriers);
    let ul_bits = payload_bits(q, qb, phi.mu_ul);
    let dl_bits = payload_bits(q, qb, phi.sbs_dl);

    let mut per_cluster_ul = Vec::with_capacity(topo.n_clusters());
    let mut per_cluster_dl = Vec::with_capacity(topo.n_clusters());
    let mut rate_sum = 0.0;
    let mut rate_count = 0usize;

    for n in 0..topo.n_clusters() {
        let dists = topo.sbs_distances(n);
        assert!(!dists.is_empty(), "cluster {n} has no users");
        // Uplink MU→SBS: Algorithm 2 within the cluster band.
        let links: Vec<LinkParams> = dists.iter().map(|&d| inputs.mu_link(d)).collect();
        let alloc = allocate_subcarriers(&links, m_cluster.max(links.len()));
        let gamma_u = alloc
            .rates
            .iter()
            .map(|r| ul_bits / r)
            .fold(0.0, f64::max);
        rate_sum += alloc.rates.iter().sum::<f64>();
        rate_count += alloc.rates.len();

        // Downlink SBS→MU broadcast of the aggregated (sparse) gradient.
        let bp = BroadcastParams {
            p_total_w: cfg.radio.sbs_power_w,
            m_subcarriers: m_cluster,
            noise_w: cfg.radio.noise_power_w(),
            b0_hz: cfg.radio.subcarrier_spacing_hz,
            alpha: cfg.radio.pathloss_exp,
            dists_m: dists,
            slot_s: cfg.radio.broadcast_slot_s,
        };
        let gamma_d = broadcast_latency(&bp, dl_bits);

        per_cluster_ul.push(gamma_u);
        per_cluster_dl.push(gamma_d);
    }

    // Fronthaul: ×multiplier of the mean per-MU UL rate (§V-A).
    let mean_mu_rate = rate_sum / rate_count as f64;
    let fronthaul_rate = cfg.radio.fronthaul_multiplier * mean_mu_rate;
    let theta_ul = payload_bits(q, qb, phi.sbs_ul) / fronthaul_rate;
    let theta_dl = payload_bits(q, qb, phi.mbs_dl) / fronthaul_rate;

    // Final SBS→MU model broadcast after global averaging: worst cluster DL.
    let final_dl = per_cluster_dl.iter().cloned().fold(0.0, f64::max);

    HflLatency {
        gamma_ul_s: per_cluster_ul.iter().cloned().fold(0.0, f64::max),
        gamma_dl_s: final_dl,
        theta_ul_s: theta_ul,
        theta_dl_s: theta_dl,
        final_dl_s: final_dl,
        h: cfg.training.h_period,
        per_cluster_ul_s: per_cluster_ul,
        per_cluster_dl_s: per_cluster_dl,
    }
}

/// Headline metric of Fig. 3–5: `speed-up = T^FL / Γ^HFL`.
pub fn speedup(inputs: &LatencyInputs) -> f64 {
    fl_latency(inputs).total() / hfl_latency(inputs).per_iteration()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    fn small_cfg() -> Config {
        // Paper-scale Q: all latency formulas are analytic, so this is fast,
        // and it keeps the broadcast slot quantization negligible.
        Config::paper_table2()
    }

    #[test]
    fn payload_bits_dense_and_sparse() {
        assert_eq!(payload_bits(1000, 32, 0.0), 32_000.0);
        // φ=0.99 → 10 values × (32 + 10) bits
        assert_eq!(payload_bits(1000, 32, 0.99), 10.0 * 42.0);
        // φ=1.0 clamps to the DGC always-send-one-element floor.
        assert_eq!(payload_bits(1000, 32, 1.0), 42.0);
        assert_eq!(payload_bits(1, 32, 1.0), 32.0);
        // Sparse must beat dense for high φ …
        assert!(payload_bits(1_000_000, 32, 0.99) < payload_bits(1_000_000, 32, 0.0));
        // … but not necessarily for tiny φ (index overhead).
        assert!(payload_bits(1_000_000, 32, 0.01) > payload_bits(1_000_000, 32, 0.0) * 0.95);
    }

    #[test]
    fn hfl_beats_fl_in_loaded_cells() {
        // Fig. 3: speed-up exceeds 1 and grows with the number of MUs per
        // cluster (at the smallest cells + H=2 the final-model broadcast
        // amortizes over too few iterations and the two roughly tie).
        let mut prev = 0.0;
        for mus in [4usize, 8, 12, 16] {
            let mut cfg = small_cfg();
            cfg.topology.mus_per_cluster = mus;
            cfg.training.h_period = 4;
            let s = speedup(&LatencyInputs::new(&cfg));
            assert!(s > prev, "speed-up should grow with MUs: {mus} gives {s} (prev {prev})");
            prev = s;
        }
        assert!(prev > 1.3, "speed-up at 16 MUs/cluster should be clear: {prev}");
        let mut cfg = small_cfg();
        cfg.topology.mus_per_cluster = 8;
        assert!(speedup(&LatencyInputs::new(&cfg)) > 1.0);
    }

    #[test]
    fn speedup_grows_with_h() {
        let mut prev = 0.0;
        for h in [1usize, 2, 4, 6] {
            let mut cfg = small_cfg();
            cfg.training.h_period = h;
            let s = speedup(&LatencyInputs::new(&cfg));
            assert!(
                s >= prev,
                "speed-up should not decrease with H: H={h} gives {s} < {prev}"
            );
            prev = s;
        }
    }

    #[test]
    fn speedup_grows_with_pathloss_exponent() {
        // Fig. 4: harsher path loss punishes the long MBS links more.
        let mut prev = 0.0;
        for alpha in [2.0, 2.4, 2.8, 3.2, 3.6, 4.0] {
            let mut cfg = small_cfg();
            cfg.radio.pathloss_exp = alpha;
            let s = speedup(&LatencyInputs::new(&cfg));
            assert!(
                s > prev * 0.98,
                "speed-up should trend up with α: α={alpha} gives {s} (prev {prev})"
            );
            prev = s;
        }
        // End-to-end it must have grown substantially.
        let mut lo = small_cfg();
        lo.radio.pathloss_exp = 2.0;
        let mut hi = small_cfg();
        hi.radio.pathloss_exp = 4.0;
        assert!(speedup(&LatencyInputs::new(&hi)) > speedup(&LatencyInputs::new(&lo)));
    }

    #[test]
    fn sparsification_cuts_latency_dramatically() {
        // Fig. 5 shape: sparse vs dense for both FL and HFL.
        let mut dense = small_cfg();
        dense.sparsity.enabled = false;
        let mut sparse = small_cfg();
        sparse.sparsity.enabled = true;
        let di = LatencyInputs::new(&dense);
        let si = LatencyInputs::new(&sparse);
        let fl_gain = fl_latency(&di).total() / fl_latency(&si).total();
        let hfl_gain = hfl_latency(&di).per_iteration() / hfl_latency(&si).per_iteration();
        assert!(fl_gain > 5.0, "FL sparsification gain {fl_gain}");
        assert!(hfl_gain > 5.0, "HFL sparsification gain {hfl_gain}");
    }

    #[test]
    fn latency_scales_linearly_in_q() {
        let mut small = small_cfg();
        small.sparsity.enabled = false; // broadcast slot quantization aside
        small.latency.q_params = 2_000_000;
        let mut big = small.clone();
        big.latency.q_params = small.latency.q_params * 4;
        let ts = fl_latency(&LatencyInputs::new(&small)).total();
        let tb = fl_latency(&LatencyInputs::new(&big)).total();
        let ratio = tb / ts;
        assert!((ratio - 4.0).abs() < 0.1, "ratio {ratio}");
    }

    #[test]
    fn eq21_period_composition() {
        let cfg = small_cfg();
        let h = hfl_latency(&LatencyInputs::new(&cfg));
        let manual = h
            .per_cluster_ul_s
            .iter()
            .zip(&h.per_cluster_dl_s)
            .map(|(u, d)| (u + d) * h.h as f64)
            .fold(0.0, f64::max)
            + h.theta_ul_s
            + h.theta_dl_s
            + h.final_dl_s;
        assert!((h.period() - manual).abs() < 1e-12);
        assert!((h.per_iteration() - manual / h.h as f64).abs() < 1e-12);
    }

    #[test]
    fn fronthaul_negligible_with_paper_multiplier() {
        let cfg = small_cfg();
        let h = hfl_latency(&LatencyInputs::new(&cfg));
        // The ×100 fronthaul should be a small share of the period.
        assert!(h.theta_ul_s + h.theta_dl_s < 0.5 * h.period());
    }

    #[test]
    fn more_mus_increase_fl_latency_more_than_hfl() {
        // Fig. 5 discussion: macro cell scarcity hurts FL harder.
        let at = |mus: usize| {
            let mut cfg = small_cfg();
            cfg.topology.mus_per_cluster = mus;
            let i = LatencyInputs::new(&cfg);
            (fl_latency(&i).total(), hfl_latency(&i).per_iteration())
        };
        let (fl4, hfl4) = at(4);
        let (fl12, hfl12) = at(12);
        assert!(fl12 > fl4);
        assert!(hfl12 > hfl4 * 0.9); // HFL may grow a little
        assert!(
            fl12 / fl4 > hfl12 / hfl4,
            "FL growth {} should exceed HFL growth {}",
            fl12 / fl4,
            hfl12 / hfl4
        );
    }
}
