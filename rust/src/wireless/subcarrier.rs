//! Optimal max-min sub-carrier allocation — Algorithm 2 and Theorem 1.
//!
//! Give every MU one sub-carrier, then repeatedly hand the next sub-carrier
//! to the MU whose current total expected rate `Ū_k` is smallest,
//! re-optimizing that MU's truncation threshold (its per-sub-carrier rate
//! depends on its count through the power split). Theorem 1 proves this
//! greedy is optimal for the max-min objective of Eq. (13); our property
//! tests check greedy ≥ every random allocation on random instances.

use super::mqam::LinkParams;

/// Result of allocating `m_total` sub-carriers among `K` users.
#[derive(Clone, Debug)]
pub struct Allocation {
    /// Sub-carrier count per user.
    pub counts: Vec<usize>,
    /// Total expected rate `Ū_k` per user (bits/s) at its final count.
    pub rates: Vec<f64>,
}

impl Allocation {
    pub fn min_rate(&self) -> f64 {
        self.rates.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max_rate(&self) -> f64 {
        self.rates.iter().cloned().fold(0.0, f64::max)
    }
}

/// Algorithm 2. `links[k]` are the static link parameters of MU k;
/// `m_total` must be ≥ K (every MU needs at least one sub-carrier,
/// otherwise its rate — and the min — is zero).
pub fn allocate_subcarriers(links: &[LinkParams], m_total: usize) -> Allocation {
    let k = links.len();
    assert!(k > 0, "no users to allocate to");
    assert!(
        m_total >= k,
        "need at least one sub-carrier per MU ({k} MUs, {m_total} sub-carriers)"
    );
    let mut counts = vec![1usize; k];
    let mut rates: Vec<f64> = links.iter().map(|l| l.total_rate(1)).collect();
    let mut remaining = m_total - k;
    while remaining > 0 {
        // k* = argmin Ū_k (line 5)
        let (kstar, _) = rates
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        counts[kstar] += 1;
        rates[kstar] = links[kstar].total_rate(counts[kstar]);
        remaining -= 1;
    }
    Allocation { counts, rates }
}

/// Rates for an arbitrary (externally chosen) allocation — used by tests and
/// the ablation bench comparing greedy against naive splits.
pub fn rates_for_counts(links: &[LinkParams], counts: &[usize]) -> Vec<f64> {
    assert_eq!(links.len(), counts.len());
    links
        .iter()
        .zip(counts)
        .map(|(l, &c)| if c == 0 { 0.0 } else { l.total_rate(c) })
        .collect()
}

/// Uniform split baseline: ⌊M/K⌋ each, remainder to the first users.
pub fn uniform_allocation(links: &[LinkParams], m_total: usize) -> Allocation {
    let k = links.len();
    let base = m_total / k;
    let extra = m_total % k;
    let counts: Vec<usize> = (0..k).map(|i| base + usize::from(i < extra)).collect();
    let rates = rates_for_counts(links, &counts);
    Allocation { counts, rates }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, Gen, PropConfig};
    use crate::util::rng::Pcg64;

    fn link(dist: f64) -> LinkParams {
        LinkParams {
            p_max_w: 0.2,
            dist_m: dist,
            alpha: 2.8,
            noise_w: 3e-14,
            b0_hz: 30_000.0,
            ber: 1e-3,
        }
    }

    #[test]
    fn conserves_subcarriers_and_covers_everyone() {
        let links: Vec<_> = [100.0, 300.0, 500.0, 700.0].map(link).into();
        let alloc = allocate_subcarriers(&links, 40);
        assert_eq!(alloc.counts.iter().sum::<usize>(), 40);
        assert!(alloc.counts.iter().all(|&c| c >= 1));
    }

    #[test]
    fn far_users_get_more_subcarriers() {
        let links: Vec<_> = [100.0, 700.0].map(link).into();
        let alloc = allocate_subcarriers(&links, 30);
        assert!(
            alloc.counts[1] > alloc.counts[0],
            "far user got {:?}",
            alloc.counts
        );
    }

    #[test]
    fn greedy_beats_uniform_min_rate_for_heterogeneous_users() {
        let links: Vec<_> = [80.0, 200.0, 450.0, 740.0].map(link).into();
        let greedy = allocate_subcarriers(&links, 60);
        let uniform = uniform_allocation(&links, 60);
        assert!(
            greedy.min_rate() >= uniform.min_rate() - 1e-9,
            "greedy {} < uniform {}",
            greedy.min_rate(),
            uniform.min_rate()
        );
        // With this heterogeneity it should be strictly better.
        assert!(greedy.min_rate() > uniform.min_rate() * 1.01);
    }

    #[test]
    fn equal_distances_get_balanced_counts() {
        let links: Vec<_> = [400.0, 400.0, 400.0].map(link).into();
        let alloc = allocate_subcarriers(&links, 31);
        let min = *alloc.counts.iter().min().unwrap();
        let max = *alloc.counts.iter().max().unwrap();
        assert!(max - min <= 1, "{:?}", alloc.counts);
    }

    /// Random-instance property: greedy's min-rate ≥ min-rate of random
    /// feasible allocations with the same total (Theorem 1 corollary).
    #[test]
    fn prop_greedy_is_maxmin_optimal_vs_random_allocations() {
        struct Instance;
        impl Gen for Instance {
            type Value = (Vec<f64>, usize, u64);
            fn generate(&self, rng: &mut Pcg64) -> Self::Value {
                let k = 2 + rng.uniform_usize(4);
                let dists: Vec<f64> = (0..k).map(|_| rng.uniform_range(50.0, 750.0)).collect();
                let m = k + rng.uniform_usize(20);
                (dists, m, rng.next_u64())
            }
        }
        check(&PropConfig { cases: 40, ..Default::default() }, &Instance, |(dists, m, seed)| {
            let links: Vec<_> = dists.iter().map(|&d| link(d)).collect();
            let greedy = allocate_subcarriers(&links, *m);
            let mut rng = Pcg64::seeded(*seed);
            for _ in 0..10 {
                // Random feasible allocation: 1 each + random remainder.
                let mut counts = vec![1usize; links.len()];
                for _ in 0..(m - links.len()) {
                    counts[rng.uniform_usize(links.len())] += 1;
                }
                let rates = rates_for_counts(&links, &counts);
                let alt_min = rates.iter().cloned().fold(f64::INFINITY, f64::min);
                if alt_min > greedy.min_rate() + 1e-6 {
                    return Err(format!(
                        "random alloc {counts:?} min {alt_min} beats greedy {}",
                        greedy.min_rate()
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn exhaustive_small_instance_optimality() {
        // K=3, M=7: enumerate all allocations (c1+c2+c3=7, ci≥1) and verify
        // greedy achieves the global max-min.
        let links: Vec<_> = [150.0, 420.0, 730.0].map(link).into();
        let greedy = allocate_subcarriers(&links, 7).min_rate();
        let mut best = 0.0f64;
        for c1 in 1..=5 {
            for c2 in 1..=(6 - c1) {
                let c3 = 7 - c1 - c2;
                let rates = rates_for_counts(&links, &[c1, c2, c3]);
                best = best.max(rates.iter().cloned().fold(f64::INFINITY, f64::min));
            }
        }
        assert!(
            (greedy - best).abs() / best < 1e-9,
            "greedy {greedy} vs exhaustive {best}"
        );
    }

    #[test]
    #[should_panic(expected = "at least one sub-carrier")]
    fn too_few_subcarriers_panics() {
        let links: Vec<_> = [100.0, 200.0, 300.0].map(link).into();
        allocate_subcarriers(&links, 2);
    }
}
