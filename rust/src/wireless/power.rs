//! Truncated channel-inversion power control — Eq. (5)–(8) — as an
//! *executable policy*, not just the closed-form rate.
//!
//! [`mqam`](super::mqam) uses the analytic optimum (Rayleigh ⇒ the power
//! normalizer is `E₁(γ_th)`); this module implements the per-slot policy a
//! transmitter would actually run — observe γ, invert the channel if
//! γ ≥ γ_th, stay silent otherwise — and the tests verify by Monte Carlo
//! that the simulated policy meets the average-power constraint of Eq. (4)
//! with equality and achieves exactly the analytic expected rate of
//! Eq. (10)–(11). This is the cross-check that the latency model stands on.

use super::mqam::LinkParams;
use crate::util::math::exp_int_e1;
use crate::util::rng::Pcg64;

/// The per-sub-carrier truncated channel-inversion policy of one MU.
#[derive(Clone, Debug)]
pub struct InversionPolicy {
    /// Truncation threshold γ_th on the raw (unit-mean) fading gain.
    pub gamma_th: f64,
    /// Power scale ρ of Eq. (7) (W).
    pub rho: f64,
    /// Constant rate when transmitting (bit/s) — Eq. (10).
    pub rate_on: f64,
    /// Per-sub-carrier average power budget (W).
    pub p_budget: f64,
    attenuation: f64,
}

impl InversionPolicy {
    /// Instantiate the policy for a link whose power is split over
    /// `m_subcarriers`, at threshold `gamma_th`.
    pub fn new(link: &LinkParams, m_subcarriers: usize, gamma_th: f64) -> Self {
        assert!(gamma_th > 0.0);
        let p_budget = link.p_max_w / m_subcarriers as f64;
        let attenuation = link.attenuation();
        // Eq. (7): ρ = P_budget / (N0·B0·d^α · E[1/γ]_{γth})  — note ρ here
        // carries the attenuation so p = ρ/γ̃ = ρ·N0B0d^α/γ simplifies to
        // p(γ) = P_budget / (E1(γth) · γ).
        let rho = p_budget / exp_int_e1(gamma_th);
        let kappa = link.qam_kappa();
        let snr_on = kappa * rho / attenuation;
        let rate_on = link.b0_hz * (1.0 + snr_on).log2();
        Self {
            gamma_th,
            rho,
            rate_on,
            p_budget,
            attenuation,
        }
    }

    /// Policy with the rate-optimal threshold (Eq. 11).
    pub fn optimal(link: &LinkParams, m_subcarriers: usize) -> Self {
        let (_, th) = link.optimal_rate_per_subcarrier(m_subcarriers);
        Self::new(link, m_subcarriers, th)
    }

    /// Instantaneous transmit power for an observed fading gain γ (Eq. 5):
    /// channel inversion above threshold, silence below.
    pub fn power_for_gain(&self, gamma: f64) -> f64 {
        if gamma >= self.gamma_th {
            self.rho / gamma
        } else {
            0.0
        }
    }

    /// Instantaneous rate for an observed gain (Eq. 10): constant when on.
    pub fn rate_for_gain(&self, gamma: f64) -> f64 {
        if gamma >= self.gamma_th {
            self.rate_on
        } else {
            0.0
        }
    }

    /// Analytic expected rate (Eq. 11 at this threshold): `rate_on·e^{−γth}`.
    pub fn expected_rate(&self) -> f64 {
        self.rate_on * (-self.gamma_th).exp()
    }

    /// Outage probability (silent fraction): `1 − e^{−γth}`.
    pub fn outage(&self) -> f64 {
        1.0 - (-self.gamma_th).exp()
    }

    /// Monte-Carlo estimate of (average power, average rate) over `n` slots
    /// of Rayleigh fading.
    pub fn simulate(&self, n: usize, rng: &mut Pcg64) -> (f64, f64) {
        let mut p_sum = 0.0;
        let mut r_sum = 0.0;
        for _ in 0..n {
            let gamma = rng.exponential();
            p_sum += self.power_for_gain(gamma);
            r_sum += self.rate_for_gain(gamma);
        }
        (p_sum / n as f64, r_sum / n as f64)
    }

    /// Received SNR when transmitting (constant by construction — that is
    /// the point of channel inversion).
    pub fn snr_on(&self) -> f64 {
        self.rho / self.attenuation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn paper_link(dist: f64) -> LinkParams {
        LinkParams {
            p_max_w: 0.2,
            dist_m: dist,
            alpha: 2.8,
            noise_w: 3e-14,
            b0_hz: 30_000.0,
            ber: 1e-3,
        }
    }

    #[test]
    fn average_power_constraint_met_with_equality() {
        // Eq. (4): E[p] = budget when ρ is set by Eq. (7).
        let link = paper_link(400.0);
        for th in [0.05, 0.3, 1.0] {
            let pol = InversionPolicy::new(&link, 20, th);
            let mut rng = Pcg64::seeded(61);
            let (p_avg, _) = pol.simulate(2_000_000, &mut rng);
            let rel = (p_avg - pol.p_budget).abs() / pol.p_budget;
            assert!(rel < 0.02, "th={th}: E[p]={p_avg} vs budget {} (rel {rel})", pol.p_budget);
        }
    }

    #[test]
    fn simulated_rate_matches_analytic_expectation() {
        let link = paper_link(300.0);
        let pol = InversionPolicy::optimal(&link, 10);
        let mut rng = Pcg64::seeded(62);
        let (_, r_avg) = pol.simulate(500_000, &mut rng);
        let want = pol.expected_rate();
        assert!(
            (r_avg - want).abs() / want < 0.01,
            "MC rate {r_avg} vs analytic {want}"
        );
        // And the analytic policy expectation equals the mqam module's
        // optimum (same formula path).
        let (opt_rate, _) = link.optimal_rate_per_subcarrier(10);
        assert!(
            (want - opt_rate).abs() / opt_rate < 1e-9,
            "policy {want} vs mqam {opt_rate}"
        );
    }

    #[test]
    fn constant_snr_while_transmitting() {
        // Channel inversion ⇒ the received SNR (hence the M-QAM
        // constellation) is fixed whenever the MU transmits.
        let link = paper_link(500.0);
        let pol = InversionPolicy::new(&link, 8, 0.2);
        let mut rng = Pcg64::seeded(63);
        for _ in 0..1000 {
            let gamma = rng.exponential();
            if gamma >= pol.gamma_th {
                let p = pol.power_for_gain(gamma);
                let snr = p * gamma / link.attenuation();
                assert!((snr - pol.snr_on()).abs() / pol.snr_on() < 1e-12);
            } else {
                assert_eq!(pol.power_for_gain(gamma), 0.0);
            }
        }
    }

    #[test]
    fn outage_fraction_matches() {
        let link = paper_link(200.0);
        let pol = InversionPolicy::new(&link, 4, 0.7);
        let mut rng = Pcg64::seeded(64);
        let n = 400_000;
        let silent = (0..n)
            .filter(|_| pol.rate_for_gain(rng.exponential()) == 0.0)
            .count() as f64
            / n as f64;
        assert!((silent - pol.outage()).abs() < 5e-3, "{silent} vs {}", pol.outage());
    }

    #[test]
    fn higher_threshold_trades_outage_for_on_rate() {
        let link = paper_link(350.0);
        let lo = InversionPolicy::new(&link, 10, 0.05);
        let hi = InversionPolicy::new(&link, 10, 1.5);
        assert!(hi.rate_on > lo.rate_on, "deep-fade inversion wastes power");
        assert!(hi.outage() > lo.outage());
    }
}
