//! Rateless broadcast downlink — Eq. (16)–(18).
//!
//! The base station spreads its power uniformly over the `M` sub-carriers
//! and adapts a rateless code to the *worst* instantaneous SNR among the
//! receivers on each sub-carrier:
//!
//! ```text
//! R_m(t) = min_k B0·log2(1 + SNR_{k,m}(t)),   SNR_{k,m} = P·γ/(M·N0·B0·d_k^α)
//! T_DL   = E[ min{ t : T_s Σ_{τ≤t} Σ_m R_m(τ) ≥ bits } ]
//! ```
//!
//! ### Closed form for the per-sub-carrier expected min-rate
//!
//! With γ ~ Exp(1) i.i.d. per user, `P(R_m > r) = Π_k P(γ_k > (2^{r/B0}−1)/c_k)
//! = exp(−(2^{r/B0}−1)·S)` where `c_k = P/(M·N0·B0·d_k^α)` and `S = Σ_k 1/c_k`.
//! Integrating the CCDF with `u = 2^{r/B0}−1` gives the exact
//!
//! ```text
//! E[R_m] = (B0/ln 2) · e^S · E₁(S).
//! ```
//!
//! The stopping time of the renewal sum is then `T_DL ≈ T_s·⌈bits/(M·E[R_m]·T_s)⌉`
//! (Wald; the per-slot sum over M ≥ 85 sub-carriers concentrates hard).
//! [`broadcast_latency_mc`] simulates Eq. (18) literally and the tests
//! verify the two agree to Monte-Carlo noise.

use crate::util::math::exp_int_e1;
use crate::util::rng::Pcg64;
use anyhow::{bail, Result};

/// Inputs for a broadcast from one base station to a set of receivers.
#[derive(Clone, Debug)]
pub struct BroadcastParams {
    /// Base-station total power (W), spread uniformly over sub-carriers.
    pub p_total_w: f64,
    /// Number of sub-carriers available to this broadcast.
    pub m_subcarriers: usize,
    /// Per-sub-carrier noise power N0·B0 (W).
    pub noise_w: f64,
    /// Sub-carrier bandwidth B0 (Hz).
    pub b0_hz: f64,
    /// Path-loss exponent α.
    pub alpha: f64,
    /// Receiver distances d_k (m).
    pub dists_m: Vec<f64>,
    /// Slot duration T_s (s).
    pub slot_s: f64,
}

impl BroadcastParams {
    /// `c_k = P/(M·N0·B0·d_k^α)` — mean SNR of receiver k (Eq. 17).
    fn mean_snrs(&self) -> Vec<f64> {
        let p_per = self.p_total_w / self.m_subcarriers as f64;
        self.dists_m
            .iter()
            .map(|d| p_per / (self.noise_w * d.powf(self.alpha)))
            .collect()
    }

    /// Exact expected worst-user rate on one sub-carrier (bits/s).
    pub fn expected_min_rate(&self) -> f64 {
        assert!(!self.dists_m.is_empty(), "broadcast needs ≥1 receiver");
        let s: f64 = self.mean_snrs().iter().map(|c| 1.0 / c).sum();
        // e^S·E1(S): for tiny S, E1 ~ −ln S so the product is finite; for
        // large S (hopeless link) it tends to 1/S.
        self.b0_hz / std::f64::consts::LN_2 * s.exp() * exp_int_e1(s.max(1e-300))
    }

    /// Expected total broadcast rate over all sub-carriers (bits/s).
    pub fn expected_total_rate(&self) -> f64 {
        self.m_subcarriers as f64 * self.expected_min_rate()
    }
}

/// Expected broadcast latency (s) for `bits` via the closed form + Wald
/// stopping-time approximation, quantized up to whole slots as the rateless
/// decoder finishes at a slot boundary.
pub fn broadcast_latency(params: &BroadcastParams, bits: f64) -> f64 {
    if bits <= 0.0 {
        return 0.0;
    }
    let rate = params.expected_total_rate();
    let slots = (bits / (rate * params.slot_s)).ceil();
    slots * params.slot_s
}

/// Literal Monte-Carlo simulation of Eq. (18): sample every sub-carrier's
/// worst-user rate per slot until `bits` are delivered; average over
/// `trials`. Exact but O(slots × M × K) — used for validation and small
/// problems. Errors (instead of spinning forever) when the link is so weak
/// that the payload cannot be delivered within the slot budget.
pub fn broadcast_latency_mc(
    params: &BroadcastParams,
    bits: f64,
    trials: usize,
    rng: &mut Pcg64,
) -> Result<f64> {
    if bits <= 0.0 {
        return Ok(0.0);
    }
    let cs = params.mean_snrs();
    let mut total = 0.0;
    for trial in 0..trials {
        let mut delivered = 0.0;
        let mut slots = 0u64;
        while delivered < bits {
            slots += 1;
            let mut slot_rate = 0.0;
            for _ in 0..params.m_subcarriers {
                // min over users of log2(1+c_k γ_k); γ i.i.d. per (user, m, t)
                let min_rate = cs
                    .iter()
                    .map(|&c| (1.0 + c * rng.exponential()).log2())
                    .fold(f64::INFINITY, f64::min);
                slot_rate += params.b0_hz * min_rate;
            }
            delivered += slot_rate * params.slot_s;
            if slots > 100_000_000 {
                bail!(
                    "broadcast Monte Carlo did not terminate: trial {trial} delivered only \
                     {delivered:.3e} of {bits:.3e} bits after {slots} slots (worst-user rate ≈ 0; \
                     check powers/distances/noise in the broadcast parameters)"
                );
            }
        }
        total += slots as f64 * params.slot_s;
    }
    Ok(total / trials as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params(dists: Vec<f64>, m: usize) -> BroadcastParams {
        BroadcastParams {
            p_total_w: 20.0,
            m_subcarriers: m,
            noise_w: 3e-14,
            b0_hz: 30_000.0,
            alpha: 2.8,
            dists_m: dists,
            slot_s: 1e-3,
        }
    }

    #[test]
    fn closed_form_matches_single_user_mc_mean() {
        // E[log2(1+cγ)] MC vs (1/ln2)·e^{1/c}E1(1/c)
        let p = params(vec![400.0], 100);
        let c = p.mean_snrs()[0];
        let mut rng = Pcg64::seeded(21);
        let n = 300_000;
        let mc: f64 = (0..n)
            .map(|_| (1.0 + c * rng.exponential()).log2())
            .sum::<f64>()
            / n as f64;
        let analytic = p.expected_min_rate() / p.b0_hz;
        assert!(
            (mc - analytic).abs() / analytic < 0.01,
            "mc {mc} vs analytic {analytic}"
        );
    }

    #[test]
    fn closed_form_matches_multiuser_mc() {
        let p = params(vec![200.0, 500.0, 700.0, 740.0], 50);
        let mut rng = Pcg64::seeded(22);
        let cs = p.mean_snrs();
        let n = 200_000;
        let mc: f64 = (0..n)
            .map(|_| {
                cs.iter()
                    .map(|&c| (1.0 + c * rng.exponential()).log2())
                    .fold(f64::INFINITY, f64::min)
            })
            .sum::<f64>()
            / n as f64;
        let analytic = p.expected_min_rate() / p.b0_hz;
        assert!(
            (mc - analytic).abs() / analytic < 0.02,
            "mc {mc} vs analytic {analytic}"
        );
    }

    #[test]
    fn latency_formula_matches_full_mc_simulation() {
        let p = params(vec![300.0, 650.0], 20);
        let bits = 2e6; // small enough for MC
        let analytic = broadcast_latency(&p, bits);
        let mut rng = Pcg64::seeded(23);
        let mc = broadcast_latency_mc(&p, bits, 30, &mut rng).unwrap();
        assert!(
            (mc - analytic).abs() / analytic < 0.05,
            "mc {mc} vs analytic {analytic}"
        );
    }

    #[test]
    fn worst_user_dominates() {
        let near = params(vec![100.0, 120.0], 50);
        let with_far = params(vec![100.0, 740.0], 50);
        assert!(near.expected_min_rate() > with_far.expected_min_rate());
        // And the min-rate is below the far user's own single-user rate.
        let far_alone = params(vec![740.0], 50);
        assert!(with_far.expected_min_rate() <= far_alone.expected_min_rate() + 1e-9);
    }

    #[test]
    fn latency_decreases_with_subcarriers_sublinearly() {
        // More sub-carriers help, but the fixed power budget is split among
        // them, so the gain is sub-linear in M (log2(1+c/M) per carrier).
        let bits = 3.57e8; // ResNet18 × 32 bits
        let t_few = broadcast_latency(&params(vec![400.0, 600.0], 85), bits);
        let t_many = broadcast_latency(&params(vec![400.0, 600.0], 600), bits);
        assert!(t_many < t_few);
        let ratio = t_few / t_many;
        assert!(
            ratio > 1.5 && ratio < 600.0 / 85.0,
            "ratio {ratio} should be sub-linear in M"
        );
    }

    #[test]
    fn more_users_never_faster() {
        let bits = 1e8;
        let t2 = broadcast_latency(&params(vec![300.0, 400.0], 100), bits);
        let t4 = broadcast_latency(&params(vec![300.0, 400.0, 500.0, 700.0], 100), bits);
        assert!(t4 >= t2);
    }

    #[test]
    fn zero_bits_zero_latency() {
        assert_eq!(broadcast_latency(&params(vec![100.0], 10), 0.0), 0.0);
    }

    #[test]
    fn latency_quantized_to_slots() {
        let p = params(vec![400.0], 10);
        let t = broadcast_latency(&p, 1.0); // one bit still costs one slot
        assert!((t - p.slot_s).abs() < 1e-12);
    }
}
