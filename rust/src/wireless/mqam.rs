//! Threshold-optimized expected uplink rate for truncated channel inversion
//! with M-QAM signalling — Eq. (5)–(12) of the paper, following
//! Goldsmith & Chua (1997).
//!
//! With Rayleigh fading (γ ~ Exp(1)) the power normalizer of Eq. (7)–(8)
//! has closed form `E[1/γ]_{γth} = E₁(γth)` (exponential integral), so the
//! expected per-sub-carrier rate of a MU with `m` assigned sub-carriers is
//!
//! ```text
//! Ū(m) = max_{γth}  B0·log2(1 + κ·P_max / (m·N0·B0·d^α·E₁(γth))) · e^{−γth}
//! κ = 1.5 / (−ln(5·BER))
//! ```
//!
//! which we maximize by golden-section search over ln γth (the objective is
//! unimodal: small γth wastes power inverting deep fades, large γth wastes
//! coverage).

use crate::util::math::{exp_int_e1, golden_section_max};

/// Static parameters of one transmitter→receiver link.
#[derive(Clone, Copy, Debug)]
pub struct LinkParams {
    /// Transmitter total power budget P_max (W).
    pub p_max_w: f64,
    /// Distance d (m).
    pub dist_m: f64,
    /// Path-loss exponent α.
    pub alpha: f64,
    /// Per-sub-carrier noise power N0·B0 (W).
    pub noise_w: f64,
    /// Sub-carrier bandwidth B0 (Hz).
    pub b0_hz: f64,
    /// Target bit error rate.
    pub ber: f64,
}

impl LinkParams {
    /// κ = 1.5 / (−ln(5·BER)) — the M-QAM SNR gap factor of Eq. (9).
    pub fn qam_kappa(&self) -> f64 {
        1.5 / (-(5.0 * self.ber).ln())
    }

    /// Deterministic link attenuation N0·B0·d^α.
    pub fn attenuation(&self) -> f64 {
        self.noise_w * self.dist_m.powf(self.alpha)
    }

    /// Expected rate on ONE sub-carrier when the transmitter's power is
    /// split over `m_subcarriers`, with the optimal truncation threshold
    /// (Eq. 11). Returns `(rate_bps, optimal_gamma_th)`.
    pub fn optimal_rate_per_subcarrier(&self, m_subcarriers: usize) -> (f64, f64) {
        assert!(m_subcarriers >= 1);
        let kappa = self.qam_kappa();
        let p_per = self.p_max_w / m_subcarriers as f64;
        let c = kappa * p_per / self.attenuation(); // κ·ρ numerator scale
        let objective = |ln_th: f64| {
            let th: f64 = ln_th.exp();
            let rho_scale = c / exp_int_e1(th);
            self.b0_hz * (1.0 + rho_scale).log2() * (-th).exp()
        };
        let (ln_th, rate) = golden_section_max(objective, (1e-9f64).ln(), (30.0f64).ln(), 1e-6);
        (rate, ln_th.exp())
    }

    /// Total expected UL rate with `m` sub-carriers: `Ū_k = m · Ū(m)`
    /// (Eq. 12; i.i.d. sub-carriers so all have the same optimum).
    pub fn total_rate(&self, m_subcarriers: usize) -> f64 {
        let (per, _) = self.optimal_rate_per_subcarrier(m_subcarriers);
        m_subcarriers as f64 * per
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn paper_mu_link(dist: f64) -> LinkParams {
        LinkParams {
            p_max_w: 0.2,
            dist_m: dist,
            alpha: 2.8,
            noise_w: 3e-14, // −150 dBm/Hz × 30 kHz
            b0_hz: 30_000.0,
            ber: 1e-3,
        }
    }

    #[test]
    fn kappa_value() {
        let k = paper_mu_link(100.0).qam_kappa();
        // 1.5 / −ln(0.005) = 1.5/5.2983 ≈ 0.28311
        assert!((k - 0.28311).abs() < 1e-4, "{k}");
    }

    #[test]
    fn rate_positive_and_sane_at_paper_scales() {
        let (rate, th) = paper_mu_link(250.0).optimal_rate_per_subcarrier(20);
        assert!(rate > 0.0);
        assert!(th > 0.0);
        // 30 kHz sub-carrier cannot exceed ~20 bit/s/Hz at these SNRs.
        assert!(rate < 30_000.0 * 25.0, "rate {rate}");
        // And at 250 m with 10 mW/sub-carrier the link is strong: expect
        // at least a few bits/s/Hz.
        assert!(rate > 30_000.0 * 2.0, "rate {rate}");
    }

    #[test]
    fn rate_decreases_with_distance() {
        let near = paper_mu_link(100.0).total_rate(10);
        let mid = paper_mu_link(400.0).total_rate(10);
        let far = paper_mu_link(750.0).total_rate(10);
        assert!(near > mid && mid > far, "{near} {mid} {far}");
    }

    #[test]
    fn total_rate_increases_with_subcarriers() {
        let l = paper_mu_link(300.0);
        let mut prev = 0.0;
        for m in [1usize, 2, 4, 8, 16, 32] {
            let r = l.total_rate(m);
            assert!(r > prev, "m={m}: {r} <= {prev}");
            prev = r;
        }
    }

    #[test]
    fn per_subcarrier_rate_decreases_with_subcarriers() {
        // Splitting the same power over more sub-carriers lowers each one's
        // rate (log concavity) even as the total grows.
        let l = paper_mu_link(300.0);
        let (r1, _) = l.optimal_rate_per_subcarrier(1);
        let (r8, _) = l.optimal_rate_per_subcarrier(8);
        let (r64, _) = l.optimal_rate_per_subcarrier(64);
        assert!(r1 > r8 && r8 > r64);
    }

    #[test]
    fn optimal_threshold_beats_fixed_thresholds() {
        let l = paper_mu_link(500.0);
        let kappa = l.qam_kappa();
        let c = kappa * (l.p_max_w / 4.0) / l.attenuation();
        let rate_at = |th: f64| l.b0_hz * (1.0 + c / exp_int_e1(th)).log2() * (-th).exp();
        let (opt_rate, _) = l.optimal_rate_per_subcarrier(4);
        for th in [1e-6, 1e-3, 0.01, 0.1, 0.5, 1.0, 2.0, 5.0] {
            assert!(
                opt_rate >= rate_at(th) - 1e-6,
                "th={th}: fixed {} > optimal {opt_rate}",
                rate_at(th)
            );
        }
    }

    #[test]
    fn rate_increases_with_power() {
        let mut weak = paper_mu_link(300.0);
        weak.p_max_w = 0.02;
        let strong = paper_mu_link(300.0);
        assert!(strong.total_rate(8) > weak.total_rate(8));
    }

    #[test]
    fn rate_decreases_with_stricter_ber() {
        let mut strict = paper_mu_link(300.0);
        strict.ber = 1e-6;
        let loose = paper_mu_link(300.0);
        assert!(loose.total_rate(8) > strict.total_rate(8));
    }
}
