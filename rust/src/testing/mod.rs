//! Minimal property-based testing harness (offline substitute for
//! `proptest`). Generators produce random values from a [`Pcg64`]; a
//! property is run for `cases` iterations and, on failure, the harness
//! performs a bounded shrink search over the generator's shrink candidates
//! before panicking with the minimal counterexample it found.
//!
//! Used for the coordinator/routing/batching invariants (Algorithm 2
//! optimality, sparsifier mass conservation, codec round-trips, scheduler
//! state machines).

use crate::util::rng::Pcg64;
use std::fmt::Debug;

/// A value generator with optional shrinking.
pub trait Gen {
    type Value: Clone + Debug;

    fn generate(&self, rng: &mut Pcg64) -> Self::Value;

    /// Candidate "smaller" values to try when shrinking a failure. Default:
    /// no shrinking.
    fn shrink(&self, _value: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Configuration for a property run.
#[derive(Clone, Debug)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_steps: usize,
}

impl Default for PropConfig {
    fn default() -> Self {
        Self {
            cases: 200,
            seed: 0xfeed_beef,
            max_shrink_steps: 200,
        }
    }
}

/// Run `prop` on `cases` generated inputs; panic with the (shrunk) minimal
/// counterexample on failure. `prop` returns `Err(reason)` to fail.
pub fn check<G: Gen, F>(cfg: &PropConfig, gen: &G, mut prop: F)
where
    F: FnMut(&G::Value) -> Result<(), String>,
{
    let mut rng = Pcg64::seeded(cfg.seed);
    for case in 0..cfg.cases {
        let value = gen.generate(&mut rng);
        if let Err(reason) = prop(&value) {
            // Shrink: greedy first-improvement descent.
            let mut best = value.clone();
            let mut best_reason = reason;
            let mut steps = 0;
            'outer: while steps < cfg.max_shrink_steps {
                for cand in gen.shrink(&best) {
                    steps += 1;
                    if let Err(r) = prop(&cand) {
                        best = cand;
                        best_reason = r;
                        continue 'outer;
                    }
                    if steps >= cfg.max_shrink_steps {
                        break;
                    }
                }
                break;
            }
            panic!(
                "property failed at case {case} (seed {:#x}):\n  input: {:?}\n  reason: {}",
                cfg.seed, best, best_reason
            );
        }
    }
}

/// Uniform usize in [lo, hi] with shrinking toward lo.
pub struct UsizeRange {
    pub lo: usize,
    pub hi: usize,
}

impl Gen for UsizeRange {
    type Value = usize;

    fn generate(&self, rng: &mut Pcg64) -> usize {
        self.lo + rng.uniform_usize(self.hi - self.lo + 1)
    }

    fn shrink(&self, v: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *v > self.lo {
            out.push(self.lo);
            out.push(self.lo + (*v - self.lo) / 2);
            out.push(*v - 1);
        }
        out.dedup();
        out
    }
}

/// Uniform f64 in [lo, hi) with shrinking toward the midpoint and lo.
pub struct F64Range {
    pub lo: f64,
    pub hi: f64,
}

impl Gen for F64Range {
    type Value = f64;

    fn generate(&self, rng: &mut Pcg64) -> f64 {
        rng.uniform_range(self.lo, self.hi)
    }

    fn shrink(&self, v: &f64) -> Vec<f64> {
        if (*v - self.lo).abs() < 1e-12 {
            Vec::new()
        } else {
            vec![self.lo, self.lo + (*v - self.lo) / 2.0]
        }
    }
}

/// Vector of f32 drawn N(0, scale), length in [min_len, max_len].
/// Shrinks by halving length and zeroing elements.
pub struct VecF32 {
    pub min_len: usize,
    pub max_len: usize,
    pub scale: f64,
}

impl Gen for VecF32 {
    type Value = Vec<f32>;

    fn generate(&self, rng: &mut Pcg64) -> Vec<f32> {
        let n = self.min_len + rng.uniform_usize(self.max_len - self.min_len + 1);
        (0..n).map(|_| (rng.normal() * self.scale) as f32).collect()
    }

    fn shrink(&self, v: &Vec<f32>) -> Vec<Vec<f32>> {
        let mut out = Vec::new();
        if v.len() > self.min_len {
            let half = self.min_len.max(v.len() / 2);
            out.push(v[..half].to_vec());
        }
        if v.iter().any(|&x| x != 0.0) {
            out.push(v.iter().map(|_| 0.0).collect());
        }
        out
    }
}

/// Pair of independent generators.
pub struct Pair<A, B>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for Pair<A, B> {
    type Value = (A::Value, B::Value);

    fn generate(&self, rng: &mut Pcg64) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }

    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = self
            .0
            .shrink(&v.0)
            .into_iter()
            .map(|a| (a, v.1.clone()))
            .collect();
        out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(&PropConfig::default(), &UsizeRange { lo: 0, hi: 100 }, |&n| {
            if n <= 100 {
                Ok(())
            } else {
                Err("out of range".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_counterexample() {
        check(
            &PropConfig { cases: 500, ..Default::default() },
            &UsizeRange { lo: 0, hi: 100 },
            |&n| if n < 90 { Ok(()) } else { Err(format!("{n} too big")) },
        );
    }

    #[test]
    fn shrinking_reaches_small_case() {
        // Property fails for all n >= 10; shrinker should descend below the
        // original failing value.
        let res = std::panic::catch_unwind(|| {
            check(
                &PropConfig { cases: 100, ..Default::default() },
                &UsizeRange { lo: 0, hi: 1000 },
                |&n| if n < 10 { Ok(()) } else { Err("ge 10".into()) },
            );
        });
        let msg = match res {
            Err(e) => e
                .downcast_ref::<String>()
                .cloned()
                .unwrap_or_else(|| "?".into()),
            Ok(()) => panic!("property should have failed"),
        };
        // The minimal counterexample is exactly 10 via binary descent, but we
        // only require it shrank to something < 100.
        let n: usize = msg
            .split("input: ")
            .nth(1)
            .unwrap()
            .split_whitespace()
            .next()
            .unwrap()
            .parse()
            .unwrap();
        assert!(n < 100, "shrunk value {n} (msg: {msg})");
    }

    #[test]
    fn vecf32_generator_respects_bounds() {
        let gen = VecF32 { min_len: 3, max_len: 8, scale: 1.0 };
        let mut rng = Pcg64::seeded(1);
        for _ in 0..100 {
            let v = gen.generate(&mut rng);
            assert!((3..=8).contains(&v.len()));
        }
    }
}
