//! Persistent deterministic **worker-pool subsystem** for the fl/des/sim
//! hot path.
//!
//! The PR-3 intra-round fan-out priced every round with fresh
//! `std::thread::scope` spawns: O(rounds × clusters) thread creations per
//! training run, which at small dimensions dominated the round itself. This
//! module replaces that with a pool that is created **once** per process
//! (or once per command via `--pool-threads`) and *leased* through the
//! stack:
//!
//! * [`crate::sim::matrix::run_matrix`] runs the outer scenario grid as one
//!   batch on the pool;
//! * [`crate::fl::run_hierarchical`] and the DES engine
//!   ([`crate::des::engine`]) lease nested lanes ([`PoolHandle::lease`])
//!   for the per-cluster compute+uplink and per-MU compute+DGC fan-outs —
//!   one batch per round, no spawns;
//! * [`crate::sim::matrix::run_parallel`] survives as a thin compatibility
//!   shim over [`PoolHandle::run_ordered`].
//!
//! ## Execution model
//!
//! The pool owns `lanes − 1` parked worker threads (std `Condvar` parking,
//! no crossbeam); the submitting thread is always the remaining lane. A
//! submitted [`lease::Batch`] carries its own per-lane work-stealing
//! queues ([`queue::LaneQueues`]) preloaded with the identical strided
//! distribution the scoped engine used. Workers wake, attach to a batch
//! with free executor slots, drain items (own queue front first, then
//! steals from victims' backs), and go back to sleep. The submitter
//! attaches too and then blocks until the batch drains — which is what
//! makes the borrowed-closure lifetime erasure sound and keeps nested
//! submissions deadlock-free: every batch can always make progress on its
//! own submitter even when all pool workers are busy.
//!
//! ## Determinism contract
//!
//! Identical to the historical `run_parallel`: results are returned in
//! item-index order through an **ordered-slot reduction**, items are
//! disjoint, and no reduction ever folds in completion order — so results
//! are bit-identical for every pool size, lease width, and scheduling
//! interleaving. The golden suites (`matrix_golden`, `des_golden`,
//! coordinator equivalence) pass unchanged with the pool active at any
//! thread count.
//!
//! ## Panics and errors
//!
//! A panicking job does not poison the pool: the panic is captured, the
//! batch still drains, and the submitter re-raises the payload on its own
//! thread with the failing item's index attached (`pool job <i> panicked:
//! …`) — preserving the `std::thread::scope` propagation semantics while
//! adding scenario context.

pub mod lease;
pub(crate) mod queue;

pub use lease::Lease;

use anyhow::{bail, Result};
use lease::Batch;
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

/// State shared between the pool's workers and every [`PoolHandle`].
struct Shared {
    state: Mutex<State>,
    work_ready: Condvar,
}

#[derive(Default)]
struct State {
    /// Batches with work outstanding, oldest first. A batch is pushed by
    /// its submitter when advertised and removed by the same submitter
    /// once it has drained.
    batches: Vec<Arc<Batch>>,
    shutdown: bool,
}

/// A persistent pool of `lanes` concurrent execution lanes — `lanes − 1`
/// parked worker threads plus whichever thread submits a batch. Dropping
/// the pool signals shutdown and joins the workers; handles taken from it
/// keep working afterwards (batches then run entirely on their submitter).
pub struct WorkerPool {
    shared: Arc<Shared>,
    lanes: usize,
    workers: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Build a pool with `lanes` total execution lanes (including the
    /// submitting thread); `0` means one lane per available core.
    pub fn new(lanes: usize) -> Self {
        let lanes = if lanes == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        } else {
            lanes
        };
        let shared = Arc::new(Shared {
            state: Mutex::new(State::default()),
            work_ready: Condvar::new(),
        });
        let workers = (1..lanes)
            .map(|w| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("hfl-pool-{w}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn pool worker")
            })
            .collect();
        Self {
            shared,
            lanes,
            workers,
        }
    }

    /// Total execution lanes (including the submitting thread).
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// A cloneable, `Send + Sync` handle for threading through options
    /// structs and leasing nested lanes.
    pub fn handle(&self) -> PoolHandle {
        PoolHandle {
            shared: Arc::clone(&self.shared),
            lanes: self.lanes,
        }
    }

    /// Ordered parallel map — see [`PoolHandle::run_ordered`].
    pub fn run_ordered<T, F>(&self, n_items: usize, width: usize, f: F) -> Result<Vec<T>>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        self.handle().run_ordered(n_items, width, f)
    }
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool")
            .field("lanes", &self.lanes)
            .finish()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.work_ready.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// A worker: park until a batch has work and a free executor slot, attach
/// and drain, repeat. Shutdown only wins once no batch is attachable, so
/// dropping the pool never strands submitted work.
fn worker_loop(shared: &Shared) {
    let mut state = shared.state.lock().unwrap();
    loop {
        let attachable = state.batches.iter().find(|b| b.attachable()).cloned();
        if let Some(batch) = attachable {
            drop(state);
            batch.work();
            state = shared.state.lock().unwrap();
            continue;
        }
        if state.shutdown {
            break;
        }
        state = shared.work_ready.wait(state).unwrap();
    }
}

/// Cloneable reference to a pool, independent of the [`WorkerPool`]'s
/// lifetime. Threaded through [`crate::fl::TrainOptions`] /
/// [`crate::sim::matrix::MatrixOptions`] so every layer of a run leases
/// lanes from the same pool.
#[derive(Clone)]
pub struct PoolHandle {
    shared: Arc<Shared>,
    lanes: usize,
}

impl std::fmt::Debug for PoolHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PoolHandle")
            .field("lanes", &self.lanes)
            .finish()
    }
}

impl PoolHandle {
    /// Lane count the pool was built with.
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Take a width-capped lease for a training run's nested fan-outs.
    /// A width of 0 is normalized to 1 (sequential).
    pub fn lease(&self, width: usize) -> Lease {
        Lease::new(self.clone(), width)
    }

    /// Ordered parallel map over item indices `0..n_items` with at most
    /// `width` concurrent executors (including the calling thread), which
    /// is clamped to `n_items` — an over-wide request never creates idle
    /// lanes. Returns `f(0), f(1), …` in index order no matter which lane
    /// computed what; bit-identical for every `width` and pool size.
    ///
    /// The calling thread always participates, so the call makes progress
    /// even when every pool worker is busy — nested calls from inside pool
    /// jobs cannot deadlock. `width == 0` is an error; a panicking `f` is
    /// re-raised on the calling thread with the item index attached.
    pub fn run_ordered<T, F>(&self, n_items: usize, width: usize, f: F) -> Result<Vec<T>>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        if width == 0 {
            bail!("pool fan-out needs at least one lane");
        }
        if n_items == 0 {
            return Ok(Vec::new());
        }
        let width = width.min(n_items);
        let slots: Vec<Mutex<Option<T>>> = (0..n_items).map(|_| Mutex::new(None)).collect();
        let job = |idx: usize| {
            let v = f(idx);
            let mut slot = slots[idx].lock().unwrap();
            // Guard against a scheduler bug handing an item out twice: the
            // panic is captured by the batch and re-raised on the submitter
            // instead of silently overwriting the first result.
            assert!(slot.is_none(), "item {idx} was computed twice (scheduler bug)");
            *slot = Some(v);
        };
        // SAFETY: `job` (and everything it borrows — `f`, `slots`) lives on
        // this stack frame until after `wait_done` returns below, and no
        // executor invokes the job once the last item has been handed out.
        let batch = Arc::new(unsafe { Batch::new(&job, n_items, width) });
        // A single-lane batch runs entirely on this thread — skip the
        // advertising round-trip.
        let advertised = width > 1;
        if advertised {
            let mut st = self.shared.state.lock().unwrap();
            st.batches.push(Arc::clone(&batch));
            drop(st);
            // At most `width − 1` workers can help (the submitter below is
            // the remaining lane); waking only that many keeps a narrow
            // nested batch from stampeding every parked worker each round.
            for _ in 1..width {
                self.shared.work_ready.notify_one();
            }
        }
        batch.work();
        batch.wait_done();
        if advertised {
            let mut st = self.shared.state.lock().unwrap();
            if let Some(pos) = st.batches.iter().position(|b| Arc::ptr_eq(b, &batch)) {
                st.batches.remove(pos);
            }
        }
        if let Some((idx, payload)) = batch.take_panic() {
            resume_with_context(idx, payload);
        }
        let mut out = Vec::with_capacity(n_items);
        for (idx, slot) in slots.into_iter().enumerate() {
            match slot.into_inner().expect("result slot poisoned") {
                Some(v) => out.push(v),
                None => bail!("pool reduction: item {idx} produced no result (scheduler bug)"),
            }
        }
        Ok(out)
    }
}

/// Re-raise a captured job panic on the submitting thread, prefixing the
/// failing item's index when the payload is a readable message.
fn resume_with_context(item: usize, payload: Box<dyn std::any::Any + Send>) -> ! {
    if let Some(s) = payload.downcast_ref::<&str>() {
        panic!("pool job {item} panicked: {s}");
    }
    if let Some(s) = payload.downcast_ref::<String>() {
        panic!("pool job {item} panicked: {s}");
    }
    std::panic::resume_unwind(payload)
}

/// Handle to the process-wide shared pool, created lazily with one lane
/// per available core the first time any engine fans out without an
/// explicit [`PoolHandle`] in its options. Never torn down: idle workers
/// stay parked on the condvar for the life of the process.
pub fn global_handle() -> PoolHandle {
    static GLOBAL: OnceLock<WorkerPool> = OnceLock::new();
    GLOBAL.get_or_init(|| WorkerPool::new(0)).handle()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn ordered_and_complete_for_any_width() {
        let pool = WorkerPool::new(4);
        for width in [1usize, 2, 3, 8, 64] {
            let calls = AtomicUsize::new(0);
            let out = pool
                .run_ordered(17, width, |i| {
                    calls.fetch_add(1, Ordering::SeqCst);
                    i * i
                })
                .unwrap();
            assert_eq!(out, (0..17).map(|i| i * i).collect::<Vec<_>>(), "width={width}");
            assert_eq!(calls.load(Ordering::SeqCst), 17, "width={width}");
        }
        assert!(pool.run_ordered(0, 3, |i| i).unwrap().is_empty());
        assert!(pool.run_ordered(3, 0, |i| i).is_err(), "zero lanes is an error");
    }

    #[test]
    fn width_is_clamped_to_items() {
        // A `width > n_items` request must not create idle lanes (the
        // historical scoped engine parked the excess workers on spawn):
        // the batch is built with exactly `n_items` lanes and completes.
        let pool = WorkerPool::new(2);
        let out = pool.run_ordered(2, 64, |i| i + 1).unwrap();
        assert_eq!(out, vec![1, 2]);
    }

    #[test]
    fn nested_submission_does_not_deadlock() {
        // Outer batch saturates the pool; every job then leases a nested
        // batch. The nested submitters drive their own batches, so the
        // whole thing drains even with zero free workers.
        let pool = WorkerPool::new(3);
        let handle = pool.handle();
        let out = pool
            .run_ordered(6, 3, |i| {
                let inner = handle.run_ordered(5, 2, |j| (i * 10 + j) as u64).unwrap();
                inner.iter().sum::<u64>()
            })
            .unwrap();
        let expect: Vec<u64> = (0..6)
            .map(|i| (0..5).map(|j| (i * 10 + j) as u64).sum())
            .collect();
        assert_eq!(out, expect);
    }

    #[test]
    fn panic_in_job_propagates_with_item_context() {
        let pool = WorkerPool::new(2);
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = pool.run_ordered(8, 4, |i| {
                if i == 5 {
                    panic!("scenario `c4x2-h2-skew1` diverged");
                }
                i
            });
        }));
        let payload = res.expect_err("panic must propagate to the submitter");
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("pool job 5 panicked"), "missing item context: {msg}");
        assert!(msg.contains("scenario `c4x2-h2-skew1` diverged"), "lost payload: {msg}");
    }

    #[test]
    fn panicking_batch_leaves_the_pool_reusable() {
        let pool = WorkerPool::new(3);
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = pool.run_ordered(4, 2, |i| {
                if i == 1 {
                    panic!("boom");
                }
                i
            });
        }));
        // The pool must keep scheduling normally after a job panic.
        assert_eq!(pool.run_ordered(5, 2, |i| i * 3).unwrap(), vec![0, 3, 6, 9, 12]);
    }

    #[test]
    fn repeated_batches_and_clean_drop() {
        let pool = WorkerPool::new(4);
        for round in 0..50 {
            let out = pool.run_ordered(9, 3, |i| i + round).unwrap();
            assert_eq!(out[8], 8 + round);
        }
        drop(pool); // joins workers; must not hang
    }

    #[test]
    fn handle_survives_pool_drop() {
        let pool = WorkerPool::new(3);
        let handle = pool.handle();
        drop(pool);
        // All workers are gone; the submitter lane still completes batches.
        assert_eq!(handle.run_ordered(6, 4, |i| i).unwrap(), (0..6).collect::<Vec<_>>());
    }

    #[test]
    fn global_handle_is_shared_and_usable() {
        let a = global_handle();
        let b = global_handle();
        assert_eq!(a.lanes(), b.lanes());
        assert_eq!(a.run_ordered(4, 2, |i| i).unwrap(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn lease_caps_width_and_runs_ordered() {
        let pool = WorkerPool::new(4);
        let lease = pool.handle().lease(2);
        assert_eq!(lease.width(), 2);
        assert_eq!(lease.run_ordered(5, |i| i * 2).unwrap(), vec![0, 2, 4, 6, 8]);
        // Width 0 normalizes to sequential rather than erroring: engines
        // resolve `inner_threads == 0` to "auto" before leasing, so a
        // literal 0 here means "no fan-out requested".
        assert_eq!(pool.handle().lease(0).width(), 1);
    }

    #[test]
    fn zero_lane_pool_uses_available_parallelism() {
        let pool = WorkerPool::new(0);
        assert!(pool.lanes() >= 1);
        assert_eq!(pool.run_ordered(3, pool.lanes(), |i| i).unwrap(), vec![0, 1, 2]);
    }
}
