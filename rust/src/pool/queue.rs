//! Per-lane job queues of one pool batch — the work-distribution half of
//! the `run_parallel` contract, extracted so the persistent pool schedules
//! jobs exactly like the historical per-round `std::thread::scope` fan-out
//! did.
//!
//! A batch of `n_items` jobs is split across `width` *lanes*. Lane `l` is
//! preloaded with the strided share `l, l + width, l + 2·width, …` — the
//! identical distribution the scoped engine used — and an executor attached
//! to lane `l` pops its own queue from the front, then steals from the back
//! of the nearest non-empty victim. Items are disjoint, so scheduling
//! affects wall-clock only, never results: the ordered-slot reduction
//! upstream is keyed by item index, not completion order.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// The strided per-lane deques of one batch plus the executor-slot
/// accounting (which lanes are currently manned, how many items remain).
pub(crate) struct LaneQueues {
    lanes: Vec<Mutex<VecDeque<usize>>>,
    /// Lane ids not currently claimed by an executor.
    free: Mutex<Vec<usize>>,
    /// Items not yet popped by any executor.
    unclaimed: AtomicUsize,
}

impl LaneQueues {
    /// Preload `n_items` across `width` lanes in the strided pattern.
    pub fn new(n_items: usize, width: usize) -> Self {
        assert!(width >= 1, "a batch needs at least one lane");
        let lanes = (0..width)
            .map(|l| Mutex::new((l..n_items).step_by(width).collect()))
            .collect();
        Self {
            lanes,
            // Popped back-to-front, so lane 0 goes to the first claimant
            // (the submitting thread, which attaches before advertising
            // completes in the common case).
            free: Mutex::new((0..width).rev().collect()),
            unclaimed: AtomicUsize::new(n_items),
        }
    }

    /// True while any item is still waiting to be popped.
    pub fn has_work(&self) -> bool {
        self.unclaimed.load(Ordering::Acquire) > 0
    }

    pub fn has_free_lane(&self) -> bool {
        !self.free.lock().unwrap().is_empty()
    }

    /// Claim an executor slot, or `None` when the batch is fully manned.
    pub fn claim_lane(&self) -> Option<usize> {
        self.free.lock().unwrap().pop()
    }

    pub fn release_lane(&self, lane: usize) {
        self.free.lock().unwrap().push(lane);
    }

    /// Next item for the executor on `lane`: own queue front first, then a
    /// steal from the back of the nearest non-empty victim.
    pub fn next_item(&self, lane: usize) -> Option<usize> {
        let width = self.lanes.len();
        if let Some(i) = self.lanes[lane].lock().unwrap().pop_front() {
            self.unclaimed.fetch_sub(1, Ordering::AcqRel);
            return Some(i);
        }
        for off in 1..width {
            let victim = (lane + off) % width;
            if let Some(i) = self.lanes[victim].lock().unwrap().pop_back() {
                self.unclaimed.fetch_sub(1, Ordering::AcqRel);
                return Some(i);
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeSet;

    #[test]
    fn preload_is_strided_like_the_scoped_engine() {
        let q = LaneQueues::new(10, 3);
        // Lane 0 drains 0, 3, 6, 9 from its own front before stealing.
        let mut own = Vec::new();
        for _ in 0..4 {
            own.push(q.next_item(0).unwrap());
        }
        assert_eq!(own, vec![0, 3, 6, 9]);
        // The next pop steals from a victim's back.
        assert!(q.next_item(0).is_some());
    }

    #[test]
    fn every_item_is_handed_out_exactly_once() {
        for (n, width) in [(1usize, 1usize), (7, 2), (16, 4), (5, 8)] {
            let q = LaneQueues::new(n, width);
            let mut seen = BTreeSet::new();
            let mut lane = 0usize;
            while let Some(i) = q.next_item(lane) {
                assert!(seen.insert(i), "item {i} handed out twice");
                lane = (lane + 1) % width;
            }
            assert_eq!(seen.len(), n, "n={n} width={width}");
            assert!(!q.has_work());
            for l in 0..width {
                assert!(q.next_item(l).is_none());
            }
        }
    }

    #[test]
    fn lane_claims_are_bounded_by_width() {
        let q = LaneQueues::new(4, 2);
        let a = q.claim_lane().unwrap();
        let b = q.claim_lane().unwrap();
        assert_ne!(a, b);
        assert!(q.claim_lane().is_none(), "only `width` executors may attach");
        assert!(!q.has_free_lane());
        q.release_lane(a);
        assert!(q.has_free_lane());
        assert!(q.claim_lane().is_some());
    }
}
