//! Leasing layer of the worker pool: the type-erased [`Batch`] a submitter
//! hands to the pool, and the width-capped [`Lease`] the training engines
//! hold for the duration of a run.
//!
//! A `Batch` is one ordered parallel map: `n_items` jobs, `width` lanes
//! ([`super::queue::LaneQueues`]), a lifetime-erased pointer to the
//! submitter's job closure, and the completion/panic bookkeeping. The
//! submitting thread always attaches as one executor and then blocks until
//! every item has finished — that wait is what makes the lifetime erasure
//! sound: the closure (and everything it borrows) provably outlives every
//! job invocation, exactly like the `std::thread::scope` fan-out this
//! subsystem replaces.

use super::queue::LaneQueues;
use super::PoolHandle;
use std::any::Any;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Condvar, Mutex};

type PanicPayload = Box<dyn Any + Send + 'static>;

/// Calls the concrete closure behind the erased pointer.
///
/// # Safety
/// `data` must point to a live `F` for the duration of the call.
unsafe fn trampoline<F: Fn(usize) + Sync>(data: *const (), idx: usize) {
    let f = &*(data as *const F);
    f(idx);
}

/// One submitted ordered parallel map, shared between the submitter and
/// any pool workers that attach to it.
pub(crate) struct Batch {
    queues: LaneQueues,
    n_items: usize,
    /// Lifetime-erased pointer to the submitter's `Fn(usize) + Sync`
    /// closure. Only dereferenced (through `job_call`) for the `n_items`
    /// claimed jobs, all of which complete before the submitter's
    /// [`Batch::wait_done`] returns.
    job_data: *const (),
    job_call: unsafe fn(*const (), usize),
    /// Completed-item count; guarded by a mutex (not an atomic) so
    /// [`Batch::wait_done`] can park on the condvar without lost wakeups.
    done: Mutex<usize>,
    all_done: Condvar,
    /// First panic observed in a job, with its item index.
    panic: Mutex<Option<(usize, PanicPayload)>>,
}

// SAFETY: `job_data` points to a closure that is `Sync` (shared calls from
// any thread are safe) and that the submitting thread keeps alive until
// `wait_done` returns; no job is ever invoked after the last item has been
// handed out. All other fields are `Send + Sync` by construction.
unsafe impl Send for Batch {}
unsafe impl Sync for Batch {}

impl Batch {
    /// Wrap `job` for pool execution over `n_items` items on `width` lanes.
    ///
    /// # Safety
    /// The caller must keep `job` alive and un-moved until
    /// [`Batch::wait_done`] has returned on the submitting thread.
    pub(crate) unsafe fn new<F: Fn(usize) + Sync>(job: &F, n_items: usize, width: usize) -> Self {
        Self {
            queues: LaneQueues::new(n_items, width),
            n_items,
            job_data: job as *const F as *const (),
            job_call: trampoline::<F>,
            done: Mutex::new(0),
            all_done: Condvar::new(),
            panic: Mutex::new(None),
        }
    }

    /// True when a pool worker could usefully attach: items remain and an
    /// executor slot is free.
    pub(crate) fn attachable(&self) -> bool {
        self.queues.has_work() && self.queues.has_free_lane()
    }

    /// Attach as one executor: claim a lane, drain items (own queue first,
    /// then steals), release the lane. Returns immediately when the batch
    /// is already fully manned. A panicking job is recorded (first one
    /// wins) and still counts as completed, so the batch always drains.
    pub(crate) fn work(&self) {
        let lane = match self.queues.claim_lane() {
            Some(lane) => lane,
            None => return,
        };
        while let Some(idx) = self.queues.next_item(lane) {
            let result =
                catch_unwind(AssertUnwindSafe(|| unsafe { (self.job_call)(self.job_data, idx) }));
            if let Err(payload) = result {
                let mut p = self.panic.lock().unwrap();
                if p.is_none() {
                    *p = Some((idx, payload));
                }
            }
            let mut d = self.done.lock().unwrap();
            *d += 1;
            if *d == self.n_items {
                self.all_done.notify_all();
            }
        }
        self.queues.release_lane(lane);
    }

    /// Block until every item has finished (successfully or by panicking).
    pub(crate) fn wait_done(&self) {
        let mut d = self.done.lock().unwrap();
        while *d < self.n_items {
            d = self.all_done.wait(d).unwrap();
        }
    }

    /// First job panic, if any — taken by the submitter after completion.
    pub(crate) fn take_panic(&self) -> Option<(usize, PanicPayload)> {
        self.panic.lock().unwrap().take()
    }
}

/// A width-capped lease on a pool. Engines resolve their fan-out width
/// once (`TrainOptions::inner_threads` → [`PoolHandle::lease`]) and push
/// one batch per round through the lease; the pool threads persist across
/// rounds, so the per-round cost is a queue push + condvar wake instead of
/// `width` thread spawns.
#[derive(Clone, Debug)]
pub struct Lease {
    handle: PoolHandle,
    width: usize,
}

impl Lease {
    pub(crate) fn new(handle: PoolHandle, width: usize) -> Self {
        Self {
            handle,
            width: width.max(1),
        }
    }

    /// Leased fan-out width: the maximum number of concurrent executors
    /// (including the submitting thread) a batch on this lease may use.
    pub fn width(&self) -> usize {
        self.width
    }

    /// Ordered parallel map over `0..n_items` at the leased width — the
    /// per-round entry point of the training engines.
    pub fn run_ordered<T, F>(&self, n_items: usize, f: F) -> anyhow::Result<Vec<T>>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        self.handle.run_ordered(n_items, self.width, f)
    }
}
