//! Deterministic adversarial-client plans: Byzantine update corruption
//! ([`AdversaryPlan`]) and client churn / energy-budgeted participation
//! ([`ChurnConfig`]).
//!
//! Both mirror the design of [`crate::net::chaos::ChaosConfig`]: every
//! decision is drawn from a [`Pcg64`] stream keyed on `(seed, mu_id,
//! round)` — never wall-clock, never arrival order — so a plan is
//! bit-reproducible at any thread count and across engines. The draws are
//! *stateless*: no RNG cursor survives between rounds, so checkpoints
//! carry only the seed (plus the stale-replay buffers, which are real
//! per-MU state).
//!
//! ## Attack taxonomy
//!
//! An attacker MU (a fixed per-seed subset of the population, chosen by a
//! per-MU coin at [`AdversaryPlan::fraction`]) corrupts its **post-DGC
//! sparse update at the uplink boundary** — after sparsification and
//! error-feedback accounting, before wire pricing and transmission — so
//! the honest-side DGC state evolves exactly as in an honest run and the
//! transmitted message is priced as sent:
//!
//! * **sign flip** — negates every value (support unchanged);
//! * **scaled amplification** — multiplies every value by
//!   [`AdversaryPlan::scale`] (support unchanged);
//! * **Gaussian garbage** — replaces every value with a keyed
//!   `N(0, garbage_std²)` draw (support unchanged);
//! * **stale replay** — re-sends the MU's *previous round's honest*
//!   post-DGC update (support may differ; the wire price follows the
//!   replayed message). The first attacking round has nothing to replay
//!   and falls back to a sign flip.
//!
//! The behavior is re-drawn per `(mu, round)`, uniformly over the four.
//!
//! ## Churn and energy
//!
//! [`ChurnConfig`] gates DES round participation: an alive MU departs
//! with probability `drop_p` per round (out of coverage — the mobility
//! outage analogue), a departed MU rejoins with probability `rejoin_p`,
//! and a finite `energy` budget retires an MU permanently after that many
//! participated rounds. Skipped `(mu, round)` pairs feed the golden
//! trace's skip digest; survivor reweighting falls out of the engines'
//! participant-count denominators.

use crate::util::rng::Pcg64;
use anyhow::{bail, Result};

/// Odd SplitMix64-style multiplier used to fold the round index into a
/// stream key without colliding adjacent `(mu, round)` pairs.
const ROUND_MIX: u64 = 0x9e37_79b9_7f4a_7c15;

const TAG_ATTACKER: u64 = 0xadf1_0000_0000_0001;
const TAG_BEHAVIOR: u64 = 0xadf1_0000_0000_0002;
const TAG_GARBAGE: u64 = 0xadf1_0000_0000_0003;
const TAG_DROP: u64 = 0xc4c1_0000_0000_0001;
const TAG_REJOIN: u64 = 0xc4c1_0000_0000_0002;

/// What an attacker does to its update in one round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AttackBehavior {
    SignFlip,
    ScaledAmplification,
    GaussianGarbage,
    StaleReplay,
}

/// Seeded Byzantine fault-injection plan (`[adversary]` / `--adversary-*`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AdversaryPlan {
    pub enabled: bool,
    /// Root seed of every keyed decision stream.
    pub seed: u64,
    /// Fraction of the MU population flipped to attackers, in `[0, 1]`.
    pub fraction: f64,
    /// Multiplier of the scaled-amplification behavior.
    pub scale: f32,
    /// Standard deviation of the Gaussian-garbage behavior.
    pub garbage_std: f32,
}

impl Default for AdversaryPlan {
    fn default() -> Self {
        Self {
            enabled: false,
            seed: 2027,
            fraction: 0.2,
            scale: 10.0,
            garbage_std: 1.0,
        }
    }
}

impl AdversaryPlan {
    pub fn validate(&self) -> Result<()> {
        if !self.fraction.is_finite() || !(0.0..=1.0).contains(&self.fraction) {
            bail!("adversary fraction must be in [0, 1], got {}", self.fraction);
        }
        if !self.scale.is_finite() || self.scale == 0.0 {
            bail!("adversary scale must be finite and non-zero, got {}", self.scale);
        }
        if !self.garbage_std.is_finite() || self.garbage_std < 0.0 {
            bail!("adversary garbage std must be finite and >= 0, got {}", self.garbage_std);
        }
        Ok(())
    }

    /// Is this MU an attacker under the plan? Fixed per `(seed, mu)` —
    /// attackers don't change identity between rounds.
    pub fn is_attacker(&self, mu: u64) -> bool {
        self.enabled
            && self.fraction > 0.0
            && Pcg64::new(self.seed ^ TAG_ATTACKER, mu).uniform() < self.fraction
    }

    /// The behavior an attacker exhibits this round, re-drawn per
    /// `(seed, mu, round)`.
    pub fn behavior(&self, mu: u64, round: u64) -> AttackBehavior {
        let mut rng =
            Pcg64::new(self.seed ^ TAG_BEHAVIOR, mu ^ round.wrapping_mul(ROUND_MIX));
        match rng.uniform_u64(4) {
            0 => AttackBehavior::SignFlip,
            1 => AttackBehavior::ScaledAmplification,
            2 => AttackBehavior::GaussianGarbage,
            _ => AttackBehavior::StaleReplay,
        }
    }

    /// Corrupt one post-DGC sparse update in place, if `mu` attacks this
    /// round. `stale` is the caller-owned replay slot for this MU (always
    /// updated to this round's *honest* message for attackers, so a later
    /// stale replay re-sends a genuine past update). Returns `true` when
    /// the update was mutated.
    pub fn corrupt(
        &self,
        mu: u64,
        round: u64,
        indices: &mut Vec<u32>,
        values: &mut Vec<f32>,
        stale: &mut Option<(Vec<u32>, Vec<f32>)>,
    ) -> bool {
        if !self.is_attacker(mu) {
            return false;
        }
        let behavior = self.behavior(mu, round);
        let prev = match behavior {
            AttackBehavior::StaleReplay => stale.take(),
            _ => None,
        };
        *stale = Some((indices.clone(), values.clone()));
        match behavior {
            AttackBehavior::SignFlip => {
                for v in values.iter_mut() {
                    *v = -*v;
                }
            }
            AttackBehavior::ScaledAmplification => {
                for v in values.iter_mut() {
                    *v *= self.scale;
                }
            }
            AttackBehavior::GaussianGarbage => {
                let mut rng =
                    Pcg64::new(self.seed ^ TAG_GARBAGE, mu ^ round.wrapping_mul(ROUND_MIX));
                for v in values.iter_mut() {
                    *v = rng.normal() as f32 * self.garbage_std;
                }
            }
            AttackBehavior::StaleReplay => {
                if let Some((si, sv)) = prev {
                    *indices = si;
                    *values = sv;
                } else {
                    // Nothing sent yet — first attacking round flips signs.
                    for v in values.iter_mut() {
                        *v = -*v;
                    }
                }
            }
        }
        true
    }
}

/// Seeded client-churn and energy-budget plan for the DES engine
/// (`--churn-*` / `[churn]`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ChurnConfig {
    pub enabled: bool,
    /// Root seed of the drop/rejoin decision streams.
    pub seed: u64,
    /// Per-round probability an alive MU departs before the round starts.
    pub drop_p: f64,
    /// Per-round probability a departed MU rejoins.
    pub rejoin_p: f64,
    /// Participation budget in rounds (energy model: one unit per
    /// participated round); `0` = unlimited.
    pub energy: f64,
}

impl Default for ChurnConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            seed: 2029,
            drop_p: 0.1,
            rejoin_p: 0.5,
            energy: 0.0,
        }
    }
}

impl ChurnConfig {
    pub fn validate(&self) -> Result<()> {
        if !self.drop_p.is_finite() || !(0.0..=1.0).contains(&self.drop_p) {
            bail!("churn drop probability must be in [0, 1], got {}", self.drop_p);
        }
        if !self.rejoin_p.is_finite() || !(0.0..=1.0).contains(&self.rejoin_p) {
            bail!("churn rejoin probability must be in [0, 1], got {}", self.rejoin_p);
        }
        if !self.energy.is_finite() || self.energy < 0.0 {
            bail!("churn energy budget must be finite and >= 0, got {}", self.energy);
        }
        Ok(())
    }

    /// Does this alive MU depart before `round` starts?
    pub fn drops(&self, mu: u64, round: u64) -> bool {
        self.enabled
            && self.drop_p > 0.0
            && Pcg64::new(self.seed ^ TAG_DROP, mu ^ round.wrapping_mul(ROUND_MIX)).uniform()
                < self.drop_p
    }

    /// Does this departed MU rejoin before `round` starts?
    pub fn rejoins(&self, mu: u64, round: u64) -> bool {
        self.enabled
            && self.rejoin_p > 0.0
            && Pcg64::new(self.seed ^ TAG_REJOIN, mu ^ round.wrapping_mul(ROUND_MIX)).uniform()
                < self.rejoin_p
    }

    /// Has a finite energy budget been exhausted after `spent` rounds of
    /// participation?
    pub fn exhausted(&self, spent: f64) -> bool {
        self.enabled && self.energy > 0.0 && spent >= self.energy
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plans_are_deterministic_and_seed_sensitive() {
        let plan = AdversaryPlan { enabled: true, fraction: 0.3, ..Default::default() };
        let other = AdversaryPlan { seed: 999, ..plan };
        let attackers: Vec<bool> = (0..200).map(|m| plan.is_attacker(m)).collect();
        assert_eq!(attackers, (0..200).map(|m| plan.is_attacker(m)).collect::<Vec<_>>());
        assert_ne!(attackers, (0..200).map(|m| other.is_attacker(m)).collect::<Vec<_>>());
        // ~30% of 200 MUs — loose bounds, deterministic draw.
        let n = attackers.iter().filter(|&&a| a).count();
        assert!((30..90).contains(&n), "{n} attackers");
        // Behaviors re-draw per round but are stable for a given key.
        let m = (0..200u64).find(|&m| plan.is_attacker(m)).unwrap();
        assert_eq!(plan.behavior(m, 3), plan.behavior(m, 3));
        let varied: std::collections::BTreeSet<_> =
            (0..40).map(|r| format!("{:?}", plan.behavior(m, r))).collect();
        assert!(varied.len() >= 3, "behaviors should vary across rounds: {varied:?}");
    }

    #[test]
    fn disabled_plan_never_touches_an_update() {
        let plan = AdversaryPlan::default();
        assert!(!plan.enabled);
        let mut idx = vec![1u32, 5];
        let mut vals = vec![0.5f32, -0.25];
        let mut stale = None;
        assert!(!plan.corrupt(0, 0, &mut idx, &mut vals, &mut stale));
        assert_eq!(vals, vec![0.5, -0.25]);
        assert!(stale.is_none());
    }

    #[test]
    fn corrupt_behaviors_mutate_as_documented() {
        let plan = AdversaryPlan { enabled: true, fraction: 1.0, ..Default::default() };
        let mu = 7u64;
        assert!(plan.is_attacker(mu));
        // Find one round per behavior.
        let find = |want: AttackBehavior| (0..1000u64).find(|&r| plan.behavior(mu, r) == want);
        let (rf, rs, rg, rr) = (
            find(AttackBehavior::SignFlip).unwrap(),
            find(AttackBehavior::ScaledAmplification).unwrap(),
            find(AttackBehavior::GaussianGarbage).unwrap(),
            find(AttackBehavior::StaleReplay).unwrap(),
        );
        let idx0 = vec![2u32, 9];
        let vals0 = vec![1.5f32, -2.0];

        let (mut idx, mut vals, mut stale) = (idx0.clone(), vals0.clone(), None);
        assert!(plan.corrupt(mu, rf, &mut idx, &mut vals, &mut stale));
        assert_eq!(vals, vec![-1.5, 2.0]);
        assert_eq!(idx, idx0);
        assert_eq!(stale, Some((idx0.clone(), vals0.clone())));

        let (mut idx, mut vals, mut stale) = (idx0.clone(), vals0.clone(), None);
        plan.corrupt(mu, rs, &mut idx, &mut vals, &mut stale);
        assert_eq!(vals, vec![15.0, -20.0]);

        let (mut idx, mut vals, mut stale) = (idx0.clone(), vals0.clone(), None);
        plan.corrupt(mu, rg, &mut idx, &mut vals, &mut stale);
        assert_ne!(vals, vals0);
        let again = {
            let (mut i2, mut v2, mut s2) = (idx0.clone(), vals0.clone(), None);
            plan.corrupt(mu, rg, &mut i2, &mut v2, &mut s2);
            v2
        };
        assert_eq!(vals, again, "garbage draws are keyed, not stateful");

        // Stale replay with no history falls back to a sign flip…
        let (mut idx, mut vals, mut stale) = (idx0.clone(), vals0.clone(), None);
        plan.corrupt(mu, rr, &mut idx, &mut vals, &mut stale);
        assert_eq!(vals, vec![-1.5, 2.0]);
        // …and with history re-sends the stored *honest* message.
        let mut stale = Some((vec![4u32], vec![0.125f32]));
        let (mut idx, mut vals) = (idx0.clone(), vals0.clone());
        plan.corrupt(mu, rr, &mut idx, &mut vals, &mut stale);
        assert_eq!(idx, vec![4]);
        assert_eq!(vals, vec![0.125]);
        assert_eq!(stale, Some((idx0.clone(), vals0.clone())));
    }

    #[test]
    fn plan_validation_names_bad_fields() {
        AdversaryPlan::default().validate().unwrap();
        let bad = AdversaryPlan { fraction: 1.5, ..Default::default() };
        assert!(bad.validate().unwrap_err().to_string().contains("fraction"));
        let bad = AdversaryPlan { fraction: -0.1, ..Default::default() };
        assert!(bad.validate().is_err());
        let bad = AdversaryPlan { scale: 0.0, ..Default::default() };
        assert!(bad.validate().unwrap_err().to_string().contains("scale"));
        let bad = AdversaryPlan { garbage_std: f32::NAN, ..Default::default() };
        assert!(bad.validate().is_err());

        ChurnConfig::default().validate().unwrap();
        let bad = ChurnConfig { drop_p: 2.0, ..Default::default() };
        assert!(bad.validate().unwrap_err().to_string().contains("drop"));
        let bad = ChurnConfig { rejoin_p: -1.0, ..Default::default() };
        assert!(bad.validate().unwrap_err().to_string().contains("rejoin"));
        let bad = ChurnConfig { energy: f64::INFINITY, ..Default::default() };
        assert!(bad.validate().unwrap_err().to_string().contains("energy"));
    }

    #[test]
    fn churn_draws_are_keyed_and_gated() {
        let off = ChurnConfig::default();
        assert!(!off.drops(3, 5) && !off.rejoins(3, 5));
        let churn = ChurnConfig { enabled: true, drop_p: 0.5, rejoin_p: 0.5, ..Default::default() };
        let drops: Vec<bool> = (0..100).map(|r| churn.drops(11, r)).collect();
        assert_eq!(drops, (0..100).map(|r| churn.drops(11, r)).collect::<Vec<_>>());
        assert!(drops.iter().any(|&d| d) && !drops.iter().all(|&d| d));
        // Different MU, different stream.
        assert_ne!(drops, (0..100).map(|r| churn.drops(12, r)).collect::<Vec<_>>());
        // Energy gate.
        assert!(!churn.exhausted(1e9)); // energy 0 = unlimited
        let budget = ChurnConfig { energy: 3.0, ..churn };
        assert!(!budget.exhausted(2.0));
        assert!(budget.exhausted(3.0));
    }
}
