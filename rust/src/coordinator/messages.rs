//! In-process messages between an SBS cell and its MU actors. Payloads are
//! the sparse index+value vectors that the real system would transmit;
//! dense state never crosses a link (except the one-time initial model,
//! which in a real deployment ships with the firmware).
//!
//! The SBS↔MBS tier speaks [`crate::net::wire::WireMsg`] over a
//! [`crate::net::transport::Transport`] instead — those messages are
//! framed and byte-serialized because they may cross process boundaries;
//! MU↔SBS messages stay plain structs on `mpsc` channels because a cell's
//! MUs always share its process.

use crate::sparse::SparseVec;

/// MU → SBS: one iteration's sparsified gradient contribution.
#[derive(Debug)]
pub struct MuToSbs {
    /// Cluster-local worker slot (0..per_cluster) — fixes aggregation order
    /// so results are bit-identical to the sequential engine.
    pub slot: usize,
    /// Global worker id (diagnostics).
    pub worker: usize,
    /// Minibatch loss (metrics only; not transmitted in the real system).
    pub loss: f64,
    /// DGC-compressed gradient ĝ.
    pub grad: SparseVec,
}

/// SBS → MU: sparsified model delta to apply to the local replica.
#[derive(Debug)]
pub enum SbsToMu {
    /// Apply `delta` to the local model replica.
    Update { iter: usize, delta: SparseVec },
    /// Training finished; terminate.
    Stop,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn messages_are_send() {
        fn assert_send<T: Send>() {}
        assert_send::<MuToSbs>();
        assert_send::<SbsToMu>();
    }
}
