//! Wire messages between the coordinator's actors. Payloads are the sparse
//! index+value vectors that the real system would transmit; dense state
//! never crosses a link (except the one-time initial model, which in a real
//! deployment ships with the firmware).

use crate::sparse::SparseVec;

/// MU → SBS: one iteration's sparsified gradient contribution.
#[derive(Debug)]
pub struct MuToSbs {
    /// Cluster-local worker slot (0..per_cluster) — fixes aggregation order
    /// so results are bit-identical to the sequential engine.
    pub slot: usize,
    /// Global worker id (diagnostics).
    pub worker: usize,
    /// Minibatch loss (metrics only; not transmitted in the real system).
    pub loss: f64,
    /// DGC-compressed gradient ĝ.
    pub grad: SparseVec,
}

/// SBS → MU: sparsified model delta to apply to the local replica.
#[derive(Debug)]
pub enum SbsToMu {
    /// Apply `delta` to the local model replica.
    Update { iter: usize, delta: SparseVec },
    /// Training finished; terminate.
    Stop,
}

/// SBS inbox: gradient uploads from its MUs plus control from the MBS.
#[derive(Debug)]
pub enum SbsControl {
    /// A gradient message from a cluster MU.
    FromMu(MuToSbs),
    /// Global model delta from the MBS (sync step).
    GlobalDelta(SparseVec),
    /// Terminate (propagates Stop to the MUs).
    Stop,
}

/// SBS → MBS: the cluster's sparsified model difference at a sync point.
#[derive(Debug)]
pub struct MbsToSbs {
    pub cluster: usize,
    pub delta: SparseVec,
    /// Mean training loss over the cluster for the elapsed period.
    pub mean_loss: f64,
}

/// SBS → MBS inbox: either a sync contribution or completion notice.
#[derive(Debug)]
pub enum SbsToMbs {
    Sync(MbsToSbs),
    /// The cluster finished all its iterations.
    Done { cluster: usize },
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn messages_are_send() {
        fn assert_send<T: Send>() {}
        assert_send::<MuToSbs>();
        assert_send::<SbsToMu>();
        assert_send::<SbsControl>();
        assert_send::<MbsToSbs>();
    }
}
