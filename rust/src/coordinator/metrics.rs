//! Per-link communication metrics emitted by the actors. The latency model
//! (`sim::experiments`) converts these into simulated network time using
//! the wireless substrate; the actors themselves are wall-clock agnostic.

use std::sync::mpsc::Sender;

/// Which of the four sparsified links a message traversed.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LinkKind {
    MuUl,
    SbsDl,
    SbsUl,
    MbsDl,
}

/// One transmitted message.
///
/// `PartialEq` follows IEEE semantics on the f64 fields (NaN ≠ NaN) — the
/// wire tests compare events by bit pattern where NaN losses matter.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MetricEvent {
    pub iter: usize,
    pub cluster: usize,
    pub link: LinkKind,
    pub bits: f64,
    /// Training loss piggybacked on MU uploads (NaN otherwise).
    pub loss: f64,
}

/// Cheap cloneable emitter.
#[derive(Clone)]
pub struct MetricsSink {
    tx: Sender<MetricEvent>,
}

impl MetricsSink {
    pub fn new(tx: Sender<MetricEvent>) -> Self {
        Self { tx }
    }

    pub fn emit(&self, ev: MetricEvent) {
        let _ = self.tx.send(ev); // receiver gone during shutdown is fine
    }
}

/// Aggregated view built by the MBS from the event stream.
#[derive(Clone, Debug, Default)]
pub struct MetricsLog {
    pub events: Vec<MetricEvent>,
}

impl MetricsLog {
    pub fn push(&mut self, ev: MetricEvent) {
        self.events.push(ev);
    }

    /// Total bits over a link.
    pub fn total_bits(&self, link: LinkKind) -> f64 {
        self.events
            .iter()
            .filter(|e| e.link == link)
            .map(|e| e.bits)
            .sum()
    }

    /// Fold the event stream into the engine's per-link accounting schema
    /// ([`crate::fl::CommBits`]) — the shared currency of
    /// [`crate::sim::result::ScenarioResult`], letting the sequential
    /// engine, the coordinator and the matrix runner be compared (and
    /// golden-traced) field by field.
    pub fn comm_bits(&self) -> crate::fl::CommBits {
        let mut bits = crate::fl::CommBits::default();
        for e in &self.events {
            match e.link {
                LinkKind::MuUl => {
                    bits.mu_ul += e.bits;
                    bits.n_mu_msgs += 1;
                }
                LinkKind::SbsDl => bits.sbs_dl += e.bits,
                LinkKind::SbsUl => bits.sbs_ul += e.bits,
                LinkKind::MbsDl => bits.mbs_dl += e.bits,
            }
        }
        bits
    }

    /// Per-iteration worst-MU uplink payload within each cluster — the
    /// quantity entering `Γ_n^U = max_k bits_k / rate_k` (uniform rates
    /// within a cluster make max-bits the max-latency proxy).
    pub fn per_iter_max_mu_bits(&self, iter: usize, cluster: usize) -> f64 {
        self.events
            .iter()
            .filter(|e| e.link == LinkKind::MuUl && e.iter == iter && e.cluster == cluster)
            .map(|e| e.bits)
            .fold(0.0, f64::max)
    }

    /// Mean training loss at an iteration (from MU uploads).
    pub fn mean_loss(&self, iter: usize) -> Option<f64> {
        let losses: Vec<f64> = self
            .events
            .iter()
            .filter(|e| e.link == LinkKind::MuUl && e.iter == iter && e.loss.is_finite())
            .map(|e| e.loss)
            .collect();
        if losses.is_empty() {
            None
        } else {
            Some(losses.iter().sum::<f64>() / losses.len() as f64)
        }
    }

    pub fn n_iters(&self) -> usize {
        self.events.iter().map(|e| e.iter + 1).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn sink_and_log_roundtrip() {
        let (tx, rx) = channel();
        let sink = MetricsSink::new(tx);
        sink.emit(MetricEvent {
            iter: 0,
            cluster: 1,
            link: LinkKind::MuUl,
            bits: 100.0,
            loss: 2.0,
        });
        sink.emit(MetricEvent {
            iter: 0,
            cluster: 1,
            link: LinkKind::MuUl,
            bits: 250.0,
            loss: 4.0,
        });
        sink.emit(MetricEvent {
            iter: 0,
            cluster: 1,
            link: LinkKind::SbsDl,
            bits: 70.0,
            loss: f64::NAN,
        });
        drop(sink);
        let mut log = MetricsLog::default();
        while let Ok(ev) = rx.recv() {
            log.push(ev);
        }
        assert_eq!(log.total_bits(LinkKind::MuUl), 350.0);
        assert_eq!(log.total_bits(LinkKind::SbsDl), 70.0);
        assert_eq!(log.per_iter_max_mu_bits(0, 1), 250.0);
        assert_eq!(log.mean_loss(0), Some(3.0));
        assert_eq!(log.n_iters(), 1);
        let bits = log.comm_bits();
        assert_eq!(bits.mu_ul, 350.0);
        assert_eq!(bits.sbs_dl, 70.0);
        assert_eq!(bits.sbs_ul, 0.0);
        assert_eq!(bits.mbs_dl, 0.0);
        assert_eq!(bits.n_mu_msgs, 2);
        assert_eq!(bits.total(), 420.0);
    }
}
