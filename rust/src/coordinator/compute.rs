//! The compute service: a single thread that owns the gradient oracle.
//!
//! PJRT handles in the `xla` crate wrap `Rc` internals and are `!Send`, so
//! the AOT executables must live and die on one thread. Every MU's gradient
//! request is serialized through this service — which matches the testbed
//! anyway (one CPU), and in a real deployment each MU owns its own device.

use crate::fl::oracle::{EvalMetrics, GradOracle};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

enum Request {
    Grad {
        worker: usize,
        params: Arc<Vec<f32>>,
        reply: Sender<(f64, Vec<f32>)>,
    },
    Eval {
        params: Arc<Vec<f32>>,
        reply: Sender<EvalMetrics>,
    },
    Meta {
        reply: Sender<(usize, usize, Vec<f32>, usize)>,
    },
    Stop,
}

/// Cloneable handle to the compute thread.
#[derive(Clone)]
pub struct ComputeHandle {
    tx: Sender<Request>,
}

impl ComputeHandle {
    /// Blocking gradient request for `worker` at `params`.
    pub fn grad(&self, worker: usize, params: Arc<Vec<f32>>) -> (f64, Vec<f32>) {
        let (reply, rx) = channel();
        self.tx
            .send(Request::Grad {
                worker,
                params,
                reply,
            })
            .expect("compute service gone");
        rx.recv().expect("compute service dropped reply")
    }

    /// Blocking evaluation request.
    pub fn eval(&self, params: Arc<Vec<f32>>) -> EvalMetrics {
        let (reply, rx) = channel();
        self.tx
            .send(Request::Eval { params, reply })
            .expect("compute service gone");
        rx.recv().expect("compute service dropped reply")
    }

    /// (dim, n_workers, init_params, iters_per_epoch).
    pub fn meta(&self) -> (usize, usize, Vec<f32>, usize) {
        let (reply, rx) = channel();
        self.tx
            .send(Request::Meta { reply })
            .expect("compute service gone");
        rx.recv().expect("compute service dropped reply")
    }

    pub fn stop(&self) {
        let _ = self.tx.send(Request::Stop);
    }
}

/// The owning service; join on drop-with-stop.
pub struct ComputeService {
    handle: ComputeHandle,
    join: Option<JoinHandle<()>>,
}

impl ComputeService {
    /// Spawn the service. `factory` runs **inside** the new thread so the
    /// oracle (and its !Send PJRT handles) is constructed where it lives.
    pub fn spawn<F, O>(factory: F) -> Self
    where
        F: FnOnce() -> O + Send + 'static,
        O: GradOracle + 'static,
    {
        let (tx, rx): (Sender<Request>, Receiver<Request>) = channel();
        let join = std::thread::Builder::new()
            .name("hfl-compute".into())
            .spawn(move || {
                let mut oracle = factory();
                let mut grad_buf = vec![0.0f32; oracle.dim()];
                while let Ok(req) = rx.recv() {
                    match req {
                        Request::Grad {
                            worker,
                            params,
                            reply,
                        } => {
                            let loss = oracle.loss_grad(worker, &params, &mut grad_buf);
                            let _ = reply.send((loss, grad_buf.clone()));
                        }
                        Request::Eval { params, reply } => {
                            let _ = reply.send(oracle.eval(&params));
                        }
                        Request::Meta { reply } => {
                            let dim = oracle.dim();
                            let n = oracle.n_workers();
                            let init = oracle.init_params();
                            let ipe = oracle.iters_per_epoch();
                            let _ = reply.send((dim, n, init, ipe));
                        }
                        Request::Stop => break,
                    }
                }
            })
            .expect("spawn compute thread");
        Self {
            handle: ComputeHandle { tx },
            join: Some(join),
        }
    }

    pub fn handle(&self) -> ComputeHandle {
        self.handle.clone()
    }

    /// Stop and join.
    pub fn shutdown(mut self) {
        self.handle.stop();
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for ComputeService {
    fn drop(&mut self) {
        self.handle.stop();
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::oracle::QuadraticOracle;
    use anyhow::{anyhow, Result};

    #[test]
    fn serves_grad_eval_meta() {
        let svc = ComputeService::spawn(|| QuadraticOracle::new(6, 3, 0.0, 1));
        let h = svc.handle();
        let (dim, n, init, ipe) = h.meta();
        assert_eq!((dim, n, ipe), (6, 3, 10));
        assert_eq!(init.len(), 6);
        let params = Arc::new(init);
        let (loss, grad) = h.grad(0, params.clone());
        assert!(loss >= 0.0);
        assert_eq!(grad.len(), 6);
        let m = h.eval(params);
        assert!(m.loss.is_finite());
        svc.shutdown();
    }

    #[test]
    fn concurrent_requests_from_many_threads() -> Result<()> {
        let svc = ComputeService::spawn(|| QuadraticOracle::new(4, 8, 0.0, 2));
        let h = svc.handle();
        let params = Arc::new(vec![0.5f32; 4]);
        let threads: Vec<_> = (0..8)
            .map(|w| {
                let h = h.clone();
                let p = params.clone();
                std::thread::spawn(move || h.grad(w, p))
            })
            .collect();
        for (worker, t) in threads.into_iter().enumerate() {
            // Named error instead of re-raising the opaque panic payload —
            // same join discipline as the coordinator's actor threads.
            let (loss, grad) = t
                .join()
                .map_err(|_| anyhow!("grad requester thread panicked (worker {worker})"))?;
            assert!(loss.is_finite());
            assert_eq!(grad.len(), 4);
        }
        svc.shutdown();
        Ok(())
    }

    #[test]
    fn drop_without_shutdown_joins_cleanly() {
        let svc = ComputeService::spawn(|| QuadraticOracle::new(2, 1, 0.0, 3));
        drop(svc);
    }
}
