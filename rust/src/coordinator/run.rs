//! Actor wiring and the coordinated training run.
//!
//! The coordinator executes the same arithmetic as the sequential reference
//! engine ([`crate::fl::run_hierarchical`]) — same compressors, same
//! aggregation order (slot-indexed), same LR schedule — so given a
//! deterministic oracle the two produce **bit-identical** final parameters
//! (asserted in `rust/tests/coordinator_equivalence.rs`). What the actor
//! version adds is the real topology: per-MU replicas and DGC state, per-SBS
//! encoders, channel-synchronized rounds, H-period global sync through the
//! MBS, metrics, and clean shutdown.
//!
//! Synchronization protocol (no explicit barriers; channels carry it):
//!
//! 1. every MU computes a gradient at its replica and uploads it;
//! 2. the SBS aggregates all `per_cluster` slots, steps its reference model
//!    and broadcasts one model delta (two at sync iterations — the second
//!    carries the pull toward the freshly averaged global model);
//! 3. MUs apply exactly the expected number of deltas (they know H), then
//!    start the next round.

use super::compute::{ComputeHandle, ComputeService};
use super::messages::{MbsToSbs, MuToSbs, SbsControl, SbsToMbs, SbsToMu};
use super::metrics::{LinkKind, MetricEvent, MetricsLog, MetricsSink};
use crate::config::SparsityConfig;
use crate::fl::lr_schedule::LrSchedule;
use crate::fl::oracle::{EvalMetrics, GradOracle};
use crate::sparse::merge::{self, AggPolicy, DenseShadow, MergeScratch};
use crate::sparse::{DgcCompressor, DiscountedError, SparseVec};
use anyhow::{anyhow, Result};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

/// Options for a coordinated run (mirrors [`crate::fl::TrainOptions`]).
#[derive(Clone, Debug)]
pub struct CoordinatorOptions {
    pub iters: usize,
    pub peak_lr: f64,
    pub warmup_iters: usize,
    pub milestones: (f64, f64),
    pub momentum: f32,
    pub weight_decay: f32,
    pub h_period: usize,
    pub n_clusters: usize,
    pub sparsity: SparsityConfig,
    /// Evaluate on the MBS's global model every this many sync points
    /// (0 → final only).
    pub eval_every_syncs: usize,
    /// Aggregation dispatch at the SBS/MBS slots (mirrors
    /// [`crate::fl::TrainOptions::agg`]; bit-identical either way).
    pub agg: AggPolicy,
}

impl Default for CoordinatorOptions {
    fn default() -> Self {
        Self {
            iters: 100,
            peak_lr: 0.1,
            warmup_iters: 0,
            milestones: (0.5, 0.75),
            momentum: 0.9,
            weight_decay: 0.0,
            h_period: 2,
            n_clusters: 1,
            sparsity: SparsityConfig::dense(),
            eval_every_syncs: 0,
            agg: AggPolicy::default(),
        }
    }
}

impl From<&crate::fl::TrainOptions> for CoordinatorOptions {
    fn from(o: &crate::fl::TrainOptions) -> Self {
        Self {
            iters: o.iters,
            peak_lr: o.peak_lr,
            warmup_iters: o.warmup_iters,
            milestones: o.milestones,
            momentum: o.momentum,
            weight_decay: o.weight_decay,
            h_period: o.h_period,
            n_clusters: o.n_clusters,
            sparsity: o.sparsity.clone(),
            eval_every_syncs: 0,
            agg: o.agg,
        }
    }
}

/// Result of a coordinated run.
#[derive(Clone, Debug)]
pub struct CoordinatorRun {
    /// Consensus (cluster-averaged) final parameters.
    pub final_params: Vec<f32>,
    /// Final held-out metrics.
    pub final_eval: EvalMetrics,
    /// (sync iteration, metrics) evaluated on the MBS global model.
    pub sync_evals: Vec<(usize, EvalMetrics)>,
    /// Per-message communication log.
    pub metrics: MetricsLog,
    /// (iteration, mean training loss).
    pub train_loss: Vec<(usize, f64)>,
}

/// Run hierarchical FL on the actor topology. `factory` constructs the
/// gradient oracle inside the compute thread (PJRT handles are !Send).
pub fn run_coordinated<F, O>(factory: F, opts: &CoordinatorOptions) -> Result<CoordinatorRun>
where
    F: FnOnce() -> O + Send + 'static,
    O: GradOracle + 'static,
{
    let svc = ComputeService::spawn(factory);
    let compute = svc.handle();
    let (dim, k_total, init, _ipe) = compute.meta();
    let n = opts.n_clusters;
    if n == 0 || k_total % n != 0 {
        return Err(anyhow!(
            "workers ({k_total}) must divide evenly into clusters ({n})"
        ));
    }
    let per_cluster = k_total / n;

    let (phi_ul, phi_sdl, phi_sul, phi_mdl) = if opts.sparsity.enabled {
        (
            opts.sparsity.phi_mu_ul,
            opts.sparsity.phi_sbs_dl,
            opts.sparsity.phi_sbs_ul,
            opts.sparsity.phi_mbs_dl,
        )
    } else {
        (0.0, 0.0, 0.0, 0.0)
    };
    let (dl_phi, dl_beta) = if n == 1 {
        (phi_mdl, opts.sparsity.beta_m as f32)
    } else {
        (phi_sdl, opts.sparsity.beta_s as f32)
    };

    let (metric_tx, metric_rx) = channel::<MetricEvent>();
    let init = Arc::new(init);

    // --- Spawn SBS actors, each spawning its MU actors -------------------
    let mut sbs_txs: Vec<Sender<SbsControl>> = Vec::with_capacity(n);
    let (mbs_tx, mbs_rx) = channel::<SbsToMbs>();
    let mut sbs_joins = Vec::with_capacity(n);
    let mbs_metrics = MetricsSink::new(metric_tx.clone());
    for c in 0..n {
        let (sbs_tx, sbs_rx) = channel::<SbsControl>();
        sbs_txs.push(sbs_tx.clone());
        let ctx = SbsContext {
            cluster: c,
            per_cluster,
            dim,
            iters: opts.iters,
            h_period: opts.h_period,
            n_clusters: n,
            schedule: LrSchedule::new(
                opts.peak_lr,
                opts.warmup_iters,
                opts.iters,
                opts.milestones,
            ),
            dl_phi,
            dl_beta,
            ul_phi: phi_sul,
            ul_beta: opts.sparsity.beta_s as f32,
            momentum: opts.momentum,
            weight_decay: opts.weight_decay,
            phi_ul,
            agg: opts.agg,
            init: init.clone(),
            compute: compute.clone(),
            metrics: MetricsSink::new(metric_tx.clone()),
            mbs_tx: mbs_tx.clone(),
            self_tx: sbs_tx,
        };
        sbs_joins.push(
            std::thread::Builder::new()
                .name(format!("hfl-sbs-{c}"))
                .spawn(move || sbs_actor(ctx, sbs_rx))
                .expect("spawn sbs"),
        );
    }
    drop(mbs_tx);
    drop(metric_tx);

    // --- MBS (leader) loop ------------------------------------------------
    // Process sync rounds as they arrive; finish when every cluster reports
    // Done (this also handles iters % H != 0 and the flat-FL no-sync case).
    let mut w_global: Vec<f32> = (*init).clone();
    let mut mbs_enc = DiscountedError::new(dim, phi_mdl, opts.sparsity.beta_m as f32);
    let mut agg = vec![0.0f32; dim];
    // Density-adaptive sync aggregation (reference baseline +0.0: the
    // accumulator is zeroed, never scaled).
    let mut mbs_shadow = DenseShadow::new();
    let mut mbs_merged = SparseVec::empty(dim);
    let mut mbs_scratch = MergeScratch::default();
    let mut sync_evals = Vec::new();
    let mut done = 0usize;
    let mut pending: Vec<Option<SparseVec>> = (0..n).map(|_| None).collect();
    let mut pending_count = 0usize;
    let mut sync_index = 0usize;
    while done < n {
        let msg = mbs_rx
            .recv()
            .map_err(|_| anyhow!("SBS actors died (sync {sync_index})"))?;
        match msg {
            SbsToMbs::Done { .. } => done += 1,
            SbsToMbs::Sync(m) => {
                assert!(pending[m.cluster].is_none(), "double sync from cluster");
                pending[m.cluster] = Some(m.delta);
                pending_count += 1;
                if pending_count == n {
                    // Aggregate in cluster order (bit-identical to the
                    // engine), through the density-adaptive dispatch: the
                    // k-way merge folds each coordinate in the same
                    // cluster order as the dense scatter.
                    let deltas: Vec<SparseVec> =
                        pending.iter_mut().map(|d| d.take().unwrap()).collect();
                    let scale = 1.0 / n as f32;
                    let parts: Vec<(&SparseVec, f32)> =
                        deltas.iter().map(|m| (m, scale)).collect();
                    merge::aggregate_adaptive(
                        &opts.agg,
                        &parts,
                        dim,
                        None,
                        &mut agg,
                        &mut mbs_merged,
                        &mut mbs_scratch,
                        &mut mbs_shadow,
                    );
                    pending_count = 0;
                    let msg = mbs_enc.compress(&agg);
                    mbs_metrics.emit(MetricEvent {
                        iter: (sync_index + 1) * opts.h_period - 1,
                        cluster: usize::MAX,
                        link: LinkKind::MbsDl,
                        bits: msg.wire_bits(32),
                        loss: f64::NAN,
                    });
                    msg.add_into(&mut w_global, 1.0);
                    for tx in &sbs_txs {
                        tx.send(SbsControl::GlobalDelta(msg.clone()))
                            .map_err(|_| anyhow!("SBS inbox closed"))?;
                    }
                    sync_index += 1;
                    if opts.eval_every_syncs > 0 && sync_index % opts.eval_every_syncs == 0 {
                        let m = compute.eval(Arc::new(w_global.clone()));
                        sync_evals.push((sync_index * opts.h_period, m));
                    }
                }
            }
        }
    }
    drop(mbs_metrics);

    // --- Shutdown: collect final cluster models ---------------------------
    for tx in &sbs_txs {
        let _ = tx.send(SbsControl::Stop);
    }
    let mut final_params = vec![0.0f32; dim];
    let mut train_loss_acc: Vec<(usize, f64, usize)> = Vec::new();
    for j in sbs_joins {
        let outcome = j.join().expect("sbs panicked");
        for (i, v) in outcome.final_model.iter().enumerate() {
            final_params[i] += v / n as f32;
        }
        for (it, loss) in outcome.iter_losses {
            match train_loss_acc.iter_mut().find(|(i, _, _)| *i == it) {
                Some((_, sum, cnt)) => {
                    *sum += loss;
                    *cnt += 1;
                }
                None => train_loss_acc.push((it, loss, 1)),
            }
        }
    }
    train_loss_acc.sort_by_key(|(i, _, _)| *i);
    let train_loss: Vec<(usize, f64)> = train_loss_acc
        .into_iter()
        .map(|(i, s, c)| (i, s / c as f64))
        .collect();

    let final_eval = compute.eval(Arc::new(final_params.clone()));
    svc.shutdown();

    let mut metrics = MetricsLog::default();
    while let Ok(ev) = metric_rx.recv() {
        metrics.push(ev);
    }

    Ok(CoordinatorRun {
        final_params,
        final_eval,
        sync_evals,
        metrics,
        train_loss,
    })
}

struct SbsContext {
    cluster: usize,
    per_cluster: usize,
    dim: usize,
    iters: usize,
    h_period: usize,
    n_clusters: usize,
    schedule: LrSchedule,
    dl_phi: f64,
    dl_beta: f32,
    ul_phi: f64,
    ul_beta: f32,
    momentum: f32,
    weight_decay: f32,
    phi_ul: f64,
    agg: AggPolicy,
    init: Arc<Vec<f32>>,
    compute: ComputeHandle,
    metrics: MetricsSink,
    mbs_tx: Sender<SbsToMbs>,
    /// Sender into this SBS's own inbox — handed to its MU actors.
    self_tx: Sender<SbsControl>,
}

struct SbsOutcome {
    final_model: Vec<f32>,
    iter_losses: Vec<(usize, f64)>,
}

/// SBS actor: spawns its MU threads, runs the intra-cluster rounds, talks
/// to the MBS at sync points, returns its final reference model.
fn sbs_actor(ctx: SbsContext, inbox: Receiver<SbsControl>) -> SbsOutcome {
    // Spawn MU actors.
    let mut mu_txs: Vec<Sender<SbsToMu>> = Vec::with_capacity(ctx.per_cluster);
    let mut mu_joins = Vec::with_capacity(ctx.per_cluster);
    for slot in 0..ctx.per_cluster {
        let (tx, rx) = channel::<SbsToMu>();
        mu_txs.push(tx);
        let mctx = MuContext {
            cluster: ctx.cluster,
            slot,
            worker: ctx.cluster * ctx.per_cluster + slot,
            dim: ctx.dim,
            iters: ctx.iters,
            h_period: ctx.h_period,
            hierarchical: ctx.n_clusters > 1,
            momentum: ctx.momentum,
            weight_decay: ctx.weight_decay,
            phi_ul: ctx.phi_ul,
            init: ctx.init.clone(),
            compute: ctx.compute.clone(),
            metrics: ctx.metrics.clone(),
        };
        let to_sbs = ctx.self_tx.clone();
        mu_joins.push(
            std::thread::Builder::new()
                .name(format!("hfl-mu-{}", mctx.worker))
                .spawn(move || mu_actor(mctx, rx, to_sbs))
                .expect("spawn mu"),
        );
    }

    let mut w_tilde: Vec<f32> = (*ctx.init).clone();
    let mut w_global: Vec<f32> = (*ctx.init).clone();
    let mut dl_enc = DiscountedError::new(ctx.dim, ctx.dl_phi, ctx.dl_beta);
    let mut ul_enc = DiscountedError::new(ctx.dim, ctx.ul_phi, ctx.ul_beta);
    let mut agg = vec![0.0f32; ctx.dim];
    // Density-adaptive round aggregation (reference baseline −0.0: the
    // accumulator is zeroed, scattered into, then scaled by −lr).
    let mut agg_shadow = DenseShadow::new();
    let mut agg_merged = SparseVec::default();
    let mut agg_scratch = MergeScratch::default();
    let mut iter_losses = Vec::with_capacity(ctx.iters);
    let mut period_loss = 0.0f64;
    let mut period_count = 0usize;

    'outer: for t in 0..ctx.iters {
        let lr = ctx.schedule.at(t) as f32;
        // Collect one gradient per slot.
        let mut slots: Vec<Option<MuToSbs>> = (0..ctx.per_cluster).map(|_| None).collect();
        let mut got = 0;
        while got < ctx.per_cluster {
            match inbox.recv() {
                Ok(SbsControl::FromMu(m)) => {
                    let slot = m.slot;
                    assert!(slots[slot].is_none(), "duplicate slot {slot}");
                    slots[slot] = Some(m);
                    got += 1;
                }
                Ok(SbsControl::Stop) | Err(_) => break 'outer,
                Ok(SbsControl::GlobalDelta(_)) => {
                    unreachable!("global delta outside sync point")
                }
            }
        }
        // Aggregate in slot order → bit-identical to the engine; the
        // sparse merge folds each coordinate in the same slot order as
        // the dense scatter, so either path is exact.
        let mut loss_sum = 0.0;
        for m in slots.iter().flatten() {
            loss_sum += m.loss;
        }
        let scale = 1.0 / ctx.per_cluster as f32;
        let parts: Vec<(&SparseVec, f32)> =
            slots.iter().flatten().map(|m| (&m.grad, scale)).collect();
        merge::aggregate_adaptive(
            &ctx.agg,
            &parts,
            ctx.dim,
            Some(-lr),
            &mut agg,
            &mut agg_merged,
            &mut agg_scratch,
            &mut agg_shadow,
        );
        let mean_loss = loss_sum / ctx.per_cluster as f64;
        iter_losses.push((t, mean_loss));
        period_loss += mean_loss;
        period_count += 1;

        let dl_msg = dl_enc.compress(&agg);
        ctx.metrics.emit(MetricEvent {
            iter: t,
            cluster: ctx.cluster,
            link: LinkKind::SbsDl,
            bits: dl_msg.wire_bits(32),
            loss: f64::NAN,
        });
        dl_msg.add_into(&mut w_tilde, 1.0);
        for tx in &mu_txs {
            if tx
                .send(SbsToMu::Update {
                    iter: t,
                    delta: dl_msg.clone(),
                })
                .is_err()
            {
                break 'outer;
            }
        }

        // Global sync.
        if ctx.n_clusters > 1 && (t + 1) % ctx.h_period == 0 {
            let delta: Vec<f32> = (0..ctx.dim)
                .map(|i| w_tilde[i] + dl_enc.error()[i] - w_global[i])
                .collect();
            let ul_msg = ul_enc.compress(&delta);
            ctx.metrics.emit(MetricEvent {
                iter: t,
                cluster: ctx.cluster,
                link: LinkKind::SbsUl,
                bits: ul_msg.wire_bits(32),
                loss: f64::NAN,
            });
            if ctx
                .mbs_tx
                .send(SbsToMbs::Sync(MbsToSbs {
                    cluster: ctx.cluster,
                    delta: ul_msg,
                    mean_loss: period_loss / period_count.max(1) as f64,
                }))
                .is_err()
            {
                break 'outer;
            }
            period_loss = 0.0;
            period_count = 0;
            // Wait for the MBS's global delta.
            let global = loop {
                match inbox.recv() {
                    Ok(SbsControl::GlobalDelta(d)) => break d,
                    Ok(SbsControl::Stop) | Err(_) => break 'outer,
                    Ok(SbsControl::FromMu(_)) => {
                        unreachable!("MU message during sync wait")
                    }
                }
            };
            // (MbsDl bits are accounted once at the MBS — it is a broadcast.)
            global.add_into(&mut w_global, 1.0);
            // Pull the cluster reference toward the new global model.
            let delta: Vec<f32> = (0..ctx.dim)
                .map(|i| w_global[i] - w_tilde[i])
                .collect();
            let dl_msg = dl_enc.compress(&delta);
            ctx.metrics.emit(MetricEvent {
                iter: t,
                cluster: ctx.cluster,
                link: LinkKind::SbsDl,
                bits: dl_msg.wire_bits(32),
                loss: f64::NAN,
            });
            dl_msg.add_into(&mut w_tilde, 1.0);
            for tx in &mu_txs {
                if tx
                    .send(SbsToMu::Update {
                        iter: t,
                        delta: dl_msg.clone(),
                    })
                    .is_err()
                {
                    break 'outer;
                }
            }
        }
    }

    let _ = ctx.mbs_tx.send(SbsToMbs::Done {
        cluster: ctx.cluster,
    });
    for tx in &mu_txs {
        let _ = tx.send(SbsToMu::Stop);
    }
    for j in mu_joins {
        let _ = j.join();
    }
    SbsOutcome {
        final_model: w_tilde,
        iter_losses,
    }
}

// --- MU actor ---------------------------------------------------------------

struct MuContext {
    cluster: usize,
    slot: usize,
    worker: usize,
    dim: usize,
    iters: usize,
    h_period: usize,
    hierarchical: bool,
    momentum: f32,
    weight_decay: f32,
    phi_ul: f64,
    init: Arc<Vec<f32>>,
    compute: ComputeHandle,
    metrics: MetricsSink,
}

/// MU actor: per-iteration compute → DGC-compress → upload, then apply the
/// deterministic number of SBS deltas (1, or 2 at sync iterations).
fn mu_actor(ctx: MuContext, inbox: Receiver<SbsToMu>, to_sbs: Sender<SbsControl>) {
    let mut replica: Vec<f32> = (*ctx.init).clone();
    let mut dgc = DgcCompressor::new(ctx.dim, ctx.momentum, ctx.phi_ul);
    let mut msg = SparseVec::empty(ctx.dim);
    for iter in 0..ctx.iters {
        // Compute this iteration's gradient at the current replica.
        let (loss, mut grad) = ctx.compute.grad(ctx.worker, Arc::new(replica.clone()));
        if ctx.weight_decay != 0.0 {
            for i in 0..ctx.dim {
                grad[i] += ctx.weight_decay * replica[i];
            }
        }
        dgc.step_into(&grad, &mut msg);
        ctx.metrics.emit(MetricEvent {
            iter,
            cluster: ctx.cluster,
            link: LinkKind::MuUl,
            bits: msg.wire_bits(32),
            loss,
        });
        if to_sbs
            .send(SbsControl::FromMu(MuToSbs {
                slot: ctx.slot,
                worker: ctx.worker,
                loss,
                grad: msg.clone(),
            }))
            .is_err()
        {
            return;
        }
        // Expect exactly one delta per round, two at sync iterations.
        let expected = if ctx.hierarchical && (iter + 1) % ctx.h_period == 0 {
            2
        } else {
            1
        };
        for _ in 0..expected {
            match inbox.recv() {
                Ok(SbsToMu::Update { delta, .. }) => delta.add_into(&mut replica, 1.0),
                Ok(SbsToMu::Stop) | Err(_) => return,
            }
        }
    }
    // All rounds done; wait for Stop so the SBS can shut down cleanly.
    while let Ok(m) = inbox.recv() {
        if matches!(m, SbsToMu::Stop) {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::oracle::QuadraticOracle;

    fn opts() -> CoordinatorOptions {
        CoordinatorOptions {
            iters: 60,
            peak_lr: 0.05,
            warmup_iters: 5,
            milestones: (0.6, 0.85),
            momentum: 0.9,
            weight_decay: 0.0,
            h_period: 4,
            n_clusters: 2,
            sparsity: SparsityConfig::dense(),
            eval_every_syncs: 3,
            agg: AggPolicy::default(),
        }
    }

    #[test]
    fn coordinated_hfl_converges() {
        let run = run_coordinated(|| QuadraticOracle::new(12, 6, 0.0, 77), &opts()).unwrap();
        let oracle = QuadraticOracle::new(12, 6, 0.0, 77);
        let gap0 = oracle.objective(&vec![0.0; 12]) - oracle.objective(&oracle.optimum());
        let gap1 = oracle.objective(&run.final_params) - oracle.objective(&oracle.optimum());
        assert!(gap1 < gap0 * 1e-2, "gap {gap0} → {gap1}");
        assert!(!run.sync_evals.is_empty());
        assert!(!run.train_loss.is_empty());
        assert_eq!(run.train_loss.len(), 60);
    }

    #[test]
    fn coordinated_sparse_run_emits_all_link_metrics() {
        let mut o = opts();
        o.sparsity = SparsityConfig {
            enabled: true,
            phi_mu_ul: 0.8,
            phi_sbs_dl: 0.5,
            phi_sbs_ul: 0.5,
            phi_mbs_dl: 0.5,
            beta_m: 0.2,
            beta_s: 0.5,
        };
        let run = run_coordinated(|| QuadraticOracle::new(30, 6, 0.0, 78), &o).unwrap();
        for link in [
            LinkKind::MuUl,
            LinkKind::SbsDl,
            LinkKind::SbsUl,
            LinkKind::MbsDl,
        ] {
            assert!(run.metrics.total_bits(link) > 0.0, "{link:?} empty");
        }
        // 6 workers × 60 iters MU uploads.
        let mu_msgs = run
            .metrics
            .events
            .iter()
            .filter(|e| e.link == LinkKind::MuUl)
            .count();
        assert_eq!(mu_msgs, 360);
    }

    #[test]
    fn agg_path_sparse_matches_dense_bit_exactly() {
        // The actor topology through the sparse-merge aggregation must
        // reproduce the dense-scatter run exactly — same final params,
        // same per-link bits — across SBS rounds and MBS syncs.
        let run = |path: crate::sparse::AggPath| {
            let mut o = opts();
            o.sparsity = SparsityConfig {
                enabled: true,
                phi_mu_ul: 0.9,
                phi_sbs_dl: 0.5,
                phi_sbs_ul: 0.5,
                phi_mbs_dl: 0.5,
                beta_m: 0.2,
                beta_s: 0.5,
            };
            o.agg = AggPolicy { path, ..Default::default() };
            run_coordinated(|| QuadraticOracle::new(40, 6, 0.0, 81), &o).unwrap()
        };
        let dense = run(crate::sparse::AggPath::Dense);
        for path in [crate::sparse::AggPath::Sparse, crate::sparse::AggPath::Auto] {
            let other = run(path);
            let bits_of = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits_of(&dense.final_params), bits_of(&other.final_params), "{path:?}");
            for link in [LinkKind::MuUl, LinkKind::SbsDl, LinkKind::SbsUl, LinkKind::MbsDl] {
                assert_eq!(
                    dense.metrics.total_bits(link).to_bits(),
                    other.metrics.total_bits(link).to_bits(),
                    "{path:?} {link:?}"
                );
            }
        }
    }

    #[test]
    fn flat_fl_runs_without_mbs_traffic() {
        let mut o = opts();
        o.n_clusters = 1;
        o.h_period = 2;
        let run = run_coordinated(|| QuadraticOracle::new(8, 4, 0.0, 79), &o).unwrap();
        assert_eq!(run.metrics.total_bits(LinkKind::SbsUl), 0.0);
        assert_eq!(run.metrics.total_bits(LinkKind::MbsDl), 0.0);
        assert!(run.metrics.total_bits(LinkKind::MuUl) > 0.0);
    }

    #[test]
    fn uneven_split_is_error() {
        let mut o = opts();
        o.n_clusters = 4;
        let res = run_coordinated(|| QuadraticOracle::new(4, 6, 0.0, 80), &o);
        assert!(res.is_err());
    }
}
