//! The coordinated training run and the MU actor.
//!
//! The coordinator executes the same arithmetic as the sequential reference
//! engine ([`crate::fl::run_hierarchical`]) — same compressors, same
//! aggregation order (slot-indexed), same LR schedule — so given a
//! deterministic oracle the two produce **bit-identical** final parameters
//! (asserted in `rust/tests/coordinator_equivalence.rs`). What the actor
//! version adds is the real topology: per-MU replicas and DGC state, per-SBS
//! encoders, channel-synchronized rounds, H-period global sync through the
//! MBS, metrics, and clean shutdown.
//!
//! Since the `net` subsystem, the topology is *service-shaped*:
//! [`run_coordinated`] delegates to
//! [`crate::net::serve::run_coordinated_service`], which runs the MBS on
//! the caller's thread and one SBS+MUs cell thread per cluster
//! ([`crate::net::worker::run_cell`]), every SBS↔MBS hop crossing a framed
//! loopback transport — the exact codec `hfl serve`/`hfl worker` ship over
//! TCP. Only the MU actor lives here: MU↔SBS traffic stays on in-process
//! channels on both deployment shapes.
//!
//! Synchronization protocol (no explicit barriers; channels carry it):
//!
//! 1. every MU computes a gradient at its replica and uploads it;
//! 2. the SBS aggregates all `per_cluster` slots, steps its reference model
//!    and broadcasts one model delta (two at sync iterations — the second
//!    carries the pull toward the freshly averaged global model);
//! 3. MUs apply exactly the expected number of deltas (they know H), then
//!    start the next round.

use super::compute::ComputeHandle;
use super::messages::{MuToSbs, SbsToMu};
use super::metrics::{LinkKind, MetricEvent, MetricsLog, MetricsSink};
use crate::adversary::AdversaryPlan;
use crate::fl::oracle::{EvalMetrics, GradOracle};
use crate::spec::RunSpec;
use crate::sparse::{DgcCompressor, SparseVec};
use anyhow::Result;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;

/// Options for a coordinated run: the shared [`RunSpec`] scalars (the
/// coordinator ignores its `inner_threads`/`pool` wiring — cells fan out
/// as threads of their own) plus the two coordinator-only knobs.
/// `Deref`s to its spec, so `opts.iters`-style reads work unchanged.
#[derive(Clone, Debug)]
pub struct CoordinatorOptions {
    /// The shared run specification (see [`crate::spec::RunSpec`]).
    pub spec: RunSpec,
    /// Number of clusters N (one SBS cell / worker process each).
    pub n_clusters: usize,
    /// Evaluate on the MBS's global model every this many sync points
    /// (0 → final only).
    pub eval_every_syncs: usize,
}

impl Default for CoordinatorOptions {
    fn default() -> Self {
        Self { spec: RunSpec::default(), n_clusters: 1, eval_every_syncs: 0 }
    }
}

impl std::ops::Deref for CoordinatorOptions {
    type Target = RunSpec;
    fn deref(&self) -> &RunSpec {
        &self.spec
    }
}

impl std::ops::DerefMut for CoordinatorOptions {
    fn deref_mut(&mut self) -> &mut RunSpec {
        &mut self.spec
    }
}

impl From<RunSpec> for CoordinatorOptions {
    fn from(spec: RunSpec) -> Self {
        Self { spec, ..Self::default() }
    }
}

impl From<&crate::fl::TrainOptions> for CoordinatorOptions {
    fn from(o: &crate::fl::TrainOptions) -> Self {
        Self {
            spec: o.spec.clone(),
            n_clusters: o.n_clusters,
            eval_every_syncs: 0,
        }
    }
}

/// The per-link sparsification levels `(φ_mu_ul, φ_sbs_dl, φ_sbs_ul,
/// φ_mbs_dl)` in effect — all zeros when sparsity is disabled. Shared by
/// the MBS, the cells and replay so the selection logic cannot drift.
pub(crate) fn effective_phis(opts: &CoordinatorOptions) -> (f64, f64, f64, f64) {
    if opts.sparsity.enabled {
        (
            opts.sparsity.phi_mu_ul,
            opts.sparsity.phi_sbs_dl,
            opts.sparsity.phi_sbs_ul,
            opts.sparsity.phi_mbs_dl,
        )
    } else {
        (0.0, 0.0, 0.0, 0.0)
    }
}

/// Result of a coordinated run.
#[derive(Clone, Debug)]
pub struct CoordinatorRun {
    /// Consensus (cluster-averaged) final parameters.
    pub final_params: Vec<f32>,
    /// Final held-out metrics.
    pub final_eval: EvalMetrics,
    /// (sync iteration, metrics) evaluated on the MBS global model.
    pub sync_evals: Vec<(usize, EvalMetrics)>,
    /// Per-message communication log.
    pub metrics: MetricsLog,
    /// (iteration, mean training loss).
    pub train_loss: Vec<(usize, f64)>,
    /// Clusters the fault policy declared dead, as `(cluster, sync round
    /// of the skip)` in skip order. Empty on every clean run; enters the
    /// golden trace as the skip digest.
    pub skips: Vec<(usize, usize)>,
}

/// Run hierarchical FL on the actor topology. `factory` constructs the
/// gradient oracle inside the compute thread (PJRT handles are !Send).
///
/// Delegates to the loopback-transport service
/// ([`crate::net::serve::run_coordinated_service`]) with logging and live
/// metrics off — so every in-process run, test, and golden trace exercises
/// the full `net` frame/wire codec.
pub fn run_coordinated<F, O>(factory: F, opts: &CoordinatorOptions) -> Result<CoordinatorRun>
where
    F: FnOnce() -> O + Send + 'static,
    O: GradOracle + 'static,
{
    crate::net::serve::run_coordinated_service(factory, opts, None, None)
}

// --- MU actor ---------------------------------------------------------------

pub(crate) struct MuContext {
    pub(crate) cluster: usize,
    pub(crate) slot: usize,
    pub(crate) worker: usize,
    pub(crate) dim: usize,
    pub(crate) iters: usize,
    pub(crate) h_period: usize,
    pub(crate) hierarchical: bool,
    pub(crate) momentum: f32,
    pub(crate) weight_decay: f32,
    pub(crate) phi_ul: f64,
    pub(crate) init: Arc<Vec<f32>>,
    pub(crate) compute: ComputeHandle,
    pub(crate) metrics: MetricsSink,
    /// Byzantine behavior keyed by the MU's *global* worker id — decisions
    /// match the sequential and DES engines bit for bit.
    pub(crate) adversary: AdversaryPlan,
}

/// MU actor: per-iteration compute → DGC-compress → upload, then apply the
/// deterministic number of SBS deltas (1, or 2 at sync iterations). The
/// metric event is emitted *before* the upload, so once the SBS holds a
/// round's gradients the round's events are already drainable.
pub(crate) fn mu_actor(ctx: MuContext, inbox: Receiver<SbsToMu>, to_sbs: Sender<MuToSbs>) {
    let mut replica: Vec<f32> = (*ctx.init).clone();
    let mut dgc = DgcCompressor::new(ctx.dim, ctx.momentum, ctx.phi_ul);
    let mut msg = SparseVec::empty(ctx.dim);
    // Stale-replay slot of the Byzantine attack model: the previous honest
    // post-DGC message (actor-local — each MU owns exactly one uplink).
    let mut stale: Option<(Vec<u32>, Vec<f32>)> = None;
    for iter in 0..ctx.iters {
        // Compute this iteration's gradient at the current replica.
        let (loss, mut grad) = ctx.compute.grad(ctx.worker, Arc::new(replica.clone()));
        if ctx.weight_decay != 0.0 {
            for i in 0..ctx.dim {
                grad[i] += ctx.weight_decay * replica[i];
            }
        }
        dgc.step_into(&grad, &mut msg);
        if ctx.adversary.enabled {
            // Attack the post-DGC uplink, before bit accounting — the DGC
            // residual keeps evolving as if the honest update was sent,
            // exactly like the sequential and DES engines.
            ctx.adversary.corrupt(
                ctx.worker as u64,
                iter as u64,
                &mut msg.indices,
                &mut msg.values,
                &mut stale,
            );
        }
        ctx.metrics.emit(MetricEvent {
            iter,
            cluster: ctx.cluster,
            link: LinkKind::MuUl,
            bits: msg.wire_bits(32),
            loss,
        });
        if to_sbs
            .send(MuToSbs {
                slot: ctx.slot,
                worker: ctx.worker,
                loss,
                grad: msg.clone(),
            })
            .is_err()
        {
            return;
        }
        // Expect exactly one delta per round, two at sync iterations.
        let expected = if ctx.hierarchical && (iter + 1) % ctx.h_period == 0 {
            2
        } else {
            1
        };
        for _ in 0..expected {
            match inbox.recv() {
                Ok(SbsToMu::Update { delta, .. }) => delta.add_into(&mut replica, 1.0),
                Ok(SbsToMu::Stop) | Err(_) => return,
            }
        }
    }
    // All rounds done; wait for Stop so the SBS can shut down cleanly.
    while let Ok(m) = inbox.recv() {
        if matches!(m, SbsToMu::Stop) {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SparsityConfig;
    use crate::fl::oracle::QuadraticOracle;
    use crate::sparse::merge::AggPolicy;

    fn opts() -> CoordinatorOptions {
        CoordinatorOptions {
            spec: RunSpec::new()
                .iters(60)
                .peak_lr(0.05)
                .warmup(5)
                .milestones(0.6, 0.85)
                .h_period(4),
            n_clusters: 2,
            eval_every_syncs: 3,
        }
    }

    #[test]
    fn coordinated_hfl_converges() {
        let run = run_coordinated(|| QuadraticOracle::new(12, 6, 0.0, 77), &opts()).unwrap();
        let oracle = QuadraticOracle::new(12, 6, 0.0, 77);
        let gap0 = oracle.objective(&vec![0.0; 12]) - oracle.objective(&oracle.optimum());
        let gap1 = oracle.objective(&run.final_params) - oracle.objective(&oracle.optimum());
        assert!(gap1 < gap0 * 1e-2, "gap {gap0} → {gap1}");
        assert!(!run.sync_evals.is_empty());
        assert!(!run.train_loss.is_empty());
        assert_eq!(run.train_loss.len(), 60);
    }

    #[test]
    fn coordinated_sparse_run_emits_all_link_metrics() {
        let mut o = opts();
        o.sparsity = SparsityConfig {
            enabled: true,
            phi_mu_ul: 0.8,
            phi_sbs_dl: 0.5,
            phi_sbs_ul: 0.5,
            phi_mbs_dl: 0.5,
            beta_m: 0.2,
            beta_s: 0.5,
        };
        let run = run_coordinated(|| QuadraticOracle::new(30, 6, 0.0, 78), &o).unwrap();
        for link in [
            LinkKind::MuUl,
            LinkKind::SbsDl,
            LinkKind::SbsUl,
            LinkKind::MbsDl,
        ] {
            assert!(run.metrics.total_bits(link) > 0.0, "{link:?} empty");
        }
        // 6 workers × 60 iters MU uploads.
        let mu_msgs = run
            .metrics
            .events
            .iter()
            .filter(|e| e.link == LinkKind::MuUl)
            .count();
        assert_eq!(mu_msgs, 360);
    }

    #[test]
    fn agg_path_sparse_matches_dense_bit_exactly() {
        // The actor topology through the sparse-merge aggregation must
        // reproduce the dense-scatter run exactly — same final params,
        // same per-link bits — across SBS rounds and MBS syncs.
        let run = |path: crate::sparse::AggPath| {
            let mut o = opts();
            o.sparsity = SparsityConfig {
                enabled: true,
                phi_mu_ul: 0.9,
                phi_sbs_dl: 0.5,
                phi_sbs_ul: 0.5,
                phi_mbs_dl: 0.5,
                beta_m: 0.2,
                beta_s: 0.5,
            };
            o.agg = AggPolicy { path, ..Default::default() };
            run_coordinated(|| QuadraticOracle::new(40, 6, 0.0, 81), &o).unwrap()
        };
        let dense = run(crate::sparse::AggPath::Dense);
        for path in [crate::sparse::AggPath::Sparse, crate::sparse::AggPath::Auto] {
            let other = run(path);
            let bits_of = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits_of(&dense.final_params), bits_of(&other.final_params), "{path:?}");
            for link in [LinkKind::MuUl, LinkKind::SbsDl, LinkKind::SbsUl, LinkKind::MbsDl] {
                assert_eq!(
                    dense.metrics.total_bits(link).to_bits(),
                    other.metrics.total_bits(link).to_bits(),
                    "{path:?} {link:?}"
                );
            }
        }
    }

    #[test]
    fn flat_fl_runs_without_mbs_traffic() {
        let mut o = opts();
        o.n_clusters = 1;
        o.h_period = 2;
        let run = run_coordinated(|| QuadraticOracle::new(8, 4, 0.0, 79), &o).unwrap();
        assert_eq!(run.metrics.total_bits(LinkKind::SbsUl), 0.0);
        assert_eq!(run.metrics.total_bits(LinkKind::MbsDl), 0.0);
        assert!(run.metrics.total_bits(LinkKind::MuUl) > 0.0);
    }

    #[test]
    fn uneven_split_is_error() {
        let mut o = opts();
        o.n_clusters = 4;
        let res = run_coordinated(|| QuadraticOracle::new(4, 6, 0.0, 80), &o);
        assert!(res.is_err());
    }
}
