//! The distributed coordinator: the paper's system realized as a
//! thread-actor topology mirroring the HCN —
//!
//! ```text
//!            MBS (leader, main thread)
//!           /    |     \            global sync every H iterations,
//!        SBS₀  SBS₁ …  SBS_{N−1}    over a framed `net` transport
//!       / | \                       (loopback in-process, TCP for
//!     MU MU MU …                     `hfl serve`/`hfl worker`);
//!             \                     intra-cluster rounds every iteration
//!              ComputeService       (single thread owning the PJRT
//!                                    runtime — xla handles are !Send)
//! ```
//!
//! Every link carries the same [`SparseVec`](crate::sparse::SparseVec)
//! messages as the reference engine in [`crate::fl::algorithms`], with the
//! same compressors in the same order — the coordinator is *bit-identical*
//! to the sequential engine (asserted by integration tests), it just runs
//! the topology for real: channels, per-actor state, barrier-free
//! synchronous rounds, graceful shutdown, and per-link metrics that the
//! latency model converts into simulated network time. The SBS↔MBS tier
//! lives in [`crate::net`]; this module keeps the MU actor, the compute
//! service, the in-process MU↔SBS messages and the metrics schema.

pub mod compute;
pub mod messages;
pub mod metrics;
pub mod run;

pub use compute::{ComputeHandle, ComputeService};
pub use messages::{MuToSbs, SbsToMu};
pub use metrics::{LinkKind, MetricEvent, MetricsLog, MetricsSink};
pub use run::{run_coordinated, CoordinatorOptions, CoordinatorRun};
