//! Foundational utilities built from scratch for the offline environment:
//! deterministic RNG + distributions, special functions, order statistics,
//! descriptive statistics, CSV/JSON emitters, a tiny logger, and a
//! criterion-style microbenchmark harness.

pub mod bench;
pub mod csv;
pub mod json;
pub mod logging;
pub mod math;
pub mod rng;
pub mod stats;

pub use rng::Pcg64;
