//! Tiny CSV writer for experiment outputs (figure series, loss curves).
//! Quotes fields only when necessary; numbers are written with enough
//! precision to round-trip f64.

use std::fmt::Write as _;
use std::fs;
use std::io;
use std::path::Path;

/// An in-memory CSV table with a fixed header.
#[derive(Clone, Debug)]
pub struct CsvTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl CsvTable {
    pub fn new<S: Into<String>>(header: impl IntoIterator<Item = S>) -> Self {
        Self {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    /// Push a row of already-formatted cells; panics on arity mismatch.
    pub fn push_row<S: Into<String>>(&mut self, row: impl IntoIterator<Item = S>) {
        let row: Vec<String> = row.into_iter().map(Into::into).collect();
        assert_eq!(
            row.len(),
            self.header.len(),
            "row arity {} != header arity {}",
            row.len(),
            self.header.len()
        );
        self.rows.push(row);
    }

    /// Push a row of f64 values.
    pub fn push_nums(&mut self, row: &[f64]) {
        self.push_row(row.iter().map(|x| format_num(*x)));
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        write_record(&self.header, &mut out);
        for r in &self.rows {
            write_record(r, &mut out);
        }
        out
    }

    /// Write to a file, creating parent directories.
    pub fn save(&self, path: impl AsRef<Path>) -> io::Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        fs::write(path, self.to_string())
    }
}

fn write_record(cells: &[String], out: &mut String) {
    for (i, c) in cells.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        if c.contains(',') || c.contains('"') || c.contains('\n') {
            out.push('"');
            out.push_str(&c.replace('"', "\"\""));
            out.push('"');
        } else {
            out.push_str(c);
        }
    }
    out.push('\n');
}

/// Format a number: integers plainly, floats with up-to-9 significant digits.
pub fn format_num(x: f64) -> String {
    if x == x.trunc() && x.abs() < 1e15 {
        format!("{}", x as i64)
    } else {
        let mut s = String::new();
        let _ = write!(s, "{:.9}", x);
        // trim trailing zeros but keep at least one decimal
        while s.ends_with('0') {
            s.pop();
        }
        if s.ends_with('.') {
            s.push('0');
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_table() {
        let mut t = CsvTable::new(["mus", "speedup"]);
        t.push_nums(&[4.0, 7.25]);
        t.push_nums(&[8.0, 9.5]);
        let s = t.to_string();
        assert_eq!(s, "mus,speedup\n4,7.25\n8,9.5\n");
    }

    #[test]
    fn quoting() {
        let mut t = CsvTable::new(["a", "b"]);
        t.push_row(["x,y", "he said \"hi\""]);
        let s = t.to_string();
        assert!(s.contains("\"x,y\""));
        assert!(s.contains("\"he said \"\"hi\"\"\""));
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = CsvTable::new(["a", "b"]);
        t.push_row(["only-one"]);
    }

    #[test]
    fn format_num_trims() {
        assert_eq!(format_num(3.0), "3");
        assert_eq!(format_num(0.5), "0.5");
        assert_eq!(format_num(-2.25), "-2.25");
    }
}
