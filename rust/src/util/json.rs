//! Minimal JSON reader/writer (no `serde` in the offline environment).
//!
//! The writer covers what experiment outputs need (objects, arrays, strings,
//! numbers, bools). The reader is a small recursive-descent parser used for
//! `artifacts/manifest.json` — it supports the full JSON grammar except for
//! `\u` surrogate pairs beyond the BMP, which the manifest never contains.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value tree.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Exact non-negative integer extraction. Returns `None` for
    /// non-integral values, negatives, and anything above 2^53 — the
    /// largest magnitude at which every integer is exactly representable
    /// in the `f64` this tree stores. (The old `as f64 as usize` cast
    /// silently rounded such values; counters that can exceed 2^53 must
    /// round-trip through decimal strings instead — see
    /// `sim::result::GoldenTrace`.)
    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|x| x as usize)
    }

    /// Exact u64 extraction with the same 2^53 safety bound as
    /// [`Json::as_usize`].
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|x| {
            if x.is_finite() && x >= 0.0 && x.trunc() == x && x <= F64_EXACT_INT_MAX {
                Some(x as u64)
            } else {
                None
            }
        })
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Serialize compactly, but **reject non-finite numbers** instead of
    /// silently emitting `null` the way [`Json::to_string_compact`] must
    /// (JSON has no NaN/Inf). Trace and snapshot boundaries use this so a
    /// diverged loss corrupts nothing undetected; the error names the path
    /// of the offending value.
    pub fn to_string_strict(&self) -> Result<String, String> {
        let mut s = String::new();
        self.write_strict(&mut s, &mut String::from("$"))?;
        Ok(s)
    }

    fn write_strict(&self, out: &mut String, path: &mut String) -> Result<(), String> {
        match self {
            Json::Num(x) if !x.is_finite() => {
                Err(format!("non-finite number {x} at {path} (strict JSON)"))
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    let len = path.len();
                    let _ = write!(path, "[{i}]");
                    v.write_strict(out, path)?;
                    path.truncate(len);
                }
                out.push(']');
                Ok(())
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    let len = path.len();
                    let _ = write!(path, ".{k}");
                    v.write_strict(out, path)?;
                    path.truncate(len);
                }
                out.push('}');
                Ok(())
            }
            other => {
                other.write(out);
                Ok(())
            }
        }
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(*x, out),
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Largest f64 magnitude at which every integer is exactly representable
/// (2^53). Integers beyond this bound cannot round-trip through a JSON
/// number and must be carried as decimal strings.
pub const F64_EXACT_INT_MAX: f64 = 9_007_199_254_740_992.0;

fn write_num(x: f64, out: &mut String) {
    if x.is_finite() {
        if x == x.trunc() && x.abs() < 1e15 {
            let _ = write!(out, "{}", x as i64);
        } else {
            let _ = write!(out, "{x}");
        }
    } else {
        out.push_str("null"); // JSON has no NaN/Inf
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builder for JSON objects in experiment logs.
#[derive(Default)]
pub struct ObjBuilder {
    map: BTreeMap<String, Json>,
}

impl ObjBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn str(mut self, k: &str, v: impl Into<String>) -> Self {
        self.map.insert(k.to_string(), Json::Str(v.into()));
        self
    }

    pub fn num(mut self, k: &str, v: f64) -> Self {
        self.map.insert(k.to_string(), Json::Num(v));
        self
    }

    pub fn bool(mut self, k: &str, v: bool) -> Self {
        self.map.insert(k.to_string(), Json::Bool(v));
        self
    }

    pub fn arr_num(mut self, k: &str, v: &[f64]) -> Self {
        self.map.insert(
            k.to_string(),
            Json::Arr(v.iter().map(|&x| Json::Num(x)).collect()),
        );
        self
    }

    pub fn val(mut self, k: &str, v: Json) -> Self {
        self.map.insert(k.to_string(), v);
        self
    }

    pub fn build(self) -> Json {
        Json::Obj(self.map)
    }
}

/// Parse a JSON document.
pub fn parse(input: &str) -> Result<Json, String> {
    let bytes = input.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != bytes.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|b| b as char), self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            arr.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(arr));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..]).map_err(|e| e.to_string())?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let j = ObjBuilder::new()
            .str("name", "train_step")
            .num("params", 123456.0)
            .bool("tuple", true)
            .arr_num("shape", &[64.0, 3072.0])
            .build();
        let s = j.to_string_compact();
        let back = parse(&s).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn parse_nested() {
        let j = parse(r#"{"a": [1, 2.5, {"b": "x\ny"}], "c": null, "d": false}"#).unwrap();
        assert_eq!(j.get("c"), Some(&Json::Null));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_f64(), Some(2.5));
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn parse_rejects_trailing_garbage() {
        assert!(parse("{} x").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\"}").is_err());
    }

    #[test]
    fn escapes_control_chars() {
        let j = Json::Str("a\"b\\c\n\u{1}".into());
        let s = j.to_string_compact();
        assert_eq!(parse(&s).unwrap(), j);
    }

    #[test]
    fn numbers_scientific() {
        let j = parse("[1e3, -2.5E-2, 0]").unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a[0].as_f64(), Some(1000.0));
        assert_eq!(a[1].as_f64(), Some(-0.025));
    }

    #[test]
    fn unicode_escape() {
        let j = parse(r#""é""#).unwrap();
        assert_eq!(j.as_str(), Some("é"));
    }

    #[test]
    fn strict_writer_rejects_non_finite_with_path() {
        let j = ObjBuilder::new()
            .num("ok", 1.5)
            .val("curve", Json::Arr(vec![Json::Num(0.5), Json::Num(f64::NAN)]))
            .build();
        let err = j.to_string_strict().unwrap_err();
        assert!(err.contains("$.curve[1]"), "err should name the path: {err}");
        assert!(Json::Num(f64::INFINITY).to_string_strict().is_err());
        assert!(Json::Num(f64::NEG_INFINITY).to_string_strict().is_err());
        // Finite trees serialize identically to the lenient writer.
        let ok = ObjBuilder::new().num("a", 2.25).str("b", "x").build();
        assert_eq!(ok.to_string_strict().unwrap(), ok.to_string_compact());
    }

    #[test]
    fn as_usize_is_exact_and_bounded() {
        assert_eq!(Json::Num(42.0).as_usize(), Some(42));
        assert_eq!(Json::Num(0.0).as_usize(), Some(0));
        assert_eq!(Json::Num(F64_EXACT_INT_MAX).as_u64(), Some(1u64 << 53));
        // Non-integral, negative, non-finite, and beyond-2^53 all refuse
        // instead of silently rounding.
        assert_eq!(Json::Num(1.5).as_usize(), None);
        assert_eq!(Json::Num(-1.0).as_usize(), None);
        assert_eq!(Json::Num(f64::NAN).as_usize(), None);
        assert_eq!(Json::Num(F64_EXACT_INT_MAX * 2.0).as_u64(), None);
        assert_eq!(Json::Str("7".into()).as_usize(), None);
    }
}
