//! Deterministic pseudo-random number generation.
//!
//! The offline build has no `rand` crate, so we implement PCG64 (XSL-RR
//! variant, O'Neill 2014) plus the distributions the wireless and training
//! simulators need: uniform, standard normal (Ziggurat-free Box–Muller,
//! which is plenty fast for our Monte-Carlo sizes), exponential (for
//! Rayleigh-fading channel power gains), and utility samplers.
//!
//! All stochastic components in the crate take an explicit seed so figures
//! and tables regenerate bit-for-bit.

/// PCG64 (XSL-RR 128/64) pseudo-random generator.
///
/// State transition: 128-bit LCG; output: xor-shift-low + random rotate.
/// Passes PractRand/TestU01 at this output size; period 2^128.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
    /// Cached second normal variate from Box–Muller.
    cached_normal: Option<f64>,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a 64-bit seed and a stream id.
    ///
    /// Distinct `stream` values yield statistically independent sequences —
    /// used to give every MU / cluster / figure its own stream derived from
    /// one experiment seed.
    pub fn new(seed: u64, stream: u64) -> Self {
        // SplitMix64 the seed into 128 bits of state so that small seeds
        // (0, 1, 2...) still start from well-mixed states.
        let mut sm = SplitMix64::new(seed);
        let s0 = sm.next() as u128;
        let s1 = sm.next() as u128;
        let mut smi = SplitMix64::new(stream ^ 0x9e37_79b9_7f4a_7c15);
        let i0 = smi.next() as u128;
        let i1 = smi.next() as u128;
        let mut rng = Self {
            state: (s0 << 64) | s1,
            inc: (((i0 << 64) | i1) << 1) | 1, // must be odd
            cached_normal: None,
        };
        // Warm up: decorrelates trivially-related seeds.
        rng.next_u64();
        rng.next_u64();
        rng
    }

    /// Convenience constructor with stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Raw generator state for checkpointing: `(state, inc, cached_normal)`.
    ///
    /// The Box–Muller cache is part of the state — dropping it would shift
    /// every subsequent [`Pcg64::normal`] draw, so resume would diverge.
    pub fn raw_state(&self) -> (u128, u128, Option<f64>) {
        (self.state, self.inc, self.cached_normal)
    }

    /// Rebuild a generator from [`Pcg64::raw_state`] output. No seed
    /// expansion, no warm-up: the restored generator continues the exact
    /// output sequence of the snapshotted one.
    pub fn from_raw_state(state: u128, inc: u128, cached_normal: Option<f64>) -> Self {
        assert!(inc & 1 == 1, "PCG increment must be odd");
        Self {
            state,
            inc,
            cached_normal,
        }
    }

    /// Derive a child generator; `tag` labels the branch (e.g. MU index).
    pub fn fork(&mut self, tag: u64) -> Self {
        let s = self.next_u64();
        Self::new(s ^ tag.wrapping_mul(0xa076_1d64_78bd_642f), tag)
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in [0, 1) with 53-bit precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn uniform_u64(&mut self, n: u64) -> u64 {
        assert!(n > 0, "uniform_u64 requires n > 0");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [0, n).
    #[inline]
    pub fn uniform_usize(&mut self, n: usize) -> usize {
        self.uniform_u64(n as u64) as usize
    }

    /// Standard normal N(0,1) via Box–Muller (pair-cached).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.cached_normal.take() {
            return z;
        }
        // Reject u1 == 0 to avoid ln(0).
        let mut u1 = self.uniform();
        while u1 <= f64::MIN_POSITIVE {
            u1 = self.uniform();
        }
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.cached_normal = Some(r * s);
        r * c
    }

    /// Normal with mean/std.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with rate 1 (mean 1). The power gain |h|^2 of a
    /// Rayleigh-fading channel with E[|h|^2]=1 is Exp(1).
    #[inline]
    pub fn exponential(&mut self) -> f64 {
        let mut u = self.uniform();
        while u <= f64::MIN_POSITIVE {
            u = self.uniform();
        }
        -u.ln()
    }

    /// Exponential with the given mean.
    #[inline]
    pub fn exponential_mean(&mut self, mean: f64) -> f64 {
        mean * self.exponential()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.uniform_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.uniform_usize(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

/// SplitMix64 — used only to expand seeds into PCG state.
struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    fn next(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seeded(1);
        let mut b = Pcg64::seeded(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same == 0, "seeds 1 and 2 should not collide");
    }

    #[test]
    fn different_streams_differ() {
        let mut a = Pcg64::new(7, 0);
        let mut b = Pcg64::new(7, 1);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same == 0);
    }

    #[test]
    fn uniform_in_unit_interval_and_roughly_uniform() {
        let mut rng = Pcg64::seeded(3);
        let n = 100_000;
        let mut sum = 0.0;
        let mut buckets = [0usize; 10];
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
            buckets[(u * 10.0) as usize] += 1;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
        for b in buckets {
            let frac = b as f64 / n as f64;
            assert!((frac - 0.1).abs() < 0.01, "bucket frac {frac}");
        }
    }

    #[test]
    fn uniform_u64_unbiased_small_n() {
        let mut rng = Pcg64::seeded(4);
        let mut counts = [0usize; 3];
        let n = 90_000;
        for _ in 0..n {
            counts[rng.uniform_u64(3) as usize] += 1;
        }
        for c in counts {
            let frac = c as f64 / n as f64;
            assert!((frac - 1.0 / 3.0).abs() < 0.01, "frac={frac}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seeded(5);
        let n = 200_000;
        let (mut m1, mut m2) = (0.0, 0.0);
        for _ in 0..n {
            let z = rng.normal();
            m1 += z;
            m2 += z * z;
        }
        m1 /= n as f64;
        m2 /= n as f64;
        assert!(m1.abs() < 0.01, "mean={m1}");
        assert!((m2 - 1.0).abs() < 0.02, "var={m2}");
    }

    #[test]
    fn exponential_moments_and_support() {
        let mut rng = Pcg64::seeded(6);
        let n = 200_000;
        let mut m1 = 0.0;
        for _ in 0..n {
            let e = rng.exponential();
            assert!(e >= 0.0);
            m1 += e;
        }
        m1 /= n as f64;
        assert!((m1 - 1.0).abs() < 0.02, "mean={m1}");
        // P(X > 1) = e^-1 ≈ 0.3679
        let mut rng = Pcg64::seeded(7);
        let tail = (0..n).filter(|_| rng.exponential() > 1.0).count() as f64 / n as f64;
        assert!((tail - (-1.0f64).exp()).abs() < 0.01, "tail={tail}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seeded(8);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>(), "astronomically unlikely identity");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Pcg64::seeded(9);
        let s = rng.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut sorted = s.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
        assert!(sorted.iter().all(|&i| i < 50));
    }

    #[test]
    fn raw_state_roundtrip_continues_exactly() {
        let mut a = Pcg64::new(42, 7);
        // Leave a Box–Muller second variate cached so the round trip must
        // carry it.
        let _ = a.normal();
        let (state, inc, cached) = a.raw_state();
        assert!(cached.is_some(), "normal() must leave a cached variate");
        let mut b = Pcg64::from_raw_state(state, inc, cached);
        assert_eq!(a.normal().to_bits(), b.normal().to_bits());
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        assert_eq!(a.uniform().to_bits(), b.uniform().to_bits());
    }

    #[test]
    fn fork_decorrelates() {
        let mut root = Pcg64::seeded(10);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
