//! Criterion-style microbenchmark harness (the offline environment has no
//! `criterion` crate). Provides warm-up, adaptive iteration counts, robust
//! statistics (median + MAD), and a black-box to defeat constant folding.
//!
//! `cargo bench` targets use [`Bencher`] with `harness = false`.

use std::hint;
use std::time::{Duration, Instant};

/// Prevent the optimizer from eliding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    hint::black_box(x)
}

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    /// Median per-iteration time.
    pub median: Duration,
    /// Median absolute deviation.
    pub mad: Duration,
    pub iters_per_sample: u64,
    pub samples: usize,
}

impl Measurement {
    pub fn ns(&self) -> f64 {
        self.median.as_secs_f64() * 1e9
    }

    /// Median absolute deviation in nanoseconds.
    pub fn mad_ns(&self) -> f64 {
        self.mad.as_secs_f64() * 1e9
    }

    pub fn report(&self) -> String {
        format!(
            "{:<48} {:>14} ± {:<12} ({} samples × {} iters)",
            self.name,
            fmt_duration(self.median),
            fmt_duration(self.mad),
            self.samples,
            self.iters_per_sample
        )
    }
}

fn fmt_duration(d: Duration) -> String {
    let ns = d.as_secs_f64() * 1e9;
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.3} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark runner with a global time budget per benchmark.
pub struct Bencher {
    /// Target measurement time per benchmark.
    pub measure_time: Duration,
    /// Warm-up time per benchmark.
    pub warmup_time: Duration,
    /// Number of samples to collect.
    pub n_samples: usize,
    results: Vec<Measurement>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    pub fn new() -> Self {
        Self {
            measure_time: Duration::from_millis(1500),
            warmup_time: Duration::from_millis(300),
            n_samples: 20,
            results: Vec::new(),
        }
    }

    /// Quick preset for expensive end-to-end benches.
    pub fn quick() -> Self {
        Self {
            measure_time: Duration::from_millis(400),
            warmup_time: Duration::from_millis(100),
            n_samples: 8,
            results: Vec::new(),
        }
    }

    /// Run `f` repeatedly and record robust timing under `name`.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> Measurement {
        // Warm-up and per-iteration estimate.
        let warm_start = Instant::now();
        let mut warm_iters: u64 = 0;
        while warm_start.elapsed() < self.warmup_time {
            f();
            warm_iters += 1;
        }
        let per_iter = warm_start.elapsed().as_secs_f64() / warm_iters.max(1) as f64;

        // Choose iterations per sample so a sample is ≥ ~50 µs but the whole
        // measurement fits the budget.
        let budget = self.measure_time.as_secs_f64();
        let per_sample_target = (budget / self.n_samples as f64).max(50e-6);
        let iters = ((per_sample_target / per_iter.max(1e-12)).ceil() as u64).max(1);

        let mut sample_times: Vec<f64> = Vec::with_capacity(self.n_samples);
        for _ in 0..self.n_samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            sample_times.push(t0.elapsed().as_secs_f64() / iters as f64);
        }
        // total_cmp: a NaN sample (clock anomaly) must not panic the bench.
        sample_times.sort_by(f64::total_cmp);
        let median = sample_times[sample_times.len() / 2];
        let mut devs: Vec<f64> = sample_times.iter().map(|t| (t - median).abs()).collect();
        devs.sort_by(f64::total_cmp);
        let mad = devs[devs.len() / 2];

        let m = Measurement {
            name: name.to_string(),
            median: Duration::from_secs_f64(median),
            mad: Duration::from_secs_f64(mad),
            iters_per_sample: iters,
            samples: sample_times.len(),
        };
        println!("{}", m.report());
        self.results.push(m.clone());
        m
    }

    /// Run a function once and report its wall time (for long end-to-end
    /// benches where repetition is impractical).
    pub fn bench_once<F: FnOnce()>(&mut self, name: &str, f: F) -> Measurement {
        let t0 = Instant::now();
        f();
        let dt = t0.elapsed();
        let m = Measurement {
            name: name.to_string(),
            median: dt,
            mad: Duration::ZERO,
            iters_per_sample: 1,
            samples: 1,
        };
        println!("{}", m.report());
        self.results.push(m.clone());
        m
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    /// Serialize every recorded measurement as the stable
    /// `BENCH_micro.json` schema — one `{name, median_ns, mad_ns, samples,
    /// iters}` object per entry — so successive PRs can track the perf
    /// trajectory. `micro_hotpath` writes this under `HFL_BENCH_JSON=1`.
    pub fn write_json(&self, path: &str) -> std::io::Result<()> {
        use crate::util::json::{Json, ObjBuilder};
        let entries: Vec<Json> = self
            .results
            .iter()
            .map(|m| {
                ObjBuilder::new()
                    .str("name", m.name.clone())
                    .num("median_ns", m.ns())
                    .num("mad_ns", m.mad_ns())
                    .num("samples", m.samples as f64)
                    .num("iters", m.iters_per_sample as f64)
                    .build()
            })
            .collect();
        let doc = ObjBuilder::new()
            .val("benchmarks", Json::Arr(entries))
            .build();
        std::fs::write(path, format!("{}\n", doc.to_string_compact()))
    }

    /// Final summary block, printed by bench mains.
    pub fn summary(&self) -> String {
        let mut s = String::from("\n== benchmark summary ==\n");
        for m in &self.results {
            s.push_str(&m.report());
            s.push('\n');
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut b = Bencher {
            measure_time: Duration::from_millis(30),
            warmup_time: Duration::from_millis(5),
            n_samples: 5,
            results: Vec::new(),
        };
        let m = b.bench("spin", || {
            let mut x = 0u64;
            for i in 0..100 {
                x = x.wrapping_add(black_box(i));
            }
            black_box(x);
        });
        assert!(m.median > Duration::ZERO);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn bench_once_records() {
        let mut b = Bencher::quick();
        let m = b.bench_once("one", || std::thread::sleep(Duration::from_millis(2)));
        assert!(m.median >= Duration::from_millis(2));
    }

    #[test]
    fn write_json_emits_the_stable_schema() {
        let mut b = Bencher::quick();
        b.bench_once("entry_a", || {});
        b.bench_once("entry_b", || {});
        let path = std::env::temp_dir().join("hfl_bench_write_json_test.json");
        let path = path.to_str().unwrap().to_string();
        b.write_json(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let json = crate::util::json::parse(&text).unwrap();
        let arr = json.get("benchmarks").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("name").unwrap().as_str(), Some("entry_a"));
        for e in arr {
            for key in ["median_ns", "mad_ns", "samples", "iters"] {
                assert!(e.get(key).unwrap().as_f64().is_some(), "missing {key}");
            }
        }
        std::fs::remove_file(&path).ok();
    }
}
