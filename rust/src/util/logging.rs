//! Minimal leveled logger to stderr (implements the `log` crate facade so
//! library modules can use `log::info!` etc. without further wiring).

use log::{Level, LevelFilter, Metadata, Record};
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

static START: once_cell::sync::Lazy<Instant> = once_cell::sync::Lazy::new(Instant::now);
static INSTALLED: AtomicBool = AtomicBool::new(false);

struct StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = START.elapsed().as_secs_f64();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{t:9.3}s {lvl} {}] {}", record.target(), record.args());
    }

    fn flush(&self) {}
}

static LOGGER: StderrLogger = StderrLogger;

/// Install the logger (idempotent). `verbose` raises the level to Debug.
pub fn init(verbose: bool) {
    if INSTALLED.swap(true, Ordering::SeqCst) {
        log::set_max_level(if verbose { LevelFilter::Debug } else { LevelFilter::Info });
        return;
    }
    let _ = log::set_logger(&LOGGER);
    log::set_max_level(if verbose { LevelFilter::Debug } else { LevelFilter::Info });
    once_cell::sync::Lazy::force(&START);
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init(false);
        super::init(true);
        log::info!("logger smoke");
    }
}
