//! Minimal leveled logger to stderr, dependency-free for the offline build
//! (no `log` facade crate). Callers use the [`crate::log_info!`] /
//! [`crate::log_debug!`] / [`crate::log_warn!`] / [`crate::log_error!`]
//! macros, which format lazily and route through [`emit`].

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Log severity, ordered from most to least severe.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
        }
    }
}

static START: OnceLock<Instant> = OnceLock::new();
/// Maximum level that is emitted (a `Level` discriminant).
static MAX_LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Install the logger (idempotent). `verbose` raises the level to Debug.
pub fn init(verbose: bool) {
    START.get_or_init(Instant::now);
    let lvl = if verbose { Level::Debug } else { Level::Info };
    MAX_LEVEL.store(lvl as u8, Ordering::SeqCst);
}

/// Whether a record at `level` would currently be emitted.
pub fn enabled(level: Level) -> bool {
    level as u8 <= MAX_LEVEL.load(Ordering::Relaxed)
}

/// Emit one record. Prefer the `log_*!` macros, which also record the
/// calling module as the target.
pub fn emit(level: Level, target: &str, args: std::fmt::Arguments) {
    if !enabled(level) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    eprintln!("[{t:9.3}s {} {target}] {args}", level.tag());
}

/// `log_info!("trained {} iters", n)` — info-level record.
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::emit(
            $crate::util::logging::Level::Info,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

/// Debug-level record (visible with `--verbose`).
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::emit(
            $crate::util::logging::Level::Debug,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

/// Warn-level record.
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::emit(
            $crate::util::logging::Level::Warn,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

/// Error-level record.
#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logging::emit(
            $crate::util::logging::Level::Error,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_idempotent_and_filters() {
        init(false);
        init(true);
        assert!(enabled(Level::Debug));
        init(false);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        crate::log_info!("logger smoke {}", 42);
    }
}
