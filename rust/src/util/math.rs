//! Special functions and numerical optimization used by the wireless model
//! and the sparsification hot path.
//!
//! * [`exp_int_e1`] — the exponential integral E₁(x), which gives the
//!   truncated channel-inversion power normalizer for Rayleigh fading:
//!   Eq. (8) of the paper with `f(γ)=e^{-γ}` is
//!   `∫_th^∞ e^{-γ}/γ dγ = E₁(th)`.
//! * [`golden_section_max`] — derivative-free 1-D maximizer for the
//!   threshold optimization of Eq. (11).
//! * [`quickselect`] / [`quantile_abs`] — O(n) order statistics for the
//!   DGC top-k threshold (no full sort on the hot path).

/// Exponential integral E₁(x) = ∫ₓ^∞ e^{-t}/t dt, x > 0.
///
/// Abramowitz & Stegun 5.1.53 (series, x ≤ 1) and 5.1.56 (rational
/// approximation, x > 1); relative error < 2e-7 over the full range, which
/// is far below the Monte-Carlo noise of the latency simulations.
pub fn exp_int_e1(x: f64) -> f64 {
    assert!(x > 0.0, "E1 requires x > 0, got {x}");
    if x <= 1.0 {
        // E1(x) = -γ - ln x + Σ_{k≥1} (-1)^{k+1} x^k / (k·k!)
        const EULER_GAMMA: f64 = 0.577_215_664_901_532_9;
        let mut sum = 0.0;
        let mut term = 1.0; // x^k / k!
        for k in 1..=30 {
            term *= x / k as f64;
            let contrib = term / k as f64;
            if k % 2 == 1 {
                sum += contrib;
            } else {
                sum -= contrib;
            }
            if contrib.abs() < 1e-17 {
                break;
            }
        }
        -EULER_GAMMA - x.ln() + sum
    } else {
        // x e^x E1(x) ≈ (x^4 + a3 x^3 + ... ) / (x^4 + b3 x^3 + ...)
        const A: [f64; 4] = [8.573_328_740_1, 18.059_016_973, 8.634_760_892_5, 0.267_773_734_3];
        const B: [f64; 4] = [9.573_322_345_4, 25.632_956_148_6, 21.099_653_082_6, 3.958_496_922_8];
        let num = ((((x + A[0]) * x + A[1]) * x + A[2]) * x) + A[3];
        let den = ((((x + B[0]) * x + B[1]) * x + B[2]) * x) + B[3];
        (num / den) / (x * x.exp())
    }
}

/// Maximize a unimodal function on [lo, hi] by golden-section search.
///
/// Returns `(argmax, max)`. `tol` is the absolute x-tolerance. The
/// threshold objective of Eq. (11) is unimodal in γ_th (rate × coverage
/// trade-off), so golden-section converges to the global maximum.
pub fn golden_section_max<F: FnMut(f64) -> f64>(
    mut f: F,
    lo: f64,
    hi: f64,
    tol: f64,
) -> (f64, f64) {
    assert!(hi > lo, "invalid bracket [{lo}, {hi}]");
    const INV_PHI: f64 = 0.618_033_988_749_894_8; // (√5 − 1)/2
    let (mut a, mut b) = (lo, hi);
    let mut c = b - INV_PHI * (b - a);
    let mut d = a + INV_PHI * (b - a);
    let mut fc = f(c);
    let mut fd = f(d);
    while (b - a).abs() > tol {
        if fc > fd {
            b = d;
            d = c;
            fd = fc;
            c = b - INV_PHI * (b - a);
            fc = f(c);
        } else {
            a = c;
            c = d;
            fc = fd;
            d = a + INV_PHI * (b - a);
            fd = f(d);
        }
    }
    let xm = 0.5 * (a + b);
    let fm = f(xm);
    if fm >= fc && fm >= fd {
        (xm, fm)
    } else if fc >= fd {
        (c, fc)
    } else {
        (d, fd)
    }
}

/// In-place quickselect: after the call, `xs[k]` holds the k-th smallest
/// element and the array is partitioned around it. Average O(n).
///
/// Uses median-of-three pivoting plus an insertion-sort base case, and a
/// deterministic fallback shuffle-free pattern — worst cases on adversarial
/// inputs do not occur for the float magnitudes we feed it.
pub fn quickselect(xs: &mut [f32], k: usize) -> f32 {
    assert!(k < xs.len(), "k={k} out of range for len={}", xs.len());
    let (mut lo, mut hi) = (0usize, xs.len() - 1);
    loop {
        if hi - lo < 16 {
            // insertion sort the small range
            for i in lo + 1..=hi {
                let mut j = i;
                while j > lo && xs[j - 1] > xs[j] {
                    xs.swap(j - 1, j);
                    j -= 1;
                }
            }
            return xs[k];
        }
        // median-of-three pivot
        let mid = lo + (hi - lo) / 2;
        if xs[lo] > xs[mid] {
            xs.swap(lo, mid);
        }
        if xs[lo] > xs[hi] {
            xs.swap(lo, hi);
        }
        if xs[mid] > xs[hi] {
            xs.swap(mid, hi);
        }
        let pivot = xs[mid];
        // Hoare partition
        let (mut i, mut j) = (lo, hi);
        loop {
            while xs[i] < pivot {
                i += 1;
            }
            while xs[j] > pivot {
                j -= 1;
            }
            if i >= j {
                break;
            }
            xs.swap(i, j);
            i += 1;
            j -= 1;
        }
        if k <= j {
            hi = j;
        } else {
            lo = j + 1;
        }
    }
}

/// Magnitude threshold `g_th` such that a fraction `phi` of `|v|` falls
/// strictly below it — i.e. keep the top `(1-phi)` fraction by magnitude
/// (Algorithm 4, line 8). Scratch buffer is caller-provided so the training
/// hot loop allocates nothing.
///
/// For large vectors (n ≥ [`QUANTILE_SAMPLE_MIN`]) the threshold is
/// estimated from a deterministic strided sample of ≥16 k elements — the
/// sampling trick of the DGC paper itself (Lin et al. §3.2 run top-k on a
/// 0.1–1% sample). This turns the dominant O(n) copy+select into O(n/stride)
/// at the cost of a small, unbiased jitter in the achieved sparsity
/// (EXPERIMENTS.md §Perf quantifies it).
pub fn quantile_abs(v: &[f32], phi: f64, scratch: &mut Vec<f32>) -> f32 {
    let m = quantile_sample_len(v.len());
    if scratch.len() < m {
        scratch.resize(m, 0.0);
    }
    quantile_abs_into(v, phi, scratch)
}

/// Number of elements the (possibly sampled) threshold estimate inspects
/// for a vector of length `n` — the scratch prefix [`quantile_abs_into`]
/// requires. Never exceeds `n`.
pub fn quantile_sample_len(n: usize) -> usize {
    if n >= QUANTILE_SAMPLE_MIN {
        let stride = (n / QUANTILE_SAMPLE_TARGET).max(1);
        n.div_ceil(stride)
    } else {
        n
    }
}

/// Slice-scratch variant of [`quantile_abs`] for arena-resident callers:
/// identical sampling, selection, and result, but the scratch is a
/// caller-provided preallocated slice of at least
/// [`quantile_sample_len`]`(v.len())` elements (a `v.len()`-long slice
/// always suffices). Performs no allocation.
pub fn quantile_abs_into(v: &[f32], phi: f64, scratch: &mut [f32]) -> f32 {
    assert!((0.0..=1.0).contains(&phi), "phi={phi} outside [0,1]");
    assert!(!v.is_empty());
    let m = quantile_sample_len(v.len());
    let scratch = &mut scratch[..m];
    if v.len() >= QUANTILE_SAMPLE_MIN {
        let stride = (v.len() / QUANTILE_SAMPLE_TARGET).max(1);
        for (dst, x) in scratch.iter_mut().zip(v.iter().step_by(stride)) {
            *dst = x.abs();
        }
    } else {
        for (dst, x) in scratch.iter_mut().zip(v) {
            *dst = x.abs();
        }
    }
    // Index of the first *kept* element when sorted ascending.
    let k = ((phi * m as f64).floor() as usize).min(m - 1);
    quickselect(scratch, k)
}

/// Vectors at least this long use sampled threshold estimation.
pub const QUANTILE_SAMPLE_MIN: usize = 1 << 16;
/// Approximate sample size for the strided estimate.
pub const QUANTILE_SAMPLE_TARGET: usize = 16_384;

/// Exact (non-sampled) variant, for callers that need the precise order
/// statistic regardless of size.
pub fn quantile_abs_exact(v: &[f32], phi: f64, scratch: &mut Vec<f32>) -> f32 {
    assert!((0.0..=1.0).contains(&phi), "phi={phi} outside [0,1]");
    assert!(!v.is_empty());
    scratch.clear();
    scratch.extend(v.iter().map(|x| x.abs()));
    let n = scratch.len();
    let k = ((phi * n as f64).floor() as usize).min(n - 1);
    quickselect(scratch, k)
}

/// Numerically stable log-sum-exp (used by test oracles for softmax loss).
pub fn log_sum_exp(xs: &[f64]) -> f64 {
    let m = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if m.is_infinite() {
        return m;
    }
    m + xs.iter().map(|x| (x - m).exp()).sum::<f64>().ln()
}

/// dB → linear power ratio.
#[inline]
pub fn db_to_linear(db: f64) -> f64 {
    10f64.powf(db / 10.0)
}

/// Linear power ratio → dB.
#[inline]
pub fn linear_to_db(x: f64) -> f64 {
    10.0 * x.log10()
}

/// dBm → Watts.
#[inline]
pub fn dbm_to_watts(dbm: f64) -> f64 {
    10f64.powf((dbm - 30.0) / 10.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    /// Reference E1 values (Abramowitz & Stegun tables / mpmath).
    #[test]
    fn e1_reference_values() {
        let cases = [
            (0.1, 1.822_923_958_4),
            (0.5, 0.559_773_594_8),
            (1.0, 0.219_383_934_4),
            (2.0, 0.048_900_510_7),
            (5.0, 0.001_148_295_6),
            (10.0, 4.156_968_9e-6),
        ];
        for (x, want) in cases {
            let got = exp_int_e1(x);
            let rel = ((got - want) / want).abs();
            assert!(rel < 2e-6, "E1({x}) = {got}, want {want} (rel {rel})");
        }
    }

    #[test]
    fn e1_continuous_at_switch_point() {
        let below = exp_int_e1(1.0 - 1e-9);
        let above = exp_int_e1(1.0 + 1e-9);
        assert!((below - above).abs() < 1e-6);
    }

    #[test]
    fn e1_matches_numerical_integral() {
        // Simpson integration of e^-t/t from x to a large cutoff.
        let numeric = |x: f64| {
            let hi = x + 60.0;
            let n = 400_000;
            let h = (hi - x) / n as f64;
            let f = |t: f64| (-t).exp() / t;
            let mut s = f(x) + f(hi);
            for i in 1..n {
                let t = x + i as f64 * h;
                s += if i % 2 == 1 { 4.0 } else { 2.0 } * f(t);
            }
            s * h / 3.0
        };
        for x in [0.3, 0.9, 1.5, 3.0] {
            let got = exp_int_e1(x);
            let want = numeric(x);
            assert!(
                ((got - want) / want).abs() < 1e-5,
                "E1({x})={got} vs integral {want}"
            );
        }
    }

    #[test]
    fn golden_section_finds_quadratic_max() {
        let (x, fx) = golden_section_max(|x| -(x - 1.7) * (x - 1.7) + 3.0, 0.0, 10.0, 1e-9);
        assert!((x - 1.7).abs() < 1e-6, "x={x}");
        assert!((fx - 3.0).abs() < 1e-10);
    }

    #[test]
    fn golden_section_handles_boundary_max() {
        // Monotone increasing — max at right edge.
        let (x, _) = golden_section_max(|x| x, 0.0, 5.0, 1e-9);
        assert!((x - 5.0).abs() < 1e-6, "x={x}");
    }

    #[test]
    fn quickselect_matches_sort() {
        let mut rng = Pcg64::seeded(11);
        for n in [1usize, 2, 5, 17, 100, 1001] {
            let orig: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let mut sorted = orig.clone();
            sorted.sort_by(f32::total_cmp);
            for k in [0, n / 3, n / 2, n - 1] {
                let mut xs = orig.clone();
                assert_eq!(quickselect(&mut xs, k), sorted[k], "n={n} k={k}");
            }
        }
    }

    #[test]
    fn quickselect_with_duplicates() {
        let mut xs = vec![2.0f32; 64];
        xs.extend(vec![1.0f32; 64]);
        assert_eq!(quickselect(&mut xs.clone(), 0), 1.0);
        assert_eq!(quickselect(&mut xs.clone(), 63), 1.0);
        assert_eq!(quickselect(&mut xs.clone(), 64), 2.0);
        assert_eq!(quickselect(&mut xs, 127), 2.0);
    }

    #[test]
    fn quantile_abs_keeps_top_fraction() {
        // |v| = 1..=100; phi=0.9 → threshold at the 91st smallest = 91.
        let v: Vec<f32> = (1..=100).map(|i| if i % 2 == 0 { i as f32 } else { -(i as f32) }).collect();
        let mut scratch = Vec::new();
        let th = quantile_abs(&v, 0.9, &mut scratch);
        let kept = v.iter().filter(|x| x.abs() >= th).count();
        assert_eq!(kept, 10, "th={th}");
    }

    #[test]
    fn quantile_abs_sampled_close_to_exact_on_large_vectors() {
        let mut rng = Pcg64::seeded(77);
        let v: Vec<f32> = (0..300_000).map(|_| rng.normal() as f32).collect();
        let mut s = Vec::new();
        let sampled = quantile_abs(&v, 0.99, &mut s);
        let exact = quantile_abs_exact(&v, 0.99, &mut s);
        // Sampled threshold keeps ~1% of coordinates, within 20% relative.
        let kept = v.iter().filter(|x| x.abs() >= sampled).count() as f64 / v.len() as f64;
        assert!((kept - 0.01).abs() < 0.002, "kept fraction {kept}");
        assert!((sampled - exact).abs() / exact < 0.05, "{sampled} vs {exact}");
    }

    #[test]
    fn quantile_abs_into_matches_vec_variant() {
        let mut rng = Pcg64::seeded(78);
        for n in [1usize, 5, 100, 70_000] {
            let v: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let mut vec_scratch = Vec::new();
            let mut slice_scratch = vec![0.0f32; n];
            for phi in [0.0, 0.5, 0.9, 1.0] {
                let a = quantile_abs(&v, phi, &mut vec_scratch);
                let b = quantile_abs_into(&v, phi, &mut slice_scratch);
                assert_eq!(a.to_bits(), b.to_bits(), "n={n} phi={phi}");
            }
            assert!(quantile_sample_len(n) <= n);
        }
    }

    #[test]
    fn quantile_abs_extremes() {
        let v = vec![3.0f32, -1.0, 2.0, -4.0];
        let mut s = Vec::new();
        // phi=0 keeps everything
        let th0 = quantile_abs(&v, 0.0, &mut s);
        assert!(v.iter().all(|x| x.abs() >= th0));
        // phi=1 keeps only the max-magnitude element
        let th1 = quantile_abs(&v, 1.0, &mut s);
        assert_eq!(v.iter().filter(|x| x.abs() >= th1).count(), 1);
    }

    #[test]
    fn log_sum_exp_stable() {
        let xs = [1000.0, 1000.0];
        let got = log_sum_exp(&xs);
        assert!((got - (1000.0 + 2f64.ln())).abs() < 1e-9);
    }

    #[test]
    fn db_conversions_roundtrip() {
        for db in [-150.0, -30.0, 0.0, 13.0] {
            assert!((linear_to_db(db_to_linear(db)) - db).abs() < 1e-9);
        }
        assert!((dbm_to_watts(30.0) - 1.0).abs() < 1e-12);
        assert!((dbm_to_watts(0.0) - 1e-3).abs() < 1e-15);
    }
}
