//! Descriptive statistics for experiment reporting: running moments,
//! mean ± standard-error (the paper's Table III format), percentiles, and
//! confidence summaries for Monte-Carlo latency estimates.

/// Online mean/variance accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Running {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn extend<I: IntoIterator<Item = f64>>(&mut self, xs: I) {
        for x in xs {
            self.push(x);
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n−1 denominator).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Standard error of the mean — the paper's `±` in Table III.
    pub fn sem(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.std() / (self.n as f64).sqrt()
        }
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// `"mean ± sem"` with the given precision, Table III style.
    pub fn fmt_mean_sem(&self, digits: usize) -> String {
        format!("{:.d$} ± {:.d$}", self.mean(), self.sem(), d = digits)
    }
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Linearly-interpolated percentile `p ∈ [0,100]` of unsorted data.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    assert!((0.0..=100.0).contains(&p));
    let mut s = xs.to_vec();
    // total_cmp: NaNs sort to the ends instead of panicking mid-sort.
    s.sort_by(f64::total_cmp);
    let rank = p / 100.0 * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        let w = rank - lo as f64;
        s[lo] * (1.0 - w) + s[hi] * w
    }
}

/// Geometric mean (speed-up summaries across sweep points).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_matches_closed_form() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut r = Running::new();
        r.extend(xs.iter().copied());
        assert_eq!(r.count(), 8);
        assert!((r.mean() - 5.0).abs() < 1e-12);
        // sample variance = 32/7
        assert!((r.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert!((r.sem() - (32.0f64 / 7.0).sqrt() / 8f64.sqrt()).abs() < 1e-12);
        assert_eq!(r.min(), 2.0);
        assert_eq!(r.max(), 9.0);
    }

    #[test]
    fn running_single_sample() {
        let mut r = Running::new();
        r.push(3.5);
        assert_eq!(r.mean(), 3.5);
        assert_eq!(r.variance(), 0.0);
        assert_eq!(r.sem(), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        assert!((percentile(&xs, 25.0) - 1.75).abs() < 1e-12);
    }

    #[test]
    fn geomean_of_constants() {
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn fmt_mean_sem_is_table3_shaped() {
        let mut r = Running::new();
        r.extend([90.0, 90.4, 90.8]);
        let s = r.fmt_mean_sem(2);
        assert!(s.contains(" ± "), "{s}");
    }
}
