//! The shared training-run specification.
//!
//! [`RunSpec`] is the single home of the ~10 scalars every training engine
//! reads — iteration budget, LR schedule, momentum/weight-decay, the H
//! averaging period, the sparsity configuration, the aggregation dispatch,
//! and the fan-out/pool wiring. [`crate::fl::TrainOptions`],
//! [`crate::coordinator::CoordinatorOptions`] and
//! [`crate::sim::MatrixOptions`] each *embed* one `RunSpec` (and `Deref`
//! to it, so `opts.iters`-style reads keep their natural spelling) and add
//! only their engine-specific knobs on top. The config fingerprints that
//! gate snapshot resume and the `hfl serve`/`hfl worker` handshake both
//! derive from [`RunSpec::put_fingerprint`], so the formerly-triplicated
//! field lists can no longer drift.

use crate::adversary::AdversaryPlan;
use crate::config::SparsityConfig;
use crate::pool::PoolHandle;
use crate::snapshot::codec::ByteWriter;
use crate::sparse::merge::{AggPolicy, AggRule};

/// The scalars shared by every training run, regardless of which engine
/// (sequential, coordinator-as-a-service, DES grid cell) executes it.
#[derive(Clone, Debug)]
pub struct RunSpec {
    /// Total iterations (global steps).
    pub iters: usize,
    /// Peak learning rate (after linear scaling).
    pub peak_lr: f64,
    /// Warm-up iterations.
    pub warmup_iters: usize,
    /// LR decay milestones as fractions of `iters`.
    pub milestones: (f64, f64),
    /// Momentum σ (both MU-side DGC correction and dense momentum).
    pub momentum: f32,
    /// Weight decay λ.
    pub weight_decay: f32,
    /// Model-averaging period H.
    pub h_period: usize,
    /// Sparsification configuration (per-link φ and β).
    pub sparsity: SparsityConfig,
    /// Aggregation dispatch: k-way sparse merge vs dense scatter
    /// (`--agg-path`, `[agg]` config). The `path`/`crossover` choice is
    /// bit-identical for every setting; the consensus `rule`
    /// (`--agg-rule`) changes the arithmetic and is therefore
    /// fingerprinted (see [`crate::sparse::merge`]).
    pub agg: AggPolicy,
    /// Byzantine fault-injection plan (`--adversary-*`, `[adversary]`):
    /// which MUs attack, and how, per round. Disabled by default; when
    /// disabled every engine path is byte-identical to the honest run.
    pub adversary: AdversaryPlan,
    /// Intra-round fan-out width: worker threads executing the independent
    /// per-cluster compute+uplink blocks of each round. `1` (default) runs
    /// sequentially; `0` uses one thread per available core. Results are
    /// bit-identical for every value.
    pub inner_threads: usize,
    /// Persistent worker pool to lease the fan-out lanes from; `None`
    /// (default) uses the process-wide shared pool
    /// ([`crate::pool::global_handle`]). Bit-identical either way.
    pub pool: Option<PoolHandle>,
}

impl Default for RunSpec {
    fn default() -> Self {
        Self {
            iters: 100,
            peak_lr: 0.1,
            warmup_iters: 0,
            milestones: (0.5, 0.75),
            momentum: 0.9,
            weight_decay: 0.0,
            h_period: 2,
            sparsity: SparsityConfig::dense(),
            agg: AggPolicy::default(),
            adversary: AdversaryPlan::default(),
            inner_threads: 1,
            pool: None,
        }
    }
}

impl RunSpec {
    /// A default spec — the starting point for the builder methods below.
    pub fn new() -> Self {
        Self::default()
    }

    /// Set the iteration budget.
    pub fn iters(mut self, iters: usize) -> Self {
        self.iters = iters;
        self
    }

    /// Set the peak learning rate.
    pub fn peak_lr(mut self, lr: f64) -> Self {
        self.peak_lr = lr;
        self
    }

    /// Set the warm-up iteration count.
    pub fn warmup(mut self, iters: usize) -> Self {
        self.warmup_iters = iters;
        self
    }

    /// Set the LR decay milestones (fractions of `iters`).
    pub fn milestones(mut self, a: f64, b: f64) -> Self {
        self.milestones = (a, b);
        self
    }

    /// Set the momentum σ.
    pub fn momentum(mut self, m: f32) -> Self {
        self.momentum = m;
        self
    }

    /// Set the weight decay λ.
    pub fn weight_decay(mut self, wd: f32) -> Self {
        self.weight_decay = wd;
        self
    }

    /// Set the model-averaging period H.
    pub fn h_period(mut self, h: usize) -> Self {
        self.h_period = h;
        self
    }

    /// Set the sparsification configuration.
    pub fn sparsity(mut self, s: SparsityConfig) -> Self {
        self.sparsity = s;
        self
    }

    /// Set the aggregation dispatch policy.
    pub fn agg(mut self, agg: AggPolicy) -> Self {
        self.agg = agg;
        self
    }

    /// Set the Byzantine fault-injection plan.
    pub fn adversary(mut self, plan: AdversaryPlan) -> Self {
        self.adversary = plan;
        self
    }

    /// Set the intra-round fan-out width.
    pub fn inner_threads(mut self, n: usize) -> Self {
        self.inner_threads = n;
        self
    }

    /// Set the worker pool handle to lease fan-out lanes from.
    pub fn pool(mut self, pool: Option<PoolHandle>) -> Self {
        self.pool = pool;
        self
    }

    /// Fold every *bit-relevant* scalar of this spec into a fingerprint
    /// stream: the iteration budget, LR schedule, momentum/weight-decay,
    /// H period, the full sparsity configuration, the consensus rule, and
    /// the adversary plan. The agg `path`/`crossover`, `inner_threads`
    /// and `pool` are deliberately excluded — they are bit-irrelevant by
    /// the determinism contract, so snapshots may resume (and
    /// serve/worker sessions may pair) across different values; the agg
    /// `rule` and the adversary plan change the arithmetic and *are*
    /// included. Both the snapshot config fingerprints and
    /// [`crate::net::NetScenario::fingerprint`] build on this single
    /// definition.
    pub fn put_fingerprint(&self, w: &mut ByteWriter) {
        w.put_usize(self.iters);
        w.put_usize(self.h_period);
        w.put_usize(self.warmup_iters);
        w.put_f64(self.peak_lr);
        w.put_f64(self.milestones.0);
        w.put_f64(self.milestones.1);
        w.put_f32(self.momentum);
        w.put_f32(self.weight_decay);
        let s = &self.sparsity;
        w.put_bool(s.enabled);
        w.put_f64(s.phi_mu_ul);
        w.put_f64(s.phi_sbs_dl);
        w.put_f64(s.phi_sbs_ul);
        w.put_f64(s.phi_mbs_dl);
        w.put_f64(s.beta_m);
        w.put_f64(s.beta_s);
        match self.agg.rule {
            AggRule::Mean => w.put_u8(0),
            AggRule::TrimmedMean(k) => {
                w.put_u8(1);
                w.put_usize(k);
            }
            AggRule::CoordMedian => w.put_u8(2),
        }
        let a = &self.adversary;
        w.put_bool(a.enabled);
        w.put_u64(a.seed);
        w.put_f64(a.fraction);
        w.put_f32(a.scale);
        w.put_f32(a.garbage_std);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_sets_every_field() {
        let s = RunSpec::new()
            .iters(7)
            .peak_lr(0.25)
            .warmup(3)
            .milestones(0.4, 0.9)
            .momentum(0.8)
            .weight_decay(0.01)
            .h_period(5)
            .inner_threads(4);
        assert_eq!(s.iters, 7);
        assert_eq!(s.peak_lr, 0.25);
        assert_eq!(s.warmup_iters, 3);
        assert_eq!(s.milestones, (0.4, 0.9));
        assert_eq!(s.momentum, 0.8);
        assert_eq!(s.weight_decay, 0.01);
        assert_eq!(s.h_period, 5);
        assert_eq!(s.inner_threads, 4);
    }

    #[test]
    fn fingerprint_covers_bit_relevant_scalars_only() {
        let bytes = |s: &RunSpec| {
            let mut w = ByteWriter::new();
            s.put_fingerprint(&mut w);
            w.into_bytes()
        };
        let base = RunSpec::new();
        let b0 = bytes(&base);
        // Every bit-relevant knob moves the stream…
        for other in [
            base.clone().iters(101),
            base.clone().peak_lr(0.2),
            base.clone().warmup(1),
            base.clone().milestones(0.5, 0.8),
            base.clone().momentum(0.5),
            base.clone().weight_decay(0.1),
            base.clone().h_period(3),
            base.clone().sparsity(SparsityConfig::default()),
        ] {
            assert_ne!(b0, bytes(&other));
        }
        // …and the thread-shape/dispatch knobs deliberately do not.
        assert_eq!(b0, bytes(&base.clone().inner_threads(8)));
        let mut agg = base.clone();
        agg.agg.path = crate::sparse::merge::AggPath::Dense;
        assert_eq!(b0, bytes(&agg));
        // The consensus *rule* changes the arithmetic — it must move the
        // stream (unlike the path, which is bit-irrelevant by contract).
        let mut rule = base.clone();
        rule.agg.rule = AggRule::TrimmedMean(1);
        assert_ne!(b0, bytes(&rule));
        let mut rule2 = base.clone();
        rule2.agg.rule = AggRule::TrimmedMean(2);
        assert_ne!(bytes(&rule), bytes(&rule2));
        // So does enabling (or re-seeding) the adversary plan.
        let mut adv = base.clone();
        adv.adversary.enabled = true;
        assert_ne!(b0, bytes(&adv));
        let mut adv2 = adv.clone();
        adv2.adversary.seed ^= 1;
        assert_ne!(bytes(&adv), bytes(&adv2));
    }
}
