//! `hfl` — command-line launcher for the hierarchical federated learning
//! system.
//!
//! ```text
//! hfl config    [--preset paper|smoke] [--file cfg.toml]      print active parameters
//! hfl topology  [--clusters N] [--mus N] [--seed S]           layout + reuse report
//! hfl latency   [--fig 3|4|5a|5b|all] [--out results/]        regenerate Fig. 3–5 data
//! hfl train     [--algo fl|hfl|sparse-fl|sparse-hfl] [--model mlp|cnn]
//!               [--iters N] [--h N] [--clusters N] [--mus N]
//!               [--inner-threads N] [--pool-threads N]
//!               [--agg-path auto|sparse|dense]
//!               [--agg-rule mean|trimmed-mean|coord-median] [--agg-trim K]
//!               [--adversary] [--adversary-frac F] [--adversary-seed S]
//!               [--adversary-scale X] [--adversary-garbage-std G]
//!               [--checkpoint-every N] [--checkpoint PATH] [--resume PATH]
//!               [--coordinated]                                train on the AOT model
//! hfl table3    [--full]                                       Fig. 6 / Table III study
//! hfl matrix    [--quick|--full|--adversarial] [--threads N] [--inner-threads N]
//!               [--pool-threads N] [--iters N] [--dim N] [--phi F]
//!               [--agg-path auto|sparse|dense]
//!               [--agg-rule mean|trimmed-mean|coord-median] [--agg-trim K]
//!               [--adversary…] [--churn…  same flags as des]
//!               [--checkpoint-every N] [--checkpoint PATH] [--resume PATH]
//!               [--out results/] [--write-golden F] [--check-golden F]
//!                                                              scenario-matrix sweep
//! hfl des       [--quick|--full] [--threads N] [--inner-threads N]
//!               [--pool-threads N] [--iters N] [--dim N] [--phi F]
//!               [--mus N] [--cells N]
//!               [--agg-path auto|sparse|dense]
//!               [--agg-rule mean|trimmed-mean|coord-median] [--agg-trim K]
//!               [--adversary] [--adversary-frac F] [--adversary-seed S]
//!               [--adversary-scale X] [--adversary-garbage-std G]
//!               [--churn] [--churn-drop P] [--churn-rejoin P]
//!               [--churn-energy E] [--churn-seed S]
//!               [--compute-mean S] [--compute-het X]
//!               [--checkpoint-every N] [--checkpoint PATH] [--resume PATH]
//!               [--out results/] [--write-golden F] [--check-golden F]
//!                                  discrete-event HCN simulation grid
//!                                  (mobility × straggler × deadline axes;
//!                                  --mus/--cells switch to scale mode: ONE
//!                                  static wait-for-all scenario at that
//!                                  size, `_` separators allowed:
//!                                  --mus 1_000_000)
//! hfl serve     [--listen A] [--standalone] [--metrics-addr A]
//!               [--session-log P] [--dim N] [--iters N] [--phi F]
//!               [--clusters N] [--mus N] [--h N] [--seed S]
//!               [--agg-path auto|sparse|dense]
//!               [--agg-rule mean|trimmed-mean|coord-median] [--agg-trim K]
//!               [--adversary…  same Byzantine-plan flags as train]
//!               [--io-timeout-ms N] [--rejoin-deadline-ms N]
//!               [--fault-policy wait-all|deadline-skip|quorum] [--fault-quorum K]
//!               [--chaos] [--chaos-seed S] [--chaos-drop P] [--chaos-delay P]
//!               [--chaos-delay-ms N] [--chaos-dup P] [--chaos-truncate P]
//!               [--chaos-corrupt P] [--chaos-kill-cluster C] [--chaos-kill-after N]
//!               [--out results/] [--write-golden F] [--check-golden F]
//!                                  MBS service: accept one TCP worker per
//!                                  cluster (or run all cells in-process
//!                                  with --standalone) and train
//! hfl worker    [--connect A] [--cluster C] [--dim N] [--iters N]
//!               [--phi F] [--clusters N] [--mus N] [--h N] [--seed S]
//!               [--agg-path auto|sparse|dense]
//!               [--agg-rule mean|trimmed-mean|coord-median] [--agg-trim K]
//!               [--adversary…  same Byzantine-plan flags as serve]
//!               [--io-timeout-ms N] [--rejoin N] [--rejoining]
//!               [--chaos…  same fault-plan flags as serve]
//!                                  one SBS+MUs cell against a serving MBS
//! hfl replay    --session-log P [--out results/]
//!               [--write-golden F] [--check-golden F]
//!                                  rebuild a run bit-exactly from its
//!                                  session log (no training)
//! ```
//!
//! `hfl serve` / `hfl worker` split the coordinator across processes: the
//! SBS↔MBS hops travel as framed `SparseWire` messages over TCP
//! (`hfl::net`), and both sides exchange a scenario fingerprint at
//! handshake so mismatched configs are refused before training starts.
//! The scenario flags (`--dim --iters --phi --clusters --mus --h --seed`)
//! must therefore match across all processes of one session. Results are
//! bit-identical to the in-process run — the CI `multiprocess` job diffs
//! the golden traces, then replays the session log and diffs again.
//!
//! `--pool-threads N` builds a dedicated persistent worker pool with `N`
//! execution lanes for the whole command (`0`/default: the lazily created
//! process-wide shared pool); every fan-out — the cross-cell grid and the
//! nested per-cluster/per-MU lanes — leases from it. Results are
//! bit-identical for every value (see `hfl::pool`).
//!
//! `--agg-path` picks the SBS/MBS aggregation implementation — k-way
//! sparse merge, dense scatter, or the measured-density `auto` default
//! (`[agg]` config section) — also bit-identical for every value (see
//! `hfl::sparse::merge`). `--phi F` pins the grid's sparsity axis to a
//! single φ cell (the CI determinism job uses it for the φ=0.99
//! sparse-vs-dense diff).
//!
//! `--agg-rule` picks the consensus rule on the merged coordinates —
//! `mean` (the weighted fold; default), `trimmed-mean` with `--agg-trim K`
//! extremes dropped per side, or `coord-median` — and, unlike the path,
//! changes the arithmetic, so it is part of the snapshot/handshake
//! fingerprint. The `--adversary-*` flags arm a seeded Byzantine plan
//! (`hfl::adversary`, `[adversary]` config section): a deterministic
//! fraction of MUs per round sends sign-flipped, amplified, garbage or
//! stale-replay uplinks, drawn from `Pcg64` streams keyed
//! `(seed, mu, round)` — same seed ⇒ bit-identical attack at any thread
//! count. `--churn-*` (DES cells only, `[churn]` config section) adds
//! seeded client churn: MUs drop, rejoin and exhaust a per-MU energy
//! budget; skipped (mu, round) pairs land in the golden trace's skip
//! digest. See README §Robust aggregation.
//!
//! The `--chaos-*` flags arm a seeded deterministic fault plan
//! (`hfl::net::chaos`, `[chaos]` config section) on serve and worker
//! transports: frames are dropped/delayed/duplicated/truncated/corrupted
//! from `Pcg64` streams keyed by the chaos seed, and
//! `--chaos-kill-cluster C --chaos-kill-after N` kills one endpoint at a
//! planned operation index. Same seed ⇒ bit-identical run (golden-diffable).
//! `--fault-policy`/`--fault-quorum` pick how the MBS degrades when a
//! cluster dies (skip + reweight over survivors vs abort);
//! `--rejoin-deadline-ms` opens the rejoin lane, which catches a
//! relaunched `hfl worker --rejoining --cluster C` up bit-exactly from the
//! per-round recovery point. `--io-timeout-ms` bounds every socket
//! read/write so a hung peer is a named error, not a wedge.
//!
//! `--checkpoint-every N` enables checkpoint/resume (`hfl::snapshot`,
//! `[checkpoint]` config section): `hfl train` snapshots full engine state
//! every N rounds, while the grid commands (`matrix`, `des`) append each
//! finished cell to a run log so a killed sweep restarts at the first
//! unfinished cell. `--resume PATH` continues from a snapshot / run log —
//! bit-identically to the uninterrupted run, at any thread count.
//! `--checkpoint PATH` overrides the default `<dir>/<subcommand>` target.

use anyhow::{bail, Context, Result};
use hfl::cli::Args;
use hfl::config::Config;
use hfl::coordinator::{run_coordinated, ComputeService, CoordinatorOptions};
use hfl::data::SyntheticSpec;
use hfl::fl::{run_hierarchical_checkpointed, TrainOptions};
use hfl::net::{
    accept_workers_timeout, handshake_worker, replay_session, run_cell, run_chaos_service,
    run_coordinated_service, run_mbs_faulty, ChaosTransport, ClusterLink, FaultContext,
    FaultCounters, LiveMetrics, MetricsServer, NetScenario, SessionLog, TcpTransport, Transport,
    WireMsg,
};
use hfl::runtime::{ModelOracle, Runtime};
use hfl::sim::experiments::{self, Scale};
use hfl::sim::{fig3, fig4, fig5a, fig5b};
use hfl::sim::{result, run_matrix_checkpointed, EngineSelect, MatrixOptions, ScenarioSpec};
use hfl::snapshot::CheckpointSpec;
use hfl::spec::RunSpec;
use hfl::topology::NetworkTopology;
use hfl::util::logging;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    logging::init(args.flag("verbose"));
    let cfg = load_config(&args)?;
    match args.subcommand.as_deref() {
        Some("config") => {
            print!("{}", cfg.render_table());
            args.finish()
        }
        Some("topology") => cmd_topology(&args, &cfg),
        Some("latency") => cmd_latency(&args, &cfg),
        Some("train") => cmd_train(&args, &cfg),
        Some("table3") => cmd_table3(&args, &cfg),
        Some("matrix") => cmd_matrix(&args, &cfg),
        Some("des") => cmd_des(&args, &cfg),
        Some("serve") => cmd_serve(&args, &cfg),
        Some("worker") => cmd_worker(&args, &cfg),
        Some("replay") => cmd_replay(&args, &cfg),
        Some(other) => {
            bail!(
                "unknown subcommand `{other}` (try: config, topology, latency, train, table3, matrix, des, serve, worker, replay)"
            )
        }
        None => {
            eprintln!(
                "usage: hfl <config|topology|latency|train|table3|matrix|des|serve|worker|replay> [options]\n\
                 see rust/src/main.rs docs or README.md"
            );
            Ok(())
        }
    }
}

/// Shared `--checkpoint-every N` / `--checkpoint PATH` / `--resume PATH`
/// parsing. `default_file` is the subcommand's snapshot (or run-log) file
/// name under the `[checkpoint] dir` directory. Returns the periodic spec
/// (None when checkpointing is off) and the resume source, if any.
fn checkpoint_from_args(
    args: &Args,
    cfg: &Config,
    default_file: &str,
) -> Result<(Option<CheckpointSpec>, Option<PathBuf>)> {
    let every = args.get_parsed_or("checkpoint-every", cfg.checkpoint.every)?;
    let path = args
        .get("checkpoint")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from(&cfg.checkpoint.dir).join(default_file));
    let resume = args.get("resume").map(PathBuf::from);
    Ok(((every > 0).then(|| CheckpointSpec::new(every, path)), resume))
}

fn load_config(args: &Args) -> Result<Config> {
    let mut cfg = match args.get_or("preset", "paper").as_str() {
        "paper" => Config::paper_table2(),
        "smoke" => Config::smoke(),
        other => bail!("unknown preset `{other}`"),
    };
    if let Some(path) = args.get("file") {
        cfg = cfg.overlay_file(path)?;
    }
    // Common CLI overrides.
    if let Some(m) = args.get_parsed::<usize>("subcarriers")? {
        cfg.radio.subcarriers = m;
    }
    if let Some(a) = args.get_parsed::<f64>("alpha")? {
        cfg.radio.pathloss_exp = a;
    }
    if let Some(n) = hfl::cli::count_from_args(args, "clusters")? {
        cfg.topology.n_clusters = n;
    }
    if let Some(m) = hfl::cli::count_from_args(args, "mus")? {
        cfg.topology.mus_per_cluster = m;
    }
    if let Some(h) = args.get_parsed::<usize>("h")? {
        cfg.training.h_period = h;
    }
    if let Some(s) = args.get_parsed::<u64>("seed")? {
        cfg.training.seed = s;
        cfg.topology.placement_seed = s;
    }
    if args.flag("dense") {
        cfg.sparsity.enabled = false;
    }
    cfg.validate()?;
    Ok(cfg)
}

fn cmd_topology(args: &Args, cfg: &Config) -> Result<()> {
    let topo = NetworkTopology::generate(&cfg.topology);
    println!("{}", topo.ascii_map(72, 36));
    println!();
    println!(
        "clusters: {}   colors: {}   sub-carriers/cluster: {}",
        topo.n_clusters(),
        topo.layout.n_colors,
        topo.layout.subcarriers_per_cluster(cfg.radio.subcarriers)
    );
    println!(
        "min co-channel distance: {:.1} m (guard {:.1} m)",
        topo.layout.min_cochannel_distance(),
        topo.layout.d_th
    );
    for c in 0..topo.n_clusters() {
        let d = topo.sbs_distances(c);
        println!(
            "  cluster {c}: color {}  {} MUs  d(SBS) {:.0}–{:.0} m",
            topo.layout.colors[c],
            d.len(),
            d.iter().cloned().fold(f64::INFINITY, f64::min),
            d.iter().cloned().fold(0.0, f64::max),
        );
    }
    args.finish()
}

fn cmd_latency(args: &Args, cfg: &Config) -> Result<()> {
    let which = args.get_or("fig", "all");
    let out_dir = args.get_or("out", "results");
    let mus: Vec<usize> = vec![2, 4, 6, 8, 10, 14, 20];
    let alphas: Vec<f64> = (0..=10).map(|i| 2.0 + 0.2 * i as f64).collect();
    let figs: Vec<(&str, hfl::sim::FigureSeries)> = match which.as_str() {
        "3" => vec![("fig3", fig3(cfg, &mus))],
        "4" => vec![("fig4", fig4(cfg, &alphas))],
        "5a" => vec![("fig5a", fig5a(cfg, &mus))],
        "5b" => vec![("fig5b", fig5b(cfg, &mus))],
        "all" => vec![
            ("fig3", fig3(cfg, &mus)),
            ("fig4", fig4(cfg, &alphas)),
            ("fig5a", fig5a(cfg, &mus)),
            ("fig5b", fig5b(cfg, &mus)),
        ],
        other => bail!("unknown figure `{other}`"),
    };
    for (name, f) in figs {
        println!("{}", f.render());
        let path = format!("{out_dir}/{name}.csv");
        f.to_csv().save(&path)?;
        println!("wrote {path}\n");
    }
    args.finish()
}

fn cmd_train(args: &Args, cfg: &Config) -> Result<()> {
    let algo = args.get_or("algo", "sparse-hfl");
    let model = args.get_or("model", cfg.training.model.as_str());
    let iters = hfl::cli::count_from_args(args, "iters")?.unwrap_or(120);
    let coordinated = args.flag("coordinated");
    let train_samples = args.get_parsed_or("train-samples", cfg.training.train_samples)?;
    let test_samples = args.get_parsed_or("test-samples", cfg.training.test_samples)?;
    // Dedicated persistent pool for this command, if requested; must stay
    // alive until training finishes (dropping it joins the workers).
    let dedicated_pool = hfl::cli::pool_from_args(args, cfg.pool.threads)?;
    let pool = dedicated_pool.as_ref().map(|p| p.handle());
    let (ckpt, resume) = checkpoint_from_args(args, cfg, "train.snap")?;

    let (n_clusters, sparse) = match algo.as_str() {
        "fl" => (1, false),
        "sparse-fl" => (1, true),
        "hfl" => (cfg.topology.n_clusters, false),
        "sparse-hfl" => (cfg.topology.n_clusters, true),
        other => bail!("unknown algo `{other}`"),
    };
    let workers = cfg.topology.total_mus();
    // The shared flags (--iters, --inner-threads, --agg-path) land on the
    // spec through the one decode path every subcommand uses.
    let spec = hfl::cli::spec_from_args(
        args,
        cfg.agg,
        &cfg.adversary,
        RunSpec::new()
            .iters(iters)
            .peak_lr(cfg.training.scaled_lr(workers))
            .warmup(iters / 10)
            .milestones(cfg.training.decay_milestones.0, cfg.training.decay_milestones.1)
            .momentum(cfg.training.momentum as f32)
            .weight_decay(cfg.training.weight_decay as f32)
            .h_period(cfg.training.h_period)
            .sparsity(if sparse {
                cfg.sparsity.clone()
            } else {
                hfl::config::SparsityConfig::dense()
            })
            .pool(pool),
    )?;
    args.finish()?;
    if coordinated && (ckpt.is_some() || resume.is_some()) {
        bail!("--checkpoint-every/--resume are not supported with --coordinated");
    }
    let opts = TrainOptions {
        spec,
        n_clusters,
        eval_every: (iters / 8).max(1),
    };
    let spec = SyntheticSpec {
        n_train: train_samples,
        n_test: test_samples,
        noise: 0.6,
        seed: cfg.training.seed,
        ..SyntheticSpec::default()
    };
    hfl::log_info!(
        "training {algo} model={model} workers={workers} clusters={n_clusters} iters={iters} coordinated={coordinated}"
    );

    if coordinated {
        let mut copts = CoordinatorOptions::from(&opts);
        copts.eval_every_syncs = 2;
        let model2 = model.clone();
        let run = run_coordinated(
            move || {
                let rt = Runtime::load_default().expect("load artifacts");
                ModelOracle::new(&rt, &model2, workers, &spec).expect("build oracle")
            },
            &copts,
        )?;
        for (it, m) in &run.sync_evals {
            println!(
                "iter {it:>5}  acc {:>6.2}%  loss {:.4}",
                m.accuracy * 100.0,
                m.loss
            );
        }
        println!(
            "final: acc {:.2}%  loss {:.4}",
            run.final_eval.accuracy * 100.0,
            run.final_eval.loss
        );
        println!(
            "bits: mu_ul {:.3e}  sbs_dl {:.3e}  sbs_ul {:.3e}  mbs_dl {:.3e}",
            run.metrics.total_bits(hfl::coordinator::LinkKind::MuUl),
            run.metrics.total_bits(hfl::coordinator::LinkKind::SbsDl),
            run.metrics.total_bits(hfl::coordinator::LinkKind::SbsUl),
            run.metrics.total_bits(hfl::coordinator::LinkKind::MbsDl),
        );
    } else {
        let rt = Runtime::load_default()?;
        let mut oracle = ModelOracle::new(&rt, &model, workers, &spec)?;
        let log = run_hierarchical_checkpointed(
            &mut oracle,
            &opts,
            ckpt.as_ref(),
            resume.as_deref(),
        )?;
        for (it, m) in &log.evals {
            println!(
                "iter {it:>5}  acc {:>6.2}%  loss {:.4}",
                m.accuracy * 100.0,
                m.loss
            );
        }
        println!("total bits: {:.3e}", log.bits.total());
    }
    Ok(())
}

fn cmd_table3(args: &Args, cfg: &Config) -> Result<()> {
    let scale = if args.flag("full") {
        Scale::full()
    } else {
        Scale::quick()
    };
    let model = args.get_or("model", "mlp");
    args.finish()?;
    let scale = Scale { model, ..scale };
    let mut factory = experiments::pjrt_oracle_factory(cfg, &scale);
    let results = experiments::run_table3(cfg, &scale, |sc, seed| factory(sc, seed))?;
    println!("{}", experiments::render_table3(&results));
    for r in &results {
        println!("-- {} accuracy curve (iter, %):", r.name);
        for (it, acc) in &r.curve {
            println!("   {it:>5} {acc:>6.2}");
        }
    }
    Ok(())
}

fn cmd_matrix(args: &Args, cfg: &Config) -> Result<()> {
    let _quick = args.flag("quick"); // the default grid; flag kept for symmetry
    let full = args.flag("full");
    // The robustness demonstration grid: 3 aggregation rules × honest/20%
    // attackers × churn off/on (`ScenarioSpec::adversarial`, trim k = 1).
    let adversarial = args.flag("adversarial");
    let threads = args.get_parsed_or("threads", 0usize)?;
    let dim = hfl::cli::count_from_args(args, "dim")?;
    let golden = hfl::cli::GoldenArgs::from_args(args);
    let dedicated_pool = hfl::cli::pool_from_args(args, cfg.pool.threads)?;
    let phi_pin = hfl::cli::phi_from_args(args)?;
    let rspec =
        hfl::cli::spec_from_args(args, cfg.agg, &cfg.adversary, MatrixOptions::default().spec)?
            .pool(dedicated_pool.as_ref().map(|p| p.handle()));
    let churn = hfl::cli::churn_from_args(args, &cfg.churn)?;
    let (ckpt, resume) = checkpoint_from_args(args, cfg, "matrix_runlog.jsonl")?;
    args.finish()?;

    let mut spec = if adversarial {
        ScenarioSpec::adversarial(1)
    } else if full {
        ScenarioSpec::full_with(&cfg.des)
    } else {
        ScenarioSpec::quick_with(&cfg.des)
    };
    if let Some(phi) = phi_pin {
        spec.phis = vec![Some(phi)];
    }
    let mut opts = MatrixOptions {
        spec: rspec,
        threads,
        base_seed: cfg.training.seed,
        compute_mean_s: cfg.des.compute_mean_s,
        compute_het: cfg.des.compute_het,
        churn,
        ..Default::default()
    };
    if let Some(d) = dim {
        opts.dim = d;
    }

    let t0 = std::time::Instant::now();
    // A cell-granular run log: `--resume PATH` continues a killed sweep
    // from its log; `--checkpoint-every N` (any N > 0) writes one.
    let runlog = resume.or_else(|| ckpt.map(|s| s.path));
    let results = run_matrix_checkpointed(cfg, &spec, &opts, runlog.as_deref())?;
    println!(
        "scenario matrix — {} scenarios, threads={} ({}), {:.2}s wall",
        results.len(),
        opts.threads,
        if opts.threads == 0 { "auto" } else { "fixed" },
        t0.elapsed().as_secs_f64()
    );
    for r in &results {
        println!("{}", r.table_row());
    }
    golden.emit(&results, "matrix")
}

fn cmd_des(args: &Args, cfg: &Config) -> Result<()> {
    let _quick = args.flag("quick"); // the default grid; flag kept for symmetry
    let full = args.flag("full");
    let threads = args.get_parsed_or("threads", 0usize)?;
    let dim = hfl::cli::count_from_args(args, "dim")?;
    // Scale-axis pins: `--mus N` / `--cells N` switch to scale mode — the
    // grid collapses to ONE static wait-for-all scenario at the requested
    // size, the million-MU entry point (underscore separators allowed).
    let mus_pin = hfl::cli::count_from_args(args, "mus")?;
    let cells_pin = hfl::cli::count_from_args(args, "cells")?;
    let compute_mean = args.get_parsed_or("compute-mean", cfg.des.compute_mean_s)?;
    let compute_het = args.get_parsed_or("compute-het", cfg.des.compute_het)?;
    let golden = hfl::cli::GoldenArgs::from_args(args);
    let dedicated_pool = hfl::cli::pool_from_args(args, cfg.pool.threads)?;
    let phi_pin = hfl::cli::phi_from_args(args)?;
    let rspec =
        hfl::cli::spec_from_args(args, cfg.agg, &cfg.adversary, MatrixOptions::default().spec)?
            .pool(dedicated_pool.as_ref().map(|p| p.handle()));
    let churn = hfl::cli::churn_from_args(args, &cfg.churn)?;
    let (ckpt, resume) = checkpoint_from_args(args, cfg, "des_runlog.jsonl")?;
    args.finish()?;

    let mut spec = if full {
        ScenarioSpec::full_des(&cfg.des)
    } else {
        ScenarioSpec::quick_des(&cfg.des)
    };
    if mus_pin.is_some() || cells_pin.is_some() {
        // Scale mode: a pinned axis collapses the whole grid to ONE
        // scenario — the canonical static wait-for-all configuration at
        // the requested size. Crossing a million-MU cell with the full
        // mobility × straggler × φ grid would multiply a laptop-scale run
        // into an OOM; anyone who wants a crossed axis at scale can pin
        // it explicitly (`--phi`) or edit the spec in code.
        let m = mus_pin.unwrap_or(4);
        if m == 0 {
            bail!("--mus must be > 0");
        }
        let c = cells_pin.unwrap_or(1);
        if c == 0 {
            bail!("--cells must be > 0");
        }
        spec = ScenarioSpec {
            cells: vec![c],
            mus_per_cell: vec![m],
            skews: vec![1.0],
            phis: vec![Some(phi_pin.unwrap_or(0.9))],
            h_periods: vec![2],
            profiles: vec![hfl::sim::ChannelProfile::nominal()],
            mobilities: vec![hfl::des::MobilityProfile::Static],
            stragglers: vec![hfl::des::StragglerPolicy::WaitForAll],
            // Default axes: the CLI-level `--agg-rule`/`--adversary-*`/
            // `--churn-*` values (already on `rspec`/`churn`) govern the
            // single scale cell instead of multiplying it.
            agg_rules: vec![hfl::sparse::AggRule::Mean],
            adversary_fracs: vec![0.0],
            churn_drops: vec![0.0],
        };
    } else if let Some(phi) = phi_pin {
        spec.phis = vec![Some(phi)];
    }
    let mut opts = MatrixOptions {
        spec: rspec,
        threads,
        base_seed: cfg.training.seed,
        engine: EngineSelect::Des,
        compute_mean_s: compute_mean,
        compute_het,
        churn,
        ..Default::default()
    };
    if let Some(d) = dim {
        opts.dim = d;
    }

    let t0 = std::time::Instant::now();
    let runlog = resume.or_else(|| ckpt.map(|s| s.path));
    let results = run_matrix_checkpointed(cfg, &spec, &opts, runlog.as_deref())?;
    println!(
        "discrete-event grid — {} scenarios, threads={} ({}), {:.2}s wall",
        results.len(),
        opts.threads,
        if opts.threads == 0 { "auto" } else { "fixed" },
        t0.elapsed().as_secs_f64()
    );
    for r in &results {
        let tl = r
            .trace
            .timeline
            .map(|t| format!("  timeline {:016x} ({} events)", t.digest, t.n_events))
            .unwrap_or_default();
        println!("{}{tl}", r.table_row());
    }
    golden.emit(&results, "des")
}

/// `hfl serve` — run the MBS side of a coordinator-as-a-service session.
///
/// Default mode binds `--listen` (or `[net] listen_addr`) and waits for
/// one `hfl worker` per cluster; `--standalone` instead runs every cell
/// in-process over loopback transports (same framed codec, no sockets).
/// Both modes share the session log, the live `/metrics` endpoint and
/// the grid-style result/golden outputs, and both are bit-identical to
/// the in-process coordinator.
fn cmd_serve(args: &Args, cfg: &Config) -> Result<()> {
    let mut scenario = NetScenario::from_cli(args, cfg)?;
    scenario.copts.agg = hfl::cli::agg_from_args(args, cfg.agg)?;
    // Set before `fingerprint()`: the adversary plan changes the
    // arithmetic, so serve and worker must agree on it at handshake.
    scenario.copts.adversary = hfl::cli::adversary_from_args(args, &cfg.adversary)?;
    let listen = args.get_or("listen", &cfg.net.listen_addr);
    let standalone = args.flag("standalone");
    let metrics_addr = args.get_or("metrics-addr", &cfg.net.metrics_addr);
    let session_log = args.get_or("session-log", &cfg.net.session_log);
    let golden = hfl::cli::GoldenArgs::from_args(args);
    let chaos = hfl::cli::chaos_from_args(args, &cfg.chaos)?;
    let policy = hfl::cli::fault_policy_from_args(args)?;
    // CLI-boundary check of the same invariant the MBS re-validates at
    // startup: a quorum above the cluster count can never be met.
    policy.validate(scenario.n_clusters)?;
    let rejoin_deadline = Duration::from_millis(args.get_parsed_or("rejoin-deadline-ms", 0u64)?);
    let io_timeout_ms = args.get_parsed_or("io-timeout-ms", cfg.net.io_timeout_ms)?;
    let io_timeout = (io_timeout_ms > 0).then(|| Duration::from_millis(io_timeout_ms));
    args.finish()?;

    let fingerprint = scenario.fingerprint();
    println!(
        "serving scenario {} (fingerprint {fingerprint:016x}, {} clusters × {} MUs)",
        scenario.name, scenario.n_clusters, scenario.mus_per_cluster
    );

    let live = Arc::new(LiveMetrics::new(scenario.n_clusters));
    let counters = Arc::new(FaultCounters::default());
    if chaos.enabled {
        live.attach_fault_counters(Arc::clone(&counters));
        println!("chaos fault plan armed (seed {})", chaos.seed);
    }
    // Bound to a variable: dropping the server closes its listener thread.
    let _metrics_server = if metrics_addr.is_empty() {
        None
    } else {
        let srv = MetricsServer::spawn(&metrics_addr, Arc::clone(&live))?;
        println!("live metrics at http://{}/metrics", srv.local_addr());
        Some(srv)
    };
    let mut log = if session_log.is_empty() {
        None
    } else {
        let l = SessionLog::create(Path::new(&session_log), &scenario.header())?;
        println!("session log at {session_log}");
        Some(l)
    };

    let t0 = std::time::Instant::now();
    let run = if standalone {
        let sc = scenario.clone();
        if chaos.enabled {
            run_chaos_service(
                move || sc.oracle(),
                &scenario.copts,
                &chaos,
                policy,
                Arc::clone(&counters),
                log.as_mut(),
                Some(live.as_ref()),
            )?
        } else {
            run_coordinated_service(
                move || sc.oracle(),
                &scenario.copts,
                log.as_mut(),
                Some(live.as_ref()),
            )?
        }
    } else {
        let listener = std::net::TcpListener::bind(&listen)
            .with_context(|| format!("binding MBS listener on {listen}"))?;
        println!("listening on {}", listener.local_addr()?);
        let links = accept_workers_timeout(&listener, fingerprint, scenario.n_clusters, io_timeout)?;
        // Chaos wraps the MBS side of each link (stream tag = cluster id,
        // matching run_chaos_service; workers tag their own side past n).
        let links: Vec<ClusterLink> = links
            .into_iter()
            .map(|l| {
                let cluster = l.cluster;
                ClusterLink {
                    cluster,
                    transport: ChaosTransport::wrap(
                        l.transport,
                        &chaos,
                        cluster,
                        cluster as u64,
                        Arc::clone(&counters),
                    ),
                }
            })
            .collect();
        // The MBS needs init + eval but never trains: its own copy of the
        // deterministic oracle matches every worker's bit-for-bit.
        let sc = scenario.clone();
        let svc = ComputeService::spawn(move || sc.oracle());
        let compute = svc.handle();
        let (dim, _k, init, _ipe) = compute.meta();
        let mut eval = |p: &[f32]| compute.eval(Arc::new(p.to_vec()));
        let faults = FaultContext {
            policy,
            rejoin_deadline,
            listener: Some(&listener),
            fingerprint,
            io_timeout,
        };
        let run = run_mbs_faulty(
            links,
            &scenario.copts,
            dim,
            &init,
            &mut eval,
            log.as_mut(),
            Some(live.as_ref()),
            &faults,
        );
        svc.shutdown();
        run?
    };
    println!(
        "session {} finished in {:.2}s wall",
        scenario.name,
        t0.elapsed().as_secs_f64()
    );
    if chaos.enabled {
        println!(
            "chaos summary: {} faults injected, {} clusters skipped",
            counters.total_faults(),
            run.skips.len()
        );
    }

    let result = result::ScenarioResult::from_coordinated(scenario.meta(), 0.0, &run);
    println!("{}", result.table_row());
    golden.emit(&[result], "net")
}

/// `hfl worker` — run one SBS+MUs cell against a serving MBS.
///
/// The worker builds its own oracle from the same flags/config as the
/// server; the fingerprint handshake refuses the session if any
/// bit-relevant scalar diverges.
fn cmd_worker(args: &Args, cfg: &Config) -> Result<()> {
    let mut scenario = NetScenario::from_cli(args, cfg)?;
    scenario.copts.agg = hfl::cli::agg_from_args(args, cfg.agg)?;
    // Must mirror `cmd_serve` exactly — the plan is fingerprinted, so a
    // worker with different `--adversary-*` flags is refused at handshake.
    scenario.copts.adversary = hfl::cli::adversary_from_args(args, &cfg.adversary)?;
    let connect = args.get_or("connect", &cfg.net.listen_addr);
    let mut want = args.get_parsed::<usize>("cluster")?;
    let chaos = hfl::cli::chaos_from_args(args, &cfg.chaos)?;
    let io_timeout_ms = args.get_parsed_or("io-timeout-ms", cfg.net.io_timeout_ms)?;
    let io_timeout = (io_timeout_ms > 0).then(|| Duration::from_millis(io_timeout_ms));
    // In-process retry budget: after a link failure the worker reconnects,
    // announces Rejoin and recomputes from round 0 (the MBS catch-up lane
    // replays the stored broadcasts). `--rejoining` marks a *relaunched*
    // process (e.g. after kill -9) so its very first connection rejoins.
    let rejoin_attempts = args.get_parsed_or("rejoin", 0usize)?;
    let rejoining = args.flag("rejoining");
    args.finish()?;

    let fingerprint = scenario.fingerprint();
    println!(
        "worker for scenario {} (fingerprint {fingerprint:016x}) connecting to {connect}",
        scenario.name
    );
    let counters = Arc::new(FaultCounters::default());
    let mut attempt = 0usize;
    loop {
        let mut transport = TcpTransport::connect_retry(&connect, Duration::from_secs(30))?;
        transport.set_io_timeout(io_timeout)?;
        let (cluster, n) = handshake_worker(&mut transport, fingerprint, want)?;
        if n != scenario.n_clusters {
            bail!(
                "MBS serves {n} clusters but local config has {} — flags diverge",
                scenario.n_clusters
            );
        }
        // A reconnect must land on the same cluster slot.
        want = Some(cluster);
        // Worker-side chaos stream tags live past the MBS's 0..n block so
        // the two endpoints of one link never share a fault stream. A
        // planned kill fires once: the reconnected link drops it (else
        // every rejoin would be killed at the same operation index).
        let mut plan = chaos.clone();
        if attempt > 0 {
            plan.kill_cluster = None;
        }
        let mut link: Box<dyn Transport> = ChaosTransport::wrap(
            Box::new(transport),
            &plan,
            cluster,
            (n + cluster) as u64,
            Arc::clone(&counters),
        );
        if rejoining || attempt > 0 {
            link.send(&WireMsg::Rejoin { cluster, round: 0 })?;
            println!("cluster {cluster}/{n} rejoining from round 0");
        } else {
            println!("assigned cluster {cluster}/{n}");
        }

        let sc = scenario.clone();
        let svc = ComputeService::spawn(move || sc.oracle());
        let res = run_cell(svc.handle(), &scenario.copts, cluster, link.as_mut());
        svc.shutdown();
        match res {
            Ok(()) => {
                println!("cluster {cluster} done");
                return Ok(());
            }
            Err(e) if attempt < rejoin_attempts => {
                attempt += 1;
                eprintln!(
                    "cluster {cluster} link failed (rejoin attempt {attempt}/{rejoin_attempts}): {e:#}"
                );
            }
            Err(e) => return Err(e),
        }
    }
}

/// `hfl replay` — reconstruct a finished run from its session log alone.
///
/// No training happens: the logged Sync/GlobalDelta/Done messages are
/// folded back into a `CoordinatorRun` whose golden trace is bit-exact
/// against the live session's (the CI multiprocess job diffs them).
fn cmd_replay(args: &Args, cfg: &Config) -> Result<()> {
    let session_log = args.get_or("session-log", &cfg.net.session_log);
    let golden = hfl::cli::GoldenArgs::from_args(args);
    args.finish()?;
    if session_log.is_empty() {
        bail!("--session-log PATH required (or set [net] session_log)");
    }

    let (header, run) = replay_session(Path::new(&session_log))?;
    println!(
        "replayed session {} ({} clusters, {} workers, {} iters, h={})",
        header.name, header.n_clusters, header.workers, header.iters, header.h_period
    );
    let result = result::ScenarioResult::from_coordinated(header.meta(), 0.0, &run);
    println!("{}", result.table_row());
    golden.emit(&[result], "net")
}
