//! Little-endian binary codec for snapshot payloads.
//!
//! Everything an engine checkpoints — f32 parameter buffers, u64 counters,
//! 128-bit PCG states, event records — flows through [`ByteWriter`] /
//! [`ByteReader`]. Floats are carried as their IEEE-754 bit patterns
//! (`to_bits`/`from_bits`), so NaN payloads and signed zeros round-trip
//! exactly; nothing ever passes through a decimal representation.
//!
//! The reader is bounds-checked and returns errors (never panics) so a
//! truncated or corrupted snapshot file surfaces as a clean `Err` at
//! resume time.

use anyhow::{bail, Result};

/// Append-only little-endian byte sink.
#[derive(Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn put_u8(&mut self, x: u8) {
        self.buf.push(x);
    }

    pub fn put_u32(&mut self, x: u32) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    pub fn put_u64(&mut self, x: u64) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    pub fn put_u128(&mut self, x: u128) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    pub fn put_usize(&mut self, x: usize) {
        self.put_u64(x as u64);
    }

    pub fn put_bool(&mut self, x: bool) {
        self.put_u8(x as u8);
    }

    /// f64 as its exact bit pattern.
    pub fn put_f64(&mut self, x: f64) {
        self.put_u64(x.to_bits());
    }

    /// f32 as its exact bit pattern.
    pub fn put_f32(&mut self, x: f32) {
        self.put_u32(x.to_bits());
    }

    pub fn put_opt_f64(&mut self, x: Option<f64>) {
        match x {
            Some(v) => {
                self.put_u8(1);
                self.put_f64(v);
            }
            None => self.put_u8(0),
        }
    }

    /// Length-prefixed f32 slice (bit patterns).
    pub fn put_f32_slice(&mut self, xs: &[f32]) {
        self.put_usize(xs.len());
        for &x in xs {
            self.put_f32(x);
        }
    }

    /// Length-prefixed f64 slice (bit patterns).
    pub fn put_f64_slice(&mut self, xs: &[f64]) {
        self.put_usize(xs.len());
        for &x in xs {
            self.put_f64(x);
        }
    }

    /// Length-prefixed u32 slice.
    pub fn put_u32_slice(&mut self, xs: &[u32]) {
        self.put_usize(xs.len());
        for &x in xs {
            self.put_u32(x);
        }
    }

    /// Length-prefixed u64 slice.
    pub fn put_u64_slice(&mut self, xs: &[u64]) {
        self.put_usize(xs.len());
        for &x in xs {
            self.put_u64(x);
        }
    }

    /// Length-prefixed UTF-8 string.
    pub fn put_str(&mut self, s: &str) {
        self.put_usize(s.len());
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// Length-prefixed nested byte blob.
    pub fn put_bytes(&mut self, b: &[u8]) {
        self.put_usize(b.len());
        self.buf.extend_from_slice(b);
    }
}

/// Bounds-checked little-endian byte source over a borrowed buffer.
pub struct ByteReader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(b: &'a [u8]) -> Self {
        Self { b, i: 0 }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.b.len() - self.i
    }

    /// Error unless every byte was consumed — catches payload/reader drift.
    pub fn finish(self) -> Result<()> {
        if self.i != self.b.len() {
            bail!(
                "snapshot payload has {} trailing bytes (read {} of {})",
                self.b.len() - self.i,
                self.i,
                self.b.len()
            );
        }
        Ok(())
    }

    /// Take the next `n` raw bytes (used by fingerprint comparisons that
    /// match a prefix wholesale).
    pub fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            bail!(
                "snapshot payload truncated: need {n} bytes at offset {}, have {}",
                self.i,
                self.remaining()
            );
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    pub fn get_u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn get_u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn get_u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_u128(&mut self) -> Result<u128> {
        Ok(u128::from_le_bytes(self.take(16)?.try_into().unwrap()))
    }

    pub fn get_usize(&mut self) -> Result<usize> {
        let x = self.get_u64()?;
        if x > usize::MAX as u64 {
            bail!("snapshot length {x} exceeds usize");
        }
        Ok(x as usize)
    }

    pub fn get_bool(&mut self) -> Result<bool> {
        match self.get_u8()? {
            0 => Ok(false),
            1 => Ok(true),
            other => bail!("bad bool byte {other} in snapshot"),
        }
    }

    pub fn get_f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.get_u64()?))
    }

    pub fn get_f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.get_u32()?))
    }

    pub fn get_opt_f64(&mut self) -> Result<Option<f64>> {
        Ok(if self.get_bool()? {
            Some(self.get_f64()?)
        } else {
            None
        })
    }

    /// Guard a length prefix against absurd values so a corrupted prefix
    /// fails cleanly instead of attempting a huge allocation.
    fn checked_len(&self, n: usize, elem_bytes: usize) -> Result<usize> {
        if n.checked_mul(elem_bytes).map_or(true, |b| b > self.remaining()) {
            bail!(
                "snapshot slice length {n} (×{elem_bytes}B) exceeds remaining {} bytes",
                self.remaining()
            );
        }
        Ok(n)
    }

    pub fn get_f32_vec(&mut self) -> Result<Vec<f32>> {
        let n = self.get_usize()?;
        let n = self.checked_len(n, 4)?;
        (0..n).map(|_| self.get_f32()).collect()
    }

    /// Read a length-prefixed f32 slice into an existing buffer of the
    /// exact expected length (arena regions, model rows).
    pub fn get_f32_into(&mut self, out: &mut [f32]) -> Result<()> {
        let n = self.get_usize()?;
        if n != out.len() {
            bail!("snapshot f32 slice length {n} != expected {}", out.len());
        }
        for slot in out.iter_mut() {
            *slot = self.get_f32()?;
        }
        Ok(())
    }

    pub fn get_f64_vec(&mut self) -> Result<Vec<f64>> {
        let n = self.get_usize()?;
        let n = self.checked_len(n, 8)?;
        (0..n).map(|_| self.get_f64()).collect()
    }

    pub fn get_u32_vec(&mut self) -> Result<Vec<u32>> {
        let n = self.get_usize()?;
        let n = self.checked_len(n, 4)?;
        (0..n).map(|_| self.get_u32()).collect()
    }

    pub fn get_u64_vec(&mut self) -> Result<Vec<u64>> {
        let n = self.get_usize()?;
        let n = self.checked_len(n, 8)?;
        (0..n).map(|_| self.get_u64()).collect()
    }

    pub fn get_str(&mut self) -> Result<String> {
        let n = self.get_usize()?;
        let n = self.checked_len(n, 1)?;
        Ok(std::str::from_utf8(self.take(n)?)?.to_string())
    }

    pub fn get_bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.get_usize()?;
        let n = self.checked_len(n, 1)?;
        Ok(self.take(n)?.to_vec())
    }
}

/// Write a [`crate::util::rng::Pcg64`] raw state.
pub fn put_rng(w: &mut ByteWriter, rng: &crate::util::rng::Pcg64) {
    let (state, inc, cached) = rng.raw_state();
    w.put_u128(state);
    w.put_u128(inc);
    w.put_opt_f64(cached);
}

/// Read a [`crate::util::rng::Pcg64`] raw state.
pub fn get_rng(r: &mut ByteReader) -> Result<crate::util::rng::Pcg64> {
    let state = r.get_u128()?;
    let inc = r.get_u128()?;
    let cached = r.get_opt_f64()?;
    if inc & 1 != 1 {
        bail!("corrupt snapshot: PCG increment is even");
    }
    Ok(crate::util::rng::Pcg64::from_raw_state(state, inc, cached))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_primitives() {
        let mut w = ByteWriter::new();
        w.put_u8(7);
        w.put_u32(0xdead_beef);
        w.put_u64(u64::MAX - 3);
        w.put_u128(u128::MAX - 9);
        w.put_bool(true);
        w.put_f64(-0.0);
        w.put_f64(f64::NAN);
        w.put_f32(f32::MIN_POSITIVE);
        w.put_opt_f64(None);
        w.put_opt_f64(Some(f64::INFINITY));
        w.put_f32_slice(&[1.5, -0.0, f32::NAN]);
        w.put_f64_slice(&[2.5, f64::MIN]);
        w.put_u32_slice(&[0, u32::MAX]);
        w.put_u64_slice(&[1u64 << 60]);
        w.put_str("héllo");
        w.put_bytes(&[1, 2, 3]);
        let bytes = w.into_bytes();

        let mut r = ByteReader::new(&bytes);
        assert_eq!(r.get_u8().unwrap(), 7);
        assert_eq!(r.get_u32().unwrap(), 0xdead_beef);
        assert_eq!(r.get_u64().unwrap(), u64::MAX - 3);
        assert_eq!(r.get_u128().unwrap(), u128::MAX - 9);
        assert!(r.get_bool().unwrap());
        assert_eq!(r.get_f64().unwrap().to_bits(), (-0.0f64).to_bits());
        assert_eq!(r.get_f64().unwrap().to_bits(), f64::NAN.to_bits());
        assert_eq!(r.get_f32().unwrap(), f32::MIN_POSITIVE);
        assert_eq!(r.get_opt_f64().unwrap(), None);
        assert_eq!(r.get_opt_f64().unwrap(), Some(f64::INFINITY));
        let v = r.get_f32_vec().unwrap();
        assert_eq!(v.len(), 3);
        assert_eq!(v[1].to_bits(), (-0.0f32).to_bits());
        assert_eq!(v[2].to_bits(), f32::NAN.to_bits());
        assert_eq!(r.get_f64_vec().unwrap(), vec![2.5, f64::MIN]);
        assert_eq!(r.get_u32_vec().unwrap(), vec![0, u32::MAX]);
        assert_eq!(r.get_u64_vec().unwrap(), vec![1u64 << 60]);
        assert_eq!(r.get_str().unwrap(), "héllo");
        assert_eq!(r.get_bytes().unwrap(), vec![1, 2, 3]);
        r.finish().unwrap();
    }

    #[test]
    fn truncation_and_trailing_bytes_are_errors() {
        let mut w = ByteWriter::new();
        w.put_u64(5);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes[..4]);
        assert!(r.get_u64().is_err());
        let mut r = ByteReader::new(&bytes);
        assert_eq!(r.get_u32().unwrap(), 5);
        assert!(r.finish().is_err(), "trailing bytes must be detected");
        // A corrupted huge length prefix fails instead of allocating.
        let mut r = ByteReader::new(&bytes);
        assert!(r.get_f32_vec().is_err());
    }

    #[test]
    fn rng_state_roundtrips_mid_stream() {
        let mut rng = crate::util::rng::Pcg64::new(9, 3);
        let _ = rng.normal(); // leave a cached variate
        let mut w = ByteWriter::new();
        put_rng(&mut w, &rng);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        let mut back = get_rng(&mut r).unwrap();
        r.finish().unwrap();
        assert_eq!(rng.normal().to_bits(), back.normal().to_bits());
        for _ in 0..100 {
            assert_eq!(rng.next_u64(), back.next_u64());
        }
    }
}
