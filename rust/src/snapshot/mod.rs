//! **Checkpoint/resume subsystem**: versioned, checksummed engine-state
//! snapshots plus the append-only run log of the scenario-matrix sweeps.
//!
//! A snapshot file is a binary container:
//!
//! ```text
//! [ magic "HFLSNAP1" | version u32 | engine u8 | payload_len u64 |
//!   payload bytes … | fnv1a64(version‥payload) u64 ]
//! ```
//!
//! The payload is engine-defined ([`codec`] little-endian encoding): the
//! fl engine serializes its arena regions (exact f32 bit patterns), DGC
//! `u`/`v` and discounted-error accumulators, the training log, and the
//! oracle's mutable state; the DES engine additionally serializes every
//! per-entity `Pcg64` stream, the `(time, seq)` event queue with its
//! `next_seq`, the timeline recorder, and all bit counters. Writes are
//! atomic (temp file + rename), so a crash mid-checkpoint leaves the
//! previous snapshot intact.
//!
//! **Determinism contract.** Resuming from a round-k snapshot reproduces
//! the uninterrupted run bit-for-bit — same `params_hash`, `loss_digest`,
//! and DES `timeline_digest`, at any thread count. Anything that could
//! advance differently after restore (RNG raw states including Box–Muller
//! caches, heap `next_seq`, loss accumulators) is part of the payload;
//! anything recomputable from the config (geometry, pricing, layouts) is
//! deliberately not, and resume revalidates a config fingerprint instead.
//!
//! The matrix engines use an *event-sourced run log* instead of one giant
//! state blob: a JSONL file whose header pins the grid fingerprint and
//! whose lines are completed cells in [`crate::sim::result::
//! ScenarioResult::to_exact_json`] form (f64s as bit patterns — NaN-safe).
//! Resume replays the log, keeps every intact line, and re-runs only the
//! missing cells; a torn final line (killed mid-append) is discarded.

pub mod codec;

use crate::sim::result::fnv1a64;
use anyhow::{bail, Context, Result};
use std::io::Write as _;
use std::path::{Path, PathBuf};

/// File magic: identifies an hfl snapshot container.
pub const MAGIC: [u8; 8] = *b"HFLSNAP1";
/// Container format version. Bump on any layout change; readers refuse
/// other versions instead of guessing.
pub const VERSION: u32 = 1;

/// Engine tag stored in the container header, so an fl snapshot can never
/// be fed to the DES resume path (or vice versa) undetected.
pub const ENGINE_FL: u8 = 1;
/// See [`ENGINE_FL`].
pub const ENGINE_DES: u8 = 2;

/// Checkpoint cadence + destination, threaded into the engines.
#[derive(Clone, Debug)]
pub struct CheckpointSpec {
    /// Snapshot after every `every`-th completed round (0 = never).
    pub every: usize,
    /// Snapshot file path (overwritten atomically at each checkpoint).
    pub path: PathBuf,
}

impl CheckpointSpec {
    pub fn new(every: usize, path: impl Into<PathBuf>) -> Self {
        Self {
            every,
            path: path.into(),
        }
    }

    /// Should a snapshot be taken after completing round `t` (0-based) of
    /// `iters` total? Never fires on the final round — the run is done and
    /// the snapshot would be dead weight.
    pub fn due_after_round(&self, t: usize, iters: usize) -> bool {
        self.every > 0 && (t + 1) % self.every == 0 && t + 1 < iters
    }
}

/// Write a snapshot container atomically: payload goes to `<path>.tmp`,
/// then a rename swaps it in, so a crash mid-write never corrupts an
/// existing snapshot.
pub fn write_snapshot(path: &Path, engine: u8, payload: &[u8]) -> Result<()> {
    let mut body = Vec::with_capacity(payload.len() + 29);
    body.extend_from_slice(&VERSION.to_le_bytes());
    body.push(engine);
    body.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    body.extend_from_slice(payload);
    let checksum = fnv1a64(body.iter().copied());

    let mut bytes = Vec::with_capacity(body.len() + 16);
    bytes.extend_from_slice(&MAGIC);
    bytes.extend_from_slice(&body);
    bytes.extend_from_slice(&checksum.to_le_bytes());

    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating snapshot dir {}", dir.display()))?;
        }
    }
    let tmp = path.with_extension("tmp");
    {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating snapshot temp file {}", tmp.display()))?;
        f.write_all(&bytes)?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming snapshot into place at {}", path.display()))?;
    Ok(())
}

/// Read and verify a snapshot container; returns the payload. Fails on a
/// wrong magic, unknown version, mismatched engine tag, truncation, or a
/// checksum mismatch — a corrupted snapshot must never half-restore.
pub fn read_snapshot(path: &Path, expect_engine: u8) -> Result<Vec<u8>> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("reading snapshot {}", path.display()))?;
    if bytes.len() < MAGIC.len() + 4 + 1 + 8 + 8 {
        bail!("snapshot {} is too short ({} bytes)", path.display(), bytes.len());
    }
    if bytes[..MAGIC.len()] != MAGIC {
        bail!("{} is not an hfl snapshot (bad magic)", path.display());
    }
    let body = &bytes[MAGIC.len()..bytes.len() - 8];
    let stored = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().unwrap());
    let computed = fnv1a64(body.iter().copied());
    if stored != computed {
        bail!(
            "snapshot {} checksum mismatch (stored {stored:016x}, computed {computed:016x})",
            path.display()
        );
    }
    let version = u32::from_le_bytes(body[..4].try_into().unwrap());
    if version != VERSION {
        bail!(
            "snapshot {} has format version {version}, this build reads {VERSION}",
            path.display()
        );
    }
    let engine = body[4];
    if engine != expect_engine {
        let name = |e: u8| match e {
            ENGINE_FL => "fl",
            ENGINE_DES => "des",
            _ => "unknown",
        };
        bail!(
            "snapshot {} was written by the {} engine, expected {}",
            path.display(),
            name(engine),
            name(expect_engine)
        );
    }
    let len = u64::from_le_bytes(body[5..13].try_into().unwrap()) as usize;
    let payload = &body[13..];
    if payload.len() != len {
        bail!(
            "snapshot {} payload length mismatch (header {len}, actual {})",
            path.display(),
            payload.len()
        );
    }
    Ok(payload.to_vec())
}

/// Append one line to a JSONL run log and flush it to disk so a `kill -9`
/// right after a cell completes still finds the line on resume.
pub fn append_runlog_line(file: &mut std::fs::File, line: &str) -> Result<()> {
    debug_assert!(!line.contains('\n'), "run-log lines must be single-line");
    file.write_all(line.as_bytes())?;
    file.write_all(b"\n")?;
    file.sync_data()?;
    Ok(())
}

/// Read a JSONL run log, tolerating a torn final line (the append that a
/// crash interrupted): returns every complete, parseable line's text.
/// A malformed line *followed by* intact lines is corruption, not a torn
/// tail, and errors out.
pub fn read_runlog_lines(path: &Path) -> Result<Vec<String>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading run log {}", path.display()))?;
    let mut out: Vec<String> = Vec::new();
    let mut torn = false;
    for (i, line) in text.split('\n').enumerate() {
        if line.is_empty() {
            continue;
        }
        let parseable = crate::util::json::parse(line).is_ok();
        if torn && parseable {
            bail!(
                "run log {}: line {} is malformed but later lines parse — corrupt log",
                path.display(),
                i
            );
        }
        if parseable {
            out.push(line.to_string());
        } else {
            torn = true;
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("hfl_snap_test_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn container_roundtrip_and_tamper_detection() {
        let dir = tmp_dir("container");
        let path = dir.join("a.snap");
        let payload: Vec<u8> = (0..=255u8).cycle().take(1000).collect();
        write_snapshot(&path, ENGINE_FL, &payload).unwrap();
        assert_eq!(read_snapshot(&path, ENGINE_FL).unwrap(), payload);

        // Wrong engine tag is refused.
        let err = read_snapshot(&path, ENGINE_DES).unwrap_err().to_string();
        assert!(err.contains("fl engine"), "{err}");

        // A flipped payload byte fails the checksum.
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        let err = read_snapshot(&path, ENGINE_FL).unwrap_err().to_string();
        assert!(err.contains("checksum"), "{err}");

        // Truncation is detected.
        write_snapshot(&path, ENGINE_FL, &payload).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 3]).unwrap();
        assert!(read_snapshot(&path, ENGINE_FL).is_err());

        // Not-a-snapshot is refused up front.
        std::fs::write(&path, b"{\"json\": true}xxxxxxxxxxxxxxxxxxxxx").unwrap();
        let err = read_snapshot(&path, ENGINE_FL).unwrap_err().to_string();
        assert!(err.contains("magic"), "{err}");
    }

    #[test]
    fn checkpoint_cadence() {
        let spec = CheckpointSpec::new(5, "/tmp/x.snap");
        assert!(!spec.due_after_round(3, 30));
        assert!(spec.due_after_round(4, 30)); // rounds 0..=4 done = 5 rounds
        assert!(spec.due_after_round(9, 30));
        assert!(!spec.due_after_round(29, 30), "never on the final round");
        let off = CheckpointSpec::new(0, "/tmp/x.snap");
        assert!(!off.due_after_round(4, 30));
    }

    #[test]
    fn runlog_tolerates_torn_tail_only() {
        let dir = tmp_dir("runlog");
        let path = dir.join("run.jsonl");
        let mut f = std::fs::File::create(&path).unwrap();
        append_runlog_line(&mut f, r#"{"id":0}"#).unwrap();
        append_runlog_line(&mut f, r#"{"id":1}"#).unwrap();
        // Simulate a torn append: partial JSON, no newline.
        use std::io::Write as _;
        f.write_all(br#"{"id":2,"tr"#).unwrap();
        drop(f);
        let lines = read_runlog_lines(&path).unwrap();
        assert_eq!(lines, vec![r#"{"id":0}"#.to_string(), r#"{"id":1}"#.to_string()]);

        // A malformed line in the middle is corruption, not a torn tail.
        std::fs::write(&path, "{\"id\":0}\nnot json\n{\"id\":2}\n").unwrap();
        assert!(read_runlog_lines(&path).is_err());
    }
}
