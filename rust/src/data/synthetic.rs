//! Deterministic synthetic CIFAR-like dataset (substitution for CIFAR-10,
//! DESIGN.md §3).
//!
//! Each class c gets (a) a smooth low-frequency template image (sum of a
//! few random 2-D cosines), and (b) a characteristic high-frequency texture
//! direction. A sample mixes the class signal with *structured background
//! clutter* (random combinations of a shared smooth-image bank — CIFAR's
//! "sky/grass" analogue, uninformative about the class and immune to
//! dimension-averaging) plus iid pixel noise:
//!
//! ```text
//! x = sep·template_c + texture_c·s·sep + Σ_j b_j·background_j + ε
//!     s ~ N(0,3²),  b_j ~ N(0,1) (3 of 32 bank images),  ε ~ N(0, noise²)·I
//! ```
//!
//! normalized to zero mean / unit variance per image. `class_sep` calibrates
//! difficulty: at the default 0.22 a nearest-class-mean classifier gets
//! ~37% (vs 10% chance) and the MLP reaches ~75% — non-saturated, so the
//! FL/HFL comparisons of Fig. 6 / Table III have dynamic range.

use crate::util::rng::Pcg64;

pub const IMAGE_DIM: usize = 32 * 32 * 3;
pub const N_CLASSES: usize = 10;

/// Generation parameters.
#[derive(Clone, Debug)]
pub struct SyntheticSpec {
    pub n_train: usize,
    pub n_test: usize,
    /// Pixel noise std.
    pub noise: f32,
    /// Class-template amplitude relative to the structured background
    /// clutter (amplitude 1). Small values bury the class signal under
    /// sample-specific structure — the knob that keeps the task from
    /// saturating (iid noise alone averages out over 3072 dimensions).
    pub class_sep: f32,
    /// Master seed (class structure + sample draws).
    pub seed: u64,
}

impl Default for SyntheticSpec {
    fn default() -> Self {
        Self {
            n_train: 8960,
            n_test: 2000,
            noise: 0.6,
            class_sep: 0.22,
            seed: 2019,
        }
    }
}

/// An in-memory dataset of flattened normalized images.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Row-major `n × IMAGE_DIM`.
    pub x: Vec<f32>,
    /// Labels `n`.
    pub y: Vec<i32>,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    pub fn image(&self, i: usize) -> &[f32] {
        &self.x[i * IMAGE_DIM..(i + 1) * IMAGE_DIM]
    }

    /// Copy rows `idx` into a dense batch buffer (`x_out`: B×IMAGE_DIM).
    pub fn fill_batch(&self, idx: &[usize], x_out: &mut [f32], y_out: &mut [i32]) {
        assert_eq!(x_out.len(), idx.len() * IMAGE_DIM);
        assert_eq!(y_out.len(), idx.len());
        for (b, &i) in idx.iter().enumerate() {
            x_out[b * IMAGE_DIM..(b + 1) * IMAGE_DIM].copy_from_slice(self.image(i));
            y_out[b] = self.y[i];
        }
    }
}

/// Class structure shared by train and test splits.
struct ClassBank {
    templates: Vec<Vec<f32>>,
    textures: Vec<Vec<f32>>,
    /// Structured background clutter bank: every sample mixes a few of
    /// these with random weights, so samples share low-frequency structure
    /// that is *uninformative* about the class (CIFAR's "sky/grass"
    /// analogue) and that dimension-averaging cannot remove.
    backgrounds: Vec<Vec<f32>>,
}

const N_BACKGROUNDS: usize = 32;
const BG_MIX: usize = 3;

/// One smooth unit-RMS image: sum of 4 random 2-D cosine waves per channel.
fn smooth_image(rng: &mut Pcg64) -> Vec<f32> {
    let mut t = vec![0.0f32; IMAGE_DIM];
    for _ in 0..4 {
        let fx = rng.uniform_range(0.5, 3.0);
        let fy = rng.uniform_range(0.5, 3.0);
        let phase = rng.uniform_range(0.0, std::f64::consts::TAU);
        let chan_w = [rng.normal(), rng.normal(), rng.normal()];
        for yy in 0..32 {
            for xx in 0..32 {
                let v = (fx * xx as f64 / 32.0 * std::f64::consts::TAU
                    + fy * yy as f64 / 32.0 * std::f64::consts::TAU
                    + phase)
                    .cos();
                for ch in 0..3 {
                    t[(yy * 32 + xx) * 3 + ch] += (v * chan_w[ch]) as f32;
                }
            }
        }
    }
    let rms = (t.iter().map(|v| v * v).sum::<f32>() / IMAGE_DIM as f32)
        .sqrt()
        .max(1e-6);
    t.iter_mut().for_each(|v| *v /= rms);
    t
}

fn build_classes(rng: &mut Pcg64) -> ClassBank {
    let mut templates = Vec::with_capacity(N_CLASSES);
    let mut textures = Vec::with_capacity(N_CLASSES);
    for _ in 0..N_CLASSES {
        templates.push(smooth_image(rng));
        // Texture direction: unit-norm high-frequency pattern.
        let mut tex: Vec<f32> = (0..IMAGE_DIM).map(|_| rng.normal() as f32).collect();
        let norm = tex.iter().map(|v| v * v).sum::<f32>().sqrt();
        tex.iter_mut().for_each(|v| *v /= norm);
        textures.push(tex);
    }
    let backgrounds = (0..N_BACKGROUNDS).map(|_| smooth_image(rng)).collect();
    ClassBank {
        templates,
        textures,
        backgrounds,
    }
}

/// Generate the train and test splits (shared class bank, disjoint draws).
pub fn generate(spec: &SyntheticSpec) -> (Dataset, Dataset) {
    let mut class_rng = Pcg64::new(spec.seed, 0xC1A5);
    let bank = build_classes(&mut class_rng);
    let mut train_rng = Pcg64::new(spec.seed, 0x7EA1);
    let mut test_rng = Pcg64::new(spec.seed, 0x7E57);
    (
        sample_split(&bank, spec.n_train, spec, &mut train_rng),
        sample_split(&bank, spec.n_test, spec, &mut test_rng),
    )
}

fn sample_split(bank: &ClassBank, n: usize, spec: &SyntheticSpec, rng: &mut Pcg64) -> Dataset {
    let noise = spec.noise;
    let sep = spec.class_sep;
    let mut x = vec![0.0f32; n * IMAGE_DIM];
    let mut y = vec![0i32; n];
    for i in 0..n {
        // Balanced labels in order c = i mod 10 (the partitioner decides
        // who sees what; labels must not correlate with shard boundaries,
        // so interleave classes).
        let c = i % N_CLASSES;
        y[i] = c as i32;
        let s = rng.normal() as f32 * 3.0 * sep;
        let row = &mut x[i * IMAGE_DIM..(i + 1) * IMAGE_DIM];
        let (tpl, tex) = (&bank.templates[c], &bank.textures[c]);
        // Per-sample structured background: mix of BG_MIX bank images.
        let bg: Vec<(usize, f32)> = (0..BG_MIX)
            .map(|_| (rng.uniform_usize(N_BACKGROUNDS), rng.normal() as f32))
            .collect();
        let mut mean = 0.0f32;
        for j in 0..IMAGE_DIM {
            let mut v = sep * tpl[j] + tex[j] * s + noise * rng.normal() as f32;
            for &(bi, bw) in &bg {
                v += bw * bank.backgrounds[bi][j];
            }
            row[j] = v;
            mean += v;
        }
        // Per-image standardization.
        mean /= IMAGE_DIM as f32;
        let mut var = 0.0f32;
        for v in row.iter_mut() {
            *v -= mean;
            var += *v * *v;
        }
        let std = (var / IMAGE_DIM as f32).sqrt().max(1e-6);
        row.iter_mut().for_each(|v| *v /= std);
    }
    Dataset { x, y }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> SyntheticSpec {
        SyntheticSpec {
            n_train: 200,
            n_test: 100,
            noise: 0.6,
            seed: 42,
            ..SyntheticSpec::default()
        }
    }

    #[test]
    fn deterministic() {
        let (a, _) = generate(&small());
        let (b, _) = generate(&small());
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
        let (c, _) = generate(&SyntheticSpec {
            seed: 43,
            ..small()
        });
        assert_ne!(a.x, c.x);
    }

    #[test]
    fn balanced_interleaved_labels() {
        let (train, test) = generate(&small());
        for c in 0..N_CLASSES as i32 {
            assert_eq!(train.y.iter().filter(|&&y| y == c).count(), 20);
            assert_eq!(test.y.iter().filter(|&&y| y == c).count(), 10);
        }
        assert_eq!(&train.y[..10], &(0..10).map(|i| i as i32).collect::<Vec<_>>()[..]);
    }

    #[test]
    fn images_standardized() {
        let (train, _) = generate(&small());
        for i in (0..train.len()).step_by(37) {
            let img = train.image(i);
            let mean: f32 = img.iter().sum::<f32>() / IMAGE_DIM as f32;
            let var: f32 = img.iter().map(|v| (v - mean).powi(2)).sum::<f32>() / IMAGE_DIM as f32;
            assert!(mean.abs() < 1e-3, "mean {mean}");
            assert!((var - 1.0).abs() < 1e-2, "var {var}");
        }
    }

    #[test]
    fn classes_are_separable_by_nearest_template_mean() {
        // Nearest-class-mean classifier on raw pixels should beat chance by
        // a wide margin — i.e. the class signal is real.
        // Use a wide class separation here: the property under test is that
        // the class signal is real, not the difficulty calibration.
        let (train, test) = generate(&SyntheticSpec {
            n_train: 1000,
            n_test: 200,
            noise: 0.6,
            class_sep: 0.8,
            seed: 7,
        });
        let mut means = vec![vec![0.0f32; IMAGE_DIM]; N_CLASSES];
        let mut counts = vec![0usize; N_CLASSES];
        for i in 0..train.len() {
            let c = train.y[i] as usize;
            counts[c] += 1;
            for (m, &v) in means[c].iter_mut().zip(train.image(i)) {
                *m += v;
            }
        }
        for c in 0..N_CLASSES {
            means[c].iter_mut().for_each(|m| *m /= counts[c] as f32);
        }
        let mut correct = 0;
        for i in 0..test.len() {
            let img = test.image(i);
            let best = (0..N_CLASSES)
                .min_by(|&a, &b| {
                    let da: f32 = means[a].iter().zip(img).map(|(m, v)| (m - v).powi(2)).sum();
                    let db: f32 = means[b].iter().zip(img).map(|(m, v)| (m - v).powi(2)).sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best == test.y[i] as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / test.len() as f64;
        assert!(acc > 0.5, "nearest-mean accuracy {acc} ≤ chance-ish");
    }

    #[test]
    fn fill_batch_copies_rows() {
        let (train, _) = generate(&small());
        let idx = [3usize, 7, 11];
        let mut x = vec![0f32; 3 * IMAGE_DIM];
        let mut y = vec![0i32; 3];
        train.fill_batch(&idx, &mut x, &mut y);
        assert_eq!(&x[..IMAGE_DIM], train.image(3));
        assert_eq!(y[0], train.y[3]);
        assert_eq!(&x[2 * IMAGE_DIM..], train.image(11));
    }
}
