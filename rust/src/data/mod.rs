//! Dataset substrate.
//!
//! The paper trains on CIFAR-10; this environment has no dataset downloads,
//! so [`synthetic`] generates a deterministic CIFAR-*like* 10-class
//! 32×32×3 corpus (per-class smooth template + class-correlated texture +
//! pixel noise — hard enough that a linear model underfits but a small
//! CNN/MLP separates it). DESIGN.md §3 documents the substitution.
//!
//! [`partition`] implements the paper's §V-B data assignment: the training
//! set is split across MUs **without shuffling** and every MU iterates its
//! own fixed shard.

pub mod partition;
pub mod synthetic;

pub use partition::{Partition, Shard};
pub use synthetic::{Dataset, SyntheticSpec};
