//! §V-B data assignment: "data sets are divided among the MUs without any
//! shuffling and through the iterations MUs train the same subset".
//!
//! The training set is cut into K contiguous equal shards; worker k cycles
//! through shard k in fixed minibatch order. (Because the synthetic
//! generator interleaves classes, contiguous shards are still IID — the
//! paper's non-IID extension is future work, §V-D.)

use super::synthetic::Dataset;

/// One worker's view of the training data.
#[derive(Clone, Debug)]
pub struct Shard {
    /// Global sample indices owned by this worker (contiguous).
    pub indices: Vec<usize>,
    cursor: usize,
}

impl Shard {
    pub fn len(&self) -> usize {
        self.indices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    /// Next `batch` indices, cycling deterministically (no shuffling).
    pub fn next_batch(&mut self, batch: usize) -> Vec<usize> {
        assert!(batch <= self.len(), "batch larger than shard");
        let mut out = Vec::with_capacity(batch);
        for _ in 0..batch {
            out.push(self.indices[self.cursor]);
            self.cursor = (self.cursor + 1) % self.indices.len();
        }
        out
    }

    pub fn reset(&mut self) {
        self.cursor = 0;
    }
}

/// Equal contiguous split of `n_samples` across `k` workers.
#[derive(Clone, Debug)]
pub struct Partition {
    pub shards: Vec<Shard>,
    pub batch_size: usize,
}

impl Partition {
    pub fn contiguous(dataset: &Dataset, k: usize, batch_size: usize) -> Self {
        assert!(k > 0);
        let n = dataset.len();
        let per = n / k;
        assert!(
            per >= batch_size,
            "shard size {per} < batch {batch_size} (need ≥1 batch per worker)"
        );
        let shards = (0..k)
            .map(|w| Shard {
                indices: (w * per..(w + 1) * per).collect(),
                cursor: 0,
            })
            .collect();
        Self { shards, batch_size }
    }

    /// Non-IID split (the paper's §V-D extension): samples are sorted by
    /// label and dealt out in label-homogeneous blocks, so each worker sees
    /// at most ~⌈blocks_per_worker⌉ classes. `blocks_per_worker = 2`
    /// reproduces the classic "2-class shards" federated non-IID setting
    /// (McMahan et al.); `= n_classes` degenerates toward IID.
    pub fn non_iid(
        dataset: &Dataset,
        k: usize,
        batch_size: usize,
        blocks_per_worker: usize,
        seed: u64,
    ) -> Self {
        assert!(k > 0 && blocks_per_worker > 0);
        let n = dataset.len();
        let per = n / k;
        assert!(
            per >= batch_size,
            "shard size {per} < batch {batch_size}"
        );
        // Sort indices by label (stable → deterministic).
        let mut by_label: Vec<usize> = (0..n).collect();
        by_label.sort_by_key(|&i| dataset.y[i]);
        // Cut into k·blocks_per_worker label-homogeneous blocks and deal a
        // random permutation of blocks to workers.
        let n_blocks = k * blocks_per_worker;
        let block_len = n / n_blocks;
        assert!(block_len > 0, "too many blocks for dataset size");
        let mut block_order: Vec<usize> = (0..n_blocks).collect();
        let mut rng = crate::util::rng::Pcg64::new(seed, 0x0D1D);
        rng.shuffle(&mut block_order);
        let shards = (0..k)
            .map(|w| {
                let mut indices = Vec::with_capacity(blocks_per_worker * block_len);
                for b in 0..blocks_per_worker {
                    let blk = block_order[w * blocks_per_worker + b];
                    indices
                        .extend_from_slice(&by_label[blk * block_len..(blk + 1) * block_len]);
                }
                Shard { indices, cursor: 0 }
            })
            .collect();
        Self { shards, batch_size }
    }

    pub fn n_workers(&self) -> usize {
        self.shards.len()
    }

    /// Iterations per epoch (shard length / batch).
    pub fn iters_per_epoch(&self) -> usize {
        (self.shards[0].len() / self.batch_size).max(1)
    }

    /// Label-distribution skew: mean over workers of the fraction of each
    /// worker's samples in its single most-common class (1.0 = fully
    /// homogeneous shards; ≈1/n_classes = IID).
    pub fn label_skew(&self, dataset: &Dataset) -> f64 {
        let mut total = 0.0;
        for s in &self.shards {
            let mut counts = std::collections::BTreeMap::new();
            for &i in &s.indices {
                *counts.entry(dataset.y[i]).or_insert(0usize) += 1;
            }
            let max = counts.values().copied().max().unwrap_or(0);
            total += max as f64 / s.len().max(1) as f64;
        }
        total / self.shards.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SyntheticSpec};

    fn ds() -> Dataset {
        let (train, _) = generate(&SyntheticSpec {
            n_train: 240,
            n_test: 10,
            noise: 0.5,
            seed: 1,
            ..SyntheticSpec::default()
        });
        train
    }

    #[test]
    fn contiguous_disjoint_cover() {
        let d = ds();
        let p = Partition::contiguous(&d, 4, 16);
        let mut all: Vec<usize> = p.shards.iter().flat_map(|s| s.indices.clone()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..240).collect::<Vec<_>>());
        for s in &p.shards {
            assert_eq!(s.len(), 60);
        }
    }

    #[test]
    fn batches_cycle_without_shuffle() {
        let d = ds();
        let mut p = Partition::contiguous(&d, 4, 16);
        let b1 = p.shards[1].next_batch(16);
        assert_eq!(b1, (60..76).collect::<Vec<_>>());
        let _b2 = p.shards[1].next_batch(16);
        let _b3 = p.shards[1].next_batch(16);
        let b4 = p.shards[1].next_batch(16);
        // 60-element shard: 4th batch wraps at 108..120 then 60..64.
        assert_eq!(b4[..12], (108..120).collect::<Vec<_>>()[..]);
        assert_eq!(b4[12..], (60..64).collect::<Vec<_>>()[..]);
    }

    #[test]
    fn deterministic_across_resets() {
        let d = ds();
        let mut p = Partition::contiguous(&d, 2, 8);
        let a = p.shards[0].next_batch(8);
        p.shards[0].reset();
        let b = p.shards[0].next_batch(8);
        assert_eq!(a, b);
    }

    #[test]
    fn iters_per_epoch() {
        let d = ds();
        let p = Partition::contiguous(&d, 4, 16);
        assert_eq!(p.iters_per_epoch(), 3); // 60/16 = 3 (floor)
    }

    #[test]
    #[should_panic(expected = "shard size")]
    fn too_many_workers_rejected() {
        let d = ds();
        let _ = Partition::contiguous(&d, 200, 16);
    }

    #[test]
    fn non_iid_covers_disjointly_and_skews_labels() {
        let d = ds(); // 240 samples, 10 balanced classes (24 each)
        // k=5 × 2 blocks = 10 blocks of 24 → each block is exactly one class.
        let p = Partition::non_iid(&d, 5, 16, 2, 7);
        // Disjoint cover of (n_blocks·block_len) samples.
        let mut all: Vec<usize> = p.shards.iter().flat_map(|s| s.indices.clone()).collect();
        let total = all.len();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), total, "shards overlap");
        for s in &p.shards {
            assert_eq!(s.len(), 48);
        }
        // 2 classes per worker → heavy skew vs IID.
        let skew = p.label_skew(&d);
        let iid_skew = Partition::contiguous(&d, 5, 16).label_skew(&d);
        assert!(
            skew > iid_skew + 0.2,
            "non-IID skew {skew} should exceed IID {iid_skew}"
        );
        assert!(skew >= 0.5, "2-class shards hold ≥50% one class: {skew}");
    }

    #[test]
    fn non_iid_deterministic_per_seed() {
        let d = ds();
        let a = Partition::non_iid(&d, 4, 16, 2, 7);
        let b = Partition::non_iid(&d, 4, 16, 2, 7);
        for (x, y) in a.shards.iter().zip(&b.shards) {
            assert_eq!(x.indices, y.indices);
        }
        let c = Partition::non_iid(&d, 4, 16, 2, 8);
        assert!(a.shards.iter().zip(&c.shards).any(|(x, y)| x.indices != y.indices));
    }

    #[test]
    fn non_iid_many_blocks_approaches_iid() {
        let d = ds();
        let skew2 = Partition::non_iid(&d, 4, 16, 2, 7).label_skew(&d);
        let skew10 = Partition::non_iid(&d, 4, 8, 6, 7).label_skew(&d);
        assert!(skew10 < skew2, "{skew10} !< {skew2}");
    }
}
