//! TOML-subset parser for config files (offline environment has no `toml`
//! crate). Supports: `[section]` headers, `key = value` with string,
//! integer, float, boolean and flat-array values, `#` comments, and blank
//! lines. This covers every config this project ships; nested tables and
//! multi-line strings are intentionally rejected with clear errors.

use std::collections::BTreeMap;

/// A parsed scalar value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Int(i) => Some(*i as f64),
            TomlValue::Float(f) => Some(*f),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            TomlValue::Int(i) if *i >= 0 => Some(*i as usize),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
}

/// `section -> key -> value`; keys before any `[section]` land in `""`.
pub type TomlDoc = BTreeMap<String, BTreeMap<String, TomlValue>>;

/// Parse a TOML-subset document.
pub fn parse(input: &str) -> Result<TomlDoc, String> {
    let mut doc: TomlDoc = BTreeMap::new();
    let mut section = String::new();
    doc.entry(section.clone()).or_default();

    for (lineno, raw) in input.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| format!("line {}: unterminated section header", lineno + 1))?
                .trim();
            if name.contains('[') || name.contains('.') {
                return Err(format!(
                    "line {}: nested tables are not supported ({name})",
                    lineno + 1
                ));
            }
            section = name.to_string();
            doc.entry(section.clone()).or_default();
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| format!("line {}: expected `key = value`", lineno + 1))?;
        let key = line[..eq].trim();
        if key.is_empty() {
            return Err(format!("line {}: empty key", lineno + 1));
        }
        let value = parse_value(line[eq + 1..].trim())
            .map_err(|e| format!("line {}: {e}", lineno + 1))?;
        doc.get_mut(&section)
            .unwrap()
            .insert(key.to_string(), value);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // A `#` inside a quoted string does not start a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or("unterminated string literal")?;
        return Ok(TomlValue::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?;
        let mut items = Vec::new();
        let trimmed = inner.trim();
        if !trimmed.is_empty() {
            for part in trimmed.split(',') {
                let part = part.trim();
                if part.is_empty() {
                    continue; // trailing comma
                }
                items.push(parse_value(part)?);
            }
        }
        return Ok(TomlValue::Array(items));
    }
    // Numbers: underscores allowed as digit separators.
    let cleaned = s.replace('_', "");
    if !cleaned.contains('.') && !cleaned.contains('e') && !cleaned.contains('E') {
        if let Ok(i) = cleaned.parse::<i64>() {
            return Ok(TomlValue::Int(i));
        }
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value `{s}`"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = parse(
            r#"
# experiment config
title = "fig3"

[radio]
subcarriers = 600
spacing_hz = 30_000.0
use_reuse = true
phis = [0.99, 0.9, 0.9, 0.9]
"#,
        )
        .unwrap();
        assert_eq!(doc[""]["title"], TomlValue::Str("fig3".into()));
        assert_eq!(doc["radio"]["subcarriers"], TomlValue::Int(600));
        assert_eq!(doc["radio"]["spacing_hz"], TomlValue::Float(30000.0));
        assert_eq!(doc["radio"]["use_reuse"], TomlValue::Bool(true));
        match &doc["radio"]["phis"] {
            TomlValue::Array(a) => assert_eq!(a.len(), 4),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn comment_inside_string_kept() {
        let doc = parse("name = \"a#b\" # real comment").unwrap();
        assert_eq!(doc[""]["name"], TomlValue::Str("a#b".into()));
    }

    #[test]
    fn rejects_nested_tables() {
        assert!(parse("[a.b]\nx = 1").is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("just a line").is_err());
        assert!(parse("x = ").is_err());
        assert!(parse("x = \"unterminated").is_err());
        assert!(parse("[unterminated").is_err());
    }

    #[test]
    fn scientific_notation() {
        let doc = parse("ber = 1e-3\nnoise = -1.5E2").unwrap();
        assert_eq!(doc[""]["ber"].as_f64(), Some(1e-3));
        assert_eq!(doc[""]["noise"].as_f64(), Some(-150.0));
    }

    #[test]
    fn value_accessors() {
        assert_eq!(TomlValue::Int(5).as_usize(), Some(5));
        assert_eq!(TomlValue::Int(-5).as_usize(), None);
        assert_eq!(TomlValue::Float(2.5).as_f64(), Some(2.5));
        assert_eq!(TomlValue::Bool(true).as_bool(), Some(true));
        assert_eq!(TomlValue::Str("x".into()).as_str(), Some("x"));
    }
}
