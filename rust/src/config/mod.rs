//! Typed configuration for the whole system: radio parameters (Table II),
//! topology (§V-A), sparsification (§IV), and training (§V-B). Configs are
//! constructed from presets, optionally overlaid from a TOML-subset file
//! ([`toml`]), and finally overridden by CLI flags.

pub mod toml;

use crate::util::math::dbm_to_watts;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Radio/PHY parameters — defaults are the paper's Table II.
#[derive(Clone, Debug, PartialEq)]
pub struct RadioConfig {
    /// Total number of OFDM sub-carriers `M`.
    pub subcarriers: usize,
    /// Sub-carrier spacing `B0` in Hz.
    pub subcarrier_spacing_hz: f64,
    /// Noise power spectral density in dBm/Hz (Table II: −150 dB).
    pub noise_psd_dbm_hz: f64,
    /// MBS maximum transmit power (W).
    pub mbs_power_w: f64,
    /// SBS maximum transmit power (W).
    pub sbs_power_w: f64,
    /// MU maximum transmit power (W).
    pub mu_power_w: f64,
    /// Path-loss exponent α.
    pub pathloss_exp: f64,
    /// Target bit error rate for M-QAM (Eq. 9).
    pub ber: f64,
    /// Rateless-broadcast slot duration `T_s` in seconds (paper leaves this
    /// implicit; we default to a 1 ms subframe).
    pub broadcast_slot_s: f64,
    /// SBS↔MBS fronthaul rate as a multiple of the *mean per-MU* UL rate
    /// (§V-A: "fronthaul link is 100 times faster").
    pub fronthaul_multiplier: f64,
}

impl Default for RadioConfig {
    fn default() -> Self {
        Self {
            subcarriers: 600,
            subcarrier_spacing_hz: 30_000.0,
            noise_psd_dbm_hz: -150.0,
            mbs_power_w: 20.0,
            sbs_power_w: 6.3,
            mu_power_w: 0.2,
            pathloss_exp: 2.8,
            ber: 1e-3,
            broadcast_slot_s: 1e-3,
            fronthaul_multiplier: 100.0,
        }
    }
}

impl RadioConfig {
    /// AWGN noise power on one sub-carrier, `N0·B0`, in Watts.
    pub fn noise_power_w(&self) -> f64 {
        dbm_to_watts(self.noise_psd_dbm_hz) * self.subcarrier_spacing_hz
    }

    pub fn validate(&self) -> Result<()> {
        if self.subcarriers == 0 {
            bail!("subcarriers must be > 0");
        }
        if self.subcarrier_spacing_hz <= 0.0 {
            bail!("subcarrier spacing must be > 0");
        }
        if !(0.0..0.5).contains(&self.ber) || self.ber <= 0.0 {
            bail!("BER must be in (0, 0.5), got {}", self.ber);
        }
        // Eq. (9) needs -ln(5·BER) > 0, i.e. BER < 0.2.
        if self.ber >= 0.2 {
            bail!("BER must be < 0.2 for the M-QAM rate formula");
        }
        for (name, p) in [
            ("mbs_power_w", self.mbs_power_w),
            ("sbs_power_w", self.sbs_power_w),
            ("mu_power_w", self.mu_power_w),
        ] {
            if p <= 0.0 {
                bail!("{name} must be > 0");
            }
        }
        if self.pathloss_exp < 1.0 || self.pathloss_exp > 6.0 {
            bail!("pathloss_exp {} outside sane range [1,6]", self.pathloss_exp);
        }
        Ok(())
    }
}

/// Network geometry — §V-A.
#[derive(Clone, Debug, PartialEq)]
pub struct TopologyConfig {
    /// Radius of the macro-cell disc (m).
    pub radius_m: f64,
    /// Diameter of the circle inscribed in each hexagonal cluster (m).
    pub hex_inscribed_diameter_m: f64,
    /// Number of SBS clusters `N` (paper: 7).
    pub n_clusters: usize,
    /// MUs per cluster (`|C_n|`, Assumption 1: equal).
    pub mus_per_cluster: usize,
    /// Number of reuse colors `N_c`. With the paper's 7-hex flower and
    /// reuse-1 pattern each cluster gets `M/N_c`; Figure 2's caption says
    /// reuse pattern one, which with the interference guard distance yields
    /// 3 colors for adjacent-hex separation. Exposed as config.
    pub reuse_colors: usize,
    /// Seed for MU placement.
    pub placement_seed: u64,
}

impl Default for TopologyConfig {
    fn default() -> Self {
        Self {
            radius_m: 750.0,
            hex_inscribed_diameter_m: 500.0,
            n_clusters: 7,
            mus_per_cluster: 4,
            reuse_colors: 3,
            placement_seed: 2019,
        }
    }
}

impl TopologyConfig {
    pub fn total_mus(&self) -> usize {
        self.n_clusters * self.mus_per_cluster
    }

    pub fn validate(&self) -> Result<()> {
        if self.radius_m <= 0.0 || self.hex_inscribed_diameter_m <= 0.0 {
            bail!("geometry lengths must be positive");
        }
        if self.n_clusters == 0 || self.mus_per_cluster == 0 {
            bail!("need at least one cluster and one MU per cluster");
        }
        if self.reuse_colors == 0 || self.reuse_colors > self.n_clusters {
            bail!(
                "reuse_colors must be in [1, n_clusters]; got {} vs {}",
                self.reuse_colors,
                self.n_clusters
            );
        }
        Ok(())
    }
}

/// Sparsification parameters φ for the four communication steps (§IV-A) and
/// the discounted-error factors (Alg. 5).
#[derive(Clone, Debug, PartialEq)]
pub struct SparsityConfig {
    pub enabled: bool,
    /// φ^ul_MU — MU → SBS (or MU → MBS for flat FL).
    pub phi_mu_ul: f64,
    /// φ^dl_SBS — SBS → MU.
    pub phi_sbs_dl: f64,
    /// φ^ul_SBS — SBS → MBS.
    pub phi_sbs_ul: f64,
    /// φ^dl_MBS — MBS → SBS.
    pub phi_mbs_dl: f64,
    /// β_m — discount for MBS error accumulation (Alg. 5 line 28).
    pub beta_m: f64,
    /// β_s — discount for SBS error accumulation (Alg. 5 line 21).
    pub beta_s: f64,
}

impl Default for SparsityConfig {
    fn default() -> Self {
        Self {
            enabled: true,
            phi_mu_ul: 0.99,
            phi_sbs_dl: 0.9,
            phi_sbs_ul: 0.9,
            phi_mbs_dl: 0.9,
            beta_m: 0.2,
            beta_s: 0.5,
        }
    }
}

impl SparsityConfig {
    /// A configuration with sparsification switched off (dense FL/HFL).
    pub fn dense() -> Self {
        Self {
            enabled: false,
            phi_mu_ul: 0.0,
            phi_sbs_dl: 0.0,
            phi_sbs_ul: 0.0,
            phi_mbs_dl: 0.0,
            ..Self::default()
        }
    }

    pub fn validate(&self) -> Result<()> {
        for (name, phi) in [
            ("phi_mu_ul", self.phi_mu_ul),
            ("phi_sbs_dl", self.phi_sbs_dl),
            ("phi_sbs_ul", self.phi_sbs_ul),
            ("phi_mbs_dl", self.phi_mbs_dl),
        ] {
            if !(0.0..1.0).contains(&phi) {
                bail!("{name} must be in [0,1), got {phi}");
            }
        }
        for (name, beta) in [("beta_m", self.beta_m), ("beta_s", self.beta_s)] {
            if !(0.0..=1.0).contains(&beta) {
                bail!("{name} must be in [0,1], got {beta}");
            }
        }
        Ok(())
    }
}

/// Model variants exported by the AOT pipeline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelKind {
    /// Multi-layer perceptron on flattened images.
    Mlp,
    /// Small CNN (conv-as-GEMM via the Pallas matmul kernel).
    Cnn,
}

impl ModelKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            ModelKind::Mlp => "mlp",
            ModelKind::Cnn => "cnn",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "mlp" => Ok(ModelKind::Mlp),
            "cnn" => Ok(ModelKind::Cnn),
            other => bail!("unknown model kind `{other}` (expected mlp|cnn)"),
        }
    }
}

/// Training hyper-parameters — §V-B.
#[derive(Clone, Debug, PartialEq)]
pub struct TrainingConfig {
    pub model: ModelKind,
    /// Per-MU minibatch size (paper: 64).
    pub batch_size: usize,
    /// Baseline LR for cumulative batch 128, scaled linearly with K·β/128
    /// (Goyal et al. trick the paper cites).
    pub base_lr: f64,
    /// Cap on the scaled LR. The paper quotes an initial LR of 0.25 even
    /// though the uncapped rule at 28×64 would give 1.4 — we mirror that
    /// (uncapped, our small MLP diverges just like theirs would).
    pub lr_cap: f64,
    /// Momentum σ.
    pub momentum: f64,
    /// Weight decay (not applied to BN params in the paper; our models have
    /// no BN so it applies to all weights).
    pub weight_decay: f64,
    /// Warm-up epochs (linear ramp).
    pub warmup_epochs: usize,
    /// Total epochs.
    pub epochs: usize,
    /// Learning-rate decay (×0.1) milestones as fractions of total epochs.
    pub decay_milestones: (f64, f64),
    /// Model-averaging period H (Alg. 3/5).
    pub h_period: usize,
    /// Experiment seed.
    pub seed: u64,
    /// Number of training samples in the synthetic dataset.
    pub train_samples: usize,
    /// Number of held-out test samples.
    pub test_samples: usize,
}

impl Default for TrainingConfig {
    fn default() -> Self {
        Self {
            model: ModelKind::Mlp,
            batch_size: 64,
            base_lr: 0.1,
            lr_cap: 0.25,
            momentum: 0.9,
            weight_decay: 1e-4,
            warmup_epochs: 5,
            epochs: 40,
            decay_milestones: (0.5, 0.75),
            h_period: 2,
            seed: 1,
            train_samples: 8960,
            test_samples: 2048,
        }
    }
}

impl TrainingConfig {
    /// Linear LR scaling rule, capped: η = min(base_lr · K·β/128, lr_cap).
    pub fn scaled_lr(&self, total_mus: usize) -> f64 {
        (self.base_lr * (total_mus as f64 * self.batch_size as f64) / 128.0).min(self.lr_cap)
    }

    pub fn validate(&self) -> Result<()> {
        if self.batch_size == 0 || self.epochs == 0 || self.h_period == 0 {
            bail!("batch_size, epochs and h_period must be > 0");
        }
        if self.base_lr <= 0.0 {
            bail!("base_lr must be > 0");
        }
        if !(0.0..1.0).contains(&self.momentum) {
            bail!("momentum must be in [0,1)");
        }
        let (a, b) = self.decay_milestones;
        if !(0.0 < a && a < b && b < 1.0) {
            bail!("decay milestones must satisfy 0 < a < b < 1");
        }
        Ok(())
    }
}

/// Latency-model parameters for the figure sweeps: the paper uses ResNet18's
/// parameter count for `Q` even though our training model is smaller.
#[derive(Clone, Debug, PartialEq)]
pub struct LatencyModelConfig {
    /// Number of model parameters `Q` used in the latency formulas.
    pub q_params: usize,
    /// Bits per parameter `Q̂` (32-bit floats).
    pub bits_per_param: u32,
    /// Monte-Carlo trials for broadcast-latency expectation (Eq. 18).
    pub mc_trials: usize,
    /// Channel-realization seed.
    pub channel_seed: u64,
}

impl Default for LatencyModelConfig {
    fn default() -> Self {
        Self {
            q_params: 11_173_962, // ResNet18 on CIFAR-10
            bits_per_param: 32,
            mc_trials: 200,
            channel_seed: 7,
        }
    }
}

impl LatencyModelConfig {
    pub fn validate(&self) -> Result<()> {
        if self.q_params == 0 || self.bits_per_param == 0 || self.mc_trials == 0 {
            bail!("latency-model sizes must be > 0");
        }
        Ok(())
    }
}

/// Discrete-event simulator knobs (`crate::des`): heterogeneous MU compute
/// profiles, the random-waypoint mobility defaults, and the deadline
/// straggler-policy defaults used by the `hfl des` scenario grids.
#[derive(Clone, Debug, PartialEq)]
pub struct DesConfig {
    /// Mean per-round gradient-compute time (s); 0 ⇒ instantaneous compute
    /// (communication-only timelines, the analytic cross-validation mode).
    pub compute_mean_s: f64,
    /// Lognormal heterogeneity σ of the per-MU mean compute speed.
    pub compute_het: f64,
    /// Random-waypoint walking speed (m/s) of the default mobility axis.
    pub waypoint_speed_mps: f64,
    /// Pause at each waypoint (s).
    pub waypoint_pause_s: f64,
    /// Deadline as a multiple of the cluster's expected slowest member
    /// round time (compute + uplink); < 1 cuts off stragglers.
    pub deadline_rel: f64,
    /// Weight applied to post-deadline (stale) updates folded into the next
    /// aggregation round; 0 discards them entirely.
    pub stale_discount: f64,
}

impl Default for DesConfig {
    fn default() -> Self {
        Self {
            compute_mean_s: 0.02,
            compute_het: 0.5,
            waypoint_speed_mps: 20.0,
            waypoint_pause_s: 10.0,
            deadline_rel: 0.9,
            stale_discount: 0.5,
        }
    }
}

impl DesConfig {
    pub fn validate(&self) -> Result<()> {
        for (name, v) in [
            ("compute_mean_s", self.compute_mean_s),
            ("compute_het", self.compute_het),
            ("waypoint_speed_mps", self.waypoint_speed_mps),
            ("waypoint_pause_s", self.waypoint_pause_s),
        ] {
            if v < 0.0 || !v.is_finite() {
                bail!("{name} must be finite and ≥ 0, got {v}");
            }
        }
        if self.deadline_rel <= 0.0 || !self.deadline_rel.is_finite() {
            bail!("deadline_rel must be > 0, got {}", self.deadline_rel);
        }
        if !(0.0..=1.0).contains(&self.stale_discount) {
            bail!("stale_discount must be in [0,1], got {}", self.stale_discount);
        }
        Ok(())
    }
}

/// Checkpoint/resume knobs (`crate::snapshot`): periodic engine snapshots
/// for `hfl train` / `hfl des` and the per-cell run log for `hfl matrix`.
/// CLI overrides: `--checkpoint-every N`, `--checkpoint PATH`, `--resume
/// PATH`.
#[derive(Clone, Debug, PartialEq)]
pub struct CheckpointConfig {
    /// Snapshot after every `every`-th completed round; 0 (the default)
    /// disables checkpointing. For `hfl matrix` any nonzero value enables
    /// the per-cell run log (cells checkpoint at cell granularity).
    pub every: usize,
    /// Directory for default snapshot / run-log paths.
    pub dir: String,
}

impl Default for CheckpointConfig {
    fn default() -> Self {
        Self {
            every: 0,
            dir: "checkpoints".into(),
        }
    }
}

impl CheckpointConfig {
    pub fn validate(&self) -> Result<()> {
        if self.dir.is_empty() {
            bail!("checkpoint dir must not be empty");
        }
        Ok(())
    }
}

/// Coordinator-as-a-service knobs (`crate::net`): where `hfl serve`
/// listens (and `hfl worker` connects), plus the optional live-metrics
/// endpoint and session log. CLI overrides: `--listen`/`--connect`,
/// `--metrics-addr`, `--session-log`.
#[derive(Clone, Debug, PartialEq)]
pub struct NetConfig {
    /// Default `hfl serve` listen address / `hfl worker` target.
    pub listen_addr: String,
    /// `GET /metrics` HTTP endpoint address; empty (the default) disables
    /// the endpoint.
    pub metrics_addr: String,
    /// Session message-log path for `hfl replay`; empty (the default)
    /// disables logging.
    pub session_log: String,
    /// Read/write timeout on every TCP transport, in milliseconds: a hung
    /// peer yields a named io-timeout error instead of wedging the MBS.
    /// 0 disables the bound. CLI override: `--io-timeout-ms`.
    pub io_timeout_ms: u64,
}

impl Default for NetConfig {
    fn default() -> Self {
        Self {
            listen_addr: "127.0.0.1:7070".into(),
            metrics_addr: String::new(),
            session_log: String::new(),
            // Generous: a full H-period of local compute plus aggregation
            // must fit comfortably under the bound.
            io_timeout_ms: 30_000,
        }
    }
}

impl NetConfig {
    pub fn validate(&self) -> Result<()> {
        if self.listen_addr.is_empty() {
            bail!("net listen_addr must not be empty");
        }
        Ok(())
    }

    /// The configured io timeout as a `Duration` (`None` when disabled).
    pub fn io_timeout(&self) -> Option<std::time::Duration> {
        (self.io_timeout_ms > 0).then(|| std::time::Duration::from_millis(self.io_timeout_ms))
    }
}

/// Persistent worker-pool knobs (`crate::pool`): the execution-lane budget
/// shared by the scenario matrix and the engines' intra-round fan-outs.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PoolConfig {
    /// Execution lanes (including the submitting thread) of a dedicated
    /// pool built at command startup; 0 (the default) keeps the lazily
    /// created process-wide shared pool sized to `available_parallelism`.
    /// CLI override: `--pool-threads N`.
    pub threads: usize,
}

impl PoolConfig {
    pub fn validate(&self) -> Result<()> {
        if self.threads > 4096 {
            bail!("pool threads {} outside sane range [0, 4096]", self.threads);
        }
        Ok(())
    }
}

/// Top-level experiment configuration.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Config {
    pub radio: RadioConfig,
    pub topology: TopologyConfig,
    pub sparsity: SparsityConfig,
    pub training: TrainingConfig,
    pub latency: LatencyModelConfig,
    pub des: DesConfig,
    pub pool: PoolConfig,
    pub checkpoint: CheckpointConfig,
    pub net: NetConfig,
    /// Deterministic fault injection (`crate::net::chaos`): a seeded
    /// fault plan applied to every serve/worker transport. `[chaos]`
    /// section / `--chaos-*` CLI flags; disabled by default, in which
    /// case every transport is the untouched status quo.
    pub chaos: crate::net::chaos::ChaosConfig,
    /// Aggregation dispatch (`crate::sparse::merge`): sparse k-way merge
    /// vs dense scatter at the SBS/MBS aggregation call sites. `[agg]
    /// path = "auto"|"sparse"|"dense"`, `[agg] crossover = 0.25`; CLI
    /// override `--agg-path`. Bit-identical for every setting. The
    /// consensus statistic (`[agg] rule = "mean"|"trimmed-mean"|
    /// "coord-median"`, `[agg] trim_k`; CLI `--agg-rule`/`--agg-trim`)
    /// changes the arithmetic — `mean` is the byte-identical default.
    pub agg: crate::sparse::merge::AggPolicy,
    /// Byzantine fault injection (`crate::adversary`): a seeded plan
    /// flipping a fraction of MUs to attacker behaviors at the post-DGC
    /// uplink boundary. `[adversary]` section / `--adversary-*` CLI
    /// flags; disabled by default, in which case every engine is the
    /// untouched honest run.
    pub adversary: crate::adversary::AdversaryPlan,
    /// Client churn + energy-budgeted participation for the DES engine
    /// (`crate::adversary::ChurnConfig`). `[churn]` section / `--churn-*`
    /// CLI flags; disabled by default.
    pub churn: crate::adversary::ChurnConfig,
}

impl Config {
    /// The paper's Table II preset (also the `Default`).
    pub fn paper_table2() -> Self {
        Self::default()
    }

    /// Quick preset for CI-sized smoke runs.
    pub fn smoke() -> Self {
        Self {
            latency: LatencyModelConfig {
                mc_trials: 10,
                ..Default::default()
            },
            training: TrainingConfig {
                epochs: 2,
                train_samples: 896,
                test_samples: 256,
                ..Default::default()
            },
            ..Default::default()
        }
    }

    pub fn validate(&self) -> Result<()> {
        self.radio.validate().context("radio")?;
        self.topology.validate().context("topology")?;
        self.sparsity.validate().context("sparsity")?;
        self.training.validate().context("training")?;
        self.latency.validate().context("latency")?;
        self.des.validate().context("des")?;
        self.pool.validate().context("pool")?;
        self.checkpoint.validate().context("checkpoint")?;
        self.net.validate().context("net")?;
        self.chaos.validate().context("chaos")?;
        self.agg.validate().context("agg")?;
        self.adversary.validate().context("adversary")?;
        self.churn.validate().context("churn")?;
        Ok(())
    }

    /// Load overrides from a TOML-subset file on top of `self`.
    pub fn overlay_file(mut self, path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading config {}", path.as_ref().display()))?;
        let doc = toml::parse(&text).map_err(|e| anyhow::anyhow!("config parse error: {e}"))?;
        for (section, entries) in &doc {
            for (key, value) in entries {
                self.apply_override(section, key, value).with_context(|| {
                    format!("applying [{section}] {key}")
                })?;
            }
        }
        Ok(self)
    }

    /// Apply one `section.key = value` override.
    pub fn apply_override(
        &mut self,
        section: &str,
        key: &str,
        value: &toml::TomlValue,
    ) -> Result<()> {
        use toml::TomlValue as V;
        let need_f64 = || -> Result<f64> {
            value
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("expected number, got {value:?}"))
        };
        let need_usize = || -> Result<usize> {
            value
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("expected non-negative integer, got {value:?}"))
        };
        match (section, key) {
            ("radio", "subcarriers") => self.radio.subcarriers = need_usize()?,
            ("radio", "subcarrier_spacing_hz") => self.radio.subcarrier_spacing_hz = need_f64()?,
            ("radio", "noise_psd_dbm_hz") => self.radio.noise_psd_dbm_hz = need_f64()?,
            ("radio", "mbs_power_w") => self.radio.mbs_power_w = need_f64()?,
            ("radio", "sbs_power_w") => self.radio.sbs_power_w = need_f64()?,
            ("radio", "mu_power_w") => self.radio.mu_power_w = need_f64()?,
            ("radio", "pathloss_exp") => self.radio.pathloss_exp = need_f64()?,
            ("radio", "ber") => self.radio.ber = need_f64()?,
            ("radio", "broadcast_slot_s") => self.radio.broadcast_slot_s = need_f64()?,
            ("radio", "fronthaul_multiplier") => self.radio.fronthaul_multiplier = need_f64()?,
            ("topology", "radius_m") => self.topology.radius_m = need_f64()?,
            ("topology", "hex_inscribed_diameter_m") => {
                self.topology.hex_inscribed_diameter_m = need_f64()?
            }
            ("topology", "n_clusters") => self.topology.n_clusters = need_usize()?,
            ("topology", "mus_per_cluster") => self.topology.mus_per_cluster = need_usize()?,
            ("topology", "reuse_colors") => self.topology.reuse_colors = need_usize()?,
            ("topology", "placement_seed") => self.topology.placement_seed = need_usize()? as u64,
            ("sparsity", "enabled") => {
                self.sparsity.enabled = value
                    .as_bool()
                    .ok_or_else(|| anyhow::anyhow!("expected bool"))?
            }
            ("sparsity", "phi_mu_ul") => self.sparsity.phi_mu_ul = need_f64()?,
            ("sparsity", "phi_sbs_dl") => self.sparsity.phi_sbs_dl = need_f64()?,
            ("sparsity", "phi_sbs_ul") => self.sparsity.phi_sbs_ul = need_f64()?,
            ("sparsity", "phi_mbs_dl") => self.sparsity.phi_mbs_dl = need_f64()?,
            ("sparsity", "beta_m") => self.sparsity.beta_m = need_f64()?,
            ("sparsity", "beta_s") => self.sparsity.beta_s = need_f64()?,
            ("training", "model") => {
                let V::Str(s) = value else {
                    bail!("expected string");
                };
                self.training.model = ModelKind::parse(s)?;
            }
            ("training", "batch_size") => self.training.batch_size = need_usize()?,
            ("training", "base_lr") => self.training.base_lr = need_f64()?,
            ("training", "lr_cap") => self.training.lr_cap = need_f64()?,
            ("training", "momentum") => self.training.momentum = need_f64()?,
            ("training", "weight_decay") => self.training.weight_decay = need_f64()?,
            ("training", "warmup_epochs") => self.training.warmup_epochs = need_usize()?,
            ("training", "epochs") => self.training.epochs = need_usize()?,
            ("training", "h_period") => self.training.h_period = need_usize()?,
            ("training", "seed") => self.training.seed = need_usize()? as u64,
            ("training", "train_samples") => self.training.train_samples = need_usize()?,
            ("training", "test_samples") => self.training.test_samples = need_usize()?,
            ("latency", "q_params") => self.latency.q_params = need_usize()?,
            ("latency", "bits_per_param") => self.latency.bits_per_param = need_usize()? as u32,
            ("latency", "mc_trials") => self.latency.mc_trials = need_usize()?,
            ("latency", "channel_seed") => self.latency.channel_seed = need_usize()? as u64,
            ("des", "compute_mean_s") => self.des.compute_mean_s = need_f64()?,
            ("des", "compute_het") => self.des.compute_het = need_f64()?,
            ("des", "waypoint_speed_mps") => self.des.waypoint_speed_mps = need_f64()?,
            ("des", "waypoint_pause_s") => self.des.waypoint_pause_s = need_f64()?,
            ("des", "deadline_rel") => self.des.deadline_rel = need_f64()?,
            ("des", "stale_discount") => self.des.stale_discount = need_f64()?,
            ("pool", "threads") => self.pool.threads = need_usize()?,
            ("checkpoint", "every") => self.checkpoint.every = need_usize()?,
            ("checkpoint", "dir") => {
                let V::Str(s) = value else {
                    bail!("expected string");
                };
                self.checkpoint.dir = s.clone();
            }
            ("net", "listen_addr") => {
                let V::Str(s) = value else {
                    bail!("expected string");
                };
                self.net.listen_addr = s.clone();
            }
            ("net", "metrics_addr") => {
                let V::Str(s) = value else {
                    bail!("expected string");
                };
                self.net.metrics_addr = s.clone();
            }
            ("net", "session_log") => {
                let V::Str(s) = value else {
                    bail!("expected string");
                };
                self.net.session_log = s.clone();
            }
            ("net", "io_timeout_ms") => self.net.io_timeout_ms = need_usize()? as u64,
            ("chaos", "enabled") => {
                self.chaos.enabled = value
                    .as_bool()
                    .ok_or_else(|| anyhow::anyhow!("expected bool"))?
            }
            ("chaos", "seed") => self.chaos.seed = need_usize()? as u64,
            ("chaos", "drop_p") => self.chaos.drop_p = need_f64()?,
            ("chaos", "delay_p") => self.chaos.delay_p = need_f64()?,
            ("chaos", "delay_ms") => self.chaos.delay_ms = need_usize()? as u64,
            ("chaos", "dup_p") => self.chaos.dup_p = need_f64()?,
            ("chaos", "truncate_p") => self.chaos.truncate_p = need_f64()?,
            ("chaos", "corrupt_p") => self.chaos.corrupt_p = need_f64()?,
            ("chaos", "kill_cluster") => self.chaos.kill_cluster = Some(need_usize()?),
            ("chaos", "kill_after") => self.chaos.kill_after = need_usize()? as u64,
            ("agg", "path") => {
                let V::Str(s) = value else {
                    bail!("expected string");
                };
                self.agg.path = crate::sparse::merge::AggPath::parse(s)?;
            }
            ("agg", "crossover") => self.agg.crossover = need_f64()?,
            ("agg", "rule") => {
                let V::Str(s) = value else {
                    bail!("expected string");
                };
                // Preserve an already-set trim depth across a re-parse.
                let k = match self.agg.rule {
                    crate::sparse::merge::AggRule::TrimmedMean(k) => k,
                    _ => 1,
                };
                self.agg.rule = crate::sparse::merge::AggRule::parse(s, k)?;
            }
            ("agg", "trim_k") => match self.agg.rule {
                crate::sparse::merge::AggRule::TrimmedMean(_) => {
                    self.agg.rule = crate::sparse::merge::AggRule::TrimmedMean(need_usize()?)
                }
                _ => bail!("[agg] trim_k requires rule = \"trimmed-mean\" (set rule first)"),
            },
            ("adversary", "enabled") => {
                self.adversary.enabled = value
                    .as_bool()
                    .ok_or_else(|| anyhow::anyhow!("expected bool"))?
            }
            ("adversary", "seed") => self.adversary.seed = need_usize()? as u64,
            ("adversary", "fraction") => self.adversary.fraction = need_f64()?,
            ("adversary", "scale") => self.adversary.scale = need_f64()? as f32,
            ("adversary", "garbage_std") => self.adversary.garbage_std = need_f64()? as f32,
            ("churn", "enabled") => {
                self.churn.enabled = value
                    .as_bool()
                    .ok_or_else(|| anyhow::anyhow!("expected bool"))?
            }
            ("churn", "seed") => self.churn.seed = need_usize()? as u64,
            ("churn", "drop_p") => self.churn.drop_p = need_f64()?,
            ("churn", "rejoin_p") => self.churn.rejoin_p = need_f64()?,
            ("churn", "energy") => self.churn.energy = need_f64()?,
            (s, k) => bail!("unknown config key [{s}] {k}"),
        }
        Ok(())
    }

    /// Render the active configuration as a Table II-style listing.
    pub fn render_table(&self) -> String {
        let r = &self.radio;
        let t = &self.topology;
        let s = &self.sparsity;
        format!(
            "Simulation parameters (cf. paper Table II)\n\
             -------------------------------------------\n\
             Number of sub-carriers      M = {}\n\
             Sub-carrier spacing         B0 = {} kHz\n\
             Noise PSD                   {} dBm/Hz\n\
             MBS Tx power                {} W\n\
             SBS Tx power                {} W\n\
             MU Tx power                 {} W\n\
             Path-loss exponent          α = {}\n\
             BER                         {:e}\n\
             Clusters                    N = {} (reuse colors {})\n\
             MUs per cluster             {}\n\
             Cell radius                 {} m (hex inscribed ∅ {} m)\n\
             Fronthaul multiplier        ×{}\n\
             Sparsity φ (MUul,SBSdl,SBSul,MBSdl) = ({}, {}, {}, {}) enabled={}\n\
             Error discounts             β_m={} β_s={}\n",
            r.subcarriers,
            r.subcarrier_spacing_hz / 1e3,
            r.noise_psd_dbm_hz,
            r.mbs_power_w,
            r.sbs_power_w,
            r.mu_power_w,
            r.pathloss_exp,
            r.ber,
            t.n_clusters,
            t.reuse_colors,
            t.mus_per_cluster,
            t.radius_m,
            t.hex_inscribed_diameter_m,
            r.fronthaul_multiplier,
            s.phi_mu_ul,
            s.phi_sbs_dl,
            s.phi_sbs_ul,
            s.phi_mbs_dl,
            s.enabled,
            s.beta_m,
            s.beta_s,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_table2_and_valid() {
        let c = Config::paper_table2();
        c.validate().unwrap();
        assert_eq!(c.radio.subcarriers, 600);
        assert_eq!(c.radio.mbs_power_w, 20.0);
        assert_eq!(c.radio.sbs_power_w, 6.3);
        assert_eq!(c.radio.mu_power_w, 0.2);
        assert_eq!(c.radio.pathloss_exp, 2.8);
        assert_eq!(c.topology.n_clusters, 7);
        assert_eq!(c.sparsity.phi_mu_ul, 0.99);
        assert_eq!(c.sparsity.beta_m, 0.2);
        assert_eq!(c.sparsity.beta_s, 0.5);
    }

    #[test]
    fn noise_power_matches_hand_calc() {
        let r = RadioConfig::default();
        // -150 dBm/Hz = 1e-18 W/Hz; ×30 kHz = 3e-14 W
        let w = r.noise_power_w();
        assert!((w - 3e-14).abs() / 3e-14 < 1e-9, "{w}");
    }

    #[test]
    fn scaled_lr_rule() {
        let t = TrainingConfig::default();
        // 28 MUs × batch 64 = 1792; uncapped rule gives 1.4 but the cap
        // pins it to the paper's quoted 0.25.
        assert!((t.scaled_lr(28) - 0.25).abs() < 1e-12);
        assert!((t.scaled_lr(5) - 0.25).abs() < 1e-12);
        // Below the cap the linear rule applies: 2×64/128 × 0.1 = 0.1.
        assert!((t.scaled_lr(2) - 0.1).abs() < 1e-12);
    }

    #[test]
    fn validation_catches_bad_values() {
        let mut c = Config::default();
        c.radio.ber = 0.3;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.sparsity.phi_mu_ul = 1.0;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.topology.reuse_colors = 99;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.training.decay_milestones = (0.8, 0.5);
        assert!(c.validate().is_err());
    }

    #[test]
    fn overlay_round_trip() {
        let dir = std::env::temp_dir().join("hfl_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("override.toml");
        std::fs::write(
            &path,
            "[radio]\nsubcarriers = 300\npathloss_exp = 3.5\n[sparsity]\nenabled = false\n[training]\nmodel = \"cnn\"\nh_period = 6\n",
        )
        .unwrap();
        let c = Config::default().overlay_file(&path).unwrap();
        assert_eq!(c.radio.subcarriers, 300);
        assert_eq!(c.radio.pathloss_exp, 3.5);
        assert!(!c.sparsity.enabled);
        assert_eq!(c.training.model, ModelKind::Cnn);
        assert_eq!(c.training.h_period, 6);
    }

    #[test]
    fn des_defaults_valid_and_overridable() {
        let c = Config::default();
        c.des.validate().unwrap();
        let mut c = Config::default();
        c.apply_override("des", "deadline_rel", &toml::TomlValue::Float(0.7))
            .unwrap();
        c.apply_override("des", "stale_discount", &toml::TomlValue::Float(0.0))
            .unwrap();
        assert_eq!(c.des.deadline_rel, 0.7);
        assert_eq!(c.des.stale_discount, 0.0);
        c.des.stale_discount = 1.5;
        assert!(c.validate().is_err());
    }

    #[test]
    fn pool_defaults_shared_and_overridable() {
        let c = Config::default();
        assert_eq!(c.pool.threads, 0, "default must defer to the shared pool");
        c.pool.validate().unwrap();
        let mut c = Config::default();
        c.apply_override("pool", "threads", &toml::TomlValue::Int(6))
            .unwrap();
        assert_eq!(c.pool.threads, 6);
        c.validate().unwrap();
        c.pool.threads = 100_000;
        assert!(c.validate().is_err());
    }

    #[test]
    fn agg_defaults_auto_and_overridable() {
        use crate::sparse::merge::{AggPath, AGG_DENSITY_CROSSOVER};
        let c = Config::default();
        assert_eq!(c.agg.path, AggPath::Auto);
        assert_eq!(c.agg.crossover, AGG_DENSITY_CROSSOVER);
        c.agg.validate().unwrap();
        let mut c = Config::default();
        c.apply_override("agg", "path", &toml::TomlValue::Str("sparse".into()))
            .unwrap();
        c.apply_override("agg", "crossover", &toml::TomlValue::Float(0.5))
            .unwrap();
        assert_eq!(c.agg.path, AggPath::Sparse);
        assert_eq!(c.agg.crossover, 0.5);
        c.validate().unwrap();
        assert!(c
            .apply_override("agg", "path", &toml::TomlValue::Str("fast".into()))
            .is_err());
        c.agg.crossover = 0.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn agg_rule_defaults_mean_and_overridable() {
        use crate::sparse::merge::AggRule;
        let c = Config::default();
        assert_eq!(c.agg.rule, AggRule::Mean);
        let mut c = Config::default();
        c.apply_override("agg", "rule", &toml::TomlValue::Str("trimmed-mean".into()))
            .unwrap();
        assert_eq!(c.agg.rule, AggRule::TrimmedMean(1));
        c.apply_override("agg", "trim_k", &toml::TomlValue::Int(2)).unwrap();
        assert_eq!(c.agg.rule, AggRule::TrimmedMean(2));
        c.apply_override("agg", "rule", &toml::TomlValue::Str("trimmed-mean".into()))
            .unwrap();
        assert_eq!(c.agg.rule, AggRule::TrimmedMean(2), "re-parse preserves trim depth");
        c.validate().unwrap();
        c.apply_override("agg", "rule", &toml::TomlValue::Str("coord-median".into()))
            .unwrap();
        assert_eq!(c.agg.rule, AggRule::CoordMedian);
        assert!(c.apply_override("agg", "trim_k", &toml::TomlValue::Int(2)).is_err());
        assert!(c
            .apply_override("agg", "rule", &toml::TomlValue::Str("krum".into()))
            .is_err());
        // k = 0 trimmed-mean is refused at validation, with the section name.
        let mut c = Config::default();
        c.agg.rule = AggRule::TrimmedMean(0);
        let err = c.validate().unwrap_err();
        assert!(format!("{err:#}").contains("agg"), "{err:#}");
    }

    #[test]
    fn adversary_and_churn_default_off_and_overridable() {
        let c = Config::default();
        assert!(!c.adversary.enabled);
        assert!(!c.churn.enabled);
        c.validate().unwrap();

        let mut c = Config::default();
        c.apply_override("adversary", "enabled", &toml::TomlValue::Bool(true))
            .unwrap();
        c.apply_override("adversary", "seed", &toml::TomlValue::Int(11)).unwrap();
        c.apply_override("adversary", "fraction", &toml::TomlValue::Float(0.25))
            .unwrap();
        c.apply_override("adversary", "scale", &toml::TomlValue::Float(5.0))
            .unwrap();
        c.apply_override("adversary", "garbage_std", &toml::TomlValue::Float(2.0))
            .unwrap();
        assert!(c.adversary.enabled);
        assert_eq!(c.adversary.seed, 11);
        assert_eq!(c.adversary.fraction, 0.25);
        assert_eq!(c.adversary.scale, 5.0);
        c.validate().unwrap();
        c.adversary.fraction = 1.5;
        let err = c.validate().unwrap_err();
        assert!(format!("{err:#}").contains("adversary"), "{err:#}");

        let mut c = Config::default();
        c.apply_override("churn", "enabled", &toml::TomlValue::Bool(true)).unwrap();
        c.apply_override("churn", "drop_p", &toml::TomlValue::Float(0.2)).unwrap();
        c.apply_override("churn", "rejoin_p", &toml::TomlValue::Float(0.7)).unwrap();
        c.apply_override("churn", "energy", &toml::TomlValue::Int(6)).unwrap();
        assert!(c.churn.enabled);
        assert_eq!(c.churn.drop_p, 0.2);
        assert_eq!(c.churn.energy, 6.0);
        c.validate().unwrap();
        c.churn.drop_p = -0.5;
        let err = c.validate().unwrap_err();
        assert!(format!("{err:#}").contains("churn"), "{err:#}");
    }

    #[test]
    fn checkpoint_defaults_off_and_overridable() {
        let c = Config::default();
        assert_eq!(c.checkpoint.every, 0, "checkpointing must default to off");
        assert_eq!(c.checkpoint.dir, "checkpoints");
        c.checkpoint.validate().unwrap();
        let mut c = Config::default();
        c.apply_override("checkpoint", "every", &toml::TomlValue::Int(5))
            .unwrap();
        c.apply_override("checkpoint", "dir", &toml::TomlValue::Str("snaps".into()))
            .unwrap();
        assert_eq!(c.checkpoint.every, 5);
        assert_eq!(c.checkpoint.dir, "snaps");
        c.validate().unwrap();
        c.checkpoint.dir.clear();
        assert!(c.validate().is_err());
    }

    #[test]
    fn net_defaults_localhost_and_overridable() {
        let c = Config::default();
        assert_eq!(c.net.listen_addr, "127.0.0.1:7070");
        assert!(c.net.metrics_addr.is_empty(), "metrics must default to off");
        assert!(c.net.session_log.is_empty(), "session log must default to off");
        c.net.validate().unwrap();
        let mut c = Config::default();
        c.apply_override("net", "listen_addr", &toml::TomlValue::Str("0.0.0.0:9000".into()))
            .unwrap();
        c.apply_override("net", "metrics_addr", &toml::TomlValue::Str("127.0.0.1:9100".into()))
            .unwrap();
        c.apply_override("net", "session_log", &toml::TomlValue::Str("s.hlog".into()))
            .unwrap();
        assert_eq!(c.net.listen_addr, "0.0.0.0:9000");
        assert_eq!(c.net.metrics_addr, "127.0.0.1:9100");
        assert_eq!(c.net.session_log, "s.hlog");
        c.validate().unwrap();
        c.net.listen_addr.clear();
        assert!(c.validate().is_err());
    }

    #[test]
    fn chaos_defaults_off_and_overridable() {
        let c = Config::default();
        assert!(!c.chaos.enabled, "chaos must default to off");
        assert_eq!(c.net.io_timeout_ms, 30_000);
        assert_eq!(c.net.io_timeout(), Some(std::time::Duration::from_secs(30)));

        let mut c = Config::default();
        c.apply_override("chaos", "enabled", &toml::TomlValue::Bool(true))
            .unwrap();
        c.apply_override("chaos", "seed", &toml::TomlValue::Int(42))
            .unwrap();
        c.apply_override("chaos", "drop_p", &toml::TomlValue::Float(0.1))
            .unwrap();
        c.apply_override("chaos", "delay_ms", &toml::TomlValue::Int(5))
            .unwrap();
        c.apply_override("chaos", "kill_cluster", &toml::TomlValue::Int(1))
            .unwrap();
        c.apply_override("chaos", "kill_after", &toml::TomlValue::Int(7))
            .unwrap();
        c.apply_override("net", "io_timeout_ms", &toml::TomlValue::Int(0))
            .unwrap();
        assert!(c.chaos.enabled);
        assert_eq!(c.chaos.seed, 42);
        assert_eq!(c.chaos.kill_cluster, Some(1));
        assert_eq!(c.chaos.kill_after, 7);
        assert_eq!(c.net.io_timeout(), None, "0 disables the io bound");
        c.validate().unwrap();

        c.chaos.drop_p = 2.0;
        let err = c.validate().unwrap_err();
        assert!(format!("{err:#}").contains("chaos"), "{err:#}");
    }

    #[test]
    fn unknown_key_is_error() {
        let mut c = Config::default();
        let v = toml::TomlValue::Int(1);
        assert!(c.apply_override("radio", "nope", &v).is_err());
    }

    #[test]
    fn render_table_mentions_key_params() {
        let s = Config::default().render_table();
        assert!(s.contains("M = 600"));
        assert!(s.contains("α = 2.8"));
        assert!(s.contains("0.99"));
    }
}
