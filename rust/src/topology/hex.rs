//! Hexagonal cluster layout and frequency-reuse coloring (§V-A, Fig. 2).
//!
//! Clusters are flat-top hexagons with inscribed-circle diameter `d` (500 m
//! in the paper), arranged as a "flower": one central hexagon (whose SBS
//! co-locates with the MBS at the origin) surrounded by rings of six,
//! twelve, ... neighbours. Adjacent hexagon centres are exactly `d` apart.
//!
//! The reuse coloring assigns different sub-carrier groups to any two
//! clusters closer than the interference guard distance `D_th`; the paper
//! assumes zero interference beyond `D_th`. Greedy smallest-available-color
//! on the conflict graph reproduces the paper's 3-color pattern for the
//! 7-cluster flower.

use super::geometry::Point;

/// Centres of the first `n` hexagons of the flower layout, ring by ring.
///
/// `center_dist` is the distance between adjacent centres (= the inscribed
/// diameter). Supports up to 3 rings (1 + 6 + 12 + 18 = 37 clusters).
pub fn hex_centers(n: usize, center_dist: f64) -> Vec<Point> {
    assert!(n >= 1 && n <= 37, "hex flower supports 1..=37 clusters, got {n}");
    let mut out = vec![Point::ORIGIN];
    // Ring r has 6r cells: start at angle 90° (top) and walk around using
    // axial-coordinate steps; equivalently place by polar formula per ring.
    // Simpler: generate cube coordinates of rings and convert.
    let mut ring = 1;
    while out.len() < n {
        out.extend(ring_centers(ring, center_dist));
        ring += 1;
    }
    out.truncate(n);
    out
}

/// Centres of hex ring `r` (6r cells), axial→cartesian for flat-top hexes
/// with adjacent-centre distance `d`.
fn ring_centers(r: usize, d: f64) -> Vec<Point> {
    // Cube coordinates: start at (r, -r, 0)·direction and walk 6 edges.
    const DIRS: [(i64, i64); 6] = [(0, 1), (-1, 1), (-1, 0), (0, -1), (1, -1), (1, 0)];
    let mut cells = Vec::with_capacity(6 * r);
    // start cell: r steps in direction 4 from origin = (r·1, r·-1)
    let (mut q, mut s) = (r as i64, -(r as i64));
    for dir in DIRS {
        for _ in 0..r {
            cells.push(axial_to_point(q, s, d));
            q += dir.0;
            s += dir.1;
        }
    }
    cells
}

/// Axial (q, r) → cartesian for flat-top orientation, neighbour distance d.
fn axial_to_point(q: i64, r: i64, d: f64) -> Point {
    // Flat-top: x = d·(3/2/√3)·q ... use standard: x = d·(√3/2·q? )
    // For neighbour distance d: x = d·(q + r/2·0)... derive simply:
    // unit axial basis for pointy-top with size s: x = s·√3·(q + r/2), y = s·3/2·r,
    // neighbour distance = s·√3. Set s·√3 = d.
    let s = d / 3f64.sqrt();
    let x = s * 3f64.sqrt() * (q as f64 + r as f64 / 2.0);
    let y = s * 1.5 * r as f64;
    Point::new(x, y)
}

/// A complete cluster layout: centres plus reuse coloring.
#[derive(Clone, Debug)]
pub struct HexLayout {
    /// Cluster centres (SBS positions). Index 0 is the central cluster.
    pub centers: Vec<Point>,
    /// Inscribed-circle radius (apothem) of each hexagon.
    pub apothem: f64,
    /// Reuse color of each cluster.
    pub colors: Vec<usize>,
    /// Number of distinct colors `N_c`.
    pub n_colors: usize,
    /// Interference guard distance used for the coloring.
    pub d_th: f64,
}

impl HexLayout {
    /// Build the flower layout for `n_clusters` hexagons with inscribed
    /// diameter `inscribed_diameter` and colour it with guard distance
    /// `d_th` (clusters strictly closer than `d_th` conflict).
    pub fn new(n_clusters: usize, inscribed_diameter: f64, d_th: f64) -> Self {
        let centers = hex_centers(n_clusters, inscribed_diameter);
        let colors = greedy_coloring(&centers, d_th);
        let n_colors = colors.iter().copied().max().unwrap_or(0) + 1;
        Self {
            centers,
            apothem: inscribed_diameter / 2.0,
            colors,
            n_colors,
            d_th,
        }
    }

    /// Default guard distance: anything closer than `√3 ×` the adjacent
    /// centre distance conflicts — this forbids sharing between edge-adjacent
    /// clusters but allows the 1-ring "opposite" cells, reproducing the
    /// paper's Fig. 2 pattern (3 colors for the 7-flower).
    pub fn with_default_guard(n_clusters: usize, inscribed_diameter: f64) -> Self {
        let d_th = inscribed_diameter * 3f64.sqrt() * 0.999;
        Self::new(n_clusters, inscribed_diameter, d_th)
    }

    /// Sub-carriers available per cluster when `m_total` are split evenly
    /// across colors (§III-A: "proportional to 1/N_c").
    pub fn subcarriers_per_cluster(&self, m_total: usize) -> usize {
        (m_total / self.n_colors).max(1)
    }

    /// Index of the cluster centre nearest to `p` (lowest index wins ties) —
    /// the association rule the DES mobility model uses for handover.
    pub fn nearest_center(&self, p: &Point) -> usize {
        let mut best = 0usize;
        let mut best_d = f64::INFINITY;
        for (i, c) in self.centers.iter().enumerate() {
            let d = p.dist(c);
            if d < best_d {
                best_d = d;
                best = i;
            }
        }
        best
    }

    /// Minimum distance between same-color cluster centres (∞ if unique).
    pub fn min_cochannel_distance(&self) -> f64 {
        let mut best = f64::INFINITY;
        for i in 0..self.centers.len() {
            for j in i + 1..self.centers.len() {
                if self.colors[i] == self.colors[j] {
                    best = best.min(self.centers[i].dist(&self.centers[j]));
                }
            }
        }
        best
    }
}

/// Greedy smallest-available-color on the distance-conflict graph.
fn greedy_coloring(centers: &[Point], d_th: f64) -> Vec<usize> {
    let n = centers.len();
    let mut colors = vec![usize::MAX; n];
    for i in 0..n {
        let mut used = vec![false; n + 1];
        for j in 0..i {
            if centers[i].dist(&centers[j]) < d_th {
                used[colors[j]] = true;
            }
        }
        colors[i] = (0..=n).find(|&c| !used[c]).unwrap();
    }
    colors
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flower_of_seven_geometry() {
        let centers = hex_centers(7, 500.0);
        assert_eq!(centers.len(), 7);
        assert_eq!(centers[0], Point::ORIGIN);
        // Ring 1: all at distance 500 from the origin.
        for c in &centers[1..] {
            assert!((c.dist(&Point::ORIGIN) - 500.0).abs() < 1e-9, "{c:?}");
        }
        // Consecutive ring cells are adjacent (distance 500).
        for k in 1..=6 {
            let a = &centers[k];
            let b = &centers[if k == 6 { 1 } else { k + 1 }];
            assert!((a.dist(b) - 500.0).abs() < 1e-6, "{a:?} {b:?}");
        }
    }

    #[test]
    fn two_rings_count_and_distinct() {
        let centers = hex_centers(19, 500.0);
        assert_eq!(centers.len(), 19);
        for i in 0..19 {
            for j in i + 1..19 {
                assert!(centers[i].dist(&centers[j]) > 1.0, "duplicate centres {i},{j}");
            }
        }
        // Ring 2 cells are at distance 500·√3 or 1000 from origin.
        for c in &centers[7..] {
            let d = c.dist(&Point::ORIGIN);
            let ok = (d - 500.0 * 3f64.sqrt()).abs() < 1e-6 || (d - 1000.0).abs() < 1e-6;
            assert!(ok, "ring-2 distance {d}");
        }
    }

    #[test]
    fn seven_flower_colors_like_paper() {
        let layout = HexLayout::with_default_guard(7, 500.0);
        assert_eq!(layout.n_colors, 3, "colors={:?}", layout.colors);
        // Centre differs from every ring cell.
        for k in 1..7 {
            assert_ne!(layout.colors[0], layout.colors[k]);
        }
        // Same-color clusters separated by ≥ guard distance.
        assert!(layout.min_cochannel_distance() >= layout.d_th);
    }

    #[test]
    fn coloring_respects_guard_distance_generally() {
        for n in [1usize, 3, 7, 12, 19, 37] {
            for guard_mult in [1.1, 1.8, 2.5] {
                let layout = HexLayout::new(n, 500.0, 500.0 * guard_mult);
                for i in 0..n {
                    for j in i + 1..n {
                        if layout.colors[i] == layout.colors[j] {
                            assert!(
                                layout.centers[i].dist(&layout.centers[j]) >= layout.d_th,
                                "n={n} guard={guard_mult} clusters {i},{j}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn single_cluster_gets_everything() {
        let layout = HexLayout::with_default_guard(1, 500.0);
        assert_eq!(layout.n_colors, 1);
        assert_eq!(layout.subcarriers_per_cluster(600), 600);
    }

    #[test]
    fn nearest_center_matches_geometry() {
        let layout = HexLayout::with_default_guard(7, 500.0);
        // Each centre is its own nearest cluster.
        for (i, c) in layout.centers.iter().enumerate() {
            assert_eq!(layout.nearest_center(c), i);
        }
        // A point just beside a ring-1 centre associates to that cluster,
        // not the central one.
        let c1 = layout.centers[1];
        let p = Point::new(c1.x + 10.0, c1.y - 10.0);
        assert_eq!(layout.nearest_center(&p), 1);
        // The origin belongs to the central cluster.
        assert_eq!(layout.nearest_center(&Point::ORIGIN), 0);
    }

    #[test]
    fn subcarrier_split() {
        let layout = HexLayout::with_default_guard(7, 500.0);
        assert_eq!(layout.subcarriers_per_cluster(600), 200);
    }
}
