//! Planar geometry primitives for the cell layout.

/// A point in the plane, metres.
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub struct Point {
    pub x: f64,
    pub y: f64,
}

impl Point {
    pub const ORIGIN: Point = Point { x: 0.0, y: 0.0 };

    pub fn new(x: f64, y: f64) -> Self {
        Self { x, y }
    }

    pub fn dist(&self, other: &Point) -> f64 {
        ((self.x - other.x).powi(2) + (self.y - other.y).powi(2)).sqrt()
    }

    pub fn norm(&self) -> f64 {
        self.dist(&Point::ORIGIN)
    }

    pub fn add(&self, other: &Point) -> Point {
        Point::new(self.x + other.x, self.y + other.y)
    }
}

/// Is `p` inside the flat-top regular hexagon centred at `c` with
/// inscribed-circle radius (apothem) `r_in`?
///
/// A flat-top hexagon with apothem `a` satisfies, for the offset
/// `(dx, dy) = p − c`:  |dy| ≤ a  and  |dy|·(1/√3) + |dx| · (2/√3) ≤ 2a/√3·...
/// We use the standard half-plane test against the three edge normals.
pub fn in_hexagon(p: &Point, c: &Point, r_in: f64) -> bool {
    // Pointy-top hexagon via axial symmetry: normals at 0°, 60°, 120°.
    let dx = (p.x - c.x).abs();
    let dy = (p.y - c.y).abs();
    // Flat-top orientation: apothem along y for the horizontal edge pair.
    // Half-planes: x·cos(θ) + y·sin(θ) ≤ r_in for θ ∈ {90°, 30°, 150°}.
    let eps = 1e-9;
    dy <= r_in + eps && (dx * (3f64.sqrt() / 2.0) + dy * 0.5) <= r_in + eps
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distance_and_norm() {
        let a = Point::new(3.0, 4.0);
        assert!((a.norm() - 5.0).abs() < 1e-12);
        assert!((a.dist(&Point::new(3.0, 0.0)) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn hexagon_contains_center_and_apothem_points() {
        let c = Point::ORIGIN;
        let r = 250.0;
        assert!(in_hexagon(&c, &c, r));
        // Points just inside the apothem along the edge normals.
        assert!(in_hexagon(&Point::new(0.0, r - 1.0), &c, r));
        assert!(in_hexagon(&Point::new((r - 1.0) * 2.0 / 3f64.sqrt(), 0.0), &c, r));
        // Outside beyond the circumradius.
        let r_out = 2.0 * r / 3f64.sqrt();
        assert!(!in_hexagon(&Point::new(r_out + 1.0, 0.0), &c, r));
        assert!(!in_hexagon(&Point::new(0.0, r + 1.0), &c, r));
    }

    #[test]
    fn hexagon_corner_cases() {
        let c = Point::ORIGIN;
        let r = 1.0;
        // Circumradius corner along x at 2/√3 (flat-top, corner on x-axis).
        let corner = Point::new(2.0 / 3f64.sqrt() - 1e-6, 0.0);
        assert!(in_hexagon(&corner, &c, r));
    }
}
