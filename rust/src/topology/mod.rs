//! Network geometry (§V-A): a circular macro-cell of radius 750 m containing
//! a flower of hexagonal SBS clusters (inscribed-circle diameter 500 m),
//! uniformly-placed MUs, and a frequency-reuse coloring that guarantees
//! co-channel clusters are separated by at least the interference guard
//! distance `D_th`.

pub mod geometry;
pub mod hex;
pub mod placement;

pub use geometry::Point;
pub use hex::{hex_centers, HexLayout};
pub use placement::{NetworkTopology, UserPlacement};
