//! Mobile-user placement (§V-A, Assumptions 1–2): MUs are uniformly
//! distributed, each cluster contains an equal number of MUs, and SBSs sit
//! at cluster centres. The macro-cell is a disc of radius 750 m centred on
//! the MBS.

use super::geometry::{in_hexagon, Point};
use super::hex::HexLayout;
use crate::config::TopologyConfig;
use crate::util::rng::Pcg64;

/// One placed mobile user.
#[derive(Clone, Debug)]
pub struct UserPlacement {
    /// Global MU index.
    pub id: usize,
    /// Cluster (SBS) index.
    pub cluster: usize,
    pub pos: Point,
    /// Distance to the serving SBS (cluster centre).
    pub dist_sbs: f64,
    /// Distance to the MBS (origin) — used by the flat-FL baseline.
    pub dist_mbs: f64,
}

/// A fully instantiated network: layout + users.
#[derive(Clone, Debug)]
pub struct NetworkTopology {
    pub layout: HexLayout,
    pub users: Vec<UserPlacement>,
    pub radius_m: f64,
}

impl NetworkTopology {
    /// Build the topology from config. MUs are sampled uniformly inside each
    /// cluster's hexagon (rejection sampling), clipped to the macro disc —
    /// equal per-cluster counts per Assumption 1.
    pub fn generate(cfg: &TopologyConfig) -> Self {
        let layout = HexLayout::with_default_guard(cfg.n_clusters, cfg.hex_inscribed_diameter_m);
        let mut rng = Pcg64::new(cfg.placement_seed, 0xD0_F0);
        let mut users = Vec::with_capacity(cfg.total_mus());
        let apothem = layout.apothem;
        for (ci, center) in layout.centers.iter().enumerate() {
            for _ in 0..cfg.mus_per_cluster {
                let pos = sample_in_hex_and_disc(&mut rng, center, apothem, cfg.radius_m);
                let id = users.len();
                users.push(UserPlacement {
                    id,
                    cluster: ci,
                    dist_sbs: pos.dist(center).max(1.0), // ≥1 m: avoid d^−α blow-up
                    dist_mbs: pos.norm().max(1.0),
                    pos,
                });
            }
        }
        Self {
            layout,
            users,
            radius_m: cfg.radius_m,
        }
    }

    /// Users of one cluster.
    pub fn cluster_users(&self, cluster: usize) -> impl Iterator<Item = &UserPlacement> {
        self.users.iter().filter(move |u| u.cluster == cluster)
    }

    /// Distances MU→MBS for all users (flat FL uplink).
    pub fn mbs_distances(&self) -> Vec<f64> {
        self.users.iter().map(|u| u.dist_mbs).collect()
    }

    /// Distances MU→SBS per cluster.
    pub fn sbs_distances(&self, cluster: usize) -> Vec<f64> {
        self.cluster_users(cluster).map(|u| u.dist_sbs).collect()
    }

    /// SBS→MBS distances (fronthaul path lengths; informational).
    pub fn sbs_mbs_distances(&self) -> Vec<f64> {
        self.layout
            .centers
            .iter()
            .map(|c| c.norm().max(1.0))
            .collect()
    }

    pub fn n_clusters(&self) -> usize {
        self.layout.centers.len()
    }

    /// ASCII rendering of the layout for `topology_report`.
    pub fn ascii_map(&self, width: usize, height: usize) -> String {
        let mut grid = vec![vec![' '; width]; height];
        let scale_x = (2.2 * self.radius_m) / width as f64;
        let scale_y = (2.2 * self.radius_m) / height as f64;
        let to_cell = |p: &Point| -> Option<(usize, usize)> {
            let col = ((p.x + 1.1 * self.radius_m) / scale_x) as isize;
            let row = ((-p.y + 1.1 * self.radius_m) / scale_y) as isize;
            if (0..width as isize).contains(&col) && (0..height as isize).contains(&row) {
                Some((row as usize, col as usize))
            } else {
                None
            }
        };
        for u in &self.users {
            if let Some((r, c)) = to_cell(&u.pos) {
                grid[r][c] = char::from_digit((u.cluster % 10) as u32, 10).unwrap_or('?');
            }
        }
        for (ci, center) in self.layout.centers.iter().enumerate() {
            if let Some((r, c)) = to_cell(center) {
                grid[r][c] = if ci == 0 { 'M' } else { 'S' };
            }
        }
        grid.into_iter()
            .map(|row| row.into_iter().collect::<String>())
            .collect::<Vec<_>>()
            .join("\n")
    }
}

/// Rejection-sample a point uniform over (hexagon ∩ macro-disc).
fn sample_in_hex_and_disc(rng: &mut Pcg64, center: &Point, apothem: f64, disc_r: f64) -> Point {
    // Bounding box of a flat-top hexagon: |dy| ≤ a, |dx| ≤ 2a/√3.
    let half_w = 2.0 * apothem / 3f64.sqrt();
    for _ in 0..10_000 {
        let p = Point::new(
            center.x + rng.uniform_range(-half_w, half_w),
            center.y + rng.uniform_range(-apothem, apothem),
        );
        if in_hexagon(&p, center, apothem) && p.norm() <= disc_r {
            return p;
        }
    }
    // Hexagon ∩ disc can be empty only for far-out rings; fall back to the
    // closest in-disc point toward the origin.
    let n = center.norm();
    if n > disc_r {
        Point::new(center.x * disc_r / n, center.y * disc_r / n)
    } else {
        *center
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TopologyConfig;

    fn cfg() -> TopologyConfig {
        TopologyConfig::default()
    }

    #[test]
    fn equal_users_per_cluster() {
        let topo = NetworkTopology::generate(&cfg());
        assert_eq!(topo.users.len(), 28);
        for c in 0..7 {
            assert_eq!(topo.cluster_users(c).count(), 4, "cluster {c}");
        }
    }

    #[test]
    fn users_inside_their_hexagon_and_disc() {
        let topo = NetworkTopology::generate(&cfg());
        for u in &topo.users {
            let center = &topo.layout.centers[u.cluster];
            assert!(
                in_hexagon(&u.pos, center, topo.layout.apothem),
                "MU {} outside hexagon {}",
                u.id,
                u.cluster
            );
            assert!(u.pos.norm() <= 750.0 + 1e-9);
        }
    }

    #[test]
    fn sbs_distance_bounded_by_circumradius() {
        let topo = NetworkTopology::generate(&cfg());
        let circum = 2.0 * topo.layout.apothem / 3f64.sqrt();
        for u in &topo.users {
            assert!(u.dist_sbs <= circum + 1e-9, "{}", u.dist_sbs);
            assert!(u.dist_sbs >= 1.0); // clamped
        }
    }

    #[test]
    fn hfl_shortens_distances_vs_mbs() {
        // The whole point of clustering: mean MU→SBS < mean MU→MBS.
        let topo = NetworkTopology::generate(&cfg());
        let mean_sbs: f64 =
            topo.users.iter().map(|u| u.dist_sbs).sum::<f64>() / topo.users.len() as f64;
        let mean_mbs: f64 =
            topo.users.iter().map(|u| u.dist_mbs).sum::<f64>() / topo.users.len() as f64;
        assert!(
            mean_sbs < mean_mbs,
            "mean SBS dist {mean_sbs} should be < mean MBS dist {mean_mbs}"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let a = NetworkTopology::generate(&cfg());
        let b = NetworkTopology::generate(&cfg());
        for (ua, ub) in a.users.iter().zip(&b.users) {
            assert_eq!(ua.pos, ub.pos);
        }
        let c = NetworkTopology::generate(&TopologyConfig {
            placement_seed: 999,
            ..cfg()
        });
        assert!(a.users.iter().zip(&c.users).any(|(x, y)| x.pos != y.pos));
    }

    #[test]
    fn ascii_map_renders() {
        let topo = NetworkTopology::generate(&cfg());
        let map = topo.ascii_map(60, 30);
        assert!(map.contains('M'));
        assert!(map.contains('S'));
        assert_eq!(map.lines().count(), 30);
    }
}
