//! Dependency-free command-line parsing (no `clap` offline).
//!
//! Grammar: `hfl <subcommand> [--flag] [--key value] [--key=value] ...`.
//! [`Args`] collects flags/options and reports unknown or missing ones with
//! helpful errors; each subcommand in `main.rs` declares what it accepts.
//!
//! Ambiguity rule: in the space-separated form `--key value`, a value that
//! itself starts with `--` is indistinguishable from the next flag, so the
//! parser classifies `--key` as a boolean flag. Accessors detect the
//! resulting kind mismatch (an option read as a flag or vice versa) and
//! [`Args::finish`] turns it into a targeted error pointing at the
//! `--key=value` escape hatch, which accepts any value verbatim
//! (e.g. `--out=--weird-name.json`).
//! The shared `--pool-threads` option (persistent worker-pool lane budget,
//! see [`crate::pool`]) is resolved by [`pool_from_args`]; the shared
//! training-run flags decode through [`spec_from_args`] into one
//! [`RunSpec`], `--phi` through [`phi_from_args`], and the grid commands'
//! `--out`/`--write-golden`/`--check-golden` surface through
//! [`GoldenArgs`].

use crate::adversary::{AdversaryPlan, ChurnConfig};
use crate::net::chaos::{ChaosConfig, FaultPolicy};
use crate::pool::WorkerPool;
use crate::sim::result::{self, ScenarioResult};
use crate::sparse::merge::{AggPath, AggPolicy, AggRule};
use crate::spec::RunSpec;
use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// Resolve the shared `--agg-path auto|sparse|dense` and `--agg-rule
/// mean|trimmed-mean|coord-median` (with `--agg-trim K` for the trim
/// depth) options against the `[agg]` config default (crossover always
/// comes from the config). The returned policy is threaded into
/// `TrainOptions::agg` / `MatrixOptions::agg`; the path is bit-identical
/// for every setting — only the consensus *rule* changes arithmetic (see
/// `crate::sparse::merge`).
pub fn agg_from_args(args: &Args, default: AggPolicy) -> Result<AggPolicy> {
    let mut agg = default;
    if let Some(s) = args.get("agg-path") {
        agg.path = AggPath::parse(s)?;
    }
    let trim_default = match agg.rule {
        AggRule::TrimmedMean(k) => k,
        _ => 1,
    };
    let trim_k = args.get_parsed_or("agg-trim", trim_default)?;
    if let Some(s) = args.get("agg-rule") {
        agg.rule = AggRule::parse(s, trim_k)?;
    } else if matches!(agg.rule, AggRule::TrimmedMean(_)) {
        agg.rule = AggRule::TrimmedMean(trim_k);
    } else if args.get("agg-trim").is_some() {
        bail!("--agg-trim requires --agg-rule trimmed-mean (or [agg] rule = \"trimmed-mean\")");
    }
    agg.validate()?;
    Ok(agg)
}

/// Resolve the `--adversary-*` Byzantine-plan options against the
/// `[adversary]` config default. `--adversary` alone enables the
/// config-file plan; any `--adversary-*` value both sets its field and
/// enables the plan (mirrors [`chaos_from_args`]). Re-validated, so CLI
/// values obey the same bounds as the config file.
pub fn adversary_from_args(args: &Args, default: &AdversaryPlan) -> Result<AdversaryPlan> {
    let mut plan = *default;
    let mut touched = args.flag("adversary");
    if let Some(f) = args.get_parsed("adversary-frac")? {
        plan.fraction = f;
        touched = true;
    }
    if let Some(seed) = args.get_parsed("adversary-seed")? {
        plan.seed = seed;
        touched = true;
    }
    if let Some(s) = args.get_parsed::<f32>("adversary-scale")? {
        plan.scale = s;
        touched = true;
    }
    if let Some(g) = args.get_parsed::<f32>("adversary-garbage-std")? {
        plan.garbage_std = g;
        touched = true;
    }
    if touched {
        plan.enabled = true;
    }
    plan.validate()?;
    Ok(plan)
}

/// Resolve the `--churn-*` client-churn options against the `[churn]`
/// config default, with the same any-flag-enables contract as
/// [`chaos_from_args`] / [`adversary_from_args`].
pub fn churn_from_args(args: &Args, default: &ChurnConfig) -> Result<ChurnConfig> {
    let mut churn = *default;
    let mut touched = args.flag("churn");
    if let Some(p) = args.get_parsed("churn-drop")? {
        churn.drop_p = p;
        touched = true;
    }
    if let Some(p) = args.get_parsed("churn-rejoin")? {
        churn.rejoin_p = p;
        touched = true;
    }
    if let Some(e) = args.get_parsed("churn-energy")? {
        churn.energy = e;
        touched = true;
    }
    if let Some(s) = args.get_parsed("churn-seed")? {
        churn.seed = s;
        touched = true;
    }
    if touched {
        churn.enabled = true;
    }
    churn.validate()?;
    Ok(churn)
}

/// Resolve the shared `--pool-threads N` option against the `[pool]`
/// config default: `0` (or absent with a zero default) keeps the lazily
/// created process-wide shared pool (`None`); any other value builds a
/// dedicated [`WorkerPool`] with that many lanes. The caller must keep the
/// returned pool alive for the duration of the command — dropping it joins
/// the workers — and thread `pool.handle()` through its options structs.
pub fn pool_from_args(args: &Args, default_lanes: usize) -> Result<Option<WorkerPool>> {
    let lanes = args.get_parsed_or("pool-threads", default_lanes)?;
    // Same sanity bound the `[pool] threads` config path enforces
    // (`PoolConfig::validate`) — reject absurd values before spawning.
    if lanes > 4096 {
        bail!("--pool-threads {lanes} outside sane range [0, 4096]");
    }
    Ok(if lanes == 0 {
        None
    } else {
        Some(WorkerPool::new(lanes))
    })
}

/// Resolve the `--chaos-*` fault-plan options against the `[chaos]`
/// config default. `--chaos` alone enables the config-file plan; any
/// `--chaos-*` value both sets its field and enables the plan (an
/// explicit fault flag is an explicit opt-in). The merged plan is
/// re-validated, so CLI values obey the same bounds as the config file.
pub fn chaos_from_args(args: &Args, default: &ChaosConfig) -> Result<ChaosConfig> {
    let mut chaos = default.clone();
    let mut touched = args.flag("chaos");
    let mut set = |field: &mut f64, v: Option<f64>| {
        if let Some(v) = v {
            *field = v;
            touched = true;
        }
    };
    set(&mut chaos.drop_p, args.get_parsed("chaos-drop")?);
    set(&mut chaos.delay_p, args.get_parsed("chaos-delay")?);
    set(&mut chaos.dup_p, args.get_parsed("chaos-dup")?);
    set(&mut chaos.truncate_p, args.get_parsed("chaos-truncate")?);
    set(&mut chaos.corrupt_p, args.get_parsed("chaos-corrupt")?);
    if let Some(seed) = args.get_parsed("chaos-seed")? {
        chaos.seed = seed;
        touched = true;
    }
    if let Some(ms) = args.get_parsed("chaos-delay-ms")? {
        chaos.delay_ms = ms;
        touched = true;
    }
    if let Some(c) = args.get_parsed("chaos-kill-cluster")? {
        chaos.kill_cluster = Some(c);
        touched = true;
    }
    if let Some(at) = args.get_parsed("chaos-kill-after")? {
        chaos.kill_after = at;
        touched = true;
    }
    if touched {
        chaos.enabled = true;
    }
    chaos.validate()?;
    Ok(chaos)
}

/// Underscore-tolerant count option: `--mus 1_000_000` reads as one
/// million. Plain digits parse as usual; `_` separators are stripped
/// first (a count axis that reaches 10^6+ is unreadable without them).
pub fn count_from_args(args: &Args, key: &str) -> Result<Option<usize>> {
    match args.get(key) {
        None => Ok(None),
        Some(s) => {
            let cleaned: String = s.chars().filter(|&c| c != '_').collect();
            if cleaned.is_empty() || s.starts_with('_') || s.ends_with('_') {
                bail!("--{key}={s}: not a count (digits with optional `_` separators)");
            }
            cleaned
                .parse::<usize>()
                .map(Some)
                .map_err(|e| anyhow!("--{key}={s}: {e}"))
        }
    }
}

/// Resolve the shared `--phi F` sparsity pin. One definition of the bound
/// check (the same bound `DgcKernel` enforces) for every subcommand that
/// accepts the flag — reject at the CLI boundary instead of panicking
/// inside a pooled worker.
pub fn phi_from_args(args: &Args) -> Result<Option<f64>> {
    let phi = args.get_parsed::<f64>("phi")?;
    if let Some(p) = phi {
        if !(0.0..1.0).contains(&p) {
            bail!("--phi {p} outside [0,1) (DGC keeps at least one coordinate)");
        }
    }
    Ok(phi)
}

/// Apply the shared training-run flags to a starting [`RunSpec`]: `--iters`
/// overrides the iteration budget, `--inner-threads` the intra-round
/// fan-out, `--agg-path`/`--agg-rule` the aggregation dispatch, and
/// `--adversary-*` the Byzantine plan (each against its config-section
/// default). This is the one decode path from CLI/config to the spec
/// shared by `train`, `matrix`, `des` and `serve`/`worker`.
pub fn spec_from_args(
    args: &Args,
    default_agg: AggPolicy,
    default_adversary: &AdversaryPlan,
    base: RunSpec,
) -> Result<RunSpec> {
    let mut spec = base
        .agg(agg_from_args(args, default_agg)?)
        .adversary(adversary_from_args(args, default_adversary)?);
    if let Some(iters) = count_from_args(args, "iters")? {
        spec.iters = iters;
    }
    if let Some(inner) = args.get_parsed::<usize>("inner-threads")? {
        spec.inner_threads = inner;
    }
    Ok(spec)
}

/// The shared golden-trace output surface of the grid subcommands
/// (`matrix`, `des`, `serve`, `replay`): `--out DIR` for the CSV/JSON/
/// golden triple, `--write-golden F` to emit a fixture, `--check-golden F`
/// to diff against one. One parse + one emit path keeps the error wording
/// identical across subcommands.
#[derive(Clone, Debug)]
pub struct GoldenArgs {
    /// Output directory for `<prefix>.csv` / `<prefix>.json` /
    /// `<prefix>_golden.json`.
    pub out: String,
    /// `--write-golden F`: also write the golden trace to this fixture path.
    pub write_golden: Option<String>,
    /// `--check-golden F`: diff the golden trace against this fixture and
    /// fail on any mismatch.
    pub check_golden: Option<String>,
}

impl GoldenArgs {
    /// Parse `--out` (default `results`), `--write-golden`, `--check-golden`.
    pub fn from_args(args: &Args) -> Self {
        Self {
            out: args.get_or("out", "results"),
            write_golden: args.get("write-golden").map(str::to_string),
            check_golden: args.get("check-golden").map(str::to_string),
        }
    }

    /// Write the grid outputs under `out/<prefix>.*`, then honor the
    /// fixture write/check requests. Golden traces are a bit-exactness
    /// boundary: serialization refuses to emit a fixture with silently
    /// nulled non-finite numbers, and any check mismatch is an error
    /// listing every diverging scenario.
    pub fn emit(&self, results: &[ScenarioResult], prefix: &str) -> Result<()> {
        let csv_path = format!("{}/{prefix}.csv", self.out);
        result::results_to_csv(results).save(&csv_path)?;
        let json_path = format!("{}/{prefix}.json", self.out);
        std::fs::write(
            &json_path,
            format!("{}\n", result::results_to_json(results).to_string_compact()),
        )?;
        let golden_text = format!(
            "{}\n",
            result::golden_to_json(results)
                .to_string_strict()
                .map_err(|e| anyhow!("golden trace serialization: {e}"))?
        );
        let golden_path = format!("{}/{prefix}_golden.json", self.out);
        std::fs::write(&golden_path, &golden_text)?;
        println!("wrote {csv_path}, {json_path} and {golden_path}");

        if let Some(path) = &self.write_golden {
            std::fs::write(path, &golden_text)?;
            println!("wrote golden fixture {path}");
        }
        if let Some(path) = &self.check_golden {
            let text = std::fs::read_to_string(path)?;
            let json = crate::util::json::parse(&text)
                .map_err(|e| anyhow!("parsing {path}: {e}"))?;
            let fixture = result::golden_from_json(&json)?;
            let diff = result::golden_diff(results, &fixture);
            if !diff.is_empty() {
                for d in &diff {
                    eprintln!("golden mismatch: {d}");
                }
                bail!("{} golden-trace mismatches against {path}", diff.len());
            }
            println!("golden traces match {path} ({} scenarios)", results.len());
        }
        Ok(())
    }
}

/// Resolve `--fault-policy wait-all|deadline-skip|quorum` (with
/// `--fault-quorum K` for the latter). Absent flags keep the pre-chaos
/// default: wait for every cluster, any fault is fatal.
pub fn fault_policy_from_args(args: &Args) -> Result<FaultPolicy> {
    let quorum = args.get_parsed_or("fault-quorum", 0usize)?;
    match args.get("fault-policy") {
        None => {
            if quorum != 0 {
                bail!("--fault-quorum requires --fault-policy quorum");
            }
            Ok(FaultPolicy::WaitAll)
        }
        Some(s) => FaultPolicy::parse(s, quorum),
    }
}

/// Parsed command line: a subcommand plus `--key value` options and
/// `--flag` booleans.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
    /// Keys that were actually consumed by accessors; used to report typos.
    consumed: std::cell::RefCell<Vec<String>>,
    /// Kind mismatches seen by accessors (option read as flag or vice
    /// versa), reported by [`Args::finish`] with the `--key=value` hint.
    misuses: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I, S>(argv: I) -> Result<Self>
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let mut args = Args::default();
        let mut it = argv.into_iter().map(Into::into).peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if stripped.is_empty() {
                    bail!("bare `--` is not supported");
                }
                if let Some((k, v)) = stripped.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else {
                    // `--key value` when next token isn't another option,
                    // else boolean flag.
                    match it.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = it.next().unwrap();
                            args.options.insert(stripped.to_string(), v);
                        }
                        _ => args.flags.push(stripped.to_string()),
                    }
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(tok);
            } else {
                bail!("unexpected positional argument `{tok}`");
            }
        }
        Ok(args)
    }

    /// Parse the real process arguments.
    pub fn from_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    fn mark(&self, key: &str) {
        self.consumed.borrow_mut().push(key.to_string());
    }

    /// String option.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.mark(key);
        let hit = self.options.get(key).map(|s| s.as_str());
        if hit.is_none() && self.flags.iter().any(|f| f == key) {
            // `--key` was parsed as a boolean flag — most likely `--key value`
            // with a value that starts with `--` (the parser cannot tell it
            // from the next flag).
            self.misuses.borrow_mut().push(format!(
                "--{key} expects a value but was given none (a following \
                 `--…` token is read as the next flag; write `--{key}=value` \
                 to pass a value that starts with `--`)"
            ));
        }
        hit
    }

    /// String option with default.
    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Typed numeric option.
    pub fn get_parsed<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|e| anyhow::anyhow!("--{key}={s}: {e}")),
        }
    }

    /// Typed numeric option with default.
    pub fn get_parsed_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        Ok(self.get_parsed(key)?.unwrap_or(default))
    }

    /// Boolean flag (present / absent).
    pub fn flag(&self, key: &str) -> bool {
        self.mark(key);
        let hit = self.flags.iter().any(|f| f == key);
        if !hit && self.options.contains_key(key) {
            // `--key value` where the subcommand treats `--key` as a boolean
            // flag: the parser swallowed the next token as its value.
            self.misuses.borrow_mut().push(format!(
                "--{key} is a boolean flag and takes no value (the token \
                 after it was consumed as one; drop the value or check for \
                 a missing `--` on it)"
            ));
        }
        hit
    }

    /// Error if any provided option/flag was never consumed — catches typos
    /// like `--epohcs` — or was used with the wrong kind (an option without
    /// a value, a flag with one). Kind mismatches come with the
    /// `--key=value` escape-hatch hint.
    pub fn finish(&self) -> Result<()> {
        let misuses = self.misuses.borrow();
        if !misuses.is_empty() {
            bail!("{}", misuses.join("; "));
        }
        let consumed = self.consumed.borrow();
        let unknown: Vec<&str> = self
            .options
            .keys()
            .map(|s| s.as_str())
            .chain(self.flags.iter().map(|s| s.as_str()))
            .filter(|k| !consumed.iter().any(|c| c == k))
            .collect();
        if !unknown.is_empty() {
            bail!("unknown option(s): {}", unknown.join(", "));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_subcommand_options_flags() {
        let a = Args::parse(["latency", "--fig", "3", "--mus=8", "--verbose"]).unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("latency"));
        assert_eq!(a.get("fig"), Some("3"));
        assert_eq!(a.get_parsed::<usize>("mus").unwrap(), Some(8));
        assert!(a.flag("verbose"));
        a.finish().unwrap();
    }

    #[test]
    fn flag_followed_by_option() {
        let a = Args::parse(["x", "--quick", "--h", "4"]).unwrap();
        assert!(a.flag("quick"));
        assert_eq!(a.get_parsed_or::<usize>("h", 2).unwrap(), 4);
    }

    #[test]
    fn trailing_flag() {
        let a = Args::parse(["x", "--h", "4", "--dense"]).unwrap();
        assert_eq!(a.get("h"), Some("4"));
        assert!(a.flag("dense"));
    }

    #[test]
    fn unknown_options_detected() {
        let a = Args::parse(["x", "--epohcs", "3"]).unwrap();
        let _ = a.get("epochs");
        assert!(a.finish().is_err());
    }

    #[test]
    fn rejects_double_positional() {
        assert!(Args::parse(["a", "b"]).is_err());
    }

    #[test]
    fn option_value_starting_with_dashes_is_a_targeted_error() {
        // `--out --weird.json`: the parser reads `--out` as a flag and
        // `--weird.json` as another flag. The option accessor notices the
        // kind mismatch and finish() points at the `--key=value` hatch
        // instead of a misleading unknown/positional error.
        let a = Args::parse(["train", "--out", "--weird.json"]).unwrap();
        assert_eq!(a.get("out"), None);
        let err = a.finish().unwrap_err().to_string();
        assert!(err.contains("--out expects a value"), "{err}");
        assert!(err.contains("--out=value"), "{err}");
    }

    #[test]
    fn key_equals_value_escape_hatch_accepts_dashed_values() {
        let a = Args::parse(["train", "--out=--weird.json"]).unwrap();
        assert_eq!(a.get("out"), Some("--weird.json"));
        a.finish().unwrap();
    }

    #[test]
    fn flag_given_a_value_is_a_targeted_error() {
        // `--quick now`: `now` is swallowed as the value of an option that
        // the subcommand treats as a boolean flag.
        let a = Args::parse(["matrix", "--quick", "now"]).unwrap();
        assert!(!a.flag("quick"));
        let err = a.finish().unwrap_err().to_string();
        assert!(err.contains("--quick is a boolean flag"), "{err}");
    }

    #[test]
    fn negative_number_as_value() {
        let a = Args::parse(["x", "--noise=-150"]).unwrap();
        assert_eq!(a.get_parsed::<f64>("noise").unwrap(), Some(-150.0));
    }

    #[test]
    fn bad_parse_is_error() {
        let a = Args::parse(["x", "--n", "abc"]).unwrap();
        assert!(a.get_parsed::<usize>("n").is_err());
    }

    #[test]
    fn agg_from_args_overrides_path_only() {
        let a = Args::parse(["matrix", "--agg-path", "sparse"]).unwrap();
        let agg = agg_from_args(&a, AggPolicy::default()).unwrap();
        assert_eq!(agg.path, AggPath::Sparse);
        assert_eq!(agg.crossover, AggPolicy::default().crossover);
        a.finish().unwrap();
        // Absent flag keeps the config default.
        let a = Args::parse(["matrix"]).unwrap();
        let cfg_default = AggPolicy { path: AggPath::Dense, crossover: 0.5, ..Default::default() };
        assert_eq!(agg_from_args(&a, cfg_default).unwrap(), cfg_default);
        // Unknown values are rejected.
        let a = Args::parse(["matrix", "--agg-path", "turbo"]).unwrap();
        assert!(agg_from_args(&a, AggPolicy::default()).is_err());
    }

    #[test]
    fn agg_rule_from_args_parses_and_validates() {
        let a = Args::parse(["matrix", "--agg-rule", "coord-median"]).unwrap();
        let agg = agg_from_args(&a, AggPolicy::default()).unwrap();
        assert_eq!(agg.rule, AggRule::CoordMedian);
        a.finish().unwrap();

        let a = Args::parse(["matrix", "--agg-rule", "trimmed-mean", "--agg-trim", "2"]).unwrap();
        let agg = agg_from_args(&a, AggPolicy::default()).unwrap();
        assert_eq!(agg.rule, AggRule::TrimmedMean(2));
        a.finish().unwrap();

        // --agg-trim defaults to 1 with trimmed-mean, and retunes a
        // trimmed-mean config default on its own.
        let a = Args::parse(["matrix", "--agg-rule", "trimmed-mean"]).unwrap();
        assert_eq!(
            agg_from_args(&a, AggPolicy::default()).unwrap().rule,
            AggRule::TrimmedMean(1)
        );
        let trimmed_default =
            AggPolicy { rule: AggRule::TrimmedMean(1), ..Default::default() };
        let a = Args::parse(["matrix", "--agg-trim", "3"]).unwrap();
        assert_eq!(
            agg_from_args(&a, trimmed_default).unwrap().rule,
            AggRule::TrimmedMean(3)
        );

        // --agg-trim without a trimmed-mean rule, unknown rules, and
        // k = 0 are all named errors at the CLI boundary.
        let a = Args::parse(["matrix", "--agg-trim", "2"]).unwrap();
        assert!(agg_from_args(&a, AggPolicy::default()).is_err());
        let a = Args::parse(["matrix", "--agg-rule", "krum"]).unwrap();
        assert!(agg_from_args(&a, AggPolicy::default()).is_err());
        let a = Args::parse(["matrix", "--agg-rule", "trimmed-mean", "--agg-trim", "0"]).unwrap();
        assert!(agg_from_args(&a, AggPolicy::default()).is_err());
    }

    #[test]
    fn adversary_from_args_merges_and_enables() {
        // No adversary flags: the (disabled) config default passes through.
        let a = Args::parse(["des"]).unwrap();
        let plan = adversary_from_args(&a, &AdversaryPlan::default()).unwrap();
        assert!(!plan.enabled);
        a.finish().unwrap();

        // Any --adversary-* value enables the plan and sets its field.
        let a = Args::parse([
            "des",
            "--adversary-frac",
            "0.2",
            "--adversary-seed",
            "9",
            "--adversary-scale",
            "25.0",
        ])
        .unwrap();
        let plan = adversary_from_args(&a, &AdversaryPlan::default()).unwrap();
        assert!(plan.enabled);
        assert_eq!(plan.fraction, 0.2);
        assert_eq!(plan.seed, 9);
        assert_eq!(plan.scale, 25.0);
        a.finish().unwrap();

        // Bare --adversary enables the config-file plan unchanged.
        let a = Args::parse(["des", "--adversary"]).unwrap();
        let base = AdversaryPlan { fraction: 0.35, ..Default::default() };
        let plan = adversary_from_args(&a, &base).unwrap();
        assert!(plan.enabled);
        assert_eq!(plan.fraction, 0.35);

        // Out-of-range fractions are refused at the CLI boundary.
        let a = Args::parse(["des", "--adversary-frac", "1.5"]).unwrap();
        assert!(adversary_from_args(&a, &AdversaryPlan::default()).is_err());
        let a = Args::parse(["des", "--adversary-frac=-0.2"]).unwrap();
        assert!(adversary_from_args(&a, &AdversaryPlan::default()).is_err());
    }

    #[test]
    fn churn_from_args_merges_and_enables() {
        let a = Args::parse(["des"]).unwrap();
        let churn = churn_from_args(&a, &ChurnConfig::default()).unwrap();
        assert!(!churn.enabled);
        a.finish().unwrap();

        let a = Args::parse([
            "des",
            "--churn-drop",
            "0.2",
            "--churn-rejoin",
            "0.6",
            "--churn-energy",
            "8",
            "--churn-seed",
            "5",
        ])
        .unwrap();
        let churn = churn_from_args(&a, &ChurnConfig::default()).unwrap();
        assert!(churn.enabled);
        assert_eq!(churn.drop_p, 0.2);
        assert_eq!(churn.rejoin_p, 0.6);
        assert_eq!(churn.energy, 8.0);
        assert_eq!(churn.seed, 5);
        a.finish().unwrap();

        let a = Args::parse(["des", "--churn-drop", "2.0"]).unwrap();
        assert!(churn_from_args(&a, &ChurnConfig::default()).is_err());
    }

    #[test]
    fn chaos_from_args_merges_and_enables() {
        // No chaos flags: the (disabled) config default passes through.
        let a = Args::parse(["serve"]).unwrap();
        let chaos = chaos_from_args(&a, &ChaosConfig::default()).unwrap();
        assert!(!chaos.enabled);
        a.finish().unwrap();

        // Any --chaos-* value enables the plan and sets its field.
        let a = Args::parse([
            "serve",
            "--chaos-seed",
            "42",
            "--chaos-drop",
            "0.25",
            "--chaos-kill-cluster",
            "1",
            "--chaos-kill-after",
            "9",
        ])
        .unwrap();
        let chaos = chaos_from_args(&a, &ChaosConfig::default()).unwrap();
        assert!(chaos.enabled);
        assert_eq!(chaos.seed, 42);
        assert_eq!(chaos.drop_p, 0.25);
        assert_eq!(chaos.kill_cluster, Some(1));
        assert_eq!(chaos.kill_after, 9);
        a.finish().unwrap();

        // Bare --chaos enables the config-file plan unchanged.
        let a = Args::parse(["serve", "--chaos"]).unwrap();
        let base = ChaosConfig {
            seed: 7,
            drop_p: 0.1,
            ..ChaosConfig::default()
        };
        let chaos = chaos_from_args(&a, &base).unwrap();
        assert!(chaos.enabled);
        assert_eq!(chaos.seed, 7);
        assert_eq!(chaos.drop_p, 0.1);
        a.finish().unwrap();

        // CLI values are validated like config values.
        let a = Args::parse(["serve", "--chaos-drop", "1.5"]).unwrap();
        assert!(chaos_from_args(&a, &ChaosConfig::default()).is_err());
    }

    #[test]
    fn fault_policy_from_args_parses_all_policies() {
        let a = Args::parse(["serve"]).unwrap();
        assert_eq!(fault_policy_from_args(&a).unwrap(), FaultPolicy::WaitAll);
        a.finish().unwrap();

        let a = Args::parse(["serve", "--fault-policy", "deadline-skip"]).unwrap();
        assert_eq!(fault_policy_from_args(&a).unwrap(), FaultPolicy::DeadlineSkip);

        let a = Args::parse(["serve", "--fault-policy", "quorum", "--fault-quorum", "2"]).unwrap();
        assert_eq!(fault_policy_from_args(&a).unwrap(), FaultPolicy::Quorum(2));

        // quorum without K, K without quorum, junk policy: all named errors.
        let a = Args::parse(["serve", "--fault-policy", "quorum"]).unwrap();
        assert!(fault_policy_from_args(&a).is_err());
        let a = Args::parse(["serve", "--fault-quorum", "2"]).unwrap();
        assert!(fault_policy_from_args(&a).is_err());
        let a = Args::parse(["serve", "--fault-policy", "panic"]).unwrap();
        assert!(fault_policy_from_args(&a).is_err());
    }

    #[test]
    fn count_from_args_strips_separators() {
        let a = Args::parse(["des", "--mus", "1_000_000"]).unwrap();
        assert_eq!(count_from_args(&a, "mus").unwrap(), Some(1_000_000));
        let a = Args::parse(["des", "--mus", "250"]).unwrap();
        assert_eq!(count_from_args(&a, "mus").unwrap(), Some(250));
        let a = Args::parse(["des"]).unwrap();
        assert_eq!(count_from_args(&a, "mus").unwrap(), None);
        for bad in ["_1000", "1000_", "abc", "_", "1_000.5"] {
            let a = Args::parse(vec!["des".to_string(), format!("--mus={bad}")]).unwrap();
            assert!(count_from_args(&a, "mus").is_err(), "{bad}");
        }
    }

    #[test]
    fn phi_from_args_validates_range() {
        let a = Args::parse(["des", "--phi", "0.9"]).unwrap();
        assert_eq!(phi_from_args(&a).unwrap(), Some(0.9));
        a.finish().unwrap();
        let a = Args::parse(["des"]).unwrap();
        assert_eq!(phi_from_args(&a).unwrap(), None);
        let a = Args::parse(["des", "--phi", "1.0"]).unwrap();
        assert!(phi_from_args(&a).is_err());
        let a = Args::parse(["des", "--phi=-0.1"]).unwrap();
        assert!(phi_from_args(&a).is_err());
    }

    #[test]
    fn spec_from_args_applies_shared_overrides() {
        let a = Args::parse([
            "des",
            "--iters",
            "5_000",
            "--inner-threads",
            "4",
            "--agg-path",
            "dense",
        ])
        .unwrap();
        let adv = AdversaryPlan::default();
        let spec = spec_from_args(&a, AggPolicy::default(), &adv, RunSpec::new().iters(30)).unwrap();
        assert_eq!(spec.iters, 5000);
        assert_eq!(spec.inner_threads, 4);
        assert_eq!(spec.agg.path, AggPath::Dense);
        assert!(!spec.adversary.enabled);
        a.finish().unwrap();
        // Absent flags keep the base spec.
        let a = Args::parse(["des"]).unwrap();
        let spec = spec_from_args(&a, AggPolicy::default(), &adv, RunSpec::new().iters(30)).unwrap();
        assert_eq!(spec.iters, 30);
        assert_eq!(spec.inner_threads, 1);
        // Adversary flags land in the spec's plan.
        let a = Args::parse(["des", "--adversary-frac", "0.2", "--agg-rule", "coord-median"])
            .unwrap();
        let spec = spec_from_args(&a, AggPolicy::default(), &adv, RunSpec::new()).unwrap();
        assert!(spec.adversary.enabled);
        assert_eq!(spec.adversary.fraction, 0.2);
        assert_eq!(spec.agg.rule, AggRule::CoordMedian);
        a.finish().unwrap();
    }

    #[test]
    fn pool_from_args_builds_dedicated_pool_or_defers() {
        let a = Args::parse(["matrix", "--pool-threads", "2"]).unwrap();
        let pool = pool_from_args(&a, 0).unwrap().expect("dedicated pool");
        assert_eq!(pool.lanes(), 2);
        a.finish().unwrap();
        // Absent with a zero default → shared pool (None).
        let a = Args::parse(["matrix"]).unwrap();
        assert!(pool_from_args(&a, 0).unwrap().is_none());
        // Absent with a nonzero `[pool] threads` default → dedicated pool.
        let a = Args::parse(["matrix"]).unwrap();
        assert_eq!(pool_from_args(&a, 3).unwrap().unwrap().lanes(), 3);
        // Explicit 0 overrides a nonzero config default back to shared.
        let a = Args::parse(["matrix", "--pool-threads", "0"]).unwrap();
        assert!(pool_from_args(&a, 3).unwrap().is_none());
        // Absurd lane counts are rejected, mirroring PoolConfig::validate.
        let a = Args::parse(["matrix", "--pool-threads", "500000"]).unwrap();
        assert!(pool_from_args(&a, 0).is_err());
    }
}
