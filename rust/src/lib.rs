//! # hfl — Hierarchical Federated Learning across Heterogeneous Cellular Networks
//!
//! A three-layer reproduction of Abad, Ozfatura, Gündüz & Ercetin (2019):
//!
//! - **Layer 3 (this crate)** — the hierarchical FL coordinator (MBS leader,
//!   SBS cluster servers, MU workers), DGC-style sparse communication, and a
//!   full wireless latency substrate (OFDM sub-carrier allocation, truncated
//!   channel-inversion power control, M-QAM rates, rateless broadcast,
//!   hexagonal frequency reuse).
//! - **Layer 2 (JAX, build-time)** — model forward/backward on flat parameter
//!   vectors, AOT-lowered to HLO text in `artifacts/`.
//! - **Layer 1 (Pallas, build-time)** — tiled-GEMM and fused-DGC kernels
//!   inside the L2 graph, checked against a pure-jnp oracle.
//!
//! Python never runs at training time: [`runtime`] loads the HLO artifacts
//! through the PJRT CPU client (`xla` crate, behind the **`pjrt`** cargo
//! feature) and the whole training loop is native Rust. The default build
//! is dependency-light (only `anyhow`): the PJRT path is replaced by an
//! API-identical stub and every pure-Rust path — quadratic oracles, the
//! wireless latency model, the scenario-matrix engine — works offline.
//!
//! ## Crate map
//!
//! | module | contents |
//! |--------|----------|
//! | [`util`] | RNG (PCG64 + per-scenario streams), special functions (E1), quickselect, stats, CSV/JSON emitters, logger, microbench |
//! | [`adversary`] | **Byzantine clients + churn**: seeded `AdversaryPlan` (sign-flip / scaled / Gaussian-garbage / stale-replay at the post-DGC uplink boundary, keyed `(seed, mu, round)` streams) and `ChurnConfig` (drop/rejoin/energy-budget participation gating for the DES) |
//! | [`config`] | typed configuration + TOML-subset parser + paper presets (Table II) + DES knobs (`[des]`) |
//! | [`cli`] | dependency-free argument parser and subcommand dispatch |
//! | [`topology`] | hexagonal clusters, frequency-reuse coloring, MU placement, nearest-SBS association |
//! | [`wireless`] | channel model, power control, M-QAM rates, Algorithm 2, broadcast, latency |
//! | [`sparse`] | DGC sparsification, sparse codec + bit accounting + delta-packed `SparseWire`, error accumulation — owning structs + stateless arena kernels |
//! | [`sparse::merge`] | **sparse-first aggregation + robust consensus**: allocation-free k-way merge (O(Σnnz·log k), bit-identical to the MU-ordered dense scatter), `AggRule::{Mean, TrimmedMean(k), CoordMedian}` on the same sorted-coordinate frontier (`--agg-rule`), pool-parallel range variant, density-adaptive dispatch (`--agg-path`, `[agg]`), −0.0-exact `DenseShadow` |
//! | [`tensor`] | **flat tensor arenas + fused kernels**: one cache-aligned allocation for all per-cluster/per-worker hot-path state, bit-exact axpy/scale/scatter kernels, lane splitting for the intra-round fan-out |
//! | [`pool`] | **persistent deterministic worker pool**: condvar-parked lanes created once per process, per-batch work-stealing queues, ordered-slot reduction, nested leases for the fl/des engines, panic propagation with item context |
//! | [`fl`] | optimizers, LR schedule, Algorithms 1 / 3 / 4 / 5 on the tensor arena with deterministic per-cluster fan-out (`inner_threads`, leased from [`pool`]), quadratic oracles (IID→non-IID skew) |
//! | [`data`] | synthetic CIFAR-like dataset, non-shuffled partitioner, batcher |
//! | [`runtime`] | PJRT client wrapper + HLO artifact registry (`pjrt` feature; offline stub by default) |
//! | [`coordinator`] | thread-actor MBS/SBS/MU runtime, per-link metrics → shared `CommBits` schema |
//! | [`net`] | **coordinator-as-a-service**: framed `SparseWire` transport (loopback + TCP), `hfl serve`/`hfl worker` multi-process roles with fingerprint handshake, fsynced session log + bit-exact `hfl replay`, live `/metrics` HTTP endpoint (`[net]`) |
//! | [`net::chaos`] | **deterministic fault injection + fault policies**: seeded `ChaosTransport` fault plans (`[chaos]`/`--chaos-*`; same seed ⇒ bit-identical run), worker rejoin with round-level recovery from the MBS broadcast history, degrade-and-continue aggregation (`--fault-policy wait-all\|deadline-skip\|quorum`) with skips pinned in the golden trace |
//! | [`des`] | **discrete-event HCN simulator at million-MU scale**: hierarchical calendar event queue (O(1) push/pop at 10⁷ events, exact `(time, seq)` order), sparse-residual per-MU DGC state (O(nnz) per idle MU, bit-exact materialize-on-touch), rolling loss window, streamed cluster/sync aggregation over the pooled k-way merge, waypoint mobility + handover, straggler deadlines with stale discounting, timeline digests |
//! | [`spec`] | **`RunSpec` unified run options**: one builder-style options block (iters, LR schedule, H, sparsity, agg policy, inner threads, pool handle) embedded by `TrainOptions`/`CoordinatorOptions`/`MatrixOptions` via deref, plus its snapshot fingerprint |
//! | [`sim`] | figure/table runners (Fig. 3–6, Table III), **scenario-matrix engine** (`sim::matrix`, now with mobility × straggler axes), shared `ScenarioResult` + golden traces (`sim::result`) |
//! | [`snapshot`] | **checkpoint/resume**: versioned FNV-1a-checksummed engine-state snapshots (exact f32/f64 bit patterns, RNG raw states, DES event queue), atomic writes, append-only JSONL run log for resumable matrix sweeps (`--checkpoint-every` / `--resume`) |
//! | [`testing`] | minimal property-testing harness (offline substitute for proptest) |
//!
//! ### Determinism contract of the event-driven paths
//!
//! The [`des`] engine is bit-reproducible: identical event order, timeline
//! digest, and golden trace for any `--threads` value and across reruns
//! with the same seed (per-entity PCG64 streams; all reductions in fixed
//! entity order, never arrival order). Its static wait-for-all
//! configuration reproduces the sequential engine's final parameters
//! bit-exactly and matches the analytic per-round latency within 1e-6
//! relative error — see `rust/tests/des_golden.rs`.
//!
//! The same contract covers the **intra-round fan-out**
//! (`--inner-threads` / `fl::TrainOptions::inner_threads`): per-cluster
//! round blocks execute on disjoint arena lanes and all f64 reductions
//! fold in global worker order afterwards, so training results are
//! bit-identical for every fan-out width — asserted across
//! `inner_threads ∈ {1, 2, 8}` by `rust/tests/property_suite.rs`.
//!
//! All of these fan-outs execute on the persistent [`pool`] subsystem
//! (created once per process, or per command via `--pool-threads`); the
//! pool's ordered-slot reduction preserves the exact contract above for
//! every pool size and lease width.

pub mod adversary;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod des;
pub mod fl;
pub mod net;
pub mod pool;
pub mod runtime;
pub mod sim;
pub mod snapshot;
pub mod sparse;
pub mod spec;
pub mod tensor;
pub mod testing;
pub mod topology;
pub mod util;
pub mod wireless;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
