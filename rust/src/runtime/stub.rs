//! API-compatible stand-in for the PJRT runtime, compiled whenever the
//! native client is unavailable: the default offline build, and builds
//! with `--features pjrt` but without the `--cfg pjrt_native` opt-in that
//! links the `xla` crate (the combination CI exercises to keep the
//! feature-gated callers from bitrotting). Every constructor returns an
//! error explaining how to enable the real thing, and the types are
//! uninhabited so no dead execution path survives into the binary: callers
//! that match on `Runtime::load*` errors (benches, examples, the
//! table3/train subcommands) degrade gracefully, everything else still
//! type-checks against the exact same signatures as the native
//! `runtime::client` / `runtime::oracle` pair.

use super::manifest::{ArtifactMeta, ModelMeta};
use crate::data::SyntheticSpec;
use crate::fl::oracle::{EvalMetrics, GradOracle, ParGradOracle};
use anyhow::{bail, Result};
use std::path::Path;
use std::sync::Arc;

/// Uninhabited token: proves at the type level that stub values can never
/// actually exist.
#[derive(Clone, Copy, Debug)]
enum Never {}

const DISABLED: &str = "hfl was built without the native PJRT/XLA runtime (pjrt feature + \
     pjrt_native cfg): rebuild with `RUSTFLAGS=\"--cfg pjrt_native\" cargo build --features \
     pjrt` after adding the `xla` dependency (see README.md §PJRT), or use the pure-Rust \
     oracles (QuadraticOracle, sim::matrix).";

/// A typed argument for [`Executable::run`] (mirrors the real signature).
pub enum TensorArg<'a> {
    F32(&'a [f32], &'a [usize]),
    I32(&'a [i32], &'a [usize]),
}

/// One compiled AOT computation (never constructible without `pjrt`).
pub struct Executable {
    pub meta: ArtifactMeta,
    never: Never,
}

impl Executable {
    pub fn run(&self, _args: &[TensorArg]) -> Result<Vec<Vec<f32>>> {
        match self.never {}
    }
}

/// The PJRT client wrapper (never constructible without `pjrt`).
pub struct Runtime {
    never: Never,
}

impl Runtime {
    /// Always fails: the `pjrt` feature is disabled.
    pub fn load(_artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        bail!(DISABLED)
    }

    /// Always fails: the `pjrt` feature is disabled.
    pub fn load_default() -> Result<Self> {
        bail!(DISABLED)
    }

    pub fn platform(&self) -> String {
        match self.never {}
    }

    pub fn executable(&self, _name: &str) -> Result<Arc<Executable>> {
        match self.never {}
    }

    pub fn model_meta(&self, _model: &str) -> Result<&ModelMeta> {
        match self.never {}
    }

    pub fn init_params(&self, _model: &str) -> Result<Vec<f32>> {
        match self.never {}
    }
}

/// AOT-backed gradient oracle (never constructible without `pjrt`).
pub struct ModelOracle {
    never: Never,
}

impl ModelOracle {
    /// Always fails: constructing a [`Runtime`] already requires `pjrt`.
    pub fn new(
        _rt: &Runtime,
        _model: &str,
        _workers: usize,
        _spec: &SyntheticSpec,
    ) -> Result<Self> {
        bail!(DISABLED)
    }

    pub fn q_params(&self) -> usize {
        match self.never {}
    }

    pub fn train_batch(&self) -> usize {
        match self.never {}
    }
}

impl GradOracle for ModelOracle {
    fn dim(&self) -> usize {
        match self.never {}
    }

    fn n_workers(&self) -> usize {
        match self.never {}
    }

    fn loss_grad(&mut self, _worker: usize, _params: &[f32], _grad_out: &mut [f32]) -> f64 {
        match self.never {}
    }

    fn eval(&mut self, _params: &[f32]) -> EvalMetrics {
        match self.never {}
    }

    fn iters_per_epoch(&self) -> usize {
        match self.never {}
    }

    fn init_params(&mut self) -> Vec<f32> {
        match self.never {}
    }

    fn par_view(&self) -> Option<&dyn ParGradOracle> {
        // Advertise the fan-out-safe view so `--features pjrt` builds
        // type-check the inner fan-out path (engines no longer hit the
        // sequential-downgrade branch at compile time for this oracle).
        // Uninhabited, so this is a pure API commitment; the *native*
        // oracle still runs sequentially until it grows per-worker
        // executable instances (ROADMAP item).
        Some(self)
    }
}

impl ParGradOracle for ModelOracle {
    fn loss_grad_par(&self, _worker: usize, _params: &[f32], _grad_out: &mut [f32]) -> f64 {
        match self.never {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_report_missing_feature() {
        let err = Runtime::load_default().unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
        let err = Runtime::load("artifacts").unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
