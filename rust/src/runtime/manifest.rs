//! `artifacts/manifest.json` schema and parser (via the crate's own JSON
//! reader — no serde offline).

use crate::util::json::{self, Json};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Tensor shape + dtype of one executable input/output.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorMeta {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorMeta {
    pub fn numel(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// One AOT artifact (an HLO-text file plus its signature).
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorMeta>,
    pub outputs: Vec<TensorMeta>,
}

/// Per-model metadata.
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub q_params: usize,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub input_dim: usize,
    pub n_classes: usize,
    pub init_file: String,
}

/// The whole manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub artifacts: BTreeMap<String, ArtifactMeta>,
    pub models: BTreeMap<String, ModelMeta>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let path = dir.as_ref().join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let root = json::parse(text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        let version = root
            .get("version")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("manifest missing version"))?;
        if version as i64 != 1 {
            bail!("unsupported manifest version {version}");
        }
        let mut artifacts = BTreeMap::new();
        for a in root
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?
        {
            let name = req_str(a, "name")?;
            artifacts.insert(
                name.clone(),
                ArtifactMeta {
                    name,
                    file: req_str(a, "file")?,
                    inputs: tensors(a.get("inputs"))?,
                    outputs: tensors(a.get("outputs"))?,
                },
            );
        }
        let mut models = BTreeMap::new();
        if let Some(obj) = root.get("models").and_then(Json::as_obj) {
            for (k, v) in obj {
                models.insert(
                    k.clone(),
                    ModelMeta {
                        q_params: req_usize(v, "q_params")?,
                        train_batch: req_usize(v, "train_batch")?,
                        eval_batch: req_usize(v, "eval_batch")?,
                        input_dim: req_usize(v, "input_dim")?,
                        n_classes: req_usize(v, "n_classes")?,
                        init_file: req_str(v, "init_file")?,
                    },
                );
            }
        }
        Ok(Self { artifacts, models })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact `{name}` not in manifest"))
    }

    pub fn model(&self, name: &str) -> Result<&ModelMeta> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("model `{name}` not in manifest"))
    }
}

fn req_str(v: &Json, key: &str) -> Result<String> {
    v.get(key)
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| anyhow!("missing string field `{key}`"))
}

fn req_usize(v: &Json, key: &str) -> Result<usize> {
    v.get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow!("missing integer field `{key}`"))
}

fn tensors(v: Option<&Json>) -> Result<Vec<TensorMeta>> {
    let arr = v
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("missing tensor list"))?;
    arr.iter()
        .map(|t| {
            let shape = t
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("tensor missing shape"))?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                .collect::<Result<Vec<_>>>()?;
            Ok(TensorMeta {
                shape,
                dtype: req_str(t, "dtype")?,
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "version": 1,
        "artifacts": [
            {"name": "train_step_mlp", "file": "train_step_mlp.hlo.txt",
             "inputs": [{"shape": [10], "dtype": "f32"},
                        {"shape": [4, 6], "dtype": "f32"},
                        {"shape": [4], "dtype": "i32"}],
             "outputs": [{"shape": [], "dtype": "f32"},
                         {"shape": [10], "dtype": "f32"}]}
        ],
        "models": {"mlp": {"q_params": 10, "train_batch": 4, "eval_batch": 8,
                            "input_dim": 6, "n_classes": 10,
                            "init_file": "init_mlp.f32"}}
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let a = m.artifact("train_step_mlp").unwrap();
        assert_eq!(a.inputs.len(), 3);
        assert_eq!(a.inputs[1].shape, vec![4, 6]);
        assert_eq!(a.inputs[1].numel(), 24);
        assert_eq!(a.outputs[0].shape, Vec::<usize>::new());
        assert_eq!(a.outputs[0].numel(), 1);
        let mm = m.model("mlp").unwrap();
        assert_eq!(mm.q_params, 10);
        assert_eq!(mm.init_file, "init_mlp.f32");
    }

    #[test]
    fn rejects_bad_version() {
        let bad = SAMPLE.replace("\"version\": 1", "\"version\": 99");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn missing_artifact_is_error() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.artifact("nope").is_err());
        assert!(m.model("nope").is_err());
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            for model in ["mlp", "cnn"] {
                assert!(m.model(model).is_ok());
                assert!(m.artifact(&format!("train_step_{model}")).is_ok());
            }
        }
    }
}
