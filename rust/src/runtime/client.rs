//! PJRT client wrapper: compile HLO text once, execute many times.
//!
//! Follows the `/opt/xla-example/load_hlo` pattern: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. Outputs arrive as a 1-element tuple per
//! the AOT `return_tuple=True` convention and are decomposed into flat
//! `Vec<f32>` buffers.

use super::manifest::{ArtifactMeta, Manifest, ModelMeta};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// A typed argument for [`Executable::run`].
pub enum TensorArg<'a> {
    F32(&'a [f32], &'a [usize]),
    I32(&'a [i32], &'a [usize]),
}

impl<'a> TensorArg<'a> {
    fn numel(&self) -> usize {
        match self {
            TensorArg::F32(d, _) => d.len(),
            TensorArg::I32(d, _) => d.len(),
        }
    }

    fn shape(&self) -> &[usize] {
        match self {
            TensorArg::F32(_, s) => s,
            TensorArg::I32(_, s) => s,
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            TensorArg::F32(d, _) => xla::Literal::vec1(d),
            TensorArg::I32(d, _) => xla::Literal::vec1(d),
        };
        if dims.len() == 1 {
            return Ok(lit);
        }
        lit.reshape(&dims).map_err(|e| anyhow!("reshape: {e:?}"))
    }
}

/// One compiled AOT computation.
pub struct Executable {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with shape-checked arguments; returns each output flattened
    /// to `Vec<f32>` (i32 outputs are converted — the exported graphs only
    /// produce f32).
    pub fn run(&self, args: &[TensorArg]) -> Result<Vec<Vec<f32>>> {
        if args.len() != self.meta.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.meta.name,
                self.meta.inputs.len(),
                args.len()
            );
        }
        for (i, (arg, want)) in args.iter().zip(&self.meta.inputs).enumerate() {
            if arg.numel() != want.numel() {
                bail!(
                    "{}: input {i} has {} elements, manifest says {} (shape {:?})",
                    self.meta.name,
                    arg.numel(),
                    want.numel(),
                    want.shape
                );
            }
        }
        let literals: Vec<xla::Literal> = args
            .iter()
            .map(|a| a.to_literal())
            .collect::<Result<Vec<_>>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("{}: execute: {e:?}", self.meta.name))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        // return_tuple=True → always a tuple at top level.
        let parts = result
            .to_tuple()
            .map_err(|e| anyhow!("{}: expected tuple output: {e:?}", self.meta.name))?;
        if parts.len() != self.meta.outputs.len() {
            bail!(
                "{}: {} outputs, manifest says {}",
                self.meta.name,
                parts.len(),
                self.meta.outputs.len()
            );
        }
        parts
            .into_iter()
            .map(|p| p.to_vec::<f32>().map_err(|e| anyhow!("output: {e:?}")))
            .collect()
    }
}

/// The shared PJRT client plus lazily compiled executables.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: Mutex<HashMap<String, std::sync::Arc<Executable>>>,
}

impl Runtime {
    /// Load the manifest and create the CPU PJRT client.
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Self {
            client,
            dir,
            manifest,
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// Default artifacts directory: `$HFL_ARTIFACTS` or `./artifacts`.
    pub fn load_default() -> Result<Self> {
        let dir = std::env::var("HFL_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::load(dir)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch the cached) executable by artifact name.
    pub fn executable(&self, name: &str) -> Result<std::sync::Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let meta = self.manifest.artifact(name)?.clone();
        let path = self.dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        let exe = std::sync::Arc::new(Executable { meta, exe });
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    pub fn model_meta(&self, model: &str) -> Result<&ModelMeta> {
        self.manifest.model(model)
    }

    /// Read the deterministic initial parameter vector exported by aot.py.
    pub fn init_params(&self, model: &str) -> Result<Vec<f32>> {
        let meta = self.manifest.model(model)?;
        let path = self.dir.join(&meta.init_file);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        if bytes.len() != meta.q_params * 4 {
            bail!(
                "{}: {} bytes, expected {}×4",
                path.display(),
                bytes.len(),
                meta.q_params
            );
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn loads_and_runs_train_step_mlp() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let rt = Runtime::load(dir).unwrap();
        let meta = rt.model_meta("mlp").unwrap().clone();
        let exe = rt.executable("train_step_mlp").unwrap();
        let params = rt.init_params("mlp").unwrap();
        assert_eq!(params.len(), meta.q_params);
        let x = vec![0.1f32; meta.train_batch * meta.input_dim];
        let y: Vec<i32> = (0..meta.train_batch as i32).map(|i| i % 10).collect();
        let out = exe
            .run(&[
                TensorArg::F32(&params, &[meta.q_params]),
                TensorArg::F32(&x, &[meta.train_batch, meta.input_dim]),
                TensorArg::I32(&y, &[meta.train_batch]),
            ])
            .unwrap();
        assert_eq!(out.len(), 2);
        let loss = out[0][0];
        // Untrained 10-class loss ≈ ln 10 ≈ 2.3.
        assert!(loss.is_finite() && loss > 0.5 && loss < 6.0, "loss {loss}");
        assert_eq!(out[1].len(), meta.q_params);
        let gnorm: f32 = out[1].iter().map(|g| g * g).sum::<f32>().sqrt();
        assert!(gnorm > 0.0 && gnorm.is_finite());
    }

    #[test]
    fn executable_cache_returns_same_instance() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let rt = Runtime::load(dir).unwrap();
        let a = rt.executable("eval_step_mlp").unwrap();
        let b = rt.executable("eval_step_mlp").unwrap();
        assert!(std::sync::Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn shape_mismatch_is_rejected() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let rt = Runtime::load(dir).unwrap();
        let exe = rt.executable("train_step_mlp").unwrap();
        let tiny = vec![0f32; 8];
        let err = exe.run(&[
            TensorArg::F32(&tiny, &[8]),
            TensorArg::F32(&tiny, &[8]),
            TensorArg::I32(&[0i32; 8], &[8]),
        ]);
        assert!(err.is_err());
    }

    #[test]
    fn gradient_descends_loss() {
        // Ten SGD steps through the AOT artifact must reduce the loss —
        // the end-to-end L3→L2→L1 correctness check.
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let rt = Runtime::load(dir).unwrap();
        let meta = rt.model_meta("mlp").unwrap().clone();
        let exe = rt.executable("train_step_mlp").unwrap();
        let mut params = rt.init_params("mlp").unwrap();
        // Deterministic separable batch.
        let mut rng = crate::util::rng::Pcg64::seeded(5);
        let mut x = vec![0f32; meta.train_batch * meta.input_dim];
        let mut y = vec![0i32; meta.train_batch];
        for i in 0..meta.train_batch {
            let cls = (i % 10) as i32;
            y[i] = cls;
            for j in 0..meta.input_dim {
                let sig = if j % 10 == cls as usize { 2.0 } else { 0.0 };
                x[i * meta.input_dim + j] = sig + 0.1 * rng.normal() as f32;
            }
        }
        let run = |params: &Vec<f32>| {
            exe.run(&[
                TensorArg::F32(params, &[meta.q_params]),
                TensorArg::F32(&x, &[meta.train_batch, meta.input_dim]),
                TensorArg::I32(&y, &[meta.train_batch]),
            ])
            .unwrap()
        };
        let loss0 = run(&params)[0][0];
        for _ in 0..10 {
            let out = run(&params);
            for (p, g) in params.iter_mut().zip(&out[1]) {
                *p -= 0.1 * g;
            }
        }
        let loss1 = run(&params)[0][0];
        assert!(
            loss1 < loss0 * 0.8,
            "loss should descend: {loss0} → {loss1}"
        );
    }
}
