//! PJRT runtime (Layer 3 ⇄ Layer 2 bridge): load the AOT HLO-text artifacts
//! produced by `python/compile/aot.py`, compile them once on the PJRT CPU
//! client, and execute them from the training hot path with flat f32/i32
//! buffers. Python is never invoked here.
//!
//! * [`manifest`] — parses `artifacts/manifest.json` (shapes, Q, batches).
//! * [`client`] — `Runtime`: one PJRT client + compiled executables.
//! * [`oracle`] — `ModelOracle`: implements [`crate::fl::GradOracle`] on top
//!   of the `train_step`/`eval_step` executables plus the synthetic dataset.

pub mod client;
pub mod manifest;
pub mod oracle;

pub use client::{Executable, Runtime, TensorArg};
pub use manifest::{ArtifactMeta, Manifest, ModelMeta, TensorMeta};
pub use oracle::ModelOracle;
