//! PJRT runtime (Layer 3 ⇄ Layer 2 bridge): load the AOT HLO-text artifacts
//! produced by `python/compile/aot.py`, compile them once on the PJRT CPU
//! client, and execute them from the training hot path with flat f32/i32
//! buffers. Python is never invoked here.
//!
//! * [`manifest`] — parses `artifacts/manifest.json` (shapes, Q, batches).
//! * `client` — `Runtime`: one PJRT client + compiled executables.
//! * `oracle` — `ModelOracle`: implements [`crate::fl::GradOracle`] on top
//!   of the `train_step`/`eval_step` executables plus the synthetic dataset.
//!
//! The `client`/`oracle` pair links against the `xla` crate and is gated
//! behind the **`pjrt`** cargo feature; the default (offline) build swaps in
//! [`stub`], which exposes the identical API but whose constructors return
//! errors — so every caller compiles unchanged and the pure-Rust paths
//! (quadratic oracles, the scenario-matrix engine, the wireless model) work
//! with zero native dependencies.

#[cfg(feature = "pjrt")]
pub mod client;
pub mod manifest;
#[cfg(feature = "pjrt")]
pub mod oracle;
#[cfg(not(feature = "pjrt"))]
pub mod stub;

#[cfg(feature = "pjrt")]
pub use client::{Executable, Runtime, TensorArg};
pub use manifest::{ArtifactMeta, Manifest, ModelMeta, TensorMeta};
#[cfg(feature = "pjrt")]
pub use oracle::ModelOracle;
#[cfg(not(feature = "pjrt"))]
pub use stub::{Executable, ModelOracle, Runtime, TensorArg};
