//! PJRT runtime (Layer 3 ⇄ Layer 2 bridge): load the AOT HLO-text artifacts
//! produced by `python/compile/aot.py`, compile them once on the PJRT CPU
//! client, and execute them from the training hot path with flat f32/i32
//! buffers. Python is never invoked here.
//!
//! * [`manifest`] — parses `artifacts/manifest.json` (shapes, Q, batches).
//! * `client` — `Runtime`: one PJRT client + compiled executables.
//! * `oracle` — `ModelOracle`: implements [`crate::fl::GradOracle`] on top
//!   of the `train_step`/`eval_step` executables plus the synthetic dataset.
//!
//! ## Gating: the `pjrt` feature and the `pjrt_native` cfg
//!
//! The native `client`/`oracle` pair links against the `xla` crate, which
//! is not on the offline registry — so it compiles only when **both** the
//! `pjrt` cargo feature is enabled *and* the builder opts in with
//! `RUSTFLAGS="--cfg pjrt_native"` after adding the `xla` dependency (see
//! README.md §PJRT). Every other combination — the default build, and
//! `--features pjrt` alone — swaps in [`stub`], which exposes the
//! identical API but whose constructors return errors. This two-level
//! gate is what lets CI build and test the `pjrt` feature set offline
//! (catching signature bitrot in every caller) without the native
//! dependency. (`pjrt_native` is declared via `[lints.rust]
//! unexpected_cfgs` check-cfg in Cargo.toml.)

#[cfg(all(feature = "pjrt", pjrt_native))]
pub mod client;
pub mod manifest;
#[cfg(all(feature = "pjrt", pjrt_native))]
pub mod oracle;
#[cfg(not(all(feature = "pjrt", pjrt_native)))]
pub mod stub;

#[cfg(all(feature = "pjrt", pjrt_native))]
pub use client::{Executable, Runtime, TensorArg};
pub use manifest::{ArtifactMeta, Manifest, ModelMeta, TensorMeta};
#[cfg(all(feature = "pjrt", pjrt_native))]
pub use oracle::ModelOracle;
#[cfg(not(all(feature = "pjrt", pjrt_native)))]
pub use stub::{Executable, ModelOracle, Runtime, TensorArg};
