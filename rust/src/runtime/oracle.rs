//! `ModelOracle`: the production [`GradOracle`] — synthetic CIFAR-like data
//! partitioned across MUs, gradients computed by the AOT `train_step`
//! executable, metrics by `eval_step`. This is the object the coordinator
//! and the Fig. 6 / Table III experiments train with; no Python anywhere.

use super::client::{Runtime, TensorArg};
use crate::data::synthetic::IMAGE_DIM;
use crate::data::{Dataset, Partition, SyntheticSpec};
use crate::fl::oracle::{EvalMetrics, GradOracle};
use anyhow::Result;
use std::sync::Arc;

/// AOT-backed gradient oracle.
pub struct ModelOracle {
    train: Arc<super::client::Executable>,
    eval: Arc<super::client::Executable>,
    q: usize,
    train_batch: usize,
    eval_batch: usize,
    init: Vec<f32>,
    train_set: Dataset,
    test_set: Dataset,
    partition: Partition,
    // Reused batch buffers (no allocation in the hot loop).
    x_buf: Vec<f32>,
    y_buf: Vec<i32>,
    ex_buf: Vec<f32>,
    ey_buf: Vec<i32>,
}

impl ModelOracle {
    /// Build from a loaded runtime. `workers` MUs share `spec.n_train`
    /// samples in contiguous unshuffled shards (§V-B).
    pub fn new(rt: &Runtime, model: &str, workers: usize, spec: &SyntheticSpec) -> Result<Self> {
        let meta = rt.model_meta(model)?.clone();
        let (train_set, test_set) = crate::data::synthetic::generate(spec);
        let partition = Partition::contiguous(&train_set, workers, meta.train_batch);
        Ok(Self {
            train: rt.executable(&format!("train_step_{model}"))?,
            eval: rt.executable(&format!("eval_step_{model}"))?,
            q: meta.q_params,
            train_batch: meta.train_batch,
            eval_batch: meta.eval_batch,
            init: rt.init_params(model)?,
            x_buf: vec![0.0; meta.train_batch * IMAGE_DIM],
            y_buf: vec![0; meta.train_batch],
            ex_buf: vec![0.0; meta.eval_batch * IMAGE_DIM],
            ey_buf: vec![0; meta.eval_batch],
            train_set,
            test_set,
            partition,
        })
    }

    pub fn q_params(&self) -> usize {
        self.q
    }

    pub fn train_batch(&self) -> usize {
        self.train_batch
    }
}

impl GradOracle for ModelOracle {
    fn dim(&self) -> usize {
        self.q
    }

    fn n_workers(&self) -> usize {
        self.partition.n_workers()
    }

    fn loss_grad(&mut self, worker: usize, params: &[f32], grad_out: &mut [f32]) -> f64 {
        let idx = self.partition.shards[worker].next_batch(self.train_batch);
        self.train_set
            .fill_batch(&idx, &mut self.x_buf, &mut self.y_buf);
        let out = self
            .train
            .run(&[
                TensorArg::F32(params, &[self.q]),
                TensorArg::F32(&self.x_buf, &[self.train_batch, IMAGE_DIM]),
                TensorArg::I32(&self.y_buf, &[self.train_batch]),
            ])
            .expect("train_step execution failed");
        grad_out.copy_from_slice(&out[1]);
        out[0][0] as f64
    }

    fn eval(&mut self, params: &[f32]) -> EvalMetrics {
        let n = self.test_set.len();
        let chunks = n / self.eval_batch;
        assert!(chunks > 0, "test set smaller than eval batch");
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        for c in 0..chunks {
            let idx: Vec<usize> = (c * self.eval_batch..(c + 1) * self.eval_batch).collect();
            self.test_set
                .fill_batch(&idx, &mut self.ex_buf, &mut self.ey_buf);
            let out = self
                .eval
                .run(&[
                    TensorArg::F32(params, &[self.q]),
                    TensorArg::F32(&self.ex_buf, &[self.eval_batch, IMAGE_DIM]),
                    TensorArg::I32(&self.ey_buf, &[self.eval_batch]),
                ])
                .expect("eval_step execution failed");
            loss_sum += out[0][0] as f64;
            correct += out[1][0] as f64;
        }
        let seen = (chunks * self.eval_batch) as f64;
        EvalMetrics {
            loss: loss_sum / seen,
            accuracy: correct / seen,
        }
    }

    fn iters_per_epoch(&self) -> usize {
        self.partition.iters_per_epoch()
    }

    fn init_params(&mut self) -> Vec<f32> {
        self.init.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn runtime() -> Option<Runtime> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json")
            .exists()
            .then(|| Runtime::load(dir).unwrap())
    }

    fn spec() -> SyntheticSpec {
        SyntheticSpec {
            n_train: 512,
            n_test: 256,
            noise: 0.6,
            seed: 11,
            ..SyntheticSpec::default()
        }
    }

    #[test]
    fn oracle_grad_and_eval_work() {
        let Some(rt) = runtime() else {
            eprintln!("skipping: run `make artifacts`");
            return;
        };
        let mut o = ModelOracle::new(&rt, "mlp", 4, &spec()).unwrap();
        let params = o.init_params();
        let mut grad = vec![0.0f32; o.dim()];
        let loss = o.loss_grad(0, &params, &mut grad);
        assert!(loss > 0.5 && loss < 6.0, "loss {loss}");
        assert!(grad.iter().any(|&g| g != 0.0));
        let m = o.eval(&params);
        // Untrained: accuracy ≈ 10%, loss ≈ ln 10.
        assert!(m.accuracy < 0.35, "untrained accuracy {}", m.accuracy);
        assert!((m.loss - 10f64.ln()).abs() < 1.0, "loss {}", m.loss);
    }

    #[test]
    fn short_fl_training_improves_accuracy() {
        // End-to-end: Algorithm 1 over the AOT model must beat chance
        // quickly on the synthetic set — the L1+L2+L3 composition proof.
        let Some(rt) = runtime() else {
            return;
        };
        let mut o = ModelOracle::new(&rt, "mlp", 4, &spec()).unwrap();
        let opts: crate::fl::TrainOptions = crate::spec::RunSpec::new()
            .iters(40)
            .peak_lr(0.05)
            .warmup(5)
            .momentum(0.9)
            .into();
        let log = crate::fl::fl(&mut o, &opts);
        let m = log.final_eval().unwrap();
        assert!(
            m.accuracy > 0.5,
            "40 iters should separate synthetic classes: acc {}",
            m.accuracy
        );
    }
}
